"""Cross-pod gradient compression: int8 quantisation with per-block scales.

The multi-pod mesh carries pure data parallelism on the 'pod' axis; its
all-reduce crosses the slow inter-pod links, so we compress: blocks agree on a
shared scale (one cheap pmax of per-block absmax), quantise to int8, all-reduce
the int8 payload as exact int32 partial sums, and dequantise — ~4× less
cross-pod traffic for ≤1/127 per-block relative error (validated in tests).

Used inside shard_map over the 'pod' axis from train_step, or standalone.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _blocked(x: jnp.ndarray) -> jnp.ndarray:
    flat = x.astype(jnp.float32).reshape(-1)
    nb = -(-flat.shape[0] // BLOCK)
    return jnp.pad(flat, (0, nb * BLOCK - flat.shape[0])).reshape(nb, BLOCK)


def quantize(x: jnp.ndarray, scale: jnp.ndarray | None = None):
    """x → (int8 blocks (nb, BLOCK), f32 scales (nb,)).  A caller-provided
    shared ``scale`` (≥ local absmax/127) keeps quantisation exact-summable."""
    blocks = _blocked(x)
    if scale is None:
        scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale[:, None], 1e-20)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum_mean(grads, axis_name: str = "pod"):
    """Mean-all-reduce a gradient pytree across `axis_name` in int8.

    Protocol: (1) pmax per-block absmax → shared scale (tiny payload);
    (2) int8 quantise with the shared scale; (3) psum int8 as int32 — exact;
    (4) dequantise and divide by pod count.
    """
    npods = jax.lax.psum(1, axis_name)

    def one(g):
        blocks = _blocked(g)
        local_max = jnp.max(jnp.abs(blocks), axis=1)
        scale = jax.lax.pmax(local_max, axis_name) / 127.0
        q, _ = quantize(g, scale)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return dequantize(q_sum.astype(jnp.float32) / npods, scale, g.shape, g.dtype)

    return jax.tree.map(one, grads)


def compression_ratio(shape, dtype_bytes: int = 4) -> float:
    """Payload reduction: int8 + 1 f32 scale per 256 elements vs f32."""
    n = 1
    for d in shape:
        n *= d
    raw = n * dtype_bytes
    comp = n * 1 + (-(-n // BLOCK)) * 4
    return raw / comp
