"""Jitted distributed train step: value_and_grad → clip → AdamW, with
optional cross-pod int8 gradient compression.

GSPMD handles the in-pod gradient reduction (batch is sharded over
('pod','data'); XLA inserts reduce-scatter/all-gather pairs it can overlap
with backprop).  When ``compress_pods`` is on, the 'pod' axis is excluded from
the automatic reduction by running loss/grad inside shard_map with the pod
axis manual — gradients then cross pods as int8 (training.compress).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as sh
from repro.models.registry import ModelApi

from . import compress, optimizer as opt


def build_train_step(api: ModelApi, mesh: Mesh, acfg: opt.AdamWConfig,
                     compress_pods: bool = False, microbatch: int = 0):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return api.train_loss(params, mesh=mesh, **batch)

    def _vg(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        # pin gradient dtypes to the parameter dtypes (x64 contexts can let
        # f64 cotangents leak out of mixed-precision einsum backward passes)
        grads = jax.tree.map(lambda g, q: g.astype(q.dtype), grads, params)
        return loss.astype(jnp.float32), grads

    def grads_of(params, batch):
        if microbatch and microbatch > 1:
            # gradient accumulation over microbatches (sequential scan)
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatch, b // microbatch, *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_step(carry, mb_i):
                loss_acc, g_acc = carry
                loss_i, g_i = _vg(params, mb_i)
                return (loss_acc + loss_i,
                        jax.tree.map(jnp.add, g_acc, g_i)), None

            zero = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_step, (jnp.zeros((), jnp.float32), zero), mb)
            inv = 1.0 / microbatch
            return loss * inv, jax.tree.map(lambda g: g * inv, grads)
        return _vg(params, batch)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        if compress_pods and "pod" in mesh.shape and mesh.shape["pod"] > 1:
            grads = _pod_compress(grads, mesh)
        params, opt_state, gnorm = opt.apply_updates(acfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm,
                                   "lr": opt.lr_at(acfg, opt_state["step"] - 1)}

    return train_step


def _pod_compress(grads, mesh: Mesh):
    """int8 all-reduce of the cross-pod gradient component.

    Grads arriving here are already averaged over 'pod' by GSPMD when the
    batch is pod-sharded; for the explicit-compression path we instead mark
    the batch pod-replicated and do the pod reduction ourselves in int8.
    """
    from jax.experimental.shard_map import shard_map

    spec = P()  # gradients handled as pod-replicated blocks per shard

    def red(g):
        fn = shard_map(
            lambda x: compress.compressed_psum_mean(x, "pod"),
            mesh=mesh,
            in_specs=P("pod"),
            out_specs=P("pod"),
            check_rep=False,
        )
        flat = g.reshape(-1)
        n = flat.shape[0]
        npod = mesh.shape["pod"]
        pad = (-n) % npod
        out = fn(jnp.pad(flat, (0, pad)).reshape(npod, -1))
        return out.reshape(-1)[:n].reshape(g.shape)

    return jax.tree.map(red, grads)


def jit_train_step(api: ModelApi, mesh: Mesh, acfg: opt.AdamWConfig,
                   batch_specs: dict, compress_pods: bool = False,
                   microbatch: int = 0, donate: bool = True):
    """jit with explicit in/out shardings — the dry-run entry point."""
    pspecs = api.param_specs(mesh)
    sspecs = opt.state_specs(pspecs)
    step = build_train_step(api, mesh, acfg, compress_pods, microbatch)
    in_sh = (
        sh.tree_shardings(mesh, pspecs),
        sh.tree_shardings(mesh, sspecs),
        {k: NamedSharding(mesh, v) for k, v in batch_specs.items()},
    )
    out_sh = (
        sh.tree_shardings(mesh, pspecs),
        sh.tree_shardings(mesh, sspecs),
        {"loss": NamedSharding(mesh, P()), "grad_norm": NamedSharding(mesh, P()),
         "lr": NamedSharding(mesh, P())},
    )
    return jax.jit(
        step, in_shardings=in_sh, out_shardings=out_sh,
        donate_argnums=(0, 1) if donate else (),
    )
