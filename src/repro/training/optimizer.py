"""AdamW with global-norm clipping (pure JAX; no optax dependency).

Moments are float32 and share the parameters' PartitionSpecs, so optimizer
state is ZeRO-sharded wherever weights are FSDP-sharded.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: AdamWConfig, step):
    warm = cfg.lr_peak * (step + 1) / cfg.warmup_steps
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * cfg.lr_peak * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_specs(param_specs) -> dict:
    """Optimizer state PartitionSpecs mirror the parameters'."""
    from jax.sharding import PartitionSpec as P

    return {"m": param_specs, "v": param_specs, "step": P()}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step; returns (new_params, new_state, grad_norm)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, state["step"])
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
