"""repro.training"""
