"""FLASH-FHE core: heterogeneous clusters, multi-job scheduler, simulator.

The paper's contribution as a composable library:
  hardware   — chip configs (FLASH-FHE + CraterLake/F1+ baselines), area/power
  jobs       — workload descriptions + deep/shallow classifier
  planner    — static instruction-stream generation (the "software driver")
  cache      — hierarchical L1/L2 SRAM model
  simulator  — cycle-level throughput model over instruction streams
  scheduler  — multi-job placement: 1 shallow job/affiliation, deep = all
               bootstrappable clusters, priority preemption (a thin wrapper
               over the discrete-event engine in repro.serve)
  executor   — shard_map execution of parallel shallow jobs (affiliation =
               device group), numerically real
"""

from . import cache, executor, hardware, jobs, planner, scheduler, simulator  # noqa: F401
