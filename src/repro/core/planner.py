"""Static instruction-stream planner — the paper's "software driver".

FHE programs are data-oblivious, so every workload expands to a fixed stream of
hardware instructions (NTT/INTT/BCONV/PMULT/PADD/PSUB/AUTO/LOAD_*).  This
module generates those streams *analytically* from the cryptographic
parameters; `tests/test_planner.py` validates the expansions against traces
captured from the real executable FHE library (multiset equality) — the same
instruction stream drives both the numerics and the cycle simulator.

Two modes:
  * mode="exec" mirrors repro.fhe exactly (incl. on-the-fly plaintext encodes
    and the full Chebyshev basis) — used for validation;
  * mode="hw" is what the accelerator would run: plaintexts are precomputed
    (LOAD_PT), EvalMod uses the Paterson–Stockmeyer mult count (~2√d), and
    CtS/StC matvec pairs share baby rotations (the paper's cache-hit-ratio
    scheduling optimisation).
"""

from __future__ import annotations

import dataclasses
import math

from repro.fhe.trace import Instr


@dataclasses.dataclass(frozen=True)
class PlanParams:
    """The crypto-parameter subset the planner needs."""

    n: int
    L: int
    alpha: int

    def beta(self, level: int) -> int:
        return -(-(level + 1) // self.alpha)

    def digit_size(self, j: int, level: int) -> int:
        lo = j * self.alpha
        hi = min((j + 1) * self.alpha, level + 1)
        return max(0, hi - lo)

    @classmethod
    def of(cls, params) -> "PlanParams":
        return cls(n=params.n, L=params.L, alpha=params.alpha)


def I(op: str, n: int, limbs: int, **meta) -> Instr:
    return Instr(op, n, limbs, meta)


# ---------------------------------------------------------------------------
# compound-op expansions (mirror repro.fhe exactly in mode="exec")
# ---------------------------------------------------------------------------


def _ws(n: int, limbs: int, fused: bool) -> list[Instr]:
    """Stage-boundary working-set round-trip: only the staged pipeline pays it.

    Mirrors ``repro.fhe.keyswitch``: a fused key-switch keeps every per-digit
    intermediate in VMEM, while the staged dispatch train stores + reloads it
    through HBM-equivalent buffers between kernel launches.
    """
    if fused:
        return []
    return [I("STORE_WS", n, limbs), I("LOAD_WS", n, limbs)]


def key_switch_accumulate(pp: PlanParams, level: int, fused: bool = True) -> list[Instr]:
    """Stages 1–4 of a key switch (digit decompose + KSK MAC), before ModDown.

    Mirrors ``repro.fhe.keyswitch.key_switch_accumulate`` — the seam BGV's
    t-wrapped relinearisation shares with the CKKS pipeline."""
    n = pp.n
    beta = pp.beta(level)
    nq = level + 1
    ext = nq + pp.alpha
    out = [I("LOAD_KSK", n, beta * 2 * ext, ext=ext, nq=nq, beta=beta)]
    out.append(I("INTT", n, nq))
    for j in range(beta):
        k = pp.digit_size(j, level)
        out += [I("PMULT", n, k, fused=fused)]  # B̂⁻¹ prescale
        out += _ws(n, k, fused)
        out += [I("BCONV", n, k, dst=ext, fused=fused)]
        out += _ws(n, ext, fused)
        out += [I("NTT", n, ext, fused=fused)]
        out += _ws(n, ext, fused)
        out += [I("PMULT", n, 2 * ext, mac=True, fused=fused)]  # ksk MAC rides the NTT exit
        out += _ws(n, 2 * ext, fused)
        out += [I("PADD", n, 2 * ext, mac=True, fused=fused)]   # when the chip fuses it
    return out


def key_switch(pp: PlanParams, level: int, fused: bool = True) -> list[Instr]:
    return key_switch_accumulate(pp, level, fused) + mod_down(pp, level, fused) * 2


def mod_up(pp: PlanParams, level: int, fused: bool = True) -> list[Instr]:
    """Digit decomposition + raise to the extended basis — the shared
    (rotation-independent) half of a key-switch.  Mirrors
    ``repro.fhe.keyswitch.hoisted_mod_up``: the materialised digits round-trip
    to the later MAC launches (one STORE/LOAD pair of β·ext limbs), in both
    pipelines — that boundary is the price of reusing them."""
    n, nq = pp.n, level + 1
    ext = nq + pp.alpha
    beta = pp.beta(level)
    out = [I("INTT", n, nq)]
    for j in range(beta):
        k = pp.digit_size(j, level)
        out += [I("PMULT", n, k, fused=fused)]
        out += _ws(n, k, fused)
        out += [I("BCONV", n, k, dst=ext, fused=fused)]
        out += _ws(n, ext, fused)
        out += [I("NTT", n, ext, fused=fused)]
    out += [I("STORE_WS", n, beta * ext), I("LOAD_WS", n, beta * ext)]
    return out


def hoisted_rotations(pp: PlanParams, level: int, n_rots: int,
                      fused: bool = True) -> list[Instr]:
    """Halevi–Shoup hoisting: one ModUp shared by ``n_rots`` rotations of the
    same ciphertext; each rotation then costs only ksk-MAC + ModDown + the
    folded automorphism (no per-rotation BConv/NTT through the extended
    basis: β + O(1) forward ext-NTTs per group instead of n_rots·β).

    Mirrors ``ctx.rotate_hoisted_group`` exactly: per rotation one
    KSK stream + β MAC pairs + a ModDown pair + the c0 add + one AUTO per
    output component (keys are σ_t^{-1}-pre-permuted, so the automorphism
    lands once, after ModDown)."""
    n, nq = pp.n, level + 1
    ext = nq + pp.alpha
    beta = pp.beta(level)
    out = mod_up(pp, level, fused)
    for _ in range(n_rots):
        out += [I("LOAD_KSK", n, beta * 2 * ext, ext=ext, nq=nq, beta=beta)]
        for _j in range(beta):
            out += [I("PMULT", n, 2 * ext, mac=True, fused=fused)]
            out += _ws(n, 2 * ext, fused)
            out += [I("PADD", n, 2 * ext, mac=True, fused=fused)]
        out += mod_down(pp, level, fused) * 2
        out += [I("PADD", n, nq), I("AUTO", n, nq), I("AUTO", n, nq)]
    return out


def mod_down(pp: PlanParams, level: int, fused: bool = True) -> list[Instr]:
    n, nq, a = pp.n, level + 1, pp.alpha
    out = [I("INTT", n, a)]
    out += [I("PMULT", n, a, fused=fused)]  # P̂⁻¹ prescale
    out += _ws(n, a, fused)
    out += [I("BCONV", n, a, dst=nq, fused=fused)]
    out += _ws(n, nq, fused)
    out += [I("NTT", n, nq, fused=fused)]
    out += _ws(n, nq, fused)
    out += [I("PSUB", n, nq, mac=True, fused=fused)]   # post-NTT elementwise stage — rides the
    out += _ws(n, nq, fused)
    out += [I("PMULT", n, nq, mac=True, fused=fused)]  # exit MACs on fused_exit_mac chips
    return out


def rescale(pp: PlanParams, level: int) -> list[Instr]:
    n, lv = pp.n, level
    one = [I("INTT", n, 1), I("NTT", n, lv),
           I("PSUB", n, lv, mac=True), I("PMULT", n, lv, mac=True)]
    return one * 2  # c0 and c1


def hmul(pp: PlanParams, level: int, rescale_after: bool = True, fused: bool = True) -> list[Instr]:
    n, nq = pp.n, level + 1
    out = [I("PMULT", n, 4 * nq), I("PADD", n, nq)]
    out += key_switch(pp, level, fused)
    out += [I("PADD", n, 2 * nq)]
    if rescale_after:
        out += rescale(pp, level)
    return out


# ---------------------------------------------------------------------------
# BGV expansions (mirror repro.fhe.bgv exactly)
# ---------------------------------------------------------------------------


def bgv_relin(pp: PlanParams, level: int, fused: bool = True) -> list[Instr]:
    """BGV relinearisation: the shared key-switch accumulate with the ModDown
    wrapped in the t-scaling sandwich (``repro.fhe.bgv._relin``): one t^{-1}
    pre-twist PMULT per accumulator over the extended basis, the unchanged
    ModDown pair, one t post-twist PMULT per component over the active basis."""
    n, nq = pp.n, level + 1
    ext = nq + pp.alpha
    out = key_switch_accumulate(pp, level, fused)
    out += [I("PMULT", n, ext)] * 2          # t^{-1} pre-twist, both accumulators
    out += mod_down(pp, level, fused) * 2
    out += [I("PMULT", n, nq)] * 2           # t post-twist, both components
    return out


def bgv_mod_switch(pp: PlanParams, level: int) -> list[Instr]:
    """BGV modulus switch (``repro.fhe.bgv._mod_switch``): the CKKS rescale
    dataflow plus one single-limb PMULT per component for the t^{-1} twist of
    the dropped limb."""
    n, lv = pp.n, level
    one = [I("INTT", n, 1), I("PMULT", n, 1), I("NTT", n, lv),
           I("PSUB", n, lv, mac=True), I("PMULT", n, lv, mac=True)]
    return one * 2  # c0 and c1


def bgv_hmul(pp: PlanParams, level: int, mod_switch_after: bool = True,
             fused: bool = True) -> list[Instr]:
    n, nq = pp.n, level + 1
    out = [I("PMULT", n, 4 * nq), I("PADD", n, nq)]
    out += bgv_relin(pp, level, fused)
    out += [I("PADD", n, 2 * nq)]
    if mod_switch_after:
        out += bgv_mod_switch(pp, level)
    return out


def mul_plain(pp: PlanParams, level: int, rescale_after: bool = True,
              mode: str = "exec") -> list[Instr]:
    n, nq = pp.n, level + 1
    out = []
    out += [I("NTT", n, nq)] if mode == "exec" else [I("LOAD_PT", n, nq)]
    out += [I("PMULT", n, 2 * nq)]
    if rescale_after:
        out += rescale(pp, level)
    return out


def add_ct(pp: PlanParams, level: int) -> list[Instr]:
    return [I("PADD", pp.n, 2 * (level + 1))]


def rotate(pp: PlanParams, level: int, fused: bool = True) -> list[Instr]:
    n, nq = pp.n, level + 1
    return (
        [I("AUTO", n, nq), I("AUTO", n, nq)]
        + key_switch(pp, level, fused)
        + [I("PADD", n, nq)]
    )


def encrypt(pp: PlanParams, level: int) -> list[Instr]:
    n, nq = pp.n, level + 1
    return [I("NTT", n, nq)] * 3 + [I("PMULT", n, 2 * nq), I("PADD", n, nq)] * 2


# ---------------------------------------------------------------------------
# BSGS linear transform (CtS / StC / encrypted matmul building block)
# ---------------------------------------------------------------------------


def bsgs_matvec(
    pp: PlanParams, level: int, n_diags: int, n1: int,
    mode: str = "exec", share_babies: bool = False, hoist: bool = False,
    fused: bool = True,
) -> list[Instr]:
    n, nq = pp.n, level + 1
    babies = sorted({d % n1 for d in range(n_diags)} - {0})
    giants = sorted({d // n1 for d in range(n_diags)} - {0})
    out: list[Instr] = []
    if hoist and not share_babies and babies:
        # Halevi–Shoup: the whole baby group shares one ModUp
        out += hoisted_rotations(pp, level, len(babies), fused=fused)
    elif not share_babies:
        for _ in babies:
            out += rotate(pp, level, fused)
    for d in range(n_diags):
        out += [I("NTT", n, nq)] if mode == "exec" else [I("LOAD_PT", n, nq)]
        out += [I("PMULT", n, 2 * nq)]
    # adds inside giant groups: one per diagonal beyond the first of its group
    n_groups = len(giants) + 1
    out += [I("PADD", n, 2 * nq)] * (n_diags - n_groups)
    for _ in giants:
        out += rotate(pp, level, fused)
    out += [I("PADD", n, 2 * nq)] * (n_groups - 1)
    out += rescale(pp, level)
    return out


def conjugate(pp: PlanParams, level: int, fused: bool = True) -> list[Instr]:
    return rotate(pp, level, fused)


# ---------------------------------------------------------------------------
# bootstrapping
# ---------------------------------------------------------------------------


def chebyshev_basis_full(pp: PlanParams, level: int, degree: int,
                         fused: bool = True) -> list[Instr]:
    """mode="exec": T_2..T_degree each one hmul (+ alignment ops, counted coarsely)."""
    out: list[Instr] = []
    lv = level
    depth_of = lambda j: math.ceil(math.log2(j)) if j > 1 else 0
    for j in range(2, degree + 1):
        lj = level - depth_of(j)
        out += hmul(pp, lj + 1 - 1, fused=fused)  # product at the operand level
    return out


def eval_mod(pp: PlanParams, level: int, degree: int, mode: str = "exec",
             fused: bool = True) -> list[Instr]:
    """Normalise + Chebyshev basis + linear combination.

    mode="hw" uses the Paterson–Stockmeyer count: k = ⌈√(d+1)⌉ babies +
    log-many giants + ~d/k block combinations, each one ct-ct mult.
    """
    n = pp.n
    out = mul_plain(pp, level, mode=mode)  # exact-scale normalisation
    lv = level - 1
    if mode == "exec":
        out += chebyshev_basis_full(pp, lv, degree, fused=fused)
        n_terms = (degree + 1) // 2  # odd sine coefficients
        for _ in range(n_terms):
            out += mul_plain(pp, lv, mode=mode)
        out += [I("PADD", n, 2 * lv)] * (n_terms - 1)
    else:
        k = 1 << math.ceil(math.log2(degree + 1) / 2)
        giants = math.ceil(math.log2((degree + 1) / k)) if (degree + 1) > k else 0
        n_mults = (k - 1) + giants + math.ceil((degree + 1) / k)
        for i in range(n_mults):
            out += hmul(pp, max(1, lv - depth_estimate(i, k)), fused=fused)
        out += [I("LOAD_PT", n, lv), I("PMULT", n, 2 * lv)] * (degree // 2)
        out += [I("PADD", n, 2 * lv)] * (degree // 2)
    return out


def depth_estimate(i: int, k: int) -> int:
    return min(6, int(math.log2(i + 2)))


def mod_raise(pp: PlanParams) -> list[Instr]:
    n, L = pp.n, pp.L
    return [I("MODRAISE", n, L + 1)] + [I("INTT", n, 1), I("NTT", n, L + 1)] * 2


def _dft_transform(pp: PlanParams, level: int, mode: str, radix: int = 32,
                   hoist: bool = False, fused: bool = True) -> tuple[list[Instr], int]:
    """CoeffToSlot/SlotToCoeff as homomorphic DFT.

    mode="exec" mirrors the executable library: one dense matvec (all `slots`
    diagonals).  mode="hw" uses the level-collapsed FFT factorisation real
    deployments use (Lattigo/CraterLake): ⌈log_radix(slots)⌉ stages of sparse
    matvecs with 2·radix−1 diagonals each — ~100× fewer rotations at N=2^16.
    Returns (stream, levels_consumed_per_matvec_chain).
    """
    slots = pp.n // 2
    out: list[Instr] = []
    if mode == "exec":
        n1 = max(1, 1 << int(round(math.log2(math.sqrt(slots)))))
        out += bsgs_matvec(pp, level, slots, n1, mode=mode, hoist=hoist, fused=fused)
        return out, 1
    stages = max(1, math.ceil(math.log(slots, radix)))
    diags = 2 * radix - 1
    n1 = max(1, 1 << int(round(math.log2(math.sqrt(diags)))))
    lv = level
    for _ in range(stages):
        out += bsgs_matvec(pp, lv, diags, n1, mode=mode, hoist=hoist, fused=fused)
        lv -= 1
    return out, stages


def bootstrap(
    pp: PlanParams, degree: int, mode: str = "exec", n1: int | None = None,
    hoist: bool = False, fused: bool = True,
) -> list[Instr]:
    """Full packed bootstrapping instruction stream."""
    n = pp.n
    out = mod_raise(pp)
    L = pp.L
    # CoeffToSlot: two transform chains (+2 conjugations for the real parts)
    s0, used = _dft_transform(pp, L, mode, hoist=hoist, fused=fused)
    s1, _ = _dft_transform(pp, L, mode, hoist=hoist, fused=fused)
    out += s0 + s1
    lv = L - used
    out += conjugate(pp, lv, fused) + [I("PADD", n, 2 * (lv + 1))]
    out += conjugate(pp, lv, fused) + [I("PADD", n, 2 * (lv + 1))]
    # EvalMod on both halves
    out += eval_mod(pp, lv, degree, mode=mode, fused=fused) * 2
    # SlotToCoeff
    cheb_depth = math.ceil(math.log2(max(2, degree))) + 1
    lv2 = max(1, lv - 1 - cheb_depth)
    s2, _ = _dft_transform(pp, lv2, mode, hoist=hoist, fused=fused)
    s3, _ = _dft_transform(pp, lv2, mode, hoist=hoist, fused=fused)
    out += s2 + s3
    out += [I("PADD", n, 2 * max(1, lv2 - used))]
    return out


# ---------------------------------------------------------------------------
# workload programs (paper §6.1) — op-level graphs expanded to instructions
# ---------------------------------------------------------------------------


import contextvars

# (hoist, fused) plan flags for the workload expansion below — set per
# workload_stream call so the _WORKLOADS bodies stay signature-stable.
_PLAN: contextvars.ContextVar[tuple[bool, bool]] = contextvars.ContextVar(
    "plan_flags", default=(False, True)
)


def _plan_hoist() -> bool:
    return _PLAN.get()[0]


def _plan_fused() -> bool:
    return _PLAN.get()[1]


def workload_stream(name: str, params, mode: str = "hw", hoist: bool = False,
                    policy=None) -> list[Instr]:
    """Expand one workload to its instruction stream.

    ``policy`` (an ``repro.fhe.context.ExecPolicy``) is the context-first way
    to choose the mirrored trace shape: ``policy.plan_hoist`` selects hoisted
    BSGS baby groups and ``policy.plan_fused`` selects the fused key-switch
    pipeline (no working-set boundary records).  The legacy ``hoist=`` bool is
    honoured when no policy is given (with the fused pipeline, as before).
    """
    pp = PlanParams.of(params)
    fn = _WORKLOADS[name]
    if policy is not None:
        flags = (policy.plan_hoist, policy.plan_fused)
    else:
        flags = (hoist, True)
    tok = _PLAN.set(flags)
    try:
        stream = fn(pp, mode)
    finally:
        _PLAN.reset(tok)
    if mode == "hw":
        stream = add_hw_annotations(stream, pp)
    return stream


# Working-set factor: digit-raised polys, two accumulators, ModDown temporaries
# and double-buffering across the fused pipeline ≈ WS_FACTOR·ext limb-polys.
# Calibrated so the dnum=1, N=2^16, L=57 key-switch saturates at ~320 MB —
# the paper's own Fig-8 design point for choosing the cache volume.
WS_FACTOR = 9


def add_hw_annotations(stream: list[Instr], pp: PlanParams) -> list[Instr]:
    """Insert key-switch working-set touches (drives the Fig-8 cache sweep)."""
    out: list[Instr] = []
    for ins in stream:
        out.append(ins)
        if ins.op == "LOAD_KSK" and "ext" in ins.meta:
            ws_limbs = WS_FACTOR * ins.meta["ext"]
            out.append(I("TOUCH_WS", ins.n, ws_limbs, ksk_limbs=ins.limbs))
    return out


def _w_matmul(pp: PlanParams, mode: str) -> list[Instr]:
    """100×1000 @ 1000×10 encrypted matmul (§3.2): diagonal method.

    Rows packed across slots; 1000-dim contraction via log-rotations & pt-muls.
    """
    lv = pp.L
    out: list[Instr] = []
    cols = 10
    for _ in range(cols):
        out += mul_plain(pp, lv, mode=mode)
    for _ in range(int(math.log2(1024)) * cols):  # rotate-and-add reduction
        out += rotate(pp, lv - 1, _plan_fused()) + add_ct(pp, lv - 1)
    return out


def _w_dblookup(pp: PlanParams, mode: str) -> list[Instr]:
    """BGV country-lookup with binary-encoded keys (§3.2): depth-log2(|key|)
    equality circuit + masked aggregation."""
    lv = pp.L
    out: list[Instr] = []
    key_bits = 8
    lvl = lv
    for _ in range(key_bits):  # bitwise XNOR via (1-a-b+2ab): 1 hmul each
        out += hmul(pp, lvl, fused=_plan_fused())
        lvl -= 1
    for _ in range(int(math.log2(key_bits))):  # AND-tree
        out += hmul(pp, lvl, fused=_plan_fused())
        lvl -= 1
    for _ in range(64):  # table mask-and-aggregate
        out += mul_plain(pp, lvl, mode=mode) + add_ct(pp, max(1, lvl - 1))
    return out


def _w_lola_mnist(pp: PlanParams, mode: str, encrypted_weights: bool = False) -> list[Instr]:
    """LoLa-MNIST (§6.1): dense 785→1000 (as BSGS matvec), square, dense
    1000→10, square — the low-latency packed pipeline."""
    lv = pp.L
    out = bsgs_matvec(pp, lv, 64, 8, mode=mode, hoist=_plan_hoist(), fused=_plan_fused())
    lvl = lv - 1
    if encrypted_weights:
        out += hmul(pp, lvl, fused=_plan_fused())  # ct×ct matvec core surrogate
        lvl -= 1
    out += hmul(pp, lvl, fused=_plan_fused())  # square activation
    lvl -= 1
    out += bsgs_matvec(pp, lvl, 32, 4, mode=mode, hoist=_plan_hoist(), fused=_plan_fused())
    lvl -= 1
    out += hmul(pp, lvl, fused=_plan_fused())  # square activation
    return out


def _w_lola_cifar(pp: PlanParams, mode: str) -> list[Instr]:
    """LoLa-CIFAR (§6.1): conv 8×8×83 → pool → dense, squares between."""
    lv = pp.L
    out: list[Instr] = []
    lvl = lv
    for _ in range(16):  # conv as shifted pt-muls
        out += mul_plain(pp, lvl, mode=mode) + rotate(pp, lvl - 1, _plan_fused()) + add_ct(pp, lvl - 1)
    lvl -= 1
    out += hmul(pp, lvl, fused=_plan_fused())  # square
    lvl -= 1
    out += bsgs_matvec(pp, lvl, 128, 8, mode=mode, hoist=_plan_hoist(), fused=_plan_fused())
    lvl -= 1
    out += hmul(pp, lvl, fused=_plan_fused())  # square
    lvl -= 1
    out += bsgs_matvec(pp, lvl, 32, 4, mode=mode, hoist=_plan_hoist(), fused=_plan_fused())
    return out


def _w_logreg(pp: PlanParams, mode: str) -> list[Instr]:
    """HE logistic regression (Han et al.): one mini-batch iteration, batch 256,
    256 features; sigmoid ≈ degree-7 poly; bootstrap when the level budget
    nears exhaustion."""
    out: list[Instr] = []
    lvl = pp.L
    # X·w: BSGS matvec over packed features
    out += bsgs_matvec(pp, lvl, 256, 16, mode=mode, hoist=_plan_hoist(), fused=_plan_fused())
    lvl -= 1
    # sigmoid degree-7 (3 mult levels, 4 mults)
    for _ in range(4):
        out += hmul(pp, lvl, fused=_plan_fused())
        lvl -= 1 if _ % 2 else 0
    lvl -= 2
    # gradient: Xᵀ·err matvec + weight update
    out += bsgs_matvec(pp, lvl, 256, 16, mode=mode, hoist=_plan_hoist(), fused=_plan_fused())
    lvl -= 1
    out += mul_plain(pp, lvl, mode=mode) + add_ct(pp, lvl - 1)
    # bootstrap once per iteration (level budget exhausted)
    out += bootstrap(pp, degree=63, mode=mode, hoist=_plan_hoist(), fused=_plan_fused())
    return out


def _w_lstm(pp: PlanParams, mode: str) -> list[Instr]:
    """One LSTM unit (Podschwadt-Takabi): 4 gates = 8 matvecs + 3 ct×ct
    (element gates) + tanh/sigmoid poly approx; bootstrap per unit."""
    out: list[Instr] = []
    lvl = pp.L
    for _ in range(8):  # W_g·x and U_g·h for 4 gates
        out += bsgs_matvec(pp, lvl, 128, 8, mode=mode, hoist=_plan_hoist(), fused=_plan_fused())
    lvl -= 1
    for _ in range(4 * 2):  # activation polys (deg-3: 2 mults each)
        out += hmul(pp, max(1, lvl), fused=_plan_fused())
        lvl -= 1 if _ % 4 == 3 else 0
    for _ in range(3):  # gate element-products
        out += hmul(pp, max(1, lvl), fused=_plan_fused())
    out += bootstrap(pp, degree=63, mode=mode, hoist=_plan_hoist(), fused=_plan_fused())
    return out


def _w_resnet20(pp: PlanParams, mode: str) -> list[Instr]:
    """ResNet-20 CIFAR inference (Lee et al.): 19 conv + FC layers, ReLU ≈
    high-degree poly; ~2 bootstraps per residual block (paper runs N=2^16,
    L=41)."""
    out: list[Instr] = []
    lvl = pp.L
    for block in range(9):  # 9 residual blocks
        for _ in range(2):  # two convs per block (as BSGS matvecs over channels)
            out += bsgs_matvec(pp, max(4, lvl), 64, 8, mode=mode, hoist=_plan_hoist(), fused=_plan_fused())
            lvl = max(4, lvl - 1)
            for _ in range(6):  # poly-ReLU mults
                out += hmul(pp, max(2, lvl), fused=_plan_fused())
            lvl = max(4, lvl - 3)
        out += add_ct(pp, max(1, lvl))  # residual add
        out += bootstrap(pp, degree=63, mode=mode, hoist=_plan_hoist(), fused=_plan_fused())
        lvl = pp.L - 14  # post-bootstrap budget
    out += bsgs_matvec(pp, max(4, lvl), 64, 8, mode=mode, hoist=_plan_hoist(), fused=_plan_fused())  # final FC
    return out


def _w_psi(pp: PlanParams, mode: str) -> list[Instr]:
    """Private set intersection (BGV, t=2): 32-bit identifiers bit-packed into
    slots.  XNOR bit-equality is additive over GF(2) (1 + a + b — PADDs only);
    the log-depth AND-tree is the multiplicative core; per-bin plaintext masks
    aggregate the matches."""
    out: list[Instr] = []
    lvl = pp.L
    key_bits = 32
    for _ in range(key_bits):  # XNOR layer: one ct add per bit position
        out += add_ct(pp, lvl)
    for _ in range(int(math.log2(key_bits))):  # AND-tree: depth log2(bits)
        out += bgv_hmul(pp, lvl, fused=_plan_fused())
        lvl -= 1
    for _ in range(16):  # per-bin mask-and-aggregate (no level cost)
        out += mul_plain(pp, lvl, rescale_after=False, mode=mode) + add_ct(pp, lvl)
    return out


def _w_exact_count(pp: PlanParams, mode: str) -> list[Instr]:
    """Exact-count aggregation (BGV, t=2^16): two predicate products (range /
    one-hot filters), then 64 groups of plaintext mask-and-accumulate — exact
    16-bit counters, no approximation error to budget for."""
    out: list[Instr] = []
    lvl = pp.L
    for _ in range(2):
        out += bgv_hmul(pp, lvl, fused=_plan_fused())
        lvl -= 1
    for _ in range(64):
        out += mul_plain(pp, lvl, rescale_after=False, mode=mode) + add_ct(pp, lvl)
    return out


def _w_packed_bootstrap(pp: PlanParams, mode: str) -> list[Instr]:
    """Paper §6.1: exhaust L then refresh — the bootstrap stream itself."""
    out: list[Instr] = []
    lvl = 3
    for _ in range(3):
        out += hmul(pp, lvl, fused=_plan_fused())
        lvl -= 1
    out += bootstrap(pp, degree=63, mode=mode, hoist=_plan_hoist(), fused=_plan_fused())
    return out


_WORKLOADS = {
    "matmul": _w_matmul,
    "dblookup": _w_dblookup,
    "lola_mnist_plain": lambda pp, m: _w_lola_mnist(pp, m, encrypted_weights=False),
    "lola_mnist_enc": lambda pp, m: _w_lola_mnist(pp, m, encrypted_weights=True),
    "lola_cifar_plain": _w_lola_cifar,
    "psi": _w_psi,
    "exact_count": _w_exact_count,
    "logreg": _w_logreg,
    "lstm": _w_lstm,
    "resnet20": _w_resnet20,
    "packed_bootstrap": _w_packed_bootstrap,
}


def available_workloads() -> tuple[str, ...]:
    return tuple(_WORKLOADS)
