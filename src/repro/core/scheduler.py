"""Multi-job FHE scheduling — compatibility wrapper over ``repro.serve``.

The actual policy now lives in the discrete-event serving subsystem
(``repro.serve.policy``): per-affiliation shallow placement with multi-exit
decomposition, deep-job gang scheduling across all bootstrappable clusters,
and priority preemption with an explicit SRAM→HBM spill/restore cost and a
real suspend/resume state machine.  This module keeps the historical
``schedule(jobs, chip) -> list[ScheduledJob]`` surface so existing call sites
(tests, examples, paper-figure benchmarks) run the new engine unchanged.

The event engine also fixes two bugs in the old one-pass heuristic:

  * preemption no longer rewinds *all* affiliation free-times (which let the
    old scheduler double-book placements) — ``ServeResult.validate`` now
    asserts that no two placements overlap on any affiliation;
  * ``ScheduledJob.preempted_cycles`` records the cycles a job actually lost
    to suspension + spill/restore, instead of always 0.0.
"""

from __future__ import annotations

import dataclasses

from .hardware import ChipConfig
from .jobs import FheJob
from .simulator import SimResult


@dataclasses.dataclass
class ScheduledJob:
    job: FheJob
    start_cycle: float
    end_cycle: float
    lanes: str
    sim: SimResult
    preempted_cycles: float = 0.0
    chip_index: int = 0  # which fleet chip ran the job (0 when n_chips == 1)

    @property
    def completion_cycle(self) -> float:
        return self.end_cycle

    @property
    def turnaround(self) -> float:
        return self.end_cycle - self.job.arrival_cycle


def schedule(jobs: list[FheJob], chip: ChipConfig | None = None, n_chips: int = 1,
             router: str = "jsq", exec_policy=None, chips=None,
             gang_max_chips: int = 1, admission=None,
             faults=None, retry=None) -> list[ScheduledJob]:
    """Run ``jobs`` through the event-driven serving engine; returns per-job
    placement and completion in submission order.  Timeline consistency
    (no overlapping placements, work conservation) is asserted on every call.

    ``n_chips > 1`` shards the stream across a fleet of identical chips via
    ``repro.serve.cluster`` (dispatch policy = ``router``); ``chips=`` a
    per-chip list of ``ChipConfig`` / ``(ChipConfig, ExecPolicy)`` entries
    builds a heterogeneous fleet instead, and ``gang_max_chips > 1`` lets
    deep jobs gang-split across identical chips.  Each returned
    ``ScheduledJob.chip_index`` names the (primary) chip that ran it.
    ``exec_policy`` (an ``repro.fhe.ExecPolicy``) selects the service-time
    kernel mode.  ``admission`` (an ``repro.serve.AdmissionConfig``) arms
    overload protection: SHED jobs are *dropped from the returned schedule*
    (they have no placement or completion) — callers that need the shed
    records use ``repro.serve.serve_cluster`` directly.  ``faults=`` (a
    ``repro.serve.FaultPlan`` / ``FaultConfig``) and ``retry=`` (a
    ``RetryPolicy``) arm fault injection on the fleet path; like SHED jobs,
    FAILED (retries-exhausted) jobs are dropped from the returned schedule.
    """
    # deferred import: repro.core.__init__ imports this module, and the serve
    # package imports repro.core submodules — a top-level import would cycle
    from repro.serve.cluster import serve_cluster
    from repro.serve.policy import JobState, serve

    if chips is None and n_chips <= 1 and faults is None:
        shed_after = admission.shed_after_cycles if admission is not None else None
        jes = serve(jobs, chip, validate=True, exec_policy=exec_policy,
                    shed_after=shed_after).jobs
    else:
        jes = serve_cluster(jobs, chip, n_chips=n_chips, router=router, validate=True,
                            exec_policy=exec_policy, chips=chips,
                            gang_max_chips=gang_max_chips, admission=admission,
                            faults=faults, retry=retry).jobs
    jes = [je for je in jes if je.state is JobState.DONE]
    return [
        ScheduledJob(
            job=je.job,
            start_cycle=je.first_start,
            end_cycle=je.completion,
            lanes=je.lanes,
            sim=je.sim,
            preempted_cycles=je.preempted_cycles,
            chip_index=je.chip_index,
        )
        for je in jes
    ]


def avg_completion_cycles(scheduled: list[ScheduledJob]) -> float:
    return sum(s.turnaround for s in scheduled) / len(scheduled)


def makespan(scheduled: list[ScheduledJob]) -> float:
    return max(s.end_cycle for s in scheduled)
