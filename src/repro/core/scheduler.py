"""Multi-job FHE scheduler — the paper's §4.2 policy, plus baselines.

FLASH-FHE policy:
  * classify each job from its crypto parameters (jobs.classify);
  * shallow job → exactly ONE cluster affiliation (parallelism up to 8), with
    the affiliation's bootstrappable circuit decomposed into two extra swift
    pipelines (multi-exit);
  * deep job → ALL bootstrappable clusters across affiliations (exclusive);
  * priority-based preemption: a deep job is suspended (SRAM→HBM spill, paid
    in cycles) when higher-priority shallow jobs arrive, avoiding the convoy
    effect.

Baseline policy (CraterLake / F1+, multi_job=False): whole chip per job,
priority-then-arrival FIFO, no preemption.
"""

from __future__ import annotations

import dataclasses

from .cache import MB
from .hardware import ChipConfig
from .jobs import FheJob
from .planner import workload_stream
from .simulator import LaneSet, SimResult, lanes_deep, lanes_shallow, lanes_whole_chip, simulate_stream


@dataclasses.dataclass
class ScheduledJob:
    job: FheJob
    start_cycle: float
    end_cycle: float
    lanes: str
    sim: SimResult
    preempted_cycles: float = 0.0

    @property
    def completion_cycle(self) -> float:
        return self.end_cycle

    @property
    def turnaround(self) -> float:
        return self.end_cycle - self.job.arrival_cycle


def _job_sim(job: FheJob, chip: ChipConfig, lanes: LaneSet, cache_mb: float) -> SimResult:
    stream = workload_stream(job.workload, job.params, mode="hw")
    return simulate_stream(stream, chip, lanes, cache_bytes=cache_mb * MB,
                           key_prefix=f"j{job.job_id}:")


def schedule(jobs: list[FheJob], chip: ChipConfig) -> list[ScheduledJob]:
    """Event-driven schedule; returns per-job placement and completion."""
    if chip.multi_job:
        return _schedule_flash(jobs, chip)
    return _schedule_sequential(jobs, chip)


def _schedule_sequential(jobs: list[FheJob], chip: ChipConfig) -> list[ScheduledJob]:
    """Homogeneous baseline: one job at a time on the whole chip."""
    lanes = lanes_whole_chip(chip)
    order = sorted(jobs, key=lambda j: (j.arrival_cycle, -j.priority, j.job_id))
    t = 0.0
    out = []
    for job in order:
        sim = _job_sim(job, chip, lanes, chip.total_cache_mb)
        start = max(t, job.arrival_cycle)
        out.append(ScheduledJob(job, start, start + sim.cycles, lanes.label, sim))
        t = start + sim.cycles
    return out


def _schedule_flash(jobs: list[FheJob], chip: ChipConfig) -> list[ScheduledJob]:
    n_aff = chip.n_affiliations
    # L2 is shared; each shallow job sees its L1 + a 1/n_aff share of L2
    shallow_cache_mb = chip.l1_mb_per_aff + chip.l2_mb / n_aff
    events = sorted(jobs, key=lambda j: (j.arrival_cycle, -j.priority, j.job_id))
    aff_free = [0.0] * n_aff
    out: list[ScheduledJob] = []
    deep_running: ScheduledJob | None = None

    for job in events:
        if job.kind == "shallow":
            sim = _job_sim(job, chip, lanes_shallow(chip), shallow_cache_mb)
            # preemption: a running deep job with lower priority is suspended
            preempt_pay = 0.0
            if deep_running is not None and deep_running.job.priority < job.priority \
                    and deep_running.end_cycle > job.arrival_cycle:
                spill_bytes = _working_set_bytes(deep_running.job)
                pay = spill_bytes / chip.hbm_bytes_per_cycle
                deep_running.end_cycle += sim.cycles + pay
                deep_running.preempted_cycles += sim.cycles + pay
                for a in range(n_aff):
                    aff_free[a] = max(0.0, job.arrival_cycle)
            a = min(range(n_aff), key=lambda i: aff_free[i])
            start = max(aff_free[a], job.arrival_cycle)
            end = start + sim.cycles
            aff_free[a] = end
            out.append(ScheduledJob(job, start, end, f"affiliation-{a}", sim,
                                    preempted_cycles=preempt_pay))
            if deep_running is not None:
                for i in range(n_aff):
                    aff_free[i] = max(aff_free[i], deep_running.end_cycle)
        else:
            sim = _job_sim(job, chip, lanes_deep(chip), chip.total_cache_mb)
            start = max(max(aff_free), job.arrival_cycle)
            end = start + sim.cycles
            sj = ScheduledJob(job, start, end, lanes_deep(chip).label, sim)
            out.append(sj)
            deep_running = sj
            for i in range(n_aff):
                aff_free[i] = end
    return out


def _working_set_bytes(job: FheJob) -> float:
    p = job.params
    # 2 ciphertext polys over the extended basis + accumulators
    return 6.0 * (p.L + 1 + p.alpha) * p.n * 4.0


def avg_completion_cycles(scheduled: list[ScheduledJob]) -> float:
    return sum(s.turnaround for s in scheduled) / len(scheduled)


def makespan(scheduled: list[ScheduledJob]) -> float:
    return max(s.end_cycle for s in scheduled)
