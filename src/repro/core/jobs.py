"""FHE job descriptions and the deep/shallow classifier (paper §4.2 step 1)."""

from __future__ import annotations

import dataclasses

from repro.fhe.params import CkksParams, workload_kind, workload_params, workload_scheme


@dataclasses.dataclass(frozen=True)
class FheJob:
    """One submitted FHE workload instance."""

    workload: str  # name in fhe.params.WORKLOAD_PRESETS
    params: CkksParams
    priority: int = 0  # higher = more urgent (preemptive scheduling)
    arrival_cycle: int = 0
    job_id: int = 0
    tenant_id: int = 0  # submitting tenant (fairness accounting in repro.serve)

    @property
    def kind(self) -> str:
        return classify(self.params)

    @property
    def scheme(self) -> str:
        """"ckks" or "bgv" — derived from the params (plain_modulus axis);
        the serving layer re-tags its ``ExecPolicy`` per job with this."""
        return self.params.scheme


def classify(params: CkksParams) -> str:
    """Paper §3.2: shallow ⇔ N ≤ 2^14 (no bootstrapping budget needed)."""
    return "shallow" if params.is_shallow() else "deep"


def make_job(workload: str, priority: int = 0, arrival_cycle: int = 0, job_id: int = 0,
             tenant_id: int = 0) -> FheJob:
    p = workload_params(workload)
    job = FheJob(workload=workload, params=p, priority=priority,
                 arrival_cycle=arrival_cycle, job_id=job_id, tenant_id=tenant_id)
    if job.kind != workload_kind(workload):
        raise ValueError(
            f"workload {workload!r}: classifier says {job.kind!r} but the preset "
            f"declares {workload_kind(workload)!r} — fix the preset's N or kind"
        )
    if job.scheme != workload_scheme(workload):
        raise ValueError(
            f"workload {workload!r}: params encode scheme {job.scheme!r} but the "
            f"preset declares {workload_scheme(workload)!r} — plain_modulus and "
            "the preset's scheme tag are out of sync"
        )
    return job
