"""Cycle-level performance model driven by planner instruction streams.

Throughput/bottleneck model (the standard analysis for these accelerators):
each instruction contributes work to one functional unit —

  NTT/INTT   2·limbs·N / ntt_lanes                (two four-step passes)
  BCONV      N·k·m / bconv_lanes                  (modular MACs)
  PMULT/…    limbs·N / modmul_lanes
  AUTO       limbs·N / modmul_lanes               (permutation datapath)
  LOAD_*     bytes through the cache model → HBM traffic

With a fused iNTT→BConv→NTT pipeline (FLASH-FHE, CraterLake) the units overlap,
so job time ≈ max over unit totals (+HBM).  Without fusion (F1+) intermediates
round-trip through memory: time ≈ sum of unit totals and every BCONV/NTT
boundary adds HBM traffic — this is the ">10× slower than expected" F1+
behaviour the paper cites.

Captured *software* traces additionally carry explicit STORE_WS/LOAD_WS
records when the staged key-switch dispatcher ran (one pair per stage
boundary); each costs its working set through HBM regardless of the chip,
because the round-trip happens between kernel launches.  Fused-pipeline
traces (``repro.kernels.fusedks``) emit none — `tests/test_fusedks.py`
validates this accounting against both captured streams.

Hoisted-rotation traces (``planner.hoisted_rotations`` /
``ctx.rotate_hoisted_group``) are the other shape this model prices:
one ModUp (INTT + β·{PMULT, BCONV, NTT}) plus ONE STORE_WS/LOAD_WS pair of
β·ext limbs — the materialised hoisted digits round-tripping to the MAC
launches — followed by k per-rotation {LOAD_KSK, MAC, ModDown, PADD, 2×AUTO}
records.  No new instruction kinds: the amortisation shows up as k·β ext-NTT
records collapsing to β, which the `ntt` unit total directly rewards;
`tests/test_hoisting.py` validates planner/simulator parity for this shape.
"""

from __future__ import annotations

import dataclasses

from repro.fhe.trace import Instr

from .cache import LruCache, MB
from .hardware import ChipConfig


@dataclasses.dataclass
class LaneSet:
    """Functional-unit widths a scheduler grants to one job.

    bconv_macs: the BConv unit is l_sub=60 *vector* pipelines, each as wide as
    the cluster datapath (256 lanes) — so one bootstrappable cluster sustains
    60·256 modular MACs/cycle.
    """

    ntt_lanes: int
    bconv_macs: int
    modmul_lanes: int
    label: str = ""
    coop_transpose: bool = False  # swift clusters joined a deep job (L3 traffic)


def lanes_deep(chip: ChipConfig) -> LaneSet:
    """Deep job: all bootstrappable clusters across affiliations (paper §4.2)."""
    nb = chip.n_bootstrappable
    return LaneSet(ntt_lanes=nb * 256, bconv_macs=nb * 60 * 256, modmul_lanes=nb * 512,
                   label=f"{chip.name}:deep({nb}×boot)")


TRANSPOSE_PORTS = 2048  # L3 transpose module port count (paper §4.1)


def lanes_deep_coop(chip: ChipConfig) -> LaneSet:
    """Beyond-paper (the paper's §7 future work): swift clusters join deep
    jobs.  Large-point NTTs decompose across boot+swift pipelines, at the cost
    of routing every (i)NTT's data through the L3 transpose (modelled as a
    dedicated unit with 2048 ports)."""
    nb, ns = chip.n_bootstrappable, chip.n_swift
    return LaneSet(ntt_lanes=nb * 256 + ns * 128, bconv_macs=nb * 60 * 256,
                   modmul_lanes=nb * 512 + ns * 256,
                   label=f"{chip.name}:deep-coop({nb}×boot+{ns}×swift)",
                   coop_transpose=True)


def lanes_shallow(chip: ChipConfig) -> LaneSet:
    """Shallow job: one affiliation.  The bootstrappable 2^8 circuit decomposes
    into two 2^7 pipelines (multi-exit), joining the two swift clusters: four
    128-lane pipelines."""
    if chip.multi_exit_ntt:
        ntt = 2 * 128 * 1 + chip.swift_per_aff * 128
        mm = 512 + chip.swift_per_aff * 256
    else:
        ntt = 256 * chip.bootstrappable_per_aff
        mm = 512 * chip.bootstrappable_per_aff
    return LaneSet(ntt_lanes=ntt, bconv_macs=60 * 256, modmul_lanes=mm,
                   label=f"{chip.name}:shallow(1 affiliation)")


def lanes_whole_chip(chip: ChipConfig) -> LaneSet:
    """Homogeneous baseline policy: every cluster on the one running job."""
    nb = chip.n_bootstrappable
    bconv = nb * 60 * 256 if chip.fused_keyswitch else nb * 512  # F1+: BConv on Mod M/A
    return LaneSet(ntt_lanes=nb * 256, bconv_macs=bconv,
                   modmul_lanes=nb * 512, label=f"{chip.name}:whole-chip")


@dataclasses.dataclass
class SimResult:
    cycles: float
    hbm_bytes: float
    unit_cycles: dict
    cache_hit_ratio: float
    instr_count: int
    freq_ghz: float | None = None  # set by finalize(); 1 GHz assumed otherwise

    def __post_init__(self):
        self._time_s: float | None = None

    @property
    def time_s(self) -> float:
        """Wall-clock seconds; computed lazily so a result that was never
        ``finalize``d still reads back (at the stored or default frequency)."""
        if self._time_s is None:
            self._time_s = self.cycles / ((self.freq_ghz or 1.0) * 1e9)
        return self._time_s

    def finalize(self, freq_ghz: float) -> "SimResult":
        self.freq_ghz = freq_ghz
        self._time_s = self.cycles / (freq_ghz * 1e9)
        return self


PIPE_LATENCY = 64  # fill/drain cycles per instruction (amortised)


def simulate_stream(
    instrs: list[Instr],
    chip: ChipConfig,
    lanes: LaneSet,
    cache: LruCache | None = None,
    cache_bytes: float | None = None,
    key_prefix: str = "",
    tracer=None,
    trace_pid: int | None = None,
) -> SimResult:
    """Run one job's instruction stream on the granted lanes.

    ``tracer`` (an ``repro.obs.Tracer``) records one occupancy slice per
    instruction per functional unit it charges, with timestamps = cumulative
    unit cycles — a per-unit utilisation timeline, not a global schedule
    (units overlap freely in the fused pipeline).  Each call gets its own
    trace process (``trace_pid`` overrides) so successive sims — whose unit
    clocks all start at 0 — never interleave on one track.
    """
    if cache is None:
        cache = LruCache(cache_bytes if cache_bytes is not None else chip.total_cache_mb * MB)
    unit = {"ntt": 0.0, "bconv": 0.0, "modmul": 0.0, "hbm": 0.0, "transpose": 0.0}
    wb = chip.word_bytes
    hbm_bytes = 0.0
    ksk_counter: dict[str, int] = {}

    trace = tracer is not None and bool(tracer)
    if trace:
        pid = trace_pid if trace_pid is not None else tracer.new_process(
            f"sim {lanes.label or chip.name}")
        tids = {u: tracer.track(pid, u) for u in unit}
        hbm_cursor = 0.0

    for ins in instrs:
        if trace:
            before = dict(unit)
            hbm_before = hbm_bytes
        n, limbs = ins.n, ins.limbs
        # Fig-2 saturation: a ring of degree N cannot keep more than ~N/16
        # lanes busy (four-step data-distribution limit) — this is WHY adding
        # clusters beyond one affiliation doesn't help a shallow job, and why
        # FLASH-FHE schedules one shallow job per affiliation instead.
        eff = max(256, n // 16)
        if lanes.coop_transpose:
            # The four-step distribution limit assumes clusters exchange NTT
            # tiles point-to-point; coop mode routes every (i)NTT through the
            # L3 transpose module instead, which re-distributes tiles to any
            # lane — so the grant is not eff-capped, and the cost shows up as
            # the explicit ``transpose`` unit charge below.
            eff = n
        ntt_l = min(lanes.ntt_lanes, eff)
        mm_l = min(lanes.modmul_lanes, eff)
        if ins.op in ("NTT", "INTT"):
            unit["ntt"] += 2.0 * limbs * n / ntt_l + PIPE_LATENCY
            if lanes.coop_transpose:
                # cross-cluster routing of both four-step passes via L3
                unit["transpose"] += 2.0 * limbs * n / TRANSPOSE_PORTS
            if not chip.fused_keyswitch:
                # unfused: (i)NTT results round-trip through the scratchpad/HBM
                hbm_bytes += 2 * limbs * n * wb
        elif ins.op == "BCONV":
            m = ins.meta.get("dst", limbs)
            unit["bconv"] += float(n) * limbs * m / lanes.bconv_macs + PIPE_LATENCY
            if not chip.fused_keyswitch:
                hbm_bytes += (limbs + m) * n * wb
        elif ins.op in ("PMULT", "PADD", "PSUB", "AUTO"):
            if chip.fused_exit_mac and ins.meta.get("mac"):
                continue  # streams through the NTT-exit MAC arrays (area cost)
            unit["modmul"] += float(limbs) * n / mm_l + PIPE_LATENCY
        elif ins.op in ("LOAD_KSK", "LOAD_PT"):
            nbytes = float(limbs) * n * wb
            if ins.op == "LOAD_KSK" and chip.on_chip_keygen:
                nbytes *= 0.5  # the uniform half of each key is re-generated on chip
            key = f"{key_prefix}{ins.op}:{n}:{limbs}:{ins.meta.get('tag','')}"
            if ins.op == "LOAD_KSK":
                # distinct keys of the same shape rotate through a small id space
                # (relin + ~2√slots galois keys per workload)
                idx = ksk_counter.get(key, 0)
                ksk_counter[key] = (idx + 1) % max(1, ins.meta.get("n_keys", 8))
                key = f"{key}#{idx}"
            hbm_bytes += cache.access(key, nbytes)
        elif ins.op in ("STORE_WS", "LOAD_WS"):
            # staged-software dispatch boundary: the intermediate polynomial
            # round-trips through HBM-equivalent buffers between kernel
            # launches (the fused key-switch pipeline emits none of these).
            # On chips WITHOUT a fused key-switch pipeline the NTT/BCONV
            # branches above already charge the same round-trips implicitly,
            # so the explicit records only bill fused-pipeline chips.
            if chip.fused_keyswitch:
                hbm_bytes += float(limbs) * n * wb
        elif ins.op == "TOUCH_WS":
            # key-switch working set vs on-chip capacity (Fig 8 mechanism):
            # whatever doesn't fit spills to HBM and returns
            ws_bytes = float(limbs) * n * wb
            ksk_bytes = float(ins.meta.get("ksk_limbs", 0)) * n * wb
            spill = max(0.0, ws_bytes + ksk_bytes - cache.capacity)
            hbm_bytes += 2.0 * spill
        elif ins.op in ("MODRAISE", "BOOTSTRAP_BEGIN", "BOOTSTRAP_END", "KSKGEN"):
            continue
        else:
            raise ValueError(f"unknown instruction {ins.op}")
        if trace:
            for u in ("ntt", "bconv", "modmul", "transpose"):
                if unit[u] > before[u]:
                    tracer.complete(ins.op, before[u], unit[u], pid=pid,
                                    tid=tids[u], n=ins.n, limbs=ins.limbs)
            if hbm_bytes > hbm_before:
                dt = (hbm_bytes - hbm_before) / chip.hbm_bytes_per_cycle
                tracer.complete(ins.op, hbm_cursor, hbm_cursor + dt, pid=pid,
                                tid=tids["hbm"], bytes=hbm_bytes - hbm_before)
                hbm_cursor += dt

    unit["hbm"] = hbm_bytes / chip.hbm_bytes_per_cycle
    if chip.fused_keyswitch:
        cycles = max(unit.values())  # pipelined: bottleneck unit governs
    else:
        cycles = unit["ntt"] + unit["bconv"] + unit["modmul"] + unit["hbm"]
    return SimResult(
        cycles=cycles, hbm_bytes=hbm_bytes, unit_cycles=dict(unit),
        cache_hit_ratio=cache.hit_ratio, instr_count=len(instrs),
    ).finalize(chip.freq_ghz)
