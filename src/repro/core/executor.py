"""shard_map executor: affiliation = device group (DESIGN.md §2 mapping).

The paper's scheduler runs one shallow FHE job per cluster affiliation; on the
TPU mesh each affiliation maps to a device group along the `data` axis, and up
to 8 shallow jobs execute *numerically in parallel* under one jitted
shard_map program.  On CPU (1 device) the same program degrades gracefully.

The executable program is the real CKKS pipeline (pointwise Montgomery ops,
(i)NTT, BConv key-switch) traced through repro.fhe — scales/levels are static,
so the whole multi-job step jits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.fhe import ops
from repro.fhe.context import ExecPolicy, FheContext
from repro.fhe.keys import KeySet
from repro.fhe.params import CkksParams


def affiliation_mesh(n_groups: int | None = None) -> Mesh:
    """1-D mesh over available devices: one group per affiliation."""
    devs = np.array(jax.devices())
    if n_groups is None:
        n_groups = len(devs)
    assert len(devs) % n_groups == 0
    return Mesh(devs[: n_groups].reshape(n_groups), ("aff",))


def _stack_jobs(cts: list[ops.Ciphertext]):
    return (
        jnp.stack([c.c0 for c in cts]),
        jnp.stack([c.c1 for c in cts]),
    )


def parallel_shallow_mul(
    params: CkksParams,
    keys: KeySet,
    pairs: list[tuple[ops.Ciphertext, ops.Ciphertext]],
    mesh: Mesh | None = None,
) -> list[ops.Ciphertext]:
    """Execute one homomorphic multiplication per job, jobs sharded over
    affiliations (the paper's multi-job scheduling, run for real)."""
    if mesh is None:
        mesh = affiliation_mesh()
    n_jobs = len(pairs)
    n_aff = mesh.devices.size
    assert n_jobs % n_aff == 0, f"{n_jobs} jobs must tile {n_aff} affiliations"
    level = pairs[0][0].level
    scale = pairs[0][0].scale
    for a, b in pairs:
        assert a.level == b.level == level and a.scale == b.scale == scale

    a0, a1 = _stack_jobs([p[0] for p in pairs])
    b0, b1 = _stack_jobs([p[1] for p in pairs])
    rlk = keys.rlk.k
    ctx = FheContext(params=params, keys=keys, policy=ExecPolicy(backend="ref"))

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P("aff"), P("aff"), P("aff"), P("aff"), P()),
        out_specs=(P("aff"), P("aff")),
        check_rep=False,
    )
    def run(a0s, a1s, b0s, b1s, rlk_arr):
        outs0, outs1 = [], []
        local = a0s.shape[0]
        for j in range(local):  # static per-affiliation job loop
            cta = ops.Ciphertext(a0s[j], a1s[j], level, scale)
            ctb = ops.Ciphertext(b0s[j], b1s[j], level, scale)
            kk = keys.rlk.__class__(k=rlk_arr)
            out = ctx.mul(cta, ctb, rlk=kk, rescale_after=True)
            outs0.append(out.c0)
            outs1.append(out.c1)
        return jnp.stack(outs0), jnp.stack(outs1)

    o0, o1 = jax.jit(run)(a0, a1, b0, b1, rlk)
    out_scale = scale * scale / float(params.q_primes[level])
    return [
        ops.Ciphertext(o0[j], o1[j], level - 1, out_scale) for j in range(n_jobs)
    ]


def lower_multi_job_step(params: CkksParams, keys: KeySet, mesh: Mesh, jobs_per_aff: int = 1):
    """Lower (without executing) the multi-job step for dry-run analysis."""
    n_aff = mesh.devices.size
    n_jobs = n_aff * jobs_per_aff
    shape = (n_jobs, params.L + 1, params.n)
    spec = jax.ShapeDtypeStruct(shape, jnp.uint32)

    level = params.L
    scale = params.scale
    rlk = keys.rlk.k
    ctx = FheContext(params=params, keys=keys, policy=ExecPolicy(backend="ref"))

    def run(a0, a1, b0, b1):
        def body(a0s, a1s, b0s, b1s):
            outs0, outs1 = [], []
            for j in range(jobs_per_aff):
                cta = ops.Ciphertext(a0s[j], a1s[j], level, scale)
                ctb = ops.Ciphertext(b0s[j], b1s[j], level, scale)
                out = ctx.mul(cta, ctb, rescale_after=True)
                outs0.append(out.c0)
                outs1.append(out.c1)
            return jnp.stack(outs0), jnp.stack(outs1)

        f = shard_map(body, mesh=mesh, in_specs=(P("aff"),) * 4,
                      out_specs=(P("aff"), P("aff")), check_rep=False)
        return f(a0, a1, b0, b1)

    return jax.jit(run).lower(spec, spec, spec, spec)
