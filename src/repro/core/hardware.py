"""Hardware models: FLASH-FHE chip parameters + baseline accelerator configs.

Everything the cycle-level simulator (repro.core.simulator) needs is declared
here as data, so baseline accelerators (CraterLake, F1+) are just different
``ChipConfig`` instances — their speed differences *emerge* from architecture
(cluster inventory, cache volume, fused key-switch pipeline, scheduling policy)
rather than being hard-coded, mirroring how the paper attributes its gains.

Area/power tables reproduce the paper's Table 3 and Fig. 13 breakdowns.

TPU-side roofline constants (for the JAX runtime deliverables) live here too.
"""

from __future__ import annotations

import dataclasses

MB = 1 << 20
GB = 1 << 30


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """One computation cluster's pipeline shape."""

    kind: str  # "bootstrappable" | "swift"
    ntt_points: int  # R-point (i)NTT circuit width (256 or 128)
    max_n: int  # largest ring degree the pipeline natively supports
    has_bconv: bool
    bconv_lanes: int = 0  # l_sub parallel modular-mul pipelines
    modmul_lanes: int = 256  # pointwise Mod M/A datapath width


BOOTSTRAPPABLE = ClusterSpec("bootstrappable", 256, 1 << 16, True, bconv_lanes=60, modmul_lanes=512)
SWIFT = ClusterSpec("swift", 128, 1 << 14, False, modmul_lanes=256)


@dataclasses.dataclass(frozen=True)
class ChipConfig:
    name: str
    freq_ghz: float
    n_affiliations: int  # cluster-affiliation count (FLASH-FHE: 8)
    bootstrappable_per_aff: int
    swift_per_aff: int
    l1_mb_per_aff: float  # shared L1 SRAM per affiliation
    total_cache_mb: float  # L1×affiliations + global L2
    hbm_gbps: float  # off-chip bandwidth (2× HBM2e = 1024 GB/s)
    fused_keyswitch: bool  # dedicated iNTT→BConv→NTT pipeline?
    multi_exit_ntt: bool  # bootstrappable circuit decomposable into small NTTs?
    multi_job: bool  # scheduler can co-run shallow jobs (1 per affiliation)?
    on_chip_keygen: bool = True  # real-time key generation (halves KSK traffic)
    fused_exit_mac: bool = False  # beyond-paper: ksk MACs at the NTT pipeline exit
    word_bytes: int = 4  # RNS limb word width in memory

    @property
    def n_bootstrappable(self) -> int:
        return self.n_affiliations * self.bootstrappable_per_aff

    @property
    def n_swift(self) -> int:
        return self.n_affiliations * self.swift_per_aff

    @property
    def l2_mb(self) -> float:
        return self.total_cache_mb - self.n_affiliations * self.l1_mb_per_aff

    @property
    def hbm_bytes_per_cycle(self) -> float:
        return self.hbm_gbps / self.freq_ghz  # GB/s over Gcycle/s


# --- FLASH-FHE (the paper, §4/§5): 8 affiliations × (1 bootstrappable + 2 swift),
#     320 MB total SRAM (8 MB L1 × 8 + 256 MB L2), 2×HBM2e, 1 GHz ---------------
FLASH_FHE = ChipConfig(
    name="flash-fhe", freq_ghz=1.0, n_affiliations=8,
    bootstrappable_per_aff=1, swift_per_aff=2,
    l1_mb_per_aff=8.0, total_cache_mb=320.0, hbm_gbps=1024.0,
    fused_keyswitch=True, multi_exit_ntt=True, multi_job=True,
)

# --- CraterLake (§6.1): 8 homogeneous 256-lane bootstrappable groups, 256 MB,
#     fused key-switch, single-job scheduling ----------------------------------
CRATERLAKE = ChipConfig(
    name="craterlake", freq_ghz=1.0, n_affiliations=8,
    bootstrappable_per_aff=1, swift_per_aff=0,
    l1_mb_per_aff=8.0, total_cache_mb=256.0, hbm_gbps=1024.0,
    fused_keyswitch=True, multi_exit_ntt=False, multi_job=False,
)

# --- F1+ (§6.1): 16 compute clusters with 256 lanes, 256 MB scratchpad, but an
#     UNOPTIMISED key-switch (no fused pipeline ⇒ intermediate polys round-trip
#     through memory), single-job ----------------------------------------------
F1PLUS = ChipConfig(
    name="f1plus", freq_ghz=1.0, n_affiliations=32,  # 32 clusters × 256 lanes (§6.1)
    bootstrappable_per_aff=1, swift_per_aff=0,
    l1_mb_per_aff=1.0, total_cache_mb=256.0, hbm_gbps=1024.0,
    fused_keyswitch=False, multi_exit_ntt=False, multi_job=False,
    on_chip_keygen=False,  # F1 predates real-time key generation
)

# Beyond-paper variant for the §Perf hillclimb: MAC units at the (i)NTT
# pipeline exits absorb the key-switch inner products (same philosophy as the
# paper's fused iNTT→BConv→NTT pipeline, one stage further).
import dataclasses as _dc

FLASH_FHE_FUSED_MAC = _dc.replace(FLASH_FHE, name="flash-fhe-fmac", fused_exit_mac=True)

CHIPS = {c.name: c for c in (FLASH_FHE, CRATERLAKE, F1PLUS, FLASH_FHE_FUSED_MAC)}


# ---------------------------------------------------------------------------
# Area model (paper Table 3, mm²) and power model (Fig 13, W)
# ---------------------------------------------------------------------------

AREA_TABLE_MM2 = {
    # component: (7nm, 14/12nm)
    "ntt_128pt": (0.50, 1.42),
    "modmul_add_swift": (0.31, 0.91),
    "swift_clusters_total": (12.96, 37.28),  # 16×NTT + 16×Mod M/A
    "ntt_256pt": (0.99, 2.81),
    "modmul_add_boot": (0.63, 1.81),
    "bconv": (0.69, 2.01),
    "bootstrappable_clusters_total": (55.09, 160.56),
    "key_generation": (0.73, 3.00),
    "automorphism": (3.21, 9.23),
    "transpose": (0.13, 0.37),
    "srams_in_clusters": (19.50, 96.6),
    "hierarchical_cache": (58.00, 185.5),
    "hbm2e_x2": (29.80, 29.80),
    "total": (178.69, 519.34),
}

BASELINE_AREAS_MM2 = {  # §6.1
    "f1plus": 636.0,  # 14/12nm
    "craterlake": 472.0,  # 14/12nm
    "ark": 418.0,  # 7nm
    "sharp": 179.0,  # 7nm
}

POWER_BREAKDOWN_W = {
    # Fig 13: total 152.11 W; bootstrappable clusters 60%, swift 11%
    "bootstrappable_clusters": 91.3,
    "swift_clusters": 16.7,
    "transpose": 2.1,
    "l1_cache": 12.4,
    "l2_cache": 18.6,
    "hbm": 11.0,
}
TOTAL_POWER_W = 152.11
BASELINE_POWER_W = {"craterlake": 317.0, "ark": 281.3, "bts": 163.2}


def area_total_mm2(node: str = "14nm") -> float:
    col = 0 if node == "7nm" else 1
    return AREA_TABLE_MM2["total"][col]


def swift_logic_fraction(node: str = "14nm") -> float:
    """Paper claim: swift-cluster logic < 7% of total chip area."""
    col = 0 if node == "7nm" else 1
    return AREA_TABLE_MM2["swift_clusters_total"][col] / AREA_TABLE_MM2["total"][col]


# ---------------------------------------------------------------------------
# TPU roofline constants (the JAX runtime target: v5e-class chips)
# ---------------------------------------------------------------------------

TPU_PEAK_FLOPS_BF16 = 197e12  # FLOP/s per chip
TPU_HBM_GBPS = 819e9  # bytes/s per chip
TPU_ICI_GBPS = 50e9  # bytes/s per link
