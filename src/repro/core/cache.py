"""Hierarchical data-cache model (paper §4.3).

Each affiliation owns an 8 MB L1 shared by its three clusters; a global L2
holds the rest of the 320 MB SRAM budget.  The dominant cached objects are
key-switching keys and precomputed plaintext diagonals — exactly what Fig. 8
sweeps.  We model an LRU over named buffers: an access either hits (no HBM
traffic) or misses (buffer streamed from HBM and inserted, evicting LRU).

Ciphertext working polynomials are pinned in L1 (the paper sizes L1 so each
affiliation holds its active slice: 8 MB ≥ 2 polys × 2^16/8 × limbs × 4B).
"""

from __future__ import annotations

import collections

MB = 1 << 20


class LruCache:
    def __init__(self, capacity_bytes: float):
        self.capacity = float(capacity_bytes)
        self.used = 0.0
        self._entries: "collections.OrderedDict[str, float]" = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.hbm_bytes = 0.0

    def access(self, key: str, nbytes: float) -> float:
        """Returns HBM bytes transferred (0 on hit)."""
        if key in self._entries:
            self._entries.move_to_end(key)
            self.hits += 1
            return 0.0
        self.misses += 1
        self.hbm_bytes += nbytes
        if nbytes <= self.capacity:
            while self.used + nbytes > self.capacity and self._entries:
                _, sz = self._entries.popitem(last=False)
                self.used -= sz
            self._entries[key] = nbytes
            self.used += nbytes
        return nbytes

    def spill(self, nbytes: float) -> float:
        """Preemption: working set written to HBM and read back later."""
        self.hbm_bytes += 2 * nbytes
        return 2 * nbytes

    @property
    def hit_ratio(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class HierarchicalCache:
    """L1-per-affiliation backed by a shared global L2.

    An access first probes the affiliation L1, then L2; a miss in both streams
    from HBM and fills both levels (inclusive).
    """

    def __init__(self, n_affiliations: int, l1_bytes: float, l2_bytes: float):
        self.l1 = [LruCache(l1_bytes) for _ in range(n_affiliations)]
        self.l2 = LruCache(l2_bytes)

    def access(self, affiliation: int, key: str, nbytes: float) -> float:
        if self.l1[affiliation].access(key, nbytes) == 0.0:
            return 0.0
        # L1 miss: charge the L1 fill to on-chip traffic; probe L2
        missed = self.l2.access(key, nbytes)
        return missed

    @property
    def hbm_bytes(self) -> float:
        return self.l2.hbm_bytes

    def hit_ratio(self) -> float:
        h = sum(c.hits for c in self.l1) + self.l2.hits
        m = self.l2.misses
        total_l1 = sum(c.hits + c.misses for c in self.l1)
        return (total_l1 - m) / total_l1 if total_l1 else 0.0
