"""Public fused pointwise RNS ops (limb-wise, arbitrary leading batch)."""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.fhe import modmath as mm
from repro.kernels import dispatch

from . import kernel as _k
from . import ref as _ref


def _resolve(backend):
    if backend == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "ref"
    return backend


@functools.lru_cache(maxsize=1024)
def _mont_cached(qs: tuple[int, ...]) -> dict:
    return mm.mont_constants_array(list(qs))


def pointwise_mulmod(a, b, qs, qinv=None, r2=None, backend: str = "auto"):
    """(a ∘ b) mod q per limb.  a, b: (..., l, N) uint32; qs: (l,).

    Montgomery constants are derived (and cached) from ``qs`` when the caller
    does not supply them, so any call site can reach the kernel path.
    """
    dispatch.record("mulmod")
    if _resolve(backend) == "ref":
        return _ref.mulmod_ref(a, b, jnp.asarray(qs, jnp.uint32))
    if qinv is None or r2 is None:
        consts = _mont_cached(tuple(int(q) for q in np.asarray(qs).tolist()))
        qinv, r2 = consts["qinv_neg"], consts["r2"]
    lead = a.shape[:-2]
    l, n = a.shape[-2:]
    reps = math.prod(lead) if lead else 1
    q = jnp.tile(jnp.asarray(qs, jnp.uint32).reshape(-1, 1), (reps, 1))
    qi = jnp.tile(jnp.asarray(qinv, jnp.uint32).reshape(-1, 1), (reps, 1))
    r2_ = jnp.tile(jnp.asarray(r2, jnp.uint32).reshape(-1, 1), (reps, 1))
    out = _k.mulmod_pallas(a.reshape(-1, n), b.reshape(-1, n), q, qi, r2_, interpret=jax.default_backend() != "tpu")
    return out.reshape(lead + (l, n))


def pointwise_addmod(a, b, qs, backend: str = "auto"):
    dispatch.record("addmod")
    if _resolve(backend) == "ref":
        return _ref.addmod_ref(a, b, jnp.asarray(qs, jnp.uint32))
    lead = a.shape[:-2]
    l, n = a.shape[-2:]
    reps = math.prod(lead) if lead else 1
    q = jnp.tile(jnp.asarray(qs, jnp.uint32).reshape(-1, 1), (reps, 1))
    out = _k.addmod_pallas(a.reshape(-1, n), b.reshape(-1, n), q, interpret=jax.default_backend() != "tpu")
    return out.reshape(lead + (l, n))


def pointwise_submod(a, b, qs, backend: str = "auto"):
    dispatch.record("submod")
    if _resolve(backend) == "ref":
        return _ref.submod_ref(a, b, jnp.asarray(qs, jnp.uint32))
    lead = a.shape[:-2]
    l, n = a.shape[-2:]
    reps = math.prod(lead) if lead else 1
    q = jnp.tile(jnp.asarray(qs, jnp.uint32).reshape(-1, 1), (reps, 1))
    out = _k.submod_pallas(a.reshape(-1, n), b.reshape(-1, n), q, interpret=jax.default_backend() != "tpu")
    return out.reshape(lead + (l, n))
