"""Public fused pointwise RNS ops (limb-wise, arbitrary leading batch)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import kernel as _k
from . import ref as _ref


def _resolve(backend):
    if backend == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "ref"
    return backend


def pointwise_mulmod(a, b, qs, qinv=None, r2=None, backend: str = "auto"):
    """(a ∘ b) mod q per limb.  a, b: (..., l, N) uint32; qs: (l,)."""
    if _resolve(backend) == "ref":
        return _ref.mulmod_ref(a, b, jnp.asarray(qs, jnp.uint32))
    lead = a.shape[:-2]
    l, n = a.shape[-2:]
    reps = math.prod(lead) if lead else 1
    q = jnp.tile(jnp.asarray(qs, jnp.uint32).reshape(-1, 1), (reps, 1))
    qi = jnp.tile(jnp.asarray(qinv, jnp.uint32).reshape(-1, 1), (reps, 1))
    r2_ = jnp.tile(jnp.asarray(r2, jnp.uint32).reshape(-1, 1), (reps, 1))
    out = _k.mulmod_pallas(a.reshape(-1, n), b.reshape(-1, n), q, qi, r2_, interpret=jax.default_backend() != "tpu")
    return out.reshape(lead + (l, n))


def pointwise_addmod(a, b, qs, backend: str = "auto"):
    if _resolve(backend) == "ref":
        return _ref.addmod_ref(a, b, jnp.asarray(qs, jnp.uint32))
    lead = a.shape[:-2]
    l, n = a.shape[-2:]
    reps = math.prod(lead) if lead else 1
    q = jnp.tile(jnp.asarray(qs, jnp.uint32).reshape(-1, 1), (reps, 1))
    out = _k.addmod_pallas(a.reshape(-1, n), b.reshape(-1, n), q, interpret=jax.default_backend() != "tpu")
    return out.reshape(lead + (l, n))


def pointwise_submod(a, b, qs, backend: str = "auto"):
    if _resolve(backend) == "ref":
        return _ref.submod_ref(a, b, jnp.asarray(qs, jnp.uint32))
    lead = a.shape[:-2]
    l, n = a.shape[-2:]
    reps = math.prod(lead) if lead else 1
    q = jnp.tile(jnp.asarray(qs, jnp.uint32).reshape(-1, 1), (reps, 1))
    out = _k.submod_pallas(a.reshape(-1, n), b.reshape(-1, n), q, interpret=jax.default_backend() != "tpu")
    return out.reshape(lead + (l, n))
