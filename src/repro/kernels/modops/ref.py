"""uint64 oracle for fused pointwise RNS ops (HMUL inner loop)."""

import jax
import jax.numpy as jnp


@jax.jit
def mulmod_ref(a, b, qs):
    q = qs.astype(jnp.uint64)[..., :, None]
    return ((a.astype(jnp.uint64) * b.astype(jnp.uint64)) % q).astype(jnp.uint32)


@jax.jit
def addmod_ref(a, b, qs):
    q = qs.astype(jnp.uint64)[..., :, None]
    s = a.astype(jnp.uint64) + b.astype(jnp.uint64)
    return jnp.where(s >= q, s - q, s).astype(jnp.uint32)


@jax.jit
def submod_ref(a, b, qs):
    q = qs.astype(jnp.uint64)[..., :, None]
    a = a.astype(jnp.uint64)
    b = b.astype(jnp.uint64)
    return jnp.where(a >= b, a - b, a + q - b).astype(jnp.uint32)
