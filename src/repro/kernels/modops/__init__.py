from .ops import pointwise_mulmod, pointwise_addmod, pointwise_submod  # noqa: F401
