"""Pallas TPU kernel: fused pointwise RNS ops on the VPU.

HMUL's pointwise limb products are the paper's swift-cluster "Modular Mul/Add"
datapath.  One kernel invocation fuses the Montgomery double-multiply
(a·b·R^{-1}, then ·R² ⇒ plain product) so each limb element makes one VMEM
round trip instead of two.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ntt.kernel import _montmul


def _mul_body(a_ref, b_ref, q_ref, qinv_ref, r2_ref, o_ref):
    q = q_ref[...]  # (1, 1) block → broadcast
    qinv = qinv_ref[...]
    r2 = r2_ref[...]
    t = _montmul(a_ref[...], b_ref[...], q, qinv)
    o_ref[...] = _montmul(t, r2, q, qinv)


def _add_body(a_ref, b_ref, q_ref, o_ref):
    q = q_ref[...]
    s = a_ref[...] + b_ref[...]
    o_ref[...] = jnp.where(s >= q, s - q, s)


def _sub_body(a_ref, b_ref, q_ref, o_ref):
    q = q_ref[...]
    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] = jnp.where(a >= b, a - b, a + q - b)


def _specs(l, n, nb, with_consts):
    base = [
        pl.BlockSpec((1, nb), lambda l_, i: (l_, i)),
        pl.BlockSpec((1, nb), lambda l_, i: (l_, i)),
        pl.BlockSpec((1, 1), lambda l_, i: (l_, 0)),
    ]
    if with_consts:
        base += [
            pl.BlockSpec((1, 1), lambda l_, i: (l_, 0)),
            pl.BlockSpec((1, 1), lambda l_, i: (l_, 0)),
        ]
    return base


def _blocked(n):
    nb = min(n, 8192)
    assert n % nb == 0
    return nb


@functools.partial(jax.jit, static_argnames=("interpret",))
def mulmod_pallas(a, b, q, qinv, r2, *, interpret):
    l, n = a.shape
    nb = _blocked(n)
    return pl.pallas_call(
        _mul_body,
        grid=(l, n // nb),
        in_specs=_specs(l, n, nb, with_consts=True),
        out_specs=pl.BlockSpec((1, nb), lambda l_, i: (l_, i)),
        out_shape=jax.ShapeDtypeStruct((l, n), jnp.uint32),
        interpret=interpret,
    )(a, b, q, qinv, r2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def addmod_pallas(a, b, q, *, interpret):
    l, n = a.shape
    nb = _blocked(n)
    return pl.pallas_call(
        _add_body,
        grid=(l, n // nb),
        in_specs=_specs(l, n, nb, with_consts=False),
        out_specs=pl.BlockSpec((1, nb), lambda l_, i: (l_, i)),
        out_shape=jax.ShapeDtypeStruct((l, n), jnp.uint32),
        interpret=interpret,
    )(a, b, q)


@functools.partial(jax.jit, static_argnames=("interpret",))
def submod_pallas(a, b, q, *, interpret):
    l, n = a.shape
    nb = _blocked(n)
    return pl.pallas_call(
        _sub_body,
        grid=(l, n // nb),
        in_specs=_specs(l, n, nb, with_consts=False),
        out_specs=pl.BlockSpec((1, nb), lambda l_, i: (l_, i)),
        out_shape=jax.ShapeDtypeStruct((l, n), jnp.uint32),
        interpret=interpret,
    )(a, b, q)
