"""Pallas TPU kernel: fast basis conversion (BConv) as a modular MXU matmul.

The paper's BConv unit is l_sub = 60 parallel modular-multiply lanes feeding
adder trees; on TPU the natural substrate is again the MXU.  out = Wᵀ·x̂ mod c
is computed by 8-bit limb decomposition of both operands: partial products are
≤ 255²·k < 2^22 for k ≤ 64 limbs, so int32 accumulation is exact; the seven
limb diagonals are recombined with Montgomery constants 2^(8s)·R mod c_j.

Grid: (coefficient blocks,).  Per program: x̂ (K8, NB) + W (K8, M8) + out (M8, NB)
⇒ ~(64·512 + 64·64 + 64·512)·4·(1+limb copies) ≈ 1.5 MB VMEM for NB=512.
K8/M8 are the 8-padded limb counts (zero rows/cols are exact no-ops).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.fhe.ntt import NDIAG, NLIMB8
from repro.kernels.ntt.kernel import _montmul


def _bconv_kernel_body(x_ref, w_ref, c_ref, q_ref, qinv_ref, o_ref):
    x = x_ref[...]  # (K8, NB) uint32
    w = w_ref[...]  # (K8, M8) uint32
    q = q_ref[...]  # (M8, 1)
    qinv = qinv_ref[...]  # (M8, 1)
    cm = c_ref[...]  # (M8, NDIAG)

    x_limbs = [((x >> (8 * k)) & 0xFF).astype(jnp.int32) for k in range(NLIMB8)]
    w_limbs = [((w >> (8 * k)) & 0xFF).astype(jnp.int32) for k in range(NLIMB8)]
    diags = [None] * NDIAG
    for kw in range(NLIMB8):
        for kx in range(NLIMB8):
            # (M8, K8) @ (K8, NB) → (M8, NB), exact in int32
            p = jax.lax.dot_general(
                w_limbs[kw].T,
                x_limbs[kx],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            s = kw + kx
            diags[s] = p if diags[s] is None else diags[s] + p
    acc = jnp.zeros(diags[0].shape, jnp.uint32)
    for s in range(NDIAG):
        term = _montmul(diags[s].astype(jnp.uint32), cm[:, s : s + 1], q, qinv)
        acc = acc + term
        acc = jnp.where(acc >= q, acc - q, acc)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def bconv_pallas(xhat, w, c_mont, q, qinv, *, interpret):
    """xhat: (K8, N) u32; w: (K8, M8) u32; c_mont: (M8, NDIAG); q/qinv: (M8, 1)."""
    k8, n = xhat.shape
    m8 = w.shape[1]
    nb = min(n, 4096)
    assert n % nb == 0
    return pl.pallas_call(
        _bconv_kernel_body,
        grid=(n // nb,),
        in_specs=[
            pl.BlockSpec((k8, nb), lambda i: (0, i)),
            pl.BlockSpec((k8, m8), lambda i: (0, 0)),
            pl.BlockSpec((m8, NDIAG), lambda i: (0, 0)),
            pl.BlockSpec((m8, 1), lambda i: (0, 0)),
            pl.BlockSpec((m8, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m8, nb), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m8, n), jnp.uint32),
        interpret=interpret,
    )(xhat, w, c_mont, q, qinv)
