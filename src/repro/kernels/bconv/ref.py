"""uint64 oracle for fast basis conversion (BConv).

Conv_{B→C}(x)[j, n] = Σ_i  x̂[i, n] · W[i, j]   (mod c_j)

where x̂[i] = x[i]·[B̂_i^{-1}]_{b_i} mod b_i was already applied by the caller
(or is applied here given the per-limb constants), and W[i, j] = B̂_i mod c_j.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def bconv_ref(xhat, w, cs):
    """xhat: (k, N) uint32; w: (k, m) uint32; cs: (m,) uint32 → (m, N) uint32.

    Accumulates per-term 62-bit products reduced mod c_j; the ≤ 2^31-bounded
    residues sum over k ≤ 64 terms well inside uint64.
    """
    xh = xhat.astype(jnp.uint64)  # (k, N)
    wu = w.astype(jnp.uint64)  # (k, m)
    cu = cs.astype(jnp.uint64)  # (m,)
    # terms[i, j, n] = (xh[i, n] * wu[i, j]) % c_j ; sum over i then % c_j
    def body(acc, inputs):
        xi, wi = inputs  # (N,), (m,)
        t = (xi[None, :] * wi[:, None]) % cu[:, None]
        return acc + t, None

    acc0 = jnp.zeros((w.shape[1], xhat.shape[1]), jnp.uint64)
    acc, _ = jax.lax.scan(body, acc0, (xh, wu))
    return (acc % cu[:, None]).astype(jnp.uint32)
