from .ops import bconv  # noqa: F401
