"""Public BConv op: pads limb counts to multiples of 8 and dispatches kernel/ref."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.fhe import modmath as mm
from repro.fhe.ntt import NDIAG
from repro.kernels import dispatch

from . import kernel as _k
from . import ref as _ref


def _pad8(v: int) -> int:
    return (v + 7) // 8 * 8


def bconv(xhat, w, cs, backend: str = "auto"):
    """Fast basis conversion.

    xhat: (k, N) uint32 — input limbs already scaled by [B̂_i^{-1}]_{b_i};
    w:    (k, m) uint32 — W[i, j] = B̂_i mod c_j;
    cs:   (m,)  target moduli.
    Returns (m, N) uint32.
    """
    dispatch.record("bconv")
    if backend == "auto":
        backend = "kernel" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return _ref.bconv_ref(xhat, w, jnp.asarray(cs, jnp.uint32))

    k, n = xhat.shape
    m = w.shape[1]
    k8, m8 = _pad8(k), _pad8(m)
    cs_np = np.asarray(cs, np.uint64)
    cs_pad = np.concatenate([cs_np, np.full(m8 - m, 3, np.uint64)])  # dummy odd modulus
    consts = mm.mont_constants_array(cs_pad.tolist())
    c_mont = np.zeros((m8, NDIAG), np.uint32)
    for j, cj in enumerate(cs_pad):
        c_mont[j] = [((1 << (8 * s)) << 32) % int(cj) for s in range(NDIAG)]
    xp = jnp.zeros((k8, n), jnp.uint32).at[:k].set(xhat.astype(jnp.uint32))
    wp = jnp.zeros((k8, m8), jnp.uint32).at[:k, :m].set(w.astype(jnp.uint32))
    out = _k.bconv_pallas(
        xp,
        wp,
        jnp.asarray(c_mont),
        jnp.asarray(consts["q"].reshape(m8, 1)),
        jnp.asarray(consts["qinv_neg"].reshape(m8, 1)),
        interpret=jax.default_backend() != "tpu",
    )
    return out[:m]
