"""Public fused key-switch ops: table building + kernel/ref dispatch.

``key_switch_digits`` covers the per-digit prescale→BConv→NTT→MAC region of a
hybrid key-switch (everything between the shared iNTT and ModDown);
``mod_down_digits`` covers the prescale→BConv→NTT→(sub, ×P⁻¹) region of
ModDown for both accumulators.  Backends:

  * "kernel" — the fused Pallas pipeline, ONE launch per region
    (interpret=True off-TPU, so CPU tests exercise the same program);
  * "ref"    — the staged oracle in ``ref`` (one launch per stage per digit);
  * "auto"   — kernel on TPU, ref elsewhere (repo-wide convention).

Tables are cached per (params, level): digit spans, per-digit prescale
constants in Montgomery form, BConv weight matrices, and the extended-basis
NTT plan views — all the state the fused kernel streams per grid step.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.fhe import modmath as mm
from repro.fhe import poly, rns
from repro.fhe.params import CkksParams
from repro.kernels import dispatch

from . import kernel as _k
from . import ref as _ref


def _pad8(v: int) -> int:
    return (v + 7) // 8 * 8

_PAD_MOD = 3  # dummy odd modulus for zero-padded source rows (exact no-op)


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "ref"
    return backend


@dataclasses.dataclass
class KsTables:
    """Per-(params, level) constants for the fused key-switch kernel."""

    beta: int
    k8: int
    m: int
    n1: int
    n2: int
    spans: tuple[tuple[int, int], ...]  # (lo, hi) master-chain slice per digit
    bh: jnp.ndarray  # (β, k8, 1) [B̂⁻¹]·R mod b
    b: jnp.ndarray  # (β, k8, 1) source moduli
    binv: jnp.ndarray  # (β, k8, 1) -b⁻¹ mod 2³²
    w: jnp.ndarray  # (β, k8, m) B̂ mod c_e
    twa: jnp.ndarray
    v2: jnp.ndarray
    v1: jnp.ndarray
    t: jnp.ndarray
    cm: jnp.ndarray
    q: jnp.ndarray
    qinv: jnp.ndarray
    r2: jnp.ndarray


def _prescale_tables(digits: list[tuple[int, ...]], dst_primes, k8: int):
    """(bh, b, binv, w) padded to (len(digits), k8, ·) for the given digit list."""
    nd = len(digits)
    m = len(dst_primes)
    bh = np.zeros((nd, k8, 1), np.uint32)
    b = np.full((nd, k8, 1), _PAD_MOD, np.uint32)
    binv = np.full((nd, k8, 1), mm.MontConstants(_PAD_MOD).qinv_neg, np.uint32)
    w = np.zeros((nd, k8, m), np.uint32)
    for j, src in enumerate(digits):
        k = len(src)
        bhat_inv, wj = rns.bconv_tables(src, tuple(int(c) for c in dst_primes))
        for i, bi in enumerate(src):
            bh[j, i, 0] = (int(bhat_inv[i]) << 32) % int(bi)
        b[j, :k, 0] = np.array(src, np.uint32)
        binv[j, :k, 0] = mm.mont_constants_array(list(src))["qinv_neg"]
        w[j, :k] = wj
    return bh, b, binv, w


def _plan_arrays(plan):
    m = plan.num_limbs
    return dict(
        twa=jnp.asarray(plan.twa_mont),
        v2=jnp.asarray(plan.v2_limbs),
        v1=jnp.asarray(plan.v1_limbs),
        t=jnp.asarray(plan.t_mont),
        cm=jnp.asarray(plan.c_mont),
        q=jnp.asarray(plan.qs.reshape(m, 1)),
        qinv=jnp.asarray(plan.qinv_neg.reshape(m, 1)),
        r2=jnp.asarray(plan.r2.reshape(m, 1)),
    )


@functools.lru_cache(maxsize=256)
def ks_tables(params: CkksParams, level: int) -> KsTables:
    alpha = params.alpha
    beta = params.beta(level)
    ext = poly.ext_idx(params, level)
    dst = poly.primes_for(params, ext)
    k8 = _pad8(alpha)
    spans, digits = [], []
    for j in range(beta):
        lo, hi = j * alpha, min((j + 1) * alpha, level + 1)
        spans.append((lo, hi))
        digits.append(poly.primes_for(params, tuple(range(lo, hi))))
    bh, b, binv, w = _prescale_tables(digits, dst, k8)
    plan = poly.plan_for(params, ext)
    return KsTables(
        beta=beta, k8=k8, m=len(ext), n1=plan.n1, n2=plan.n2, spans=tuple(spans),
        bh=jnp.asarray(bh), b=jnp.asarray(b), binv=jnp.asarray(binv), w=jnp.asarray(w),
        **_plan_arrays(plan),
    )


@dataclasses.dataclass
class ModDownTables:
    k8: int
    m: int
    n1: int
    n2: int
    bh: jnp.ndarray
    b: jnp.ndarray
    binv: jnp.ndarray
    w: jnp.ndarray
    pinv: jnp.ndarray  # (m, 1) Montgomery [P⁻¹]_{q_e}
    twa: jnp.ndarray
    v2: jnp.ndarray
    v1: jnp.ndarray
    t: jnp.ndarray
    cm: jnp.ndarray
    q: jnp.ndarray
    qinv: jnp.ndarray
    r2: jnp.ndarray


@functools.lru_cache(maxsize=256)
def moddown_tables(params: CkksParams, level: int) -> ModDownTables:
    p_primes = poly.primes_for(params, poly.p_idx(params))
    q_primes = poly.primes_for(params, poly.q_idx(params, level))
    k8 = _pad8(len(p_primes))
    bh, b, binv, w = _prescale_tables([p_primes], q_primes, k8)
    P = rns.product(p_primes)
    pinv = np.array(
        [(pow(P % int(q), -1, int(q)) << 32) % int(q) for q in q_primes], np.uint32
    ).reshape(-1, 1)
    plan = poly.plan_for(params, poly.q_idx(params, level))
    return ModDownTables(
        k8=k8, m=len(q_primes), n1=plan.n1, n2=plan.n2,
        bh=jnp.asarray(bh[0]), b=jnp.asarray(b[0]), binv=jnp.asarray(binv[0]),
        w=jnp.asarray(w[0]), pinv=jnp.asarray(pinv), **_plan_arrays(plan),
    )


def pack_digits(d_coeff, tb: KsTables, n: int):
    """(nq, N) coefficient limbs → (β, k8, N) zero-padded digit blocks."""
    xd = jnp.zeros((tb.beta, tb.k8, n), jnp.uint32)
    for j, (lo, hi) in enumerate(tb.spans):
        xd = xd.at[j, : hi - lo].set(d_coeff[lo:hi])
    return xd


def key_switch_digits(d_coeff, ksk_sel, params: CkksParams, level: int, backend: str = "auto"):
    """Σ_j NTT(BConv(d̂_j)) ∘ ksk_j over the extended basis, both components.

    d_coeff: (level+1, N) coefficient-domain limbs; ksk_sel: (β, 2, m, N)
    eval-domain key limbs restricted to the active extended basis.
    Returns (acc0, acc1), each (m, N) uint32 eval-domain.
    """
    if _resolve(backend) == "ref":
        return _ref.key_switch_digits_ref(d_coeff, ksk_sel, params, level)
    tb = ks_tables(params, level)
    xd = pack_digits(jnp.asarray(d_coeff, jnp.uint32), tb, params.n)
    dispatch.record("fusedks")
    out = _k.fused_ks_pallas(
        xd, tb.bh, tb.b, tb.binv, tb.w, tb.twa, tb.v2, tb.v1, tb.t, tb.cm,
        tb.q, tb.qinv, tb.r2, jnp.asarray(ksk_sel, jnp.uint32),
        n1=tb.n1, n2=tb.n2, interpret=jax.default_backend() != "tpu",
    )
    return out[:, 0], out[:, 1]


def mod_down_digits(p_coeff, q_part, params: CkksParams, level: int, backend: str = "auto"):
    """Fused ModDown tail for a batch of accumulators.

    p_coeff: (C, α, N) coefficient-domain P-block limbs (post-iNTT);
    q_part: (C, level+1, N) eval-domain q limbs.  Returns (C, level+1, N).
    C = 2 for one key-switch's accumulator pair; a hoisted rotation group
    passes C = 2·R to ModDown every rotation's pair in one launch.
    """
    if _resolve(backend) == "ref":
        return _ref.mod_down_digits_ref(p_coeff, q_part, params, level)
    tb = moddown_tables(params, level)
    alpha = params.alpha
    nb = p_coeff.shape[0]
    pc = jnp.zeros((nb, tb.k8, params.n), jnp.uint32).at[:, :alpha].set(
        jnp.asarray(p_coeff, jnp.uint32)
    )
    dispatch.record("fused_moddown")
    return _k.fused_moddown_pallas(
        pc, tb.bh, tb.b, tb.binv, tb.w, tb.twa, tb.v2, tb.v1, tb.t, tb.cm,
        tb.q, tb.qinv, jnp.asarray(q_part, jnp.uint32), tb.pinv,
        n1=tb.n1, n2=tb.n2, interpret=jax.default_backend() != "tpu",
    )
