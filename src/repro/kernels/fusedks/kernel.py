"""Pallas TPU kernel: the fused prescale→BConv→NTT→KSK-MAC key-switch pipeline.

This is the kernel-level realisation of FLASH-FHE's fused key-switch datapath
(the iNTT→BConv→NTT pipeline the bootstrappable clusters are built around).
The staged software path launches one kernel per stage per digit, so every
intermediate polynomial round-trips through HBM-equivalent host buffers; here
the whole per-digit pipeline runs inside one ``pallas_call`` and intermediates
never leave VMEM:

  grid = (ext_limb e, digit j) — j innermost, so each output limb's pair of
  accumulators stays resident in VMEM while all β digits stream through it.
  One program:

    1. prescale   x̂_i = x_i ∘ [B̂_i⁻¹]_{b_i}        (one Montgomery mul/limb)
    2. BConv row  y_e = Σ_i x̂_i · (B̂_i mod c_e)     (8-bit limb MXU dot)
    3. NTT        ŷ_e = NTT_{c_e}(y_e)               (four-step MXU matmuls)
    4. KSK MAC    acc_{0,1}[e] += ŷ_e ∘ ksk_{j,{0,1}}[e]   (both components)

Digits are padded to a uniform k8 source-limb count (zero rows with a dummy
modulus are exact no-ops through every stage), so all β digits and both key
components ride one grid.  A second entry point runs the same pipeline with a
ModDown epilogue — (q_part − ŷ) ∘ P⁻¹ — for both accumulators at once.

VMEM per program is dominated by the digit block (k8·N·4 B) plus the two NTT
limb matrices (~2 MB at N=2^16); deep dnum=1 chains exceed VMEM on real TPUs
and are served by the staged path — the dispatcher in ``ops`` stays honest
about that limit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.fhe.ntt import NDIAG, NLIMB8
from repro.kernels.ntt.kernel import _mod_matmul_left, _montmul


def _prescale_bconv_row(x, bh, b, binv, wcol, cm, q, qinv):
    """Stages 1+2: one BConv output row, straight out of the prescale.

    x: (k8, N) digit source limbs; bh: (k8, 1) [B̂⁻¹]·R mod b (Montgomery);
    b/binv: (k8, 1) source moduli + their -b⁻¹ mod 2³²; wcol: (1, k8) B̂ mod c_e;
    cm: (NDIAG,) Montgomery 2^(8s) mod c_e.  Returns (1, N) uint32 < c_e.
    """
    xhat = _montmul(x, bh, b, binv)  # x·B̂⁻¹ mod b, still (k8, N)
    w_limbs = [((wcol >> (8 * k)) & 0xFF).astype(jnp.int32) for k in range(NLIMB8)]
    x_limbs = [((xhat >> (8 * k)) & 0xFF).astype(jnp.int32) for k in range(NLIMB8)]
    diags = [None] * NDIAG
    for kw in range(NLIMB8):
        for kx in range(NLIMB8):
            p = jax.lax.dot_general(
                w_limbs[kw],
                x_limbs[kx],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )  # (1, N), exact: 255²·k8 < 2^22
            s = kw + kx
            diags[s] = p if diags[s] is None else diags[s] + p
    acc = jnp.zeros(diags[0].shape, jnp.uint32)
    for s in range(NDIAG):
        term = _montmul(diags[s].astype(jnp.uint32), cm[s], q, qinv)
        acc = acc + term
        acc = jnp.where(acc >= q, acc - q, acc)
    return acc


def _ntt_fwd_inline(y, twa, v2, v1, tm, cm, q, qinv, n1, n2):
    """Stage 3: forward four-step negacyclic NTT of one limb, all in VMEM.

    Mirrors ``repro.kernels.ntt.kernel._ntt_kernel_body`` (inverse=False).
    """
    a = y.reshape(n2, n1).T
    a = _montmul(a, twa, q, qinv)  # psi twist (A-layout)
    b = _mod_matmul_left(v2, a.T, cm, q, qinv).T  # row NTTs
    b = _montmul(b, tm, q, qinv)  # inter-step twiddle
    c = _mod_matmul_left(v1, b, cm, q, qinv)  # col NTTs
    return c.reshape(n1 * n2)


def _fused_ks_body(
    xd_ref, bh_ref, b_ref, binv_ref, w_ref, twa_ref, v2_ref, v1_ref, t_ref,
    c_ref, q_ref, qinv_ref, r2_ref, ksk_ref, o_ref, *, n1, n2,
):
    j = pl.program_id(1)  # digit index — innermost, accumulates into o_ref
    q = q_ref[0, 0]
    qinv = qinv_ref[0, 0]
    r2 = r2_ref[0, 0]
    cm = c_ref[0]  # (NDIAG,)

    y = _prescale_bconv_row(
        xd_ref[0], bh_ref[0], b_ref[0], binv_ref[0], w_ref[0].T, cm, q, qinv
    )
    yhat = _ntt_fwd_inline(
        y.reshape(-1), twa_ref[0], v2_ref[0], v1_ref[0], t_ref[0], cm, q, qinv, n1, n2
    )

    # stage 4: plain products ŷ∘ksk via Montgomery double-multiply, accumulate
    k0 = ksk_ref[0, 0, 0]
    k1 = ksk_ref[0, 1, 0]
    t0 = _montmul(_montmul(yhat, k0, q, qinv), r2, q, qinv)
    t1 = _montmul(_montmul(yhat, k1, q, qinv), r2, q, qinv)

    @pl.when(j == 0)
    def _():
        o_ref[0, 0] = t0
        o_ref[0, 1] = t1

    @pl.when(j > 0)
    def _():
        s0 = o_ref[0, 0] + t0
        o_ref[0, 0] = jnp.where(s0 >= q, s0 - q, s0)
        s1 = o_ref[0, 1] + t1
        o_ref[0, 1] = jnp.where(s1 >= q, s1 - q, s1)


@functools.partial(jax.jit, static_argnames=("n1", "n2", "interpret"))
def fused_ks_pallas(xd, bh, b, binv, w, twa, v2, v1, t, cm, q, qinv, r2, ksk, *, n1, n2, interpret):
    """All β digits × both key components of one key-switch in one launch.

    xd:  (β, k8, N) digit source limbs (coeff domain, rows zero-padded)
    bh/b/binv: (β, k8, 1) per-digit prescale constants
    w:   (β, k8, m) BConv weights B̂_i mod c_e
    twa/v2/v1/t/cm/q/qinv/r2: ext-basis NTT plan tables, leading (m, ...) axis
    ksk: (β, 2, m, N) switching-key limbs (eval domain)
    Returns (m, 2, N): the two MAC accumulators over the extended basis.
    """
    beta, k8, n = xd.shape
    m = w.shape[2]
    return pl.pallas_call(
        functools.partial(_fused_ks_body, n1=n1, n2=n2),
        grid=(m, beta),
        in_specs=[
            pl.BlockSpec((1, k8, n), lambda e, j: (j, 0, 0)),  # xd
            pl.BlockSpec((1, k8, 1), lambda e, j: (j, 0, 0)),  # bh
            pl.BlockSpec((1, k8, 1), lambda e, j: (j, 0, 0)),  # b
            pl.BlockSpec((1, k8, 1), lambda e, j: (j, 0, 0)),  # binv
            pl.BlockSpec((1, k8, 1), lambda e, j: (j, 0, e)),  # w column e
            pl.BlockSpec((1, n1, n2), lambda e, j: (e, 0, 0)),  # twist
            pl.BlockSpec((1, NLIMB8, n2, n2), lambda e, j: (e, 0, 0, 0)),  # V2
            pl.BlockSpec((1, NLIMB8, n1, n1), lambda e, j: (e, 0, 0, 0)),  # V1
            pl.BlockSpec((1, n1, n2), lambda e, j: (e, 0, 0)),  # inter-step twiddle
            pl.BlockSpec((1, NDIAG), lambda e, j: (e, 0)),  # diagonal mont consts
            pl.BlockSpec((1, 1), lambda e, j: (e, 0)),  # q
            pl.BlockSpec((1, 1), lambda e, j: (e, 0)),  # qinv_neg
            pl.BlockSpec((1, 1), lambda e, j: (e, 0)),  # r2
            pl.BlockSpec((1, 2, 1, n), lambda e, j: (j, 0, e, 0)),  # ksk
        ],
        out_specs=pl.BlockSpec((1, 2, n), lambda e, j: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 2, n), jnp.uint32),
        interpret=interpret,
    )(xd, bh, b, binv, w, twa, v2, v1, t, cm, q, qinv, r2, ksk)


def _fused_moddown_body(
    pc_ref, bh_ref, b_ref, binv_ref, w_ref, twa_ref, v2_ref, v1_ref, t_ref,
    c_ref, q_ref, qinv_ref, qpart_ref, pinv_ref, o_ref, *, n1, n2,
):
    q = q_ref[0, 0]
    qinv = qinv_ref[0, 0]
    cm = c_ref[0]
    y = _prescale_bconv_row(
        pc_ref[0], bh_ref[...], b_ref[...], binv_ref[...], w_ref[...].T, cm, q, qinv
    )
    yhat = _ntt_fwd_inline(
        y.reshape(-1), twa_ref[0], v2_ref[0], v1_ref[0], t_ref[0], cm, q, qinv, n1, n2
    )
    # ModDown epilogue: (q_part − BConv_P→Q(⌊·⌉)) ∘ P⁻¹, still in VMEM
    d = qpart_ref[0, 0]
    diff = jnp.where(d >= yhat, d - yhat, d + q - yhat)
    o_ref[0, 0] = _montmul(diff, pinv_ref[0, 0], q, qinv)


@functools.partial(jax.jit, static_argnames=("n1", "n2", "interpret"))
def fused_moddown_pallas(pc, bh, b, binv, w, twa, v2, v1, t, cm, q, qinv, qpart, pinv, *, n1, n2, interpret):
    """Fused prescale→BConv→NTT→(sub, ×P⁻¹) for a batch of accumulators.

    pc:    (C, k8, N) P-block coefficients of the accumulators after the iNTT
           (C = 2 for one key-switch's pair; C = 2·R when a hoisted rotation
           group ModDowns every rotation's pair in one launch)
    bh/b/binv: (k8, 1) prescale constants for the special block
    w:     (k8, m) B̂ mod q_e;  qpart: (C, m, N) eval-domain q limbs
    pinv:  (m, 1) Montgomery [P⁻¹]_{q_e}
    NTT tables carry the q-basis (m = level+1 limbs).  Returns (C, m, N).
    """
    nb, k8, n = pc.shape
    m = w.shape[1]
    return pl.pallas_call(
        functools.partial(_fused_moddown_body, n1=n1, n2=n2),
        grid=(nb, m),
        in_specs=[
            pl.BlockSpec((1, k8, n), lambda c, e: (c, 0, 0)),  # pc
            pl.BlockSpec((k8, 1), lambda c, e: (0, 0)),  # bh
            pl.BlockSpec((k8, 1), lambda c, e: (0, 0)),  # b
            pl.BlockSpec((k8, 1), lambda c, e: (0, 0)),  # binv
            pl.BlockSpec((k8, 1), lambda c, e: (0, e)),  # w column e
            pl.BlockSpec((1, n1, n2), lambda c, e: (e, 0, 0)),  # twist
            pl.BlockSpec((1, NLIMB8, n2, n2), lambda c, e: (e, 0, 0, 0)),  # V2
            pl.BlockSpec((1, NLIMB8, n1, n1), lambda c, e: (e, 0, 0, 0)),  # V1
            pl.BlockSpec((1, n1, n2), lambda c, e: (e, 0, 0)),  # inter-step twiddle
            pl.BlockSpec((1, NDIAG), lambda c, e: (e, 0)),  # diagonal mont consts
            pl.BlockSpec((1, 1), lambda c, e: (e, 0)),  # q
            pl.BlockSpec((1, 1), lambda c, e: (e, 0)),  # qinv_neg
            pl.BlockSpec((1, 1, n), lambda c, e: (c, e, 0)),  # qpart
            pl.BlockSpec((1, 1), lambda c, e: (e, 0)),  # pinv (mont)
        ],
        out_specs=pl.BlockSpec((1, 1, n), lambda c, e: (c, e, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, m, n), jnp.uint32),
        interpret=interpret,
    )(pc, bh, b, binv, w, twa, v2, v1, t, cm, q, qinv, qpart, pinv)
