"""Staged oracle for the fused key-switch pipeline.

Composes the per-stage reference ops (u64 XLA paths) exactly as the staged
dispatcher in ``repro.fhe.keyswitch`` does, but with no trace recording — this
is the bit-exactness target the fused kernel is tested against, mirroring how
``ntt/ref.py`` and ``bconv/ref.py`` serve their kernels.  Per-(params, level)
tables (digit spans, BConv weights, [P⁻¹]_q) are lru-cached host-side.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.fhe import poly, rns
from repro.fhe.params import CkksParams
from repro.kernels.bconv import ops as bconv_ops
from repro.kernels.modops import ops as mo
from repro.kernels.ntt import ops as ntt_ops


def _scale(x, consts, qs):
    c = jnp.broadcast_to(jnp.asarray(consts, jnp.uint32)[:, None], x.shape)
    return mo.pointwise_mulmod(x, c, qs, backend="ref")


@functools.lru_cache(maxsize=256)
def _digit_ref_tables(params: CkksParams, level: int, j: int):
    """(lo, hi, src_np, bhat_inv, w) for digit j at ``level``."""
    alpha = params.alpha
    lo, hi = j * alpha, min((j + 1) * alpha, level + 1)
    src = poly.primes_for(params, tuple(range(lo, hi)))
    dst = poly.primes_for(params, poly.ext_idx(params, level))
    bhat_inv, w = rns.bconv_tables(src, dst)
    return lo, hi, np.array(src, np.uint64), bhat_inv, jnp.asarray(w)


@functools.lru_cache(maxsize=256)
def _moddown_ref_tables(params: CkksParams, level: int):
    p_primes = poly.primes_for(params, poly.p_idx(params))
    q_primes = poly.primes_for(params, poly.q_idx(params, level))
    bhat_inv, w = rns.bconv_tables(p_primes, q_primes)
    P = rns.product(p_primes)
    pinv = np.array([pow(P % int(q), -1, int(q)) for q in q_primes], np.uint64)
    return (
        np.array(p_primes, np.uint64), np.array(q_primes, np.uint64),
        bhat_inv, jnp.asarray(w), jnp.asarray(pinv[:, None].astype(np.uint32)),
    )


def key_switch_digits_ref(d_coeff, ksk_sel, params: CkksParams, level: int):
    ext = poly.ext_idx(params, level)
    ext_primes = np.array(poly.primes_for(params, ext), np.uint64)
    plan = poly.plan_for(params, ext)
    n = params.n
    acc0 = jnp.zeros((len(ext), n), jnp.uint32)
    acc1 = jnp.zeros((len(ext), n), jnp.uint32)
    for j in range(params.beta(level)):
        lo, hi, src_np, bhat_inv, w = _digit_ref_tables(params, level, j)
        xhat = _scale(d_coeff[lo:hi], bhat_inv, src_np)
        dj_ext = bconv_ops.bconv(xhat, w, ext_primes, backend="ref")
        dj_eval = ntt_ops.ntt_fwd(dj_ext, plan, "ref")
        t0 = mo.pointwise_mulmod(dj_eval, ksk_sel[j, 0], ext_primes, backend="ref")
        t1 = mo.pointwise_mulmod(dj_eval, ksk_sel[j, 1], ext_primes, backend="ref")
        acc0 = mo.pointwise_addmod(acc0, t0, ext_primes, backend="ref")
        acc1 = mo.pointwise_addmod(acc1, t1, ext_primes, backend="ref")
    return acc0, acc1


def mod_down_digits_ref(p_coeff, q_part, params: CkksParams, level: int):
    p_np, q_np, bhat_inv, w, pinv = _moddown_ref_tables(params, level)
    plan = poly.plan_for(params, poly.q_idx(params, level))
    outs = []
    for c in range(p_coeff.shape[0]):
        xhat = _scale(p_coeff[c], bhat_inv, p_np)
        conv = bconv_ops.bconv(xhat, w, q_np, backend="ref")
        conv_eval = ntt_ops.ntt_fwd(conv, plan, "ref")
        diff = mo.pointwise_submod(q_part[c], conv_eval, q_np, backend="ref")
        pinv_b = jnp.broadcast_to(pinv, diff.shape)
        outs.append(mo.pointwise_mulmod(diff, pinv_b, q_np, backend="ref"))
    return jnp.stack(outs)
