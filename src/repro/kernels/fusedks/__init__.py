"""Fused key-switch pipeline kernels (prescale→BConv→NTT→KSK-MAC)."""
