"""Kernel-dispatch counting — the measurable half of the fusion story.

Every public op wrapper (ntt, bconv, modops, fusedks) records one dispatch per
device-kernel launch it issues.  The fused key-switch pipeline's whole point is
collapsing the staged per-digit launch train (prescale, BConv, NTT, two MACs,
two accumulates — each a separate launch whose intermediates round-trip through
HBM-equivalent buffers) into one `pallas_call`; this module lets benchmarks and
tests *measure* that collapse instead of asserting it.

Counting happens at Python call time, so inside an enclosing `jax.jit` the
counts reflect trace-time launches (once per compilation), which is exactly
the static dispatch count of the compiled program.

Tracing: ``repro.obs.Tracer.dispatch_hook()`` plugs into ``hook_dispatches``
(or ``ExecPolicy.traced``) and turns each launch into a unit-width Perfetto
slice at its dispatch *index* — kernels carry no simulated time, so the index
is the deterministic clock for that track (see docs/observability.md).
"""

from __future__ import annotations

import contextlib
import contextvars

_COUNTS: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "kernel_dispatch_counts", default=None
)
_HOOKS: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "kernel_dispatch_hooks", default=()
)


def record(op: str) -> None:
    """Count one kernel dispatch under ``op`` when a counter is active."""
    c = _COUNTS.get()
    if c is not None:
        c[op] = c.get(op, 0) + 1
    for hook in _HOOKS.get():
        hook(op)


@contextlib.contextmanager
def count_dispatches():
    """Collect {op: dispatch_count} for every kernel launched in the block."""
    token = _COUNTS.set({})
    try:
        yield _COUNTS.get()
    finally:
        _COUNTS.reset(token)


@contextlib.contextmanager
def hook_dispatches(fn):
    """Invoke ``fn(op)`` on every kernel dispatch inside the block.

    Unlike ``count_dispatches`` (one aggregate dict per block), hooks compose:
    nested blocks stack, and every active hook sees every dispatch.  This is
    the mechanism behind ``ExecPolicy.dispatch_hook`` — an evaluation context
    can observe its own kernel-launch stream without owning the call site.
    """
    token = _HOOKS.set(_HOOKS.get() + (fn,))
    try:
        yield
    finally:
        _HOOKS.reset(token)


def total(counts: dict) -> int:
    return sum(counts.values())


def counting() -> bool:
    return _COUNTS.get() is not None
