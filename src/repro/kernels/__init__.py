"""Pallas TPU kernels for the FHE hot spots the paper accelerates.

Each kernel package ships three files:
  kernel.py — ``pl.pallas_call`` body with explicit BlockSpec VMEM tiling (TPU target);
  ops.py    — jit'd public wrapper (interpret=True on CPU, compiled on TPU);
  ref.py    — pure-jnp uint64 oracle used by tests as the ground truth.
"""
