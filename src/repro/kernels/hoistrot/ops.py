"""Public hoisted-rotation ops: shared ModUp + batched Galois MAC dispatch.

``mod_up_digits`` raises all β digits of one polynomial to the extended basis
(one launch, digits materialised for reuse); ``galois_mac`` applies every
Galois key of a rotation group against those digits in a single launch.
Backends follow the repo convention:

  * "kernel" — the Pallas pipelines (interpret=True off-TPU);
  * "ref"    — staged u64 oracle in ``ref``;
  * "auto"   — kernel on TPU, ref elsewhere.

Tables are shared with ``kernels.fusedks`` — the ModUp half of a hoisted
rotation is exactly the fused key-switch digit region minus the MAC epilogue,
so the per-(params, level) constants (digit spans, prescale constants, BConv
weights, extended-basis NTT plan) are the same cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.fhe import poly
from repro.fhe.params import CkksParams
from repro.kernels import dispatch
from repro.kernels.fusedks import ops as fused_ops

from . import kernel as _k
from . import ref as _ref


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "ref"
    return backend


def mod_up_digits(d_coeff, params: CkksParams, level: int, backend: str = "auto"):
    """prescale→BConv→NTT for all β digits of one polynomial, ONE launch.

    d_coeff: (level+1, N) coefficient-domain limbs.  Returns (β, m, N) uint32
    eval-domain digits over the extended basis — the reusable ModUp half of a
    key-switch (rotation-independent, shared by a whole hoisted group).
    """
    if _resolve(backend) == "ref":
        return _ref.mod_up_digits_ref(d_coeff, params, level)
    tb = fused_ops.ks_tables(params, level)
    xd = fused_ops.pack_digits(jnp.asarray(d_coeff, jnp.uint32), tb, params.n)
    dispatch.record("hoistmodup")
    return _k.hoist_modup_pallas(
        xd, tb.bh, tb.b, tb.binv, tb.w, tb.twa, tb.v2, tb.v1, tb.t, tb.cm,
        tb.q, tb.qinv, n1=tb.n1, n2=tb.n2, interpret=jax.default_backend() != "tpu",
    )


def galois_mac(dig, ksk, params: CkksParams, level: int, backend: str = "auto",
               staged: bool = False):
    """KSK inner products of one hoisted group: all rotations, ONE launch.

    dig: (β, m, N) hoisted digits (eval, extended basis); ksk: (R, β, 2, m, N)
    σ_t^{-1}-pre-permuted key limbs.  Returns (R, 2, m, N) accumulator pairs.
    ``staged=True`` forces the per-op composition with ``backend`` as the
    stage for every pointwise op (the staged pipeline's semantics) instead of
    the single batched launch.
    """
    if staged:
        return _ref.galois_mac_ref(dig, ksk, params, level, stage=backend)
    if _resolve(backend) == "ref":
        return _ref.galois_mac_ref(dig, ksk, params, level)
    plan = poly.plan_for(params, poly.ext_idx(params, level))
    m = plan.num_limbs
    dispatch.record("hoistmac")
    return _k.hoist_mac_pallas(
        jnp.asarray(dig, jnp.uint32), jnp.asarray(ksk, jnp.uint32),
        jnp.asarray(plan.qs.reshape(m, 1)), jnp.asarray(plan.qinv_neg.reshape(m, 1)),
        jnp.asarray(plan.r2.reshape(m, 1)),
        interpret=jax.default_backend() != "tpu",
    )
