"""Hoisted-rotation kernels: shared ModUp + batched Galois KSK-MAC."""
