"""Pallas TPU kernels for hoisted (Halevi–Shoup) rotation key-switching.

A key-switched rotation splits into a ModUp half (digit decompose → prescale →
BConv → NTT into the extended basis) and an apply half (KSK-MAC + ModDown).
The ModUp half depends only on the input polynomial — never on the Galois
element — so a group of rotations of the same ciphertext can share ONE ModUp.
Two kernels realise that split:

  * ``hoist_modup_pallas`` — the fused prescale→BConv→NTT pipeline of
    ``kernels.fusedks`` with the MAC epilogue removed: grid = (ext_limb e,
    digit j), one launch raises all β digits to the extended basis and
    *materialises* them (β, m, N) instead of folding them into accumulators.

  * ``hoist_mac_pallas`` — the batched Galois apply: grid = (ext_limb e,
    rotation r) with r innermost, so the hoisted digit block for limb e
    ((β, N) words) is copied into VMEM once and stays resident while every
    rotation of the group streams its switching key through the MAC.  Keys
    arrive pre-permuted by σ_t^{-1} (see ``fhe.keyswitch.hoisted_ksk``), which
    turns the per-digit automorphism into a single post-ModDown permutation
    and keeps this kernel a pure Montgomery multiply-accumulate.

Per-rotation work after hoisting is one (1, β, 2, 1, N) key stream + 2N MACs
per extended limb — no NTT, no BConv.  The β forward NTTs of the ModUp are
paid once per group instead of once per rotation: O(β + k) vs O(k·β).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.fhe.ntt import NDIAG, NLIMB8
from repro.kernels.fusedks.kernel import _ntt_fwd_inline, _prescale_bconv_row
from repro.kernels.ntt.kernel import _montmul


def _modup_body(
    xd_ref, bh_ref, b_ref, binv_ref, w_ref, twa_ref, v2_ref, v1_ref, t_ref,
    c_ref, q_ref, qinv_ref, o_ref, *, n1, n2,
):
    q = q_ref[0, 0]
    qinv = qinv_ref[0, 0]
    cm = c_ref[0]  # (NDIAG,)
    y = _prescale_bconv_row(
        xd_ref[0], bh_ref[0], b_ref[0], binv_ref[0], w_ref[0].T, cm, q, qinv
    )
    o_ref[0, 0] = _ntt_fwd_inline(
        y.reshape(-1), twa_ref[0], v2_ref[0], v1_ref[0], t_ref[0], cm, q, qinv, n1, n2
    )


@functools.partial(jax.jit, static_argnames=("n1", "n2", "interpret"))
def hoist_modup_pallas(xd, bh, b, binv, w, twa, v2, v1, t, cm, q, qinv, *, n1, n2, interpret):
    """Raise all β digits of one polynomial to the extended basis: ONE launch.

    Same inputs as ``fusedks.fused_ks_pallas`` minus the key material:
    xd (β, k8, N) zero-padded digit source limbs (coeff domain), per-digit
    prescale constants, BConv weights, and the extended-basis NTT plan.
    Returns (β, m, N) uint32 — the hoisted digits, eval domain, reusable by
    every rotation of the group.
    """
    beta, k8, n = xd.shape
    m = w.shape[2]
    return pl.pallas_call(
        functools.partial(_modup_body, n1=n1, n2=n2),
        grid=(m, beta),
        in_specs=[
            pl.BlockSpec((1, k8, n), lambda e, j: (j, 0, 0)),  # xd
            pl.BlockSpec((1, k8, 1), lambda e, j: (j, 0, 0)),  # bh
            pl.BlockSpec((1, k8, 1), lambda e, j: (j, 0, 0)),  # b
            pl.BlockSpec((1, k8, 1), lambda e, j: (j, 0, 0)),  # binv
            pl.BlockSpec((1, k8, 1), lambda e, j: (j, 0, e)),  # w column e
            pl.BlockSpec((1, n1, n2), lambda e, j: (e, 0, 0)),  # twist
            pl.BlockSpec((1, NLIMB8, n2, n2), lambda e, j: (e, 0, 0, 0)),  # V2
            pl.BlockSpec((1, NLIMB8, n1, n1), lambda e, j: (e, 0, 0, 0)),  # V1
            pl.BlockSpec((1, n1, n2), lambda e, j: (e, 0, 0)),  # inter-step twiddle
            pl.BlockSpec((1, NDIAG), lambda e, j: (e, 0)),  # diagonal mont consts
            pl.BlockSpec((1, 1), lambda e, j: (e, 0)),  # q
            pl.BlockSpec((1, 1), lambda e, j: (e, 0)),  # qinv_neg
        ],
        out_specs=pl.BlockSpec((1, 1, n), lambda e, j: (j, e, 0)),
        out_shape=jax.ShapeDtypeStruct((beta, m, n), jnp.uint32),
        interpret=interpret,
    )(xd, bh, b, binv, w, twa, v2, v1, t, cm, q, qinv)


def _mac_body(dig_ref, ksk_ref, q_ref, qinv_ref, r2_ref, o_ref, *, beta):
    q = q_ref[0, 0]
    qinv = qinv_ref[0, 0]
    r2 = r2_ref[0, 0]
    acc0 = acc1 = None
    for j in range(beta):  # β is static — the loop unrolls inside one program
        x = dig_ref[j, 0]
        t0 = _montmul(_montmul(x, ksk_ref[0, j, 0, 0], q, qinv), r2, q, qinv)
        t1 = _montmul(_montmul(x, ksk_ref[0, j, 1, 0], q, qinv), r2, q, qinv)
        if acc0 is None:
            acc0, acc1 = t0, t1
        else:
            s0 = acc0 + t0
            acc0 = jnp.where(s0 >= q, s0 - q, s0)
            s1 = acc1 + t1
            acc1 = jnp.where(s1 >= q, s1 - q, s1)
    o_ref[0, 0, 0] = acc0
    o_ref[0, 1, 0] = acc1


@functools.partial(jax.jit, static_argnames=("interpret",))
def hoist_mac_pallas(dig, ksk, q, qinv, r2, *, interpret):
    """Every rotation of one hoisted group in a single launch.

    dig: (β, m, N) hoisted digits (eval domain, extended basis) — the limb-e
         block is VMEM-resident across all R rotations (r is the inner grid
         axis, so its block index is constant while r sweeps);
    ksk: (R, β, 2, m, N) σ_t^{-1}-pre-permuted switching-key limbs;
    q/qinv/r2: (m, 1) extended-basis Montgomery constants.
    Returns (R, 2, m, N): one MAC accumulator pair per rotation, still in the
    σ_t^{-1} frame (the caller ModDowns, then applies the permutation once).
    """
    beta, m, n = dig.shape
    nrot = ksk.shape[0]
    return pl.pallas_call(
        functools.partial(_mac_body, beta=beta),
        grid=(m, nrot),
        in_specs=[
            pl.BlockSpec((beta, 1, n), lambda e, r: (0, e, 0)),  # dig (resident per e)
            pl.BlockSpec((1, beta, 2, 1, n), lambda e, r: (r, 0, 0, e, 0)),  # ksk
            pl.BlockSpec((1, 1), lambda e, r: (e, 0)),  # q
            pl.BlockSpec((1, 1), lambda e, r: (e, 0)),  # qinv_neg
            pl.BlockSpec((1, 1), lambda e, r: (e, 0)),  # r2
        ],
        out_specs=pl.BlockSpec((1, 2, 1, n), lambda e, r: (r, 0, e, 0)),
        out_shape=jax.ShapeDtypeStruct((nrot, 2, m, n), jnp.uint32),
        interpret=interpret,
    )(dig, ksk, q, qinv, r2)
