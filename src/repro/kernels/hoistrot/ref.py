"""Staged oracle for the hoisted-rotation kernels.

Composes the per-stage reference ops exactly as the staged dispatcher in
``repro.fhe.keyswitch`` does (no trace recording) — the bit-exactness target
for ``hoist_modup_pallas``/``hoist_mac_pallas``, mirroring ``fusedks/ref.py``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.fhe import poly
from repro.fhe.params import CkksParams
from repro.kernels.bconv import ops as bconv_ops
from repro.kernels.fusedks.ref import _digit_ref_tables, _scale
from repro.kernels.modops import ops as mo
from repro.kernels.ntt import ops as ntt_ops


def mod_up_digits_ref(d_coeff, params: CkksParams, level: int):
    """(level+1, N) coeff limbs → (β, m, N) eval-domain extended-basis digits."""
    ext = poly.ext_idx(params, level)
    ext_primes = np.array(poly.primes_for(params, ext), np.uint64)
    plan = poly.plan_for(params, ext)
    rows = []
    for j in range(params.beta(level)):
        lo, hi, src_np, bhat_inv, w = _digit_ref_tables(params, level, j)
        xhat = _scale(d_coeff[lo:hi], bhat_inv, src_np)
        dj_ext = bconv_ops.bconv(xhat, w, ext_primes, backend="ref")
        rows.append(ntt_ops.ntt_fwd(dj_ext, plan, "ref"))
    return jnp.stack(rows)


def galois_mac_ref(dig, ksk, params: CkksParams, level: int, stage: str = "ref"):
    """Σ_j dig_j ∘ ksk_{r,j} per rotation: (R, β, 2, m, N) keys → (R, 2, m, N).

    ``stage`` is the per-op backend for every pointwise MAC (the staged
    pipeline threads its resolved stage here; "ref" is the u64 oracle)."""
    ext = poly.ext_idx(params, level)
    ext_primes = np.array(poly.primes_for(params, ext), np.uint64)
    m, n = dig.shape[1], dig.shape[2]
    outs = []
    for r in range(ksk.shape[0]):
        acc0 = jnp.zeros((m, n), jnp.uint32)
        acc1 = jnp.zeros((m, n), jnp.uint32)
        for j in range(params.beta(level)):
            t0 = mo.pointwise_mulmod(dig[j], ksk[r, j, 0], ext_primes, backend=stage)
            t1 = mo.pointwise_mulmod(dig[j], ksk[r, j, 1], ext_primes, backend=stage)
            acc0 = mo.pointwise_addmod(acc0, t0, ext_primes, backend=stage)
            acc1 = mo.pointwise_addmod(acc1, t1, ext_primes, backend=stage)
        outs.append(jnp.stack([acc0, acc1]))
    return jnp.stack(outs)
