"""Pure-jnp uint64 oracle for the negacyclic NTT (natural-order output).

Iterative radix-2 decimation-in-time over the cyclic root w = psi^2, with the
negacyclic psi-twist applied before (fwd) / after (inv).  O(N log N), fully
vectorised in XLA — this is also the fast CPU execution path for the FHE library.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.fhe.ntt import NttPlan, bit_reverse_indices


@functools.lru_cache(maxsize=32)
def _bitrev(n: int):
    # numpy (not jnp): a jnp constant materialised inside a jit trace would be a
    # tracer, and the lru_cache would leak it across traces.
    return bit_reverse_indices(n)


def _cyclic_ntt_u64(a, w_pows, qs):
    """Cyclic NTT along last axis.  a: (..., L, N) u64; w_pows: (L, N); qs: (L,)."""
    n = a.shape[-1]
    q = qs.astype(jnp.uint64)[..., :, None]
    a = jnp.take(a, _bitrev(n), axis=-1)
    m = 1
    while m < n:
        span = 2 * m
        tw = w_pows[..., :, :: n // span][..., :m]  # (L, m): w^((N/2m)·j)
        ar = a.reshape(a.shape[:-1] + (n // span, 2, m))
        even = ar[..., 0, :]  # (..., L, n//span, m)
        odd = (ar[..., 1, :] * tw[..., :, None, :]) % q[..., None]
        s = even + odd
        plus = jnp.where(s >= q[..., None], s - q[..., None], s)
        minus = jnp.where(even >= odd, even - odd, even + q[..., None] - odd)
        a = jnp.concatenate([plus, minus], axis=-1)  # per-block [first half | second half]
        a = a.reshape(a.shape[:-2] + (n,))
        m = span
    return a


@functools.partial(jax.jit, static_argnames=())
def _ntt_fwd_impl(x, psi_pows, w_pows, qs):
    q = qs.astype(jnp.uint64)[..., :, None]
    a = (x.astype(jnp.uint64) * psi_pows) % q
    return _cyclic_ntt_u64(a, w_pows, qs)


@functools.partial(jax.jit, static_argnames=())
def _ntt_inv_impl(x, psiinv_ninv, winv_pows, qs):
    q = qs.astype(jnp.uint64)[..., :, None]
    a = _cyclic_ntt_u64(x.astype(jnp.uint64), winv_pows, qs)
    return (a * psiinv_ninv) % q


def ntt_fwd_ref(x, plan: NttPlan, level: int | None = None):
    """x: (..., l, N) uint32/uint64 coefficients → (..., l, N) uint32 slots."""
    l = x.shape[-2] if level is None else level
    out = _ntt_fwd_impl(
        x, jnp.asarray(plan.psi_pows[:l]), jnp.asarray(plan.w_pows[:l]), jnp.asarray(plan.qs[:l])
    )
    return out.astype(jnp.uint32)


def ntt_inv_ref(x, plan: NttPlan, level: int | None = None):
    l = x.shape[-2] if level is None else level
    out = _ntt_inv_impl(
        x,
        jnp.asarray(plan.psiinv_ninv[:l]),
        jnp.asarray(plan.winv_pows[:l]),
        jnp.asarray(plan.qs[:l]),
    )
    return out.astype(jnp.uint32)


def negacyclic_mul_schoolbook(a: np.ndarray, b: np.ndarray, q: int) -> np.ndarray:
    """O(N^2) host oracle for ring multiplication in Z_q[x]/(x^N+1) (tiny N only)."""
    n = a.shape[-1]
    a = a.astype(object)
    b = b.astype(object)
    out = np.zeros(n, dtype=object)
    for i in range(n):
        for j in range(n):
            k = i + j
            v = a[i] * b[j]
            if k >= n:
                out[k - n] = (out[k - n] - v) % q
            else:
                out[k] = (out[k] + v) % q
    return np.array([int(v) % q for v in out], dtype=np.uint64)
