from .ops import ntt_fwd, ntt_inv  # noqa: F401
