"""Pallas TPU kernel: four-step negacyclic NTT as MXU matmuls.

This is the TPU-native re-think of FLASH-FHE's (i)NTT circuits (DESIGN.md §2):

* the paper's R-point NTT *circuit* becomes an R×R modular **matmul on the MXU** —
  operands are decomposed into 8-bit limbs so int32 accumulation is exact
  (255·255·N2 < 2^26 for N2 ≤ 512), limb diagonals are recombined with Montgomery
  constants 2^(8s)·R mod q;
* the paper's L1 transpose becomes an in-VMEM transpose between the two matmuls;
* multi-entrance/exit: the same kernel body is instantiated per ring degree
  (N1×N2 ∈ {16..256}×{128,256}); parallel small-point NTTs ride the (batch, limb)
  grid, which is how a "bootstrappable" 256-wide datapath serves many shallow jobs.

Grid: (batch, limbs).  Per-program VMEM working set for N=2^16:
x block 256 KB + V1/V2 limb matrices 2×1 MB + twiddles 2×256 KB ≈ 3 MB < VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.fhe.ntt import NDIAG, NLIMB8


def _mulhi32(a, b):
    al = a & 0xFFFF
    ah = a >> 16
    bl = b & 0xFFFF
    bh = b >> 16
    t = al * bl
    u = ah * bl + (t >> 16)
    v = al * bh + (u & 0xFFFF)
    return ah * bh + (u >> 16) + (v >> 16)


def _montmul(a, b, q, qinv_neg):
    t_lo = a * b
    t_hi = _mulhi32(a, b)
    m = t_lo * qinv_neg
    mq_hi = _mulhi32(m, q)
    res = t_hi + mq_hi + (t_lo != 0).astype(jnp.uint32)
    return jnp.where(res >= q, res - q, res)


def _mod_matmul_left(v_limbs, x, c_mont, q, qinv_neg):
    """(V @ x) mod q.  v_limbs: (NLIMB8, M, K) int32 8-bit limbs of V;
    x: (K, N) uint32 < q.  Exact MXU path: int32 dot per (limb_v, limb_x) pair,
    diagonals recombined via Montgomery mult by 2^(8s)·R."""
    x_limbs = [((x >> (8 * k)) & 0xFF).astype(jnp.int32) for k in range(NLIMB8)]
    diags = [None] * NDIAG
    for kv in range(NLIMB8):
        for kx in range(NLIMB8):
            p = jax.lax.dot_general(
                v_limbs[kv],
                x_limbs[kx],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            s = kv + kx
            diags[s] = p if diags[s] is None else diags[s] + p
    acc = jnp.zeros(diags[0].shape, jnp.uint32)
    for s in range(NDIAG):
        term = _montmul(diags[s].astype(jnp.uint32), c_mont[s], q, qinv_neg)
        acc = acc + term
        acc = jnp.where(acc >= q, acc - q, acc)
    return acc


def _ntt_kernel_body(
    x_ref, twa_ref, v2_ref, v1_ref, t_ref, c_ref, q_ref, qinv_ref, o_ref, *, n1, n2, inverse
):
    q = q_ref[0, 0]
    qinv = qinv_ref[0, 0]
    c = c_ref[0]  # (NDIAG,)
    v2 = v2_ref[0]  # (NLIMB8, N2, N2)
    v1 = v1_ref[0]  # (NLIMB8, N1, N1)
    tm = t_ref[0]  # (N1, N2) mont
    twa = twa_ref[0]  # (N1, N2) mont

    x = x_ref[0, 0]  # (N,) uint32
    if not inverse:
        # A[n1_, n2_] = a[n1_ + N1·n2_]  (reshape (N2,N1) then transpose — the L1 transpose)
        a = x.reshape(n2, n1).T
        a = _montmul(a, twa, q, qinv)  # psi twist (A-layout)
        # step 1: row NTTs (contract n2):  B = A @ V2  ⇒  (V2ᵀ @ Aᵀ)ᵀ ; V2 symmetric
        b = _mod_matmul_left(v2, a.T, c, q, qinv).T
        b = _montmul(b, tm, q, qinv)  # inter-step twiddle w^(n1·k2)
        cmat = _mod_matmul_left(v1, b, c, q, qinv)  # col NTTs (contract n1)
        o_ref[0, 0] = cmat.reshape(n1 * n2)  # X[N2·k1 + k2]
    else:
        xm = x.reshape(n1, n2)  # X[k1, k2]
        cmat = _mod_matmul_left(v1, xm, c, q, qinv)  # contract k1 with V1^{-1}
        cmat = _montmul(cmat, tm, q, qinv)  # w^{-n1·k2}
        a = _mod_matmul_left(v2, cmat.T, c, q, qinv).T  # contract k2 with V2^{-1}
        a = _montmul(a, twa, q, qinv)  # psi^{-i}·N^{-1} twist (A-layout)
        o_ref[0, 0] = a.T.reshape(n1 * n2)  # a[n1_ + N1·n2_]


@functools.partial(jax.jit, static_argnames=("n1", "n2", "inverse", "interpret"))
def ntt_pallas(x, twa, v2, v1, t, c, q, qinv, *, n1, n2, inverse, interpret):
    """x: (B, L, N) uint32.  Table args carry the leading (L, ...) limb axis."""
    bsz, nlimb, n = x.shape
    grid = (bsz, nlimb)
    return pl.pallas_call(
        functools.partial(_ntt_kernel_body, n1=n1, n2=n2, inverse=inverse),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, n), lambda b, l: (b, l, 0)),  # x
            pl.BlockSpec((1, n1, n2), lambda b, l: (l, 0, 0)),  # twist (A layout)
            pl.BlockSpec((1, NLIMB8, n2, n2), lambda b, l: (l, 0, 0, 0)),  # V2 limbs
            pl.BlockSpec((1, NLIMB8, n1, n1), lambda b, l: (l, 0, 0, 0)),  # V1 limbs
            pl.BlockSpec((1, n1, n2), lambda b, l: (l, 0, 0)),  # inter-step twiddle
            pl.BlockSpec((1, NDIAG), lambda b, l: (l, 0)),  # diagonal mont consts
            pl.BlockSpec((1, 1), lambda b, l: (l, 0)),  # q
            pl.BlockSpec((1, 1), lambda b, l: (l, 0)),  # qinv_neg
        ],
        out_specs=pl.BlockSpec((1, 1, n), lambda b, l: (b, l, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, nlimb, n), jnp.uint32),
        interpret=interpret,
    )(x, twa, v2, v1, t, c, q, qinv)
