"""Public NTT ops: jit'd wrappers over the Pallas kernel / u64 reference.

``backend``:
  * "kernel" — the Pallas four-step MXU kernel (interpret=True off-TPU);
  * "ref"    — vectorised uint64 XLA path (fast on CPU; exact oracle);
  * "auto"   — kernel on TPU, ref elsewhere (keeps CPU tests fast while the
               TPU target exercises the MXU datapath).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.fhe.ntt import NttPlan
from repro.kernels import dispatch

from . import kernel as _k
from . import ref as _ref


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "ref"
    return backend


def _run_kernel(x, plan: NttPlan, inverse: bool):
    l = x.shape[-2]
    lead = x.shape[:-2]
    xb = x.reshape((-1, l, plan.n)).astype(jnp.uint32)
    twa = jnp.asarray((plan.twia_mont if inverse else plan.twa_mont)[:l])
    v2 = jnp.asarray((plan.v2i_limbs if inverse else plan.v2_limbs)[:l])
    v1 = jnp.asarray((plan.v1i_limbs if inverse else plan.v1_limbs)[:l])
    t = jnp.asarray((plan.ti_mont if inverse else plan.t_mont)[:l])
    c = jnp.asarray(plan.c_mont[:l])
    q = jnp.asarray(plan.qs[:l]).reshape(l, 1)
    qinv = jnp.asarray(plan.qinv_neg[:l]).reshape(l, 1)
    out = _k.ntt_pallas(
        xb, twa, v2, v1, t, c, q, qinv,
        n1=plan.n1, n2=plan.n2, inverse=inverse,
        interpret=jax.default_backend() != "tpu",
    )
    return out.reshape(lead + (l, plan.n))


def ntt_fwd(x, plan: NttPlan, backend: str = "auto"):
    """Coefficients → NTT slots (natural order).  x: (..., l, N) uint32."""
    dispatch.record("ntt")
    if _resolve(backend) == "kernel":
        return _run_kernel(x, plan, inverse=False)
    return _ref.ntt_fwd_ref(x, plan)


def ntt_inv(x, plan: NttPlan, backend: str = "auto"):
    """NTT slots → coefficients.  x: (..., l, N) uint32."""
    dispatch.record("intt")
    if _resolve(backend) == "kernel":
        return _run_kernel(x, plan, inverse=True)
    return _ref.ntt_inv_ref(x, plan)
