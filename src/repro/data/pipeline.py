"""Deterministic, index-addressable synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) — any host can
recompute any other host's shard, which is the substrate for straggler
mitigation and elastic restart (no data-loader state to checkpoint; the
manifest stores only the step counter).

Two sources:
  * `synthetic_lm_batch` — hashed pseudo-random token ids (throughput work);
  * `ByteCorpus` — byte-level language modelling over a real text buffer,
    so the end-to-end example trains on something learnable.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def _hash_u32(x: np.ndarray) -> np.ndarray:
    """splitmix-style avalanche over uint32 (vectorised, stateless)."""
    x = x.astype(np.uint64)
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = x
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    return (z ^ (z >> np.uint64(31))).astype(np.uint32)


def synthetic_lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int,
                       shard: int = 0, n_shards: int = 1) -> np.ndarray:
    """(batch/n_shards, seq+1) int32 tokens — pure function of its arguments."""
    local = batch // n_shards
    idx = (np.uint64(seed) << np.uint64(40)) ^ (np.uint64(step) << np.uint64(20))
    rows = np.arange(local, dtype=np.uint64) + np.uint64(shard * local)
    base = _hash_u32((idx + rows)[:, None] * np.uint64(1000003) +
                     np.arange(seq + 1, dtype=np.uint64)[None, :])
    return (base % np.uint32(vocab)).astype(np.int32)


_DEFAULT_TEXT = (
    "the quick brown fox jumps over the lazy dog. "
    "flash-fhe schedules shallow jobs one per affiliation while deep "
    "bootstrapping pipelines span every cluster. "
) * 512


@dataclasses.dataclass
class ByteCorpus:
    """Byte-level LM over an in-memory buffer with deterministic sampling."""

    text: str = _DEFAULT_TEXT
    vocab: int = 256

    def __post_init__(self):
        self.buf = np.frombuffer(self.text.encode(), dtype=np.uint8)

    def batch(self, seed: int, step: int, batch: int, seq: int,
              shard: int = 0, n_shards: int = 1) -> np.ndarray:
        local = batch // n_shards
        rows = np.arange(local, dtype=np.uint64) + np.uint64(shard * local)
        starts = _hash_u32(np.uint64(seed * 2654435761 + step) + rows) % \
            np.uint32(len(self.buf) - seq - 1)
        out = np.stack([self.buf[s : s + seq + 1] for s in starts.astype(np.int64)])
        return out.astype(np.int32)
