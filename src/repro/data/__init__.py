"""repro.data"""
