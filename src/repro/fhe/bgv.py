"""BGV exact integer arithmetic over the shared CKKS RNS/NTT substrate.

The scheme axis of the repo (ROADMAP "multi-scheme frontier", APACHE/BASALISC
in PAPERS.md): BGV ciphertexts are the *same* (level+1, N) uint32 eval-domain
RNS polynomials CKKS uses, run through the same NTT / BConv / key-switch
kernels — only the plaintext embedding and the level-drop arithmetic differ.
Messages are integers mod t packed into polynomial coefficients (message in
the LOW-order bits: phase = m + t·e), so every result is bit-exact mod t, with
no scale tracking.

Parameter restriction that makes this work (``CkksParams.plain_modulus``):
t is a power of two dividing 2·N_MAX = 2^17.  Every master-chain prime is
NTT-friendly for N_MAX, hence q ≡ 1 (mod 2^17) ⇒ q ≡ 1 (mod t), and the
special-modulus product P ≡ 1 (mod t).  Consequences used throughout:

  * **Modulus switch** (``_mod_switch``, the BGV analogue of rescale): drop
    the last limb by subtracting δ = t·[t^{-1}·c]_{q_ℓ} (centred) and dividing
    by q_ℓ.  δ ≡ c (mod q_ℓ) and δ ≡ 0 (mod t), and q_ℓ^{-1} ≡ 1 (mod t), so
    the message mod t is preserved exactly.
  * **Relinearisation** (inside ``_mul``): the shared hybrid key-switch ends
    in a ModDown by P whose rounding term must also vanish mod t.  Rather
    than fork the fused/staged ModDown kernels, we wrap them in a t-scaling
    sandwich: BGV_ModDown(x) = t · ModDown(t^{-1} · x).  Pre-multiplying the
    extended-basis accumulators by [t^{-1}] makes the correction the kernel
    subtracts equal t·(lift) ≡ 0 (mod t); post-multiplying the q-basis result
    by t undoes the twist.  Both pipelines (fused Pallas and staged oracle)
    run unchanged between the two pointwise scalings, so cross-backend
    bit-exactness is inherited rather than re-proven.
  * **Keys**: BGV public/switching keys carry t-scaled errors (b = -a·s +
    t·e [+ P·F_j·s']) — ``keys._err_scale`` derives the multiplier from the
    params, so ``full_keyset`` needs no scheme flag.

Every op records the same planner-visible trace instructions as its CKKS
sibling plus the explicit t-wrap PMULTs; ``core.planner`` mirrors the BGV
expansions (``bgv_hmul``, ``bgv_mod_switch``) for the serving simulator.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.kernels.modops import ops as mo

from . import keyswitch, poly, rns, trace
from .keys import PublicKey, SecretKey, SwitchingKey
from .params import CkksParams


@dataclasses.dataclass
class BgvPlaintext:
    """Integer message packed into coefficients — (level+1, N) uint32 eval."""

    data: jnp.ndarray
    level: int


@dataclasses.dataclass
class BgvCiphertext:
    c0: jnp.ndarray  # (level+1, N) uint32, eval domain
    c1: jnp.ndarray
    level: int

    @property
    def nbytes(self) -> int:
        return int(self.c0.nbytes + self.c1.nbytes)


def _t(params: CkksParams) -> int:
    t = params.plain_modulus
    if t is None:
        raise ValueError("BGV ops need params with plain_modulus set")
    return int(t)


def _qs(params: CkksParams, level: int) -> np.ndarray:
    return np.array(params.q_primes[: level + 1], np.uint64)


# ---------------------------------------------------------------------------
# encode / decode — coefficient packing of integers mod t
# ---------------------------------------------------------------------------


def _encode(ctx, z, level: int | None = None) -> BgvPlaintext:
    """Pack ≤ N integers mod t into polynomial coefficients (eval domain).

    Multiplication therefore acts as negacyclic convolution mod t — exactly
    the u64-oracle semantics the differential tests pin against.
    """
    params = ctx.params
    t = _t(params)
    level = params.L if level is None else level
    z = np.asarray(z, dtype=np.int64) % t
    if z.ndim != 1 or z.shape[0] > params.n:
        raise ValueError(f"BGV encode wants ≤ {params.n} integers, got shape {z.shape}")
    coeffs = np.zeros(params.n, np.int64)
    coeffs[: z.shape[0]] = z
    # centred representatives keep |m| ≤ t/2 — half a bit of noise headroom
    coeffs = np.where(coeffs > t // 2, coeffs - t, coeffs)
    data = poly.to_eval(
        poly.to_rns_signed(coeffs, params.q_primes[: level + 1]),
        params, poly.q_idx(params, level), ctx.stage,
    )
    return BgvPlaintext(data=data, level=level)


def _decode(ctx, pt: BgvPlaintext) -> np.ndarray:
    """Coefficients → integers in [0, t).  Exact as long as the phase noise
    m + t·e is smaller than q_ℓ/2 — full-limb centred CRT, unlike the CKKS
    decode which only needs decode-scale magnitudes."""
    params = ctx.params
    t = _t(params)
    coeffs = poly.to_coeff(pt.data, params, poly.q_idx(params, pt.level), ctx.stage)
    centered = rns.crt_reconstruct_centered(
        np.asarray(coeffs), params.q_primes[: pt.level + 1], max_limbs=pt.level + 1
    )
    return (centered % t).astype(np.int64)


# ---------------------------------------------------------------------------
# encrypt / decrypt — message in the low-order bits: phase = m + t·e
# ---------------------------------------------------------------------------


def _encrypt(ctx, pk: PublicKey, pt: BgvPlaintext, seed: int = 17) -> BgvCiphertext:
    params = ctx.params
    t = _t(params)
    rng = np.random.default_rng(seed)
    level = pt.level
    idx = poly.q_idx(params, level)
    primes = params.q_primes[: level + 1]
    qs = _qs(params, level)
    bk = ctx.stage
    v = poly.to_eval(
        poly.to_rns_signed(poly.sample_ternary(rng, params.n, params.n // 2), primes),
        params, idx, bk,
    )
    # encryption errors are t-scaled, like the key errors (pk.b = -a·s + t·e)
    e0 = poly.to_eval(
        poly.to_rns_signed(t * poly.sample_gaussian(rng, params.n), primes), params, idx, bk
    )
    e1 = poly.to_eval(
        poly.to_rns_signed(t * poly.sample_gaussian(rng, params.n), primes), params, idx, bk
    )
    trace.record("PMULT", params.n, 2 * (level + 1))
    c0 = mo.pointwise_addmod(
        mo.pointwise_addmod(mo.pointwise_mulmod(v, pk.b[: level + 1], qs, backend=bk), e0, qs, backend=bk),
        pt.data, qs, backend=bk,
    )
    c1 = mo.pointwise_addmod(mo.pointwise_mulmod(v, pk.a[: level + 1], qs, backend=bk), e1, qs, backend=bk)
    return BgvCiphertext(c0=c0, c1=c1, level=level)


def _decrypt(ctx, sk: SecretKey, ct: BgvCiphertext) -> BgvPlaintext:
    params = ctx.params
    qs = _qs(params, ct.level)
    bk = ctx.stage
    trace.record("PMULT", params.n, ct.level + 1)
    m = mo.pointwise_addmod(
        ct.c0, mo.pointwise_mulmod(ct.c1, sk.s_eval[: ct.level + 1], qs, backend=bk), qs, backend=bk
    )
    return BgvPlaintext(data=m, level=ct.level)


# ---------------------------------------------------------------------------
# additive ops
# ---------------------------------------------------------------------------


def level_drop(ct: BgvCiphertext, level: int) -> BgvCiphertext:
    """Limb truncation — valid in BGV exactly because dropping limbs of the
    RNS tower is reduction mod a smaller Q' ≡ ... the phase mod Q' still
    equals m + t·e' (every dropped prime ≡ 1 mod t)."""
    if level == ct.level:
        return ct
    assert level < ct.level
    return BgvCiphertext(c0=ct.c0[: level + 1], c1=ct.c1[: level + 1], level=level)


def _align(a: BgvCiphertext, b: BgvCiphertext):
    lv = min(a.level, b.level)
    return level_drop(a, lv), level_drop(b, lv)


def _add(ctx, a: BgvCiphertext, b: BgvCiphertext) -> BgvCiphertext:
    params = ctx.params
    a, b = _align(a, b)
    qs = _qs(params, a.level)
    bk = ctx.stage
    trace.record("PADD", params.n, 2 * (a.level + 1))
    return BgvCiphertext(
        c0=mo.pointwise_addmod(a.c0, b.c0, qs, backend=bk),
        c1=mo.pointwise_addmod(a.c1, b.c1, qs, backend=bk),
        level=a.level,
    )


def _sub(ctx, a: BgvCiphertext, b: BgvCiphertext) -> BgvCiphertext:
    params = ctx.params
    a, b = _align(a, b)
    qs = _qs(params, a.level)
    bk = ctx.stage
    trace.record("PSUB", params.n, 2 * (a.level + 1))
    return BgvCiphertext(
        c0=mo.pointwise_submod(a.c0, b.c0, qs, backend=bk),
        c1=mo.pointwise_submod(a.c1, b.c1, qs, backend=bk),
        level=a.level,
    )


def _negate(ctx, a: BgvCiphertext) -> BgvCiphertext:
    params = ctx.params
    qs = _qs(params, a.level)
    bk = ctx.stage
    z = jnp.zeros_like(a.c0)
    trace.record("PSUB", params.n, 2 * (a.level + 1))
    return BgvCiphertext(
        c0=mo.pointwise_submod(z, a.c0, qs, backend=bk),
        c1=mo.pointwise_submod(z, a.c1, qs, backend=bk),
        level=a.level,
    )


# ---------------------------------------------------------------------------
# multiplication + relinearisation (t-wrapped hybrid key switch)
# ---------------------------------------------------------------------------


def _relin(ctx, d2, rlk: SwitchingKey, level: int):
    """Key-switch d2·s² → s with the ModDown wrapped in the t-scaling
    sandwich (module docstring): the subtracted rounding correction becomes a
    multiple of t, so the key-switch error lands entirely in the t·e slot."""
    params = ctx.params
    t = _t(params)
    bk = ctx.backend
    stage = ctx.stage
    ksk_sel = keyswitch._select_ksk(rlk, params, level, params.beta(level))
    acc0, acc1 = keyswitch.key_switch_accumulate(d2, params, level, ksk_sel, bk)

    ext_primes = np.array(
        poly.primes_for(params, poly.ext_idx(params, level)), np.uint64
    )
    tinv_ext = np.array([pow(t, -1, int(p)) for p in ext_primes], np.uint64)
    acc0 = keyswitch._scale_limbs(acc0, tinv_ext, ext_primes, stage)
    acc1 = keyswitch._scale_limbs(acc1, tinv_ext, ext_primes, stage)

    ks0, ks1 = keyswitch.mod_down_pair(acc0, acc1, params, level, bk)

    qs = _qs(params, level)
    t_q = np.full(level + 1, t, np.uint64)  # t < 2^31 ⇒ [t]_q = t
    ks0 = keyswitch._scale_limbs(ks0, t_q, qs, stage)
    ks1 = keyswitch._scale_limbs(ks1, t_q, qs, stage)
    return ks0, ks1


def _mul(ctx, a: BgvCiphertext, b: BgvCiphertext, rlk: SwitchingKey,
         mod_switch_after: bool = True) -> BgvCiphertext:
    """Homomorphic multiply: tensor, relinearise d2, optionally mod-switch one
    level down (the BGV noise-management analogue of the CKKS rescale)."""
    params = ctx.params
    a, b = _align(a, b)
    qs = _qs(params, a.level)
    bk = ctx.stage
    trace.record("PMULT", params.n, 4 * (a.level + 1))
    d0 = mo.pointwise_mulmod(a.c0, b.c0, qs, backend=bk)
    d2 = mo.pointwise_mulmod(a.c1, b.c1, qs, backend=bk)
    cross1 = mo.pointwise_mulmod(a.c0, b.c1, qs, backend=bk)
    cross2 = mo.pointwise_mulmod(a.c1, b.c0, qs, backend=bk)
    trace.record("PADD", params.n, a.level + 1)
    d1 = mo.pointwise_addmod(cross1, cross2, qs, backend=bk)
    ks0, ks1 = _relin(ctx, d2, rlk, a.level)
    trace.record("PADD", params.n, 2 * (a.level + 1))
    out = BgvCiphertext(
        c0=mo.pointwise_addmod(d0, ks0, qs, backend=bk),
        c1=mo.pointwise_addmod(d1, ks1, qs, backend=bk),
        level=a.level,
    )
    return _mod_switch(ctx, out) if mod_switch_after else out


# ---------------------------------------------------------------------------
# modulus switch — the BGV level-drop
# ---------------------------------------------------------------------------


def _mod_switch(ctx, ct: BgvCiphertext) -> BgvCiphertext:
    """Drop q_ℓ: c' = (c − δ)·q_ℓ^{-1} with δ = t·[t^{-1}·c]_{q_ℓ} centred.

    δ ≡ c (mod q_ℓ) makes the division exact; δ ≡ 0 (mod t) and q_ℓ ≡ 1
    (mod t) preserve the message mod t bit-exactly while the noise drops by a
    factor ≈ q_ℓ.  Mirrors the CKKS ``ops._rescale`` dataflow (and its trace
    shape, plus one single-limb PMULT for the t^{-1} twist).
    """
    params = ctx.params
    t = _t(params)
    lv = ct.level
    assert lv >= 1, "cannot mod-switch at level 0"
    q_last = int(params.q_primes[lv])
    qs_rem = _qs(params, lv - 1)
    rem_primes = params.q_primes[:lv]
    bk = ctx.stage
    tinv = pow(t, -1, q_last)
    qinv = np.array([pow(q_last % int(q), -1, int(q)) for q in rem_primes], np.uint64)
    qinv_b = jnp.asarray(qinv[:, None].astype(np.uint32))
    qs_rem_i64 = jnp.asarray(qs_rem.astype(np.int64))[:, None]

    def _one(c):
        # iNTT the dropped limb, twist by t^{-1}, centre, re-scale by t — the
        # centred multiple-of-t congruent to c mod q_ℓ — then re-embed in the
        # remaining bases, subtract, and divide by q_ℓ.
        last_coeff = poly.to_coeff(c[lv : lv + 1], params, (lv,), bk)
        trace.record("PMULT", params.n, 1)
        u = (last_coeff[0].astype(jnp.uint64) * tinv) % q_last
        u_signed = jnp.where(u > q_last // 2, u.astype(jnp.int64) - q_last, u.astype(jnp.int64))
        delta = t * u_signed  # |δ| ≤ t·q_ℓ/2 < 2^47: exact in int64
        rem = (delta[None, :] % qs_rem_i64).astype(jnp.uint32)
        rem_eval = poly.to_eval(rem, params, poly.q_idx(params, lv - 1), bk)
        trace.record("PSUB", params.n, lv)
        diff = mo.pointwise_submod(c[:lv], rem_eval, qs_rem, backend=bk)
        trace.record("PMULT", params.n, lv)
        return mo.pointwise_mulmod(diff, jnp.broadcast_to(qinv_b, diff.shape), qs_rem, backend=bk)

    return BgvCiphertext(c0=_one(ct.c0), c1=_one(ct.c1), level=lv - 1)
