"""First-class evaluation contexts: the primary public API of ``repro.fhe``.

Historically every homomorphic op took loose execution kwargs — a kernel
``backend`` (fused/staged/ref/kernel/auto), a rotation ``hoisting`` mode
(never/auto/always), and the planner's ``fused=`` mirror — threaded through
~40 signatures across ``ops``/``linear``/``bootstrap``/``polyeval`` and the
serving memo keys.  ``FheContext`` replaces that threading with one immutable
object bundling the three things an evaluation needs:

  * ``CkksParams``  — the cryptographic parameter set,
  * ``KeySet``      — public/secret/relinearisation/Galois keys (optional for
                      key-less ops like ``add``),
  * ``ExecPolicy``  — *how* to execute: kernel backend, hoisting mode, the
                      numerics mode (future: double-hoisting keeps BSGS inner
                      products in the extended basis — not bit-exact, so it is
                      a policy field, not a kwarg), and an optional
                      dispatch-counter hook observing every kernel launch.

Ops are implemented ONCE, against a context (the ``_impl`` functions in
``ops``/``linear``/``bootstrap``/``polyeval``); the legacy module-level free
functions are deprecated shims that build an equivalent context and delegate.
``ExecPolicy.policy_key()`` is the single source of truth wherever a policy
must act as a cache key: the serving service-time memo
(``repro.serve.policy.job_service_sim``) and the planner's mirrored trace
shapes (``repro.core.planner.workload_stream(policy=...)``).

Quick use::

    from repro.fhe import FheContext, ExecPolicy, keys as K, params as P

    p = P.make_params(1 << 9, 6, 2, check_security=False)
    ctx = FheContext(params=p, keys=K.full_keyset(p, rotations=(1,)))

    ct = ctx.encrypt(ctx.encode(x))
    ct = ctx.rotate(ctx.mul(ct, ct), 1)
    y = ctx.decrypt_decode(ct)

    fast = ctx.with_policy(backend="fused", hoisting="always")  # scoped override
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

from repro.kernels import dispatch

from . import bgv as _bgv
from . import bootstrap as _bootstrap
from . import keyswitch, linear, ops, polyeval
from .keys import KeySet, SwitchingKey
from .params import CkksParams

BACKENDS = ("fused", "kernel", "staged", "ref", "auto")
HOISTING_MODES = ops.HOISTING_MODES  # ("never", "auto", "always")
# "standard" is today's exact-arithmetic pipeline; "double_hoist" (Bossuat et
# al.: ModDown once per giant group, ext-basis plaintext muls) is the next
# planned mode — it changes the noise profile, so it must be opted into here
# rather than through yet another kwarg thread.
NUMERICS_MODES = ("standard",)
# Scheme axis: CKKS (approximate complex arithmetic) and BGV (exact integer
# arithmetic mod t) share the whole RNS/NTT/key-switch substrate but expand to
# different instruction streams, so the scheme is part of the policy identity.
SCHEMES = ("ckks", "bgv")


@dataclasses.dataclass(frozen=True)
class ExecPolicy:
    """How to execute: every evaluation-shaping knob, in one immutable value.

    ``policy_key()`` is the canonical cache identity — two policies with equal
    keys are guaranteed to produce identical instruction streams and cycle
    counts, and distinct (scheme, backend, hoisting, numerics) tuples never
    alias.  ``dispatch_hook`` is deliberately NOT part of the key (or of
    equality): observing kernel launches cannot change what is launched.
    """

    backend: str = "auto"  # kernel pipeline: fused | kernel | staged | ref | auto
    hoisting: str = "auto"  # rotation key-switch shape: never | auto | always
    numerics: str = "standard"  # exactness class (future: double_hoist)
    scheme: str = "ckks"  # which scheme's op expansions run: ckks | bgv
    dispatch_hook: Callable[[str], None] | None = dataclasses.field(
        default=None, compare=False
    )

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown key-switch backend {self.backend!r}")
        if self.hoisting not in HOISTING_MODES:
            raise ValueError(f"unknown hoisting mode {self.hoisting!r}")
        if self.numerics not in NUMERICS_MODES:
            raise ValueError(
                f"unknown numerics mode {self.numerics!r}; available: {NUMERICS_MODES}"
            )
        if self.scheme not in SCHEMES:
            raise ValueError(f"unknown scheme {self.scheme!r}; available: {SCHEMES}")

    # -- identity -----------------------------------------------------------

    def policy_key(self) -> tuple[str, str, str, str]:
        """Hashable identity for memo keys (serving service times, planner
        stream caches).  The scheme leads: a BGV and a CKKS job with otherwise
        identical knobs run different op expansions and must never share a
        cached service time.  Excludes ``dispatch_hook`` — hooks observe
        execution, they never change it."""
        return (self.scheme, self.backend, self.hoisting, self.numerics)

    def replace(self, **changes) -> "ExecPolicy":
        return dataclasses.replace(self, **changes)

    def for_scheme(self, scheme: str) -> "ExecPolicy":
        """This policy re-tagged for ``scheme`` (identity when it already
        matches) — the serving layer derives per-job effective policies this
        way, so one engine can price mixed CKKS+BGV traffic distinctly."""
        return self if scheme == self.scheme else dataclasses.replace(self, scheme=scheme)

    def traced(self, tracer) -> "ExecPolicy":
        """This policy with its kernel launches recorded into ``tracer`` (an
        ``repro.obs.Tracer``): each dispatch becomes a unit-width slice at its
        dispatch index (kernels have no sim-time of their own).  Composes with
        an existing hook — both observe every launch.  A disabled tracer (or
        None) returns ``self`` unchanged, preserving the zero-overhead rule.
        ``policy_key`` ignores hooks, so the traced policy prices identically.
        """
        if tracer is None or not tracer:
            return self
        traced_hook = tracer.dispatch_hook()
        prior = self.dispatch_hook
        if prior is None:
            hook = traced_hook
        else:
            def hook(op: str) -> None:
                prior(op)
                traced_hook(op)
        return dataclasses.replace(self, dispatch_hook=hook)

    # -- resolved views -----------------------------------------------------

    @property
    def stage(self) -> str:
        """Pointwise-stage backend this policy resolves to."""
        return keyswitch.resolve_pipeline(self.backend)[1]

    @property
    def plan_fused(self) -> bool:
        """Does this policy run the fused key-switch pipeline?  Drives the
        planner's working-set boundary records (``fused=`` mirror)."""
        return keyswitch.resolve_pipeline(self.backend)[0] == "fused"

    @property
    def plan_hoist(self) -> bool:
        """Does this policy hoist BSGS baby-step groups?  ``auto`` counts as
        hoisted: every multi-rotation group shares its ModUp."""
        return self.hoisting != "never"


def _hooked(fn):
    """Run a context method under the policy's dispatch-counter hook."""

    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        hook = self.policy.dispatch_hook
        if hook is None:
            return fn(self, *args, **kwargs)
        with dispatch.hook_dispatches(hook):
            return fn(self, *args, **kwargs)

    return wrapper


@dataclasses.dataclass(frozen=True)
class FheContext:
    """Immutable (params, keys, policy) bundle — the context every op runs in.

    All methods delegate to the single context-consuming implementations in
    ``ops``/``linear``/``bootstrap``/``polyeval``; the legacy free functions
    are deprecated shims over the same implementations.  Contexts are cheap
    values: ``with_policy`` derives a scoped override sharing params and keys.
    """

    params: CkksParams
    keys: KeySet | None = None
    policy: ExecPolicy = ExecPolicy()

    def __post_init__(self):
        # The scheme is ground truth on the params (plain_modulus set ⇔ BGV);
        # the policy's scheme tag is derived state for cache identity.  Align
        # it here so ``ctx.policy_key()`` is correctly scheme-tagged without
        # every construction site having to thread ``scheme=`` by hand.
        object.__setattr__(self, "policy", self.policy.for_scheme(self.params.scheme))

    # -- derivation ---------------------------------------------------------

    def with_policy(self, policy: ExecPolicy | None = None, **changes) -> "FheContext":
        """A context with an overridden policy (same params/keys).

        Either pass a full ``ExecPolicy`` or field overrides:
        ``ctx.with_policy(backend="fused", hoisting="always")``.
        """
        if policy is not None and changes:
            raise TypeError("pass either a policy or field overrides, not both")
        new = policy if policy is not None else self.policy.replace(**changes)
        return dataclasses.replace(self, policy=new)

    def with_keys(self, keys: KeySet) -> "FheContext":
        return dataclasses.replace(self, keys=keys)

    def policy_key(self) -> tuple[str, str, str, str]:
        return self.policy.policy_key()

    # -- resolved execution knobs (used by the impl layer) ------------------

    @property
    def scheme(self) -> str:
        """The scheme this context evaluates ("ckks" or "bgv") — always equal
        to ``params.scheme`` (aligned at construction)."""
        return self.policy.scheme

    @property
    def backend(self) -> str:
        """Key-switch pipeline choice, passed to the ``keyswitch`` layer."""
        return self.policy.backend

    @property
    def stage(self) -> str:
        """Resolved pointwise-stage backend for elementwise/NTT kernels."""
        return self.policy.stage

    def require_keys(self) -> KeySet:
        if self.keys is None:
            raise ValueError(
                "this operation needs a KeySet; build the context with keys= "
                "or derive one via ctx.with_keys(...)"
            )
        return self.keys

    # -- encode / encrypt / decrypt -----------------------------------------

    @_hooked
    def encode(self, z, level: int | None = None, scale: float | None = None):
        if self.scheme == "bgv":
            return _bgv._encode(self, z, level)
        return ops._encode(self, z, level, scale)

    @_hooked
    def encode_const(self, c, level: int, scale: float) -> "ops.Plaintext":
        return ops._encode_const(self, c, level, scale)

    @_hooked
    def decode(self, pt):
        if self.scheme == "bgv":
            return _bgv._decode(self, pt)
        return ops._decode(self, pt)

    @_hooked
    def encrypt(self, pt, seed: int = 17):
        if self.scheme == "bgv":
            return _bgv._encrypt(self, self.require_keys().pk, pt, seed)
        return ops._encrypt(self, self.require_keys().pk, pt, seed)

    @_hooked
    def decrypt(self, ct):
        if self.scheme == "bgv":
            return _bgv._decrypt(self, self.require_keys().sk, ct)
        return ops._decrypt(self, self.require_keys().sk, ct)

    @_hooked
    def decrypt_decode(self, ct):
        sk = self.require_keys().sk
        if self.scheme == "bgv":
            return _bgv._decode(self, _bgv._decrypt(self, sk, ct))
        return ops._decode(self, ops._decrypt(self, sk, ct))

    # -- additive ops -------------------------------------------------------

    @_hooked
    def add(self, a, b):
        if self.scheme == "bgv":
            return _bgv._add(self, a, b)
        return ops._add(self, a, b)

    @_hooked
    def sub(self, a, b):
        if self.scheme == "bgv":
            return _bgv._sub(self, a, b)
        return ops._sub(self, a, b)

    @_hooked
    def negate(self, a):
        if self.scheme == "bgv":
            return _bgv._negate(self, a)
        return ops._negate(self, a)

    @_hooked
    def add_plain(self, a, pt):
        return ops._add_plain(self, a, pt)

    @_hooked
    def add_const(self, a, c):
        return ops._add_const(self, a, c)

    def level_drop(self, ct, level: int):
        return ops.level_drop(ct, level)

    # -- multiplicative ops -------------------------------------------------

    @_hooked
    def mul_plain(self, a, pt, rescale_after: bool = True):
        return ops._mul_plain(self, a, pt, rescale_after)

    @_hooked
    def mul_const(self, a, c, rescale_after: bool = True):
        return ops._mul_const(self, a, c, rescale_after)

    @_hooked
    def mul_const_exact(self, a, c, target_scale: float):
        return ops._mul_const_exact(self, a, c, target_scale)

    @_hooked
    def mul(self, a, b, rlk: SwitchingKey | None = None, rescale_after: bool = True):
        """Ciphertext-ciphertext multiplication with relinearisation.  Under a
        BGV context, ``rescale_after`` means "modulus-switch one level down
        after the product" (the BGV analogue of the CKKS rescale)."""
        rlk = rlk if rlk is not None else self.require_keys().rlk
        if self.scheme == "bgv":
            return _bgv._mul(self, a, b, rlk, mod_switch_after=rescale_after)
        return ops._mul(self, a, b, rlk, rescale_after)

    @_hooked
    def square(self, a, rlk: SwitchingKey | None = None, rescale_after: bool = True):
        rlk = rlk if rlk is not None else self.require_keys().rlk
        if self.scheme == "bgv":
            return _bgv._mul(self, a, a, rlk, mod_switch_after=rescale_after)
        return ops._mul(self, a, a, rlk, rescale_after)

    @_hooked
    def rescale(self, ct):
        if self.scheme == "bgv":
            raise ValueError("BGV has no rescale; use ctx.mod_switch(ct) instead")
        return ops._rescale(self, ct)

    @_hooked
    def mod_switch(self, ct):
        """BGV modulus switch: drop the last chain prime, preserving the
        message mod t exactly (q_ℓ ≡ 1 mod t on the shared chain)."""
        if self.scheme != "bgv":
            raise ValueError("mod_switch is a BGV op; use ctx.rescale for CKKS")
        return _bgv._mod_switch(self, ct)

    # -- rotations / conjugation --------------------------------------------

    @_hooked
    def rotate(self, ct, r: int):
        """Cyclic slot rotation by r; the policy's hoisting mode picks the
        key-switch shape ("always" routes a single rotation through the
        hoisted path — bit-exact either way)."""
        return ops._rotate(self, ct, r, self.require_keys())

    @_hooked
    def rotate_hoisted(self, ct, r: int, hoisted=None):
        return ops._rotate_hoisted(self, ct, r, self.require_keys(), hoisted)

    @_hooked
    def rotate_hoisted_group(self, ct, rots) -> dict:
        return ops._rotate_hoisted_group(self, ct, rots, self.require_keys())

    @_hooked
    def conjugate(self, ct):
        return ops._conjugate(self, ct, self.require_keys())

    # -- linear transforms ---------------------------------------------------

    def plan_matrix(self, m, n1: int | None = None, tol: float = 0.0,
                    level: int | None = None) -> "linear.BsgsPlan":
        """BSGS plan for a dense matrix; when ``n1`` is not forced, the baby
        count comes from the hoisting-aware cost model (under a hoisting
        policy, baby steps are nearly free, so the optimum shifts upward)."""
        return linear.plan_matrix(
            m, n1=n1, tol=tol, params=self.params,
            level=self.params.L if level is None else level,
            hoisting=self.policy.plan_hoist,
        )

    @_hooked
    def apply_bsgs(self, ct, plan: "linear.BsgsPlan", scale: float | None = None):
        return linear._apply_bsgs(self, ct, plan, scale)

    @_hooked
    def apply_bsgs_pair(self, ct, plans, scale: float | None = None):
        return (
            linear._apply_bsgs(self, ct, plans[0], scale),
            linear._apply_bsgs(self, ct, plans[1], scale),
        )

    @_hooked
    def real_part(self, ct):
        return linear._real_part(self, ct)

    @_hooked
    def imag_part(self, ct):
        return linear._imag_part(self, ct)

    # -- polynomial evaluation ----------------------------------------------

    @_hooked
    def force_to(self, ct, level: int, scale: float):
        return polyeval._force_to(self, ct, level, scale)

    @_hooked
    def add_any(self, a, b):
        return polyeval._add_any(self, a, b)

    @_hooked
    def chebyshev_basis(self, x, degree: int) -> "polyeval.ChebyshevBasis":
        return polyeval.ChebyshevBasis(self, x, degree)

    @_hooked
    def eval_poly(self, ct, coeffs, degree: int | None = None):
        """Σ c_i·T_i(ct) in the Chebyshev basis (exact scale discipline)."""
        import numpy as np

        degree = len(np.asarray(coeffs)) - 1 if degree is None else degree
        basis = polyeval.ChebyshevBasis(self, ct, degree)
        return polyeval._eval_chebyshev(self, basis, coeffs)

    @_hooked
    def eval_chebyshev(self, basis: "polyeval.ChebyshevBasis", coeffs):
        return polyeval._eval_chebyshev(self, basis, coeffs)

    # -- bootstrapping -------------------------------------------------------

    @_hooked
    def bootstrap(self, bctx: "_bootstrap.BootstrapContext", ct, post_scale: float | None = None):
        """Refresh an exhausted ciphertext through ``bctx``'s precomputed
        plans/keys under THIS context's execution policy."""
        return _bootstrap._bootstrap(self._bootstrap_ctx(bctx), bctx, ct, post_scale)

    @_hooked
    def mod_raise(self, bctx, ct):
        return _bootstrap._mod_raise(self._bootstrap_ctx(bctx), bctx, ct)

    @_hooked
    def coeff_to_slot(self, bctx, ct):
        return _bootstrap._coeff_to_slot(self._bootstrap_ctx(bctx), bctx, ct)

    @_hooked
    def eval_mod(self, bctx, ct, coeff_scale: float):
        return _bootstrap._eval_mod(self._bootstrap_ctx(bctx), bctx, ct, coeff_scale)

    @_hooked
    def slot_to_coeff(self, bctx, a0, a1):
        return _bootstrap._slot_to_coeff(self._bootstrap_ctx(bctx), bctx, a0, a1)

    def _bootstrap_ctx(self, bctx) -> "FheContext":
        """This policy over the bootstrap context's params/keys (the plans are
        precomputed against those — a mismatched KeySet would be unsound)."""
        assert bctx.params == self.params, (
            "BootstrapContext params differ from this FheContext's params"
        )
        if self.keys is bctx.keys:
            return self
        return dataclasses.replace(self, keys=bctx.keys)
