"""Hybrid key switching — the iNTT→BConv→NTT pipeline the paper accelerates.

`key_switch(d, level, ...)` homomorphically maps a polynomial d (eval domain,
basis q_0..q_ℓ) multiplied by s' into a pair under s:

    1. INTT d over the active basis                       (iNTT stage)
    2. per digit j < β(ℓ): prescale by [B̂_i^{-1}]_{b_i},
       BConv digit → {q_0..q_ℓ} ∪ {p_0..p_α-1}            (BConv stage)
    3. NTT each converted digit over the extended basis   (NTT stage)
    4. accumulate  Σ_j  d̂_j ∘ ksk_j                       (MAC stage)
    5. ModDown by P: INTT(P limbs) → BConv P→Q → NTT → subtract, ×[P^{-1}]_q

Every stage records trace instructions; this function *is* the workload the
bootstrappable clusters are shaped around.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.kernels.bconv import ops as bconv_ops
from repro.kernels.modops import ops as mo

from . import poly, rns, trace
from .keys import SwitchingKey
from .params import CkksParams


@functools.lru_cache(maxsize=2048)
def _digit_tables(params: CkksParams, level: int, j: int):
    """(src_idx, bhat_inv, w, dst_primes) for digit j at ``level``."""
    digit_idx = tuple(i for i in params.digit(j) if i <= level)
    src = poly.primes_for(params, digit_idx)
    dst_idx = poly.ext_idx(params, level)
    dst = poly.primes_for(params, dst_idx)
    bhat_inv, w = rns.bconv_tables(src, dst)
    return digit_idx, jnp.asarray(bhat_inv), jnp.asarray(w), np.array(dst, np.uint64)


@functools.lru_cache(maxsize=512)
def _moddown_tables(params: CkksParams, level: int):
    p_primes = poly.primes_for(params, poly.p_idx(params))
    q_primes = poly.primes_for(params, poly.q_idx(params, level))
    bhat_inv, w = rns.bconv_tables(p_primes, q_primes)
    P = 1
    for p in p_primes:
        P *= int(p)
    pinv = np.array([pow(P % int(q), -1, int(q)) for q in q_primes], np.uint64)
    return jnp.asarray(bhat_inv), jnp.asarray(w), np.array(q_primes, np.uint64), jnp.asarray(
        pinv[:, None].astype(np.uint32)
    )


def _scale_limbs(x, consts, qs, backend):
    """x ∘ diag(consts) per limb — consts: (k,) broadcast over N."""
    trace.record("PMULT", x.shape[-1], x.shape[-2])
    c = jnp.broadcast_to(jnp.asarray(consts, jnp.uint32)[:, None], x.shape)
    return mo.pointwise_mulmod(x, c, qs, backend="ref" if backend == "ref" else backend)


def mod_down(acc_ext, params: CkksParams, level: int, backend: str = "auto"):
    """Extended-basis eval-domain poly → q-basis, divided (rounded) by P."""
    nq = level + 1
    q_part, p_part = acc_ext[:nq], acc_ext[nq:]
    bhat_inv, w, q_np, pinv = _moddown_tables(params, level)
    p_np = np.array(poly.primes_for(params, poly.p_idx(params)), np.uint64)

    p_coeff = poly.to_coeff(p_part, params, poly.p_idx(params), backend)
    xhat = _scale_limbs(p_coeff, bhat_inv, p_np, backend)
    trace.record("BCONV", params.n, len(p_np), dst=nq)
    conv = bconv_ops.bconv(xhat, w, q_np, backend="ref" if backend == "ref" else "auto")
    conv_eval = poly.to_eval(conv, params, poly.q_idx(params, level), backend)

    trace.record("PSUB", params.n, nq)
    diff = mo.pointwise_submod(q_part, conv_eval, q_np, backend="ref")
    trace.record("PMULT", params.n, nq)
    pinv_b = jnp.broadcast_to(pinv, diff.shape)
    return mo.pointwise_mulmod(diff, pinv_b, q_np, backend="ref")


def key_switch(d_eval, params: CkksParams, level: int, ksk: SwitchingKey, backend: str = "auto"):
    """d (eval, basis q_0..q_ℓ) ⊗ s' → (ks0, ks1) eval over q_0..q_ℓ under s."""
    n = params.n
    beta = params.beta(level)
    ext = poly.ext_idx(params, level)
    ext_primes = np.array(poly.primes_for(params, ext), np.uint64)
    nq = level + 1

    trace.record("LOAD_KSK", n, beta * 2 * len(ext))
    d_coeff = poly.to_coeff(d_eval, params, poly.q_idx(params, level), backend)

    acc0 = jnp.zeros((len(ext), n), jnp.uint32)
    acc1 = jnp.zeros((len(ext), n), jnp.uint32)
    ksk_sel = jnp.concatenate(
        [ksk.k[:, :, : level + 1], ksk.k[:, :, params.L + 1 :]], axis=2
    )  # (dnum, 2, |ext|, N) restricted to active + special limbs
    for j in range(beta):
        digit_idx, bhat_inv, w, dst = _digit_tables(params, level, j)
        src_np = np.array(poly.primes_for(params, digit_idx), np.uint64)
        dj = d_coeff[digit_idx[0] : digit_idx[-1] + 1]
        xhat = _scale_limbs(dj, bhat_inv, src_np, backend)
        trace.record("BCONV", n, len(digit_idx), dst=len(ext))
        dj_ext = bconv_ops.bconv(xhat, w, dst, backend="ref" if backend == "ref" else "auto")
        dj_eval = poly.to_eval(dj_ext, params, ext, backend)
        trace.record("PMULT", n, 2 * len(ext))
        t0 = mo.pointwise_mulmod(dj_eval, ksk_sel[j, 0], ext_primes, backend="ref")
        t1 = mo.pointwise_mulmod(dj_eval, ksk_sel[j, 1], ext_primes, backend="ref")
        trace.record("PADD", n, 2 * len(ext))
        acc0 = mo.pointwise_addmod(acc0, t0, ext_primes, backend="ref")
        acc1 = mo.pointwise_addmod(acc1, t1, ext_primes, backend="ref")

    ks0 = mod_down(acc0, params, level, backend)
    ks1 = mod_down(acc1, params, level, backend)
    return ks0, ks1
