"""Hybrid key switching — the iNTT→BConv→NTT pipeline the paper accelerates.

`key_switch(d, level, ...)` homomorphically maps a polynomial d (eval domain,
basis q_0..q_ℓ) multiplied by s' into a pair under s:

    1. INTT d over the active basis                       (iNTT stage)
    2. per digit j < β(ℓ): prescale by [B̂_i^{-1}]_{b_i},
       BConv digit → {q_0..q_ℓ} ∪ {p_0..p_α-1}            (BConv stage)
    3. NTT each converted digit over the extended basis   (NTT stage)
    4. accumulate  Σ_j  d̂_j ∘ ksk_j                       (MAC stage)
    5. ModDown by P: INTT(P limbs) → BConv P→Q → NTT → subtract, ×[P^{-1}]_q

Two pipeline shapes execute the same math:

  * **fused** — stages 2–4 run as ONE `pallas_call` per key-switch (and one
    more for the ModDown tails of both accumulators) via
    ``repro.kernels.fusedks``; intermediates stay in VMEM, and the trace
    carries the fused per-stage records with no working-set boundaries.
    This is FLASH-FHE's fused key-switch datapath.
  * **staged** — one kernel launch per stage per digit (the F1+-style
    software pipeline); every stage boundary emits STORE_WS/LOAD_WS trace
    records because the intermediate polynomial round-trips through
    HBM-equivalent buffers between launches.

``backend`` selects both the pipeline and the stage numerics:
  "fused"/"kernel" → fused Pallas pipeline (interpret off-TPU);
  "staged"         → staged pipeline, per-stage auto backends;
  "ref"            → staged pipeline, u64 oracle stages (jit-traceable);
  "auto"           → fused on TPU, staged-ref elsewhere (CPU tests stay fast).

Every stage records trace instructions; this function *is* the workload the
bootstrappable clusters are shaped around.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bconv import ops as bconv_ops
from repro.kernels.fusedks import ops as fused_ops
from repro.kernels.modops import ops as mo
from repro.kernels.ntt import ops as ntt_ops

from . import poly, rns, trace
from .keys import SwitchingKey
from .params import CkksParams


def resolve_pipeline(backend: str) -> tuple[str, str]:
    """Map a backend choice to (pipeline, stage_backend)."""
    if backend == "fused":
        return "fused", "auto"
    if backend == "kernel":
        return "fused", "kernel"
    if backend == "staged":
        return "staged", "auto"
    if backend == "ref":
        return "staged", "ref"
    if backend == "auto":
        if jax.default_backend() == "tpu":
            return "fused", "auto"
        return "staged", "ref"
    raise ValueError(f"unknown key-switch backend {backend!r}")


def _boundary(n: int, limbs: int) -> None:
    """A staged-dispatch boundary: the intermediate round-trips through memory."""
    trace.record("STORE_WS", n, limbs)
    trace.record("LOAD_WS", n, limbs)


@functools.lru_cache(maxsize=2048)
def _digit_tables(params: CkksParams, level: int, j: int):
    """(src_idx, bhat_inv, w, dst_primes) for digit j at ``level``."""
    digit_idx = tuple(i for i in params.digit(j) if i <= level)
    src = poly.primes_for(params, digit_idx)
    dst_idx = poly.ext_idx(params, level)
    dst = poly.primes_for(params, dst_idx)
    bhat_inv, w = rns.bconv_tables(src, dst)
    return digit_idx, jnp.asarray(bhat_inv), jnp.asarray(w), np.array(dst, np.uint64)


@functools.lru_cache(maxsize=512)
def _moddown_tables(params: CkksParams, level: int):
    p_primes = poly.primes_for(params, poly.p_idx(params))
    q_primes = poly.primes_for(params, poly.q_idx(params, level))
    bhat_inv, w = rns.bconv_tables(p_primes, q_primes)
    P = rns.product(p_primes)
    pinv = np.array([pow(P % int(q), -1, int(q)) for q in q_primes], np.uint64)
    return jnp.asarray(bhat_inv), jnp.asarray(w), np.array(q_primes, np.uint64), jnp.asarray(
        pinv[:, None].astype(np.uint32)
    )


def _scale_limbs(x, consts, qs, backend):
    """x ∘ diag(consts) per limb — consts: (k,) broadcast over N."""
    trace.record("PMULT", x.shape[-1], x.shape[-2])
    c = jnp.broadcast_to(jnp.asarray(consts, jnp.uint32)[:, None], x.shape)
    return mo.pointwise_mulmod(x, c, qs, backend=backend)


def _select_ksk(ksk: SwitchingKey, params: CkksParams, level: int, beta: int):
    """(β, 2, |ext|, N): key limbs restricted to active + special moduli."""
    return jnp.concatenate(
        [ksk.k[:, :, : level + 1], ksk.k[:, :, params.L + 1 :]], axis=2
    )[:beta]


def _record_fused_digits(params: CkksParams, level: int) -> None:
    """Trace the fused per-digit pipeline (planner `key_switch(fused=True)`)."""
    n = params.n
    m = len(poly.ext_idx(params, level))
    for j in range(params.beta(level)):
        k = len(tuple(i for i in params.digit(j) if i <= level))
        trace.record("PMULT", n, k, fused=True)
        trace.record("BCONV", n, k, dst=m, fused=True)
        trace.record("NTT", n, m, fused=True)
        trace.record("PMULT", n, 2 * m, mac=True, fused=True)
        trace.record("PADD", n, 2 * m, mac=True, fused=True)


def _record_fused_moddown(params: CkksParams, level: int) -> None:
    n, nq, a = params.n, level + 1, params.alpha
    trace.record("INTT", n, a)
    trace.record("PMULT", n, a, fused=True)
    trace.record("BCONV", n, a, dst=nq, fused=True)
    trace.record("NTT", n, nq, fused=True)
    trace.record("PSUB", n, nq, mac=True, fused=True)
    trace.record("PMULT", n, nq, mac=True, fused=True)


def mod_down(acc_ext, params: CkksParams, level: int, backend: str = "auto"):
    """Extended-basis eval-domain poly → q-basis, divided (rounded) by P.

    Staged pipeline for one accumulator; the fused path batches both
    accumulators through ``mod_down_pair`` instead.
    """
    _, stage = resolve_pipeline(backend)
    n = params.n
    nq = level + 1
    alpha = params.alpha
    q_part, p_part = acc_ext[:nq], acc_ext[nq:]
    bhat_inv, w, q_np, pinv = _moddown_tables(params, level)
    p_np = np.array(poly.primes_for(params, poly.p_idx(params)), np.uint64)

    p_coeff = poly.to_coeff(p_part, params, poly.p_idx(params), stage)
    xhat = _scale_limbs(p_coeff, bhat_inv, p_np, stage)
    _boundary(n, alpha)
    trace.record("BCONV", n, alpha, dst=nq)
    conv = bconv_ops.bconv(xhat, w, q_np, backend=stage)
    _boundary(n, nq)
    conv_eval = poly.to_eval(conv, params, poly.q_idx(params, level), stage)
    _boundary(n, nq)
    trace.record("PSUB", n, nq, mac=True)
    diff = mo.pointwise_submod(q_part, conv_eval, q_np, backend=stage)
    _boundary(n, nq)
    trace.record("PMULT", n, nq, mac=True)
    pinv_b = jnp.broadcast_to(pinv, diff.shape)
    return mo.pointwise_mulmod(diff, pinv_b, q_np, backend=stage)


def mod_down_pair(acc0, acc1, params: CkksParams, level: int, backend: str = "auto"):
    """ModDown both MAC accumulators; fused path shares one kernel launch."""
    pipeline, stage = resolve_pipeline(backend)
    if pipeline != "fused":
        return (
            mod_down(acc0, params, level, backend),
            mod_down(acc1, params, level, backend),
        )
    nq = level + 1
    _record_fused_moddown(params, level)
    _record_fused_moddown(params, level)
    p_part = jnp.stack([acc0[nq:], acc1[nq:]])
    plan = poly.plan_for(params, poly.p_idx(params))
    p_coeff = ntt_ops.ntt_inv(p_part, plan, stage)
    q_part = jnp.stack([acc0[:nq], acc1[:nq]])
    out = fused_ops.mod_down_digits(p_coeff, q_part, params, level, backend="kernel")
    return out[0], out[1]


def key_switch(d_eval, params: CkksParams, level: int, ksk: SwitchingKey, backend: str = "auto"):
    """d (eval, basis q_0..q_ℓ) ⊗ s' → (ks0, ks1) eval over q_0..q_ℓ under s."""
    pipeline, stage = resolve_pipeline(backend)
    n = params.n
    beta = params.beta(level)
    ext = poly.ext_idx(params, level)
    ext_primes = np.array(poly.primes_for(params, ext), np.uint64)
    m = len(ext)

    trace.record("LOAD_KSK", n, beta * 2 * m)
    d_coeff = poly.to_coeff(d_eval, params, poly.q_idx(params, level), stage)
    ksk_sel = _select_ksk(ksk, params, level, beta)

    if pipeline == "fused":
        # stages 2–4 for all β digits and both key components: ONE launch
        _record_fused_digits(params, level)
        acc0, acc1 = fused_ops.key_switch_digits(
            d_coeff, ksk_sel, params, level, backend="kernel"
        )
        return mod_down_pair(acc0, acc1, params, level, backend)

    acc0 = jnp.zeros((m, n), jnp.uint32)
    acc1 = jnp.zeros((m, n), jnp.uint32)
    for j in range(beta):
        digit_idx, bhat_inv, w, dst = _digit_tables(params, level, j)
        k = len(digit_idx)
        src_np = np.array(poly.primes_for(params, digit_idx), np.uint64)
        dj = d_coeff[digit_idx[0] : digit_idx[-1] + 1]
        xhat = _scale_limbs(dj, bhat_inv, src_np, stage)
        _boundary(n, k)
        trace.record("BCONV", n, k, dst=m)
        dj_ext = bconv_ops.bconv(xhat, w, dst, backend=stage)
        _boundary(n, m)
        dj_eval = poly.to_eval(dj_ext, params, ext, stage)
        _boundary(n, m)
        trace.record("PMULT", n, 2 * m, mac=True)
        t0 = mo.pointwise_mulmod(dj_eval, ksk_sel[j, 0], ext_primes, backend=stage)
        t1 = mo.pointwise_mulmod(dj_eval, ksk_sel[j, 1], ext_primes, backend=stage)
        _boundary(n, 2 * m)
        trace.record("PADD", n, 2 * m, mac=True)
        acc0 = mo.pointwise_addmod(acc0, t0, ext_primes, backend=stage)
        acc1 = mo.pointwise_addmod(acc1, t1, ext_primes, backend=stage)

    ks0 = mod_down(acc0, params, level, backend)
    ks1 = mod_down(acc1, params, level, backend)
    return ks0, ks1
