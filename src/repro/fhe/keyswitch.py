"""Hybrid key switching — the iNTT→BConv→NTT pipeline the paper accelerates.

`key_switch(d, level, ...)` homomorphically maps a polynomial d (eval domain,
basis q_0..q_ℓ) multiplied by s' into a pair under s:

    1. INTT d over the active basis                       (iNTT stage)
    2. per digit j < β(ℓ): prescale by [B̂_i^{-1}]_{b_i},
       BConv digit → {q_0..q_ℓ} ∪ {p_0..p_α-1}            (BConv stage)
    3. NTT each converted digit over the extended basis   (NTT stage)
    4. accumulate  Σ_j  d̂_j ∘ ksk_j                       (MAC stage)
    5. ModDown by P: INTT(P limbs) → BConv P→Q → NTT → subtract, ×[P^{-1}]_q

Two pipeline shapes execute the same math:

  * **fused** — stages 2–4 run as ONE `pallas_call` per key-switch (and one
    more for the ModDown tails of both accumulators) via
    ``repro.kernels.fusedks``; intermediates stay in VMEM, and the trace
    carries the fused per-stage records with no working-set boundaries.
    This is FLASH-FHE's fused key-switch datapath.
  * **staged** — one kernel launch per stage per digit (the F1+-style
    software pipeline); every stage boundary emits STORE_WS/LOAD_WS trace
    records because the intermediate polynomial round-trips through
    HBM-equivalent buffers between launches.

``backend`` selects both the pipeline and the stage numerics:
  "fused"/"kernel" → fused Pallas pipeline (interpret off-TPU);
  "staged"         → staged pipeline, per-stage auto backends;
  "ref"            → staged pipeline, u64 oracle stages (jit-traceable);
  "auto"           → fused on TPU, staged-ref elsewhere (CPU tests stay fast).

Every stage records trace instructions; this function *is* the workload the
bootstrappable clusters are shaped around.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bconv import ops as bconv_ops
from repro.kernels.fusedks import ops as fused_ops
from repro.kernels.hoistrot import ops as hoist_ops
from repro.kernels.modops import ops as mo
from repro.kernels.ntt import ops as ntt_ops

from . import poly, rns, trace
from .keys import KeySet, SwitchingKey
from .params import CkksParams


def resolve_pipeline(backend: str) -> tuple[str, str]:
    """Map a backend choice to (pipeline, stage_backend)."""
    if backend == "fused":
        return "fused", "auto"
    if backend == "kernel":
        return "fused", "kernel"
    if backend == "staged":
        return "staged", "auto"
    if backend == "ref":
        return "staged", "ref"
    if backend == "auto":
        if jax.default_backend() == "tpu":
            return "fused", "auto"
        return "staged", "ref"
    raise ValueError(f"unknown key-switch backend {backend!r}")


def _boundary(n: int, limbs: int) -> None:
    """A staged-dispatch boundary: the intermediate round-trips through memory."""
    trace.record("STORE_WS", n, limbs)
    trace.record("LOAD_WS", n, limbs)


@functools.lru_cache(maxsize=2048)
def _digit_tables(params: CkksParams, level: int, j: int):
    """(src_idx, bhat_inv, w, dst_primes) for digit j at ``level``."""
    digit_idx = tuple(i for i in params.digit(j) if i <= level)
    src = poly.primes_for(params, digit_idx)
    dst_idx = poly.ext_idx(params, level)
    dst = poly.primes_for(params, dst_idx)
    bhat_inv, w = rns.bconv_tables(src, dst)
    return digit_idx, jnp.asarray(bhat_inv), jnp.asarray(w), np.array(dst, np.uint64)


@functools.lru_cache(maxsize=512)
def _moddown_tables(params: CkksParams, level: int):
    p_primes = poly.primes_for(params, poly.p_idx(params))
    q_primes = poly.primes_for(params, poly.q_idx(params, level))
    bhat_inv, w = rns.bconv_tables(p_primes, q_primes)
    P = rns.product(p_primes)
    pinv = np.array([pow(P % int(q), -1, int(q)) for q in q_primes], np.uint64)
    return jnp.asarray(bhat_inv), jnp.asarray(w), np.array(q_primes, np.uint64), jnp.asarray(
        pinv[:, None].astype(np.uint32)
    )


def _scale_limbs(x, consts, qs, backend):
    """x ∘ diag(consts) per limb — consts: (k,) broadcast over N."""
    trace.record("PMULT", x.shape[-1], x.shape[-2])
    c = jnp.broadcast_to(jnp.asarray(consts, jnp.uint32)[:, None], x.shape)
    return mo.pointwise_mulmod(x, c, qs, backend=backend)


def _select_ksk(ksk: SwitchingKey, params: CkksParams, level: int, beta: int):
    """(β, 2, |ext|, N): key limbs restricted to active + special moduli."""
    return jnp.concatenate(
        [ksk.k[:, :, : level + 1], ksk.k[:, :, params.L + 1 :]], axis=2
    )[:beta]


def _record_fused_digits(params: CkksParams, level: int) -> None:
    """Trace the fused per-digit pipeline (planner `key_switch(fused=True)`)."""
    n = params.n
    m = len(poly.ext_idx(params, level))
    for j in range(params.beta(level)):
        k = len(tuple(i for i in params.digit(j) if i <= level))
        trace.record("PMULT", n, k, fused=True)
        trace.record("BCONV", n, k, dst=m, fused=True)
        trace.record("NTT", n, m, fused=True)
        trace.record("PMULT", n, 2 * m, mac=True, fused=True)
        trace.record("PADD", n, 2 * m, mac=True, fused=True)


def _record_fused_moddown(params: CkksParams, level: int) -> None:
    n, nq, a = params.n, level + 1, params.alpha
    trace.record("INTT", n, a)
    trace.record("PMULT", n, a, fused=True)
    trace.record("BCONV", n, a, dst=nq, fused=True)
    trace.record("NTT", n, nq, fused=True)
    trace.record("PSUB", n, nq, mac=True, fused=True)
    trace.record("PMULT", n, nq, mac=True, fused=True)


def mod_down(acc_ext, params: CkksParams, level: int, backend: str = "auto"):
    """Extended-basis eval-domain poly → q-basis, divided (rounded) by P.

    Staged pipeline for one accumulator; the fused path batches both
    accumulators through ``mod_down_pair`` instead.
    """
    _, stage = resolve_pipeline(backend)
    n = params.n
    nq = level + 1
    alpha = params.alpha
    q_part, p_part = acc_ext[:nq], acc_ext[nq:]
    bhat_inv, w, q_np, pinv = _moddown_tables(params, level)
    p_np = np.array(poly.primes_for(params, poly.p_idx(params)), np.uint64)

    p_coeff = poly.to_coeff(p_part, params, poly.p_idx(params), stage)
    xhat = _scale_limbs(p_coeff, bhat_inv, p_np, stage)
    _boundary(n, alpha)
    trace.record("BCONV", n, alpha, dst=nq)
    conv = bconv_ops.bconv(xhat, w, q_np, backend=stage)
    _boundary(n, nq)
    conv_eval = poly.to_eval(conv, params, poly.q_idx(params, level), stage)
    _boundary(n, nq)
    trace.record("PSUB", n, nq, mac=True)
    diff = mo.pointwise_submod(q_part, conv_eval, q_np, backend=stage)
    _boundary(n, nq)
    trace.record("PMULT", n, nq, mac=True)
    pinv_b = jnp.broadcast_to(pinv, diff.shape)
    return mo.pointwise_mulmod(diff, pinv_b, q_np, backend=stage)


def mod_down_pair(acc0, acc1, params: CkksParams, level: int, backend: str = "auto"):
    """ModDown both MAC accumulators; fused path shares one kernel launch."""
    pipeline, stage = resolve_pipeline(backend)
    if pipeline != "fused":
        return (
            mod_down(acc0, params, level, backend),
            mod_down(acc1, params, level, backend),
        )
    nq = level + 1
    _record_fused_moddown(params, level)
    _record_fused_moddown(params, level)
    p_part = jnp.stack([acc0[nq:], acc1[nq:]])
    plan = poly.plan_for(params, poly.p_idx(params))
    p_coeff = ntt_ops.ntt_inv(p_part, plan, stage)
    q_part = jnp.stack([acc0[:nq], acc1[:nq]])
    out = fused_ops.mod_down_digits(p_coeff, q_part, params, level, backend="kernel")
    return out[0], out[1]


def key_switch(d_eval, params: CkksParams, level: int, ksk: SwitchingKey, backend: str = "auto"):
    """d (eval, basis q_0..q_ℓ) ⊗ s' → (ks0, ks1) eval over q_0..q_ℓ under s."""
    ksk_sel = _select_ksk(ksk, params, level, params.beta(level))
    return key_switch_selected(d_eval, params, level, ksk_sel, backend)


def key_switch_selected(d_eval, params: CkksParams, level: int, ksk_sel, backend: str = "auto"):
    """``key_switch`` over pre-selected key limbs ksk_sel: (β, 2, m, N).

    The rotation path hands in σ_t^{-1}-pre-permuted Galois keys here (see
    ``hoisted_ksk``) so the standard and hoisted pipelines run the *same*
    per-digit math and stay bit-exact against each other."""
    acc0, acc1 = key_switch_accumulate(d_eval, params, level, ksk_sel, backend)
    return mod_down_pair(acc0, acc1, params, level, backend)


def key_switch_accumulate(d_eval, params: CkksParams, level: int, ksk_sel,
                          backend: str = "auto"):
    """Stages 1–4 of a key switch: decompose d into digits and MAC against the
    key, returning both raw accumulators (eval domain, extended basis Q∪P)
    *before* ModDown.

    This seam exists so BGV relinearisation (``repro.fhe.bgv``) can wrap the
    shared ModDown in its t-scaling sandwich; the CKKS path goes straight to
    ``mod_down_pair``.
    """
    pipeline, stage = resolve_pipeline(backend)
    n = params.n
    beta = params.beta(level)
    ext = poly.ext_idx(params, level)
    ext_primes = np.array(poly.primes_for(params, ext), np.uint64)
    m = len(ext)

    trace.record("LOAD_KSK", n, beta * 2 * m)
    d_coeff = poly.to_coeff(d_eval, params, poly.q_idx(params, level), stage)

    if pipeline == "fused":
        # stages 2–4 for all β digits and both key components: ONE launch
        _record_fused_digits(params, level)
        return fused_ops.key_switch_digits(d_coeff, ksk_sel, params, level, backend="kernel")

    acc0 = jnp.zeros((m, n), jnp.uint32)
    acc1 = jnp.zeros((m, n), jnp.uint32)
    for j in range(beta):
        digit_idx, bhat_inv, w, dst = _digit_tables(params, level, j)
        k = len(digit_idx)
        src_np = np.array(poly.primes_for(params, digit_idx), np.uint64)
        dj = d_coeff[digit_idx[0] : digit_idx[-1] + 1]
        xhat = _scale_limbs(dj, bhat_inv, src_np, stage)
        _boundary(n, k)
        trace.record("BCONV", n, k, dst=m)
        dj_ext = bconv_ops.bconv(xhat, w, dst, backend=stage)
        _boundary(n, m)
        dj_eval = poly.to_eval(dj_ext, params, ext, stage)
        _boundary(n, m)
        trace.record("PMULT", n, 2 * m, mac=True)
        t0 = mo.pointwise_mulmod(dj_eval, ksk_sel[j, 0], ext_primes, backend=stage)
        t1 = mo.pointwise_mulmod(dj_eval, ksk_sel[j, 1], ext_primes, backend=stage)
        _boundary(n, 2 * m)
        trace.record("PADD", n, 2 * m, mac=True)
        acc0 = mo.pointwise_addmod(acc0, t0, ext_primes, backend=stage)
        acc1 = mo.pointwise_addmod(acc1, t1, ext_primes, backend=stage)
    return acc0, acc1


# ---------------------------------------------------------------------------
# hoisted (Halevi–Shoup) rotation key-switching
# ---------------------------------------------------------------------------
#
# The ModUp half of a key-switch (iNTT → digit decompose → prescale → BConv →
# NTT into the extended basis) depends only on the input polynomial — never on
# the Galois element — so k rotations of the same ciphertext can share ONE
# ModUp and pay only KSK-MAC + ModDown each: O(β + k) forward NTTs through the
# extended basis instead of O(k·β).
#
# The automorphism is folded instead of applied per digit: with keys
# pre-permuted by σ_t^{-1} (cached per KeySet in ``hoisted_ksk``),
#
#   KS(σ_t(d)) = σ_t( ModDown( Σ_j D_j(d) ∘ σ_t^{-1}(ksk_j) ) )
#
# because σ_t commutes exactly (bit-exactly, per-residue) with every stage:
# it is a pure slot permutation in the eval domain, a signed coefficient
# permutation in the coefficient domain, and every ModUp/ModDown stage is a
# per-coefficient-index linear map over the limbs.  So the whole MAC + ModDown
# runs in the σ_t^{-1} frame and ONE permutation per output component lands
# the result — that single AUTO also absorbs the σ_t(c0) term: the final
# ciphertext is (σ_t(c0 + ks0'), σ_t(ks1')).


@dataclasses.dataclass
class HoistedDigits:
    """Reusable ModUp decomposition of one eval-domain polynomial.

    ``digits`` is (β, m, N) uint32 over the extended basis (eval domain) —
    the rotation-independent half of a key-switch, shared by every rotation
    of a hoisted group.
    """

    digits: jnp.ndarray
    level: int

    @property
    def beta(self) -> int:
        return int(self.digits.shape[0])


def _record_modup_digits(params: CkksParams, level: int) -> None:
    """Trace the fused ModUp pipeline (planner ``mod_up(fused=True)``)."""
    n = params.n
    m = len(poly.ext_idx(params, level))
    for j in range(params.beta(level)):
        k = len(tuple(i for i in params.digit(j) if i <= level))
        trace.record("PMULT", n, k, fused=True)
        trace.record("BCONV", n, k, dst=m, fused=True)
        trace.record("NTT", n, m, fused=True)


def hoisted_mod_up(d_eval, params: CkksParams, level: int, backend: str = "auto") -> HoistedDigits:
    """ModUp once: d (eval, q_0..q_ℓ) → reusable extended-basis digits.

    The returned digits are materialised (they round-trip to the later MAC
    launches — the trace carries one STORE_WS/LOAD_WS pair of β·m limbs),
    amortising the β forward NTTs across every rotation that reuses them.
    """
    pipeline, stage = resolve_pipeline(backend)
    n = params.n
    beta = params.beta(level)
    ext = poly.ext_idx(params, level)
    m = len(ext)
    d_coeff = poly.to_coeff(d_eval, params, poly.q_idx(params, level), stage)

    if pipeline == "fused":
        _record_modup_digits(params, level)
        digits = hoist_ops.mod_up_digits(d_coeff, params, level, backend="kernel")
    else:
        rows = []
        for j in range(beta):
            digit_idx, bhat_inv, w, dst = _digit_tables(params, level, j)
            k = len(digit_idx)
            src_np = np.array(poly.primes_for(params, digit_idx), np.uint64)
            dj = d_coeff[digit_idx[0] : digit_idx[-1] + 1]
            xhat = _scale_limbs(dj, bhat_inv, src_np, stage)
            _boundary(n, k)
            trace.record("BCONV", n, k, dst=m)
            dj_ext = bconv_ops.bconv(xhat, w, dst, backend=stage)
            _boundary(n, m)
            rows.append(poly.to_eval(dj_ext, params, ext, stage))
        digits = jnp.stack(rows)
    _boundary(n, beta * m)  # hoisted digits round-trip to the MAC launches
    return HoistedDigits(digits=digits, level=level)


# Each cached entry is a full (β, 2, m, N) key copy — comparable to the
# level-restricted key itself — so the per-KeySet cache is LRU-bounded BY
# BYTES (an entry count would still admit ~β·m·N-sized blowups at production
# parameters: one N=2^16 deep entry is >100 MB).  An entry larger than the
# whole budget is simply not cached.
HOIST_KSK_CACHE_BYTES = 256 * 2**20


def hoisted_ksk(params: CkksParams, keys: KeySet, t: int, level: int):
    """σ_t^{-1}-pre-permuted Galois key, restricted to the active basis.

    (β, 2, m, N) uint32 — LRU-cached per KeySet/(t, level): the permutation
    is a keygen-time precompute, not per-rotation work (no trace records).
    """
    cache = keys.hoist_cache
    hit = cache.get((t, level))
    if hit is not None:
        cache[(t, level)] = cache.pop((t, level))  # move to MRU position
        return hit
    sel = _select_ksk(keys.galois(t), params, level, params.beta(level))
    tinv = pow(t, -1, 2 * params.n)
    pre = jnp.take(sel, poly._eval_perm(params.n, tinv), axis=-1)
    if int(pre.nbytes) <= HOIST_KSK_CACHE_BYTES:
        while cache and sum(int(v.nbytes) for v in cache.values()) + int(pre.nbytes) > (
            HOIST_KSK_CACHE_BYTES
        ):
            cache.pop(next(iter(cache)))  # evict LRU (dicts preserve insertion order)
        cache[(t, level)] = pre
    return pre


def hoisted_galois_ks(hd: HoistedDigits, ksk_stack, params: CkksParams, level: int,
                      backend: str = "auto"):
    """KSK inner products for a whole rotation group, σ_t^{-1} frame.

    ksk_stack: (R, β, 2, m, N) pre-permuted key limbs (``hoisted_ksk``).
    Returns (R, 2, m, N) accumulator pairs; the fused pipeline issues ONE
    batched MAC launch with the hoisted digits VMEM-resident.
    """
    pipeline, stage = resolve_pipeline(backend)
    n = params.n
    beta = params.beta(level)
    m = int(hd.digits.shape[1])
    fused = pipeline == "fused"
    for _ in range(ksk_stack.shape[0]):
        trace.record("LOAD_KSK", n, beta * 2 * m)
        for _j in range(beta):
            trace.record("PMULT", n, 2 * m, mac=True, fused=fused)
            if not fused:
                _boundary(n, 2 * m)
            trace.record("PADD", n, 2 * m, mac=True, fused=fused)
    # non-fused: per-op MAC at the resolved stage backend, mirroring
    # key_switch_selected's staged pipeline (stage="auto" uses per-op kernels
    # on TPU, the u64 oracle elsewhere)
    return hoist_ops.galois_mac(
        hd.digits, ksk_stack, params, level,
        backend="kernel" if fused else stage, staged=not fused,
    )


def mod_down_group(accs, params: CkksParams, level: int, backend: str = "auto"):
    """ModDown every accumulator pair of a hoisted group.

    accs: (R, 2, m, N) → (R, 2, level+1, N).  The fused pipeline batches all
    2·R tails through ONE P-block iNTT + ONE ModDown launch.
    """
    pipeline, _stage = resolve_pipeline(backend)
    nrot = accs.shape[0]
    if pipeline != "fused":
        return jnp.stack([
            jnp.stack([mod_down(accs[i, c], params, level, backend) for c in range(2)])
            for i in range(nrot)
        ])
    nq = level + 1
    for _ in range(2 * nrot):
        _record_fused_moddown(params, level)
    p_part = accs[:, :, nq:].reshape(2 * nrot, params.alpha, params.n)
    plan = poly.plan_for(params, poly.p_idx(params))
    p_coeff = ntt_ops.ntt_inv(p_part, plan, _stage)
    q_part = accs[:, :, :nq].reshape(2 * nrot, nq, params.n)
    out = fused_ops.mod_down_digits(p_coeff, q_part, params, level, backend="kernel")
    return out.reshape(nrot, 2, nq, params.n)


def permute_last(c0_eval, ks0, ks1, t: int, params: CkksParams, level: int,
                 backend: str = "auto"):
    """The shared rotation epilogue: c0 + ks0, then ONE σ_t per component.

    ``ks0``/``ks1`` come from a key-switch against the σ_t^{-1}-pre-permuted
    key (``hoisted_ksk``), so the single automorphism here lands the rotated
    ciphertext — it also absorbs the σ_t(c0) term.  Every rotation path
    (standard, single-hoisted, group-hoisted) MUST end through this helper:
    the trace shape ([PADD, AUTO, AUTO], matching the planner) and the
    bit-exactness of hoisted vs standard both hang on the three paths doing
    literally the same thing.
    """
    _pipeline, stage = resolve_pipeline(backend)
    n = params.n
    qs = np.array(params.q_primes[: level + 1], np.uint64)
    trace.record("PADD", n, level + 1)
    s0 = mo.pointwise_addmod(jnp.asarray(c0_eval, jnp.uint32), ks0, qs, backend=stage)
    return poly.automorphism_eval(s0, n, t), poly.automorphism_eval(ks1, n, t)


def rotate_hoisted(c0_eval, hd: HoistedDigits, t: int, keys: KeySet, params: CkksParams,
                   level: int, backend: str = "auto"):
    """One key-switched automorphism σ_t over a hoisted decomposition.

    Runs only KSK-MAC + ModDown (+ the folded automorphism) — the expensive
    ModUp was paid once when ``hd`` was built.  Returns the rotated
    ciphertext's (c0, c1) eval-domain polynomials; bit-exact against the
    un-hoisted ``ctx.rotate`` path.
    """
    ksk_stack = hoisted_ksk(params, keys, t, level)[None]
    accs = hoisted_galois_ks(hd, ksk_stack, params, level, backend)
    ks = mod_down_group(accs, params, level, backend)
    return permute_last(c0_eval, ks[0, 0], ks[0, 1], t, params, level, backend)
