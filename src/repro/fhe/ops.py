"""CKKS homomorphic operations over eval-domain RNS ciphertexts.

Ciphertexts are pairs of (level+1, N) uint32 eval-domain polynomials with a
tracked floating-point scale (Lattigo-style scale management).  All heavy ops
dispatch through the kernel wrappers (Pallas on TPU, u64 oracle elsewhere) and
record trace instructions for the core scheduler/simulator.

Execution choices (kernel backend, rotation-hoisting mode, numerics mode) are
owned by ``repro.fhe.context.FheContext`` — every op here is implemented ONCE
as a context-consuming ``_impl`` function, and the context's methods
(``ctx.add``, ``ctx.rotate``, ...) are the primary API.  The module-level free
functions that took a loose ``backend=`` kwarg are **retired** (retirement
plan step 3, docs/context_api.md): the old names resolve to a module
``__getattr__`` stub that raises with the migration hint.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.kernels.modops import ops as mo

from . import encoder, keyswitch, poly, trace
from .keys import KeySet, PublicKey, SecretKey, SwitchingKey
from .params import CkksParams

HOISTING_MODES = ("never", "auto", "always")


@dataclasses.dataclass
class Ciphertext:
    c0: jnp.ndarray  # (level+1, N) uint32, eval domain
    c1: jnp.ndarray
    level: int
    scale: float

    @property
    def nbytes(self) -> int:
        return int(self.c0.nbytes + self.c1.nbytes)


@dataclasses.dataclass
class Plaintext:
    data: jnp.ndarray  # (level+1, N) uint32, eval domain
    level: int
    scale: float


def _qs(params: CkksParams, level: int) -> np.ndarray:
    return np.array(params.q_primes[: level + 1], np.uint64)


# ---------------------------------------------------------------------------
# encode / encrypt / decrypt — context implementations
# ---------------------------------------------------------------------------


def _encode(ctx, z, level: int | None = None, scale: float | None = None) -> Plaintext:
    params = ctx.params
    level = params.L if level is None else level
    scale = params.scale if scale is None else scale
    primes = params.q_primes[: level + 1]
    coeffs = encoder.encode(np.asarray(z), params.n, scale, primes)
    data = poly.to_eval(coeffs, params, poly.q_idx(params, level), ctx.stage)
    return Plaintext(data=data, level=level, scale=scale)


def _encode_const(ctx, c, level: int, scale: float) -> Plaintext:
    params = ctx.params
    primes = params.q_primes[: level + 1]
    coeffs = encoder.encode_const(c, params.n, scale, primes)
    data = poly.to_eval(coeffs, params, poly.q_idx(params, level), ctx.stage)
    return Plaintext(data=data, level=level, scale=scale)


def _decode(ctx, pt: Plaintext) -> np.ndarray:
    params = ctx.params
    coeffs = poly.to_coeff(pt.data, params, poly.q_idx(params, pt.level), ctx.stage)
    limbs = min(pt.level + 1, 4)
    return encoder.decode(np.asarray(coeffs), params.q_primes[: pt.level + 1], pt.scale, max_limbs=limbs)


def _encrypt(ctx, pk: PublicKey, pt: Plaintext, seed: int = 17) -> Ciphertext:
    params = ctx.params
    rng = np.random.default_rng(seed)
    level = pt.level
    idx = poly.q_idx(params, level)
    qs = _qs(params, level)
    bk = ctx.stage
    v = poly.to_eval(
        poly.to_rns_signed(poly.sample_ternary(rng, params.n, params.n // 2), params.q_primes[: level + 1]),
        params, idx, bk,
    )
    e0 = poly.to_eval(
        poly.to_rns_signed(poly.sample_gaussian(rng, params.n), params.q_primes[: level + 1]), params, idx, bk
    )
    e1 = poly.to_eval(
        poly.to_rns_signed(poly.sample_gaussian(rng, params.n), params.q_primes[: level + 1]), params, idx, bk
    )
    trace.record("PMULT", params.n, 2 * (level + 1))
    c0 = mo.pointwise_addmod(
        mo.pointwise_addmod(mo.pointwise_mulmod(v, pk.b[: level + 1], qs, backend=bk), e0, qs, backend=bk),
        pt.data, qs, backend=bk,
    )
    c1 = mo.pointwise_addmod(mo.pointwise_mulmod(v, pk.a[: level + 1], qs, backend=bk), e1, qs, backend=bk)
    return Ciphertext(c0=c0, c1=c1, level=level, scale=pt.scale)


def _decrypt(ctx, sk: SecretKey, ct: Ciphertext) -> Plaintext:
    params = ctx.params
    qs = _qs(params, ct.level)
    bk = ctx.stage
    trace.record("PMULT", params.n, ct.level + 1)
    m = mo.pointwise_addmod(
        ct.c0, mo.pointwise_mulmod(ct.c1, sk.s_eval[: ct.level + 1], qs, backend=bk), qs, backend=bk
    )
    return Plaintext(data=m, level=ct.level, scale=ct.scale)


# ---------------------------------------------------------------------------
# additive ops — context implementations
# ---------------------------------------------------------------------------


def _align(params: CkksParams, a: Ciphertext, b: Ciphertext) -> tuple[Ciphertext, Ciphertext]:
    """Drop the deeper ciphertext to the shallower level. Scales must match closely."""
    lv = min(a.level, b.level)
    a = level_drop(a, lv)
    b = level_drop(b, lv)
    assert abs(a.scale / b.scale - 1.0) < 1e-9, f"scale mismatch {a.scale} vs {b.scale}"
    return a, b


def level_drop(ct: Ciphertext, level: int) -> Ciphertext:
    if level == ct.level:
        return ct
    assert level < ct.level
    return Ciphertext(c0=ct.c0[: level + 1], c1=ct.c1[: level + 1], level=level, scale=ct.scale)


def _add(ctx, a: Ciphertext, b: Ciphertext) -> Ciphertext:
    params = ctx.params
    a, b = _align(params, a, b)
    qs = _qs(params, a.level)
    bk = ctx.stage
    trace.record("PADD", params.n, 2 * (a.level + 1))
    return Ciphertext(
        c0=mo.pointwise_addmod(a.c0, b.c0, qs, backend=bk),
        c1=mo.pointwise_addmod(a.c1, b.c1, qs, backend=bk),
        level=a.level, scale=a.scale,
    )


def _sub(ctx, a: Ciphertext, b: Ciphertext) -> Ciphertext:
    params = ctx.params
    a, b = _align(params, a, b)
    qs = _qs(params, a.level)
    bk = ctx.stage
    trace.record("PSUB", params.n, 2 * (a.level + 1))
    return Ciphertext(
        c0=mo.pointwise_submod(a.c0, b.c0, qs, backend=bk),
        c1=mo.pointwise_submod(a.c1, b.c1, qs, backend=bk),
        level=a.level, scale=a.scale,
    )


def _negate(ctx, a: Ciphertext) -> Ciphertext:
    params = ctx.params
    qs = _qs(params, a.level)
    bk = ctx.stage
    z = jnp.zeros_like(a.c0)
    trace.record("PSUB", params.n, 2 * (a.level + 1))
    return Ciphertext(
        c0=mo.pointwise_submod(z, a.c0, qs, backend=bk),
        c1=mo.pointwise_submod(z, a.c1, qs, backend=bk),
        level=a.level, scale=a.scale,
    )


def _add_plain(ctx, a: Ciphertext, pt: Plaintext) -> Ciphertext:
    params = ctx.params
    assert pt.level >= a.level
    qs = _qs(params, a.level)
    trace.record("PADD", params.n, a.level + 1)
    return Ciphertext(
        c0=mo.pointwise_addmod(a.c0, pt.data[: a.level + 1], qs, backend=ctx.stage),
        c1=a.c1, level=a.level, scale=a.scale,
    )


def _add_const(ctx, a: Ciphertext, c) -> Ciphertext:
    pt = _encode_const(ctx, c, a.level, a.scale)
    return _add_plain(ctx, a, pt)


# ---------------------------------------------------------------------------
# multiplicative ops — context implementations
# ---------------------------------------------------------------------------


def _mul_plain(ctx, a: Ciphertext, pt: Plaintext, rescale_after: bool = True) -> Ciphertext:
    params = ctx.params
    assert pt.level >= a.level
    qs = _qs(params, a.level)
    bk = ctx.stage
    trace.record("PMULT", params.n, 2 * (a.level + 1))
    d = pt.data[: a.level + 1]
    out = Ciphertext(
        c0=mo.pointwise_mulmod(a.c0, d, qs, backend=bk),
        c1=mo.pointwise_mulmod(a.c1, d, qs, backend=bk),
        level=a.level, scale=a.scale * pt.scale,
    )
    return _rescale(ctx, out) if rescale_after else out


def _mul_const(ctx, a: Ciphertext, c, rescale_after: bool = True) -> Ciphertext:
    pt = _encode_const(ctx, c, a.level, ctx.params.scale)
    return _mul_plain(ctx, a, pt, rescale_after)


def _mul_const_exact(ctx, a: Ciphertext, c, target_scale: float) -> Ciphertext:
    """a·c with the constant's encoding scale chosen so the rescaled result has
    exactly ``target_scale`` — the anchor that keeps scale bookkeeping from
    drifting through multiplicative trees (see polyeval)."""
    params = ctx.params
    q = float(params.q_primes[a.level])
    enc_scale = target_scale * q / a.scale
    assert enc_scale > 256.0, f"enc_scale underflow ({enc_scale}); scale drift upstream"
    pt = _encode_const(ctx, c, a.level, enc_scale)
    out = _mul_plain(ctx, a, pt, rescale_after=True)
    return Ciphertext(out.c0, out.c1, out.level, target_scale)


def _mul(ctx, a: Ciphertext, b: Ciphertext, rlk: SwitchingKey,
         rescale_after: bool = True) -> Ciphertext:
    """Full homomorphic multiplication with relinearisation (key-switch of d2)."""
    params = ctx.params
    a, b = _align_mul(params, a, b)
    qs = _qs(params, a.level)
    bk = ctx.stage
    trace.record("PMULT", params.n, 4 * (a.level + 1))
    d0 = mo.pointwise_mulmod(a.c0, b.c0, qs, backend=bk)
    d2 = mo.pointwise_mulmod(a.c1, b.c1, qs, backend=bk)
    cross1 = mo.pointwise_mulmod(a.c0, b.c1, qs, backend=bk)
    cross2 = mo.pointwise_mulmod(a.c1, b.c0, qs, backend=bk)
    trace.record("PADD", params.n, a.level + 1)
    d1 = mo.pointwise_addmod(cross1, cross2, qs, backend=bk)
    ks0, ks1 = keyswitch.key_switch(d2, params, a.level, rlk, ctx.backend)
    trace.record("PADD", params.n, 2 * (a.level + 1))
    out = Ciphertext(
        c0=mo.pointwise_addmod(d0, ks0, qs, backend=bk),
        c1=mo.pointwise_addmod(d1, ks1, qs, backend=bk),
        level=a.level, scale=a.scale * b.scale,
    )
    return _rescale(ctx, out) if rescale_after else out


def _align_mul(params: CkksParams, a: Ciphertext, b: Ciphertext):
    lv = min(a.level, b.level)
    return level_drop(a, lv), level_drop(b, lv)


def _rescale(ctx, ct: Ciphertext) -> Ciphertext:
    """Divide by q_ℓ and drop a level (eval-domain RNS rescale)."""
    params = ctx.params
    lv = ct.level
    assert lv >= 1, "cannot rescale at level 0"
    q_last = int(params.q_primes[lv])
    qs_rem = _qs(params, lv - 1)
    rem_primes = params.q_primes[:lv]
    bk = ctx.stage
    qinv = np.array([pow(q_last % int(q), -1, int(q)) for q in rem_primes], np.uint64)
    qinv_b = jnp.asarray(qinv[:, None].astype(np.uint32))

    def _one(c):
        # iNTT the dropped limb, re-embed its (centred) coefficients in every
        # remaining basis, NTT back, subtract, multiply by q_ℓ^{-1}.
        last_coeff = poly.to_coeff(c[lv : lv + 1], params, (lv,), bk)
        v = last_coeff[0].astype(jnp.uint64)
        centered = jnp.where(v > q_last // 2, v + jnp.asarray(qs_rem[:, None]) - q_last, v)
        rem = (centered % jnp.asarray(qs_rem[:, None])).astype(jnp.uint32)
        rem_eval = poly.to_eval(rem, params, poly.q_idx(params, lv - 1), bk)
        trace.record("PSUB", params.n, lv)
        diff = mo.pointwise_submod(c[:lv], rem_eval, qs_rem, backend=bk)
        trace.record("PMULT", params.n, lv)
        return mo.pointwise_mulmod(diff, jnp.broadcast_to(qinv_b, diff.shape), qs_rem, backend=bk)

    return Ciphertext(c0=_one(ct.c0), c1=_one(ct.c1), level=lv - 1, scale=ct.scale / q_last)


# ---------------------------------------------------------------------------
# rotations / conjugation — context implementations
# ---------------------------------------------------------------------------


def _rotate(ctx, ct: Ciphertext, r: int, keys: KeySet) -> Ciphertext:
    """Cyclic left-rotation of the slot vector by r (σ_{5^r} + key switch).

    The policy's hoisting mode selects the key-switch shape: "never"/"auto"
    run the standard per-rotation ModUp (a single rotation has nothing to
    amortise); "always" routes through the hoisted path — bit-exact either
    way.  Groups of rotations of the same ciphertext should use
    ``rotate_hoisted_group`` to actually share the ModUp.
    """
    params = ctx.params
    if r % params.slots == 0:
        return ct
    if ctx.policy.hoisting == "always":
        return _rotate_hoisted(ctx, ct, r, keys)
    return _rotate_standard(ctx, ct, r, keys)


def _rotate_standard(ctx, ct: Ciphertext, r: int, keys: KeySet) -> Ciphertext:
    """Per-rotation key switch regardless of the policy's hoisting mode —
    the path for rotations of *distinct* ciphertexts (e.g. BSGS giant steps),
    which can never share a ModUp."""
    params = ctx.params
    if r % params.slots == 0:
        return ct
    t = pow(5, r % params.slots, 2 * params.n)
    return _apply_galois(ctx, ct, t, keys)


def _rotate_hoisted(ctx, ct: Ciphertext, r: int, keys: KeySet,
                    hoisted: keyswitch.HoistedDigits | None = None) -> Ciphertext:
    """Hoisted rotation: reuse (or build) the ModUp decomposition of ct.c1.

    Pass ``hoisted=keyswitch.hoisted_mod_up(ct.c1, ...)`` to amortise the
    ModUp across several calls on the same ciphertext; each call then costs
    only KSK-MAC + ModDown + one automorphism.  Bit-exact vs ``rotate``.
    """
    params = ctx.params
    if r % params.slots == 0:
        return ct
    t = pow(5, r % params.slots, 2 * params.n)
    hd = hoisted if hoisted is not None else keyswitch.hoisted_mod_up(
        ct.c1, params, ct.level, ctx.backend
    )
    c0, c1 = keyswitch.rotate_hoisted(ct.c0, hd, t, keys, params, ct.level, ctx.backend)
    return Ciphertext(c0=c0, c1=c1, level=ct.level, scale=ct.scale)


def _rotate_hoisted_group(ctx, ct: Ciphertext, rots, keys: KeySet) -> dict[int, Ciphertext]:
    """Halevi–Shoup hoisting: ONE ModUp shared by every rotation in ``rots``.

    The fused pipeline batches the whole group: one ModUp launch, one Galois
    KSK-MAC launch covering every rotation's key (hoisted digits resident in
    VMEM), and one batched ModDown pair launch — O(β + k) extended-basis NTTs
    for k rotations instead of O(k·β).  Returns {r: rotated ciphertext} keyed
    by the input rotation values; each entry is bit-exact vs ``rotate``.
    """
    params = ctx.params
    backend = ctx.backend
    uniq: dict[int, int] = {}  # r mod slots → galois element
    for r in rots:
        rm = r % params.slots
        if rm and rm not in uniq:
            uniq[rm] = pow(5, rm, 2 * params.n)
    if not uniq:
        return {r: ct for r in rots}
    lv = ct.level
    hd = keyswitch.hoisted_mod_up(ct.c1, params, lv, backend)
    ksk_stack = jnp.stack(
        [keyswitch.hoisted_ksk(params, keys, t, lv) for t in uniq.values()]
    )
    accs = keyswitch.hoisted_galois_ks(hd, ksk_stack, params, lv, backend)
    ks = keyswitch.mod_down_group(accs, params, lv, backend)
    by_rm: dict[int, Ciphertext] = {}
    for i, (rm, t) in enumerate(uniq.items()):
        c0, c1 = keyswitch.permute_last(ct.c0, ks[i, 0], ks[i, 1], t, params, lv, backend)
        by_rm[rm] = Ciphertext(c0=c0, c1=c1, level=lv, scale=ct.scale)
    return {r: (by_rm[r % params.slots] if r % params.slots else ct) for r in rots}


def _conjugate(ctx, ct: Ciphertext, keys: KeySet) -> Ciphertext:
    t = 2 * ctx.params.n - 1
    return _apply_galois(ctx, ct, t, keys)


def _apply_galois(ctx, ct: Ciphertext, t: int, keys: KeySet) -> Ciphertext:
    """Key-switched automorphism σ_t, permute-last formulation.

    The key-switch runs against the σ_t^{-1}-pre-permuted Galois key and the
    shared ``keyswitch.permute_last`` epilogue lands the result.  This is the
    same per-digit math as the hoisted path — ``rotate`` and
    ``rotate_hoisted``/``rotate_hoisted_group`` are bit-exact against each
    other — and the trace shape matches the classic permute-first pipeline
    (2×AUTO + key-switch + PADD).
    """
    params = ctx.params
    lv = ct.level
    ksk_pre = keyswitch.hoisted_ksk(params, keys, t, lv)
    ks0, ks1 = keyswitch.key_switch_selected(ct.c1, params, lv, ksk_pre, ctx.backend)
    c0, c1 = keyswitch.permute_last(ct.c0, ks0, ks1, t, params, lv, ctx.backend)
    return Ciphertext(c0=c0, c1=c1, level=lv, scale=ct.scale)

