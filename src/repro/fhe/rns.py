"""RNS (residue number system) helpers: CRT reconstruction and BConv table builders.

All functions here are host-side Python-int exact computations producing small
numpy tables; the heavy per-coefficient work happens in repro.kernels.bconv.
"""

from __future__ import annotations

import functools

import numpy as np


def product(primes) -> int:
    out = 1
    for p in primes:
        out *= int(p)
    return out


@functools.lru_cache(maxsize=512)
def bconv_tables(src: tuple[int, ...], dst: tuple[int, ...]):
    """Tables for Conv_{src→dst}.

    Returns (bhat_inv, w):
      bhat_inv[i] = [ (B/b_i)^{-1} ]_{b_i}            — (k,) uint32 (pre-scale)
      w[i, j]     = (B/b_i) mod c_j                   — (k, m) uint32
    """
    B = product(src)
    bhat_inv = np.array([pow(B // b, -1, b) for b in src], np.uint32)
    w = np.array([[(B // b) % c for c in dst] for b in src], np.uint32)
    return bhat_inv, w


def crt_reconstruct_centered(residues: np.ndarray, primes, max_limbs: int = 4) -> np.ndarray:
    """Centered CRT over the first ≤ max_limbs primes (object-int array).

    residues: (k, N) uint array.  Valid when the true centered value fits in
    ±Π_{i<k'} q_i / 2 — guaranteed for decode-scale magnitudes.
    """
    k = min(len(primes), max_limbs)
    ps = [int(p) for p in primes[:k]]
    Q = product(ps)
    # m = Σ r_i · Q̂_i · [Q̂_i^{-1}]_{q_i}  mod Q, vectorised with object ints
    acc = np.zeros(residues.shape[1], dtype=object)
    for i, p in enumerate(ps):
        qhat = Q // p
        coef = qhat * pow(qhat, -1, p)
        acc = acc + residues[i].astype(object) * coef
    acc = acc % Q
    return np.where(acc > Q // 2, acc - Q, acc)


def to_rns(values: np.ndarray, primes) -> np.ndarray:
    """Signed integer coefficients (object/int64) → (k, N) uint32 residues."""
    out = np.zeros((len(primes), values.shape[-1]), np.uint32)
    for i, p in enumerate(primes):
        p = int(p)
        r = np.mod(values.astype(object), p)  # python % is non-negative
        out[i] = np.array([int(v) for v in r], np.uint32)
    return out


def to_rns_i64(values: np.ndarray, primes) -> np.ndarray:
    """Fast path for int64-range coefficients."""
    v = values.astype(np.int64)
    out = np.zeros((len(primes), v.shape[-1]), np.uint32)
    for i, p in enumerate(primes):
        out[i] = np.mod(v, np.int64(p)).astype(np.uint32)
    return out
