"""Instruction-trace hooks.

FHE programs are data-oblivious, so the exact instruction stream (NTT/INTT/BCONV/
PMULT/PADD/AUTO/KSK loads...) is known statically.  The FHE ops record into an
ambient trace when one is active; the scheduler (repro.core) replays these traces
through the cycle-level simulator and the cache model — mirroring the paper's
"software driver generates static control instructions" design.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses


@dataclasses.dataclass
class Instr:
    op: str  # NTT | INTT | BCONV | PMULT | PADD | PSUB | AUTO | LOAD_KSK | RESCALE_DIV
    n: int  # ring degree
    limbs: int  # limbs processed
    meta: dict


_TRACE: contextvars.ContextVar[list | None] = contextvars.ContextVar("fhe_trace", default=None)


def record(op: str, n: int, limbs: int, **meta) -> None:
    t = _TRACE.get()
    if t is not None:
        t.append(Instr(op, n, limbs, meta))


@contextlib.contextmanager
def capture_trace():
    token = _TRACE.set([])
    try:
        yield _TRACE.get()
    finally:
        _TRACE.reset(token)


def tracing() -> bool:
    return _TRACE.get() is not None
