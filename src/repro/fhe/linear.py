"""Homomorphic linear transforms via the BSGS diagonal method.

M·v = Σ_g rot_{g·n1}( Σ_b  rot_{-g·n1}(diag_{g·n1+b}(M)) ∘ rot_b(v) )

Baby rotations rot_b(v) are shared across giants, so an n×n dense transform
costs ≈ 2√n key-switched rotations + n plaintext multiplies — the dominant
workload of CoeffToSlot/SlotToCoeff in bootstrapping (paper §3.3: rotation-
heavy deep pipelines).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from . import ops
from .keys import KeySet
from .params import CkksParams


@dataclasses.dataclass
class BsgsPlan:
    n1: int  # baby-step count
    diags: dict[int, np.ndarray]  # d → diag_d(M) (length n complex)

    def rotations(self) -> set[int]:
        """Slot rotations whose Galois keys the transform needs."""
        rots = set()
        for d in self.diags:
            g, b = divmod(d, self.n1)
            if b:
                rots.add(b)
            if g:
                rots.add(g * self.n1)
        return rots


def plan_matrix(m: np.ndarray, n1: int | None = None, tol: float = 0.0) -> BsgsPlan:
    """Extract (optionally sparse) diagonals of an n×n matrix for BSGS."""
    n = m.shape[0]
    assert m.shape == (n, n)
    if n1 is None:
        n1 = max(1, 1 << int(round(math.log2(math.sqrt(n)))))  # ≈ √n, power of two
    idx = np.arange(n)
    diags = {}
    mx = np.abs(m).max() or 1.0
    for d in range(n):
        u = m[idx, (idx + d) % n]
        if tol == 0.0 or np.abs(u).max() > tol * mx:
            diags[int(d)] = u.astype(np.complex128)
    return BsgsPlan(n1=n1, diags=diags)


def apply_bsgs(
    params: CkksParams,
    ct: ops.Ciphertext,
    plan: BsgsPlan,
    keys: KeySet,
    scale: float | None = None,
    backend: str = "auto",
) -> ops.Ciphertext:
    """Homomorphic M·v.  Consumes one level (single rescale at the end)."""
    n = params.slots
    scale = params.scale if scale is None else scale
    lv = ct.level

    babies: dict[int, ops.Ciphertext] = {0: ct}
    needed_b = sorted({d % plan.n1 for d in plan.diags})
    for b in needed_b:
        if b and b not in babies:
            babies[b] = ops.rotate(params, ct, b, keys, backend)

    by_giant: dict[int, list[int]] = {}
    for d in plan.diags:
        by_giant.setdefault(d // plan.n1, []).append(d)

    total: ops.Ciphertext | None = None
    for g, ds in sorted(by_giant.items()):
        acc: ops.Ciphertext | None = None
        for d in ds:
            b = d % plan.n1
            u = np.roll(plan.diags[d], g * plan.n1)  # pre-rotate the diagonal
            pt = ops.encode(params, u, level=lv, scale=scale, backend=backend)
            term = ops.mul_plain(params, babies[b], pt, rescale_after=False, backend=backend)
            acc = term if acc is None else ops.add(params, acc, term, backend)
        if g:
            acc = ops.rotate(params, acc, g * plan.n1, keys, backend)
        total = acc if total is None else ops.add(params, total, acc, backend)

    return ops.rescale(params, total, backend)


def apply_bsgs_pair(
    params: CkksParams,
    ct: ops.Ciphertext,
    plans: tuple[BsgsPlan, BsgsPlan],
    keys: KeySet,
    scale: float | None = None,
    backend: str = "auto",
) -> tuple[ops.Ciphertext, ops.Ciphertext]:
    """Two transforms of the same input sharing the baby rotations."""
    # (simple composition; baby-step sharing is an optimisation the scheduler
    # models — numerically we just apply twice)
    return (
        apply_bsgs(params, ct, plans[0], keys, scale, backend),
        apply_bsgs(params, ct, plans[1], keys, scale, backend),
    )


def real_part(params: CkksParams, ct: ops.Ciphertext, keys: KeySet,
              backend: str = "auto") -> ops.Ciphertext:
    """(ct + conj(ct)) / 2 — scale the ½ into the bookkeeping (free)."""
    s = ops.add(params, ct, ops.conjugate(params, ct, keys, backend), backend)
    return ops.Ciphertext(s.c0, s.c1, s.level, s.scale * 2.0)


def imag_part(params: CkksParams, ct: ops.Ciphertext, keys: KeySet,
              backend: str = "auto") -> ops.Ciphertext:
    """(ct − conj(ct)) / 2i — fold 1/(2i) into a plaintext mul."""
    d = ops.sub(params, ct, ops.conjugate(params, ct, keys, backend), backend)
    return ops.mul_const(params, d, -0.5j, rescale_after=True, backend=backend)
