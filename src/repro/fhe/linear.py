"""Homomorphic linear transforms via the BSGS diagonal method.

M·v = Σ_g rot_{g·n1}( Σ_b  rot_{-g·n1}(diag_{g·n1+b}(M)) ∘ rot_b(v) )

Baby rotations rot_b(v) are shared across giants, so an n×n dense transform
costs ≈ 2√n key-switched rotations + n plaintext multiplies — the dominant
workload of CoeffToSlot/SlotToCoeff in bootstrapping (paper §3.3: rotation-
heavy deep pipelines).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from . import ops
from .keys import KeySet
from .params import CkksParams


@dataclasses.dataclass
class BsgsPlan:
    n1: int  # baby-step count
    diags: dict[int, np.ndarray]  # d → diag_d(M) (length n complex)
    _rot_cache: dict = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False
    )

    def baby_steps(self) -> tuple[int, ...]:
        """Sorted non-zero baby rotations {d mod n1} — one hoisting group."""
        hit = self._rot_cache.get("babies")
        if hit is None:
            hit = tuple(sorted({d % self.n1 for d in self.diags} - {0}))
            self._rot_cache["babies"] = hit
        return hit

    def giant_steps(self) -> tuple[int, ...]:
        """Sorted non-zero giant rotations {(d // n1) · n1}."""
        hit = self._rot_cache.get("giants")
        if hit is None:
            hit = tuple(sorted({(d // self.n1) * self.n1 for d in self.diags} - {0}))
            self._rot_cache["giants"] = hit
        return hit

    def rotations(self) -> frozenset[int]:
        """Slot rotations whose Galois keys the transform needs (cached —
        keygen and every apply call share one computation)."""
        hit = self._rot_cache.get("all")
        if hit is None:
            hit = frozenset(self.baby_steps()) | frozenset(self.giant_steps())
            self._rot_cache["all"] = hit
        return hit


def plan_matrix(m: np.ndarray, n1: int | None = None, tol: float = 0.0) -> BsgsPlan:
    """Extract (optionally sparse) diagonals of an n×n matrix for BSGS."""
    n = m.shape[0]
    assert m.shape == (n, n)
    if n1 is None:
        n1 = max(1, 1 << int(round(math.log2(math.sqrt(n)))))  # ≈ √n, power of two
    idx = np.arange(n)
    diags = {}
    mx = np.abs(m).max() or 1.0
    for d in range(n):
        u = m[idx, (idx + d) % n]
        if tol == 0.0 or np.abs(u).max() > tol * mx:
            diags[int(d)] = u.astype(np.complex128)
    return BsgsPlan(n1=n1, diags=diags)


def apply_bsgs(
    params: CkksParams,
    ct: ops.Ciphertext,
    plan: BsgsPlan,
    keys: KeySet,
    scale: float | None = None,
    backend: str = "auto",
    hoisting: str = "auto",
) -> ops.Ciphertext:
    """Homomorphic M·v.  Consumes one level (single rescale at the end).

    ``hoisting`` controls the baby-step rotations (the dominant key-switch
    cost): "auto"/"always" share ONE ModUp across the whole baby group
    (Halevi–Shoup; "auto" falls back to per-rotation key-switching when the
    group has fewer than two rotations), "never" key-switches each baby
    separately.  All modes are bit-exact against each other.  Giant-step
    rotations apply to *different* ciphertexts (the per-group partial sums),
    so they cannot share a ModUp and always run the standard path.
    """
    if hoisting not in ops.HOISTING_MODES:
        raise ValueError(f"unknown hoisting mode {hoisting!r}")
    scale = params.scale if scale is None else scale
    lv = ct.level

    babies: dict[int, ops.Ciphertext] = {0: ct}
    needed_b = plan.baby_steps()
    if hoisting == "always" or (hoisting == "auto" and len(needed_b) >= 2):
        babies.update(ops.rotate_hoisted_group(params, ct, needed_b, keys, backend))
    else:
        for b in needed_b:
            babies[b] = ops.rotate(params, ct, b, keys, backend)

    by_giant: dict[int, list[int]] = {}
    for d in plan.diags:
        by_giant.setdefault(d // plan.n1, []).append(d)

    total: ops.Ciphertext | None = None
    for g, ds in sorted(by_giant.items()):
        acc: ops.Ciphertext | None = None
        for d in ds:
            b = d % plan.n1
            u = np.roll(plan.diags[d], g * plan.n1)  # pre-rotate the diagonal
            pt = ops.encode(params, u, level=lv, scale=scale, backend=backend)
            term = ops.mul_plain(params, babies[b], pt, rescale_after=False, backend=backend)
            acc = term if acc is None else ops.add(params, acc, term, backend)
        if g:
            acc = ops.rotate(params, acc, g * plan.n1, keys, backend)
        total = acc if total is None else ops.add(params, total, acc, backend)

    return ops.rescale(params, total, backend)


def apply_bsgs_pair(
    params: CkksParams,
    ct: ops.Ciphertext,
    plans: tuple[BsgsPlan, BsgsPlan],
    keys: KeySet,
    scale: float | None = None,
    backend: str = "auto",
    hoisting: str = "auto",
) -> tuple[ops.Ciphertext, ops.Ciphertext]:
    """Two transforms of the same input sharing the baby rotations."""
    # (simple composition; baby-step sharing is an optimisation the scheduler
    # models — numerically we just apply twice)
    return (
        apply_bsgs(params, ct, plans[0], keys, scale, backend, hoisting),
        apply_bsgs(params, ct, plans[1], keys, scale, backend, hoisting),
    )


def real_part(params: CkksParams, ct: ops.Ciphertext, keys: KeySet,
              backend: str = "auto") -> ops.Ciphertext:
    """(ct + conj(ct)) / 2 — scale the ½ into the bookkeeping (free)."""
    s = ops.add(params, ct, ops.conjugate(params, ct, keys, backend), backend)
    return ops.Ciphertext(s.c0, s.c1, s.level, s.scale * 2.0)


def imag_part(params: CkksParams, ct: ops.Ciphertext, keys: KeySet,
              backend: str = "auto") -> ops.Ciphertext:
    """(ct − conj(ct)) / 2i — fold 1/(2i) into a plaintext mul."""
    d = ops.sub(params, ct, ops.conjugate(params, ct, keys, backend), backend)
    return ops.mul_const(params, d, -0.5j, rescale_after=True, backend=backend)
