"""Homomorphic linear transforms via the BSGS diagonal method.

M·v = Σ_g rot_{g·n1}( Σ_b  rot_{-g·n1}(diag_{g·n1+b}(M)) ∘ rot_b(v) )

Baby rotations rot_b(v) are shared across giants, so an n×n dense transform
costs ≈ 2√n key-switched rotations + n plaintext multiplies — the dominant
workload of CoeffToSlot/SlotToCoeff in bootstrapping (paper §3.3: rotation-
heavy deep pipelines).

Execution policy comes from ``repro.fhe.context.FheContext`` —
``ctx.apply_bsgs``/``ctx.plan_matrix`` are the primary API, and
``plan_matrix`` picks the baby-step count n1 from a hoisting-aware cost model
(under hoisting, baby steps are nearly free — see ``choose_n1``).  The
deprecated module-level free functions taking ``backend=``/``hoisting=``
kwargs were retired (docs/context_api.md); only the pure planning helpers
remain at module level.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from . import ops
from .params import CkksParams


@dataclasses.dataclass
class BsgsPlan:
    n1: int  # baby-step count
    diags: dict[int, np.ndarray]  # d → diag_d(M) (length n complex)
    _rot_cache: dict = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False
    )

    def baby_steps(self) -> tuple[int, ...]:
        """Sorted non-zero baby rotations {d mod n1} — one hoisting group."""
        hit = self._rot_cache.get("babies")
        if hit is None:
            hit = tuple(sorted({d % self.n1 for d in self.diags} - {0}))
            self._rot_cache["babies"] = hit
        return hit

    def giant_steps(self) -> tuple[int, ...]:
        """Sorted non-zero giant rotations {(d // n1) · n1}."""
        hit = self._rot_cache.get("giants")
        if hit is None:
            hit = tuple(sorted({(d // self.n1) * self.n1 for d in self.diags} - {0}))
            self._rot_cache["giants"] = hit
        return hit

    def rotations(self) -> frozenset[int]:
        """Slot rotations whose Galois keys the transform needs (cached —
        keygen and every apply call share one computation)."""
        hit = self._rot_cache.get("all")
        if hit is None:
            hit = frozenset(self.baby_steps()) | frozenset(self.giant_steps())
            self._rot_cache["all"] = hit
        return hit


# ---------------------------------------------------------------------------
# BSGS planning: the hoisting-aware n1 cost model
# ---------------------------------------------------------------------------


def bsgs_rotation_cost(diag_indices, n1: int, params: CkksParams, level: int,
                       hoisted: bool) -> float:
    """Key-switch cost of a BSGS split, in limb-NTT-equivalents.

    The model counts the (i)NTT limb-transforms each rotation path issues —
    the planner's own instruction shapes, collapsed to the dominant unit:

      * a full key-switched rotation (unhoisted baby, or any giant — giants
        act on *different* partial sums, so they can never share a ModUp):
        ModUp (1 iNTT over nq limbs + β forward NTTs over m = nq+α limbs)
        plus two ModDown tails (each α iNTT + nq NTT limbs);
      * a hoisted baby: only the two ModDown tails — the group's single ModUp
        is charged once.

    Plaintext multiplies are diagonal-count work, identical for every n1, so
    they cancel out of the argmin and are omitted.
    """
    nq = level + 1
    alpha = params.alpha
    beta = params.beta(level)
    m = nq + alpha
    full = nq + beta * m + 2 * (alpha + nq)  # ModUp + 2× ModDown
    baby_hoisted = 2 * (alpha + nq)  # MAC rides the exit; ModDown dominates
    babies = len({d % n1 for d in diag_indices} - {0})
    giants = len({(d // n1) * n1 for d in diag_indices} - {0})
    if not hoisted:
        return (babies + giants) * full
    modup_once = nq + beta * m if babies else 0.0
    return modup_once + babies * baby_hoisted + giants * full


def choose_n1(diag_indices, params: CkksParams, level: int, hoisted: bool) -> int:
    """Baby-step count minimising the rotation cost model over powers of two.

    Without hoisting the optimum sits at the classic ≈ √(#diags) balance
    point.  With hoisting, baby steps cost only a ModDown each (the ModUp is
    shared), so the optimum shifts toward more babies / fewer giants — e.g.
    the radix-32 CtS stage (63 diagonals) moves from n1 = 8 to n1 = 16, the
    value ``benchmarks/hoisting_bench.py`` exploits.
    """
    diag_indices = tuple(diag_indices)
    if not diag_indices:
        return 1
    top = 1 << max(0, (max(diag_indices)).bit_length())
    candidates = []
    n1 = 1
    while n1 <= max(2, top):
        candidates.append(n1)
        n1 <<= 1
    return min(
        candidates,
        key=lambda c: (bsgs_rotation_cost(diag_indices, c, params, level, hoisted), c),
    )


def plan_matrix(m: np.ndarray, n1: int | None = None, tol: float = 0.0,
                params: CkksParams | None = None, level: int | None = None,
                hoisting: bool = False) -> BsgsPlan:
    """Extract (optionally sparse) diagonals of an n×n matrix for BSGS.

    n1 selection, in priority order: an explicit ``n1``; the hoisting-aware
    cost model when ``params`` is given (``choose_n1`` — pass
    ``hoisting=True`` when the transform will run under a hoisting policy);
    otherwise the classic ≈ √n power of two.
    """
    n = m.shape[0]
    assert m.shape == (n, n)
    idx = np.arange(n)
    diags = {}
    mx = np.abs(m).max() or 1.0
    for d in range(n):
        u = m[idx, (idx + d) % n]
        if tol == 0.0 or np.abs(u).max() > tol * mx:
            diags[int(d)] = u.astype(np.complex128)
    if n1 is None:
        if params is not None:
            n1 = choose_n1(diags, params, params.L if level is None else level, hoisting)
        else:
            n1 = max(1, 1 << int(round(math.log2(math.sqrt(n)))))  # ≈ √n, power of two
    return BsgsPlan(n1=n1, diags=diags)


def plan_diags(diags: dict[int, np.ndarray], params: CkksParams, level: int | None = None,
               hoisting: bool = False, n1: int | None = None) -> BsgsPlan:
    """BSGS plan straight from a diagonal dict (for banded transforms whose
    dense matrix is too large to materialise), n1 from the cost model."""
    if n1 is None:
        n1 = choose_n1(diags, params, params.L if level is None else level, hoisting)
    return BsgsPlan(n1=n1, diags=dict(diags))


# ---------------------------------------------------------------------------
# context implementations
# ---------------------------------------------------------------------------


def _apply_bsgs(ctx, ct: ops.Ciphertext, plan: BsgsPlan,
                scale: float | None = None) -> ops.Ciphertext:
    """Homomorphic M·v.  Consumes one level (single rescale at the end).

    The policy's hoisting mode controls the baby-step rotations (the dominant
    key-switch cost): "auto"/"always" share ONE ModUp across the whole baby
    group (Halevi–Shoup; "auto" falls back to per-rotation key-switching when
    the group has fewer than two rotations), "never" key-switches each baby
    separately.  All modes are bit-exact against each other.  Giant-step
    rotations apply to *different* ciphertexts (the per-group partial sums),
    so they cannot share a ModUp and always run the standard path.
    """
    params = ctx.params
    keys = ctx.require_keys()
    hoisting = ctx.policy.hoisting
    scale = params.scale if scale is None else scale
    lv = ct.level

    babies: dict[int, ops.Ciphertext] = {0: ct}
    needed_b = plan.baby_steps()
    if hoisting == "always" or (hoisting == "auto" and len(needed_b) >= 2):
        babies.update(ops._rotate_hoisted_group(ctx, ct, needed_b, keys))
    else:
        for b in needed_b:
            babies[b] = ops._rotate_standard(ctx, ct, b, keys)

    by_giant: dict[int, list[int]] = {}
    for d in plan.diags:
        by_giant.setdefault(d // plan.n1, []).append(d)

    total: ops.Ciphertext | None = None
    for g, ds in sorted(by_giant.items()):
        acc: ops.Ciphertext | None = None
        for d in ds:
            b = d % plan.n1
            u = np.roll(plan.diags[d], g * plan.n1)  # pre-rotate the diagonal
            pt = ops._encode(ctx, u, level=lv, scale=scale)
            term = ops._mul_plain(ctx, babies[b], pt, rescale_after=False)
            acc = term if acc is None else ops._add(ctx, acc, term)
        if g:
            acc = ops._rotate_standard(ctx, acc, g * plan.n1, keys)
        total = acc if total is None else ops._add(ctx, total, acc)

    return ops._rescale(ctx, total)


def _real_part(ctx, ct: ops.Ciphertext) -> ops.Ciphertext:
    """(ct + conj(ct)) / 2 — scale the ½ into the bookkeeping (free)."""
    s = ops._add(ctx, ct, ops._conjugate(ctx, ct, ctx.require_keys()))
    return ops.Ciphertext(s.c0, s.c1, s.level, s.scale * 2.0)


def _imag_part(ctx, ct: ops.Ciphertext) -> ops.Ciphertext:
    """(ct − conj(ct)) / 2i — fold 1/(2i) into a plaintext mul."""
    d = ops._sub(ctx, ct, ops._conjugate(ctx, ct, ctx.require_keys()))
    return ops._mul_const(ctx, d, -0.5j, rescale_after=True)

