"""Key generation: secret/public keys and hybrid key-switching keys.

Hybrid KSK layout (Han–Ki / Lattigo convention, DESIGN.md §6): the chain
q_0..q_L is partitioned into dnum digits of ≤ α consecutive primes.  The key for
digit j encrypts  P·F_j·s'  under s over the extended basis Q∪P, where
F_j = Q̂_j·[Q̂_j^{-1}]_{Q_j}  satisfies  F_j ≡ 1 (mod q∈D_j), ≡ 0 (mod q∉D_j).
Level restriction is pure limb-dropping — the congruences hold per limb.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from . import poly, trace
from .params import CkksParams


@dataclasses.dataclass
class SecretKey:
    s_coeff: np.ndarray  # (N,) int64 ternary
    s_eval: jnp.ndarray  # (L+1+α, N) uint32, eval domain over the master chain


@dataclasses.dataclass
class PublicKey:
    b: jnp.ndarray  # (L+1, N) eval domain over Q
    a: jnp.ndarray


@dataclasses.dataclass
class SwitchingKey:
    """(dnum, 2, L+1+α, N) uint32 — eval domain over the full extended basis."""

    k: jnp.ndarray

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.k.shape)) * 4


@dataclasses.dataclass
class KeySet:
    sk: SecretKey
    pk: PublicKey
    rlk: SwitchingKey
    gks: dict[int, SwitchingKey]  # galois element t → key for σ_t(s) → s
    # (t, level) → σ_t^{-1}-pre-permuted level-restricted key, filled lazily by
    # ``keyswitch.hoisted_ksk`` — a keygen-time precompute for hoisted rotations
    hoist_cache: dict = dataclasses.field(default_factory=dict, repr=False, compare=False)

    def galois(self, t: int) -> SwitchingKey:
        if t not in self.gks:
            raise KeyError(f"galois key for t={t} not generated")
        return self.gks[t]


def _uniform_rns(rng: np.random.Generator, primes, n: int) -> np.ndarray:
    out = np.empty((len(primes), n), np.uint32)
    for i, p in enumerate(primes):
        out[i] = rng.integers(0, int(p), size=n, dtype=np.uint64).astype(np.uint32)
    return out


def keygen(params: CkksParams, seed: int = 0, h: int | None = None) -> SecretKey:
    rng = np.random.default_rng(seed)
    if h is None:
        h = min(192, params.n // 4)
    s = poly.sample_ternary(rng, params.n, h)
    all_primes = params.all_primes
    s_rns = poly.to_rns_signed(s, all_primes)
    idx = tuple(range(len(all_primes)))
    s_eval = poly.to_eval(s_rns, params, idx)
    return SecretKey(s_coeff=s, s_eval=s_eval)


def _err_scale(params: CkksParams) -> int:
    """Error multiplier for key material: BGV keys carry t·e errors (message in
    the low-order bits), CKKS keys plain e."""
    return int(params.plain_modulus) if params.plain_modulus is not None else 1


def pkgen(params: CkksParams, sk: SecretKey, seed: int = 1) -> PublicKey:
    rng = np.random.default_rng(seed)
    qp = params.q_primes
    idx = poly.q_idx(params, params.L)
    a = jnp.asarray(_uniform_rns(rng, qp, params.n))
    e_coeff = _err_scale(params) * poly.sample_gaussian(rng, params.n)
    e = poly.to_eval(poly.to_rns_signed(e_coeff, qp), params, idx)
    s_q = sk.s_eval[: params.L + 1]
    from repro.kernels.modops import ops as mo

    qs = np.array(qp, np.uint64)
    b = mo.pointwise_submod(e, mo.pointwise_mulmod(a, s_q, qs, backend="ref"), qs, backend="ref")
    return PublicKey(b=b, a=a)


def kskgen(params: CkksParams, sk: SecretKey, s_prime_eval: jnp.ndarray, seed: int) -> SwitchingKey:
    """Key switching s' → s.  s_prime_eval: (L+1+α, N) over the master chain."""
    from repro.kernels.modops import ops as mo

    rng = np.random.default_rng(seed)
    all_primes = params.all_primes
    n = params.n
    L, alpha = params.L, params.alpha
    next_ = len(all_primes)
    idx_full = tuple(range(next_))
    qs = np.array(all_primes, np.uint64)
    P = 1
    for p in params.p_primes:
        P *= int(p)

    dnum = params.num_digits
    out = np.empty((dnum, 2, next_, n), np.uint32)
    for j in range(dnum):
        digit = params.digit(j)
        Qj = 1
        for i in digit:
            Qj *= int(all_primes[i])
        Q = 1
        for i in range(L + 1):
            Q *= int(all_primes[i])
        Qhat = Q // Qj
        Fj = Qhat * pow(Qhat, -1, Qj)  # ≡ 1 mod Q_j, ≡ 0 mod q∉D_j
        PFj = P * Fj
        pfj_limbs = np.array([PFj % int(p) for p in all_primes], np.uint64)

        a = jnp.asarray(_uniform_rns(rng, all_primes, n))
        e_coeff = _err_scale(params) * poly.sample_gaussian(rng, n)
        e = poly.to_eval(poly.to_rns_signed(e_coeff, all_primes), params, idx_full)
        # b = -a·s + e + PFj·s'  (eval domain, per limb)
        asq = mo.pointwise_mulmod(a, sk.s_eval, qs, backend="ref")
        pf = mo.pointwise_mulmod(
            s_prime_eval, jnp.asarray(pfj_limbs[:, None] % qs[:, None], jnp.uint32), qs,
            backend="ref",
        )
        b = mo.pointwise_submod(mo.pointwise_addmod(e, pf, qs, backend="ref"), asq, qs, backend="ref")
        out[j, 0] = np.asarray(b)
        out[j, 1] = np.asarray(a)
    trace.record("KSKGEN", n, dnum * 2 * next_)
    return SwitchingKey(k=jnp.asarray(out))


def relin_keygen(params: CkksParams, sk: SecretKey, seed: int = 2) -> SwitchingKey:
    from repro.kernels.modops import ops as mo

    qs = np.array(params.all_primes, np.uint64)
    s2 = mo.pointwise_mulmod(sk.s_eval, sk.s_eval, qs, backend="ref")
    return kskgen(params, sk, s2, seed)


def galois_keygen(params: CkksParams, sk: SecretKey, t: int, seed: int = 3) -> SwitchingKey:
    s_t = poly.automorphism_eval(sk.s_eval, params.n, t)
    return kskgen(params, sk, s_t, seed + t)


def galois_elements(params: CkksParams, rotations: tuple[int, ...] = (),
                    conjugate: bool = False) -> tuple[int, ...]:
    """Deduplicated Galois elements a rotation set needs keys for.

    Rotations congruent mod ``slots`` share one element, so precomputing this
    union (e.g. over every BSGS plan of a bootstrapping context) is what keeps
    keygen from over-generating switching keys."""
    ts = {pow(5, r % params.slots, 2 * params.n) for r in rotations if r % params.slots}
    if conjugate:
        ts.add(2 * params.n - 1)
    return tuple(sorted(ts))


def full_keyset(
    params: CkksParams,
    seed: int = 0,
    rotations: tuple[int, ...] = (),
    conjugate: bool = False,
    h: int | None = None,
) -> KeySet:
    """Generate sk/pk/rlk plus exactly one Galois key per needed element."""
    sk = keygen(params, seed, h=h)
    pk = pkgen(params, sk, seed + 1)
    rlk = relin_keygen(params, sk, seed + 2)
    gks: dict[int, SwitchingKey] = {
        t: galois_keygen(params, sk, t, seed + 100)
        for t in galois_elements(params, rotations, conjugate)
    }
    return KeySet(sk=sk, pk=pk, rlk=rlk, gks=gks)
