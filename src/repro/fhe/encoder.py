"""CKKS encoder: C^{N/2} ↔ R_q via the canonical embedding.

Slot ordering follows the standard generator-5 convention: slot j evaluates the
message polynomial at ζ^{5^j mod 2N} (ζ = e^{iπ/N}), with conjugate slots at the
negated exponents.  Under this ordering the Galois automorphism σ_{5^r} is a
cyclic left-rotation of the slot vector by r — which is what `ctx.rotate`
key-switches.

Both directions are O(N log N): the evaluation at all odd powers ζ^{2k+1}
(natural order) is an FFT with a ζ^i pre-twist; the generator ordering is a
permutation on top.
"""

from __future__ import annotations

import functools

import numpy as np

from . import rns


@functools.lru_cache(maxsize=16)
def _tables(n: int):
    """(zeta_pows, slot_to_nat, conj_to_nat) for ring degree n."""
    i = np.arange(n)
    zeta = np.exp(1j * np.pi * i / n)  # ζ^i, ζ = e^{iπ/N}
    # generator-5 exponents g_j = 5^j mod 2N for j < N/2
    g = np.empty(n // 2, dtype=np.int64)
    cur = 1
    for j in range(n // 2):
        g[j] = cur
        cur = (cur * 5) % (2 * n)
    slot_to_nat = (g - 1) // 2  # natural index k with 2k+1 = g_j
    conj_to_nat = (2 * n - g - 1) // 2
    return zeta, slot_to_nat, conj_to_nat


def _eval_all_odd(a: np.ndarray) -> np.ndarray:
    """a(ζ^{2k+1}) for k = 0..N-1 from real coefficient vector a (length N)."""
    n = a.shape[-1]
    zeta, _, _ = _tables(n)
    return n * np.fft.ifft(a * zeta)


def decode(coeffs_rns: np.ndarray, primes, scale: float, max_limbs: int = 4) -> np.ndarray:
    """(limbs, N) uint32 coefficient-domain RNS → complex slot vector (N/2,)."""
    n = coeffs_rns.shape[-1]
    vals = rns.crt_reconstruct_centered(np.asarray(coeffs_rns), primes, max_limbs=max_limbs)
    a = np.array([float(v) for v in vals]) / scale
    nat = _eval_all_odd(a)
    _, s2n, _ = _tables(n)
    return nat[s2n]


def encode_coeffs(z: np.ndarray, n: int, scale: float) -> np.ndarray:
    """Complex slots (≤ N/2,) → integer coefficient vector (N,) int64.

    Shorter vectors are zero-padded (standard sparse packing is NOT applied —
    full-slot packing per the paper's packed bootstrapping).
    """
    zeta, s2n, c2n = _tables(n)
    zfull = np.zeros(n, dtype=np.complex128)
    z = np.asarray(z, dtype=np.complex128).ravel()
    assert z.shape[0] <= n // 2, "too many slots"
    zfull[s2n[: z.shape[0]]] = z
    zfull[c2n[: z.shape[0]]] = np.conj(z)
    b = np.fft.fft(zfull) / n
    a = np.real(b * np.conj(zeta))
    return np.rint(a * scale).astype(np.int64)


def encode(z: np.ndarray, n: int, scale: float, primes) -> np.ndarray:
    """Complex slots → (limbs, N) uint32 RNS coefficients over ``primes``."""
    return rns.to_rns_i64(encode_coeffs(z, n, scale), primes)


def encode_const(c: complex, n: int, scale: float, primes) -> np.ndarray:
    """Scalar broadcast to all slots.  Real scalars encode to a constant poly."""
    if abs(complex(c).imag) < 1e-300:
        v = int(round(float(np.real(c)) * scale))
        out = np.zeros((len(primes), n), np.uint32)
        for i, p in enumerate(primes):
            out[i, 0] = v % int(p)
        return out
    return encode(np.full(n // 2, c), n, scale, primes)


def max_encode_error(n: int, scale: float) -> float:
    """Rounding bound: |decode(encode(z)) - z|_∞ ≤ N/(2·scale) (loose)."""
    return n / (2.0 * scale)
