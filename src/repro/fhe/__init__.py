"""CKKS FHE scheme implemented in JAX.

The FHE layer uses exact integer arithmetic:
  * oracle path: uint64 jnp ops (requires x64 — enabled below at import);
  * TPU path:    uint32 Montgomery arithmetic (see repro.fhe.modmath / repro.kernels).

x64 is enabled here (and only here) because RNS arithmetic on the host/reference path
needs 64-bit integers.  Model/training code is dtype-explicit and unaffected.
"""

import jax

jax.config.update("jax_enable_x64", True)
