"""CKKS FHE scheme implemented in JAX.

The FHE layer uses exact integer arithmetic:
  * oracle path: uint64 jnp ops (requires x64 — enabled below at import);
  * TPU path:    uint32 Montgomery arithmetic (see repro.fhe.modmath / repro.kernels).

x64 is enabled here (and only here) because RNS arithmetic on the host/reference path
needs 64-bit integers.  Model/training code is dtype-explicit and unaffected.

Public API: ``FheContext`` (an immutable bundle of params + keys + an
``ExecPolicy``) is the primary way to evaluate — see ``repro.fhe.context``.
The per-op ``backend=`` kwargs on the module-level free functions are a
deprecated compatibility surface.  Both names are exported lazily so that
lightweight imports (``repro.fhe.params``, ``repro.fhe.trace``) stay cheap.
"""

import jax

jax.config.update("jax_enable_x64", True)

_CONTEXT_EXPORTS = ("FheContext", "ExecPolicy")


def __getattr__(name):
    if name in _CONTEXT_EXPORTS:
        from . import context

        return getattr(context, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_CONTEXT_EXPORTS))
