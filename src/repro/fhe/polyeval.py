"""Homomorphic polynomial evaluation in the Chebyshev basis.

Used by EvalMod (homomorphic sine) in bootstrapping.  Depth is
⌈log2(degree)⌉+1 levels: T_j is built by the product rule
T_{a+b} = 2·T_a·T_b − T_{|a−b|} with a ≈ b ≈ j/2, then the polynomial is a
single plaintext linear combination over the basis.

Scale discipline (exact — no tolerance fudging):
  * T_{|a−b|} always lives at a strictly higher level than the product, so the
    subtraction aligns through `force_to`, which folds the exact scale ratio
    into a mul-by-one plaintext (rounding ≤ 2^-25 relative).
  * the linear combination encodes each coefficient at scale
    s*·q_ℓ/s_i so every term lands at exactly (level*, s*).

The mult count here is O(d); the hardware planner (repro.core.planner) models
the Paterson–Stockmeyer count ~2√d when emitting instruction streams — the
*depth* (what the level budget sees) is identical.

Evaluate through a context: ``ctx.eval_poly(ct, coeffs)`` (or
``ctx.chebyshev_basis`` + ``ctx.eval_chebyshev`` to reuse a basis).  The
``backend=``-kwarg free functions were retired (docs/context_api.md).
"""

from __future__ import annotations

import numpy as np

from . import ops


def chebyshev_fit(f, degree: int, k: float = 1.0) -> np.ndarray:
    """Chebyshev coefficients of f on [-k, k] (degree+1 coeffs)."""
    cheb = np.polynomial.chebyshev.Chebyshev.interpolate(f, degree, domain=[-k, k])
    return cheb.coef


# ---------------------------------------------------------------------------
# context implementations
# ---------------------------------------------------------------------------


def _force_to(ctx, ct: ops.Ciphertext, level: int, scale: float) -> ops.Ciphertext:
    """Bring ct to exactly (level, scale).

    Exact whenever ≥1 level is consumed: the scale ratio is folded into a
    mul-by-one encoded at scale  target·q_{lv+1}/current  (≈ 2^30 ≫ 1),
    followed by one rescale.
    """
    params = ctx.params
    assert ct.level >= level
    if ct.level == level:
        if scale != ct.scale:
            assert abs(scale / ct.scale - 1.0) < 1e-7, (
                f"same-level scale mismatch {ct.scale} vs {scale} — exact-scale "
                "discipline violated upstream"
            )
            ct = ops.Ciphertext(ct.c0, ct.c1, ct.level, scale)
        return ct
    ct = ops.level_drop(ct, level + 1)
    q = float(params.q_primes[level + 1])
    enc_scale = scale * q / ct.scale
    pt = ops._encode_const(ctx, 1.0, ct.level, enc_scale)
    out = ops._mul_plain(ctx, ct, pt, rescale_after=True)
    return ops.Ciphertext(out.c0, out.c1, out.level, scale)  # exact by construction


def _add_any(ctx, a: ops.Ciphertext, b: ops.Ciphertext) -> ops.Ciphertext:
    """Add ciphertexts at arbitrary levels (aligns to the deeper one, exactly)."""
    if a.level < b.level:
        b = _force_to(ctx, b, a.level, a.scale)
    elif b.level < a.level:
        a = _force_to(ctx, a, b.level, b.scale)
    elif a.scale != b.scale:
        b = _force_to(ctx, b, a.level, a.scale)  # asserts near-equality
    return ops._add(ctx, a, b)


class ChebyshevBasis:
    """T_1..T_degree over a normalised input x ∈ [-1, 1] (log-depth tree).

    Context-first construction: ``ChebyshevBasis(ctx, x, degree)`` (or
    ``ctx.chebyshev_basis(x, degree)``).  The legacy positional form
    ``ChebyshevBasis(params, x, keys, degree, backend=...)`` was retired
    along with the kwarg-threading shims (docs/context_api.md).
    """

    def __init__(self, ctx, x: ops.Ciphertext, degree: int):
        from .context import FheContext

        assert isinstance(ctx, FheContext) and isinstance(degree, int), (
            "ChebyshevBasis(ctx, x, degree) — the legacy "
            "(params, x, keys, degree, backend=...) form was removed; build an "
            "FheContext (see docs/context_api.md)"
        )
        self.ctx = ctx
        self.params = ctx.params
        self.keys = ctx.keys
        self.degree = degree
        self.backend = ctx.backend
        self.t: dict[int, ops.Ciphertext] = {1: x}
        for j in range(2, degree + 1):
            self.t[j] = self._pair(j)

    def _pair(self, j: int) -> ops.Ciphertext:
        """T_j = 2·T_a·T_b − T_{|a−b|},  a = ⌊j/2⌋."""
        ctx = self.ctx
        a = j // 2
        b = j - a
        prod = ops._mul(ctx, self.t[a], self.t[b], ctx.require_keys().rlk)  # rescaled
        two = ops._add(ctx, prod, prod)
        if a == b:
            return ops._add_const(ctx, two, -1.0)
        # T_{|a-b|} = T_{b-a} was built earlier ⇒ strictly higher level ⇒ exact
        return _add_any(ctx, two, ops._negate(ctx, self.t[b - a]))

    def min_level(self) -> int:
        return min(ct.level for ct in self.t.values())


def _eval_chebyshev(ctx, basis: ChebyshevBasis, coeffs: np.ndarray) -> ops.Ciphertext:
    """Σ c_i·T_i(x) as one exact plaintext linear combination."""
    params = ctx.params
    c = np.asarray(coeffs, dtype=np.float64)
    assert len(c) - 1 <= basis.degree
    s_star = params.scale
    lv_star = basis.min_level() - 1

    acc: ops.Ciphertext | None = None
    for i in range(1, len(c)):
        if abs(c[i]) < 1e-14:
            continue
        ti = basis.t[i]
        # encode so the rescaled product lands at exactly (ti.level-1, s*)
        enc_scale = s_star * float(params.q_primes[ti.level]) / ti.scale
        assert enc_scale > 256.0, f"enc_scale underflow at T_{i} (scale drift)"
        pt = ops._encode_const(ctx, float(c[i]), ti.level, enc_scale)
        term = ops._mul_plain(ctx, ti, pt, rescale_after=True)
        term = ops.Ciphertext(term.c0, term.c1, term.level, s_star)  # exact
        term = _force_to(ctx, term, lv_star, s_star)
        acc = term if acc is None else ops._add(ctx, acc, term)
    if acc is None:
        z = ops._mul_const(ctx, basis.t[1], 0.0)
        acc = _force_to(ctx, ops.Ciphertext(z.c0, z.c1, z.level, s_star), lv_star, s_star)
    if abs(c[0]) > 1e-14:
        acc = ops._add_const(ctx, acc, float(c[0]))
    return acc

