"""Homomorphic polynomial evaluation in the Chebyshev basis.

Used by EvalMod (homomorphic sine) in bootstrapping.  Depth is
⌈log2(degree)⌉+1 levels: T_j is built by the product rule
T_{a+b} = 2·T_a·T_b − T_{|a−b|} with a ≈ b ≈ j/2, then the polynomial is a
single plaintext linear combination over the basis.

Scale discipline (exact — no tolerance fudging):
  * T_{|a−b|} always lives at a strictly higher level than the product, so the
    subtraction aligns through `force_to`, which folds the exact scale ratio
    into a mul-by-one plaintext (rounding ≤ 2^-25 relative).
  * the linear combination encodes each coefficient at scale
    s*·q_ℓ/s_i so every term lands at exactly (level*, s*).

The mult count here is O(d); the hardware planner (repro.core.planner) models
the Paterson–Stockmeyer count ~2√d when emitting instruction streams — the
*depth* (what the level budget sees) is identical.
"""

from __future__ import annotations

import numpy as np

from . import ops
from .keys import KeySet
from .params import CkksParams


def chebyshev_fit(f, degree: int, k: float = 1.0) -> np.ndarray:
    """Chebyshev coefficients of f on [-k, k] (degree+1 coeffs)."""
    cheb = np.polynomial.chebyshev.Chebyshev.interpolate(f, degree, domain=[-k, k])
    return cheb.coef


def force_to(params: CkksParams, ct: ops.Ciphertext, level: int, scale: float,
             backend: str = "auto") -> ops.Ciphertext:
    """Bring ct to exactly (level, scale).

    Exact whenever ≥1 level is consumed: the scale ratio is folded into a
    mul-by-one encoded at scale  target·q_{lv+1}/current  (≈ 2^30 ≫ 1),
    followed by one rescale.
    """
    assert ct.level >= level
    if ct.level == level:
        if scale != ct.scale:
            assert abs(scale / ct.scale - 1.0) < 1e-7, (
                f"same-level scale mismatch {ct.scale} vs {scale} — exact-scale "
                "discipline violated upstream"
            )
            ct = ops.Ciphertext(ct.c0, ct.c1, ct.level, scale)
        return ct
    ct = ops.level_drop(ct, level + 1)
    q = float(params.q_primes[level + 1])
    enc_scale = scale * q / ct.scale
    pt = ops.encode_const(params, 1.0, ct.level, enc_scale, backend)
    out = ops.mul_plain(params, ct, pt, rescale_after=True, backend=backend)
    return ops.Ciphertext(out.c0, out.c1, out.level, scale)  # exact by construction


def add_any(params: CkksParams, a: ops.Ciphertext, b: ops.Ciphertext,
            backend: str = "auto") -> ops.Ciphertext:
    """Add ciphertexts at arbitrary levels (aligns to the deeper one, exactly)."""
    if a.level < b.level:
        b = force_to(params, b, a.level, a.scale, backend)
    elif b.level < a.level:
        a = force_to(params, a, b.level, b.scale, backend)
    elif a.scale != b.scale:
        b = force_to(params, b, a.level, a.scale, backend)  # asserts near-equality
    return ops.add(params, a, b, backend)


class ChebyshevBasis:
    """T_1..T_degree over a normalised input x ∈ [-1, 1] (log-depth tree)."""

    def __init__(self, params: CkksParams, x: ops.Ciphertext, keys: KeySet, degree: int,
                 backend: str = "auto"):
        self.params = params
        self.keys = keys
        self.degree = degree
        self.backend = backend
        self.t: dict[int, ops.Ciphertext] = {1: x}
        for j in range(2, degree + 1):
            self.t[j] = self._pair(j)

    def _pair(self, j: int) -> ops.Ciphertext:
        """T_j = 2·T_a·T_b − T_{|a−b|},  a = ⌊j/2⌋."""
        p, keys, bk = self.params, self.keys, self.backend
        a = j // 2
        b = j - a
        prod = ops.mul(p, self.t[a], self.t[b], keys.rlk, backend=bk)  # rescaled
        two = ops.add(p, prod, prod, bk)
        if a == b:
            return ops.add_const(p, two, -1.0, bk)
        # T_{|a-b|} = T_{b-a} was built earlier ⇒ strictly higher level ⇒ exact
        return add_any(p, two, ops.negate(p, self.t[b - a], bk), bk)

    def min_level(self) -> int:
        return min(ct.level for ct in self.t.values())


def eval_chebyshev(
    params: CkksParams, basis: ChebyshevBasis, coeffs: np.ndarray, keys: KeySet,
    backend: str = "auto",
) -> ops.Ciphertext:
    """Σ c_i·T_i(x) as one exact plaintext linear combination."""
    c = np.asarray(coeffs, dtype=np.float64)
    assert len(c) - 1 <= basis.degree
    s_star = params.scale
    lv_star = basis.min_level() - 1

    acc: ops.Ciphertext | None = None
    for i in range(1, len(c)):
        if abs(c[i]) < 1e-14:
            continue
        ti = basis.t[i]
        # encode so the rescaled product lands at exactly (ti.level-1, s*)
        enc_scale = s_star * float(params.q_primes[ti.level]) / ti.scale
        assert enc_scale > 256.0, f"enc_scale underflow at T_{i} (scale drift)"
        pt = ops.encode_const(params, float(c[i]), ti.level, enc_scale, backend)
        term = ops.mul_plain(params, ti, pt, rescale_after=True, backend=backend)
        term = ops.Ciphertext(term.c0, term.c1, term.level, s_star)  # exact
        term = force_to(params, term, lv_star, s_star, backend)
        acc = term if acc is None else ops.add(params, acc, term, backend)
    if acc is None:
        z = ops.mul_const(params, basis.t[1], 0.0, backend=backend)
        acc = force_to(params, ops.Ciphertext(z.c0, z.c1, z.level, s_star), lv_star, s_star, backend)
    if abs(c[0]) > 1e-14:
        acc = ops.add_const(params, acc, float(c[0]), backend)
    return acc
