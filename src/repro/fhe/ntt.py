"""Negacyclic NTT plans for RNS-CKKS.

The ring is Z_q[x]/(x^N + 1).  With psi a primitive 2N-th root of unity mod q and
w = psi^2, the negacyclic NTT is a twist by psi^i followed by a cyclic N-point NTT;
slot j of the result is the evaluation a(psi^(2j+1)) (natural order).

Two executable forms share these plans:
  * ``repro.kernels.ntt.ref``    — uint64 iterative radix-2 oracle (fast on CPU/XLA);
  * ``repro.kernels.ntt.kernel`` — Pallas four-step kernel: an N1-point NTT is an
    N1×N1 modular *matmul* on the MXU (8-bit limb decomposition, exact int32
    accumulation, Montgomery recombination).  N = N1·N2 mirrors the paper's
    256×256 (bootstrappable, N=2^16) and 128×128 (swift, N=2^14) circuits.

Plans are cached per (N, primes).  All tables are host numpy; ops convert lazily.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from . import modmath as mm

NLIMB8 = 4  # number of 8-bit limbs covering q < 2^31
NDIAG = 2 * NLIMB8 - 1


def fourstep_split(n: int) -> tuple[int, int]:
    """N = N1·N2 with N2 ≥ 128 (lane-aligned) and N1 the 'circuit' size.

    2^16 → 256×256 (bootstrappable circuit), 2^14 → 128×128 (swift circuit),
    2^11 → 16×128, matching the paper's multi-entrance/exit decomposition.
    """
    logn = n.bit_length() - 1
    assert 1 << logn == n and logn >= 8, f"N={n} must be a power of two ≥ 256"
    log2_n2 = max(7, (logn + 1) // 2)
    n2 = 1 << log2_n2
    return n // n2, n2


def _pow_table(w: int, n: int, q: int) -> np.ndarray:
    """[w^0, ..., w^(n-1)] mod q as uint64, via log-doubling."""
    t = np.ones(n, dtype=np.uint64)
    if n == 1:
        return t
    t[1] = w % q
    filled = 2
    step = np.uint64(w % q)
    qq = np.uint64(q)
    while filled < n:
        take = min(filled, n - filled)
        # two exact sub-2^62 steps: t[i]·w^(filled-1) then ·w
        block = (t[:take] * t[filled - 1]) % qq
        block = (block * step) % qq
        t[filled : filled + take] = block
        filled += take
    return t


def bit_reverse_indices(n: int) -> np.ndarray:
    logn = n.bit_length() - 1
    idx = np.arange(n)
    rev = np.zeros(n, dtype=np.int64)
    for b in range(logn):
        rev |= ((idx >> b) & 1) << (logn - 1 - b)
    return rev


def _to_mont(v: np.ndarray, q: int) -> np.ndarray:
    """Plain u64 values < q → Montgomery form (v·2^32 mod q) as uint32."""
    return (((v.astype(np.uint64)) << np.uint64(32)) % np.uint64(q)).astype(np.uint32)


def _limbs8(v: np.ndarray) -> np.ndarray:
    """(..., ) u64 values < 2^31 → (NLIMB8, ...) int32 8-bit limbs."""
    v = v.astype(np.uint64)
    return np.stack(
        [((v >> np.uint64(8 * k)) & np.uint64(0xFF)).astype(np.int32) for k in range(NLIMB8)],
        axis=0,
    )


@dataclasses.dataclass(frozen=True)
class NttPlan:
    """All tables for one ring degree N over one RNS prime chain."""

    n: int
    n1: int
    n2: int
    qs: np.ndarray  # (L,) uint32
    qinv_neg: np.ndarray  # (L,) uint32
    r2: np.ndarray  # (L,) uint32
    # --- reference (u64) tables ---
    w_pows: np.ndarray  # (L, N)  powers of w
    winv_pows: np.ndarray  # (L, N)
    psi_pows: np.ndarray  # (L, N)  twist
    psiinv_ninv: np.ndarray  # (L, N)  psi^{-i}·N^{-1}
    # --- four-step kernel tables (plain-value limb matrices + mont twiddles) ---
    v2_limbs: np.ndarray  # (L, NLIMB8, N2, N2) int32   row NTT matrix
    v1_limbs: np.ndarray  # (L, NLIMB8, N1, N1) int32   col NTT matrix
    v2i_limbs: np.ndarray
    v1i_limbs: np.ndarray
    t_mont: np.ndarray  # (L, N1, N2) uint32  inter-step twiddle w^(n1·k2)·R
    ti_mont: np.ndarray  # (L, N1, N2) uint32  inverse twiddle
    twa_mont: np.ndarray  # (L, N1, N2) uint32  fwd twist psi^(n1+N1·n2)·R in A-layout
    twia_mont: np.ndarray  # (L, N1, N2) uint32  inv twist·N^{-1} in A-layout
    c_mont: np.ndarray  # (L, NDIAG) uint32   mont form of 2^(8s)

    @property
    def num_limbs(self) -> int:
        return len(self.qs)


@functools.lru_cache(maxsize=32)
def build_plan(n: int, primes: tuple[int, ...]) -> NttPlan:
    n1, n2 = fourstep_split(n)
    L = len(primes)
    qs = np.array(primes, np.uint32)
    consts = mm.mont_constants_array(primes)

    w_pows = np.zeros((L, n), np.uint64)
    winv_pows = np.zeros((L, n), np.uint64)
    psi_pows = np.zeros((L, n), np.uint64)
    psiinv_ninv = np.zeros((L, n), np.uint64)
    v2_limbs = np.zeros((L, NLIMB8, n2, n2), np.int32)
    v1_limbs = np.zeros((L, NLIMB8, n1, n1), np.int32)
    v2i_limbs = np.zeros((L, NLIMB8, n2, n2), np.int32)
    v1i_limbs = np.zeros((L, NLIMB8, n1, n1), np.int32)
    t_mont = np.zeros((L, n1, n2), np.uint32)
    ti_mont = np.zeros((L, n1, n2), np.uint32)
    twa_mont = np.zeros((L, n1, n2), np.uint32)
    twia_mont = np.zeros((L, n1, n2), np.uint32)
    c_mont = np.zeros((L, NDIAG), np.uint32)

    i1 = np.arange(n1)
    i2 = np.arange(n2)
    for li, q in enumerate(primes):
        psi = mm.root_of_unity(2 * n, q)
        psi_inv = pow(psi, -1, q)
        w = psi * psi % q
        w_inv = pow(w, -1, q)
        n_inv = pow(n, -1, q)

        wp = _pow_table(w, n, q)
        wip = _pow_table(w_inv, n, q)
        pp = _pow_table(psi, n, q)
        pip = _pow_table(psi_inv, n, q)
        w_pows[li] = wp
        winv_pows[li] = wip
        psi_pows[li] = pp
        psiinv_ninv[li] = (pip * np.uint64(n_inv)) % np.uint64(q)

        # V matrices: V2[a, b] = w_{N2}^(a·b);   w_{N2} = w^(N/N2)
        e2 = (np.outer(i2, i2) % n2).astype(np.int64)
        e1 = (np.outer(i1, i1) % n1).astype(np.int64)
        w2p = _pow_table(pow(w, n // n2, q), n2, q)
        w1p = _pow_table(pow(w, n // n1, q), n1, q)
        w2ip = _pow_table(pow(w_inv, n // n2, q), n2, q)
        w1ip = _pow_table(pow(w_inv, n // n1, q), n1, q)
        v2_limbs[li] = _limbs8(w2p[e2])
        v1_limbs[li] = _limbs8(w1p[e1])
        v2i_limbs[li] = _limbs8(w2ip[e2])
        v1i_limbs[li] = _limbs8(w1ip[e1])

        # inter-step twiddles T[n1,k2] = w^(n1·k2)
        et = (np.outer(i1, i2) % n).astype(np.int64)
        t_mont[li] = _to_mont(wp[et], q)
        ti_mont[li] = _to_mont(wip[et], q)

        # twists in A-layout: A[a, b] ↔ coefficient index a + N1·b
        idx_a = (i1[:, None] + n1 * i2[None, :]) % n
        twa_mont[li] = _to_mont(pp[idx_a], q)
        twia_mont[li] = _to_mont(((pip[idx_a] * np.uint64(n_inv)) % np.uint64(q)), q)

        c_mont[li] = _to_mont(
            np.array([(1 << (8 * s)) % q for s in range(NDIAG)], np.uint64), q
        )

    return NttPlan(
        n=n,
        n1=n1,
        n2=n2,
        qs=qs,
        qinv_neg=consts["qinv_neg"],
        r2=consts["r2"],
        w_pows=w_pows,
        winv_pows=winv_pows,
        psi_pows=psi_pows,
        psiinv_ninv=psiinv_ninv,
        v2_limbs=v2_limbs,
        v1_limbs=v1_limbs,
        v2i_limbs=v2i_limbs,
        v1i_limbs=v1i_limbs,
        t_mont=t_mont,
        ti_mont=ti_mont,
        twa_mont=twa_mont,
        twia_mont=twia_mont,
        c_mont=c_mont,
    )


_PER_LIMB_FIELDS = (
    "qs", "qinv_neg", "r2", "w_pows", "winv_pows", "psi_pows", "psiinv_ninv",
    "v2_limbs", "v1_limbs", "v2i_limbs", "v1i_limbs",
    "t_mont", "ti_mont", "twa_mont", "twia_mont", "c_mont",
)


@functools.lru_cache(maxsize=1024)
def subplan(n: int, primes: tuple[int, ...], idx: tuple[int, ...]) -> NttPlan:
    """A view of build_plan(n, primes) restricted to the limb subset ``idx``.

    Ciphertexts live on arbitrary sub-chains of the master prime chain (levels,
    key-switch digits, the special-modulus block); this selects the matching
    rows of every per-limb table.  Cached — the set of distinct subsets during a
    workload is O(L·dnum).
    """
    base = build_plan(n, primes)
    sel = np.array(idx, np.int64)
    return dataclasses.replace(base, **{f: getattr(base, f)[sel] for f in _PER_LIMB_FIELDS})


def galois_eval_perm(n: int, t: int) -> np.ndarray:
    """Permutation p with NTT(σ_t(a))[j] = NTT(a)[p[j]] (natural slot order).

    σ_t : a(x) → a(x^t), t odd.  Slot j evaluates at psi^(2j+1), so
    σ_t(a)(psi^(2j+1)) = a(psi^(t(2j+1))) = slot ((t(2j+1) mod 2N) - 1)/2 of a.
    """
    assert t % 2 == 1
    j = np.arange(n, dtype=np.int64)
    src = ((t * (2 * j + 1)) % (2 * n) - 1) // 2
    return src.astype(np.int32)


def galois_coeff_map(n: int, t: int) -> tuple[np.ndarray, np.ndarray]:
    """Coefficient-domain σ_t: out[(t·i mod 2N) fold] = sign·a[i].

    Returns (dst_index, sign) arrays over source index i; sign ∈ {+1 (0), -1 (1)}.
    """
    i = np.arange(n, dtype=np.int64)
    e = (t * i) % (2 * n)
    dst = np.where(e < n, e, e - n)
    neg = (e >= n).astype(np.int64)
    return dst.astype(np.int32), neg.astype(np.int32)
