"""Polynomial-domain helpers shared by the CKKS ops.

A polynomial is a (limbs, N) uint32 jnp array of RNS residues, either in
coefficient domain or evaluation (NTT) domain.  Which master-chain limbs a
tensor carries is tracked by the caller via index tuples from `q_idx`/`ext_idx`;
NTT plans restricted to those limbs come from `fhe.ntt.subplan`.

Every domain crossing records an instruction into the ambient trace — these are
exactly the (i)NTT pipeline occupancies the core scheduler/simulator replays.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.kernels.ntt import ops as ntt_ops

from . import ntt as nttmod
from . import trace
from .params import CkksParams


def q_idx(params: CkksParams, level: int) -> tuple[int, ...]:
    """Master-chain indices of the ciphertext basis at ``level``."""
    return tuple(range(level + 1))

def p_idx(params: CkksParams) -> tuple[int, ...]:
    """Master-chain indices of the special (key) modulus block."""
    return tuple(range(params.L + 1, params.L + 1 + params.alpha))

def ext_idx(params: CkksParams, level: int) -> tuple[int, ...]:
    """Extended basis {q_0..q_level} ∪ {p_0..p_α-1}."""
    return q_idx(params, level) + p_idx(params)


@functools.lru_cache(maxsize=4096)
def plan_for(params: CkksParams, idx: tuple[int, ...]) -> nttmod.NttPlan:
    return nttmod.subplan(params.n, params.all_primes, idx)


def primes_for(params: CkksParams, idx: tuple[int, ...]) -> tuple[int, ...]:
    allp = params.all_primes
    return tuple(allp[i] for i in idx)


def to_eval(x, params: CkksParams, idx: tuple[int, ...], backend: str = "auto"):
    """Coefficient → evaluation domain over the limb subset ``idx``."""
    trace.record("NTT", params.n, len(idx))
    return ntt_ops.ntt_fwd(jnp.asarray(x, jnp.uint32), plan_for(params, idx), backend)


def to_coeff(x, params: CkksParams, idx: tuple[int, ...], backend: str = "auto"):
    """Evaluation → coefficient domain over the limb subset ``idx``."""
    trace.record("INTT", params.n, len(idx))
    return ntt_ops.ntt_inv(jnp.asarray(x, jnp.uint32), plan_for(params, idx), backend)


@functools.lru_cache(maxsize=512)
def _eval_perm(n: int, t: int):
    return jnp.asarray(nttmod.galois_eval_perm(n, t))


def automorphism_eval(x, n: int, t: int):
    """σ_t in the evaluation domain — a pure slot permutation (paper's AUTO unit)."""
    trace.record("AUTO", n, x.shape[-2] if x.ndim >= 2 else 1)
    return jnp.take(x, _eval_perm(n, t), axis=-1)


def sample_ternary(rng: np.random.Generator, n: int, h: int) -> np.ndarray:
    """Ternary secret with hamming weight h (int64 coefficients in {-1,0,1})."""
    s = np.zeros(n, np.int64)
    pos = rng.choice(n, size=h, replace=False)
    s[pos] = rng.choice(np.array([-1, 1]), size=h)
    return s


def sample_gaussian(rng: np.random.Generator, n: int, sigma: float = 3.2) -> np.ndarray:
    return np.rint(rng.normal(0.0, sigma, size=n)).astype(np.int64)


def to_rns_signed(v: np.ndarray, primes) -> np.ndarray:
    """Signed int64 coefficients → (limbs, N) uint32 residues."""
    out = np.empty((len(primes), v.shape[-1]), np.uint32)
    for i, p in enumerate(primes):
        out[i] = np.mod(v, np.int64(p)).astype(np.uint32)
    return out
