"""CKKS bootstrapping: ModRaise → CoeffToSlot → EvalMod → SlotToCoeff.

Full-slot ("packed") bootstrapping per the paper's Packed Bootstrapping
workload: all N/2 slots are used, so CoeffToSlot produces two ciphertexts
(first/second half of the coefficient vector) and EvalMod runs on both.

The homomorphic pipeline here is exactly the instruction mix the paper's
bootstrappable clusters are provisioned for: BSGS rotations (key-switch =
iNTT→BConv→NTT) dominate CtS/StC, and EvalMod is a Chebyshev ladder of
ct×ct multiplications (each with a relinearisation key-switch).

Math summary (DESIGN.md §6): with E0[j,i] = ζ^{g_j·i} (i < n), E1 the second
half, and z = slots of the ModRaise'd ciphertext, the coefficient halves are
a0 = Re(A0·z), a1 = Re(A1·z) with A{0,1} = (2/N)·E{0,1}^H.  EvalMod applies
(q0/2πΔ)·sin(2π·a/q0) via Chebyshev on [-(K+½)θ, (K+½)θ], θ = q0/Δ.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from . import encoder, linear, ops, poly, polyeval, trace
from .keys import KeySet, full_keyset
from .params import CkksParams


@functools.lru_cache(maxsize=8)
def _cts_matrices(n: int) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(A0, A1) coeff-extraction and (E0, E1) slot-restoration matrices."""
    slots = n // 2
    zeta, s2n, _ = encoder._tables(n)
    g = 2 * s2n + 1  # generator exponents
    i0 = np.arange(slots)
    E0 = np.exp(1j * np.pi * np.outer(g, i0) / n)  # (slots, slots): ζ^{g_j·i}
    E1 = np.exp(1j * np.pi * np.outer(g, i0 + slots) / n)
    A0 = (2.0 / n) * E0.conj().T
    A1 = (2.0 / n) * E1.conj().T
    return A0, A1, E0, E1


@dataclasses.dataclass
class BootstrapContext:
    params: CkksParams  # the (large-L) bootstrapping parameter set
    keys: KeySet
    cts_plans: tuple[linear.BsgsPlan, linear.BsgsPlan]
    stc_plans: tuple[linear.BsgsPlan, linear.BsgsPlan]
    sine_coeffs: np.ndarray
    K: int
    eval_mod_degree: int
    galois_rotations: tuple[int, ...] = ()  # precomputed per-plan rotation union

    @property
    def depth(self) -> int:
        """Levels consumed: CtS(1) + normalise(1) + Chebyshev + StC(1)."""
        d = self.eval_mod_degree
        k = 1
        while k * k < d + 1:
            k *= 2
        cheb_depth = int(np.ceil(np.log2(k))) + max(0, int(np.ceil(np.log2((d + 1) / k)))) + 2
        return 3 + cheb_depth


def build_context(
    params: CkksParams,
    seed: int = 0,
    K: int | None = None,
    degree: int | None = None,
    h: int | None = None,
) -> BootstrapContext:
    """Precompute matrices, sine approximation and every needed Galois key."""
    n = params.n
    if h is None:
        h = min(192, n // 4)
    if K is None:
        K = max(8, int(np.ceil(1.3 * np.sqrt(h))))
    if degree is None:
        degree = _default_degree(K)

    A0, A1, E0, E1 = _cts_matrices(n)
    cts_plans = (linear.plan_matrix(A0), linear.plan_matrix(A1))
    stc_plans = (linear.plan_matrix(E0), linear.plan_matrix(E1))

    # EvalMod target: h(x) = (q0/Δ)·sin(2π·(K+½)·x)/(2π) fitted on [-1, 1];
    # input is a/q0 normalised by (K+½)·θ with θ = q0/Δ.
    q0 = float(params.q_primes[0])
    theta = q0 / params.scale
    c = 2.0 * np.pi * (K + 0.5)
    f = lambda x: (q0 / params.scale) * np.sin(c * x) / (2.0 * np.pi)
    coeffs = polyeval.chebyshev_fit(f, degree)

    # precompute the union of Galois rotations across every BSGS plan ONCE
    # (plan.rotations() is cached per plan) so keygen generates exactly one
    # switching key per needed Galois element — no over-generation
    rots = set()
    for p in (*cts_plans, *stc_plans):
        rots |= p.rotations()
    rotations = tuple(sorted(rots))
    keys = full_keyset(params, seed=seed, rotations=rotations, conjugate=True, h=h)
    return BootstrapContext(
        params=params, keys=keys, cts_plans=cts_plans, stc_plans=stc_plans,
        sine_coeffs=coeffs, K=K, eval_mod_degree=degree, galois_rotations=rotations,
    )


def _default_degree(K: int) -> int:
    """Chebyshev degree for sin(2π(K+½)x): Bessel decay sets ~1.3·c + margin."""
    c = 2.0 * np.pi * (K + 0.5)
    return int(np.ceil(1.25 * c + 12))


def mod_raise(ctx: BootstrapContext, ct: ops.Ciphertext, backend: str = "auto") -> ops.Ciphertext:
    """Level-0 ciphertext → top level; plaintext becomes m + q0·I."""
    params = ctx.params
    assert ct.level == 0, "mod_raise expects an exhausted (level-0) ciphertext"
    q0 = int(params.q_primes[0])
    L = params.L
    trace.record("MODRAISE", params.n, L + 1)
    bk = ops._stage(backend)

    def raise_poly(c_eval):
        c = poly.to_coeff(c_eval, params, (0,), bk)  # (1, N) residues mod q0
        v = np.asarray(c[0], np.uint64)
        centered = v.astype(np.int64) - np.where(v > q0 // 2, q0, 0)
        rns = poly.to_rns_signed(centered, params.q_primes)
        return poly.to_eval(rns, params, poly.q_idx(params, L), bk)

    return ops.Ciphertext(
        c0=raise_poly(ct.c0), c1=raise_poly(ct.c1), level=L, scale=ct.scale
    )


def coeff_to_slot(ctx: BootstrapContext, ct: ops.Ciphertext, backend: str = "auto",
                  hoisting: str = "auto") -> tuple[ops.Ciphertext, ops.Ciphertext]:
    """Slots become the coefficient halves a0, a1 (each real).

    Both BSGS transforms hoist their baby-step rotations per group
    (``hoisting`` threads through to ``linear.apply_bsgs``)."""
    p, keys = ctx.params, ctx.keys
    u0 = linear.apply_bsgs(p, ct, ctx.cts_plans[0], keys, backend=backend, hoisting=hoisting)
    u1 = linear.apply_bsgs(p, ct, ctx.cts_plans[1], keys, backend=backend, hoisting=hoisting)
    return linear.real_part(p, u0, keys, backend), linear.real_part(p, u1, keys, backend)


def eval_mod(ctx: BootstrapContext, ct: ops.Ciphertext, coeff_scale: float,
             backend: str = "auto") -> ops.Ciphertext:
    """Remove the q0·I component: slot values v = a/coeff_scale → (q0/Δ)·sin(2π·a/q0)/(2π) ≈ m/Δ.

    ``coeff_scale`` is the ModRaise'd ciphertext's scale — the factor relating
    the CtS slot *values* to the underlying integer coefficients a (homomorphic
    ops preserve values, so the CtS output's own bookkeeping scale is NOT it).
    """
    p, keys = ctx.params, ctx.keys
    q0 = float(p.q_primes[0])
    norm = coeff_scale / ((ctx.K + 0.5) * q0)  # v·norm = a/((K+½)·q0) ∈ [-1, 1]
    # exact-scale normalisation: seeds the Chebyshev tree at scale Δ so the
    # multiplicative scale-doubling dynamics stay bounded
    x = ops.mul_const_exact(p, ct, norm, p.scale, backend)
    basis = polyeval.ChebyshevBasis(p, x, keys, ctx.eval_mod_degree, backend)
    return polyeval.eval_chebyshev(p, basis, ctx.sine_coeffs, keys, backend)


def slot_to_coeff(ctx: BootstrapContext, a0: ops.Ciphertext, a1: ops.Ciphertext,
                  backend: str = "auto", hoisting: str = "auto") -> ops.Ciphertext:
    p, keys = ctx.params, ctx.keys
    v0 = linear.apply_bsgs(p, a0, ctx.stc_plans[0], keys, backend=backend, hoisting=hoisting)
    v1 = linear.apply_bsgs(p, a1, ctx.stc_plans[1], keys, backend=backend, hoisting=hoisting)
    return polyeval.add_any(p, v0, v1, backend)


def bootstrap(
    ctx: BootstrapContext, ct: ops.Ciphertext, post_scale: float | None = None,
    backend: str = "auto", hoisting: str = "auto",
) -> ops.Ciphertext:
    """Refresh an exhausted ciphertext to level L − depth.

    ``post_scale``: uniform-prime adaptation (DESIGN.md §6) — with 30-bit q0 ≈ Δ
    the message must enter bootstrapping attenuated (|m| ≪ q0); the caller
    divides before exhaustion and passes the same factor here to restore it.
    ``backend`` selects the key-switch pipeline for every rotation/relin inside
    (see ``keyswitch.resolve_pipeline``); ``hoisting`` selects whether CtS/StC
    baby-step groups share one ModUp per group (bit-exact either way).
    """
    trace.record("BOOTSTRAP_BEGIN", ctx.params.n, ctx.params.L + 1)
    in_scale = ct.scale
    raised = mod_raise(ctx, ct, backend)
    a0, a1 = coeff_to_slot(ctx, raised, backend, hoisting)
    m0 = eval_mod(ctx, a0, raised.scale, backend)
    m1 = eval_mod(ctx, a1, raised.scale, backend)
    out = slot_to_coeff(ctx, m0, m1, backend, hoisting)
    # amplitude bookkeeping: the sine was fitted for input scale = params.scale
    out = ops.Ciphertext(out.c0, out.c1, out.level, out.scale * in_scale / ctx.params.scale)
    if post_scale is not None:
        out = ops.mul_const(ctx.params, out, float(post_scale), rescale_after=True, backend=backend)
    trace.record("BOOTSTRAP_END", ctx.params.n, out.level + 1)
    return out
