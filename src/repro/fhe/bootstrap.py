"""CKKS bootstrapping: ModRaise → CoeffToSlot → EvalMod → SlotToCoeff.

Full-slot ("packed") bootstrapping per the paper's Packed Bootstrapping
workload: all N/2 slots are used, so CoeffToSlot produces two ciphertexts
(first/second half of the coefficient vector) and EvalMod runs on both.

The homomorphic pipeline here is exactly the instruction mix the paper's
bootstrappable clusters are provisioned for: BSGS rotations (key-switch =
iNTT→BConv→NTT) dominate CtS/StC, and EvalMod is a Chebyshev ladder of
ct×ct multiplications (each with a relinearisation key-switch).

Math summary (DESIGN.md §6): with E0[j,i] = ζ^{g_j·i} (i < n), E1 the second
half, and z = slots of the ModRaise'd ciphertext, the coefficient halves are
a0 = Re(A0·z), a1 = Re(A1·z) with A{0,1} = (2/N)·E{0,1}^H.  EvalMod applies
(q0/2πΔ)·sin(2π·a/q0) via Chebyshev on [-(K+½)θ, (K+½)θ], θ = q0/Δ.

``BootstrapContext`` holds the precomputes (params, keys, BSGS plans, sine
coefficients); *how* to execute comes from an ``FheContext``:
``fhe_ctx.bootstrap(bctx, ct)`` is the primary API, with the policy choosing
the key-switch pipeline and whether CtS/StC baby groups hoist.  The
``backend=``/``hoisting=``-kwarg free functions were retired
(docs/context_api.md).
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from . import encoder, linear, ops, poly, polyeval, trace
from .keys import KeySet, full_keyset
from .params import CkksParams


@functools.lru_cache(maxsize=8)
def _cts_matrices(n: int) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(A0, A1) coeff-extraction and (E0, E1) slot-restoration matrices."""
    slots = n // 2
    zeta, s2n, _ = encoder._tables(n)
    g = 2 * s2n + 1  # generator exponents
    i0 = np.arange(slots)
    E0 = np.exp(1j * np.pi * np.outer(g, i0) / n)  # (slots, slots): ζ^{g_j·i}
    E1 = np.exp(1j * np.pi * np.outer(g, i0 + slots) / n)
    A0 = (2.0 / n) * E0.conj().T
    A1 = (2.0 / n) * E1.conj().T
    return A0, A1, E0, E1


@dataclasses.dataclass
class BootstrapContext:
    params: CkksParams  # the (large-L) bootstrapping parameter set
    keys: KeySet
    cts_plans: tuple[linear.BsgsPlan, linear.BsgsPlan]
    stc_plans: tuple[linear.BsgsPlan, linear.BsgsPlan]
    sine_coeffs: np.ndarray
    K: int
    eval_mod_degree: int
    galois_rotations: tuple[int, ...] = ()  # precomputed per-plan rotation union

    @property
    def depth(self) -> int:
        """Levels consumed: CtS(1) + normalise(1) + Chebyshev + StC(1)."""
        d = self.eval_mod_degree
        k = 1
        while k * k < d + 1:
            k *= 2
        cheb_depth = int(np.ceil(np.log2(k))) + max(0, int(np.ceil(np.log2((d + 1) / k)))) + 2
        return 3 + cheb_depth


def build_context(
    params: CkksParams,
    seed: int = 0,
    K: int | None = None,
    degree: int | None = None,
    h: int | None = None,
) -> BootstrapContext:
    """Precompute matrices, sine approximation and every needed Galois key."""
    n = params.n
    if h is None:
        h = min(192, n // 4)
    if K is None:
        K = max(8, int(np.ceil(1.3 * np.sqrt(h))))
    if degree is None:
        degree = _default_degree(K)

    A0, A1, E0, E1 = _cts_matrices(n)
    cts_plans = (linear.plan_matrix(A0), linear.plan_matrix(A1))
    stc_plans = (linear.plan_matrix(E0), linear.plan_matrix(E1))

    # EvalMod target: h(x) = (q0/Δ)·sin(2π·(K+½)·x)/(2π) fitted on [-1, 1];
    # input is a/q0 normalised by (K+½)·θ with θ = q0/Δ.
    q0 = float(params.q_primes[0])
    c = 2.0 * np.pi * (K + 0.5)
    f = lambda x: (q0 / params.scale) * np.sin(c * x) / (2.0 * np.pi)
    coeffs = polyeval.chebyshev_fit(f, degree)

    # precompute the union of Galois rotations across every BSGS plan ONCE
    # (plan.rotations() is cached per plan) so keygen generates exactly one
    # switching key per needed Galois element — no over-generation
    rots = set()
    for p in (*cts_plans, *stc_plans):
        rots |= p.rotations()
    rotations = tuple(sorted(rots))
    keys = full_keyset(params, seed=seed, rotations=rotations, conjugate=True, h=h)
    return BootstrapContext(
        params=params, keys=keys, cts_plans=cts_plans, stc_plans=stc_plans,
        sine_coeffs=coeffs, K=K, eval_mod_degree=degree, galois_rotations=rotations,
    )


def _default_degree(K: int) -> int:
    """Chebyshev degree for sin(2π(K+½)x): Bessel decay sets ~1.3·c + margin."""
    c = 2.0 * np.pi * (K + 0.5)
    return int(np.ceil(1.25 * c + 12))


# ---------------------------------------------------------------------------
# context implementations (fc: FheContext over bctx.params/bctx.keys)
# ---------------------------------------------------------------------------


def _mod_raise(fc, bctx: BootstrapContext, ct: ops.Ciphertext) -> ops.Ciphertext:
    """Level-0 ciphertext → top level; plaintext becomes m + q0·I."""
    params = bctx.params
    assert ct.level == 0, "mod_raise expects an exhausted (level-0) ciphertext"
    q0 = int(params.q_primes[0])
    L = params.L
    trace.record("MODRAISE", params.n, L + 1)
    bk = fc.stage

    def raise_poly(c_eval):
        c = poly.to_coeff(c_eval, params, (0,), bk)  # (1, N) residues mod q0
        v = np.asarray(c[0], np.uint64)
        centered = v.astype(np.int64) - np.where(v > q0 // 2, q0, 0)
        rns = poly.to_rns_signed(centered, params.q_primes)
        return poly.to_eval(rns, params, poly.q_idx(params, L), bk)

    return ops.Ciphertext(
        c0=raise_poly(ct.c0), c1=raise_poly(ct.c1), level=L, scale=ct.scale
    )


def _coeff_to_slot(fc, bctx: BootstrapContext,
                   ct: ops.Ciphertext) -> tuple[ops.Ciphertext, ops.Ciphertext]:
    """Slots become the coefficient halves a0, a1 (each real).

    Both BSGS transforms hoist their baby-step rotations per group when the
    policy's hoisting mode allows (see ``linear._apply_bsgs``)."""
    u0 = linear._apply_bsgs(fc, ct, bctx.cts_plans[0])
    u1 = linear._apply_bsgs(fc, ct, bctx.cts_plans[1])
    return linear._real_part(fc, u0), linear._real_part(fc, u1)


def _eval_mod(fc, bctx: BootstrapContext, ct: ops.Ciphertext,
              coeff_scale: float) -> ops.Ciphertext:
    """Remove the q0·I component: slot values v = a/coeff_scale → (q0/Δ)·sin(2π·a/q0)/(2π) ≈ m/Δ.

    ``coeff_scale`` is the ModRaise'd ciphertext's scale — the factor relating
    the CtS slot *values* to the underlying integer coefficients a (homomorphic
    ops preserve values, so the CtS output's own bookkeeping scale is NOT it).
    """
    p = bctx.params
    q0 = float(p.q_primes[0])
    norm = coeff_scale / ((bctx.K + 0.5) * q0)  # v·norm = a/((K+½)·q0) ∈ [-1, 1]
    # exact-scale normalisation: seeds the Chebyshev tree at scale Δ so the
    # multiplicative scale-doubling dynamics stay bounded
    x = ops._mul_const_exact(fc, ct, norm, p.scale)
    basis = polyeval.ChebyshevBasis(fc, x, bctx.eval_mod_degree)
    return polyeval._eval_chebyshev(fc, basis, bctx.sine_coeffs)


def _slot_to_coeff(fc, bctx: BootstrapContext, a0: ops.Ciphertext,
                   a1: ops.Ciphertext) -> ops.Ciphertext:
    v0 = linear._apply_bsgs(fc, a0, bctx.stc_plans[0])
    v1 = linear._apply_bsgs(fc, a1, bctx.stc_plans[1])
    return polyeval._add_any(fc, v0, v1)


def _bootstrap(fc, bctx: BootstrapContext, ct: ops.Ciphertext,
               post_scale: float | None = None) -> ops.Ciphertext:
    """Refresh an exhausted ciphertext to level L − depth.

    ``post_scale``: uniform-prime adaptation (DESIGN.md §6) — with 30-bit q0 ≈ Δ
    the message must enter bootstrapping attenuated (|m| ≪ q0); the caller
    divides before exhaustion and passes the same factor here to restore it.
    The policy on ``fc`` selects the key-switch pipeline for every
    rotation/relin inside and whether CtS/StC baby-step groups share one ModUp
    per group (bit-exact either way).
    """
    trace.record("BOOTSTRAP_BEGIN", bctx.params.n, bctx.params.L + 1)
    in_scale = ct.scale
    raised = _mod_raise(fc, bctx, ct)
    a0, a1 = _coeff_to_slot(fc, bctx, raised)
    m0 = _eval_mod(fc, bctx, a0, raised.scale)
    m1 = _eval_mod(fc, bctx, a1, raised.scale)
    out = _slot_to_coeff(fc, bctx, m0, m1)
    # amplitude bookkeeping: the sine was fitted for input scale = params.scale
    out = ops.Ciphertext(out.c0, out.c1, out.level, out.scale * in_scale / bctx.params.scale)
    if post_scale is not None:
        out = ops._mul_const(fc, out, float(post_scale), rescale_after=True)
    trace.record("BOOTSTRAP_END", bctx.params.n, out.level + 1)
    return out

