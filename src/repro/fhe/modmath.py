"""Modular arithmetic for RNS-CKKS, twice:

1. ``*_u64`` — exact uint64 jnp arithmetic.  The oracle path (requires x64; enabled by
   ``repro.fhe``).  Used by kernel ``ref.py`` oracles and host-side precomputation.

2. ``*_u32`` — TPU-native path.  TPUs have no 64-bit integer datapath, so every product
   is built from 16-bit limbs in uint32 (``mulhi32``) and reduced with Montgomery
   multiplication (R = 2^32, primes q < 2^31).  This is what the Pallas kernels use —
   inside a kernel *and* as plain jnp (the functions are dtype-pure and jit/pallas
   compatible).

Host-side (Python int) utilities generate NTT-friendly primes (q ≡ 1 mod 2^(log2N+1))
and roots of unity.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

MASK16 = jnp.uint32(0xFFFF)
U32_MOD = 1 << 32


# ---------------------------------------------------------------------------
# Host-side integer number theory (Python ints; runs once at parameter build)
# ---------------------------------------------------------------------------

_MR_BASES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)  # deterministic < 3.3e24


def is_prime(n: int) -> bool:
    if n < 2:
        return False
    for p in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        if n % p == 0:
            return n == p
    d, r = n - 1, 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for a in _MR_BASES:
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(r - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def gen_ntt_primes(nbits: int, count: int, two_n: int, skip: tuple[int, ...] = ()) -> list[int]:
    """``count`` primes of ~``nbits`` bits with q ≡ 1 (mod two_n), descending from 2^nbits.

    ``two_n`` should be 2N for the largest supported ring degree so the same primes work
    for every smaller power-of-two ring.
    """
    assert nbits < 31, "u32 Montgomery path requires q < 2^31"
    out: list[int] = []
    q = (1 << nbits) + 1
    # descend over the arithmetic progression 1 mod two_n
    q -= (q - 1) % two_n
    while len(out) < count:
        if q < (1 << (nbits - 1)):
            raise ValueError(f"not enough {nbits}-bit NTT primes for 2N={two_n}")
        if q not in skip and is_prime(q):
            out.append(q)
        q -= two_n
    return out


def find_primitive_root(q: int) -> int:
    """Smallest primitive root of prime q."""
    phi = q - 1
    factors = set()
    n = phi
    d = 2
    while d * d <= n:
        while n % d == 0:
            factors.add(d)
            n //= d
        d += 1
    if n > 1:
        factors.add(n)
    for g in range(2, q):
        if all(pow(g, phi // f, q) != 1 for f in factors):
            return g
    raise ValueError(f"no primitive root for {q}")


@functools.lru_cache(maxsize=None)
def root_of_unity(order: int, q: int) -> int:
    """A primitive ``order``-th root of unity mod prime q (order | q-1)."""
    assert (q - 1) % order == 0, f"{order} does not divide {q}-1"
    g = find_primitive_root(q)
    w = pow(g, (q - 1) // order, q)
    assert pow(w, order, q) == 1 and pow(w, order // 2, q) == q - 1
    return w


# ---------------------------------------------------------------------------
# Montgomery constants (host-side, per prime)
# ---------------------------------------------------------------------------


class MontConstants:
    """Per-prime Montgomery constants for the u32 path (R = 2^32)."""

    __slots__ = ("q", "qinv_neg", "r1", "r2")

    def __init__(self, q: int):
        assert q % 2 == 1 and q < (1 << 31)
        self.q = q
        self.qinv_neg = (-pow(q, -1, U32_MOD)) % U32_MOD  # -q^{-1} mod 2^32
        self.r1 = U32_MOD % q  # R mod q   (Montgomery form of 1)
        self.r2 = (U32_MOD * U32_MOD) % q  # R^2 mod q (to_mont multiplier)

    def to_mont_int(self, a: int) -> int:
        return (a << 32) % self.q


def mont_constants_array(qs) -> dict[str, np.ndarray]:
    cs = [MontConstants(int(q)) for q in qs]
    return {
        "q": np.array([c.q for c in cs], np.uint32),
        "qinv_neg": np.array([c.qinv_neg for c in cs], np.uint32),
        "r1": np.array([c.r1 for c in cs], np.uint32),
        "r2": np.array([c.r2 for c in cs], np.uint32),
    }


# ---------------------------------------------------------------------------
# uint64 oracle path
# ---------------------------------------------------------------------------


def add_mod_u64(a, b, q):
    a = jnp.asarray(a, jnp.uint64)
    b = jnp.asarray(b, jnp.uint64)
    q = jnp.asarray(q, jnp.uint64)
    s = a + b
    return jnp.where(s >= q, s - q, s)


def sub_mod_u64(a, b, q):
    a = jnp.asarray(a, jnp.uint64)
    b = jnp.asarray(b, jnp.uint64)
    q = jnp.asarray(q, jnp.uint64)
    return jnp.where(a >= b, a - b, a + q - b)


def mul_mod_u64(a, b, q):
    """(a*b) mod q for q < 2^31 — the 62-bit product is exact in uint64."""
    a = jnp.asarray(a, jnp.uint64)
    b = jnp.asarray(b, jnp.uint64)
    q = jnp.asarray(q, jnp.uint64)
    return (a * b) % q


# ---------------------------------------------------------------------------
# uint32 TPU-native path
# ---------------------------------------------------------------------------


def mulhi32(a, b):
    """High 32 bits of the 64-bit product of two uint32, using only uint32 ops.

    Schoolbook over 16-bit limbs; every intermediate provably fits uint32.
    """
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    al = a & MASK16
    ah = a >> 16
    bl = b & MASK16
    bh = b >> 16
    t = al * bl
    u = ah * bl + (t >> 16)  # ≤ (2^16-1)^2 + (2^16-1) < 2^32
    v = al * bh + (u & MASK16)  # same bound
    return ah * bh + (u >> 16) + (v >> 16)


def mont_mul_u32(a, b, q, qinv_neg):
    """Montgomery product a·b·R^{-1} mod q (R = 2^32, q < 2^31, odd).

    All inputs uint32 (broadcastable).  Output in [0, q).
    """
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    q = q.astype(jnp.uint32)
    qinv_neg = qinv_neg.astype(jnp.uint32)
    t_lo = a * b  # low 32 bits (wrap)
    t_hi = mulhi32(a, b)
    m = t_lo * qinv_neg  # wrap; m = t_lo * (-q^{-1}) mod 2^32
    mq_hi = mulhi32(m, q)
    # t + m*q ≡ 0 mod 2^32 by construction ⇒ low word of the sum is zero and the
    # carry into the high word is 1 unless t_lo == 0.
    carry = (t_lo != 0).astype(jnp.uint32)
    res = t_hi + mq_hi + carry  # < 2q < 2^32
    return jnp.where(res >= q, res - q, res)


def add_mod_u32(a, b, q):
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    q = q.astype(jnp.uint32)
    s = a + b  # < 2q < 2^32
    return jnp.where(s >= q, s - q, s)


def sub_mod_u32(a, b, q):
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    q = q.astype(jnp.uint32)
    return jnp.where(a >= b, a - b, a + q - b)


def to_mont_u32(a, q, qinv_neg, r2):
    """a → a·R mod q."""
    return mont_mul_u32(a, jnp.asarray(r2, jnp.uint32), q, qinv_neg)


def from_mont_u32(a, q, qinv_neg):
    """a·R → a mod q (montmul by 1)."""
    return mont_mul_u32(a, jnp.ones((), jnp.uint32), q, qinv_neg)


def mul_mod_u32(a, b, q, qinv_neg, r2):
    """Plain (a*b) mod q via two Montgomery multiplies (variable × variable)."""
    return mont_mul_u32(mont_mul_u32(a, b, q, qinv_neg), jnp.asarray(r2, jnp.uint32), q, qinv_neg)


def pow_mod_host(base: int, exp: int, q: int) -> int:
    return pow(base, exp, q)
