"""CKKS parameter sets — the paper's crypto-parameter policy (§3.2, §6.1).

Shallow workloads: N ≤ 2^14, small L, 80-bit security (paper §6.3).
Deep workloads:   2^15 ≤ N ≤ 2^16, large L, hybrid key-switching, 128-bit.

We use ≤30-bit NTT-friendly primes (q ≡ 1 mod 2N_max) so the u32 Montgomery TPU
path stays exact (DESIGN.md §2).  Word-size assumption change: the paper's deep
workloads use 28-bit scale words; with uniform 30-bit words the L=57/L=41 chains
exceed the 128-bit logPQ budget by ~10-60%, so those two presets keep the paper's
*limb counts* (which drive the performance model) and carry check=False; logreg
and lstm fit the budget exactly with dnum=2.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from . import modmath as mm

# Paper Table 2: max log PQ at 128-bit security per log2(N).
MAX_LOGPQ_128 = {12: 101, 13: 192, 14: 399, 15: 816, 16: 1550, 17: 3125}
# 80-bit budget (paper §6.3 uses 80-bit for shallow): N/logPQ heuristic × 128/80.
MAX_LOGPQ_80 = {k: int(v * 1.6) for k, v in MAX_LOGPQ_128.items()}

PRIME_BITS = 30  # word size of the u32 Montgomery path (q < 2^31)
DEFAULT_SCALE_BITS = 30  # ≈ prime size so rescale keeps the scale stationary


@dataclasses.dataclass(frozen=True)
class CkksParams:
    """One CKKS parameter set over a shared RNS prime chain.

    q_primes[0..L] are the ciphertext chain (level ℓ uses q_primes[:ℓ+1]);
    p_primes[0..α-1] are the special (key) moduli; ⌈(L+1)/α⌉ digits of ≤ α
    primes each cover the chain for hybrid key-switching.
    """

    n: int
    L: int  # multiplicative depth of a fresh ciphertext (levels L..0)
    dnum: int
    scale_bits: int
    q_primes: tuple[int, ...]  # len L+1
    p_primes: tuple[int, ...]  # len alpha
    security_bits: int = 128
    # BGV plaintext modulus t, or None for CKKS.  Restricted to powers of two
    # dividing 2·N_MAX = 2^17: every master-chain prime satisfies q ≡ 1
    # (mod 2^17), hence q ≡ 1 (mod t) and P ≡ 1 (mod t) — modulus switching
    # and key switching then preserve the message mod t with no scale-factor
    # bookkeeping (see repro.fhe.bgv).
    plain_modulus: int | None = None

    def __post_init__(self):
        t = self.plain_modulus
        if t is not None:
            if t < 2 or (t & (t - 1)) or (2 * N_MAX) % t:
                raise ValueError(
                    f"plain_modulus {t} must be a power of two dividing 2^17 "
                    "(so every chain prime is ≡ 1 mod t)"
                )

    @property
    def scheme(self) -> str:
        """Which scheme these params encode for: "bgv" iff a plaintext modulus
        is set, "ckks" otherwise."""
        return "bgv" if self.plain_modulus is not None else "ckks"

    @property
    def alpha(self) -> int:
        return len(self.p_primes)

    @property
    def slots(self) -> int:
        return self.n // 2

    @property
    def scale(self) -> float:
        return float(2**self.scale_bits)

    @property
    def all_primes(self) -> tuple[int, ...]:
        """q chain followed by the special block — the master kernel-plan chain."""
        return self.q_primes + self.p_primes

    @property
    def log_pq(self) -> float:
        return float(sum(np.log2(np.array(self.all_primes, dtype=np.float64))))

    def digit(self, j: int) -> tuple[int, ...]:
        """Indices (into q_primes) of hybrid key-switching digit j."""
        a = self.alpha
        return tuple(range(j * a, min((j + 1) * a, self.L + 1)))

    @property
    def num_digits(self) -> int:
        return -(-(self.L + 1) // self.alpha)

    def beta(self, level: int) -> int:
        """Number of key-switch digits active at ``level``."""
        return -(-(level + 1) // self.alpha)

    def is_shallow(self) -> bool:
        """Paper §3.2: shallow ⇔ N ≤ 2^14 (no bootstrapping budget)."""
        return self.n <= 2**14

    def check_security(self) -> bool:
        logn = self.n.bit_length() - 1
        table = MAX_LOGPQ_80 if self.security_bits <= 80 else MAX_LOGPQ_128
        budget = table.get(logn)
        return budget is not None and self.log_pq <= budget


# The master ring degree all prime chains are NTT-friendly for.  Every plan for a
# smaller N reuses the same primes (q ≡ 1 mod 2^17 ⇒ ≡ 1 mod 2N for all N ≤ 2^16).
N_MAX = 1 << 16


@functools.lru_cache(maxsize=8)
def master_chain(count: int, nbits: int = PRIME_BITS) -> tuple[int, ...]:
    return tuple(mm.gen_ntt_primes(nbits, count, 2 * N_MAX))


def make_params(
    n: int,
    L: int,
    dnum: int = 1,
    scale_bits: int = DEFAULT_SCALE_BITS,
    security_bits: int = 128,
    check_security: bool = True,
    plain_modulus: int | None = None,
) -> CkksParams:
    """Build a parameter set: L+1 chain primes + α = ⌈(L+1)/dnum⌉ special primes.

    ``plain_modulus`` selects BGV over the same RNS tower (see
    ``CkksParams.scheme``); leave it ``None`` for CKKS.
    """
    alpha = -(-(L + 1) // dnum)
    chain = master_chain(L + 1 + alpha)
    p = CkksParams(
        n=n,
        L=L,
        dnum=dnum,
        scale_bits=scale_bits,
        q_primes=chain[: L + 1],
        p_primes=chain[L + 1 : L + 1 + alpha],
        security_bits=security_bits,
        plain_modulus=plain_modulus,
    )
    if check_security and not p.check_security():
        raise ValueError(
            f"params N=2^{n.bit_length()-1} L={L} dnum={dnum}: "
            f"logPQ={p.log_pq:.0f} exceeds {security_bits}-bit budget"
        )
    return p


# ---------------------------------------------------------------------------
# Paper workload presets (§6.1).
# ---------------------------------------------------------------------------


def _preset(n_log2: int, L: int, dnum: int, kind: str, sec: int = 128, check: bool = True,
            t: int | None = None) -> dict:
    return dict(n=1 << n_log2, L=L, dnum=dnum, kind=kind, sec=sec, check=check,
                scheme="bgv" if t is not None else "ckks", t=t)


WORKLOAD_PRESETS: dict[str, dict] = {
    # --- shallow CKKS: 80-bit security (paper §6.3) ---
    "matmul": _preset(13, 2, 3, "shallow", sec=80),  # Fig 1a sweet spot N=2^13
    "dblookup": _preset(14, 8, 3, "shallow", sec=80),  # Fig 1b sweet spot N=2^14
    "lola_mnist_plain": _preset(13, 6, 3, "shallow", sec=80),  # §6.1: L=6
    "lola_mnist_enc": _preset(13, 6, 3, "shallow", sec=80),
    "lola_cifar_plain": _preset(13, 7, 4, "shallow", sec=80),  # §6.1: L=7
    # --- shallow BGV: exact integer workloads (APACHE-style mixed deployments).
    #     psi: private set intersection — depth-log equality circuits over
    #     binary-packed identifiers (t=2); exact_count: private aggregation
    #     with 16-bit exact counters (t=2^16).  Both ride swift clusters.
    "psi": _preset(13, 6, 3, "shallow", sec=80, t=2),
    "exact_count": _preset(13, 4, 3, "shallow", sec=80, t=1 << 16),
    # --- deep: 128-bit; L matches the paper so limb counts (the perf driver)
    #     match; the two check=False chains exceed the budget only because of
    #     our wider 30-bit words (see module docstring).
    "packed_bootstrap": _preset(16, 57, 1, "deep", check=False),
    "resnet20": _preset(16, 41, 1, "deep", check=False),
    "lstm": _preset(16, 13, 2, "deep"),
    "logreg": _preset(16, 33, 2, "deep"),
}

SHALLOW_WORKLOADS = tuple(k for k, v in WORKLOAD_PRESETS.items() if v["kind"] == "shallow")
DEEP_WORKLOADS = tuple(k for k, v in WORKLOAD_PRESETS.items() if v["kind"] == "deep")
BGV_WORKLOADS = tuple(k for k, v in WORKLOAD_PRESETS.items() if v["scheme"] == "bgv")


def workload_params(name: str) -> CkksParams:
    cfg = WORKLOAD_PRESETS[name]
    return make_params(
        cfg["n"], cfg["L"], cfg["dnum"], security_bits=cfg["sec"], check_security=cfg["check"],
        plain_modulus=cfg["t"],
    )


def workload_kind(name: str) -> str:
    return WORKLOAD_PRESETS[name]["kind"]


def workload_scheme(name: str) -> str:
    return WORKLOAD_PRESETS[name]["scheme"]
