"""repro: FLASH-FHE on TPU — heterogeneous JAX framework for mixed FHE workloads.

Layout:
  repro.fhe        CKKS scheme (modmath/rns/ntt/keys/ops/keyswitch/bootstrap)
  repro.kernels    Pallas TPU kernels (+ jit wrappers + pure-jnp oracles)
  repro.core       the paper's contribution: heterogeneous clusters + multi-job scheduler
  repro.serve      discrete-event multi-tenant serving (§4.2 online policy, traffic, SLOs)
  repro.models     assigned LM architectures (dense / MoE / SSM / hybrid / enc-dec / VLM)
  repro.training   optimizer + train step substrate
  repro.serving    KV cache + decode substrate
  repro.distributed / repro.launch   mesh, sharding rules, dry-run
  repro.roofline   HLO-derived roofline terms
"""

__version__ = "1.0.0"
