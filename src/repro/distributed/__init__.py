"""repro.distributed"""
