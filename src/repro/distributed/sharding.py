"""Sharding rules for the multi-pod mesh (DESIGN.md §5).

Logical mesh axes:
  pod    — cross-pod pure data parallelism (gradient all-reduce, compressible)
  data   — in-pod data parallel + FSDP (weights/optimizer sharded over it)
  model  — tensor/expert/sequence parallel

Divisibility-aware rules: a tensor dim is sharded on an axis only when the
axis size divides it — configs like hymba (25 heads) or vocab 32001 fall back
to the next-best layout instead of failing to lower.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Sharding policy (perf hillclimb knob):
#   "tp"  — default: tensor-parallel over 'model', FSDP over 'data'
#   "dp"  — pure data parallel: batch over every mesh axis, weights FSDP over
#           ('data','model'); right for small models whose TP all-gathers
#           dominate (see EXPERIMENTS.md §Perf, smollm cell)
_POLICY: contextvars.ContextVar[str] = contextvars.ContextVar("shard_policy", default="tp")


@contextlib.contextmanager
def policy(name: str):
    assert name in ("tp", "dp")
    tok = _POLICY.set(name)
    try:
        yield
    finally:
        _POLICY.reset(tok)


def current_policy() -> str:
    return _POLICY.get()


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Batch-sharding axes: ('pod', 'data') when multi-pod; under the pure-DP
    policy the 'model' axis carries batch too."""
    names = ("pod", "data", "model") if _POLICY.get() == "dp" else ("pod", "data")
    return tuple(a for a in names if a in mesh.shape)


def divisible(dim: int, mesh: Mesh, *axes: str) -> bool:
    total = 1
    for a in axes:
        total *= axis_size(mesh, a)
    return dim % total == 0


def weight_spec(mesh: Mesh, shape: tuple[int, ...], tp_dim: int | None,
                fsdp_dim: int | None) -> P:
    """Spec for a weight: tensor-parallel on `tp_dim`, FSDP on `fsdp_dim`.

    Falls back to replication per-dim when sizes don't divide.  Under the
    pure-DP policy nothing is tensor-parallel; FSDP spans ('data','model').
    """
    parts: list = [None] * len(shape)
    if _POLICY.get() == "dp":
        if fsdp_dim is None:
            fsdp_dim = tp_dim
        if fsdp_dim is not None:
            if divisible(shape[fsdp_dim], mesh, "data", "model"):
                parts[fsdp_dim] = ("data", "model")
            elif divisible(shape[fsdp_dim], mesh, "data"):
                parts[fsdp_dim] = "data"
        return P(*parts)
    if tp_dim is not None and divisible(shape[tp_dim], mesh, "model"):
        parts[tp_dim] = "model"
    if fsdp_dim is not None and fsdp_dim != tp_dim and \
            divisible(shape[fsdp_dim], mesh, "data"):
        parts[fsdp_dim] = "data"
    return P(*parts)


def constrain(x, mesh: Mesh, spec: P):
    """with_sharding_constraint that silently no-ops off-mesh (CPU tests)."""
    if mesh.devices.size == 1:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_spec(mesh: Mesh, ndim: int, seq_axis: int | None = None,
               shard_seq: bool = False) -> P:
    """Activations: batch dim over ('pod','data'); optionally seq over 'model'."""
    parts: list = [None] * ndim
    parts[0] = dp_axes(mesh) or None
    if shard_seq and seq_axis is not None:
        parts[seq_axis] = "model"
    return P(*parts)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def sanitize_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop any axis assignment that doesn't divide its dimension."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, part in zip(shape, parts):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        total = 1
        for a in axes:
            total *= axis_size(mesh, a)
        out.append(part if dim % total == 0 else None)
    return P(*out)


def sanitize_tree(spec_tree, struct_tree, mesh: Mesh):
    """sanitize_spec over matching (spec, ShapeDtypeStruct) trees."""
    return jax.tree.map(
        lambda s, x: sanitize_spec(s, x.shape, mesh),
        spec_tree, struct_tree,
        is_leaf=lambda s: isinstance(s, P),
    )
