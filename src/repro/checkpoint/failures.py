"""Fault-tolerance runtime: heartbeats, straggler detection, restart policy.

On a real multi-pod deployment each host runs a HeartbeatMonitor; the trainer
loop consults it each step.  Decisions:
  * missing heartbeat > deadline       → declare host dead → restart from the
    latest committed checkpoint on the surviving mesh (elastic restore);
  * heartbeat slow but alive (straggler) → reassign its data-shard index
    (deterministic pipeline ⇒ any host can recompute any shard) and keep going;
  * repeated stragglers                 → drop-and-continue for non-critical
    (eval) jobs, quarantine list for scheduling.

Tests drive this with a fake clock; nothing here sleeps.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float = 0.0
    slow_strikes: int = 0
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, deadline: float = 60.0,
                 straggle_factor: float = 3.0, strike_limit: int = 3):
        self.hosts = {i: HostState(i) for i in range(n_hosts)}
        self.deadline = deadline
        self.straggle_factor = straggle_factor
        self.strike_limit = strike_limit
        self.median_step_time = 1.0

    def beat(self, host_id: int, now: float, step_time: float | None = None):
        h = self.hosts[host_id]
        h.last_beat = now
        if step_time is not None:
            if step_time > self.straggle_factor * self.median_step_time:
                h.slow_strikes += 1
            else:
                h.slow_strikes = max(0, h.slow_strikes - 1)

    def set_median_step_time(self, t: float):
        self.median_step_time = t

    def check(self, now: float) -> dict:
        """Returns {'dead': [...], 'stragglers': [...], 'quarantine': [...]}."""
        dead, strag, quar = [], [], []
        for h in self.hosts.values():
            if not h.alive:
                continue
            if now - h.last_beat > self.deadline:
                h.alive = False
                dead.append(h.host_id)
            elif h.slow_strikes >= self.strike_limit:
                quar.append(h.host_id)
            elif h.slow_strikes > 0:
                strag.append(h.host_id)
        return {"dead": dead, "stragglers": strag, "quarantine": quar}

    def surviving(self) -> list[int]:
        return [h.host_id for h in self.hosts.values() if h.alive]


@dataclasses.dataclass
class RestartPlan:
    """What the launcher does after a failure event."""

    restore_step: int
    new_shard_of_host: dict  # host → data-shard index (reassigned around dead hosts)
    mesh_hosts: list


def plan_restart(monitor: HeartbeatMonitor, latest_ckpt_step: int) -> RestartPlan:
    alive = monitor.surviving()
    return RestartPlan(
        restore_step=latest_ckpt_step,
        new_shard_of_host={h: i for i, h in enumerate(alive)},
        mesh_hosts=alive,
    )
