"""repro.checkpoint"""
