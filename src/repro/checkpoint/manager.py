"""Sharded, atomic, elastic checkpointing.

Layout:  <dir>/step_<N>/
           manifest.msgpack    — tree structure, shapes, dtypes, mesh shape
           shard_<host>.npz    — this host's slices of every array
           COMMIT              — written last; restore ignores dirs without it

Fault-tolerance properties:
  * atomic commit: the step directory is staged under a tmp name and renamed
    after the COMMIT marker is in place — a preempted save never corrupts the
    latest checkpoint;
  * elastic restore: the manifest stores the *global* shapes; restore slices
    them for an arbitrary target mesh/sharding (different device count than
    the writer's), so jobs can restart on a degraded or grown cluster;
  * retention: keep the last K steps.
"""

from __future__ import annotations

import os
import shutil

import jax
import msgpack
import numpy as np


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        out = {}
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
        return out
    return {prefix[:-1]: tree}


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        cur = root
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return root


def save(ckpt_dir: str, step: int, tree, keep: int = 3) -> str:
    """Write one checkpoint step (single-host writer covers the global view;
    multi-host would write per-host shard files with the same manifest)."""
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    manifest = {
        "step": step,
        "keys": list(arrays),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
    }
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in sorted(os.listdir(ckpt_dir)):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "COMMIT")):
                best = int(d.split("_")[1])
    return best


def restore(ckpt_dir: str, step: int | None = None, shardings=None):
    """Load a checkpoint; optionally place arrays with target `shardings`
    (a pytree of NamedSharding matching the saved tree) — elastic restore."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.exists(os.path.join(d, "COMMIT")):
        raise FileNotFoundError(f"checkpoint {d} has no COMMIT marker")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    with np.load(os.path.join(d, "shard_0.npz")) as z:
        flat = {k: z[k] for k in manifest["keys"]}
    tree = _unflatten(flat)
    if shardings is not None:
        flat_sh = _flatten(shardings)

        def place(path, arr):
            sharding = flat_sh.get(path)
            if sharding is None:
                return jax.numpy.asarray(arr)
            return jax.make_array_from_callback(
                arr.shape, sharding, lambda idx: arr[idx])

        tree = _unflatten({k: place(k, v) for k, v in flat.items()})
    return manifest["step"], tree
