"""Three-term roofline from a compiled dry-run artifact (no hardware needed).

  compute    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory     = HLO_bytes / (chips × HBM_bw)
  collective = collective_bytes / (chips × link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective bytes are
parsed from the optimised HLO text (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute operand sizes).  Hardware constants: TPU
v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re

from repro.core.hardware import TPU_HBM_GBPS, TPU_ICI_GBPS, TPU_PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[16,128]' → bytes.  Tuples handled by the caller via findall."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective kind over the optimised HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # match "<name> = <shape(s)> <op>(" — the op name before the paren
        m = re.search(r"=\s*(\([^)]*\)|[^\s]+)\s+([\w-]+)", ls)
        if not m:
            continue
        op = m.group(2)
        # strip fusion suffixes like all-reduce-start
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):
                base = c
                break
        if base is None or op.endswith("-done"):
            continue
        out[base] += _shape_bytes(m.group(1))
        count[base] += 1
    return {"bytes": out, "count": count, "total_bytes": sum(out.values())}


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int) -> Roofline:
    comp = flops / (chips * TPU_PEAK_FLOPS_BF16)
    mem = hbm_bytes / (chips * TPU_HBM_GBPS)
    coll = coll_bytes / (chips * TPU_ICI_GBPS)
    dominant = max((("compute", comp), ("memory", mem), ("collective", coll)),
                   key=lambda kv: kv[1])[0]
    return Roofline(flops=flops, hbm_bytes=hbm_bytes, coll_bytes=coll_bytes,
                    chips=chips, compute_s=comp, memory_s=mem,
                    collective_s=coll, dominant=dominant)


def model_flops_per_step(param_count: int, active_param_count: int,
                         tokens: int, kind: str) -> float:
    """6·N·D (train) / 2·N·D (inference) with N = active parameters."""
    n = active_param_count
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
