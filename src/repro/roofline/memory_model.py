"""Structural TPU HBM-traffic model for the roofline memory term.

Why not cost_analysis bytes: the dry-run compiles for the CPU backend, whose
"bytes accessed" counts every unfused op's operands at f32 — orders of
magnitude above what a fused TPU program moves through HBM.  The memory term
therefore comes from the program *structure* (which the compiled artifact
fixes: layer counts, remat policy, cache shapes), with explicit accounting:

train step (remat at block boundaries, AdamW f32):
  params:      read fwd + read bwd(recompute) + read update       3×4B·P
  grads:       write + read                                       2×4B·P
  adam m,v:    read + write each                                  4×4B·P
  params out:  write                                              1×4B·P
  activations: per layer one residual stream saved (remat) r/w    ~4×2B·B·S·d
  flash K/V:   re-read per q-chunk (fwd + bwd)                    2·nq·S·KV·hd·2B
  MoE:         every expert's weights stream per step (EP local)  3·E·d·f·4B/layer ×10 (fwd+bwd+opt)
prefill: params read once + activations write + KV cache write
decode:  params read once + KV cache read to t + state r/w
"""

from __future__ import annotations

from repro.models.config import ModelConfig

F32, BF16_B = 4, 2


def _attn_kv_reread_bytes(cfg: ModelConfig, b: int, s: int, q_chunk=512) -> float:
    if cfg.mixer == "mamba" or cfg.n_heads == 0:
        return 0.0
    nq = -(-s // q_chunk)
    kv_bytes = b * s * cfg.n_kv_heads * cfg.hd * 2 * BF16_B  # K and V
    return float(nq) * kv_bytes


def _moe_weight_bytes(cfg: ModelConfig) -> float:
    if not cfg.is_moe:
        return 0.0
    return 3.0 * cfg.n_experts * cfg.d_model * cfg.d_ff * F32


def train_bytes(cfg: ModelConfig, batch: int, seq: int) -> float:
    p = cfg.param_count()
    layers = cfg.n_layers + cfg.enc_layers
    base = (3 + 2 + 4 + 1) * F32 * p  # params/grads/adam traffic
    acts = 4.0 * BF16_B * batch * seq * cfg.d_model * layers
    attn = 2.0 * _attn_kv_reread_bytes(cfg, batch, seq) * layers
    moe = 10.0 * _moe_weight_bytes(cfg) * cfg.n_layers
    return base + acts + attn + moe


def prefill_bytes(cfg: ModelConfig, batch: int, seq: int) -> float:
    p = cfg.param_count()
    layers = cfg.n_layers + cfg.enc_layers
    base = F32 * p  # one read of the weights
    acts = 2.0 * BF16_B * batch * seq * cfg.d_model * layers
    attn = _attn_kv_reread_bytes(cfg, batch, seq) * layers
    cache_w = _cache_bytes(cfg, batch, seq)
    moe = _moe_weight_bytes(cfg) * cfg.n_layers
    return base + acts + attn + cache_w + moe


def decode_bytes(cfg: ModelConfig, batch: int, cache_len: int) -> float:
    p = cfg.param_count()
    base = F32 * p  # weights stream once per token
    cache_r = _cache_bytes(cfg, batch, cache_len)  # attention reads the cache
    moe = _moe_weight_bytes(cfg) * cfg.n_layers  # experts stream (batch ≫ E·topk)
    return base + cache_r + moe


def _cache_bytes(cfg: ModelConfig, batch: int, s: int) -> float:
    total = 0.0
    if cfg.mixer in ("attn", "hymba") and cfg.n_heads:
        s_eff = min(s, cfg.sliding_window) if cfg.sliding_window else s
        total += cfg.n_layers * batch * s_eff * cfg.n_kv_heads * cfg.hd * 2 * BF16_B
    if cfg.mixer in ("mamba", "hymba"):
        total += cfg.n_layers * batch * cfg.n_ssm_heads * cfg.ssm_head_dim * \
            cfg.ssm_state * F32 * 2  # state read + write
    if cfg.family == "audio":
        total += cfg.n_layers * batch * cfg.enc_seq * cfg.n_heads * cfg.hd * 2 * BF16_B
    return total


def hbm_bytes(cfg: ModelConfig, kind: str, batch: int, seq: int) -> float:
    if kind == "train":
        return train_bytes(cfg, batch, seq)
    if kind == "prefill":
        return prefill_bytes(cfg, batch, seq)
    return decode_bytes(cfg, batch, seq)
