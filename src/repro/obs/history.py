"""Perf-history tracker: append bench rows, detect regressions vs the past.

``BENCH_HISTORY.json`` is a flat JSON list of rows, one per (bench, scenario,
metric) measurement::

    {"bench": "cluster", "scenario": "shallow.flash.jsq.chips4.gang1",
     "metric": "latency_p99_cycles", "value": 123456.0,
     "commit": "a7c8264", "date": "2026-08-09"}

Rows are appended by ``benchmarks/run.py --smoke`` (every gated bench row)
and by ``tools/obs_smoke.py`` (the traced-fleet scenario); the file is the
repo's perf trajectory — cycle-level metrics are deterministic functions of
the code, so any drift between appends is a code-behaviour change.

``check_regression`` compares the NEWEST row of each (bench, scenario,
metric) group against the trailing median of up to ``window`` prior rows
with a symmetric relative tolerance band.  Wall-clock metrics (name
containing any of ``SKIP_SUBSTRINGS``) are skipped — host timing noise is
not a regression.  Single-row groups pass vacuously (a new metric has no
history to regress against).

Bench-row names like ``cluster.shallow.flash.jsq.chips4.gang1.latency_p99``
split as bench = first dot-segment, metric = last, scenario = the middle.
"""

from __future__ import annotations

import datetime
import json
import os
import subprocess

__all__ = ["append_rows", "check_regression", "load_history", "parse_row_name",
           "SKIP_SUBSTRINGS"]

# host-timing metrics: noisy across machines, never regression-gated
SKIP_SUBSTRINGS = ("wall_ms", "seconds", "wall_speedup")


def parse_row_name(name: str) -> tuple[str, str, str]:
    """Split a ``bench.scenario...metric`` row name into its three parts."""
    parts = name.split(".")
    if len(parts) == 1:
        return parts[0], "", parts[0]
    if len(parts) == 2:
        return parts[0], "", parts[1]
    return parts[0], ".".join(parts[1:-1]), parts[-1]


def current_commit(repo_dir: str | None = None) -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=repo_dir, capture_output=True, text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return "unknown"


def load_history(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as fh:
        data = json.load(fh)
    if not isinstance(data, list):
        raise ValueError(f"{path}: expected a JSON list of rows")
    return data


def append_rows(path: str, rows, commit: str | None = None,
                date: str | None = None) -> int:
    """Append ``rows`` — ``(name, value)`` pairs or ready-made row dicts —
    stamping commit/date; returns the number appended.  Non-numeric values
    are skipped (history tracks numbers only)."""
    commit = commit if commit is not None else current_commit(os.path.dirname(path) or ".")
    date = date if date is not None else datetime.date.today().isoformat()
    history = load_history(path)
    n = 0
    for row in rows:
        if isinstance(row, dict):
            rec = dict(row)
        else:
            name, value = row
            bench, scenario, metric = parse_row_name(name)
            rec = {"bench": bench, "scenario": scenario, "metric": metric,
                   "value": value}
        try:
            rec["value"] = float(rec["value"])
        except (TypeError, ValueError):
            continue
        rec.setdefault("commit", commit)
        rec.setdefault("date", date)
        history.append(rec)
        n += 1
    with open(path, "w") as fh:
        json.dump(history, fh, indent=1)
        fh.write("\n")
    return n


def _median(xs: list[float]) -> float:
    s = sorted(xs)
    m = len(s) // 2
    return s[m] if len(s) % 2 else 0.5 * (s[m - 1] + s[m])


def check_regression(history: list[dict], window: int = 8,
                     tolerance: float = 0.15,
                     skip_substrings: tuple[str, ...] = SKIP_SUBSTRINGS) -> list[str]:
    """Regression messages (empty = clean): per (bench, scenario, metric)
    group in append order, the newest value must sit within ``tolerance``
    (relative, symmetric — an improvement outside the band is ALSO flagged,
    because for a deterministic simulator it means behaviour changed) of the
    median of up to ``window`` immediately-prior rows."""
    groups: dict[tuple[str, str, str], list[float]] = {}
    for row in history:
        key = (str(row.get("bench", "")), str(row.get("scenario", "")),
               str(row.get("metric", "")))
        try:
            groups.setdefault(key, []).append(float(row["value"]))
        except (KeyError, TypeError, ValueError):
            continue
    problems: list[str] = []
    for (bench, scenario, metric), values in sorted(groups.items()):
        if len(values) < 2:
            continue
        if any(s in metric for s in skip_substrings):
            continue
        newest = values[-1]
        baseline = _median(values[-1 - window:-1])
        scale = max(abs(baseline), 1e-12)
        dev = abs(newest - baseline) / scale
        if dev > tolerance:
            label = ".".join(p for p in (bench, scenario, metric) if p)
            problems.append(
                f"{label}: newest {newest:g} deviates {dev:.1%} from trailing "
                f"median {baseline:g} (tolerance {tolerance:.0%}, "
                f"n={len(values) - 1} prior)")
    return problems
