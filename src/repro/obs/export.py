"""Chrome/Perfetto ``trace_event`` JSON exporter + structural validator.

``to_chrome_trace`` turns a ``Tracer`` into the JSON-object form of the
Trace Event Format (a dict with a ``traceEvents`` list), which both
``chrome://tracing`` and https://ui.perfetto.dev open directly.  The mapping
convention across this repo:

  * **process (pid)** — one per chip (pid = chip index + 1), plus pid 0 for
    the fleet router (sheds, admission, retries, backlog counters).
  * **thread (tid)**  — one per resource lane inside a chip: the chip-level
    health track, one track per cluster affiliation, the ``deep`` gang
    track (FLASH-FHE chips) or the single ``whole-chip`` track (sequential
    baselines).  Simulator/dispatch traces intern tracks per functional
    unit the same way.
  * **ts/dur**        — simulated *cycles*, not microseconds.  Perfetto
    renders them as µs; read "1 µs" as "1 cycle".  Timestamps are sim-clock
    or dispatch-index values, so same-seed runs export byte-identical files.

Serialisation is canonical — events stably sorted by (ts, emission order)
with metadata first, ``json.dumps(sort_keys=True, separators=(",", ":"))``
— so byte equality is the determinism test (``tests/test_obs.py``).

``validate_chrome_trace`` is the structural checker shared by the tests and
the obs-smoke CI job: required keys per phase, non-negative monotone
timestamps per track, balanced B/E nesting per (pid, tid), balanced b/e
async spans per (cat, id) with no negative depth, and JSON-serialisability.
It returns a list of human-readable problems (empty = valid) so callers
choose between asserting and reporting.
"""

from __future__ import annotations

import json

from .trace import Tracer

__all__ = ["to_chrome_trace", "dumps_chrome_trace", "write_chrome_trace",
           "validate_chrome_trace"]

_REQUIRED = ("ph", "ts", "pid", "tid")


def to_chrome_trace(tracer: Tracer) -> dict:
    """Trace Event Format (JSON-object form) for one recorded run."""
    events: list[dict] = []
    for pid, name in sorted(tracer.process_names.items()):
        events.append({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                       "ts": 0.0, "args": {"name": name}})
        events.append({"ph": "M", "name": "process_sort_index", "pid": pid,
                       "tid": 0, "ts": 0.0, "args": {"sort_index": pid}})
    for (pid, tid), label in sorted(tracer.thread_names.items()):
        events.append({"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                       "ts": 0.0, "args": {"name": label}})
        events.append({"ph": "M", "name": "thread_sort_index", "pid": pid,
                       "tid": tid, "ts": 0.0, "args": {"sort_index": tid}})
    # stable sort: ties keep emission order, so B-before-E and b-before-e
    # relationships at one instant survive (and the output is deterministic)
    events.extend(sorted(tracer.events, key=lambda e: e["ts"]))
    return {"traceEvents": events, "displayTimeUnit": "ns",
            "metadata": {"clock": "sim-cycles"}}


def dumps_chrome_trace(tracer: Tracer) -> str:
    """Canonical byte form — the unit of the byte-determinism guarantee."""
    return json.dumps(to_chrome_trace(tracer), sort_keys=True,
                      separators=(",", ":"))


def write_chrome_trace(tracer: Tracer, path: str) -> str:
    with open(path, "w") as fh:
        fh.write(dumps_chrome_trace(tracer))
    return path


def validate_chrome_trace(obj: dict) -> list[str]:
    """Structural problems in a trace dict (empty list = valid)."""
    problems: list[str] = []
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    try:
        json.dumps(obj)
    except (TypeError, ValueError) as e:  # non-serialisable payload
        problems.append(f"not JSON-serialisable: {e}")
    last_ts: dict[tuple, float] = {}
    open_spans: dict[tuple, list[str]] = {}
    async_depth: dict[tuple, int] = {}
    async_counts: dict[tuple, list[int]] = {}
    for k, ev in enumerate(events):
        missing = [key for key in _REQUIRED if key not in ev]
        if missing:
            problems.append(f"event {k}: missing keys {missing}")
            continue
        ph, ts = ev["ph"], ev["ts"]
        if ph == "M":
            continue
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {k}: bad ts {ts!r}")
            continue
        track = (ev["pid"], ev["tid"])
        if ph in ("X", "B", "E", "i", "C"):
            if ts < last_ts.get(track, 0.0):
                problems.append(
                    f"event {k}: ts {ts} not monotone on track {track}")
            last_ts[track] = ts
        if ph == "X":
            if ev.get("dur", -1.0) < 0:
                problems.append(f"event {k}: X without non-negative dur")
        elif ph == "B":
            open_spans.setdefault(track, []).append(ev.get("name", ""))
        elif ph == "E":
            stack = open_spans.get(track)
            if not stack:
                problems.append(f"event {k}: E with no open B on track {track}")
            else:
                opened = stack.pop()
                name = ev.get("name")
                if name is not None and name != opened:
                    problems.append(
                        f"event {k}: E({name}) closes B({opened}) on {track}")
        elif ph in ("b", "n", "e"):
            if "id" not in ev or "cat" not in ev:
                problems.append(f"event {k}: async {ph} without id/cat")
                continue
            key = (ev["cat"], ev["id"])
            counts = async_counts.setdefault(key, [0, 0])
            if ph == "b":
                async_depth[key] = async_depth.get(key, 0) + 1
                counts[0] += 1
            elif ph == "e":
                async_depth[key] = async_depth.get(key, 0) - 1
                counts[1] += 1
                if async_depth[key] < 0:
                    problems.append(f"event {k}: async e before b for {key}")
        elif ph not in ("i", "C"):
            problems.append(f"event {k}: unknown phase {ph!r}")
    for track, stack in open_spans.items():
        if stack:
            problems.append(f"unclosed B spans on track {track}: {stack}")
    for key, (nb, ne) in async_counts.items():
        if nb != ne:
            problems.append(f"async span {key}: {nb} begins vs {ne} ends")
    return problems
