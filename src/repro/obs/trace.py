"""Deterministic span/event tracer for simulator and serving timelines.

``Tracer`` records a flat list of Chrome ``trace_event``-shaped dicts (see
``repro.obs.export`` for the file format and the pid/tid conventions) with
three hard rules that make traces *reproducible artifacts* rather than
profiler noise:

* **Sim-clock timestamps only.**  Every timestamp comes from the bound clock
  (the serving ``EventLoop``'s cycle counter), an explicit ``ts=`` argument,
  or a dispatch index — never from wall-clock time.  Two runs with the same
  seed therefore export byte-identical traces, and a trace diff is a
  behaviour diff.
* **Zero overhead when disabled.**  ``Tracer(enabled=False)`` (and the
  ``tracer=None`` default at every seam) records nothing: seams guard with
  ``if tracer:`` — ``__bool__`` returns ``enabled`` — so the disabled path
  is one attribute test and no allocation.  The no-op/unchanged-bench
  properties are pinned by ``tests/test_obs.py``.
* **No ambient identity.**  Track ids are interned per (pid, label) in
  registration order and span/async ids are explicit caller-provided keys
  (job ids), so nothing depends on ``id()``, hashing order, or interpreter
  state.

Event vocabulary (one method per Chrome phase the exporter understands):

  ``complete``      — a closed interval (phase "X"): run segments,
                      per-instruction unit occupancy
  ``begin``/``end`` — open/close a nested interval on a track (phases
                      "B"/"E"): chip downtime windows
  ``instant``       — a point event (phase "i"): sheds, faults, gang
                      barriers, retries
  ``counter``       — a sampled value (phase "C"): backlog, dispatch totals
  ``async_begin`` / ``async_instant`` / ``async_end`` — a logical operation
                      spanning tracks (phases "b"/"n"/"e", keyed by
                      ``(cat, id)``): job lifecycles with their
                      QUEUED→RUNNING→…→terminal state transitions
  ``span``          — context-manager sugar over ``begin``/``end``

Domain helpers (``job_begin``/``job_state``/``job_end``) wrap the async
trio with ``cat="job"`` so the serving seams stay one-liners.
"""

from __future__ import annotations

import contextlib
from typing import Callable

__all__ = ["Tracer"]


class Tracer:
    """Deterministic event recorder; export via ``repro.obs.export``."""

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.events: list[dict] = []
        self._clock: Callable[[], float] | None = None
        self.process_names: dict[int, str] = {}
        # (pid, label) -> tid, interned in registration order per pid
        self._tracks: dict[tuple[int, str], int] = {}
        self._next_tid: dict[int, int] = {}
        self.n_dispatches = 0  # dispatch-index clock for kernel-launch events

    def __bool__(self) -> bool:
        return self.enabled

    # -- clock / topology ----------------------------------------------------

    def bind_clock(self, clock: Callable[[], float]) -> None:
        """Set the default timestamp source (e.g. ``lambda: loop.now``)."""
        self._clock = clock

    def now(self) -> float:
        return float(self._clock()) if self._clock is not None else 0.0

    def _ts(self, ts: float | None) -> float:
        return float(ts) if ts is not None else self.now()

    def name_process(self, pid: int, name: str) -> None:
        if self.enabled:
            self.process_names[pid] = name

    def new_process(self, name: str) -> int:
        """Allocate a fresh pid (one past the highest seen) and name it.
        Per-call timelines — e.g. each ``simulate_stream`` invocation — get
        their own process so their ts=0-based events never violate another
        track's monotonicity.  Deterministic: depends only on registration
        order, like ``track``."""
        if not self.enabled:
            return 0
        used = set(self.process_names) | {p for p, _ in self._tracks}
        pid = max(used, default=-1) + 1
        self.name_process(pid, name)
        return pid

    def track(self, pid: int, label: str) -> int:
        """Intern a (pid, label) thread track; stable tid per registration
        order.  Pre-register tracks in a fixed order (the cluster router does)
        when a human-friendly fixed layout matters."""
        key = (pid, label)
        tid = self._tracks.get(key)
        if tid is None:
            tid = self._next_tid.get(pid, 0)
            self._next_tid[pid] = tid + 1
            self._tracks[key] = tid
        return tid

    @property
    def thread_names(self) -> dict[tuple[int, int], str]:
        return {(pid, tid): label for (pid, label), tid in self._tracks.items()}

    # -- core event emitters -------------------------------------------------

    def complete(self, name: str, start: float, end: float, pid: int = 0,
                 tid: int = 0, **args) -> None:
        """Closed interval [start, end) on a track (phase "X")."""
        if self.enabled:
            self.events.append({"ph": "X", "name": name, "ts": float(start),
                                "dur": float(end) - float(start),
                                "pid": pid, "tid": tid, "args": args})

    def begin(self, name: str, ts: float | None = None, pid: int = 0,
              tid: int = 0, **args) -> None:
        if self.enabled:
            self.events.append({"ph": "B", "name": name, "ts": self._ts(ts),
                                "pid": pid, "tid": tid, "args": args})

    def end(self, name: str, ts: float | None = None, pid: int = 0,
            tid: int = 0) -> None:
        if self.enabled:
            self.events.append({"ph": "E", "name": name, "ts": self._ts(ts),
                                "pid": pid, "tid": tid})

    def instant(self, name: str, ts: float | None = None, pid: int = 0,
                tid: int = 0, **args) -> None:
        if self.enabled:
            self.events.append({"ph": "i", "name": name, "ts": self._ts(ts),
                                "pid": pid, "tid": tid, "s": "t", "args": args})

    def counter(self, name: str, values: dict, ts: float | None = None,
                pid: int = 0) -> None:
        """Sampled counter series (phase "C"); ``values`` maps series→number."""
        if self.enabled:
            self.events.append({"ph": "C", "name": name, "ts": self._ts(ts),
                                "pid": pid, "tid": 0,
                                "args": {k: float(v) for k, v in values.items()}})

    def async_begin(self, name: str, aid, cat: str = "async",
                    ts: float | None = None, pid: int = 0, tid: int = 0,
                    **args) -> None:
        if self.enabled:
            self.events.append({"ph": "b", "name": name, "cat": cat,
                                "id": aid, "ts": self._ts(ts),
                                "pid": pid, "tid": tid, "args": args})

    def async_instant(self, name: str, aid, cat: str = "async",
                      ts: float | None = None, pid: int = 0, tid: int = 0,
                      **args) -> None:
        if self.enabled:
            self.events.append({"ph": "n", "name": name, "cat": cat,
                                "id": aid, "ts": self._ts(ts),
                                "pid": pid, "tid": tid, "args": args})

    def async_end(self, name: str, aid, cat: str = "async",
                  ts: float | None = None, pid: int = 0, tid: int = 0,
                  **args) -> None:
        if self.enabled:
            self.events.append({"ph": "e", "name": name, "cat": cat,
                                "id": aid, "ts": self._ts(ts),
                                "pid": pid, "tid": tid, "args": args})

    @contextlib.contextmanager
    def span(self, name: str, pid: int = 0, tid: int = 0, **args):
        """Lexical span on a track: ``with tracer.span("route"): ...``."""
        if not self.enabled:
            yield self
            return
        self.begin(name, pid=pid, tid=tid, **args)
        try:
            yield self
        finally:
            self.end(name, pid=pid, tid=tid)

    # -- job-lifecycle helpers (async span keyed by job id, cat="job") -------

    def job_begin(self, job_id: int, name: str, ts: float | None = None,
                  pid: int = 0, **args) -> None:
        self.async_begin(name, job_id, cat="job", ts=ts, pid=pid, **args)

    def job_state(self, job_id: int, name: str, state: str,
                  ts: float | None = None, pid: int = 0, **args) -> None:
        self.async_instant(name, job_id, cat="job", ts=ts, pid=pid,
                           state=state, **args)

    def job_end(self, job_id: int, name: str, state: str,
                ts: float | None = None, pid: int = 0, **args) -> None:
        self.async_end(name, job_id, cat="job", ts=ts, pid=pid,
                       state=state, **args)

    # -- kernel-dispatch seam -------------------------------------------------

    def dispatch_hook(self, pid: int = 0, label: str = "kernel-dispatch"):
        """A hook for ``kernels.dispatch.hook_dispatches`` (or
        ``ExecPolicy(dispatch_hook=...)``, via ``ExecPolicy.traced``): each
        kernel launch becomes a unit-width "X" slice at its *dispatch index*
        — kernels carry no sim-time of their own, so the index is the
        deterministic clock for this track."""
        tid = self.track(pid, label)

        def hook(op: str) -> None:
            if self.enabled:
                i = self.n_dispatches
                self.n_dispatches = i + 1
                self.events.append({"ph": "X", "name": op, "ts": float(i),
                                    "dur": 1.0, "pid": pid, "tid": tid,
                                    "args": {}})
        return hook
