"""repro.obs — observability: span tracing, Perfetto export, metrics, perf history.

  trace    — ``Tracer``: deterministic span/instant/counter/async events with
             sim-clock (event loop) or dispatch-index timestamps; a no-op
             when disabled, so every seam defaults to zero overhead
  export   — Chrome/Perfetto ``trace_event`` JSON: chips→processes,
             affiliations/lanes→threads; canonical byte-stable serialisation
             plus the structural validator CI uses
  metrics  — in-process registry (labelled counters, gauges, fixed-bucket
             histograms) with a plain-dict ``snapshot()``; the cluster
             router's shed/fault books live here
  history  — ``BENCH_HISTORY.json`` append + trailing-median regression
             check (``tools/bench_history.py`` is the CLI)

Quick use (see docs/observability.md for the full seam map)::

    from repro import serve
    from repro.obs import Tracer, write_chrome_trace

    tracer = Tracer()
    result = serve.serve_cluster(jobs, chip, n_chips=4, tracer=tracer)
    write_chrome_trace(tracer, "fleet.json")   # open in ui.perfetto.dev
"""

from .export import (
    dumps_chrome_trace,
    to_chrome_trace,
    validate_chrome_trace,
    write_chrome_trace,
)
from .history import append_rows, check_regression, load_history, parse_row_name
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import Tracer

__all__ = [
    "Tracer",
    "to_chrome_trace",
    "dumps_chrome_trace",
    "write_chrome_trace",
    "validate_chrome_trace",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "append_rows",
    "check_regression",
    "load_history",
    "parse_row_name",
]
