"""Lightweight in-process metrics registry: counters, gauges, histograms.

A deliberately small Prometheus-shaped surface for the serving subsystem,
replacing ad-hoc ``dict.get(k, 0) + 1`` accumulation where that was a
drop-in (the cluster router's shed/fault books are the first client).  No
background threads, no wall-clock, no global state: a registry is an
explicit object you thread to whoever should report into it, and
``snapshot()`` is the only read path — a plain nested dict, safe to
serialise or diff in tests.

* ``Counter``   — monotone totals, optionally labelled:
  ``c = reg.counter("serve.shed", labels=("reason", "chip"))`` then
  ``c.inc(reason="timeout", chip=3)``.  ``group_sum("reason")`` re-aggregates
  over one label (how the router derives its fleet-global ``shed_reasons``
  from the per-chip books), ``by_label("chip")`` nests the remaining labels
  under each value of one.
* ``Gauge``     — last-written value (``set``/``add``), same labelling.
* ``Histogram`` — fixed buckets chosen at creation; ``observe(v)`` bins it.
  ``snapshot`` reports per-bucket counts plus count/sum, so means and
  coarse percentiles are recoverable without storing samples.

Label values are normalised to strings in snapshots (Prometheus-style);
ints are accepted at the call site for convenience (chip indices).
"""

from __future__ import annotations

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class _Labelled:
    """Shared label plumbing: values keyed by a tuple in ``labels`` order."""

    def __init__(self, name: str, labels: tuple[str, ...] = ()):
        self.name = name
        self.labels = tuple(labels)
        self._values: dict[tuple[str, ...], float] = {}

    def _key(self, kw: dict) -> tuple[str, ...]:
        if set(kw) != set(self.labels):
            raise ValueError(
                f"{self.name}: expected labels {self.labels}, got {tuple(kw)}")
        return tuple(str(kw[label]) for label in self.labels)

    def value(self, **kw) -> float:
        return self._values.get(self._key(kw), 0.0)

    def total(self) -> float:
        return sum(self._values.values())

    def group_sum(self, label: str) -> dict[str, float]:
        """Aggregate over every label except ``label``."""
        i = self.labels.index(label)
        out: dict[str, float] = {}
        for key, v in self._values.items():
            out[key[i]] = out.get(key[i], 0.0) + v
        return out

    def by_label(self, label: str) -> dict[str, dict[tuple[str, ...], float]]:
        """Nest the remaining label tuples under each value of ``label``."""
        i = self.labels.index(label)
        out: dict[str, dict[tuple[str, ...], float]] = {}
        for key, v in self._values.items():
            rest = key[:i] + key[i + 1:]
            out.setdefault(key[i], {})[rest] = v
        return out

    def snapshot(self) -> dict:
        if not self.labels:
            return {"value": self._values.get((), 0.0)}
        return {"labels": list(self.labels),
                "values": {",".join(k): v for k, v in sorted(self._values.items())}}


class Counter(_Labelled):
    """Monotone counter; ``inc`` rejects negative steps."""

    def inc(self, n: float = 1.0, **kw) -> None:
        if n < 0:
            raise ValueError(f"{self.name}: counters only go up (inc {n})")
        key = self._key(kw)
        self._values[key] = self._values.get(key, 0.0) + n


class Gauge(_Labelled):
    """Last-written value (e.g. current backlog, peak watermarks via max)."""

    def set(self, v: float, **kw) -> None:
        self._values[self._key(kw)] = float(v)

    def add(self, v: float, **kw) -> None:
        key = self._key(kw)
        self._values[key] = self._values.get(key, 0.0) + float(v)

    def max(self, v: float, **kw) -> None:
        key = self._key(kw)
        self._values[key] = max(self._values.get(key, float("-inf")), float(v))


class Histogram:
    """Fixed-bucket histogram: ``buckets`` are inclusive upper bounds (an
    implicit +inf bucket catches the rest)."""

    def __init__(self, name: str, buckets: tuple[float, ...]):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(f"{name}: buckets must be sorted and non-empty")
        self.name = name
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)
        self.n = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.n += 1
        self.sum += v
        for i, bound in enumerate(self.buckets):
            if v <= bound:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.n if self.n else 0.0

    def snapshot(self) -> dict:
        return {"buckets": list(self.buckets), "counts": list(self.counts),
                "count": self.n, "sum": self.sum}


class MetricsRegistry:
    """Get-or-create home for named instruments; one per serving run."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str, labels: tuple[str, ...] = ()) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name, labels)
        elif c.labels != tuple(labels):
            raise ValueError(f"counter {name} re-registered with labels "
                             f"{tuple(labels)} != {c.labels}")
        return c

    def gauge(self, name: str, labels: tuple[str, ...] = ()) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name, labels)
        elif g.labels != tuple(labels):
            raise ValueError(f"gauge {name} re-registered with labels "
                             f"{tuple(labels)} != {g.labels}")
        return g

    def histogram(self, name: str, buckets: tuple[float, ...] = ()) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, buckets)
        elif buckets and h.buckets != tuple(float(b) for b in buckets):
            raise ValueError(f"histogram {name} re-registered with different buckets")
        return h

    def snapshot(self) -> dict:
        """Nested plain-dict view of everything registered (sorted names)."""
        return {
            "counters": {k: v.snapshot() for k, v in sorted(self._counters.items())},
            "gauges": {k: v.snapshot() for k, v in sorted(self._gauges.items())},
            "histograms": {k: v.snapshot() for k, v in sorted(self._histograms.items())},
        }
