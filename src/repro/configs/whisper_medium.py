"""whisper-medium — enc-dec, stub conv frontend [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-medium", family="audio", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=51865,
    act="gelu", norm="layernorm", enc_layers=24, enc_seq=1500,
)

SMOKE = ModelConfig(
    arch_id="whisper-smoke", family="audio", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
    act="gelu", norm="layernorm", enc_layers=2, enc_seq=32,
)
