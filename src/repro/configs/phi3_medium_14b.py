"""phi3-medium-14b — RoPE SwiGLU GQA [arXiv:2404.14219]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi3-medium-14b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=10, d_ff=17920, vocab=100352,
)

SMOKE = ModelConfig(
    arch_id="phi3m-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=256, vocab=128,
)
