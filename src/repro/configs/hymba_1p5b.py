"""hymba-1.5b — hybrid parallel attention+Mamba heads [arXiv:2411.13676; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, d_ff=5504, vocab=32001,
    mixer="hymba", ssm_state=16, ssm_head_dim=64, ssm_expand=2,
    sliding_window=1024,  # hymba pairs global SSM state with local SWA
)

SMOKE = ModelConfig(
    arch_id="hymba-smoke", family="hybrid", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
    mixer="hymba", ssm_state=8, ssm_head_dim=16, sliding_window=16,
)
