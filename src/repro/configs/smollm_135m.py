"""smollm-135m — llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-135m", family="dense", n_layers=30, d_model=576,
    n_heads=9, n_kv_heads=3, d_ff=1536, vocab=49152, tie_embeddings=True,
)

SMOKE = ModelConfig(
    arch_id="smollm-smoke", family="dense", n_layers=2, d_model=48,
    n_heads=3, n_kv_heads=1, d_ff=128, vocab=128, tie_embeddings=True,
)
