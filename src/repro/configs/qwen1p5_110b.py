"""qwen1.5-110b — dense GQA with QKV bias [hf:Qwen/Qwen1.5-110B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen1.5-110b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=49152, vocab=152064, qkv_bias=True,
)

SMOKE = ModelConfig(
    arch_id="qwen-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=256, vocab=128, qkv_bias=True,
)
