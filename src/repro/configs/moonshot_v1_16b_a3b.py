"""moonshot-v1-16b-a3b — Moonlight MoE, 64 experts top-6 [hf:moonshotai/Moonlight-16B-A3B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=163840,
    n_experts=64, n_shared_experts=2, top_k=6,
)

SMOKE = ModelConfig(
    arch_id="moonshot-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=32, vocab=128,
    n_experts=8, n_shared_experts=1, top_k=2,
)
