"""phi-3-vision-4.2b — phi3-mini backbone + stub CLIP patches [hf:microsoft/Phi-3-vision-128k-instruct]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi-3-vision-4.2b", family="vlm", n_layers=32, d_model=3072,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32064,
    n_patches=256,  # stub frontend: precomputed patch embeddings
)

SMOKE = ModelConfig(
    arch_id="phi3v-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, n_patches=8,
)
