"""mamba2-1.3b — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
    mixer="mamba", ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    head_dim=64, tie_embeddings=True,
)

SMOKE = ModelConfig(
    arch_id="mamba2-smoke", family="ssm", n_layers=2, d_model=64,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=128,
    mixer="mamba", ssm_state=16, ssm_head_dim=16, head_dim=16, tie_embeddings=True,
)
