"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6 [arXiv:2401.06066]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=102400,
    n_experts=64, n_shared_experts=2, top_k=6,
)

SMOKE = ModelConfig(
    arch_id="deepseek-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=32, vocab=128,
    n_experts=8, n_shared_experts=2, top_k=2,
)
