"""Assigned-architecture registry: --arch <id> resolves here."""

from . import (
    deepseek_moe_16b, granite_20b, hymba_1p5b, mamba2_1p3b,
    moonshot_v1_16b_a3b, phi3_medium_14b, phi3_vision_4p2b,
    qwen1p5_110b, smollm_135m, whisper_medium,
)

_MODULES = {
    "hymba-1.5b": hymba_1p5b,
    "phi-3-vision-4.2b": phi3_vision_4p2b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "mamba2-1.3b": mamba2_1p3b,
    "smollm-135m": smollm_135m,
    "granite-20b": granite_20b,
    "qwen1.5-110b": qwen1p5_110b,
    "phi3-medium-14b": phi3_medium_14b,
    "whisper-medium": whisper_medium,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, smoke: bool = False):
    mod = _MODULES[arch_id]
    return mod.SMOKE if smoke else mod.CONFIG
