"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets its device-count env var
before the first jax call.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def single_device_mesh():
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
