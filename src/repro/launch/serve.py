"""Serving driver: batched generation with the reduced (--smoke) or full config.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --batch 4 --prompt-len 16 --tokens 32
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.data import pipeline
from repro.models import registry
from repro.serving.engine import Engine, SamplerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    api = registry.build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    eng = Engine(api, params, batch=args.batch, max_seq=args.max_seq)
    prompts = pipeline.synthetic_lm_batch(0, 0, args.batch, args.prompt_len - 1,
                                          cfg.vocab)
    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, cfg.n_patches, cfg.d_model))
    if cfg.family == "audio":
        extra["frames"] = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, cfg.enc_seq, cfg.d_model))
    out = eng.generate(prompts, args.tokens,
                       SamplerConfig(temperature=args.temperature), **extra)
    print(f"[serve] arch={cfg.arch_id} generated {out.shape} tokens")
    print(out[:, :16])


if __name__ == "__main__":
    main()
