"""End-to-end training driver.

Local mode (default) trains a reduced config on the available devices with the
same code path as the production mesh: sharded params, jitted train step,
checkpoint/restore (resume-safe), heartbeat + straggler bookkeeping, and the
deterministic data pipeline.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 200 --batch 16 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.checkpoint import failures, manager
from repro.data import pipeline
from repro.distributed import sharding as sh
from repro.models import registry
from repro.training import optimizer as opt, train_step as ts


def local_mesh() -> Mesh:
    devs = np.array(jax.devices())
    return Mesh(devs.reshape(len(devs), 1), ("data", "model"))


def run(arch: str, smoke: bool, steps: int, batch: int, seq: int,
        ckpt_dir: str | None, ckpt_every: int = 50, lr: float = 3e-3,
        microbatch: int = 0, log_every: int = 10) -> dict:
    cfg = configs.get_config(arch, smoke=smoke)
    api = registry.build(cfg)
    mesh = local_mesh()
    acfg = opt.AdamWConfig(lr_peak=lr, warmup_steps=max(5, steps // 20),
                           total_steps=steps)

    corpus = pipeline.ByteCorpus(vocab=cfg.vocab)
    monitor = failures.HeartbeatMonitor(n_hosts=1)

    start_step = 0
    params = state = None
    if ckpt_dir and manager.latest_step(ckpt_dir) is not None:
        start_step, tree = manager.restore(ckpt_dir)
        params, state = tree["params"], tree["opt"]
        state["step"] = jnp.asarray(np.asarray(state["step"]).item(), jnp.int32)
        print(f"[train] resumed from step {start_step}")
    if params is None:
        params = api.init_params(jax.random.PRNGKey(0))
        state = opt.init_state(params)

    dp = sh.dp_axes(mesh) or None
    batch_specs = {"tokens": sh.sanitize_spec(P(dp), (batch, seq + 1), mesh)}
    step_fn = ts.jit_train_step(api, mesh, acfg, batch_specs,
                                microbatch=microbatch, donate=True)

    hist = []
    t0 = time.time()
    for step in range(start_step, steps):
        tokens = jnp.asarray(corpus.batch(seed=0, step=step, batch=batch, seq=seq))
        params, state, metrics = step_fn(params, state, {"tokens": tokens})
        loss = float(metrics["loss"])
        hist.append(loss)
        monitor.beat(0, now=time.time() - t0, step_time=0.0)
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            manager.save(ckpt_dir, step + 1,
                         {"params": jax.tree.map(np.asarray, params),
                          "opt": jax.tree.map(np.asarray, state)})
    if ckpt_dir:
        manager.save(ckpt_dir, steps,
                     {"params": jax.tree.map(np.asarray, params),
                      "opt": jax.tree.map(np.asarray, state)})
    return {"first_loss": hist[0], "final_loss": float(np.mean(hist[-10:])),
            "history": hist, "params": params}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list(configs.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    out = run(args.arch, args.smoke, args.steps, args.batch, args.seq,
              args.ckpt_dir, lr=args.lr, microbatch=args.microbatch)
    print(f"[train] loss {out['first_loss']:.3f} → {out['final_loss']:.3f}")


if __name__ == "__main__":
    main()
