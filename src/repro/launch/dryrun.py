import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: a successful
compile on the 16×16 (single-pod) and 2×16×16 (multi-pod) meshes means the
shardings, collectives and memory plan are valid.  Emits per-cell JSON with
memory_analysis, cost_analysis, parsed collective bytes and the three-term
roofline (§Roofline).

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod --out-dir experiments/dryrun
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.distributed import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.models.registry import SHAPES
from repro.roofline import analysis as roofl
from repro.roofline import memory_model as mem_model
from repro.training import optimizer as opt, train_step as ts


def _param_structs(api):
    return jax.eval_shape(api.init_params, jax.random.PRNGKey(0))


def _cost_get(cost, key):
    if cost is None:
        return 0.0
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return float(cost.get(key, 0.0))


# ---------------------------------------------------------------------------
# Scan-exact cost reconstruction.
#
# XLA's cost_analysis counts a while-loop body ONCE, so scanned models report
# ~L× too few flops/bytes.  We recover the exact totals from small *probe*
# lowerings compiled with every scan unrolled (models.scan_util.unrolled):
#   cost(L, S) = A(S) + L·B(S)         (linear in layer count)
# with A, B exact polynomials in sequence length (degree 2: attention is
# quadratic; degree 1 for decode cache reads).  Probing L ∈ {1,2} and three
# (two for decode) S values determines the polynomial exactly; we then
# evaluate at the cell's true (L, S).  Collective bytes (parsed from HLO) are
# reconstructed the same way.
# ---------------------------------------------------------------------------

_PROBE_CACHE: dict = {}


def _lower_for(cfg, mesh, kind: str, seq: int, batch: int):
    """Lower one (possibly modified-config) step; returns the compiled obj."""
    api = registry.build(cfg)
    pspecs = sh.sanitize_tree(api.param_specs(mesh), _param_structs(api), mesh)
    p_structs = _param_structs(api)
    dp = sh.dp_axes(mesh) or None

    def batch_structs():
        out = {}
        if cfg.family == "vlm":
            s_txt = seq - cfg.n_patches
            out["tokens"] = jax.ShapeDtypeStruct(
                (batch, s_txt + (1 if kind == "train" else 0)), jnp.int32)
            out["patches"] = jax.ShapeDtypeStruct(
                (batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        elif cfg.family == "audio":
            s_dec = seq - cfg.enc_seq
            out["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
            out["tokens"] = jax.ShapeDtypeStruct(
                (batch, s_dec + (1 if kind == "train" else 0)), jnp.int32)
        else:
            out["tokens"] = jax.ShapeDtypeStruct(
                (batch, seq + (1 if kind == "train" else 0)), jnp.int32)
        return out

    if kind == "train":
        acfg = opt.AdamWConfig()
        s_structs = jax.eval_shape(opt.init_state, p_structs)
        sspecs = sh.sanitize_tree(opt.state_specs(pspecs), s_structs, mesh)
        step = ts.build_train_step(api, mesh, acfg)
        ins = batch_structs()
        in_specs = {k: sh.sanitize_spec(P(dp), v.shape, mesh) for k, v in ins.items()}
        jitted = jax.jit(step, in_shardings=(
            sh.tree_shardings(mesh, pspecs), sh.tree_shardings(mesh, sspecs),
            {k: NamedSharding(mesh, v) for k, v in in_specs.items()}),
            donate_argnums=(0, 1))
        return jitted.lower(p_structs, s_structs, ins)
    if kind == "prefill":
        cache_structs = jax.eval_shape(lambda: api.init_cache(batch, seq))
        cspecs = sh.sanitize_tree(api.cache_specs(mesh), cache_structs, mesh)
        ins = batch_structs()
        in_specs = {k: sh.sanitize_spec(P(dp), v.shape, mesh) for k, v in ins.items()}
        jitted = jax.jit(
            lambda params, cache, batch: api.prefill(params, cache, mesh=mesh, **batch),
            in_shardings=(sh.tree_shardings(mesh, pspecs),
                          sh.tree_shardings(mesh, cspecs),
                          {k: NamedSharding(mesh, v) for k, v in in_specs.items()}),
            donate_argnums=(1,))
        return jitted.lower(p_structs, cache_structs, ins)
    # decode
    cache_structs = jax.eval_shape(lambda: api.init_cache(batch, seq))
    cspecs = sh.sanitize_tree(api.cache_specs(mesh), cache_structs, mesh)
    tok = jax.ShapeDtypeStruct((batch,), jnp.int32)
    jitted = jax.jit(
        lambda params, token, cache: api.decode_step(params, token, cache, mesh=mesh),
        in_shardings=(sh.tree_shardings(mesh, pspecs),
                      NamedSharding(mesh, sh.sanitize_spec(P(dp), (batch,), mesh)),
                      sh.tree_shardings(mesh, cspecs)),
        donate_argnums=(2,))
    return jitted.lower(p_structs, tok, cache_structs)


def _measure_unrolled(cfg, mesh, kind, seq, batch) -> dict:
    from repro.models.scan_util import unrolled

    key = (cfg.arch_id, cfg.n_layers, cfg.enc_layers, kind, seq, batch,
           tuple(sorted(mesh.shape.items())))
    if key in _PROBE_CACHE:
        return _PROBE_CACHE[key]
    with unrolled():
        lowered = _lower_for(cfg, mesh, kind, seq, batch)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = roofl.collective_bytes(compiled.as_text())
    out = {
        "flops": _cost_get(cost, "flops"),
        "bytes": _cost_get(cost, "bytes accessed"),
        "coll": float(coll["total_bytes"]),
    }
    _PROBE_CACHE[key] = out
    return out


def _polyfit_eval(xs, ys, x_star, deg):
    coef = np.polyfit(np.asarray(xs, float), np.asarray(ys, float), deg)
    return float(max(0.0, np.polyval(coef, x_star)))


def probe_costs(cfg, mesh, kind: str, seq: int, batch: int) -> dict:
    """Reconstruct exact HLO costs for the full config at (seq, batch)."""
    import dataclasses as dc

    quadratic = cfg.mixer == "attn" and not cfg.sliding_window
    if kind == "decode":
        s_probes = [4096, 8192]
        deg = 1
    elif quadratic:
        s_probes = [1024, 2048, 4096]
        deg = 2
    else:
        # SSM / sliding-window mixers are linear in S beyond the window
        s_probes = [2048, 4096]
        deg = 1
    if cfg.family == "vlm":
        s_probes = [max(s, cfg.n_patches + 512) for s in s_probes]
    if cfg.family == "audio" and kind != "decode":
        s_probes = [s + cfg.enc_seq for s in s_probes]

    layer_fields = [("n_layers", cfg.n_layers)]
    if cfg.enc_layers:
        layer_fields.append(("enc_layers", cfg.enc_layers))

    def cfg_at(**layer_counts):
        return dc.replace(cfg, **layer_counts)

    base_counts = {f: 1 for f, _ in layer_fields}
    out = {}
    for metric in ("flops", "bytes", "coll"):
        vals_at_s = []
        for s in s_probes:
            f_base = _measure_unrolled(cfg_at(**base_counts), mesh, kind, s, batch)[metric]
            total = f_base
            for field, true_count in layer_fields:
                bumped = dict(base_counts)
                bumped[field] = 2
                f_b = _measure_unrolled(cfg_at(**bumped), mesh, kind, s, batch)[metric]
                slope = f_b - f_base
                total += slope * (true_count - 1)
            vals_at_s.append(total)
        out[metric] = _polyfit_eval(s_probes, vals_at_s, seq, deg)
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               with_probes: bool = True) -> dict:
    cfg = configs.get_config(arch)
    api = registry.build(cfg)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    ok, reason = api.supports_shape(shape_name)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    rec["chips"] = chips
    info = SHAPES[shape_name]
    kind = info["kind"]
    t0 = time.time()

    pspecs = sh.sanitize_tree(api.param_specs(mesh), _param_structs(api), mesh)
    p_structs = _param_structs(api)
    inputs = api.input_specs(shape_name, mesh)
    in_structs = {k: v[0] for k, v in inputs.items()}
    in_specs = {k: sh.sanitize_spec(v[1], v[0].shape, mesh)
                for k, v in inputs.items()}

    if kind == "train":
        acfg = opt.AdamWConfig()
        s_structs = jax.eval_shape(opt.init_state, p_structs)
        sspecs = sh.sanitize_tree(opt.state_specs(pspecs), s_structs, mesh)
        step = ts.build_train_step(api, mesh, acfg,
                                   compress_pods=False, microbatch=0)
        jitted = jax.jit(
            step,
            in_shardings=(sh.tree_shardings(mesh, pspecs),
                          sh.tree_shardings(mesh, sspecs),
                          {k: NamedSharding(mesh, v) for k, v in in_specs.items()}),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(p_structs, s_structs, in_structs)
        tokens = info["batch"] * info["seq"]
        model_flops = roofl.model_flops_per_step(
            cfg.param_count(), cfg.active_param_count(), tokens, "train")
    elif kind == "prefill":
        cache_structs = jax.eval_shape(
            lambda: api.init_cache(info["batch"], info["seq"]))
        cspecs = sh.sanitize_tree(api.cache_specs(mesh), cache_structs, mesh)

        def prefill_step(params, cache, batch):
            return api.prefill(params, cache, mesh=mesh, **batch)

        jitted = jax.jit(
            prefill_step,
            in_shardings=(sh.tree_shardings(mesh, pspecs),
                          sh.tree_shardings(mesh, cspecs),
                          {k: NamedSharding(mesh, v) for k, v in in_specs.items()}),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(p_structs, cache_structs, in_structs)
        tokens = info["batch"] * info["seq"]
        model_flops = roofl.model_flops_per_step(
            cfg.param_count(), cfg.active_param_count(), tokens, "serve")
    else:  # decode
        cache_structs = jax.eval_shape(
            lambda: api.init_cache(info["batch"], info["seq"]))
        cspecs = sh.sanitize_tree(api.cache_specs(mesh), cache_structs, mesh)

        def serve_step(params, token, cache):
            return api.decode_step(params, token, cache, mesh=mesh)

        jitted = jax.jit(
            serve_step,
            in_shardings=(sh.tree_shardings(mesh, pspecs),
                          NamedSharding(mesh, in_specs["token"]),
                          sh.tree_shardings(mesh, cspecs)),
            donate_argnums=(2,),
        )
        lowered = jitted.lower(p_structs, in_structs["token"], cache_structs)
        tokens = info["batch"]  # one new token per sequence
        model_flops = roofl.model_flops_per_step(
            cfg.param_count(), cfg.active_param_count(), tokens, "serve")

    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not expose it
        rec["memory"] = {"error": str(e)}

    cost = compiled.cost_analysis()
    coll = roofl.collective_bytes(compiled.as_text())
    rec["raw_cost"] = {  # per-device, scan bodies counted once (XLA quirk)
        "flops": _cost_get(cost, "flops"),
        "hbm_bytes": _cost_get(cost, "bytes accessed"),
        "coll_bytes": coll["total_bytes"],
    }
    if not with_probes:
        # multi-pod pass: compile success + memory plan is the deliverable;
        # the roofline table is single-pod only (§Roofline)
        rec.update(status="ok", collectives=coll, model_flops=model_flops)
        return rec

    # scan-exact reconstruction from unrolled probe lowerings (see header)
    t2 = time.time()
    probes = probe_costs(cfg, mesh, kind, info["seq"], info["batch"])
    rec["probe_s"] = round(time.time() - t2, 2)
    flops = probes["flops"] * chips  # per-device → global
    # memory term: structural TPU model — the CPU backend's unfused
    # "bytes accessed" (kept in raw_cost/probes) is not HBM-representative
    hbm = mem_model.hbm_bytes(cfg, kind, info["batch"], info["seq"])
    rec["cpu_bytes_probe"] = probes["bytes"] * chips
    coll_total = probes["coll"] * chips
    rl = roofl.roofline_terms(flops, hbm, coll_total, chips)
    rec.update(
        status="ok",
        flops=flops, hbm_bytes=hbm,
        collectives=coll,
        coll_bytes_total=coll_total,
        roofline=rl.to_dict(),
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / flops) if flops else None,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--policy", default="tp", choices=("tp", "dp"),
                    help="sharding policy (perf hillclimb knob)")
    ap.add_argument("--block-skip", action="store_true",
                    help="causal block skipping in flash attention (hillclimb)")
    ap.add_argument("--tag", default="", help="suffix for output files")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    args = ap.parse_args()

    # cheap-to-compile archs first so the table fills up early
    order = ("smollm-135m", "phi3-medium-14b", "granite-20b", "qwen1.5-110b",
             "phi-3-vision-4.2b", "whisper-medium", "deepseek-moe-16b",
             "moonshot-v1-16b-a3b", "mamba2-1.3b", "hymba-1.5b")
    archs = [a for a in order if a in configs.ARCH_IDS] if args.arch == "all" \
        else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(args.out_dir, exist_ok=True)

    import contextlib

    from repro.models.layers import causal_block_skipping

    knobs = contextlib.ExitStack()
    if args.policy != "tp":
        knobs.enter_context(sh.policy(args.policy))
    if args.block_skip:
        knobs.enter_context(causal_block_skipping())
    suffix = args.tag or ""
    if args.policy != "tp":
        suffix += f"_{args.policy}"
    if args.block_skip:
        suffix += "_skip"

    failures = 0
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}_{shape}_{'pod2' if args.multi_pod else 'pod1'}{suffix}"
            out_path = os.path.join(args.out_dir, tag + ".json")
            if os.path.exists(out_path):
                print(f"[dryrun] {tag}: cached")
                continue
            print(f"[dryrun] {tag}: lowering...", flush=True)
            try:
                rec = lower_cell(arch, shape, args.multi_pod,
                                 with_probes=not args.no_probes)
                rec["policy"] = args.policy
                rec["block_skip"] = args.block_skip
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "status": "FAILED",
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                failures += 1
            with open(out_path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec["status"] == "ok" and "roofline" in rec:
                r = rec["roofline"]
                print(f"[dryrun] {tag}: ok compile={rec['compile_s']}s "
                      f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
                      f"collective={r['collective_s']:.2e}s dom={r['dominant']}",
                      flush=True)
            elif rec["status"] == "ok":
                print(f"[dryrun] {tag}: ok compile={rec['compile_s']}s "
                      f"(no-probe pass)", flush=True)
            else:
                print(f"[dryrun] {tag}: {rec['status']} "
                      f"{rec.get('reason', rec.get('error', ''))}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
