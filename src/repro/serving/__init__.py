"""repro.serving"""
