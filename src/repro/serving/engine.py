"""Batched serving engine: prefill + jitted decode loop with sampling.

Fixed-batch engine (continuous batching reduces to refill-on-finish with the
deterministic cache layout; the decode step itself is batch-uniform).  Both
steps are jitted once per (batch, cache) geometry.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelApi


@dataclasses.dataclass
class SamplerConfig:
    temperature: float = 0.0  # 0 ⇒ greedy
    seed: int = 0


class Engine:
    def __init__(self, api: ModelApi, params, batch: int, max_seq: int,
                 mesh=None):
        self.api = api
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.mesh = mesh
        self._prefill = jax.jit(
            lambda p, c, **kw: api.prefill(p, c, mesh=mesh, **kw))
        self._decode = jax.jit(
            lambda p, t, c: api.decode_step(p, t, c, mesh=mesh))

    def generate(self, prompts: np.ndarray, n_tokens: int,
                 sampler: SamplerConfig = SamplerConfig(), **extra_inputs):
        """prompts: (batch, prompt_len) int32 → (batch, n_tokens) int32."""
        cache = self.api.init_cache(self.batch, self.max_seq)
        logits, cache = self._prefill(self.params, cache,
                                      tokens=jnp.asarray(prompts), **extra_inputs)
        key = jax.random.PRNGKey(sampler.seed)
        out = []
        tok = self._sample(logits, sampler, key)
        for i in range(n_tokens):
            out.append(np.asarray(tok))
            if i + 1 == n_tokens:
                break
            logits, cache = self._decode(self.params, tok, cache)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sampler, sub)
        return np.stack(out, axis=1)

    @staticmethod
    def _sample(logits, sampler: SamplerConfig, key):
        if sampler.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / sampler.temperature, axis=-1
                                      ).astype(jnp.int32)
