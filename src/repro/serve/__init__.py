"""repro.serve — discrete-event multi-tenant serving for mixed FHE traffic.

The online realisation of the paper's §4.2 scheduling policy:

  events   — generic event heap / clock / run loop (the DES kernel)
  policy   — FlashPolicy (shallow-per-affiliation + deep gang + priority
             preemption with spill/restore, optional ``deep_coop`` swift-lane
             sharing) and the sequential baseline, plus the ServingEngine,
             the timeline-validated ServeResult, and the cross-chip
             GangReservation barrier
  cluster  — multi-chip scale-out: a DES front-end router sharding one
             arrival stream over a homogeneous OR heterogeneous fleet in one
             shared loop (round-robin / join-shortest-queue / power-of-two /
             workload-affinity / hetero routing, a per-chip warm-set
             cold-start model, and cross-chip deep gangs with an explicit
             inter-chip link cost)
  traffic  — seeded Poisson / sharded / bursty / diurnal / trace-replay /
             closed-loop tenant sources (multi-source RNGs via
             SeedSequence.spawn) plus mix/fleet capacity estimators
  metrics  — SLO summary: latency & queueing percentiles (overall and
             per-kind), throughput, utilization (+ per-chip and per-chip-type
             views), fairness, starvation, gang/link totals, and the overload
             block (goodput, drop rate by kind/tenant, time-to-shed)

Overload protection (``AdmissionConfig``): per-tenant token buckets and a
utilization reserve at the cluster router plus an engine-level queue
timeout; rejected jobs end in the terminal ``JobState.SHED`` with their
queued events cancelled and never touch warm-sets or backlog estimators —
see docs/serving.md "Overload & admission".

Fault tolerance (``repro.serve.faults``): seeded chip-crash/recover,
transient-failure and straggler injection (``FaultPlan``/``FaultConfig``)
with recovery under a ``RetryPolicy`` — capped exponential backoff,
checkpoint resume from the last SRAM→HBM spill for deep jobs, lockstep
gang aborts, and health-aware routing that excludes dead chips — see
docs/serving.md "Fault tolerance & recovery".

Quick use::

    from repro.core.hardware import CRATERLAKE, F1PLUS, FLASH_FHE
    from repro import serve

    cfg = serve.traffic.PoissonConfig(rate_per_mcycle=4.0, n_jobs=64, seed=7)
    result = serve.serve(serve.traffic.poisson_jobs(cfg), FLASH_FHE)
    print(serve.metrics.summarize(result))

    fleet = serve.serve_cluster(serve.traffic.poisson_jobs(cfg),
                                chips=[FLASH_FHE, FLASH_FHE, CRATERLAKE, F1PLUS],
                                router="hetero", gang_max_chips=2)
    print(serve.summarize(fleet))

Service-time execution modes (kernel pipeline, rotation hoisting, numerics)
are selected with an ``repro.fhe.ExecPolicy`` (re-exported here):
``serve(..., exec_policy=ExecPolicy(backend="fused", hoisting="always"))``.
The policy's ``policy_key()`` keys the per-(chip, workload, kind) service
memo, so distinct modes never alias.

``repro.core.scheduler.schedule`` is a thin compatibility wrapper over this
package (``n_chips=`` routes through the cluster).
"""

from repro.fhe.context import ExecPolicy

from . import cluster, events, faults, metrics, policy, traffic
from .cluster import ClusterConfig, ClusterResult, ClusterRouter, serve_cluster
from .events import Event, EventLoop
from .faults import FAULT_KINDS, FaultConfig, FaultEvent, FaultPlan, RetryPolicy
from .metrics import (
    drop_rate_by_tenant,
    goodput_by_tenant,
    max_queueing_by_kind,
    per_chip_type_utilization,
    summarize,
    summarize_cluster,
)
from .policy import (
    AdmissionConfig,
    FlashPolicy,
    GangReservation,
    JobExec,
    JobState,
    Segment,
    SequentialPolicy,
    ServeResult,
    ServingEngine,
    TokenBucket,
    exec_policy_from_hoist,
    gang_link_bytes,
    gang_service_cycles,
    job_service_sim,
    serve,
    serve_source,
    working_set_bytes,
)
from .traffic import (
    BurstyConfig,
    ClosedLoopSource,
    DiurnalConfig,
    PoissonConfig,
    bursty_jobs,
    diurnal_jobs,
    diurnal_rate,
    fleet_capacity_jobs_per_mcycle,
    mix_capacity_jobs_per_mcycle,
    poisson_jobs,
    sharded_poisson_jobs,
    trace_jobs,
)
