"""repro.serve — discrete-event multi-tenant serving for mixed FHE traffic.

The online realisation of the paper's §4.2 scheduling policy:

  events   — generic event heap / clock / run loop (the DES kernel)
  policy   — FlashPolicy (shallow-per-affiliation + deep gang + priority
             preemption with spill/restore) and the sequential baseline,
             plus the ServingEngine and timeline-validated ServeResult
  traffic  — seeded Poisson / trace-replay / closed-loop tenant sources
  metrics  — SLO summary: latency & queueing percentiles, throughput,
             utilization, fairness

Quick use::

    from repro.core.hardware import FLASH_FHE
    from repro import serve

    cfg = serve.traffic.PoissonConfig(rate_per_mcycle=4.0, n_jobs=64, seed=7)
    result = serve.serve(serve.traffic.poisson_jobs(cfg), FLASH_FHE)
    print(serve.metrics.summarize(result))

``repro.core.scheduler.schedule`` is a thin compatibility wrapper over this
package.
"""

from . import events, metrics, policy, traffic
from .events import Event, EventLoop
from .metrics import summarize
from .policy import (
    FlashPolicy,
    JobExec,
    JobState,
    Segment,
    SequentialPolicy,
    ServeResult,
    ServingEngine,
    job_service_sim,
    serve,
    serve_source,
    working_set_bytes,
)
from .traffic import ClosedLoopSource, PoissonConfig, poisson_jobs, trace_jobs
