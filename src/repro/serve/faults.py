"""Seeded fault injection + retry policy for fleet serving.

The fault model covers the three failure classes a real accelerator fleet
sees (the SoK on FHE accelerators assumes datacenter deployment; EFFACT's
full-stack platform targets the same):

* **chip crash / recover** — a die goes dark: every job resident on it (and
  every gang it participates in) fails transiently, its backlog estimator is
  zeroed, and the router stops placing work on it until the matching
  ``recover`` event.  Recovered chips rejoin with a *cold* warm-set.
* **transient job failure** — a single running job dies (ECC fault, kernel
  abort) without taking the chip down.
* **slowdown (straggler) windows** — a chip runs at ``factor``× its nominal
  service time between ``slow_start``/``slow_end`` (thermal throttling, a
  noisy neighbour on the HBM bus).  Wall-clock excess is charged to
  ``wasted_cycles`` so work-conservation invariants stay checkable.

``FaultConfig`` draws a ``FaultPlan`` (a sorted list of ``FaultEvent``)
deterministically from a seed via per-chip spawned ``SeedSequence`` streams —
same seed, same plan, same ``ClusterResult``.  Scripted plans for benches
come from the classmethod helpers (``FaultPlan.single_crash`` etc.).

``RetryPolicy`` owns the recovery knobs: max attempts, capped exponential
backoff (in cycles), and whether deep jobs may resume from their last
SRAM→HBM spill (checkpoint) instead of restarting from zero.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "FaultEvent",
    "FaultPlan",
    "FaultConfig",
    "RetryPolicy",
    "FAULT_KINDS",
]

FAULT_KINDS = ("crash", "recover", "transient", "slow_start", "slow_end")


@dataclasses.dataclass(frozen=True, order=True)
class FaultEvent:
    """One injected fault, ordered by time for deterministic replay."""

    at: float  # cycle at which the fault fires
    chip: int  # victim chip index
    kind: str  # one of FAULT_KINDS
    factor: float = 1.0  # slowdown factor (slow_start only; > 1 means slower)

    def __post_init__(self):
        assert self.kind in FAULT_KINDS, f"unknown fault kind {self.kind!r}"
        assert self.at >= 0.0
        assert self.chip >= 0
        if self.kind == "slow_start":
            assert self.factor > 1.0, "slowdown factor must exceed 1.0"


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, time-sorted schedule of fault events.

    Build one from ``FaultConfig.draw()`` (seeded random plan) or from the
    scripted classmethods below (bench scenarios want exact timings).
    """

    events: tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(sorted(self.events)))

    def __len__(self) -> int:
        return len(self.events)

    def for_chip(self, chip: int) -> tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.chip == chip)

    # -- scripted scenario helpers -----------------------------------------

    @classmethod
    def single_crash(cls, chip: int, at: float, down: float) -> FaultPlan:
        """One chip dies at ``at`` and recovers ``down`` cycles later."""
        return cls(events=(
            FaultEvent(at=at, chip=chip, kind="crash"),
            FaultEvent(at=at + down, chip=chip, kind="recover"),
        ))

    @classmethod
    def straggler(cls, chip: int, at: float, span: float,
                  factor: float = 2.0) -> FaultPlan:
        """One chip runs ``factor``× slower for ``span`` cycles."""
        return cls(events=(
            FaultEvent(at=at, chip=chip, kind="slow_start", factor=factor),
            FaultEvent(at=at + span, chip=chip, kind="slow_end"),
        ))

    @classmethod
    def flaky(cls, chip: int, times) -> FaultPlan:
        """Transient single-job failures on ``chip`` at each time in ``times``."""
        return cls(events=tuple(
            FaultEvent(at=float(t), chip=chip, kind="transient") for t in times
        ))

    def merged(self, other: FaultPlan) -> FaultPlan:
        return FaultPlan(events=self.events + other.events)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Recovery knobs for transiently-failed jobs.

    ``max_attempts`` counts *retries* after the first attempt; 0 disables
    recovery entirely (the bench's no-recovery baseline).  Backoff for retry
    k (1-based) is ``min(backoff_cap, backoff_base * backoff_factor**(k-1))``
    cycles of re-queue delay.  ``checkpoint`` lets deep jobs resume from
    their last SRAM→HBM spill instead of restarting from zero.
    """

    max_attempts: int = 3
    backoff_base: float = 1000.0
    backoff_factor: float = 2.0
    backoff_cap: float = 64_000.0
    checkpoint: bool = True

    def __post_init__(self):
        assert self.max_attempts >= 0
        assert self.backoff_base >= 0.0
        assert self.backoff_factor >= 1.0
        assert self.backoff_cap >= self.backoff_base

    def backoff_cycles(self, attempt: int) -> float:
        """Delay before retry ``attempt`` (1-based count of prior failures)."""
        assert attempt >= 1
        return float(min(self.backoff_cap,
                         self.backoff_base * self.backoff_factor ** (attempt - 1)))


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Seeded random fault-plan generator.

    Per chip, crash arrivals follow a Poisson process with mean inter-crash
    gap ``mtbf_cycles`` and exponential downtime with mean ``mttr_cycles``
    (next crash is drawn after the recovery, so windows never overlap on one
    chip).  Independent streams draw transient job failures
    (``transient_rate`` per Mcycle) and slowdown windows
    (``slow_rate`` per Mcycle, span ``slow_span_cycles``, factor
    ``slow_factor``).  All randomness descends from ``seed`` via spawned
    ``SeedSequence`` streams, one per (chip, fault-class), so plans are
    reproducible and chips are independent.
    """

    seed: int = 0
    horizon_cycles: float = 1e6
    mtbf_cycles: float | None = None  # mean cycles between crashes; None = no crashes
    mttr_cycles: float = 50_000.0  # mean downtime per crash
    transient_rate: float = 0.0  # transient job failures per Mcycle per chip
    slow_rate: float = 0.0  # slowdown windows per Mcycle per chip
    slow_span_cycles: float = 50_000.0
    slow_factor: float = 2.0

    def __post_init__(self):
        assert self.horizon_cycles > 0.0
        assert self.mtbf_cycles is None or self.mtbf_cycles > 0.0
        assert self.mttr_cycles > 0.0
        assert self.transient_rate >= 0.0
        assert self.slow_rate >= 0.0
        assert self.slow_span_cycles > 0.0
        assert self.slow_factor > 1.0

    def draw(self, n_chips: int) -> FaultPlan:
        """Materialise a deterministic plan over ``n_chips`` chips."""
        root = np.random.SeedSequence(self.seed)
        streams = root.spawn(3 * n_chips)
        events: list[FaultEvent] = []
        for chip in range(n_chips):
            crash_rng = np.random.default_rng(streams[3 * chip + 0])
            trans_rng = np.random.default_rng(streams[3 * chip + 1])
            slow_rng = np.random.default_rng(streams[3 * chip + 2])
            if self.mtbf_cycles is not None:
                t = float(crash_rng.exponential(self.mtbf_cycles))
                while t < self.horizon_cycles:
                    down = float(crash_rng.exponential(self.mttr_cycles))
                    events.append(FaultEvent(at=t, chip=chip, kind="crash"))
                    up = t + down
                    if up < self.horizon_cycles:
                        events.append(FaultEvent(at=up, chip=chip, kind="recover"))
                    t = up + float(crash_rng.exponential(self.mtbf_cycles))
            if self.transient_rate > 0.0:
                gap = 1e6 / self.transient_rate
                t = float(trans_rng.exponential(gap))
                while t < self.horizon_cycles:
                    events.append(FaultEvent(at=t, chip=chip, kind="transient"))
                    t += float(trans_rng.exponential(gap))
            if self.slow_rate > 0.0:
                gap = 1e6 / self.slow_rate
                t = float(slow_rng.exponential(gap))
                while t < self.horizon_cycles:
                    span = self.slow_span_cycles
                    events.append(FaultEvent(
                        at=t, chip=chip, kind="slow_start", factor=self.slow_factor))
                    end = t + span
                    if end < self.horizon_cycles:
                        events.append(FaultEvent(at=end, chip=chip, kind="slow_end"))
                    t = end + float(slow_rng.exponential(gap))
        return FaultPlan(events=tuple(events))
