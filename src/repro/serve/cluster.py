"""Multi-chip serving scale-out: a DES front-end router over a (possibly
heterogeneous) fleet of FHE accelerator chips.

One FLASH-FHE die saturates quickly under shallow-heavy Poisson streams (8
affiliations × ~0.15 Mcycle shallow services ≈ 50 jobs/Mcycle); the ROADMAP's
"millions of users" north star is a fleet problem.  This module shards a
single arrival stream across per-chip ``ServingEngine``s that all tick inside
ONE shared ``EventLoop`` — the router is itself a discrete-event component:
each arrival fires a routing event, the chosen engine schedules the job, and
completions flow back through the engine's ``on_job_complete`` hook to keep
the router's backlog estimates exact.

Fleet shape: homogeneous (``n_chips`` copies of one ``ChipConfig``) or
heterogeneous — ``ClusterConfig.chips`` takes a per-chip list of
``(ChipConfig, ExecPolicy)`` pairs, so a fleet can mix FLASH-FHE, CraterLake
and F1+ dies with different kernel/hoisting modes per chip (service-time
memoisation keys on ``ExecPolicy.policy_key()``, so mixed modes never alias).

Dispatch policies (``ClusterConfig.router``):

  round_robin  — cyclic, state-free; the baseline every queueing text beats
  jsq          — join-shortest-queue by *estimated backlog cycles* (the sum of
                 outstanding routed service demand per chip); near-optimal
                 when service demand is known, as it is here (the cycle-level
                 simulator prices every job before placement)
  po2          — power-of-two-choices: sample two chips with the router's own
                 seeded RNG, keep the shorter backlog; O(1) state reads with
                 most of jsq's benefit (Mitzenmacher's classic result)
  affinity     — workload-affinity: route to the chip minimising
                 ``backlog + cold_start_penalty``, where the penalty is the
                 HBM cost of faulting the job's KSK/plaintext working set
                 (``working_set_bytes / hbm_bytes_per_cycle × cold_factor``)
                 into a chip whose warm-set doesn't hold it.  With penalties
                 zeroed this degrades to jsq exactly.
  hetero       — heterogeneity-aware: minimise ``backlog + THIS chip's
                 service time for THIS job + cold penalty``.  On a mixed
                 fleet this is what routes deep jobs toward big-cache
                 bootstrappable-heavy chips and shallow floods toward
                 multi-affiliation chips; on a homogeneous fleet it degrades
                 to ``affinity``.

Cross-chip deep gangs (``ClusterConfig.gang_max_chips > 1``): a deep job may
split across up to M identical FlashPolicy chips' bootstrappable clusters.
Per-chip compute shards M ways, and each fragment additionally stalls through
the serialized inter-chip link exchanges (``policy.gang_service_cycles``;
bandwidth ``ClusterConfig.link_bytes_per_cycle``, priced ≫ the on-chip L3
transpose).  The planner compares the best gang's estimated completion
(barrier wait = the most-backlogged member, plus the per-chip gang demand)
against the best single-chip placement and only commits a multi-chip
``GangReservation`` when the gang strictly wins — queueing delay is weighed
against split speedup at routing time.  Gang fragments skip the warm-set
model (the gang streams its state through the link, not the per-chip LRU).

Warm-set model: every chip keeps an LRU of workload working sets capped at
its shared-L2 capacity (configurable).  ALL policies pay the cold-start
penalty on a warm-set miss — residency is a property of the chip, not of the
router — but only ``affinity``/``hetero`` *steer around* it.  The penalty is
charged into the job's service demand (``ServingEngine.submit``) so the
per-chip timeline invariants (work conservation, no overlap) hold
penalty-inclusive and ``ClusterResult.validate`` can re-assert them.

Quick use::

    from repro.core.hardware import CRATERLAKE, F1PLUS, FLASH_FHE
    from repro import serve

    jobs = serve.poisson_jobs(serve.PoissonConfig(rate_per_mcycle=200.0,
                                                  n_jobs=320, seed=7))
    mixed = serve.serve_cluster(
        jobs, chips=[FLASH_FHE, FLASH_FHE, CRATERLAKE, F1PLUS],
        router="hetero", gang_max_chips=2)
    print(serve.summarize(mixed))           # fleet-level SLOs
"""

from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict

import numpy as np

from repro.core.cache import MB
from repro.core.hardware import ChipConfig
from repro.core.jobs import FheJob
from repro.fhe.context import ExecPolicy

from .events import EventLoop
from .policy import (
    GANG_SYNCS,
    AdmissionConfig,
    FlashPolicy,
    GangReservation,
    JobExec,
    JobState,
    ServeResult,
    ServingEngine,
    TokenBucket,
    gang_link_bytes,
    gang_service_cycles,
    working_set_bytes,
)

ROUTERS = ("round_robin", "jsq", "po2", "affinity", "hetero")


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Fleet shape + router policy + warm-set/cold-start + gang model."""

    n_chips: int = 0  # 0 = derive from ``chips`` (one of the two is required)
    router: str = "jsq"
    seed: int = 0  # router-local RNG (po2 sampling) — split off via SeedSequence
    cold_start: bool = True  # model warm-set misses at all?
    cold_factor: float = 2.0  # penalty = factor × working_set_bytes / hbm_B_per_cycle
    warm_capacity_mb: float | None = None  # per-chip warm-set cap; default: chip L2
    hoist: bool = False  # legacy bool spelling of the hoisted-rotation kernel mode
    # service-time execution policy per engine; wins over ``hoist`` when set —
    # its ``policy_key()`` is what keys the per-(chip, workload, kind) memo
    exec_policy: ExecPolicy | None = None
    # heterogeneous fleet: one (ChipConfig, ExecPolicy | None) pair per chip
    # (bare ChipConfig entries are accepted; ``exec_policy`` fills the gaps).
    # ``None`` = homogeneous fleet of ``n_chips`` × the serve_cluster chip.
    chips: tuple | None = None
    # cross-chip deep gangs: a deep job may split across up to this many
    # identical FlashPolicy chips (1 = gangs off)
    gang_max_chips: int = 1
    # inter-chip link bandwidth the gang exchanges are serialized through.
    # 256 B/cycle = 4× slower than one chip's HBM (1024 B/cycle) and 32×
    # slower than the 2048-port on-chip L3 transpose — crossing the package
    # boundary is deliberately expensive
    link_bytes_per_cycle: float = 256.0
    gang_syncs: int = GANG_SYNCS  # global barriers per ganged deep job
    # overload protection (None = admit everything, the historical behaviour):
    # utilization reserve + per-tenant token buckets at the router, and an
    # engine-level queue timeout — see ``policy.AdmissionConfig``
    admission: AdmissionConfig | None = None

    def __post_init__(self):
        if self.admission is not None and not isinstance(self.admission, AdmissionConfig):
            raise ValueError(
                f"admission must be an AdmissionConfig, got {type(self.admission).__name__}")
        if self.chips is not None:
            norm = []
            for entry in self.chips:
                if isinstance(entry, ChipConfig):
                    norm.append((entry, self.exec_policy))
                else:
                    c, p = entry
                    norm.append((c, p if p is not None else self.exec_policy))
            object.__setattr__(self, "chips", tuple(norm))
            if self.n_chips == 0:
                object.__setattr__(self, "n_chips", len(norm))
            elif self.n_chips != len(norm):
                raise ValueError(
                    f"n_chips={self.n_chips} disagrees with len(chips)={len(norm)}")
        if self.n_chips < 1:
            raise ValueError(f"n_chips must be >= 1, got {self.n_chips}")
        if self.router not in ROUTERS:
            raise ValueError(f"unknown router {self.router!r}; choose from {ROUTERS}")
        if self.gang_max_chips < 1:
            raise ValueError(f"gang_max_chips must be >= 1, got {self.gang_max_chips}")
        if self.link_bytes_per_cycle <= 0:
            raise ValueError("link_bytes_per_cycle must be positive")
        if self.gang_syncs < 0:
            raise ValueError("gang_syncs must be >= 0")

    def chip_pairs(self, default_chip: ChipConfig | None = None) -> tuple:
        """The fleet as (ChipConfig, ExecPolicy | None) pairs, one per chip."""
        if self.chips is not None:
            return self.chips
        if default_chip is None:
            raise ValueError("homogeneous ClusterConfig needs a default chip")
        return tuple((default_chip, self.exec_policy) for _ in range(self.n_chips))


@dataclasses.dataclass
class ClusterResult:
    """Per-chip timelines + the merged fleet view.

    ``jobs`` holds one ``JobExec`` per routed job in submission order; for a
    ganged deep job that is its rank-0 (primary) fragment — the other
    fragments live only in their chips' ``chip_results`` timelines, and
    ``gangs`` maps the job id to the full member-chip tuple.
    """

    chip: ChipConfig  # primary/default chip (chips[0] on heterogeneous fleets)
    config: ClusterConfig
    chip_results: list[ServeResult]  # NB: each carries the SHARED loop's event
    # total in events_processed (per-chip attribution is not meaningful when
    # one clock drives every engine); the fleet-wide count lives below
    jobs: list[JobExec]  # submission order (matching ``serve.serve`` semantics)
    placements: dict[int, int]  # job_id -> chip index (primary member for gangs)
    makespan: float
    events_processed: int
    chips: list[ChipConfig] = dataclasses.field(default_factory=list)  # per-chip
    gangs: dict[int, tuple[int, ...]] = dataclasses.field(default_factory=dict)
    # router state snapshots at drain (admission/overload observability):
    # per-chip backlog estimators (should both be ~0 after a full drain and
    # are invariant-checked non-negative with serial <= total), the peak
    # fleet-wide backlog over the run (the "are queues bounded?" observable),
    # and shed counts by trigger ("token_bucket" / "reserve" / "timeout")
    final_backlog: list[float] = dataclasses.field(default_factory=list)
    final_backlog_serial: list[float] = dataclasses.field(default_factory=list)
    peak_backlog_cycles: float = 0.0
    shed_reasons: dict[str, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.chips:
            self.chips = [self.chip] * self.config.n_chips

    @property
    def n_chips(self) -> int:
        return self.config.n_chips

    def validate(self) -> "ClusterResult":
        """Fleet invariants on top of each chip's own ``ServeResult.validate``:
        every non-gang job completed on EXACTLY one chip (or was shed); every
        gang job ran EXACTLY once on each reserved member chip (never
        double-booked, never anywhere else) with its fragments finishing in
        lockstep; the recorded placements match the per-chip timelines;
        admission-shed jobs appear on NO chip and in NO placement; the
        backlog estimators never drift negative (and the serial component
        never exceeds the total); and the fleet makespan is the max over
        chips."""
        for r in self.chip_results:
            r.validate()
        on_chips: dict[int, list[int]] = {}
        frags: dict[int, list[JobExec]] = {}
        for i, r in enumerate(self.chip_results):
            for je in r.jobs:
                jid = je.job.job_id
                assert i not in on_chips.get(jid, ()), (
                    f"job {jid} double-booked on chip {i}"
                )
                assert je.chip_index == i, (
                    f"job {jid} tagged chip {je.chip_index}, found on chip {i}"
                )
                on_chips.setdefault(jid, []).append(i)
                frags.setdefault(jid, []).append(je)
        # router-shed jobs (chip_index < 0): rejected at the door, so they
        # must never have reached a chip timeline, a placement, or a warm-set
        # (the cold_start_cycles charge is the warm-set's observable)
        router_shed = {je.job.job_id for je in self.jobs
                       if je.state is JobState.SHED and je.chip_index < 0}
        for je in self.jobs:
            if je.job.job_id in router_shed:
                assert not je.segments and je.completion is None
                assert je.shed_cycle is not None and je.cold_start_cycles == 0.0
        assert not router_shed & set(on_chips), (
            f"admission-shed jobs found on chips: {sorted(router_shed & set(on_chips))}"
        )
        assert not router_shed & set(self.placements), (
            "admission-shed jobs leaked into router placements"
        )
        for name, arr in (("backlog", self.final_backlog),
                          ("backlog_serial", self.final_backlog_serial)):
            for i, v in enumerate(arr):
                assert v >= 0.0, f"chip {i} {name} estimator drifted negative: {v}"
        for i, (total, serial) in enumerate(zip(self.final_backlog,
                                                self.final_backlog_serial)):
            assert serial <= total + 1e-6 * max(1.0, total), (
                f"chip {i} serial backlog {serial} exceeds total {total}"
            )
        for jid, used in on_chips.items():
            members = self.gangs.get(jid)
            if members is None:
                assert len(used) == 1, f"non-gang job {jid} ran on chips {used}"
                assert self.placements[jid] == used[0], (
                    f"job {jid} placed on chip {self.placements[jid]}, ran on {used[0]}"
                )
                continue
            assert len(set(members)) == len(members), (
                f"gang {jid} reserves chip(s) twice: {members}"
            )
            assert sorted(used) == sorted(members), (
                f"gang job {jid} ran on chips {used}, reserved {members}"
            )
            assert self.placements[jid] == members[0]
            fs = frags[jid]
            assert all(f.gang_size == len(members) for f in fs)
            comps = [f.completion for f in fs]
            assert max(comps) - min(comps) <= 1e-6 * max(1.0, max(comps)), (
                f"gang job {jid} fragments finished out of lockstep: {comps}"
            )
        assert set(on_chips) == set(self.placements), (
            "router placements disagree with chip timelines"
        )
        assert len(self.jobs) == len(on_chips) + len(router_shed), (
            f"{len(self.jobs)} jobs routed, {len(on_chips)} found on chips "
            f"+ {len(router_shed)} shed at admission"
        )
        per_chip_mk = max((r.makespan for r in self.chip_results), default=0.0)
        assert abs(self.makespan - per_chip_mk) <= 1e-6 * max(1.0, per_chip_mk)
        return self


class ClusterRouter:
    """Front-end DES router: shards one arrival stream over N engines."""

    def __init__(self, chip: ChipConfig | None, config: ClusterConfig,
                 loop: EventLoop | None = None):
        pairs = config.chip_pairs(chip)
        self.chip = chip if chip is not None else pairs[0][0]
        self.config = config
        self.loop = loop if loop is not None else EventLoop()
        self.chips = [c for c, _ in pairs]
        adm = config.admission
        self.engines = [ServingEngine(c, loop=self.loop, hoist=config.hoist,
                                      exec_policy=p,
                                      shed_after=(adm.shed_after_cycles
                                                  if adm is not None else None))
                        for c, p in pairs]
        for i, eng in enumerate(self.engines):
            eng.on_job_complete = functools.partial(self._completed, i)
            eng.on_job_shed = functools.partial(self._shed_echo, i)
        # per-tenant token buckets, created lazily on first arrival
        self._buckets: dict[int, TokenBucket] = {}
        self.shed_reasons: dict[str, int] = {}
        # peak fleet-wide backlog estimate over the run: THE bounded-queues
        # observable (without admission it grows with the overload integral,
        # with admission it plateaus near the utilization reserve)
        self.peak_backlog = 0.0
        # estimated outstanding service cycles per chip: the simulator prices
        # each job at routing time and completions echo back.  An estimate,
        # not an oracle — spill/restore added to a preempted deep job after
        # placement is not re-echoed into the backlog
        self.backlog = [0.0] * config.n_chips
        # the deep-job component of each backlog: deep service occupies a
        # whole chip (all affiliations), so it drains serially even on a
        # multi-affiliation chip — the wait estimator prices it at full width
        self.backlog_serial = [0.0] * config.n_chips
        self.placements: dict[int, int] = {}
        self.gangs: dict[int, tuple[int, ...]] = {}  # job_id -> member chips
        self._submit_order: list[int] = []  # job_ids in submission order
        self._seen_ids: set[int] = set()
        self._by_id: dict[int, JobExec] = {}
        self._rr_next = 0
        self._rng = np.random.default_rng(np.random.SeedSequence(config.seed))
        self._warm_cap = [
            (config.warm_capacity_mb if config.warm_capacity_mb is not None
             else c.l2_mb) * MB
            for c in self.chips]
        self._warm: list[OrderedDict[str, float]] = [OrderedDict() for _ in range(config.n_chips)]
        # gang-capable chips, grouped by identical pricing — fragments must
        # progress in lockstep, so members share (chip, policy_key, coop)
        groups: dict[tuple, list[int]] = {}
        for i, eng in enumerate(self.engines):
            if isinstance(eng.policy, FlashPolicy):
                key = (eng.chip, eng.exec_policy.policy_key(),
                       eng.policy.deep_coop)
                groups.setdefault(key, []).append(i)
        self._gang_groups = [idxs for idxs in groups.values() if len(idxs) >= 2]

    # -- submission ---------------------------------------------------------

    def submit(self, job: FheJob) -> None:
        """Schedule the routing decision at the job's arrival instant."""
        assert job.job_id not in self._seen_ids, (
            f"duplicate job_id {job.job_id}: the router keys placements by id"
        )
        self._seen_ids.add(job.job_id)
        self._submit_order.append(job.job_id)
        self.loop.call_at(max(self.loop.now, float(job.arrival_cycle)),
                          lambda: self._route(job))

    # -- dispatch policies --------------------------------------------------

    def _pick(self, job: FheJob) -> int:
        n = self.config.n_chips
        if n == 1:
            return 0
        r = self.config.router
        if r == "round_robin":
            i = self._rr_next % n
            self._rr_next += 1
            return i
        if r == "jsq":
            return min(range(n), key=lambda i: (self.backlog[i], i))
        if r == "po2":
            a, b = (int(x) for x in self._rng.choice(n, size=2, replace=False))
            return a if (self.backlog[a], a) <= (self.backlog[b], b) else b
        if r == "affinity":
            # total marginal cost = backlog + the cold-start you'd pay
            return min(range(n), key=lambda i: (self.backlog[i] + self._cold_penalty(job, i), i))
        # hetero: like affinity, but also price THIS chip's service time for
        # THIS job — on a mixed fleet the estimate is what steers deep jobs to
        # bootstrappable-heavy chips and shallow floods to swift-heavy ones
        return min(range(n), key=lambda i: (self._est(job, i), i))

    def _drain_width(self, i: int) -> int:
        """How many jobs chip i retires concurrently: a FlashPolicy chip
        drains a (shallow-dominated) backlog one job per affiliation, a
        sequential chip one at a time.  Raw backlog cycles would overstate a
        multi-affiliation chip's congestion by exactly this factor."""
        eng = self.engines[i]
        return eng.chip.n_affiliations if isinstance(eng.policy, FlashPolicy) else 1

    def _wait(self, i: int) -> float:
        """Estimated wall-clock cycles until chip i drains its backlog: the
        shallow component retires ``_drain_width`` jobs at a time, the deep
        component (whole-chip gangs) serially."""
        serial = self.backlog_serial[i]
        parallel = max(0.0, self.backlog[i] - serial)
        return parallel / self._drain_width(i) + serial

    def _est(self, job: FheJob, i: int) -> float:
        """Estimated completion of ``job`` on chip i: the backlog's wall-clock
        drain time plus this chip's service time for this job (+ cold start)."""
        return (self._wait(i)
                + self.engines[i].service_sim(job).cycles
                + self._cold_penalty(job, i))

    # -- cross-chip gang planner --------------------------------------------

    def _plan_gang(self, job: FheJob) -> list[int] | None:
        """Pick gang members for a deep job, or ``None`` to stay single-chip.

        For every group of identically-priced gang-capable chips, try widths
        M = 2..gang_max_chips over the M least-loaded members: estimated
        completion = the most-loaded member's drain time (the lockstep
        barrier waits for it) + the per-chip gang demand (compute/M + link
        stalls).  Commit only if the best gang strictly beats the best
        single-chip estimate — split speedup is weighed against the queueing
        delay of aligning M chips."""
        if not self._gang_groups:
            return None
        best_single = min(self._est(job, i) for i in range(self.config.n_chips))
        best: tuple[float, int, list[int]] | None = None
        for idxs in self._gang_groups:
            single = self.engines[idxs[0]].service_sim(job).cycles
            order = sorted(idxs, key=lambda i: (self._wait(i), i))
            for m in range(2, min(self.config.gang_max_chips, len(order)) + 1):
                members = order[:m]
                per_chip, _ = gang_service_cycles(
                    single, job, m, self.config.link_bytes_per_cycle,
                    self.config.gang_syncs)
                est = max(self._wait(i) for i in members) + per_chip
                if best is None or (est, m) < (best[0], best[1]):
                    best = (est, m, members)
        if best is not None and best[0] < best_single:
            return best[2]
        return None

    # -- warm-set / cold-start model ----------------------------------------

    def _cold_penalty(self, job: FheJob, i: int) -> float:
        if not self.config.cold_start or job.workload in self._warm[i]:
            return 0.0
        return (self.config.cold_factor * working_set_bytes(job)
                / self.chips[i].hbm_bytes_per_cycle)

    def _touch_warm(self, job: FheJob, i: int) -> None:
        w = self._warm[i]
        if job.workload in w:
            w.move_to_end(job.workload)
        else:
            w[job.workload] = working_set_bytes(job)
        while len(w) > 1 and sum(w.values()) > self._warm_cap[i]:
            w.popitem(last=False)  # evict least-recently-used working set

    # -- admission control ---------------------------------------------------

    def _admission_verdict(self, job: FheJob) -> str | None:
        """``None`` = admit; otherwise the shed trigger ("token_bucket" /
        "reserve").  The bucket is charged first — an over-rate tenant pays
        with its own tokens before it can even contend for fleet capacity."""
        adm = self.config.admission
        if adm is None:
            return None
        if adm.tenant_rate_per_mcycle is not None:
            bucket = self._buckets.get(job.tenant_id)
            if bucket is None:
                bucket = self._buckets[job.tenant_id] = TokenBucket(
                    adm.tenant_rate_per_mcycle, adm.tenant_burst)
            if not bucket.try_take(self.loop.now):
                return "token_bucket"
        if adm.max_wait_cycles is not None:
            best = min(self._wait(i) for i in range(self.config.n_chips))
            if best > adm.max_wait_cycles:
                return "reserve"
        return None

    def _shed_at_door(self, job: FheJob, reason: str) -> None:
        """Admission rejection: terminal SHED without touching any engine,
        warm-set, or backlog estimator.  The record keeps the job visible to
        the metrics layer (drop rate by tenant/kind) via ``ClusterResult.jobs``
        with the sentinel ``chip_index = -1``."""
        je = JobExec(job=job, service_cycles=0.0, sim=None, lanes="",
                     state=JobState.SHED, chip_index=-1)
        je.shed_cycle = self.loop.now
        self._by_id[job.job_id] = je
        self.shed_reasons[reason] = self.shed_reasons.get(reason, 0) + 1

    def _note_backlog(self) -> None:
        self.peak_backlog = max(self.peak_backlog, sum(self.backlog))

    # -- event handlers ------------------------------------------------------

    def _route(self, job: FheJob) -> None:
        verdict = self._admission_verdict(job)
        if verdict is not None:
            self._shed_at_door(job, verdict)
            return
        if job.kind == "deep" and self.config.gang_max_chips > 1:
            members = self._plan_gang(job)
            if members is not None:
                self._route_gang(job, members)
                return
        i = self._pick(job)
        pay = self._cold_penalty(job, i)  # counted in metrics via cold_start_cycles
        self._touch_warm(job, i)
        je = self.engines[i].submit(job, extra_cycles=pay)
        je.chip_index = i
        self.placements[job.job_id] = i
        self._by_id[job.job_id] = je
        self.backlog[i] += je.service_cycles
        if job.kind == "deep":
            self.backlog_serial[i] += je.service_cycles
        self._note_backlog()

    def _route_gang(self, job: FheJob, members: list[int]) -> None:
        """Commit a multi-chip reservation: one lockstep fragment per member.

        Every fragment carries the full per-chip gang demand (compute/M +
        link stalls) so each member chip's work conservation validates; the
        rank-0 fragment is the job's primary record (``ClusterResult.jobs``)
        and additionally logs the gang-total link bytes."""
        eng = self.engines[members[0]]
        sim = eng.service_sim(job)
        per_chip, link = gang_service_cycles(
            sim.cycles, job, len(members), self.config.link_bytes_per_cycle,
            self.config.gang_syncs)
        gang = GangReservation(job, self.loop)
        for rank, i in enumerate(members):
            je = self.engines[i].submit(job, sim=sim, service_cycles=per_chip,
                                        gang=gang)
            je.chip_index = i
            je.gang_rank = rank
            je.gang_size = len(members)
            je.link_cycles = link
            if rank == 0:
                je.link_bytes = gang_link_bytes(job, len(members),
                                                self.config.gang_syncs)
                self._by_id[job.job_id] = je
            self.backlog[i] += je.service_cycles
            self.backlog_serial[i] += je.service_cycles
        self.placements[job.job_id] = members[0]
        self.gangs[job.job_id] = tuple(members)
        self._note_backlog()

    def _debit_backlog(self, i: int, je: JobExec) -> None:
        """Echo a job's routed service demand back out of chip i's estimators.

        Every decrement clamps at 0.0 — actual service can diverge from the
        routed estimate (preemption spill/restore accrues after placement,
        gang suspensions re-price remaining work), so naive subtraction can
        drift the estimators negative and then *attract* the jsq/po2/hetero
        routers to phantom capacity.  The serial component is additionally
        clamped to never exceed the total (``ClusterResult.validate`` asserts
        both invariants on the drained snapshot)."""
        self.backlog[i] = max(0.0, self.backlog[i] - je.service_cycles)
        if je.kind == "deep":
            self.backlog_serial[i] = max(
                0.0, self.backlog_serial[i] - je.service_cycles)
        self.backlog_serial[i] = min(self.backlog_serial[i], self.backlog[i])

    def _completed(self, i: int, je: JobExec) -> None:
        self._debit_backlog(i, je)

    def _shed_echo(self, i: int, je: JobExec) -> None:
        """A queue-timeout shed un-books the backlog the router charged at
        routing time (the job will never run), so the estimators keep
        tracking genuinely outstanding work."""
        self._debit_backlog(i, je)
        self.shed_reasons["timeout"] = self.shed_reasons.get("timeout", 0) + 1

    # -- run -----------------------------------------------------------------

    def run(self) -> ClusterResult:
        self.loop.run()
        chip_results = [eng.result() for eng in self.engines]
        makespan = max((r.makespan for r in chip_results), default=0.0)
        jobs = [self._by_id[jid] for jid in self._submit_order]  # submission order
        return ClusterResult(chip=self.chip, config=self.config,
                             chip_results=chip_results, jobs=jobs,
                             placements=dict(self.placements), makespan=makespan,
                             events_processed=self.loop.processed,
                             chips=list(self.chips), gangs=dict(self.gangs),
                             final_backlog=list(self.backlog),
                             final_backlog_serial=list(self.backlog_serial),
                             peak_backlog_cycles=self.peak_backlog,
                             shed_reasons=dict(self.shed_reasons))


def serve_cluster(jobs: list[FheJob], chip: ChipConfig | None = None, n_chips: int = 2,
                  router: str = "jsq", seed: int = 0, cold_start: bool = True,
                  cold_factor: float = 2.0, warm_capacity_mb: float | None = None,
                  config: ClusterConfig | None = None,
                  validate: bool = True, hoist: bool = False,
                  exec_policy: ExecPolicy | None = None,
                  chips=None, gang_max_chips: int = 1,
                  link_bytes_per_cycle: float = 256.0,
                  gang_syncs: int = GANG_SYNCS,
                  admission: AdmissionConfig | None = None) -> ClusterResult:
    """Serve an open-loop job list on a chip fleet; the one-call API.

    Homogeneous fleet: pass ``chip`` + ``n_chips``.  Heterogeneous fleet:
    pass ``chips=`` a per-chip list of ``ChipConfig`` or ``(ChipConfig,
    ExecPolicy)`` entries (``chip``/``n_chips`` are then ignored).
    ``gang_max_chips > 1`` lets deep jobs gang across identical FlashPolicy
    chips with link exchanges priced at ``link_bytes_per_cycle``.  Pass
    ``config=`` to reuse a prepared ``ClusterConfig`` (the other keyword
    arguments are ignored in that case); ``exec_policy`` sets the per-engine
    service-time execution policy (wins over the legacy ``hoist=`` bool).
    ``admission=`` arms overload protection (``AdmissionConfig``: per-tenant
    token buckets + utilization reserve at the router, queue-timeout at the
    engines); rejected jobs end ``JobState.SHED`` and surface through the
    drop-rate/goodput metrics rather than growing the backlog.
    """
    cfg = config if config is not None else ClusterConfig(
        n_chips=0 if chips is not None else n_chips, router=router, seed=seed,
        cold_start=cold_start, cold_factor=cold_factor,
        warm_capacity_mb=warm_capacity_mb, hoist=hoist, exec_policy=exec_policy,
        chips=tuple(chips) if chips is not None else None,
        gang_max_chips=gang_max_chips, link_bytes_per_cycle=link_bytes_per_cycle,
        gang_syncs=gang_syncs, admission=admission)
    rt = ClusterRouter(chip, cfg)
    for job in jobs:
        rt.submit(job)
    result = rt.run()
    return result.validate() if validate else result
