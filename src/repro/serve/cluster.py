"""Multi-chip serving scale-out: a DES front-end router over a (possibly
heterogeneous) fleet of FHE accelerator chips.

One FLASH-FHE die saturates quickly under shallow-heavy Poisson streams (8
affiliations × ~0.15 Mcycle shallow services ≈ 50 jobs/Mcycle); the ROADMAP's
"millions of users" north star is a fleet problem.  This module shards a
single arrival stream across per-chip ``ServingEngine``s that all tick inside
ONE shared ``EventLoop`` — the router is itself a discrete-event component:
each arrival fires a routing event, the chosen engine schedules the job, and
completions flow back through the engine's ``on_job_complete`` hook to keep
the router's backlog estimates exact.

Fleet shape: homogeneous (``n_chips`` copies of one ``ChipConfig``) or
heterogeneous — ``ClusterConfig.chips`` takes a per-chip list of
``(ChipConfig, ExecPolicy)`` pairs, so a fleet can mix FLASH-FHE, CraterLake
and F1+ dies with different kernel/hoisting modes per chip (service-time
memoisation keys on ``ExecPolicy.policy_key()``, so mixed modes never alias).

Dispatch policies (``ClusterConfig.router``):

  round_robin  — cyclic, state-free; the baseline every queueing text beats
  jsq          — join-shortest-queue by *estimated backlog cycles* (the sum of
                 outstanding routed service demand per chip); near-optimal
                 when service demand is known, as it is here (the cycle-level
                 simulator prices every job before placement)
  po2          — power-of-two-choices: sample two chips with the router's own
                 seeded RNG, keep the shorter backlog; O(1) state reads with
                 most of jsq's benefit (Mitzenmacher's classic result)
  affinity     — workload-affinity: route to the chip minimising
                 ``backlog + cold_start_penalty``, where the penalty is the
                 HBM cost of faulting the job's KSK/plaintext working set
                 (``working_set_bytes / hbm_bytes_per_cycle × cold_factor``)
                 into a chip whose warm-set doesn't hold it.  With penalties
                 zeroed this degrades to jsq exactly.
  hetero       — heterogeneity-aware: minimise ``backlog + THIS chip's
                 service time for THIS job + cold penalty``.  On a mixed
                 fleet this is what routes deep jobs toward big-cache
                 bootstrappable-heavy chips and shallow floods toward
                 multi-affiliation chips; on a homogeneous fleet it degrades
                 to ``affinity``.

Cross-chip deep gangs (``ClusterConfig.gang_max_chips > 1``): a deep job may
split across up to M identical FlashPolicy chips' bootstrappable clusters.
Per-chip compute shards M ways, and each fragment additionally stalls through
the serialized inter-chip link exchanges (``policy.gang_service_cycles``;
bandwidth ``ClusterConfig.link_bytes_per_cycle``, priced ≫ the on-chip L3
transpose).  The planner compares the best gang's estimated completion
(barrier wait = the most-backlogged member, plus the per-chip gang demand)
against the best single-chip placement and only commits a multi-chip
``GangReservation`` when the gang strictly wins — queueing delay is weighed
against split speedup at routing time.  Gang fragments skip the warm-set
model (the gang streams its state through the link, not the per-chip LRU).

Warm-set model: every chip keeps an LRU of workload working sets capped at
its shared-L2 capacity (configurable).  ALL policies pay the cold-start
penalty on a warm-set miss — residency is a property of the chip, not of the
router — but only ``affinity``/``hetero`` *steer around* it.  The penalty is
charged into the job's service demand (``ServingEngine.submit``) so the
per-chip timeline invariants (work conservation, no overlap) hold
penalty-inclusive and ``ClusterResult.validate`` can re-assert them.

Quick use::

    from repro.core.hardware import CRATERLAKE, F1PLUS, FLASH_FHE
    from repro import serve

    jobs = serve.poisson_jobs(serve.PoissonConfig(rate_per_mcycle=200.0,
                                                  n_jobs=320, seed=7))
    mixed = serve.serve_cluster(
        jobs, chips=[FLASH_FHE, FLASH_FHE, CRATERLAKE, F1PLUS],
        router="hetero", gang_max_chips=2)
    print(serve.summarize(mixed))           # fleet-level SLOs
"""

from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict

import numpy as np

from repro.core.cache import MB
from repro.core.hardware import ChipConfig
from repro.core.jobs import FheJob
from repro.fhe.context import ExecPolicy
from repro.obs.metrics import MetricsRegistry

from .events import EventLoop
from .faults import FaultConfig, FaultEvent, FaultPlan, RetryPolicy
from .policy import (
    GANG_SYNCS,
    AdmissionConfig,
    FlashPolicy,
    GangReservation,
    JobExec,
    JobState,
    ServeResult,
    ServingEngine,
    TokenBucket,
    _trace_job_end,
    gang_link_bytes,
    gang_service_cycles,
    working_set_bytes,
)

ROUTERS = ("round_robin", "jsq", "po2", "affinity", "hetero")


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Fleet shape + router policy + warm-set/cold-start + gang model."""

    n_chips: int = 0  # 0 = derive from ``chips`` (one of the two is required)
    router: str = "jsq"
    seed: int = 0  # router-local RNG (po2 sampling) — split off via SeedSequence
    cold_start: bool = True  # model warm-set misses at all?
    cold_factor: float = 2.0  # penalty = factor × working_set_bytes / hbm_B_per_cycle
    warm_capacity_mb: float | None = None  # per-chip warm-set cap; default: chip L2
    hoist: bool = False  # legacy bool spelling of the hoisted-rotation kernel mode
    # service-time execution policy per engine; wins over ``hoist`` when set —
    # its ``policy_key()`` is what keys the per-(chip, workload, kind) memo
    exec_policy: ExecPolicy | None = None
    # heterogeneous fleet: one (ChipConfig, ExecPolicy | None) pair per chip
    # (bare ChipConfig entries are accepted; ``exec_policy`` fills the gaps).
    # ``None`` = homogeneous fleet of ``n_chips`` × the serve_cluster chip.
    chips: tuple | None = None
    # cross-chip deep gangs: a deep job may split across up to this many
    # identical FlashPolicy chips (1 = gangs off)
    gang_max_chips: int = 1
    # inter-chip link bandwidth the gang exchanges are serialized through.
    # 256 B/cycle = 4× slower than one chip's HBM (1024 B/cycle) and 32×
    # slower than the 2048-port on-chip L3 transpose — crossing the package
    # boundary is deliberately expensive
    link_bytes_per_cycle: float = 256.0
    gang_syncs: int = GANG_SYNCS  # global barriers per ganged deep job
    # overload protection (None = admit everything, the historical behaviour):
    # utilization reserve + per-tenant token buckets at the router, and an
    # engine-level queue timeout — see ``policy.AdmissionConfig``
    admission: AdmissionConfig | None = None
    # fault injection (repro.serve.faults): a FaultPlan (scripted) or a
    # FaultConfig (seeded random plan, drawn over the fleet at router build).
    # None = fault-free, the historical behaviour
    faults: FaultPlan | FaultConfig | None = None
    # recovery policy for transiently-failed jobs; None with faults armed
    # means NO recovery (failed jobs are lost — the bench's divergence
    # baseline uses RetryPolicy(max_attempts=0), which is equivalent)
    retry: RetryPolicy | None = None

    def __post_init__(self):
        if self.admission is not None and not isinstance(self.admission, AdmissionConfig):
            raise ValueError(
                f"admission must be an AdmissionConfig, got {type(self.admission).__name__}")
        if self.faults is not None and not isinstance(self.faults, (FaultPlan, FaultConfig)):
            raise ValueError(
                f"faults must be a FaultPlan or FaultConfig, got {type(self.faults).__name__}")
        if self.retry is not None and not isinstance(self.retry, RetryPolicy):
            raise ValueError(
                f"retry must be a RetryPolicy, got {type(self.retry).__name__}")
        if self.chips is not None:
            norm = []
            for entry in self.chips:
                if isinstance(entry, ChipConfig):
                    norm.append((entry, self.exec_policy))
                else:
                    c, p = entry
                    norm.append((c, p if p is not None else self.exec_policy))
            object.__setattr__(self, "chips", tuple(norm))
            if self.n_chips == 0:
                object.__setattr__(self, "n_chips", len(norm))
            elif self.n_chips != len(norm):
                raise ValueError(
                    f"n_chips={self.n_chips} disagrees with len(chips)={len(norm)}")
        if self.n_chips < 1:
            raise ValueError(f"n_chips must be >= 1, got {self.n_chips}")
        if self.router not in ROUTERS:
            raise ValueError(f"unknown router {self.router!r}; choose from {ROUTERS}")
        if self.gang_max_chips < 1:
            raise ValueError(f"gang_max_chips must be >= 1, got {self.gang_max_chips}")
        if self.link_bytes_per_cycle <= 0:
            raise ValueError("link_bytes_per_cycle must be positive")
        if self.gang_syncs < 0:
            raise ValueError("gang_syncs must be >= 0")

    def chip_pairs(self, default_chip: ChipConfig | None = None) -> tuple:
        """The fleet as (ChipConfig, ExecPolicy | None) pairs, one per chip."""
        if self.chips is not None:
            return self.chips
        if default_chip is None:
            raise ValueError("homogeneous ClusterConfig needs a default chip")
        return tuple((default_chip, self.exec_policy) for _ in range(self.n_chips))


@dataclasses.dataclass
class ClusterResult:
    """Per-chip timelines + the merged fleet view.

    ``jobs`` holds one ``JobExec`` per routed job in submission order; for a
    ganged deep job that is its rank-0 (primary) fragment — the other
    fragments live only in their chips' ``chip_results`` timelines, and
    ``gangs`` maps the job id to the full member-chip tuple.
    """

    chip: ChipConfig  # primary/default chip (chips[0] on heterogeneous fleets)
    config: ClusterConfig
    chip_results: list[ServeResult]  # NB: each carries the SHARED loop's event
    # total in events_processed (per-chip attribution is not meaningful when
    # one clock drives every engine); the fleet-wide count lives below
    jobs: list[JobExec]  # submission order (matching ``serve.serve`` semantics)
    placements: dict[int, int]  # job_id -> chip index (primary member for gangs)
    makespan: float
    events_processed: int
    chips: list[ChipConfig] = dataclasses.field(default_factory=list)  # per-chip
    gangs: dict[int, tuple[int, ...]] = dataclasses.field(default_factory=dict)
    # router state snapshots at drain (admission/overload observability):
    # per-chip backlog estimators (should both be ~0 after a full drain and
    # are invariant-checked non-negative with serial <= total), the peak
    # fleet-wide backlog over the run (the "are queues bounded?" observable),
    # and shed counts by trigger ("token_bucket" / "reserve" / "timeout")
    final_backlog: list[float] = dataclasses.field(default_factory=list)
    final_backlog_serial: list[float] = dataclasses.field(default_factory=list)
    peak_backlog_cycles: float = 0.0
    shed_reasons: dict[str, int] = dataclasses.field(default_factory=dict)
    # per-chip shed attribution: chip -1 = rejected at the router's door
    # (token_bucket / reserve / no_healthy_chip — never routed anywhere),
    # chip i >= 0 = queue-timeout sheds on that chip.  ``validate`` asserts
    # the breakdown sums back to the fleet-global ``shed_reasons``
    shed_reasons_by_chip: dict[int, dict[str, int]] = dataclasses.field(default_factory=dict)
    # fault observability: per-chip [crash, recover) downtime windows (an
    # unrecovered crash closes at the run's end) and injected/handled fault
    # counters ("crashes" / "transients" / "slow_windows" / "retries" /
    # "jobs_lost" / "retry_no_chip")
    downtime: dict[int, list[tuple[float, float]]] = dataclasses.field(default_factory=dict)
    fault_counts: dict[str, int] = dataclasses.field(default_factory=dict)
    # per-chip fault attribution: injected events on their target chip,
    # retries/jobs_lost on the chip the attempt failed on, retry_no_chip
    # (whole fleet dark) on -1; sums back to ``fault_counts``
    fault_counts_by_chip: dict[int, dict[str, int]] = dataclasses.field(default_factory=dict)
    # ``MetricsRegistry.snapshot()`` of the run's registry (serve.shed /
    # serve.faults counters, turnaround histogram, peak-backlog gauge)
    metrics: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.chips:
            self.chips = [self.chip] * self.config.n_chips

    @property
    def n_chips(self) -> int:
        return self.config.n_chips

    def check_no_lost_jobs(self) -> "ClusterResult":
        """The no-lost-job invariant, cheap enough to run UNCONDITIONALLY:
        every submitted job's primary record is terminal — DONE, SHED, or
        FAILED (retries exhausted).  A job silently dropped by a buggy policy
        (stranded QUEUED/SUSPENDED, or a FAILED_TRANSIENT attempt never
        retried or given up on) trips this even with ``validate=False``."""
        terminal = (JobState.DONE, JobState.SHED, JobState.FAILED)
        for je in self.jobs:
            assert je.state in terminal, (
                f"job {je.job.job_id} lost: final state {je.state} is not terminal "
                f"(DONE/SHED/FAILED)"
            )
        return self

    def validate(self) -> "ClusterResult":
        """Fleet invariants on top of each chip's own ``ServeResult.validate``:
        no job is lost (every primary record terminal); every non-gang job
        completed on EXACTLY one chip (or was shed/failed); every gang job ran
        EXACTLY once on each reserved member chip with its fragments finishing
        in lockstep; an aborted gang failed in lockstep too (every fragment
        frozen at the same ``failed_cycle``); no run segment overlaps its
        chip's downtime windows (nothing placed on a dead chip); the recorded
        placements match the per-chip timelines; admission-shed jobs appear on
        NO chip and in NO placement; the backlog estimators never drift
        negative (and the serial component never exceeds the total); and the
        fleet makespan is the max over chips."""
        self.check_no_lost_jobs()
        for r in self.chip_results:
            r.validate()
        done_on: dict[int, list[int]] = {}  # jid -> chips holding a DONE record
        done_frags: dict[int, list[JobExec]] = {}
        failed_records: list[JobExec] = []
        for i, r in enumerate(self.chip_results):
            for je in r.jobs:
                jid = je.job.job_id
                assert je.chip_index == i, (
                    f"job {jid} tagged chip {je.chip_index}, found on chip {i}"
                )
                if je.state is JobState.DONE:
                    assert not (je.gang_size == 1 and i in done_on.get(jid, ())), (
                        f"job {jid} double-booked on chip {i}"
                    )
                    done_on.setdefault(jid, []).append(i)
                    done_frags.setdefault(jid, []).append(je)
                elif je.state in (JobState.FAILED_TRANSIENT, JobState.FAILED):
                    failed_records.append(je)
                # no-placement-on-dead-chip: every run interval must avoid the
                # chip's downtime windows entirely
                for seg in je.segments:
                    for lo, hi in self.downtime.get(i, ()):
                        assert seg.end <= lo + 1e-6 or seg.start >= hi - 1e-6, (
                            f"job {jid} ran [{seg.start}, {seg.end}) on chip {i} "
                            f"during its downtime [{lo}, {hi})"
                        )
        # gang lockstep-abort: an aborted gang freezes EVERY fragment at one
        # instant — group failed gang fragments by (job, failed_cycle) and
        # demand each abort event covers the full membership on distinct chips
        aborts: dict[tuple[int, float], list[JobExec]] = {}
        for je in failed_records:
            if je.gang_size > 1:
                aborts.setdefault((je.job.job_id, je.failed_cycle), []).append(je)
        for (jid, at), group in aborts.items():
            want = group[0].gang_size
            assert len(group) == want, (
                f"gang job {jid} aborted at {at} with {len(group)} of {want} "
                f"fragments — lockstep abort violated"
            )
            used = [f.chip_index for f in group]
            assert len(set(used)) == len(used), (
                f"gang job {jid} abort records collide on chips {used}"
            )
        # router-shed jobs (chip_index < 0): rejected at the door, so they
        # must never have reached a chip timeline, a placement, or a warm-set
        # (the cold_start_cycles charge is the warm-set's observable)
        router_shed = {je.job.job_id for je in self.jobs
                       if je.state is JobState.SHED and je.chip_index < 0}
        for je in self.jobs:
            if je.job.job_id in router_shed:
                assert not je.segments and je.completion is None
                assert je.shed_cycle is not None and je.cold_start_cycles == 0.0
        assert not router_shed & set(done_on), (
            f"admission-shed jobs found on chips: {sorted(router_shed & set(done_on))}"
        )
        for name, arr in (("backlog", self.final_backlog),
                          ("backlog_serial", self.final_backlog_serial)):
            for i, v in enumerate(arr):
                assert v >= 0.0, f"chip {i} {name} estimator drifted negative: {v}"
        for i, (total, serial) in enumerate(zip(self.final_backlog,
                                                self.final_backlog_serial)):
            assert serial <= total + 1e-6 * max(1.0, total), (
                f"chip {i} serial backlog {serial} exceeds total {total}"
            )
        for jid, used in done_on.items():
            fs = done_frags[jid]
            if fs[0].gang_size == 1:
                assert len(used) == 1, f"non-gang job {jid} completed on chips {used}"
                assert self.placements[jid] == used[0], (
                    f"job {jid} placed on chip {self.placements[jid]}, ran on {used[0]}"
                )
                continue
            members = self.gangs.get(jid)
            assert members is not None, f"gang fragments of {jid} lack a reservation"
            assert len(set(members)) == len(members), (
                f"gang {jid} reserves chip(s) twice: {members}"
            )
            assert sorted(used) == sorted(members), (
                f"gang job {jid} ran on chips {used}, reserved {members}"
            )
            assert self.placements[jid] == members[0]
            assert all(f.gang_size == len(members) for f in fs)
            comps = [f.completion for f in fs]
            assert max(comps) - min(comps) <= 1e-6 * max(1.0, max(comps)), (
                f"gang job {jid} fragments finished out of lockstep: {comps}"
            )
        done_primary = {je.job.job_id for je in self.jobs if je.state is JobState.DONE}
        assert done_primary == set(done_on), (
            "primary DONE records disagree with chip timelines"
        )
        n_failed = sum(1 for je in self.jobs if je.state is JobState.FAILED)
        n_shed = sum(1 for je in self.jobs if je.state is JobState.SHED)
        assert len(self.jobs) == len(done_primary) + n_shed + n_failed, (
            f"{len(self.jobs)} jobs routed != {len(done_primary)} done "
            f"+ {n_shed} shed + {n_failed} failed"
        )
        per_chip_mk = max((r.makespan for r in self.chip_results), default=0.0)
        assert abs(self.makespan - per_chip_mk) <= 1e-6 * max(1.0, per_chip_mk)
        # per-chip attribution must re-aggregate to the fleet-global books
        # (both are views over one labelled counter, so a mismatch means the
        # router double- or under-counted somewhere)
        for label, per_chip, total in (
                ("shed", self.shed_reasons_by_chip, self.shed_reasons),
                ("fault", self.fault_counts_by_chip, self.fault_counts)):
            agg: dict[str, int] = {}
            for chip, counts in per_chip.items():
                assert -1 <= chip < self.config.n_chips, (
                    f"{label} attribution names unknown chip {chip}")
                for k, v in counts.items():
                    agg[k] = agg.get(k, 0) + v
            assert agg == total, (
                f"per-chip {label} breakdown {agg} does not sum to the "
                f"fleet-global book {total}")
        return self


class ClusterRouter:
    """Front-end DES router: shards one arrival stream over N engines."""

    def __init__(self, chip: ChipConfig | None, config: ClusterConfig,
                 loop: EventLoop | None = None, tracer=None, metrics=None):
        pairs = config.chip_pairs(chip)
        self.chip = chip if chip is not None else pairs[0][0]
        self.config = config
        # observability (repro.obs): the tracer timestamps off the SHARED
        # loop; the metrics registry is the fleet's shed/fault book of record
        # (``shed_reasons``/``fault_counts`` re-aggregate it, so the global
        # and per-chip views can never disagree)
        self.tracer = tracer if tracer else None
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._shed_ctr = self.metrics.counter("serve.shed", labels=("reason", "chip"))
        self._fault_ctr = self.metrics.counter("serve.faults", labels=("kind", "chip"))
        self._backlog_gauge = self.metrics.gauge("serve.peak_backlog_cycles")
        self.loop = loop if loop is not None else EventLoop(tracer=self.tracer)
        self.chips = [c for c, _ in pairs]
        adm = config.admission
        self.engines = [ServingEngine(c, loop=self.loop, hoist=config.hoist,
                                      exec_policy=p,
                                      shed_after=(adm.shed_after_cycles
                                                  if adm is not None else None),
                                      tracer=self.tracer, metrics=self.metrics)
                        for c, p in pairs]
        for i, eng in enumerate(self.engines):
            eng.chip_index = i
            eng._fleet = True  # the router owns job async spans
            eng.on_job_complete = functools.partial(self._completed, i)
            eng.on_job_shed = functools.partial(self._shed_echo, i)
        self._router_tid = 0
        if self.tracer is not None:
            # fixed trace topology up front: pid 0 = router, pid i+1 = chip i,
            # every resource track interned now so tids depend only on the
            # fleet shape (not on arrival order)
            self.tracer.name_process(0, "fleet router")
            self._router_tid = self.tracer.track(0, "router")
            for eng in self.engines:
                eng._trace_register()
        # per-tenant token buckets, created lazily on first arrival
        self._buckets: dict[int, TokenBucket] = {}
        # fault state: chip health, downtime windows, and the retry policy.
        # ``alive`` mirrors each policy's flag but lives here so the routing
        # hot path never reaches into engines
        self.alive = [True] * config.n_chips
        self.retry = config.retry
        self.downtime: dict[int, list[tuple[float, float]]] = {}
        self._down_since: dict[int, float] = {}
        if config.faults is not None:
            plan = (config.faults.draw(config.n_chips)
                    if isinstance(config.faults, FaultConfig) else config.faults)
            self.arm_faults(plan)
        # peak fleet-wide backlog estimate over the run: THE bounded-queues
        # observable (without admission it grows with the overload integral,
        # with admission it plateaus near the utilization reserve)
        self.peak_backlog = 0.0
        # estimated outstanding service cycles per chip: the simulator prices
        # each job at routing time and completions echo back.  An estimate,
        # not an oracle — spill/restore added to a preempted deep job after
        # placement is not re-echoed into the backlog
        self.backlog = [0.0] * config.n_chips
        # the deep-job component of each backlog: deep service occupies a
        # whole chip (all affiliations), so it drains serially even on a
        # multi-affiliation chip — the wait estimator prices it at full width
        self.backlog_serial = [0.0] * config.n_chips
        self.placements: dict[int, int] = {}
        self.gangs: dict[int, tuple[int, ...]] = {}  # job_id -> member chips
        self._submit_order: list[int] = []  # job_ids in submission order
        self._seen_ids: set[int] = set()
        self._by_id: dict[int, JobExec] = {}
        self._rr_next = 0
        self._rng = np.random.default_rng(np.random.SeedSequence(config.seed))
        self._warm_cap = [
            (config.warm_capacity_mb if config.warm_capacity_mb is not None
             else c.l2_mb) * MB
            for c in self.chips]
        self._warm: list[OrderedDict[str, float]] = [OrderedDict() for _ in range(config.n_chips)]
        # gang-capable chips, grouped by identical pricing — fragments must
        # progress in lockstep, so members share (chip, policy_key, coop)
        groups: dict[tuple, list[int]] = {}
        for i, eng in enumerate(self.engines):
            if isinstance(eng.policy, FlashPolicy):
                key = (eng.chip, eng.exec_policy.policy_key(),
                       eng.policy.deep_coop)
                groups.setdefault(key, []).append(i)
        self._gang_groups = [idxs for idxs in groups.values() if len(idxs) >= 2]

    # -- shed/fault books: derived views over the metrics counters -----------
    # (single source of truth — the fleet-global dicts and the per-chip
    # breakdowns are two aggregations of the same labelled counter, so
    # ``ClusterResult.validate`` can assert they sum without ever diverging)

    @staticmethod
    def _per_chip(ctr) -> dict[int, dict[str, int]]:
        return {int(chip): {key[0]: int(v) for key, v in rest.items()}
                for chip, rest in ctr.by_label("chip").items()}

    @property
    def shed_reasons(self) -> dict[str, int]:
        return {k: int(v) for k, v in self._shed_ctr.group_sum("reason").items()}

    @property
    def shed_reasons_by_chip(self) -> dict[int, dict[str, int]]:
        """Shed counts by chip: ``-1`` = rejected at the router's door
        (token_bucket / reserve / no_healthy_chip), ``i >= 0`` = queue-timeout
        sheds that had already been routed to chip i."""
        return self._per_chip(self._shed_ctr)

    @property
    def fault_counts(self) -> dict[str, int]:
        return {k: int(v) for k, v in self._fault_ctr.group_sum("kind").items()}

    @property
    def fault_counts_by_chip(self) -> dict[int, dict[str, int]]:
        """Fault/recovery counts by chip: injected events land on their target
        chip; retries/jobs_lost attribute to the chip the attempt FAILED on;
        ``retry_no_chip`` (whole fleet dark) lands on ``-1``."""
        return self._per_chip(self._fault_ctr)

    # -- submission ---------------------------------------------------------

    def submit(self, job: FheJob) -> None:
        """Schedule the routing decision at the job's arrival instant."""
        assert job.job_id not in self._seen_ids, (
            f"duplicate job_id {job.job_id}: the router keys placements by id"
        )
        self._seen_ids.add(job.job_id)
        self._submit_order.append(job.job_id)
        self.loop.call_at(max(self.loop.now, float(job.arrival_cycle)),
                          lambda: self._route(job))

    # -- dispatch policies --------------------------------------------------

    def _alive_idx(self) -> list[int]:
        return [i for i in range(self.config.n_chips) if self.alive[i]]

    def _pick(self, job: FheJob) -> int:
        """Health-aware placement: dead chips are invisible to every policy.
        Callers must guarantee at least one healthy chip (``_route`` sheds
        with reason "no_healthy_chip" otherwise)."""
        alive = self._alive_idx()
        assert alive, "_pick called with no healthy chip"
        if len(alive) == 1:
            return alive[0]
        r = self.config.router
        if r == "round_robin":
            while True:  # skip dead chips, keep the cyclic order among live ones
                i = self._rr_next % self.config.n_chips
                self._rr_next += 1
                if self.alive[i]:
                    return i
        if r == "jsq":
            return min(alive, key=lambda i: (self.backlog[i], i))
        if r == "po2":
            a, b = (alive[int(x)] for x in
                    self._rng.choice(len(alive), size=2, replace=False))
            return a if (self.backlog[a], a) <= (self.backlog[b], b) else b
        if r == "affinity":
            # total marginal cost = backlog + the cold-start you'd pay
            return min(alive, key=lambda i: (self.backlog[i] + self._cold_penalty(job, i), i))
        # hetero: like affinity, but also price THIS chip's service time for
        # THIS job — on a mixed fleet the estimate is what steers deep jobs to
        # bootstrappable-heavy chips and shallow floods to swift-heavy ones
        return min(alive, key=lambda i: (self._est(job, i), i))

    def _drain_width(self, i: int) -> int:
        """How many jobs chip i retires concurrently: a FlashPolicy chip
        drains a (shallow-dominated) backlog one job per affiliation, a
        sequential chip one at a time.  Raw backlog cycles would overstate a
        multi-affiliation chip's congestion by exactly this factor."""
        eng = self.engines[i]
        return eng.chip.n_affiliations if isinstance(eng.policy, FlashPolicy) else 1

    def _wait(self, i: int) -> float:
        """Estimated wall-clock cycles until chip i drains its backlog: the
        shallow component retires ``_drain_width`` jobs at a time, the deep
        component (whole-chip gangs) serially."""
        serial = self.backlog_serial[i]
        parallel = max(0.0, self.backlog[i] - serial)
        return parallel / self._drain_width(i) + serial

    def _est(self, job: FheJob, i: int) -> float:
        """Estimated completion of ``job`` on chip i: the backlog's wall-clock
        drain time plus this chip's service time for this job (+ cold start)."""
        return (self._wait(i)
                + self.engines[i].service_sim(job).cycles
                + self._cold_penalty(job, i))

    # -- cross-chip gang planner --------------------------------------------

    def _plan_gang(self, job: FheJob) -> list[int] | None:
        """Pick gang members for a deep job, or ``None`` to stay single-chip.

        For every group of identically-priced gang-capable chips, try widths
        M = 2..gang_max_chips over the M least-loaded members: estimated
        completion = the most-loaded member's drain time (the lockstep
        barrier waits for it) + the per-chip gang demand (compute/M + link
        stalls).  Commit only if the best gang strictly beats the best
        single-chip estimate — split speedup is weighed against the queueing
        delay of aligning M chips."""
        if not self._gang_groups:
            return None
        best_single = min(self._est(job, i) for i in self._alive_idx())
        best: tuple[float, int, list[int]] | None = None
        for group in self._gang_groups:
            idxs = [i for i in group if self.alive[i]]  # dead members can't gang
            if len(idxs) < 2:
                continue
            single = self.engines[idxs[0]].service_sim(job).cycles
            order = sorted(idxs, key=lambda i: (self._wait(i), i))
            for m in range(2, min(self.config.gang_max_chips, len(order)) + 1):
                members = order[:m]
                per_chip, _ = gang_service_cycles(
                    single, job, m, self.config.link_bytes_per_cycle,
                    self.config.gang_syncs)
                est = max(self._wait(i) for i in members) + per_chip
                if best is None or (est, m) < (best[0], best[1]):
                    best = (est, m, members)
        if best is not None and best[0] < best_single:
            return best[2]
        return None

    # -- warm-set / cold-start model ----------------------------------------

    def _cold_penalty(self, job: FheJob, i: int) -> float:
        if not self.config.cold_start or job.workload in self._warm[i]:
            return 0.0
        return (self.config.cold_factor * working_set_bytes(job)
                / self.chips[i].hbm_bytes_per_cycle)

    def _touch_warm(self, job: FheJob, i: int) -> None:
        w = self._warm[i]
        if job.workload in w:
            w.move_to_end(job.workload)
        else:
            w[job.workload] = working_set_bytes(job)
        while len(w) > 1 and sum(w.values()) > self._warm_cap[i]:
            w.popitem(last=False)  # evict least-recently-used working set

    # -- admission control ---------------------------------------------------

    def _admission_verdict(self, job: FheJob) -> str | None:
        """``None`` = admit; otherwise the shed trigger ("token_bucket" /
        "reserve").  The bucket is charged first — an over-rate tenant pays
        with its own tokens before it can even contend for fleet capacity."""
        adm = self.config.admission
        if adm is None:
            return None
        if adm.tenant_rate_per_mcycle is not None:
            bucket = self._buckets.get(job.tenant_id)
            if bucket is None:
                bucket = self._buckets[job.tenant_id] = TokenBucket(
                    adm.tenant_rate_per_mcycle, adm.tenant_burst)
            if not bucket.try_take(self.loop.now):
                return "token_bucket"
        if adm.max_wait_cycles is not None:
            # price the DEGRADED fleet: the reserve shrinks with the healthy
            # fraction, so admission tightens during an outage instead of
            # letting arrivals queue up against capacity that no longer exists
            # and shedding late (by timeout) after the SLO is already blown
            alive = self._alive_idx()
            bound = adm.max_wait_cycles * len(alive) / self.config.n_chips
            best = min(self._wait(i) for i in alive)
            if best > bound:
                return "reserve"
        return None

    def _shed_at_door(self, job: FheJob, reason: str) -> None:
        """Admission rejection: terminal SHED without touching any engine,
        warm-set, or backlog estimator.  The record keeps the job visible to
        the metrics layer (drop rate by tenant/kind) via ``ClusterResult.jobs``
        with the sentinel ``chip_index = -1``."""
        je = JobExec(job=job, service_cycles=0.0, sim=None, lanes="",
                     state=JobState.SHED, chip_index=-1)
        je.shed_cycle = self.loop.now
        self._by_id[job.job_id] = je
        self._shed_ctr.inc(reason=reason, chip=-1)
        if self.tracer is not None:
            # door-shed jobs never reach a chip: their whole (empty) lifecycle
            # lives on the router process
            self.tracer.job_begin(job.job_id, job.workload, pid=0,
                                  kind=job.kind, tenant=job.tenant_id,
                                  priority=job.priority)
            self.tracer.instant("shed", pid=0, tid=self._router_tid,
                                job=job.job_id, reason=reason)
            self.tracer.job_end(job.job_id, job.workload, "SHED", pid=0)

    def _note_backlog(self) -> None:
        total = sum(self.backlog)
        self.peak_backlog = max(self.peak_backlog, total)
        self._backlog_gauge.max(total)
        if self.tracer is not None:
            self.tracer.counter("backlog_cycles", {"total": total})

    # -- fault injection + recovery ------------------------------------------

    def arm_faults(self, plan: FaultPlan) -> None:
        """Schedule every fault event on the shared loop.  Must happen before
        arrivals are submitted (the constructor arms ``config.faults``): fault
        events then carry the lowest sequence numbers, so at any shared
        timestamp the fault processes FIRST and routing decisions already see
        the new health state — same-instant races resolve deterministically.
        Events aimed past the fleet (chip >= n_chips) are dropped."""
        for ev in plan.events:
            if ev.chip < self.config.n_chips:
                self.loop.call_at(ev.at, functools.partial(self._fault, ev))

    def _count(self, key: str, chip: int, n: int = 1) -> None:
        self._fault_ctr.inc(n, kind=key, chip=chip)

    def _fault_mark(self, name: str, i: int, **args) -> None:
        """Instant on chip i's health track (the "chip" tid is always 0 —
        ``_trace_register`` interns it first)."""
        if self.tracer is not None:
            self.tracer.instant(name, pid=i + 1,
                                tid=self.tracer.track(i + 1, "chip"), **args)

    def _fault(self, ev: FaultEvent) -> None:
        now = self.loop.now
        i = ev.chip
        policy = self.engines[i].policy
        if ev.kind == "crash":
            if not self.alive[i]:
                return  # random plans can crash an already-dead chip
            self._count("crashes", i)
            self.alive[i] = False
            self._down_since[i] = now
            if self.tracer is not None:
                # downtime is a B/E span on the health track: crash/recover
                # windows never overlap per chip (the guards above/below), so
                # the stack stays balanced; ``run`` closes unrecovered spans
                self.tracer.begin("down", pid=i + 1,
                                  tid=self.tracer.track(i + 1, "chip"))
            victims = policy.fail_all(now)
            self._handle_victims(victims, now)
            # the chip's outstanding work is gone: zero its estimators (the
            # victims' demand requeues against HEALTHY chips) and drop its
            # warm-set — recovery rejoins cold
            self.backlog[i] = 0.0
            self.backlog_serial[i] = 0.0
            self._warm[i].clear()
        elif ev.kind == "recover":
            if self.alive[i]:
                return
            self.alive[i] = True
            policy.revive()
            self.downtime.setdefault(i, []).append((self._down_since.pop(i), now))
            if self.tracer is not None:
                self.tracer.end("down", pid=i + 1,
                                tid=self.tracer.track(i + 1, "chip"))
        elif ev.kind == "transient":
            if not self.alive[i]:
                return  # a dead chip has nothing running to fault
            self._count("transients", i)
            self._fault_mark("transient", i)
            self._handle_victims(policy.fail_one(now), now)
        elif ev.kind == "slow_start":
            # slowdown windows are instants, NOT B/E spans: they may straddle
            # a crash/recover window on the same track, which would break the
            # B/E stack discipline the validator enforces
            self._count("slow_windows", i)
            self._fault_mark("slow_start", i, factor=ev.factor)
            policy.slow_factor = ev.factor
        else:  # slow_end
            self._fault_mark("slow_end", i)
            policy.slow_factor = 1.0

    def _handle_victims(self, victims: list[JobExec], now: float) -> None:
        """Requeue (or give up on) every job a fault just killed.  ``victims``
        holds one record per failed FRAGMENT; a gang abort contributes its
        whole membership, which collapses to ONE retry of the job."""
        by_job: dict[int, list[JobExec]] = {}
        for je in victims:
            self._debit_backlog(je.chip_index, je)
            by_job.setdefault(je.job.job_id, []).append(je)
        for records in by_job.values():
            primary = min(records, key=lambda je: je.gang_rank)
            carried = (primary.prior_wasted_cycles
                       + sum(r.wasted_cycles for r in records))
            self._by_id[primary.job.job_id] = primary
            self._after_failure(primary.job, primary, primary.attempts, carried)

    def _after_failure(self, job: FheJob, old: JobExec, attempts_done: int,
                       carried_wasted: float) -> None:
        """Decide the failed job's fate: exhausted → terminal FAILED; else
        schedule a retry after the policy's capped exponential backoff.
        ``attempts_done`` counts consumed attempts (a retry window finding
        zero healthy chips consumes one too, without producing a record)."""
        rp = self.retry
        if rp is None or attempts_done > rp.max_attempts:
            old.state = JobState.FAILED
            self._count("jobs_lost", old.chip_index)
            _trace_job_end(self.tracer, old, "FAILED")
            return
        self._count("retries", old.chip_index)
        delay = rp.backoff_cycles(attempts_done)
        if self.tracer is not None:
            self.tracer.instant("retry", pid=0, tid=self._router_tid,
                                job=job.job_id, attempt=attempts_done + 1,
                                delay=delay)
        self.loop.call_after(delay, functools.partial(
            self._retry, job, old, attempts_done, carried_wasted))

    def _price_key(self, i: int) -> tuple:
        """Service-pricing identity of chip i — a checkpoint's ``remaining``
        is denominated in these cycles, so resume needs an exact match."""
        eng = self.engines[i]
        return (eng.chip, eng.exec_policy.policy_key(),
                getattr(eng.policy, "deep_coop", None))

    def _retry(self, job: FheJob, old: JobExec, attempts_done: int,
               carried_wasted: float) -> None:
        """Re-place a transiently-failed job on the healthy sub-fleet.

        Retries bypass admission (the job was already admitted and has
        already paid — shedding it mid-recovery would both waste that work
        and violate the shed carve-outs) and skip the queue-timeout deadline
        (measured from the original arrival it would fire instantly).  A deep
        job with a spill checkpoint resumes its ``remaining`` on an
        identically-priced chip; everything else restarts in full, deep jobs
        re-entering the gang planner over the healthy sub-fleet."""
        now = self.loop.now
        if not any(self.alive):
            # the whole fleet is dark: burn an attempt and back off again
            self._count("retry_no_chip", -1)
            self._after_failure(job, old, attempts_done + 1, carried_wasted)
            return
        rp = self.retry
        attempts = attempts_done + 1
        use_ckpt = (rp.checkpoint and old._has_checkpoint and old.gang is None
                    and job.kind == "deep")
        if use_ckpt:
            okey = self._price_key(old.chip_index)
            cands = [i for i in self._alive_idx() if self._price_key(i) == okey]
            if cands:
                i = min(cands, key=lambda c: (self._wait(c), c))
                je = self.engines[i].submit(job, sim=old.sim,
                                            service_cycles=old.remaining,
                                            arm_deadline=False)
                je.full_service_cycles = old.full_service_cycles
                je.checkpoint_cycles = max(
                    0.0, old.full_service_cycles - old.remaining)
                je._has_checkpoint = True  # the HBM image outlives the crash
                self._book_retry(je, i, job, old, attempts, carried_wasted)
                return
            # no identically-priced healthy chip: fall through to full restart
        if job.kind == "deep" and self.config.gang_max_chips > 1:
            members = self._plan_gang(job)
            if members is not None:
                self._route_gang(job, members,
                                 retry_meta=(attempts, carried_wasted,
                                             old.first_start))
                return
        i = self._pick(job)
        je = self.engines[i].submit(job, arm_deadline=False)
        self._book_retry(je, i, job, old, attempts, carried_wasted)

    def _book_retry(self, je: JobExec, i: int, job: FheJob, old: JobExec,
                    attempts: int, carried_wasted: float) -> None:
        je.attempts = attempts
        je.prior_wasted_cycles = carried_wasted
        je.first_start = old.first_start  # queueing delay stays the original's
        self.placements[job.job_id] = i
        self.gangs.pop(job.job_id, None)  # a single-chip retry ends gang status
        self._by_id[job.job_id] = je
        self.backlog[i] += je.service_cycles
        if job.kind == "deep":
            self.backlog_serial[i] += je.service_cycles
        self._note_backlog()

    # -- event handlers ------------------------------------------------------

    def _route(self, job: FheJob) -> None:
        if not any(self.alive):
            # the entire fleet is dark: there is no queue to wait in (the
            # router holds no backlog of its own), so arrivals shed at the
            # door — the availability metrics surface the outage window
            self._shed_at_door(job, "no_healthy_chip")
            return
        verdict = self._admission_verdict(job)
        if verdict is not None:
            self._shed_at_door(job, verdict)
            return
        if job.kind == "deep" and self.config.gang_max_chips > 1:
            members = self._plan_gang(job)
            if members is not None:
                self._route_gang(job, members)
                return
        i = self._pick(job)
        if self.tracer is not None:
            # the router opens the job's async span (engines are fleet-managed
            # and stay silent in submit); the routing instant makes the
            # placement decision visible on the router track
            self.tracer.job_begin(job.job_id, job.workload, pid=i + 1,
                                  kind=job.kind, tenant=job.tenant_id,
                                  priority=job.priority)
            self.tracer.instant("routed", pid=0, tid=self._router_tid,
                                job=job.job_id, chip=i)
        pay = self._cold_penalty(job, i)  # counted in metrics via cold_start_cycles
        self._touch_warm(job, i)
        je = self.engines[i].submit(job, extra_cycles=pay)
        self.placements[job.job_id] = i
        self._by_id[job.job_id] = je
        self.backlog[i] += je.service_cycles
        if job.kind == "deep":
            self.backlog_serial[i] += je.service_cycles
        self._note_backlog()

    def _route_gang(self, job: FheJob, members: list[int],
                    retry_meta: tuple[int, float, float | None] | None = None) -> None:
        """Commit a multi-chip reservation: one lockstep fragment per member.

        Every fragment carries the full per-chip gang demand (compute/M +
        link stalls) so each member chip's work conservation validates; the
        rank-0 fragment is the job's primary record (``ClusterResult.jobs``)
        and additionally logs the gang-total link bytes.  ``retry_meta``
        (attempts, carried waste, original first_start) marks a re-ganged
        retry of a failed job."""
        eng = self.engines[members[0]]
        sim = eng.service_sim(job)
        per_chip, link = gang_service_cycles(
            sim.cycles, job, len(members), self.config.link_bytes_per_cycle,
            self.config.gang_syncs)
        if self.tracer is not None and retry_meta is None:
            self.tracer.job_begin(job.job_id, job.workload, pid=members[0] + 1,
                                  kind=job.kind, tenant=job.tenant_id,
                                  priority=job.priority)
        if self.tracer is not None:
            self.tracer.instant("routed_gang", pid=0, tid=self._router_tid,
                                job=job.job_id, chips=list(members))
        gang = GangReservation(job, self.loop)
        for rank, i in enumerate(members):
            je = self.engines[i].submit(job, sim=sim, service_cycles=per_chip,
                                        gang=gang,
                                        arm_deadline=retry_meta is None)
            je.chip_index = i
            je.gang_rank = rank
            je.gang_size = len(members)
            je.link_cycles = link
            if retry_meta is not None:
                attempts, carried, first_start = retry_meta
                je.attempts = attempts
                je.first_start = first_start
                if rank == 0:
                    je.prior_wasted_cycles = carried
            if rank == 0:
                je.link_bytes = gang_link_bytes(job, len(members),
                                                self.config.gang_syncs)
                self._by_id[job.job_id] = je
            self.backlog[i] += je.service_cycles
            self.backlog_serial[i] += je.service_cycles
        self.placements[job.job_id] = members[0]
        self.gangs[job.job_id] = tuple(members)
        self._note_backlog()

    def _debit_backlog(self, i: int, je: JobExec) -> None:
        """Echo a job's routed service demand back out of chip i's estimators.

        Every decrement clamps at 0.0 — actual service can diverge from the
        routed estimate (preemption spill/restore accrues after placement,
        gang suspensions re-price remaining work), so naive subtraction can
        drift the estimators negative and then *attract* the jsq/po2/hetero
        routers to phantom capacity.  The serial component is additionally
        clamped to never exceed the total (``ClusterResult.validate`` asserts
        both invariants on the drained snapshot)."""
        self.backlog[i] = max(0.0, self.backlog[i] - je.service_cycles)
        if je.kind == "deep":
            self.backlog_serial[i] = max(
                0.0, self.backlog_serial[i] - je.service_cycles)
        self.backlog_serial[i] = min(self.backlog_serial[i], self.backlog[i])

    def _completed(self, i: int, je: JobExec) -> None:
        self._debit_backlog(i, je)

    def _shed_echo(self, i: int, je: JobExec) -> None:
        """A queue-timeout shed un-books the backlog the router charged at
        routing time (the job will never run), so the estimators keep
        tracking genuinely outstanding work."""
        self._debit_backlog(i, je)
        self._shed_ctr.inc(reason="timeout", chip=i)

    # -- run -----------------------------------------------------------------

    def run(self) -> ClusterResult:
        self.loop.run()
        # a chip still dark at drain closes its downtime window at run end so
        # availability integrates the full outage (and its open "down" trace
        # span closes with it, keeping the B/E stacks balanced)
        for i, start in sorted(self._down_since.items()):
            self.downtime.setdefault(i, []).append((start, self.loop.now))
            if self.tracer is not None:
                self.tracer.end("down", pid=i + 1,
                                tid=self.tracer.track(i + 1, "chip"))
        self._down_since.clear()
        chip_results = [eng.result() for eng in self.engines]
        makespan = max((r.makespan for r in chip_results), default=0.0)
        jobs = [self._by_id[jid] for jid in self._submit_order]  # submission order
        return ClusterResult(chip=self.chip, config=self.config,
                             chip_results=chip_results, jobs=jobs,
                             placements=dict(self.placements), makespan=makespan,
                             events_processed=self.loop.processed,
                             chips=list(self.chips), gangs=dict(self.gangs),
                             final_backlog=list(self.backlog),
                             final_backlog_serial=list(self.backlog_serial),
                             peak_backlog_cycles=self.peak_backlog,
                             shed_reasons=dict(self.shed_reasons),
                             shed_reasons_by_chip=self.shed_reasons_by_chip,
                             downtime={i: list(w) for i, w in self.downtime.items()},
                             fault_counts=dict(self.fault_counts),
                             fault_counts_by_chip=self.fault_counts_by_chip,
                             metrics=self.metrics.snapshot())


def serve_cluster(jobs: list[FheJob], chip: ChipConfig | None = None, n_chips: int = 2,
                  router: str = "jsq", seed: int = 0, cold_start: bool = True,
                  cold_factor: float = 2.0, warm_capacity_mb: float | None = None,
                  config: ClusterConfig | None = None,
                  validate: bool = True, hoist: bool = False,
                  exec_policy: ExecPolicy | None = None,
                  chips=None, gang_max_chips: int = 1,
                  link_bytes_per_cycle: float = 256.0,
                  gang_syncs: int = GANG_SYNCS,
                  admission: AdmissionConfig | None = None,
                  faults: FaultPlan | FaultConfig | None = None,
                  retry: RetryPolicy | None = None,
                  tracer=None, metrics=None) -> ClusterResult:
    """Serve an open-loop job list on a chip fleet; the one-call API.

    Homogeneous fleet: pass ``chip`` + ``n_chips``.  Heterogeneous fleet:
    pass ``chips=`` a per-chip list of ``ChipConfig`` or ``(ChipConfig,
    ExecPolicy)`` entries (``chip``/``n_chips`` are then ignored).
    ``gang_max_chips > 1`` lets deep jobs gang across identical FlashPolicy
    chips with link exchanges priced at ``link_bytes_per_cycle``.  Pass
    ``config=`` to reuse a prepared ``ClusterConfig`` (the other keyword
    arguments are ignored in that case); ``exec_policy`` sets the per-engine
    service-time execution policy (wins over the legacy ``hoist=`` bool).
    ``admission=`` arms overload protection (``AdmissionConfig``: per-tenant
    token buckets + utilization reserve at the router, queue-timeout at the
    engines); rejected jobs end ``JobState.SHED`` and surface through the
    drop-rate/goodput metrics rather than growing the backlog.  ``faults=``
    arms seeded fault injection (``FaultPlan`` scripted / ``FaultConfig``
    random) and ``retry=`` the recovery policy — see ``repro.serve.faults``.
    ``tracer=`` (an ``repro.obs.Tracer``) records the whole fleet run —
    chips→processes, affiliations/lanes→threads, job lifecycles as async
    spans — for Perfetto export (``repro.obs.write_chrome_trace``);
    ``metrics=`` supplies the ``repro.obs.MetricsRegistry`` backing the
    shed/fault books (one is created per run when omitted, and its snapshot
    lands in ``ClusterResult.metrics`` either way).
    """
    cfg = config if config is not None else ClusterConfig(
        n_chips=0 if chips is not None else n_chips, router=router, seed=seed,
        cold_start=cold_start, cold_factor=cold_factor,
        warm_capacity_mb=warm_capacity_mb, hoist=hoist, exec_policy=exec_policy,
        chips=tuple(chips) if chips is not None else None,
        gang_max_chips=gang_max_chips, link_bytes_per_cycle=link_bytes_per_cycle,
        gang_syncs=gang_syncs, admission=admission, faults=faults, retry=retry)
    rt = ClusterRouter(chip, cfg, tracer=tracer, metrics=metrics)
    for job in jobs:
        rt.submit(job)
    result = rt.run()
    result.check_no_lost_jobs()  # cheap, unconditional: no job may vanish
    return result.validate() if validate else result
