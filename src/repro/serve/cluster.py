"""Multi-chip serving scale-out: a DES front-end router over N FLASH-FHE chips.

One FLASH-FHE die saturates quickly under shallow-heavy Poisson streams (8
affiliations × ~0.15 Mcycle shallow services ≈ 50 jobs/Mcycle); the ROADMAP's
"millions of users" north star is a fleet problem.  This module shards a
single arrival stream across ``n_chips`` per-chip ``ServingEngine``s that all
tick inside ONE shared ``EventLoop`` — the router is itself a discrete-event
component: each arrival fires a routing event, the chosen engine schedules the
job, and completions flow back through the engine's ``on_job_complete`` hook
to keep the router's backlog estimates exact.

Dispatch policies (``ClusterConfig.router``):

  round_robin  — cyclic, state-free; the baseline every queueing text beats
  jsq          — join-shortest-queue by *estimated backlog cycles* (the sum of
                 outstanding routed service demand per chip); near-optimal
                 when service demand is known, as it is here (the cycle-level
                 simulator prices every job before placement)
  po2          — power-of-two-choices: sample two chips with the router's own
                 seeded RNG, keep the shorter backlog; O(1) state reads with
                 most of jsq's benefit (Mitzenmacher's classic result)
  affinity     — workload-affinity: route to the chip minimising
                 ``backlog + cold_start_penalty``, where the penalty is the
                 HBM cost of faulting the job's KSK/plaintext working set
                 (``working_set_bytes / hbm_bytes_per_cycle × cold_factor``)
                 into a chip whose warm-set doesn't hold it.  With penalties
                 zeroed this degrades to jsq exactly.

Warm-set model: every chip keeps an LRU of workload working sets capped at its
shared-L2 capacity (configurable).  ALL policies pay the cold-start penalty on
a warm-set miss — residency is a property of the chip, not of the router —
but only ``affinity`` *steers around* it.  The penalty is charged into the
job's service demand (``ServingEngine.submit(extra_cycles=...)``) so the
per-chip timeline invariants (work conservation, no overlap) hold
penalty-inclusive and ``ClusterResult.validate`` can re-assert them.

Quick use::

    from repro.core.hardware import FLASH_FHE
    from repro import serve

    jobs = serve.poisson_jobs(serve.PoissonConfig(rate_per_mcycle=200.0,
                                                  n_jobs=320, seed=7))
    result = serve.serve_cluster(jobs, FLASH_FHE, n_chips=4, router="jsq")
    print(serve.summarize(result))          # fleet-level SLOs
"""

from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict

import numpy as np

from repro.core.cache import MB
from repro.core.hardware import ChipConfig
from repro.core.jobs import FheJob
from repro.fhe.context import ExecPolicy

from .events import EventLoop
from .policy import JobExec, ServeResult, ServingEngine, working_set_bytes

ROUTERS = ("round_robin", "jsq", "po2", "affinity")


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Fleet shape + router policy + warm-set/cold-start model."""

    n_chips: int
    router: str = "jsq"
    seed: int = 0  # router-local RNG (po2 sampling) — split off via SeedSequence
    cold_start: bool = True  # model warm-set misses at all?
    cold_factor: float = 2.0  # penalty = factor × working_set_bytes / hbm_B_per_cycle
    warm_capacity_mb: float | None = None  # per-chip warm-set cap; default: chip L2
    hoist: bool = False  # legacy bool spelling of the hoisted-rotation kernel mode
    # service-time execution policy per engine; wins over ``hoist`` when set —
    # its ``policy_key()`` is what keys the per-(chip, workload, kind) memo
    exec_policy: ExecPolicy | None = None

    def __post_init__(self):
        if self.n_chips < 1:
            raise ValueError(f"n_chips must be >= 1, got {self.n_chips}")
        if self.router not in ROUTERS:
            raise ValueError(f"unknown router {self.router!r}; choose from {ROUTERS}")


@dataclasses.dataclass
class ClusterResult:
    """Per-chip timelines + the merged fleet view."""

    chip: ChipConfig
    config: ClusterConfig
    chip_results: list[ServeResult]  # NB: each carries the SHARED loop's event
    # total in events_processed (per-chip attribution is not meaningful when
    # one clock drives every engine); the fleet-wide count lives below
    jobs: list[JobExec]  # submission order (matching ``serve.serve`` semantics)
    placements: dict[int, int]  # job_id -> chip index
    makespan: float
    events_processed: int

    @property
    def n_chips(self) -> int:
        return self.config.n_chips

    def validate(self) -> "ClusterResult":
        """Fleet invariants on top of each chip's own ``ServeResult.validate``:
        every submitted job completed on EXACTLY one chip, the recorded
        placements match the per-chip timelines, and the fleet makespan is the
        max over chips."""
        for r in self.chip_results:
            r.validate()
        seen: dict[int, int] = {}
        for i, r in enumerate(self.chip_results):
            for je in r.jobs:
                assert je.job.job_id not in seen, (
                    f"job {je.job.job_id} appears on chips {seen[je.job.job_id]} and {i}"
                )
                assert je.chip_index == i, (
                    f"job {je.job.job_id} tagged chip {je.chip_index}, found on chip {i}"
                )
                seen[je.job.job_id] = i
        assert seen == self.placements, "router placements disagree with chip timelines"
        assert len(self.jobs) == len(seen), (
            f"{len(self.jobs)} jobs routed, {len(seen)} found on chips"
        )
        per_chip_mk = max((r.makespan for r in self.chip_results), default=0.0)
        assert abs(self.makespan - per_chip_mk) <= 1e-6 * max(1.0, per_chip_mk)
        return self


class ClusterRouter:
    """Front-end DES router: shards one arrival stream over N engines."""

    def __init__(self, chip: ChipConfig, config: ClusterConfig, loop: EventLoop | None = None):
        self.chip = chip
        self.config = config
        self.loop = loop if loop is not None else EventLoop()
        self.engines = [ServingEngine(chip, loop=self.loop, hoist=config.hoist,
                                      exec_policy=config.exec_policy)
                        for _ in range(config.n_chips)]
        for i, eng in enumerate(self.engines):
            eng.on_job_complete = functools.partial(self._completed, i)
        # estimated outstanding service cycles per chip: the simulator prices
        # each job at routing time and completions echo back.  An estimate,
        # not an oracle — spill/restore added to a preempted deep job after
        # placement is not re-echoed into the backlog
        self.backlog = [0.0] * config.n_chips
        self.placements: dict[int, int] = {}
        self._submit_order: list[int] = []  # job_ids in submission order
        self._seen_ids: set[int] = set()
        self._by_id: dict[int, JobExec] = {}
        self._rr_next = 0
        self._rng = np.random.default_rng(np.random.SeedSequence(config.seed))
        cap_mb = config.warm_capacity_mb if config.warm_capacity_mb is not None else chip.l2_mb
        self._warm_cap = cap_mb * MB
        self._warm: list[OrderedDict[str, float]] = [OrderedDict() for _ in range(config.n_chips)]

    # -- submission ---------------------------------------------------------

    def submit(self, job: FheJob) -> None:
        """Schedule the routing decision at the job's arrival instant."""
        assert job.job_id not in self._seen_ids, (
            f"duplicate job_id {job.job_id}: the router keys placements by id"
        )
        self._seen_ids.add(job.job_id)
        self._submit_order.append(job.job_id)
        self.loop.call_at(max(self.loop.now, float(job.arrival_cycle)),
                          lambda: self._route(job))

    # -- dispatch policies --------------------------------------------------

    def _pick(self, job: FheJob) -> int:
        n = self.config.n_chips
        if n == 1:
            return 0
        r = self.config.router
        if r == "round_robin":
            i = self._rr_next % n
            self._rr_next += 1
            return i
        if r == "jsq":
            return min(range(n), key=lambda i: (self.backlog[i], i))
        if r == "po2":
            a, b = (int(x) for x in self._rng.choice(n, size=2, replace=False))
            return a if (self.backlog[a], a) <= (self.backlog[b], b) else b
        # affinity: total marginal cost = backlog + the cold-start you'd pay
        return min(range(n), key=lambda i: (self.backlog[i] + self._cold_penalty(job, i), i))

    # -- warm-set / cold-start model ----------------------------------------

    def _cold_penalty(self, job: FheJob, i: int) -> float:
        if not self.config.cold_start or job.workload in self._warm[i]:
            return 0.0
        return self.config.cold_factor * working_set_bytes(job) / self.chip.hbm_bytes_per_cycle

    def _touch_warm(self, job: FheJob, i: int) -> None:
        w = self._warm[i]
        if job.workload in w:
            w.move_to_end(job.workload)
        else:
            w[job.workload] = working_set_bytes(job)
        while len(w) > 1 and sum(w.values()) > self._warm_cap:
            w.popitem(last=False)  # evict least-recently-used working set

    # -- event handlers ------------------------------------------------------

    def _route(self, job: FheJob) -> None:
        i = self._pick(job)
        pay = self._cold_penalty(job, i)  # counted in metrics via cold_start_cycles
        self._touch_warm(job, i)
        je = self.engines[i].submit(job, extra_cycles=pay)
        je.chip_index = i
        self.placements[job.job_id] = i
        self._by_id[job.job_id] = je
        self.backlog[i] += je.service_cycles

    def _completed(self, i: int, je: JobExec) -> None:
        self.backlog[i] = max(0.0, self.backlog[i] - je.service_cycles)

    # -- run -----------------------------------------------------------------

    def run(self) -> ClusterResult:
        self.loop.run()
        chip_results = [eng.result() for eng in self.engines]
        makespan = max((r.makespan for r in chip_results), default=0.0)
        jobs = [self._by_id[jid] for jid in self._submit_order]  # submission order
        return ClusterResult(chip=self.chip, config=self.config,
                             chip_results=chip_results, jobs=jobs,
                             placements=dict(self.placements), makespan=makespan,
                             events_processed=self.loop.processed)


def serve_cluster(jobs: list[FheJob], chip: ChipConfig, n_chips: int = 2,
                  router: str = "jsq", seed: int = 0, cold_start: bool = True,
                  cold_factor: float = 2.0, warm_capacity_mb: float | None = None,
                  config: ClusterConfig | None = None,
                  validate: bool = True, hoist: bool = False,
                  exec_policy: ExecPolicy | None = None) -> ClusterResult:
    """Serve an open-loop job list on an ``n_chips`` fleet; the one-call API.

    Pass ``config=`` to reuse a prepared ``ClusterConfig`` (the keyword
    arguments are ignored in that case); ``exec_policy`` sets the per-engine
    service-time execution policy (wins over the legacy ``hoist=`` bool).
    """
    cfg = config if config is not None else ClusterConfig(
        n_chips=n_chips, router=router, seed=seed, cold_start=cold_start,
        cold_factor=cold_factor, warm_capacity_mb=warm_capacity_mb, hoist=hoist,
        exec_policy=exec_policy)
    rt = ClusterRouter(chip, cfg)
    for job in jobs:
        rt.submit(job)
    result = rt.run()
    return result.validate() if validate else result
