"""Online multi-tenant scheduling policies over the discrete-event engine.

Implements the paper's §4.2 policy as a *reactive* scheduler driven by
arrival/completion events (replacing the old one-pass offline heuristic in
``repro.core.scheduler``):

  * shallow job → exactly ONE cluster affiliation, with the affiliation's
    bootstrappable circuit decomposed into two extra swift pipelines
    (multi-exit — the lane math lives in ``core.simulator.lanes_shallow``);
  * deep job → gang-scheduled across ALL bootstrappable clusters
    (exclusive: every affiliation is occupied while a deep job runs);
  * priority preemption: a running deep job is suspended when a
    strictly-higher-priority shallow job arrives.  Suspension runs a proper
    state machine (QUEUED → RUNNING → SUSPENDED → RUNNING → DONE) and charges
    the SRAM→HBM working-set spill plus the later restore to the *deep* job's
    remaining work — the DMA overlaps the incoming shallow job's ramp-up, so
    affiliations free immediately (matching the paper's "avoid the convoy
    effect" argument).  A preemption at zero progress spills nothing.

  Deep jobs otherwise yield to shallow traffic (the paper schedules one
  shallow job per affiliation to maximise throughput); a *waiting* deep job
  with strictly higher priority than a queued shallow job drains the chip
  instead of letting that shallow job jump ahead, so priorities mean the same
  thing in both directions.

Two extensions beyond the single-chip policy live here too:

  * ``FlashPolicy(deep_coop=True)`` grants deep jobs the swift clusters as
    well (``core.simulator.lanes_deep_coop``): large-point NTTs decompose
    across boot+swift pipelines with every (i)NTT routed through the L3
    transpose module — deep service time drops, bounded by the transpose
    bandwidth (the paper's §7 future-work direction).
  * ``GangReservation`` is the cross-chip deep-gang barrier used by
    ``repro.serve.cluster``: one deep job splits across M identical chips'
    bootstrappable clusters, with serialized inter-chip link exchanges
    (``gang_service_cycles``) charged into every fragment's service demand so
    per-chip work conservation still validates.  Fragments start, suspend
    (a preemption on ANY member suspends the whole gang), resume, and finish
    in lockstep.

``SequentialPolicy`` is the CraterLake / F1+ baseline: whole chip per job,
non-preemptive, highest-priority-then-arrival at each dispatch point.

Per-job service times come from the cycle-level simulator
(``core.simulator.simulate_stream``) over planner instruction streams, so the
fused-key-switch accounting composes directly.  Identical
(chip, workload, kind, ``ExecPolicy.policy_key()``) jobs share one memoised
``SimResult`` — the policy key is the canonical identity of the execution
mode (scheme, kernel pipeline, hoisting, numerics); each job's policy is
re-tagged with its scheme (CKKS vs BGV) before keying, so mixed-scheme
streams never alias cached service times.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from typing import Callable

from repro.core.cache import MB
from repro.core.hardware import ChipConfig
from repro.core.jobs import FheJob
from repro.core.planner import workload_stream
from repro.core.simulator import (
    SimResult,
    lanes_deep,
    lanes_deep_coop,
    lanes_shallow,
    lanes_whole_chip,
    simulate_stream,
)
from repro.fhe.context import ExecPolicy

from .events import Event, EventLoop

_TOL = 1e-6  # cycle-arithmetic tolerance used by the consistency checks


class JobState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    SUSPENDED = "suspended"
    DONE = "done"
    # terminal rejection: admission control (router) or queue-timeout (engine)
    # dropped the job before it ever ran — no segments, no completion, and the
    # work-conservation invariants exclude it
    SHED = "shed"
    # fault injection (repro.serve.faults): the attempt died under it — chip
    # crash, gang abort, or a transient job fault.  The record freezes (each
    # retry is a FRESH JobExec) with ``failed_cycle`` set and the running
    # invariant busy + remaining == service + spill + wasted still holding
    FAILED_TRANSIENT = "failed_transient"
    # terminal: retries exhausted (or recovery disabled) — the fleet gave up
    FAILED = "failed"


@dataclasses.dataclass(frozen=True)
class Segment:
    """One contiguous occupancy interval on a resource.

    ``resource`` is ``affiliation-<i>`` for shallow placements and ``deep``
    for gang placements (which occupy *every* affiliation).  ``chip`` is the
    fleet chip index the interval ran on — retried jobs can hold segments on
    several chips, so overlap checks must group by (chip, resource).
    """

    start: float
    end: float
    resource: str
    chip: int = 0

    @property
    def cycles(self) -> float:
        return self.end - self.start


@dataclasses.dataclass
class JobExec:
    """Execution record + suspend/resume state machine for one job."""

    job: FheJob
    service_cycles: float
    sim: SimResult | None  # None only for admission-shed jobs (never priced)
    lanes: str  # final placement label (affiliation-i / deep / whole-chip)
    state: JobState = JobState.QUEUED
    remaining: float = 0.0  # cycles left, incl. unpaid spill/restore overhead
    segments: list[Segment] = dataclasses.field(default_factory=list)
    first_start: float | None = None
    completion: float | None = None
    spill_restore_cycles: float = 0.0
    n_preemptions: int = 0
    chip_index: int = 0  # which fleet chip served the job (0 when single-chip)
    cold_start_cycles: float = 0.0  # router-charged warm-set miss, part of service_cycles
    # cross-chip gang fields: a ganged deep job has one JobExec *fragment* per
    # member chip, all pointing at the same reservation and moving in lockstep
    gang: "GangReservation | None" = dataclasses.field(default=None, repr=False)
    gang_rank: int = 0  # this fragment's position in the gang (0 = primary)
    gang_size: int = 1  # chips in the gang (1 = not ganged)
    link_cycles: float = 0.0  # per-chip inter-chip exchange stalls, inside service_cycles
    link_bytes: float = 0.0  # gang-total link traffic, recorded on the rank-0 fragment
    shed_cycle: float | None = None  # instant the job was dropped (SHED only)
    # fault/retry accounting (repro.serve.faults): each retry is a FRESH record
    attempts: int = 1  # 1-based attempt number this record represents
    wasted_cycles: float = 0.0  # THIS attempt's lost work: failed runs + straggler excess
    prior_wasted_cycles: float = 0.0  # waste carried from earlier failed attempts
    checkpoint_cycles: float = 0.0  # work a checkpoint resume skipped (vs full restart)
    full_service_cycles: float = 0.0  # un-checkpointed demand, for the turnaround identity
    failed_cycle: float | None = None  # instant the attempt died (FAILED* only)
    _has_checkpoint: bool = False  # a SRAM→HBM spill exists to resume from
    _run_factor: float = 1.0  # straggler slowdown of the current run segment
    _run_start: float | None = None
    _suspended_at: float | None = None  # last preemption time (aging reference)
    _complete_ev: Event | None = None
    _deadline_ev: Event | None = None  # queue-timeout shed deadline, if armed

    def __post_init__(self):
        self.remaining = self.service_cycles
        if self.full_service_cycles == 0.0:
            self.full_service_cycles = self.service_cycles

    @property
    def kind(self) -> str:
        return self.job.kind

    @property
    def time_to_shed(self) -> float:
        """Arrival → shed decision (0.0 = rejected at admission)."""
        assert self.shed_cycle is not None, "job was not shed"
        return self.shed_cycle - self.job.arrival_cycle

    @property
    def turnaround(self) -> float:
        assert self.completion is not None, "job not finished"
        return self.completion - self.job.arrival_cycle

    @property
    def queueing_delay(self) -> float:
        assert self.first_start is not None, "job never started"
        return self.first_start - self.job.arrival_cycle

    @property
    def wasted_total(self) -> float:
        """All fault-lost work across attempts: failed runs, straggler excess,
        and abandoned spill payments — everything busy that was not progress."""
        return self.prior_wasted_cycles + self.wasted_cycles

    @property
    def preempted_cycles(self) -> float:
        """Extra cycles vs an uninterrupted run: suspension gaps, spill/restore,
        retry backoff and re-queue gaps — everything between first start and
        completion that is neither service demand nor fault-wasted work.
        Crash-requeue spill goes to ``wasted_cycles``, never double-counted
        here: turnaround = queueing_delay + full_service + preempted + wasted.
        """
        if self.completion is None or self.first_start is None:
            return 0.0
        return ((self.completion - self.first_start)
                - self.full_service_cycles - self.wasted_total)

    @property
    def busy_cycles(self) -> float:
        return sum(s.cycles for s in self.segments)


def working_set_bytes(job: FheJob) -> float:
    """SRAM-resident state a preempted deep job must spill: two ciphertext
    polynomials over the extended basis plus key-switch accumulators."""
    p = job.params
    return 6.0 * (p.L + 1 + p.alpha) * p.n * 4.0


# ---------------------------------------------------------------------------
# admission control (overload protection)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Overload-protection policy: which jobs get dropped (``JobState.SHED``)
    instead of growing the backlog without bound.

    Three independent mechanisms, each off (``None``) by default:

      * ``max_wait_cycles`` — *utilization reserve* at the cluster router: a
        job is shed on arrival when the best estimated wait across the fleet
        (``ClusterRouter._wait``, the same drain-width/serial estimator the
        ``hetero`` router uses) already exceeds this bound.  This is what
        keeps queues bounded under sustained overload: once the fleet's
        backlog covers ``max_wait_cycles`` of work, further arrivals shed at
        the door rather than queueing behind it.
      * ``tenant_rate_per_mcycle`` (+ ``tenant_burst``) — a classic *token
        bucket per tenant* at the router: each tenant's bucket refills at the
        rate (jobs per Mcycle of simulated time) up to the burst capacity and
        each admitted job takes one token; an empty bucket sheds.  Isolates
        an abusive tenant: a flood drains only its own bucket, so a
        well-behaved tenant's admissions are untouched.
      * ``shed_after_cycles`` — an *engine-level queue timeout*: a job still
        QUEUED (never started) this many cycles after arrival is shed where
        it waits.  This is the SLO backstop for jobs the router admitted into
        a queue that subsequently congested (e.g. behind a deep gang); its
        ``time_to_shed`` is exactly this bound, where router sheds are 0.

    Shed jobs are terminal: no segments, no completion, queued events
    cancelled, never counted into warm-sets, and their admission never
    touched (router path) or is echoed back out of (engine path) the backlog
    estimators.
    """

    max_wait_cycles: float | None = None
    tenant_rate_per_mcycle: float | None = None
    tenant_burst: float = 8.0
    shed_after_cycles: float | None = None

    def __post_init__(self):
        if self.max_wait_cycles is not None and self.max_wait_cycles < 0:
            raise ValueError(f"max_wait_cycles must be >= 0, got {self.max_wait_cycles}")
        if self.tenant_rate_per_mcycle is not None and self.tenant_rate_per_mcycle <= 0:
            raise ValueError(
                f"tenant_rate_per_mcycle must be positive, got {self.tenant_rate_per_mcycle}")
        if self.tenant_burst < 1:
            raise ValueError(f"tenant_burst must be >= 1, got {self.tenant_burst}")
        if self.shed_after_cycles is not None and self.shed_after_cycles <= 0:
            raise ValueError(
                f"shed_after_cycles must be positive, got {self.shed_after_cycles}")


class TokenBucket:
    """Continuous-refill token bucket (rate in tokens per Mcycle).

    Starts full.  ``try_take`` refills by elapsed simulated time, then either
    spends one token (admit) or reports empty (shed).  Fractional tokens
    accumulate, so a rate of 0.5/Mcycle admits one job every 2 Mcycles in
    steady state.
    """

    __slots__ = ("rate_per_cycle", "burst", "tokens", "_t")

    def __init__(self, rate_per_mcycle: float, burst: float):
        assert rate_per_mcycle > 0 and burst >= 1
        self.rate_per_cycle = rate_per_mcycle / 1e6
        self.burst = float(burst)
        self.tokens = float(burst)
        self._t = 0.0

    def try_take(self, now: float) -> bool:
        self.tokens = min(self.burst, self.tokens + (now - self._t) * self.rate_per_cycle)
        self._t = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


# ---------------------------------------------------------------------------
# service-time model (memoised cycle simulation)
# ---------------------------------------------------------------------------

_SERVICE_MEMO: dict[tuple, SimResult] = {}


def exec_policy_from_hoist(hoist: bool) -> ExecPolicy:
    """The ExecPolicy equivalent of the legacy ``hoist=`` bool: the fused
    accelerator pipeline, with hoisted vs per-rotation key-switching."""
    return ExecPolicy(backend="fused", hoisting="always" if hoist else "never")


def job_service_sim(job: FheJob, chip: ChipConfig, hoist: bool = False,
                    policy: ExecPolicy | None = None,
                    deep_coop: bool = False) -> SimResult:
    """Cycle-accurate service time for one job under its granted lanes.

    Identical (chip, workload, kind, policy_key, coop) tuples share one
    SimResult — the planner stream and lane grant are functions of those
    alone, so the simulation is too.  ``ExecPolicy.policy_key()`` is the
    single source of truth for the execution-mode part of the key: it covers
    the kernel pipeline, the hoisting mode, and the numerics mode, and
    distinct policies never alias — a memo keyed only on (chip, workload,
    kind) would silently hand post-hoisting callers the pre-hoisting cycle
    counts.  ``deep_coop`` grants a deep job the swift clusters too
    (``lanes_deep_coop``; ignored for shallow jobs and whole-chip baselines).
    The legacy ``hoist=`` bool maps through ``exec_policy_from_hoist`` when
    no policy is given.  Callers must treat the result as read-only.
    """
    policy = policy if policy is not None else exec_policy_from_hoist(hoist)
    # re-tag the execution policy with the job's scheme (CKKS vs BGV): a mixed
    # stream prices BGV jobs off their own planner expansions, and the
    # scheme-leading policy_key keeps the memo entries from aliasing
    policy = policy.for_scheme(job.scheme)
    coop = bool(deep_coop) and job.kind == "deep" and chip.multi_job
    key = (chip, job.workload, job.kind, policy.policy_key(), coop)
    hit = _SERVICE_MEMO.get(key)
    if hit is not None:
        return hit
    if not chip.multi_job:
        lanes, cache_mb = lanes_whole_chip(chip), chip.total_cache_mb
    elif job.kind == "shallow":
        # L2 is shared: a shallow job sees its L1 plus a 1/n_aff share of L2
        lanes = lanes_shallow(chip)
        cache_mb = chip.l1_mb_per_aff + chip.l2_mb / chip.n_affiliations
    else:
        lanes = lanes_deep_coop(chip) if coop else lanes_deep(chip)
        cache_mb = chip.total_cache_mb
    stream = workload_stream(job.workload, job.params, mode="hw", policy=policy)
    sim = simulate_stream(stream, chip, lanes, cache_bytes=cache_mb * MB)
    _SERVICE_MEMO[key] = sim
    return sim


# ---------------------------------------------------------------------------
# cross-chip deep gangs (service model + lockstep barrier)
# ---------------------------------------------------------------------------

GANG_SYNCS = 8  # global barriers per ganged deep job (bootstrap stage boundaries)


def gang_link_bytes(job: FheJob, n_chips: int, syncs: int = GANG_SYNCS) -> float:
    """Total inter-chip link traffic for one ``n_chips``-wide deep gang.

    The gang shards a deep job's independent baby-step/batch work across M
    chips' bootstrappable clusters and synchronises at ``syncs`` global
    barriers (the bootstrapping stage boundaries: CtS radix stages, EvalMod,
    StC).  Each barrier all-gathers the sharded ciphertext working set — of
    which a ``(M-1)/M`` fraction is remote to any member — in both
    directions (scatter updated shards, gather the merged state), hence the
    factor 2.  Monotone in M: wider gangs exchange strictly more bytes.
    """
    if n_chips <= 1:
        return 0.0
    return 2.0 * syncs * working_set_bytes(job) * (n_chips - 1) / n_chips


def gang_service_cycles(single_chip_cycles: float, job: FheJob, n_chips: int,
                        link_bytes_per_cycle: float,
                        syncs: int = GANG_SYNCS) -> tuple[float, float]:
    """Per-chip busy time ``(cycles, link_cycles)`` of an M-chip deep gang.

    Compute shards M ways; every member then stalls through the serialized
    link exchanges (the link is the bottleneck during a barrier, so its cost
    is charged into each fragment's service demand — work conservation stays
    penalty-inclusive, exactly like the router's cold-start charge).  The
    link is priced ≫ the on-chip L3 transpose: at the default 256 B/cycle it
    moves bytes 32× slower than the 2048-port transpose module and 4× slower
    than one chip's HBM.
    """
    if n_chips <= 1:
        return float(single_chip_cycles), 0.0
    link = gang_link_bytes(job, n_chips, syncs) / float(link_bytes_per_cycle)
    return float(single_chip_cycles) / n_chips + link, link


class GangReservation:
    """Lockstep barrier for ONE deep job split across M chips.

    The cluster router creates one reservation per multi-chip deep placement
    and submits a fragment ``JobExec`` to each member engine; every fragment
    carries the full per-chip gang demand (``gang_service_cycles``).  The
    fragments move through the state machine in lockstep:

      * start / resume — each member signals ``member_ready`` once its chip
        has drained; its ``FlashPolicy`` then *holds* the chip idle
        (``_gang_hold``, no shallow admission) so the reservation cannot be
        stolen.  When the LAST member arrives the barrier fires a zero-delay
        launch event and every fragment enters RUNNING at the same instant —
        holding is the visible queueing price of aligning M chips.
      * preempt — a strictly-higher-priority shallow arrival on ANY member
        chip suspends EVERY fragment at that instant (each spills its 1/M
        shard of the working set), after which members independently drain
        and re-enter the barrier.

    Members must be identical (chip, exec-policy) pairs so fragments price
    and progress identically — the router's gang planner groups chips by
    exactly that key.
    """

    def __init__(self, job: FheJob, loop: EventLoop):
        self.job = job
        self.loop = loop
        self.members: list[tuple["FlashPolicy", JobExec]] = []
        self._ready: set[int] = set()
        self._launch_pending = False
        self.running = False
        self.aborted = False  # fault abort: the gang is dead, fragments frozen

    @property
    def size(self) -> int:
        return len(self.members)

    def attach(self, policy: "FlashPolicy", je: JobExec) -> None:
        assert isinstance(policy, FlashPolicy), (
            "gang fragments need a FlashPolicy chip (multi_job=True)"
        )
        self.members.append((policy, je))

    def member_ready(self, policy: "FlashPolicy") -> None:
        """Barrier arrival (idempotent); launches once every member holds."""
        if self.aborted:
            return
        if policy.tracer and id(policy) not in self._ready:
            je = next(j for p, j in self.members if p is policy)
            policy.tracer.instant(
                "gang_ready", pid=je.chip_index + 1,
                tid=policy.tracer.track(je.chip_index + 1, "deep"),
                job=self.job.job_id, rank=je.gang_rank, size=self.size)
        self._ready.add(id(policy))
        if len(self._ready) == self.size and not self._launch_pending:
            self._launch_pending = True
            self.loop.call_after(0.0, self._launch)

    def _launch(self) -> None:
        self._launch_pending = False
        if self.aborted:
            return  # a member chip died between barrier entry and launch
        self._ready.clear()
        self.running = True
        # lockstep pacing: every fragment runs at the SLOWEST member's factor,
        # so a straggler chip drags the whole gang (the real failure mode wide
        # gangs have) and fragments still finish at the same instant
        factor = max(p.slow_factor for p, _ in self.members)
        for policy, je in self.members:
            if policy.tracer:
                policy.tracer.instant(
                    "gang_launch", pid=je.chip_index + 1,
                    tid=policy.tracer.track(je.chip_index + 1, "deep"),
                    job=self.job.job_id, rank=je.gang_rank, factor=factor)
            policy._gang_launch(je, factor)

    def suspend(self) -> None:
        """Gang-wide preemption: suspend every fragment at this instant."""
        if not self.running:
            return
        self.running = False
        for policy, je in self.members:
            policy._gang_suspend(je)

    def abort(self, now: float) -> list[JobExec]:
        """Fault-driven lockstep abort: a member chip died (or a fragment hit
        a transient fault), so EVERY fragment fails at this instant — per-chip
        shard checkpoints are useless once gang membership changes, so the job
        re-plans from scratch on the healthy sub-fleet.  Idempotent; returns
        the newly-failed fragment records (all sharing one ``failed_cycle``).
        """
        if self.aborted:
            return []
        self.aborted = True
        self.running = False
        self._ready.clear()
        victims: list[JobExec] = []
        for policy, je in self.members:
            if je.state in (JobState.QUEUED, JobState.RUNNING, JobState.SUSPENDED):
                policy._gang_member_fail(je, now)
                victims.append(je)
        return victims


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------


# states that mark a queue entry dead-in-place (lazily purged, never dispatched)
_DEAD_STATES = (JobState.SHED, JobState.FAILED_TRANSIENT, JobState.FAILED)


class _PriorityQueue:
    """Max-priority, then FIFO-by-arrival, then submission order.

    Shed/failed entries are dropped lazily: a queue-timeout shed (or a fault)
    marks the job terminal in place (O(1)) and the entry is discarded whenever
    it surfaces at the top — the same trick the event heap uses for
    cancellations."""

    def __init__(self):
        self._heap: list[tuple[float, float, int, JobExec]] = []
        self._seq = itertools.count()

    def _purge(self) -> None:
        while self._heap and self._heap[0][-1].state in _DEAD_STATES:
            heapq.heappop(self._heap)

    def __len__(self) -> int:
        # after the purge a non-zero length guarantees a live (non-shed) head,
        # which is all the dispatch loops rely on; shed entries buried deeper
        # may still be counted until they surface
        self._purge()
        return len(self._heap)

    def push(self, je: JobExec) -> None:
        heapq.heappush(self._heap, (-je.job.priority, je.job.arrival_cycle, next(self._seq), je))

    def pop(self) -> JobExec:
        self._purge()
        return heapq.heappop(self._heap)[-1]

    def peek(self) -> JobExec | None:
        self._purge()
        return self._heap[0][-1] if self._heap else None


def _cancel_deadline(je: JobExec) -> None:
    """Revoke a job's queue-timeout shed deadline (it is starting to run)."""
    if je._deadline_ev is not None:
        je._deadline_ev.cancel()
        je._deadline_ev = None


# -- tracing helpers (repro.obs seam) ----------------------------------------
# Every emission is guarded by ``if tracer:`` — ``tracer`` is None (or a
# disabled Tracer, which is falsy) on every default path, so the serving hot
# loops pay one attribute test.  Conventions (see docs/observability.md):
# pid = chip_index + 1 (pid 0 is the fleet router), tid = the resource track
# (affiliation-i / deep / whole-chip / chip), job lifecycles are async spans
# keyed by job_id with state-transition instants.  Gang fragments share one
# job id, so only the rank-0 fragment speaks for the job's async span; every
# fragment still records its own run segments on its own chip's tracks.

# turnaround histogram buckets (cycles): decade-ish ladder covering shallow
# sub-ms jobs through deep bootstrapped pipelines at 1 GHz-scale clocks
TURNAROUND_BUCKETS = (1e4, 3e4, 1e5, 3e5, 1e6, 3e6, 1e7, 3e7, 1e8, 3e8, 1e9)


def _trace_segment(tracer, je: JobExec, start: float, end: float,
                   resource: str) -> None:
    """One closed run interval — emitted exactly where ``segments.append`` is."""
    if tracer:
        pid = je.chip_index + 1
        tracer.complete(je.job.workload, start, end, pid=pid,
                        tid=tracer.track(pid, resource),
                        job=je.job.job_id, kind=je.kind, attempt=je.attempts)


def _primary(je: JobExec) -> bool:
    return je.gang is None or je.gang_rank == 0


def _trace_state(tracer, je: JobExec, state: str, **args) -> None:
    if tracer and _primary(je):
        tracer.job_state(je.job.job_id, je.job.workload, state,
                         pid=je.chip_index + 1, attempt=je.attempts, **args)


def _trace_job_end(tracer, je: JobExec, state: str) -> None:
    if tracer and _primary(je):
        tracer.job_end(je.job.job_id, je.job.workload, state,
                       pid=max(je.chip_index, -1) + 1)


def _fail_record(je: JobExec, now: float, resource: str, chip: ChipConfig,
                 tracer=None) -> None:
    """Freeze one attempt record as FAILED_TRANSIENT with consistent books.

    Closes any open run segment (that wall time is lost → ``wasted_cycles``).
    A deep job holding a SRAM→HBM spill checkpoint keeps its ``remaining``
    (the retry resumes from the last suspension point, paying one fresh HBM
    restore); everything else restarts from zero — its entire busy history
    becomes waste and abandoned spill payments are re-classified as waste too,
    so the frozen record satisfies busy + remaining == service + spill +
    wasted and fleet-wide work conservation stays checkable.
    """
    _cancel_deadline(je)
    if je._complete_ev is not None:
        je._complete_ev.cancel()
        je._complete_ev = None
    if je.state is JobState.RUNNING and je._run_start is not None:
        w = now - je._run_start
        if w > 0:
            je.segments.append(Segment(je._run_start, now, resource, chip=je.chip_index))
            _trace_segment(tracer, je, je._run_start, now, resource)
        je.wasted_cycles += w
        je._run_start = None
        if je._has_checkpoint:
            # the checkpoint survives in HBM; the retry pays one restore
            pay = working_set_bytes(je.job) / je.gang_size / chip.hbm_bytes_per_cycle
            je.remaining += pay
            je.spill_restore_cycles += pay
    if not je._has_checkpoint:
        je.wasted_cycles = je.busy_cycles
        je.spill_restore_cycles = 0.0
        je.remaining = je.service_cycles
    je.state = JobState.FAILED_TRANSIENT
    je.failed_cycle = now
    _trace_state(tracer, je, "FAILED_TRANSIENT", resource=resource)


class _DeferredDispatchMixin:
    """Coalesce dispatch: arrivals/completions enqueue state changes, and the
    actual placement decision runs in a zero-delay follow-up event.  This makes
    simultaneous arrivals commute — all jobs landing at cycle *t* are queued
    before any of them is placed, so priority order (not event insertion
    order) decides, matching the old offline sort semantics."""

    loop: EventLoop | None
    _dispatch_pending: bool

    def _schedule_dispatch(self) -> None:
        if not self._dispatch_pending:
            self._dispatch_pending = True
            self.loop.call_after(0.0, self._run_dispatch)

    def _run_dispatch(self) -> None:
        self._dispatch_pending = False
        self.dispatch()


class FlashPolicy(_DeferredDispatchMixin):
    """The paper's §4.2 heterogeneous multi-job policy (online form).

    ``aging_quanta`` is the deep-job aging / utilization-reserve knob
    (ROADMAP): a saturating same-priority shallow stream would otherwise
    starve a deep job indefinitely, because the gang launch needs every
    affiliation free at once.  Once the oldest waiting (or suspended) deep
    job has queued longer than ``aging_quanta`` × the observed mean shallow
    service time, the policy stops admitting shallow jobs at or below the
    deep job's priority — the chip drains within one shallow quantum and the
    gang launches.  ``None`` (the default) disables aging: the knob trades
    shallow tail latency for a deep-job starvation bound, so operators opt
    in per deployment (``tests/test_serving.py`` pins both behaviours).
    Strictly-higher-priority shallow traffic still overtakes an aged deep
    job, so priorities keep their meaning.

    ``deep_coop`` grants deep jobs the swift clusters too
    (``lanes_deep_coop``): the serving engine prices deep services with the
    boot+swift lane grant, trading L3-transpose traffic for lane width —
    shallow services are untouched.  Off by default because it is a
    beyond-paper mode (§7 future work); ``tests/test_serving.py`` pins that
    it strictly reduces deep p99 on a deep-only stream.
    """

    def __init__(self, chip: ChipConfig, aging_quanta: float | None = None,
                 deep_coop: bool = False):
        assert chip.multi_job, f"{chip.name} cannot co-schedule jobs (multi_job=False)"
        assert aging_quanta is None or aging_quanta > 0
        self.chip = chip
        self.aging_quanta = aging_quanta
        self.deep_coop = bool(deep_coop)
        self.loop: EventLoop | None = None
        self.on_complete: Callable[[JobExec], None] = lambda je: None
        self._dispatch_pending = False
        self.tracer = None  # repro.obs seam; the owning ServingEngine sets it
        # fault state (repro.serve.faults): a dead chip accepts no work; a
        # straggler window stretches every NEW run segment by slow_factor
        self.alive = True
        self.slow_factor = 1.0
        self.aff_running: list[JobExec | None] = [None] * chip.n_affiliations
        self.shallow_q = _PriorityQueue()
        self.deep_q = _PriorityQueue()
        self.deep_active: JobExec | None = None
        # holding for a cross-chip gang barrier: the chip stays drained (no
        # shallow admission) until every member chip is ready
        self._gang_hold = False
        self._deep_label = (lanes_deep_coop(chip) if self.deep_coop
                            else lanes_deep(chip)).label
        self._shallow_svc_sum = 0.0
        self._shallow_svc_n = 0

    def bind(self, loop: EventLoop, on_complete: Callable[[JobExec], None]) -> None:
        self.loop = loop
        self.on_complete = on_complete

    def submit(self, je: JobExec) -> None:
        # a FAILED_TRANSIENT entry can legitimately arrive here (its arrival
        # event raced a crash at the same instant); the queue purges it lazily.
        # A live QUEUED submission to a dead chip is a router bug.
        assert self.alive or je.state is not JobState.QUEUED, (
            f"job {je.job.job_id} routed to dead chip {je.chip_index}"
        )
        (self.shallow_q if je.kind == "shallow" else self.deep_q).push(je)
        self._schedule_dispatch()

    def _aged(self, je: JobExec, now: float) -> bool:
        """Has this deep job *waited* past the aging threshold?

        Waiting is measured from arrival for a never-started job and from the
        last suspension for a preempted one — time spent RUNNING must not
        count, or a long-running deep job would be "aged" the instant it is
        preempted.  The shallow quantum is the running mean of *completed*
        shallow service times — before any shallow job completes there is
        nothing to starve behind, so aging stays off and arrival-order
        semantics are unchanged.
        """
        if self.aging_quanta is None or self._shallow_svc_n == 0:
            return False
        since = je._suspended_at if je._suspended_at is not None else je.job.arrival_cycle
        quantum = self._shallow_svc_sum / self._shallow_svc_n
        return (now - since) >= self.aging_quanta * quantum

    # -- dispatch -----------------------------------------------------------

    def dispatch(self) -> None:
        now = self.loop.now
        self._maybe_preempt(now)
        self._place_shallow(now)
        self._maybe_start_deep(now)

    def _maybe_preempt(self, now: float) -> None:
        d = self.deep_active
        top = self.shallow_q.peek()
        if d is None or d.state is not JobState.RUNNING or top is None:
            return
        if top.job.priority <= d.job.priority:
            return
        if d.gang is not None:
            d.gang.suspend()  # lockstep: every member fragment suspends now
        else:
            self._suspend_deep(d, now)

    def _suspend_deep(self, d: JobExec, now: float) -> None:
        # suspend: close the deep segment, revoke its completion, charge the
        # SRAM→HBM spill + later restore to its remaining work (a gang
        # fragment spills only its 1/M shard of the working set).  Under a
        # straggler window only worked/_run_factor of the wall time is real
        # progress; the excess is charged to wasted_cycles.  The spilled image
        # doubles as a crash checkpoint (_has_checkpoint) for retries.
        worked = now - d._run_start
        d._complete_ev.cancel()
        spill_pay = 0.0
        if worked > 0:
            progress = worked / d._run_factor
            d.segments.append(Segment(d._run_start, now, "deep", chip=d.chip_index))
            _trace_segment(self.tracer, d, d._run_start, now, "deep")
            pay = (2.0 * working_set_bytes(d.job) / d.gang_size
                   / self.chip.hbm_bytes_per_cycle)
            d.remaining = max(0.0, d.remaining - progress) + pay
            d.spill_restore_cycles += pay
            d.wasted_cycles += worked - progress
            d._has_checkpoint = True
            spill_pay = pay
        d.n_preemptions += 1
        _trace_state(self.tracer, d, "SUSPENDED", spill_cycles=spill_pay)
        d.state = JobState.SUSPENDED
        d._run_start = None
        d._suspended_at = now  # aging clock restarts: only waiting counts
        d._complete_ev = None

    # -- gang callbacks (invoked by GangReservation, possibly cross-chip) ----

    def _gang_launch(self, d: JobExec, factor: float = 1.0) -> None:
        self._gang_hold = False
        self._run_deep(d, self.loop.now, factor=factor)

    def _gang_suspend(self, d: JobExec) -> None:
        if d.state is not JobState.RUNNING:
            return
        self._suspend_deep(d, self.loop.now)
        self._schedule_dispatch()  # this chip's affiliations just freed

    def _deep_fence(self, now: float) -> tuple[float, bool] | None:
        """(priority, strict) below which shallow jobs yield to a deep job.

        ``strict`` (set by aging) also fences *equal*-priority shallow jobs —
        the starvation case the knob exists for.  A suspended deep job fences
        only once aged (it was legitimately preempted); a queued head fences
        lower priorities always, equals only when aged."""
        d = self.deep_active
        if d is not None:
            if d.state is JobState.SUSPENDED and self._aged(d, now):
                return d.job.priority, True
            return None
        head = self.deep_q.peek()
        if head is None:
            return None
        return head.job.priority, self._aged(head, now)

    def _place_shallow(self, now: float) -> None:
        if self._gang_hold:
            return  # chip is reserved for a cross-chip gang barrier
        if self.deep_active is not None and self.deep_active.state is JobState.RUNNING:
            return  # deep gang owns every affiliation
        fence = self._deep_fence(now)
        while len(self.shallow_q):
            top = self.shallow_q.peek()
            if fence is not None and (
                top.job.priority < fence[0] or (fence[1] and top.job.priority <= fence[0])
            ):
                return  # drain for the (possibly aged) deep job
            free = [i for i, r in enumerate(self.aff_running) if r is None]
            if not free:
                return
            self._start_shallow(self.shallow_q.pop(), free[0], now)

    def _start_shallow(self, je: JobExec, aff: int, now: float) -> None:
        _cancel_deadline(je)
        je.state = JobState.RUNNING
        _trace_state(self.tracer, je, "RUNNING", resource=f"affiliation-{aff}")
        je.lanes = f"affiliation-{aff}"
        if je.first_start is None:  # a retry keeps its original first start
            je.first_start = now
        je._run_start = now
        je._run_factor = self.slow_factor
        self.aff_running[aff] = je
        je._complete_ev = self.loop.call_after(
            je.remaining * je._run_factor, lambda: self._finish_shallow(je, aff))

    def _finish_shallow(self, je: JobExec, aff: int) -> None:
        now = self.loop.now
        je.segments.append(Segment(je._run_start, now, f"affiliation-{aff}",
                                   chip=je.chip_index))
        _trace_segment(self.tracer, je, je._run_start, now, f"affiliation-{aff}")
        je.wasted_cycles += (now - je._run_start) - je.remaining  # straggler excess
        je.remaining = 0.0
        je.state = JobState.DONE
        je.completion = now
        _trace_job_end(self.tracer, je, "DONE")
        self.aff_running[aff] = None
        self._shallow_svc_sum += je.service_cycles
        self._shallow_svc_n += 1
        self.on_complete(je)
        self._schedule_dispatch()

    def _maybe_start_deep(self, now: float) -> None:
        if any(r is not None for r in self.aff_running):
            return  # gang needs the whole chip
        top = self.shallow_q.peek()
        if self.deep_active is not None:
            # a suspended deep resumes once the shallow system drains — or,
            # aged, once the fence has drained the equal/lower-priority queue
            d = self.deep_active
            if d.state is JobState.SUSPENDED and (
                top is None or (self._aged(d, now) and top.job.priority <= d.job.priority)
            ):
                self._start_or_hold(d, now)
            return
        head = self.deep_q.peek()
        if head is None:
            return
        # after _place_shallow, any still-queued shallow job is fenced behind
        # this deep job's priority — the chip is drained, so the gang launches
        # (an aged deep job also overtakes equal-priority queued shallow jobs)
        if top is not None and (
            top.job.priority > head.job.priority
            or (top.job.priority == head.job.priority and not self._aged(head, now))
        ):
            return
        self.deep_active = self.deep_q.pop()
        self._start_or_hold(self.deep_active, now)

    def _start_or_hold(self, d: JobExec, now: float) -> None:
        """Run a single-chip deep job now; for a gang fragment, hold the chip
        and enter the cross-chip barrier instead (the reservation launches
        every fragment once the last member chip drains)."""
        if d.gang is not None:
            self._gang_hold = True
            d.gang.member_ready(self)
        else:
            self._run_deep(d, now)

    def _run_deep(self, d: JobExec, now: float, factor: float | None = None) -> None:
        _cancel_deadline(d)
        d.state = JobState.RUNNING
        _trace_state(self.tracer, d, "RUNNING", resource="deep")
        d.lanes = (f"{self._deep_label}+gang[{d.gang_rank}/{d.gang_size}]"
                   if d.gang is not None else self._deep_label)
        if d.first_start is None:
            d.first_start = now
        d._run_start = now
        d._run_factor = factor if factor is not None else self.slow_factor
        d._complete_ev = self.loop.call_after(
            d.remaining * d._run_factor, lambda: self._finish_deep(d))

    def _finish_deep(self, d: JobExec) -> None:
        now = self.loop.now
        d.segments.append(Segment(d._run_start, now, "deep", chip=d.chip_index))
        _trace_segment(self.tracer, d, d._run_start, now, "deep")
        d.wasted_cycles += (now - d._run_start) - d.remaining  # straggler excess
        d.remaining = 0.0
        d.state = JobState.DONE
        d.completion = now
        _trace_job_end(self.tracer, d, "DONE")
        self.deep_active = None
        if d.gang is not None:
            d.gang.running = False  # all fragments finish at this instant
        self.on_complete(d)
        self._schedule_dispatch()

    # -- fault injection (invoked by the cluster router's fault handlers) ----

    def fail_all(self, now: float) -> list[JobExec]:
        """Chip crash: every resident job fails transiently and the chip stops
        accepting work until ``revive``.  Returns every newly-failed record —
        including fragments a gang abort killed on OTHER (healthy) chips, so
        the router sees each victim exactly once."""
        self.alive = False
        victims: list[JobExec] = []
        for i, je in enumerate(self.aff_running):
            if je is not None:
                _fail_record(je, now, f"affiliation-{i}", self.chip, self.tracer)
                victims.append(je)
                self.aff_running[i] = None
        d = self.deep_active
        if d is not None:
            if d.gang is not None:
                victims.extend(d.gang.abort(now))
            else:
                _fail_record(d, now, "deep", self.chip, self.tracer)
                victims.append(d)
            self.deep_active = None
        for q in (self.shallow_q, self.deep_q):
            while len(q):
                je = q.pop()
                if je.state is not JobState.QUEUED:
                    continue  # a gang abort above already froze this fragment
                if je.gang is not None:
                    victims.extend(je.gang.abort(now))
                else:
                    _fail_record(je, now, "queued", self.chip, self.tracer)
                    victims.append(je)
        self._gang_hold = False
        return victims

    def fail_one(self, now: float) -> list[JobExec]:
        """Transient job fault: kill ONE running job (deterministically the
        active deep job, else the lowest busy affiliation) without taking the
        chip down.  A ganged victim aborts its whole gang in lockstep."""
        d = self.deep_active
        if d is not None and d.state is JobState.RUNNING:
            if d.gang is not None:
                return d.gang.abort(now)
            _fail_record(d, now, "deep", self.chip, self.tracer)
            self.deep_active = None
            self._schedule_dispatch()
            return [d]
        for i, je in enumerate(self.aff_running):
            if je is not None:
                _fail_record(je, now, f"affiliation-{i}", self.chip, self.tracer)
                self.aff_running[i] = None
                self._schedule_dispatch()
                return [je]
        return []

    def _gang_member_fail(self, d: JobExec, now: float) -> None:
        """Abort this chip's fragment of a dead gang.  Always a full restart:
        the re-planned job may land on different chips, where a per-chip shard
        checkpoint is meaningless."""
        d._has_checkpoint = False
        _fail_record(d, now, "deep", self.chip, self.tracer)
        if self.deep_active is d:
            self.deep_active = None
        self._gang_hold = False
        if self.alive:
            self._schedule_dispatch()  # the gang's claim on this chip is gone

    def revive(self) -> None:
        """Chip recovered from a crash: accept placements again.  The crash
        cleared every queue, so the chip rejoins empty (and the router rejoins
        it with a cold warm-set)."""
        self.alive = True


class SequentialPolicy(_DeferredDispatchMixin):
    """Homogeneous baseline (CraterLake / F1+): whole chip per job, priority-
    then-arrival dispatch, no preemption."""

    def __init__(self, chip: ChipConfig):
        self.chip = chip
        self.loop: EventLoop | None = None
        self.on_complete: Callable[[JobExec], None] = lambda je: None
        self._dispatch_pending = False
        self.tracer = None  # repro.obs seam; the owning ServingEngine sets it
        self.queue = _PriorityQueue()
        self.running: JobExec | None = None
        self.alive = True
        self.slow_factor = 1.0

    def bind(self, loop: EventLoop, on_complete: Callable[[JobExec], None]) -> None:
        self.loop = loop
        self.on_complete = on_complete

    def submit(self, je: JobExec) -> None:
        assert self.alive or je.state is not JobState.QUEUED, (
            f"job {je.job.job_id} routed to dead chip {je.chip_index}"
        )
        self.queue.push(je)
        self._schedule_dispatch()

    def dispatch(self) -> None:
        if self.running is not None or not len(self.queue):
            return
        je = self.queue.pop()
        now = self.loop.now
        _cancel_deadline(je)
        je.state = JobState.RUNNING
        _trace_state(self.tracer, je, "RUNNING", resource="whole-chip")
        je.lanes = lanes_whole_chip(self.chip).label
        if je.first_start is None:  # a retry keeps its original first start
            je.first_start = now
        je._run_start = now
        je._run_factor = self.slow_factor
        self.running = je
        je._complete_ev = self.loop.call_after(
            je.remaining * je._run_factor, lambda: self._finish(je))

    def _finish(self, je: JobExec) -> None:
        now = self.loop.now
        je.segments.append(Segment(je._run_start, now, "whole-chip", chip=je.chip_index))
        _trace_segment(self.tracer, je, je._run_start, now, "whole-chip")
        je.wasted_cycles += (now - je._run_start) - je.remaining  # straggler excess
        je.remaining = 0.0
        je.state = JobState.DONE
        je.completion = now
        _trace_job_end(self.tracer, je, "DONE")
        self.running = None
        self.on_complete(je)
        self._schedule_dispatch()

    # -- fault injection (mirrors FlashPolicy; sequential chips never gang) --

    def fail_all(self, now: float) -> list[JobExec]:
        self.alive = False
        victims: list[JobExec] = []
        if self.running is not None:
            _fail_record(self.running, now, "whole-chip", self.chip, self.tracer)
            victims.append(self.running)
            self.running = None
        while len(self.queue):
            je = self.queue.pop()
            if je.state is JobState.QUEUED:
                _fail_record(je, now, "queued", self.chip, self.tracer)
                victims.append(je)
        return victims

    def fail_one(self, now: float) -> list[JobExec]:
        je = self.running
        if je is None or je.state is not JobState.RUNNING:
            return []
        _fail_record(je, now, "whole-chip", self.chip, self.tracer)
        self.running = None
        self._schedule_dispatch()
        return [je]

    def revive(self) -> None:
        self.alive = True


def policy_for(chip: ChipConfig):
    return FlashPolicy(chip) if chip.multi_job else SequentialPolicy(chip)


# ---------------------------------------------------------------------------
# engine + result
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeResult:
    chip: ChipConfig
    jobs: list[JobExec]  # submission order
    makespan: float
    events_processed: int
    chip_index: int = 0  # this engine's fleet position (0 when single-chip)

    def validate(self) -> "ServeResult":
        """Timeline-consistency invariants (raises AssertionError on violation):
        every submission reached a terminal state (DONE, SHED, or frozen by a
        fault), per-affiliation intervals on THIS chip never overlap, and each
        record's run segments sum to the work it was charged (work
        conservation): a completed job ran service + spill/restore + wasted
        cycles; a fault-frozen attempt satisfies the running form busy +
        remaining == service + spill + wasted.  Shed jobs must have NO
        segments, no start, no completion, and a shed instant no earlier than
        their arrival."""
        n_aff = self.chip.n_affiliations if self.chip.multi_job else 1
        per_resource: dict[str, list[Segment]] = {}
        for je in self.jobs:
            if je.state is JobState.SHED:
                assert not je.segments, f"shed job {je.job.job_id} holds run segments"
                assert je.completion is None and je.first_start is None, (
                    f"shed job {je.job.job_id} has start/completion timestamps"
                )
                assert je.shed_cycle is not None, f"shed job {je.job.job_id} missing shed_cycle"
                assert je.shed_cycle >= je.job.arrival_cycle - _TOL, (
                    f"job {je.job.job_id} shed before it arrived"
                )
                continue
            if je.state in (JobState.FAILED_TRANSIENT, JobState.FAILED):
                assert je.failed_cycle is not None, (
                    f"failed job {je.job.job_id} missing failed_cycle"
                )
                assert je.completion is None, (
                    f"failed attempt of {je.job.job_id} holds a completion"
                )
                got = je.busy_cycles + je.remaining
                want = je.service_cycles + je.spill_restore_cycles + je.wasted_cycles
                assert abs(got - want) <= _TOL * max(1.0, want), (
                    f"failed attempt of {je.job.job_id}: busy+remaining {got} != "
                    f"service+spill+wasted {want}"
                )
            else:
                assert je.state is JobState.DONE, (
                    f"job {je.job.job_id} never completed ({je.state})"
                )
                assert je.completion is not None and je.first_start is not None
                assert je.first_start >= je.job.arrival_cycle - _TOL, (
                    f"job {je.job.job_id} started before it arrived"
                )
                got = je.busy_cycles
                want = je.service_cycles + je.spill_restore_cycles + je.wasted_cycles
                assert abs(got - want) <= _TOL * max(1.0, want), (
                    f"job {je.job.job_id} ran {got} cycles, owed {want} "
                    f"(service {je.service_cycles} + spill/restore "
                    f"{je.spill_restore_cycles} + wasted {je.wasted_cycles})"
                )
            for seg in je.segments:
                assert seg.end >= seg.start - _TOL
                if seg.chip != self.chip_index:
                    continue  # an earlier attempt's run on another fleet chip
                if seg.resource == "deep":  # a gang occupies every affiliation
                    for a in range(n_aff):
                        per_resource.setdefault(f"affiliation-{a}", []).append(seg)
                else:
                    per_resource.setdefault(seg.resource, []).append(seg)
        for resource, segs in per_resource.items():
            segs.sort(key=lambda s: (s.start, s.end))
            for prev, cur in zip(segs, segs[1:]):
                assert cur.start >= prev.end - _TOL, (
                    f"overlapping placements on {resource}: "
                    f"[{prev.start}, {prev.end}) and [{cur.start}, {cur.end})"
                )
        return self


class ServingEngine:
    """Feeds arrivals into a policy over the event loop and collects results.

    Open-loop: pass finished ``FheJob`` lists (arrival_cycle set).  Closed
    loop: pass a *source* object with ``initial_jobs()`` and
    ``on_complete(job_exec, now) -> list[FheJob]`` (see
    ``repro.serve.traffic.ClosedLoopSource``).
    """

    def __init__(self, chip: ChipConfig, policy=None, loop: EventLoop | None = None,
                 hoist: bool = False, exec_policy: ExecPolicy | None = None,
                 shed_after: float | None = None, tracer=None, metrics=None):
        self.chip = chip
        self.policy = policy if policy is not None else policy_for(chip)
        # engine-level queue timeout (AdmissionConfig.shed_after_cycles): a job
        # still QUEUED this long after arrival is shed where it waits
        assert shed_after is None or shed_after > 0
        self.shed_after = shed_after
        # observability (repro.obs): a disabled tracer normalises to None so
        # every guard below is one attribute test; the policy shares it.  The
        # optional MetricsRegistry collects completion counters/histograms
        self.tracer = tracer if tracer else None
        self.metrics = metrics
        self.policy.tracer = self.tracer
        self._fleet = False  # True under a ClusterRouter (it owns job spans)
        self._trace_registered = False
        # a caller-supplied loop lets N engines share one clock (fleet serving,
        # repro.serve.cluster); by default each engine owns its own
        self.loop = loop if loop is not None else EventLoop(tracer=self.tracer)
        # execution policy for service-time estimation (kernel pipeline +
        # hoisting + numerics mode); ``hoist=`` is the legacy bool spelling.
        # Hoisted rotations amortise ModUp across BSGS baby steps, shrinking
        # deep (CtS/StC-heavy) jobs.
        self.exec_policy = (exec_policy if exec_policy is not None
                            else exec_policy_from_hoist(hoist))
        self.hoist = self.exec_policy.plan_hoist
        self.chip_index = 0  # fleet position; the cluster router assigns it
        self.jobs: list[JobExec] = []
        self._source = None
        # fleet hooks: the cluster router tracks per-chip backlog through these
        # (a queue-timeout shed must echo its admission back OUT of the backlog)
        self.on_job_complete: Callable[[JobExec], None] | None = None
        self.on_job_shed: Callable[[JobExec], None] | None = None
        self.policy.bind(self.loop, self._job_completed)

    def service_sim(self, job: FheJob) -> SimResult:
        """The memoised cycle sim this engine prices ``job`` at — the cluster
        router estimates through the same entry, so routing estimates match
        the engine's charges exactly.  Honours the policy's ``deep_coop``."""
        coop = job.kind == "deep" and bool(getattr(self.policy, "deep_coop", False))
        return job_service_sim(job, self.chip, policy=self.exec_policy, deep_coop=coop)

    def _trace_register(self) -> None:
        """Name this chip's trace process and intern its resource tracks in a
        fixed order (chip health first, then placement lanes), so track ids —
        and therefore exported bytes — depend only on topology, not on which
        job happens to land first.  The cluster router calls this after
        assigning ``chip_index``; standalone engines call it on first submit."""
        if self.tracer is None or self._trace_registered:
            return
        self._trace_registered = True
        pid = self.chip_index + 1
        self.tracer.name_process(pid, f"chip{self.chip_index} {self.chip.name}")
        self.tracer.track(pid, "chip")  # health: down spans, fault instants
        if hasattr(self.policy, "aff_running"):  # FlashPolicy-shaped
            for a in range(self.chip.n_affiliations):
                self.tracer.track(pid, f"affiliation-{a}")
            self.tracer.track(pid, "deep")
        else:
            self.tracer.track(pid, "whole-chip")

    def submit(self, job: FheJob, extra_cycles: float = 0.0, sim: SimResult | None = None,
               service_cycles: float | None = None,
               gang: "GangReservation | None" = None,
               arm_deadline: bool = True) -> JobExec:
        """Queue one job.  ``extra_cycles`` is added to the service demand —
        the cluster router charges warm-set cold starts (KSK/plaintext fetch)
        this way, so work conservation holds penalty-inclusive.  The router's
        gang path overrides the priced demand (``service_cycles`` = per-chip
        gang duration incl. link stalls, with ``sim`` the single-chip sim for
        reference) and attaches the fragment to its cross-chip reservation.
        ``arm_deadline=False`` skips the queue-timeout shed — the router's
        retry path uses it because a retry's deadline measured from the
        ORIGINAL arrival would already be in the past (and a retried job must
        not be shed mid-recovery anyway).
        """
        if sim is None:
            sim = self.service_sim(job)
        base = float(service_cycles) if service_cycles is not None else sim.cycles
        je = JobExec(job=job, service_cycles=base + float(extra_cycles), sim=sim,
                     lanes="", cold_start_cycles=float(extra_cycles), gang=gang,
                     chip_index=self.chip_index)
        if gang is not None:
            gang.attach(self.policy, je)
        self.jobs.append(je)
        # clamp: integer-rounded arrivals from a closed-loop source can land a
        # fraction of a cycle before a fractional clock (non-integral spill pay)
        arrival = max(self.loop.now, float(job.arrival_cycle))
        if self.tracer is not None and not self._fleet:
            # standalone engines own the job's async span; in fleet mode the
            # router opens it at routing time (retries re-enter here, and a
            # second ``b`` per job id would corrupt the async track)
            self._trace_register()
            self.tracer.job_begin(job.job_id, job.workload, ts=arrival,
                                  pid=self.chip_index + 1, kind=job.kind,
                                  tenant=job.tenant_id, priority=job.priority)
        self.loop.call_at(arrival, lambda: self.policy.submit(je))
        if self.shed_after is not None and gang is None and arm_deadline:
            # gang fragments are exempt: the lockstep barrier already bounds
            # their queueing through the router's gang-vs-single estimate, and
            # shedding one fragment of a committed reservation would deadlock
            # the others at the barrier
            je._deadline_ev = self.loop.call_at(
                arrival + self.shed_after, lambda: self._shed_deadline(je))
        return je

    def _shed_deadline(self, je: JobExec) -> None:
        """Queue-timeout shed: fires ``shed_after`` cycles past arrival; a
        no-op unless the job is still waiting for its first dispatch."""
        je._deadline_ev = None
        if je.state is JobState.QUEUED and je.first_start is None:
            self.shed(je)

    def shed(self, je: JobExec) -> None:
        """Terminal SHED for a queued job: cancel its pending events, mark it,
        and notify the fleet hook (the router un-books its backlog charge).
        The policy queues drop the entry lazily (``_PriorityQueue._purge``)."""
        assert je.state is JobState.QUEUED and je.first_start is None, (
            f"can only shed a never-started queued job, not {je.state}"
        )
        _cancel_deadline(je)
        if je._complete_ev is not None:  # defensive: queued jobs hold none
            je._complete_ev.cancel()
            je._complete_ev = None
        je.state = JobState.SHED
        je.shed_cycle = self.loop.now
        if self.tracer is not None and _primary(je):
            self.tracer.instant("shed", pid=self.chip_index + 1,
                                tid=self.tracer.track(self.chip_index + 1, "chip"),
                                job=je.job.job_id, reason="timeout")
        _trace_job_end(self.tracer, je, "SHED")
        if self.on_job_shed is not None:
            self.on_job_shed(je)

    def _job_completed(self, je: JobExec) -> None:
        # gang fragments complete once per member; only rank 0 is the job
        if self.metrics is not None and _primary(je):
            self.metrics.counter("serve.jobs_completed", labels=("kind",)).inc(
                kind=je.kind)
            self.metrics.histogram(
                "serve.turnaround_cycles", buckets=TURNAROUND_BUCKETS,
            ).observe(je.completion - je.job.arrival_cycle)
        if self.on_job_complete is not None:
            self.on_job_complete(je)
        if self._source is not None:
            for job in self._source.on_complete(je, self.loop.now):
                self.submit(job)

    def result(self) -> ServeResult:
        """Snapshot this engine's timeline (fleet mode runs the shared loop
        once, then collects per-chip results through here).  NB: with a
        shared loop, ``events_processed`` is the loop-wide total — events are
        not attributable to one engine."""
        makespan = max((je.completion for je in self.jobs
                        if je.completion is not None), default=0.0)
        return ServeResult(chip=self.chip, jobs=list(self.jobs),
                           makespan=makespan, events_processed=self.loop.processed,
                           chip_index=self.chip_index)

    def run(self, source=None) -> ServeResult:
        if source is not None:
            self._source = source
            for job in source.initial_jobs():
                self.submit(job)
        self.loop.run()
        return self.result()


def serve(jobs: list[FheJob], chip: ChipConfig, policy=None, validate: bool = True,
          hoist: bool = False, exec_policy: ExecPolicy | None = None,
          shed_after: float | None = None, tracer=None, metrics=None) -> ServeResult:
    """Run an open-loop job list through the event engine; the one-call API.

    ``exec_policy`` selects the service-time kernel mode (an
    ``repro.fhe.ExecPolicy``); the legacy ``hoist=`` bool is honoured when no
    policy is given.  ``shed_after`` arms the engine-level queue timeout: jobs
    still queued that many cycles after arrival end ``JobState.SHED`` instead
    of waiting forever (fleet admission lives in ``serve_cluster``).
    ``tracer`` (an ``repro.obs.Tracer``) records the run for Perfetto export;
    ``metrics`` (an ``repro.obs.MetricsRegistry``) collects completion stats."""
    eng = ServingEngine(chip, policy=policy, hoist=hoist, exec_policy=exec_policy,
                        shed_after=shed_after, tracer=tracer, metrics=metrics)
    for job in jobs:
        eng.submit(job)
    result = eng.run()
    return result.validate() if validate else result


def serve_source(source, chip: ChipConfig, policy=None, validate: bool = True,
                 hoist: bool = False, exec_policy: ExecPolicy | None = None,
                 shed_after: float | None = None, tracer=None, metrics=None) -> ServeResult:
    """Run a closed-loop traffic source (arrivals depend on completions)."""
    eng = ServingEngine(chip, policy=policy, hoist=hoist, exec_policy=exec_policy,
                        shed_after=shed_after, tracer=tracer, metrics=metrics)
    result = eng.run(source=source)
    return result.validate() if validate else result
