"""Traffic generation for the serving subsystem: open-loop Poisson streams,
sharded per-chip sub-streams, a skewed bursty-tenant stream, a diurnal
(day/night rate curve) production-shaped stream, trace replay, and a
closed-loop "N concurrent tenants" source — plus the mix-capacity helpers
(``mix_capacity_jobs_per_mcycle`` / ``fleet_capacity_jobs_per_mcycle``) that
turn "serve X× fleet capacity" into a concrete arrival rate.

All generators are seeded and fully deterministic — the same seed reproduces
the same arrival sequence bit-for-bit (the determinism test in
``tests/test_serving.py`` relies on this).  Multi-source generators
(``sharded_poisson_jobs``, ``bursty_jobs``) derive one RNG per source by
deterministic seed splitting (``numpy.random.SeedSequence.spawn``) rather
than seed arithmetic, so the same seed with different shard counts yields
uncorrelated yet reproducible streams.  Times are in cycles; rates are jobs
per megacycle so they read naturally against the simulator's outputs.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

import numpy as np

from repro.core.jobs import FheJob, make_job

from .policy import JobExec

# Workload mixes over the paper's §6.1 presets.  Weights are relative
# (normalised at draw time).
SHALLOW_MIX: dict[str, float] = {
    "lola_mnist_plain": 0.35,
    "matmul": 0.30,
    "dblookup": 0.20,
    "lola_cifar_plain": 0.15,
}
DEEP_MIX: dict[str, float] = {"lstm": 0.6, "logreg": 0.4}
# shallow-heavy mixed traffic: the paper's headline multi-tenant scenario
MIXED_MIX: dict[str, float] = {
    "lola_mnist_plain": 0.30,
    "matmul": 0.25,
    "dblookup": 0.20,
    "lola_cifar_plain": 0.10,
    "lstm": 0.10,
    "logreg": 0.05,
}
# pure exact-arithmetic traffic (BGV presets only)
BGV_MIX: dict[str, float] = {"psi": 0.55, "exact_count": 0.45}
# mixed-scheme deployment (APACHE's argument): CKKS inference traffic plus
# exact integer workloads in one stream — shallow BGV jobs ride the swift
# clusters alongside shallow CKKS per the paper's affiliation policy
MULTISCHEME_MIX: dict[str, float] = {
    "lola_mnist_plain": 0.22,
    "matmul": 0.18,
    "psi": 0.20,
    "exact_count": 0.15,
    "dblookup": 0.10,
    "lola_cifar_plain": 0.05,
    "lstm": 0.07,
    "logreg": 0.03,
}


def _normalise(weights: Mapping) -> tuple[list, np.ndarray]:
    keys = list(weights.keys())
    w = np.asarray([float(weights[k]) for k in keys], dtype=float)
    total = w.sum()
    if total <= 0:
        raise ValueError("mix weights must sum to a positive value")
    return keys, w / total


@dataclasses.dataclass(frozen=True)
class PoissonConfig:
    """Open-loop Poisson arrivals over a workload/priority mix."""

    rate_per_mcycle: float  # mean arrival rate, jobs per 1e6 cycles
    n_jobs: int
    mix: Mapping[str, float] = dataclasses.field(default_factory=lambda: dict(MIXED_MIX))
    priority_mix: Mapping[int, float] = dataclasses.field(default_factory=lambda: {0: 1.0})
    seed: int = 0
    start_id: int = 0
    tenant_id: int = 0
    start_cycle: float = 0.0  # arrivals begin after this offset


def _draw_poisson(cfg: PoissonConfig, rng: np.random.Generator) -> list[FheJob]:
    names, name_p = _normalise(cfg.mix)
    prios, prio_p = _normalise(cfg.priority_mix)
    mean_gap = 1e6 / cfg.rate_per_mcycle
    t = float(cfg.start_cycle)
    jobs = []
    for i in range(cfg.n_jobs):
        t += float(rng.exponential(mean_gap))
        w = names[int(rng.choice(len(names), p=name_p))]
        pr = int(prios[int(rng.choice(len(prios), p=prio_p))])
        jobs.append(make_job(w, priority=pr, arrival_cycle=int(round(t)),
                             job_id=cfg.start_id + i, tenant_id=cfg.tenant_id))
    return jobs


def poisson_jobs(cfg: PoissonConfig) -> list[FheJob]:
    """Draw ``cfg.n_jobs`` arrivals with exponential inter-arrival gaps."""
    return _draw_poisson(cfg, np.random.default_rng(cfg.seed))


def sharded_poisson_jobs(cfg: PoissonConfig, n_shards: int) -> list[list[FheJob]]:
    """Split one logical Poisson stream into ``n_shards`` sub-streams.

    Each shard is an independent Poisson process at ``rate / n_shards`` (the
    superposition is statistically the original stream), seeded from its own
    ``SeedSequence.spawn`` child — per-shard RNGs are uncorrelated by
    construction, and the SAME ``cfg.seed`` stays reproducible at ANY shard
    count (no seed arithmetic collisions like ``seed + shard``).  Job ids
    partition ``[start_id, start_id + n_jobs)`` contiguously per shard;
    ``tenant_id`` is inherited from ``cfg``.

    Use case: pre-sharding an arrival stream per front-end (one router per
    region), or generating per-chip background traffic.  For a SINGLE router
    over N chips, pass the unsharded stream to ``serve_cluster`` instead.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    base, extra = divmod(cfg.n_jobs, n_shards)
    shards, next_id = [], cfg.start_id
    for k, child in enumerate(np.random.SeedSequence(cfg.seed).spawn(n_shards)):
        n_k = base + (1 if k < extra else 0)
        sub = dataclasses.replace(cfg, rate_per_mcycle=cfg.rate_per_mcycle / n_shards,
                                  n_jobs=n_k, start_id=next_id)
        shards.append(_draw_poisson(sub, np.random.default_rng(child)))
        next_id += n_k
    return shards


@dataclasses.dataclass(frozen=True)
class DiurnalConfig:
    """Production-shaped open-loop arrivals: a Poisson process whose rate
    follows a raised-cosine day/night curve over hours of simulated time.

    The instantaneous rate is::

        rate(t) = trough + (peak − trough) · ½(1 − cos 2π(t/period + phase))

    i.e. the stream starts at the trough (``phase_frac=0`` ≈ midnight), peaks
    half a period in, and returns — the canonical diurnal shape every
    production service sees.  The long-run mean rate is
    ``peak · (1 + trough_frac) / 2`` (``mean_rate_per_mcycle``), which is how
    the overload bench dials a stream to X× fleet capacity.  Arrivals are
    drawn by *thinning* (Lewis & Shedler): candidate arrivals at the peak
    rate, each kept with probability ``rate(t)/peak`` — exact for a
    non-homogeneous Poisson process and fully seeded/deterministic like every
    other source here.
    """

    peak_rate_per_mcycle: float
    period_mcycles: float = 40.0  # one simulated "day"
    n_periods: float = 2.0  # stream horizon in days
    trough_frac: float = 0.25  # night-time rate as a fraction of peak
    phase_frac: float = 0.0  # fraction of a period to shift the curve by
    mix: Mapping[str, float] = dataclasses.field(default_factory=lambda: dict(MIXED_MIX))
    priority_mix: Mapping[int, float] = dataclasses.field(default_factory=lambda: {0: 1.0})
    seed: int = 0
    start_id: int = 0
    tenant_id: int = 0

    def __post_init__(self):
        if self.peak_rate_per_mcycle <= 0:
            raise ValueError(f"peak rate must be positive, got {self.peak_rate_per_mcycle}")
        if self.period_mcycles <= 0 or self.n_periods <= 0:
            raise ValueError("period_mcycles and n_periods must be positive")
        if not 0.0 <= self.trough_frac <= 1.0:
            raise ValueError(f"trough_frac must be in [0, 1], got {self.trough_frac}")

    @property
    def mean_rate_per_mcycle(self) -> float:
        """Long-run mean of the rate curve (jobs per Mcycle)."""
        return self.peak_rate_per_mcycle * (1.0 + self.trough_frac) / 2.0

    @property
    def horizon_cycles(self) -> float:
        return self.n_periods * self.period_mcycles * 1e6


def diurnal_rate(cfg: DiurnalConfig, t_cycles: float) -> float:
    """Instantaneous arrival rate (jobs/Mcycle) at simulated time ``t_cycles``."""
    peak, trough = cfg.peak_rate_per_mcycle, cfg.trough_frac * cfg.peak_rate_per_mcycle
    x = t_cycles / (cfg.period_mcycles * 1e6) + cfg.phase_frac
    return trough + (peak - trough) * 0.5 * (1.0 - np.cos(2.0 * np.pi * x))


def diurnal_jobs(cfg: DiurnalConfig) -> list[FheJob]:
    """Materialise the diurnal stream over ``n_periods`` simulated days.

    Unlike ``poisson_jobs`` the job COUNT is not fixed — it is governed by
    the rate curve and the horizon (≈ ``mean_rate_per_mcycle × horizon``),
    exactly like real traffic.  Job ids are ``start_id, start_id+1, …`` in
    arrival order.
    """
    rng = np.random.default_rng(cfg.seed)
    names, name_p = _normalise(cfg.mix)
    prios, prio_p = _normalise(cfg.priority_mix)
    peak_gap = 1e6 / cfg.peak_rate_per_mcycle
    horizon = cfg.horizon_cycles
    t, jobs = 0.0, []
    while True:
        t += float(rng.exponential(peak_gap))
        if t >= horizon:
            return jobs
        # thinning: keep this candidate with probability rate(t)/peak
        if float(rng.uniform()) * cfg.peak_rate_per_mcycle > diurnal_rate(cfg, t):
            continue
        w = names[int(rng.choice(len(names), p=name_p))]
        pr = int(prios[int(rng.choice(len(prios), p=prio_p))])
        jobs.append(make_job(w, priority=pr, arrival_cycle=int(round(t)),
                             job_id=cfg.start_id + len(jobs), tenant_id=cfg.tenant_id))


def mix_capacity_jobs_per_mcycle(mix: Mapping[str, float], chip,
                                 exec_policy=None, deep_coop: bool = False) -> float:
    """Steady-state service capacity of ONE chip on this workload mix.

    Each shallow job occupies one of ``n_affiliations`` lanes for its service
    time (the §4.2 policy drains shallow work affiliation-wide); a deep job
    owns the whole chip.  The expected chip-time per offered job is therefore
    ``Σ p_w · service_w / width_w``, and capacity is its reciprocal in jobs
    per Mcycle.  An estimate, not an oracle — it ignores queueing geometry,
    cold starts, and preemption — but it is exactly the number a capacity
    planner needs to dial offered load to X× capacity.
    """
    from .policy import job_service_sim  # local: traffic is imported by policy users

    names, p = _normalise(mix)
    cost = 0.0
    for name, prob in zip(names, p):
        job = make_job(name)
        sim = job_service_sim(job, chip, policy=exec_policy, deep_coop=deep_coop)
        width = chip.n_affiliations if (chip.multi_job and job.kind == "shallow") else 1
        cost += float(prob) * sim.cycles / width
    return 1e6 / cost


def fleet_capacity_jobs_per_mcycle(mix: Mapping[str, float], chip_pairs,
                                   deep_coop: bool = False) -> float:
    """Aggregate ``mix_capacity_jobs_per_mcycle`` over a fleet.

    ``chip_pairs`` is an iterable of ``ChipConfig`` or ``(ChipConfig,
    ExecPolicy | None)`` entries — the same shape ``ClusterConfig.chip_pairs``
    returns, so benches can size offered load straight off a cluster config.
    """
    total = 0.0
    for entry in chip_pairs:
        chip, pol = entry if isinstance(entry, tuple) else (entry, None)
        total += mix_capacity_jobs_per_mcycle(mix, chip, exec_policy=pol,
                                              deep_coop=deep_coop)
    return total


@dataclasses.dataclass(frozen=True)
class BurstyConfig:
    """Skewed stream: a smooth Poisson background (tenant 0) plus one bursty
    tenant (tenant 1) that dumps ``burst_size`` back-to-back jobs at each of
    ``n_bursts`` Poisson-placed epochs.  Background and burst sources draw
    from separately spawned RNGs (same seed ⇒ same stream; changing burst
    shape never perturbs the background draws)."""

    base: PoissonConfig  # the background stream (tenant 0)
    n_bursts: int = 4
    burst_size: int = 12
    intra_gap_cycles: float = 2_000.0  # spacing inside one burst
    burst_mix: Mapping[str, float] | None = None  # default: base.mix
    burst_priority_mix: Mapping[int, float] | None = None  # default: base's


def bursty_jobs(cfg: BurstyConfig) -> list[FheJob]:
    """Materialise the merged (background + bursts) stream, sorted by arrival."""
    bg_seq, burst_seq = np.random.SeedSequence(cfg.base.seed).spawn(2)
    background = _draw_poisson(cfg.base, np.random.default_rng(bg_seq))
    span = max((j.arrival_cycle for j in background), default=0)
    rng = np.random.default_rng(burst_seq)
    names, name_p = _normalise(cfg.burst_mix if cfg.burst_mix is not None else cfg.base.mix)
    prios, prio_p = _normalise(cfg.burst_priority_mix if cfg.burst_priority_mix is not None
                               else cfg.base.priority_mix)
    epochs = sorted(float(x) for x in rng.uniform(0.0, max(span, 1.0), size=cfg.n_bursts))
    jobs = list(background)
    next_id = cfg.base.start_id + cfg.base.n_jobs
    for epoch in epochs:
        for k in range(cfg.burst_size):
            w = names[int(rng.choice(len(names), p=name_p))]
            pr = int(prios[int(rng.choice(len(prios), p=prio_p))])
            jobs.append(make_job(w, priority=pr,
                                 arrival_cycle=int(round(epoch + k * cfg.intra_gap_cycles)),
                                 job_id=next_id, tenant_id=cfg.base.tenant_id + 1))
            next_id += 1
    jobs.sort(key=lambda j: (j.arrival_cycle, j.job_id))
    return jobs


def trace_jobs(rows: Iterable[Sequence | Mapping]) -> list[FheJob]:
    """Replay a recorded trace.  Rows are ``(workload, arrival_cycle[, priority])``
    tuples or dicts with those keys (plus optional ``job_id``/``tenant_id``)."""
    jobs = []
    for i, row in enumerate(rows):
        if isinstance(row, Mapping):
            jobs.append(make_job(row["workload"],
                                 priority=int(row.get("priority", 0)),
                                 arrival_cycle=int(row["arrival_cycle"]),
                                 job_id=int(row.get("job_id", i)),
                                 tenant_id=int(row.get("tenant_id", 0))))
        else:
            workload, arrival, *rest = row
            jobs.append(make_job(workload, priority=int(rest[0]) if rest else 0,
                                 arrival_cycle=int(arrival), job_id=i))
    return jobs


class ClosedLoopSource:
    """N concurrent tenants, each keeping exactly one job in flight.

    Every tenant submits its first job at cycle 0 (plus an optional think-time
    draw) and its next job ``think_cycles`` (exponentially distributed, mean)
    after the previous one completes, until ``jobs_per_tenant`` jobs are done.
    Pass to ``repro.serve.serve_source`` / ``ServingEngine.run(source=...)``.
    """

    def __init__(self, n_tenants: int, jobs_per_tenant: int,
                 mix: Mapping[str, float] | None = None,
                 priority_mix: Mapping[int, float] | None = None,
                 think_cycles: float = 0.0, seed: int = 0):
        self.n_tenants = n_tenants
        self.jobs_per_tenant = jobs_per_tenant
        self._names, self._name_p = _normalise(mix if mix is not None else SHALLOW_MIX)
        self._prios, self._prio_p = _normalise(priority_mix if priority_mix is not None else {0: 1.0})
        self.think_cycles = float(think_cycles)
        self._rng = np.random.default_rng(seed)
        self._submitted = {t: 0 for t in range(n_tenants)}
        self._next_id = 0

    def _draw(self, tenant: int, arrival: float) -> FheJob:
        w = self._names[int(self._rng.choice(len(self._names), p=self._name_p))]
        pr = int(self._prios[int(self._rng.choice(len(self._prios), p=self._prio_p))])
        job = make_job(w, priority=pr, arrival_cycle=int(round(arrival)),
                       job_id=self._next_id, tenant_id=tenant)
        self._next_id += 1
        self._submitted[tenant] += 1
        return job

    def _think(self) -> float:
        return float(self._rng.exponential(self.think_cycles)) if self.think_cycles > 0 else 0.0

    def initial_jobs(self) -> list[FheJob]:
        return [self._draw(t, self._think()) for t in range(self.n_tenants)]

    def on_complete(self, je: JobExec, now: float) -> list[FheJob]:
        tenant = je.job.tenant_id
        if self._submitted[tenant] >= self.jobs_per_tenant:
            return []
        return [self._draw(tenant, now + self._think())]
