"""Traffic generation for the serving subsystem: open-loop Poisson streams,
trace replay, and a closed-loop "N concurrent tenants" source.

All generators are seeded and fully deterministic — the same seed reproduces
the same arrival sequence bit-for-bit (the determinism test in
``tests/test_serving.py`` relies on this).  Times are in cycles; rates are
jobs per megacycle so they read naturally against the simulator's outputs.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

import numpy as np

from repro.core.jobs import FheJob, make_job

from .policy import JobExec

# Workload mixes over the paper's §6.1 presets.  Weights are relative
# (normalised at draw time).
SHALLOW_MIX: dict[str, float] = {
    "lola_mnist_plain": 0.35,
    "matmul": 0.30,
    "dblookup": 0.20,
    "lola_cifar_plain": 0.15,
}
DEEP_MIX: dict[str, float] = {"lstm": 0.6, "logreg": 0.4}
# shallow-heavy mixed traffic: the paper's headline multi-tenant scenario
MIXED_MIX: dict[str, float] = {
    "lola_mnist_plain": 0.30,
    "matmul": 0.25,
    "dblookup": 0.20,
    "lola_cifar_plain": 0.10,
    "lstm": 0.10,
    "logreg": 0.05,
}


def _normalise(weights: Mapping) -> tuple[list, np.ndarray]:
    keys = list(weights.keys())
    w = np.asarray([float(weights[k]) for k in keys], dtype=float)
    total = w.sum()
    if total <= 0:
        raise ValueError("mix weights must sum to a positive value")
    return keys, w / total


@dataclasses.dataclass(frozen=True)
class PoissonConfig:
    """Open-loop Poisson arrivals over a workload/priority mix."""

    rate_per_mcycle: float  # mean arrival rate, jobs per 1e6 cycles
    n_jobs: int
    mix: Mapping[str, float] = dataclasses.field(default_factory=lambda: dict(MIXED_MIX))
    priority_mix: Mapping[int, float] = dataclasses.field(default_factory=lambda: {0: 1.0})
    seed: int = 0
    start_id: int = 0


def poisson_jobs(cfg: PoissonConfig) -> list[FheJob]:
    """Draw ``cfg.n_jobs`` arrivals with exponential inter-arrival gaps."""
    rng = np.random.default_rng(cfg.seed)
    names, name_p = _normalise(cfg.mix)
    prios, prio_p = _normalise(cfg.priority_mix)
    mean_gap = 1e6 / cfg.rate_per_mcycle
    t = 0.0
    jobs = []
    for i in range(cfg.n_jobs):
        t += float(rng.exponential(mean_gap))
        w = names[int(rng.choice(len(names), p=name_p))]
        pr = int(prios[int(rng.choice(len(prios), p=prio_p))])
        jobs.append(make_job(w, priority=pr, arrival_cycle=int(round(t)),
                             job_id=cfg.start_id + i))
    return jobs


def trace_jobs(rows: Iterable[Sequence | Mapping]) -> list[FheJob]:
    """Replay a recorded trace.  Rows are ``(workload, arrival_cycle[, priority])``
    tuples or dicts with those keys (plus optional ``job_id``/``tenant_id``)."""
    jobs = []
    for i, row in enumerate(rows):
        if isinstance(row, Mapping):
            jobs.append(make_job(row["workload"],
                                 priority=int(row.get("priority", 0)),
                                 arrival_cycle=int(row["arrival_cycle"]),
                                 job_id=int(row.get("job_id", i)),
                                 tenant_id=int(row.get("tenant_id", 0))))
        else:
            workload, arrival, *rest = row
            jobs.append(make_job(workload, priority=int(rest[0]) if rest else 0,
                                 arrival_cycle=int(arrival), job_id=i))
    return jobs


class ClosedLoopSource:
    """N concurrent tenants, each keeping exactly one job in flight.

    Every tenant submits its first job at cycle 0 (plus an optional think-time
    draw) and its next job ``think_cycles`` (exponentially distributed, mean)
    after the previous one completes, until ``jobs_per_tenant`` jobs are done.
    Pass to ``repro.serve.serve_source`` / ``ServingEngine.run(source=...)``.
    """

    def __init__(self, n_tenants: int, jobs_per_tenant: int,
                 mix: Mapping[str, float] | None = None,
                 priority_mix: Mapping[int, float] | None = None,
                 think_cycles: float = 0.0, seed: int = 0):
        self.n_tenants = n_tenants
        self.jobs_per_tenant = jobs_per_tenant
        self._names, self._name_p = _normalise(mix if mix is not None else SHALLOW_MIX)
        self._prios, self._prio_p = _normalise(priority_mix if priority_mix is not None else {0: 1.0})
        self.think_cycles = float(think_cycles)
        self._rng = np.random.default_rng(seed)
        self._submitted = {t: 0 for t in range(n_tenants)}
        self._next_id = 0

    def _draw(self, tenant: int, arrival: float) -> FheJob:
        w = self._names[int(self._rng.choice(len(self._names), p=self._name_p))]
        pr = int(self._prios[int(self._rng.choice(len(self._prios), p=self._prio_p))])
        job = make_job(w, priority=pr, arrival_cycle=int(round(arrival)),
                       job_id=self._next_id, tenant_id=tenant)
        self._next_id += 1
        self._submitted[tenant] += 1
        return job

    def _think(self) -> float:
        return float(self._rng.exponential(self.think_cycles)) if self.think_cycles > 0 else 0.0

    def initial_jobs(self) -> list[FheJob]:
        return [self._draw(t, self._think()) for t in range(self.n_tenants)]

    def on_complete(self, je: JobExec, now: float) -> list[FheJob]:
        tenant = je.job.tenant_id
        if self._submitted[tenant] >= self.jobs_per_tenant:
            return []
        return [self._draw(tenant, now + self._think())]
