"""SLO metrics over a ``ServeResult`` / ``ClusterResult``: latency
percentiles, throughput, per-cluster utilization, queueing delay, fairness,
and starvation counters.

Everything is derived from the per-job ``Segment`` timelines the event engine
records, so the numbers are exact (no sampling).  Cycle quantities convert to
wall-clock through the chip frequency.  ``summarize`` accepts either result
type; ``summarize_cluster`` is the explicit fleet path (per-chip utilization
imbalance, Jain fairness across chips as well as tenants, cold-start totals).
"""

from __future__ import annotations

import numpy as np

from .cluster import ClusterResult
from .policy import JobState, ServeResult

PERCENTILES = (50.0, 95.0, 99.0)


def _pct(values: list[float]) -> dict[str, float]:
    """Percentiles of a sample; an EMPTY sample yields NaN, not 0.0.

    A zero here used to read as a *perfect* tail — a stream with no deep
    completions (or every job shed) would sail through a "p99 must beat X"
    CI gate.  NaN poisons any such comparison instead (NaN > x and NaN < x
    are both False), and the ``n_completed_{kind}`` counts let gates require
    a non-empty sample explicitly."""
    if not values:
        return {f"p{int(q)}": float("nan") for q in PERCENTILES}
    arr = np.asarray(values, dtype=float)
    return {f"p{int(q)}": float(np.percentile(arr, q)) for q in PERCENTILES}


def jain_fairness(values: list[float]) -> float:
    """Jain's index: 1.0 = perfectly fair, 1/n = one value dominates."""
    if not values:
        return 1.0
    arr = np.asarray(values, dtype=float)
    denom = len(arr) * float((arr ** 2).sum())
    return float(arr.sum()) ** 2 / denom if denom > 0 else 1.0


def per_affiliation_busy(result: ServeResult) -> dict[str, float]:
    """Busy cycles per affiliation; deep gangs occupy every affiliation."""
    n_aff = result.chip.n_affiliations if result.chip.multi_job else 1
    busy = {f"affiliation-{a}": 0.0 for a in range(n_aff)}
    for je in result.jobs:
        for seg in je.segments:
            if seg.resource in busy:
                busy[seg.resource] += seg.cycles
            else:  # "deep" / "whole-chip": the whole machine is occupied
                for a in range(n_aff):
                    busy[f"affiliation-{a}"] += seg.cycles
    return busy


def tenant_slowdowns(result: ServeResult | ClusterResult) -> dict[int, float]:
    """Mean slowdown (turnaround ÷ service) per tenant."""
    acc: dict[int, list[float]] = {}
    for je in result.jobs:
        if je.state is JobState.DONE and je.service_cycles > 0:
            acc.setdefault(je.job.tenant_id, []).append(je.turnaround / je.service_cycles)
    return {t: float(np.mean(v)) for t, v in acc.items()}


def max_queueing_by_kind(result: ServeResult | ClusterResult) -> dict[str, float]:
    """Worst-case queueing delay (arrival → first dispatch) per job kind.

    This is the starvation indicator the ROADMAP asks for: under
    ``FlashPolicy`` a saturating shallow stream can hold every affiliation
    busy indefinitely, so a same-priority deep job's gang never launches —
    the deep entry here grows with the stream length while the shallow entry
    stays bounded by the service quantum.  (The aging/utilization-reserve
    knob that bounds it is a follow-on PR; the metric ships now.)
    """
    out = {"shallow": 0.0, "deep": 0.0}
    for je in result.jobs:
        if je.state is JobState.DONE:
            out[je.kind] = max(out[je.kind], je.queueing_delay)
    return out


def drop_rate_by_tenant(result: ServeResult | ClusterResult) -> dict[int, float]:
    """Shed fraction of each tenant's offered jobs (admission + timeout sheds)."""
    offered: dict[int, int] = {}
    shed: dict[int, int] = {}
    for je in result.jobs:
        t = je.job.tenant_id
        offered[t] = offered.get(t, 0) + 1
        if je.state is JobState.SHED:
            shed[t] = shed.get(t, 0) + 1
    return {t: shed.get(t, 0) / n for t, n in offered.items()}


def goodput_by_tenant(result: ServeResult | ClusterResult) -> dict[int, int]:
    """Completed-job count per tenant — the per-tenant goodput numerator the
    token-bucket isolation property compares (victim goodput under a flood vs
    its solo goodput)."""
    out: dict[int, int] = {}
    for je in result.jobs:
        if je.state is JobState.DONE:
            out[je.job.tenant_id] = out.get(je.job.tenant_id, 0) + 1
    return out


def _overload_block(result: ServeResult | ClusterResult,
                    done: list, makespan: float) -> dict[str, float]:
    """Shared SLO-degradation keys: offered/completed/shed counts, drop rates
    by kind, goodput, and the time-to-shed tail.  ``time_to_shed_*`` is NaN
    when nothing shed (same empty-sample semantics as the latency
    percentiles)."""
    jobs = result.jobs
    shed = [je for je in jobs if je.state is JobState.SHED]
    n_offered = len(jobs)
    out = {
        "n_offered": float(n_offered),
        "n_shed": float(len(shed)),
        "drop_rate": len(shed) / n_offered if n_offered else 0.0,
        # goodput two ways: completed fraction of offered load (what the
        # overload gates compare against the feasible fraction), and the
        # completion rate (identical to throughput_jobs_per_mcycle — named
        # here so SLO tables read naturally)
        "goodput_frac": len(done) / n_offered if n_offered else 0.0,
        "goodput_jobs_per_mcycle": (len(done) / (makespan / 1e6)
                                    if makespan > 0 else 0.0),
    }
    for kind in ("shallow", "deep"):
        offered_k = sum(1 for je in jobs if je.kind == kind)
        shed_k = sum(1 for je in shed if je.kind == kind)
        out[f"n_completed_{kind}"] = float(sum(1 for je in done if je.kind == kind))
        out[f"drop_rate_{kind}"] = shed_k / offered_k if offered_k else 0.0
    tts = _pct([je.time_to_shed for je in shed])
    out["time_to_shed_p50_cycles"] = tts["p50"]
    out["time_to_shed_p99_cycles"] = tts["p99"]
    return out


def _availability_block(result: ServeResult | ClusterResult,
                        done: list) -> dict[str, float]:
    """Shared fault/recovery keys.  ``wasted_mcycles`` sums the per-attempt
    ``wasted_cycles`` over EVERY record in the chip timelines (each attempt
    counted once — ``prior_wasted_cycles`` is a carry, not new waste);
    ``checkpoint_saved_mcycles`` is service a checkpoint resume did NOT have
    to redo."""
    primaries = result.jobs
    records = (
        [je for r in result.chip_results for je in r.jobs]
        if isinstance(result, ClusterResult) else primaries)
    return {
        "n_failed": float(sum(1 for je in primaries
                              if je.state is JobState.FAILED)),
        "n_retried_jobs": float(sum(1 for je in done if je.attempts > 1)),
        "retries_total": float(sum(je.attempts - 1 for je in primaries)),
        "wasted_mcycles": sum(je.wasted_cycles for je in records) / 1e6,
        "checkpoint_saved_mcycles": sum(je.checkpoint_cycles for je in done) / 1e6,
    }


def summarize(result: ServeResult | ClusterResult) -> dict[str, float]:
    """Flat metric dict (CSV-friendly).  Keys:

    latency_p50/p95/p99_cycles, latency_p99_ms — end-to-end turnaround;
    latency_p99_shallow/deep_cycles            — per-kind tail latency (what
                                                 the hetero/gang gates check);
    queue_p50/p95/p99_cycles                   — arrival → first dispatch;
    queue_max_shallow/deep_cycles              — worst queueing per kind
                                                 (deep = starvation indicator);
    makespan_mcycles, throughput_jobs_per_mcycle;
    util_mean, util_min, util_max              — busy/makespan per affiliation;
    fairness_jain                              — over per-tenant mean slowdown
                                                 (per-job when single-tenant);
    n_jobs, n_shallow, n_deep, n_preemptions, spill_restore_mcycles;
    n_offered, n_shed, n_completed_shallow/deep — admission accounting
                                                 (n_jobs counts completions;
                                                 offered = completed + shed);
    drop_rate, drop_rate_shallow/deep          — shed fraction of offered;
    goodput_frac, goodput_jobs_per_mcycle      — completed/offered, and the
                                                 completion rate;
    time_to_shed_p50/p99_cycles                — arrival → shed decision
                                                 (NaN when nothing shed);
    n_failed, n_retried_jobs, retries_total    — fault/recovery accounting;
    wasted_mcycles, checkpoint_saved_mcycles   — work lost to faults, and
                                                 service a checkpoint resume
                                                 did not redo.

    Empty percentile samples (a kind with zero completions, nothing shed)
    are NaN, never 0.0 — gates must check the ``n_completed_{kind}`` counts
    before comparing tails.

    A ``ClusterResult`` routes to ``summarize_cluster`` (fleet-level SLOs).
    """
    if isinstance(result, ClusterResult):
        return summarize_cluster(result)
    done = [je for je in result.jobs if je.state is JobState.DONE]
    lat = _pct([je.turnaround for je in done])
    queue = _pct([je.queueing_delay for je in done])
    mk = result.makespan
    busy = per_affiliation_busy(result)
    utils = [b / mk if mk > 0 else 0.0 for b in busy.values()]
    by_tenant = tenant_slowdowns(result)
    if len(by_tenant) > 1:
        slow = list(by_tenant.values())
    else:  # single tenant: fairness across individual jobs instead
        slow = [je.turnaround / je.service_cycles for je in done if je.service_cycles > 0]
    freq_hz = result.chip.freq_ghz * 1e9
    out = {
        "n_jobs": float(len(done)),
        "n_shallow": float(sum(1 for je in done if je.kind == "shallow")),
        "n_deep": float(sum(1 for je in done if je.kind == "deep")),
        "makespan_mcycles": mk / 1e6,
        "makespan_ms": mk / freq_hz * 1e3,
        "throughput_jobs_per_mcycle": len(done) / (mk / 1e6) if mk > 0 else 0.0,
        "util_mean": float(np.mean(utils)) if utils else 0.0,
        "util_min": float(np.min(utils)) if utils else 0.0,
        "util_max": float(np.max(utils)) if utils else 0.0,
        "fairness_jain": jain_fairness(slow),
        "n_preemptions": float(sum(je.n_preemptions for je in done)),
        "spill_restore_mcycles": sum(je.spill_restore_cycles for je in done) / 1e6,
    }
    out.update(_overload_block(result, done, mk))
    out.update(_availability_block(result, done))
    for k, v in lat.items():
        out[f"latency_{k}_cycles"] = v
    out["latency_p99_ms"] = lat["p99"] / freq_hz * 1e3
    for kind in ("shallow", "deep"):
        out[f"latency_p99_{kind}_cycles"] = _pct(
            [je.turnaround for je in done if je.kind == kind])["p99"]
    for k, v in queue.items():
        out[f"queue_{k}_cycles"] = v
    for kind, v in max_queueing_by_kind(result).items():
        out[f"queue_max_{kind}_cycles"] = v
    return out


def per_chip_utilization(result: ClusterResult) -> list[float]:
    """Busy fraction of the fleet makespan per chip (mean over affiliations)."""
    mk = result.makespan
    utils = []
    for r in result.chip_results:
        busy = per_affiliation_busy(r)
        utils.append(float(np.mean([b / mk if mk > 0 else 0.0 for b in busy.values()]))
                     if busy else 0.0)
    return utils


def per_chip_type_utilization(result: ClusterResult) -> dict[str, float]:
    """Mean busy fraction per chip *type* (e.g. on a mixed fleet: how loaded
    are the FLASH-FHE dies vs the CraterLake die?).  Keyed by chip name;
    kept out of the flat ``summarize_cluster`` dict so CSV columns stay
    uniform across fleets of different composition."""
    utils = per_chip_utilization(result)
    acc: dict[str, list[float]] = {}
    for chip, u in zip(result.chips, utils):
        acc.setdefault(chip.name, []).append(u)
    return {name: float(np.mean(v)) for name, v in acc.items()}


def summarize_cluster(result: ClusterResult) -> dict[str, float]:
    """Fleet-level SLOs: the merged-job latency/queueing view plus per-chip
    balance.  Keys beyond ``summarize``'s:

    n_chips;
    chip_util_mean/min/max                     — per-chip busy fraction;
    chip_util_imbalance                        — max − min (0 = perfectly even);
    fairness_jain_chips                        — Jain over per-chip busy cycles;
    n_cold_starts, cold_start_mcycles          — warm-set misses the router
                                                 charged into service demand;
    n_gang_jobs, gang_chips_mean               — deep jobs that gang-split, and
                                                 their mean width in chips;
    gang_link_bytes, gang_link_mcycles         — inter-chip exchange totals
                                                 (mcycles = per-chip link
                                                 stalls summed over members);
    peak_backlog_mcycles                       — max fleet-wide outstanding
                                                 routed demand over the run
                                                 (the bounded-queues
                                                 observable under overload);
    plus the admission block (n_offered, n_shed, n_completed_{kind},
    drop_rate[_kind], goodput_frac, goodput_jobs_per_mcycle,
    time_to_shed_p50/p99_cycles) shared with ``summarize``, and the
    availability block: the shared fault keys (n_failed, n_retried_jobs,
    retries_total, wasted_mcycles, checkpoint_saved_mcycles) plus
    downtime_mcycles / mttr_mcycles (NaN when nothing crashed) /
    availability (1 − downtime ÷ (n_chips × makespan)) and the injected
    fault counters (n_crashes, n_transients, n_slow_windows, n_retries,
    n_jobs_lost, n_retry_no_chip).

    Per-job numbers (latency, queueing, preemptions, spill) count each ganged
    job ONCE through its primary fragment — fragments share completion times
    by the lockstep invariant, so nothing is lost.  Per-chip numbers (busy
    cycles, utilization) naturally include every fragment's segments.

    Every latency/queueing/fairness number is computed from the union of the
    per-chip ``ServeResult`` timelines — the property suite asserts this merge
    identity directly.
    """
    done = [je for je in result.jobs if je.state is JobState.DONE]
    lat = _pct([je.turnaround for je in done])
    queue = _pct([je.queueing_delay for je in done])
    mk = result.makespan
    chip_utils = per_chip_utilization(result)
    chip_busy = [sum(per_affiliation_busy(r).values()) for r in result.chip_results]
    by_tenant = tenant_slowdowns(result)
    if len(by_tenant) > 1:
        slow = list(by_tenant.values())
    else:
        slow = [je.turnaround / je.service_cycles for je in done if je.service_cycles > 0]
    freq_hz = result.chip.freq_ghz * 1e9
    out = {
        "n_chips": float(result.n_chips),
        "n_jobs": float(len(done)),
        "n_shallow": float(sum(1 for je in done if je.kind == "shallow")),
        "n_deep": float(sum(1 for je in done if je.kind == "deep")),
        "makespan_mcycles": mk / 1e6,
        "makespan_ms": mk / freq_hz * 1e3,
        "throughput_jobs_per_mcycle": len(done) / (mk / 1e6) if mk > 0 else 0.0,
        "chip_util_mean": float(np.mean(chip_utils)) if chip_utils else 0.0,
        "chip_util_min": float(np.min(chip_utils)) if chip_utils else 0.0,
        "chip_util_max": float(np.max(chip_utils)) if chip_utils else 0.0,
        "chip_util_imbalance": (float(np.max(chip_utils) - np.min(chip_utils))
                                if chip_utils else 0.0),
        "fairness_jain": jain_fairness(slow),
        "fairness_jain_chips": jain_fairness(chip_busy),
        "n_preemptions": float(sum(je.n_preemptions for je in done)),
        "spill_restore_mcycles": sum(je.spill_restore_cycles for je in done) / 1e6,
        "n_cold_starts": float(sum(1 for je in done if je.cold_start_cycles > 0)),
        "cold_start_mcycles": sum(je.cold_start_cycles for je in done) / 1e6,
        "peak_backlog_mcycles": result.peak_backlog_cycles / 1e6,
    }
    out.update(_overload_block(result, done, mk))
    out.update(_availability_block(result, done))
    # availability under faults: per-chip downtime integrates the [crash,
    # recover) windows; MTTR is the mean window (NaN when nothing crashed,
    # same empty-sample semantics as the latency percentiles)
    windows = [hi - lo for ws in result.downtime.values() for lo, hi in ws]
    total_down = sum(windows)
    out["downtime_mcycles"] = total_down / 1e6
    out["mttr_mcycles"] = float(np.mean(windows)) / 1e6 if windows else float("nan")
    out["availability"] = (1.0 - total_down / (result.n_chips * mk)
                           if mk > 0 else 1.0)
    fc = result.fault_counts
    for key in ("crashes", "transients", "slow_windows", "retries",
                "jobs_lost", "retry_no_chip"):
        out[f"n_{key}"] = float(fc.get(key, 0))
    ganged = [je for je in done if je.gang_size > 1]
    out["n_gang_jobs"] = float(len(ganged))
    out["gang_chips_mean"] = (float(np.mean([je.gang_size for je in ganged]))
                              if ganged else 0.0)
    out["gang_link_bytes"] = sum(je.link_bytes for je in ganged)
    out["gang_link_mcycles"] = sum(je.link_cycles * je.gang_size for je in ganged) / 1e6
    for k, v in lat.items():
        out[f"latency_{k}_cycles"] = v
    out["latency_p99_ms"] = lat["p99"] / freq_hz * 1e3
    for kind in ("shallow", "deep"):
        out[f"latency_p99_{kind}_cycles"] = _pct(
            [je.turnaround for je in done if je.kind == kind])["p99"]
    for k, v in queue.items():
        out[f"queue_{k}_cycles"] = v
    for kind, v in max_queueing_by_kind(result).items():
        out[f"queue_max_{kind}_cycles"] = v
    return out
