"""Discrete-event simulation kernel: event heap, clock, run loop.

Deliberately tiny and generic — the serving policies (``repro.serve.policy``)
are the only intended client, but nothing here knows about FHE.  Events are
plain callbacks ordered by (time, insertion sequence); the sequence number
makes simultaneous events deterministic (submission order) and breaks heap
ties without comparing payloads.  Cancellation is lazy: a cancelled event
stays in the heap and is skipped when popped — O(1) cancel, which preemption
uses to revoke a suspended job's completion event.  The loop compacts the heap
once cancelled entries outnumber live ones — checked on BOTH insertion and
cancellation, so a mass-cancellation burst with no follow-up inserts (admission
shedding revoking thousands of queued deadline events at once) still compacts
immediately.  Long fleet runs (many engines sharing one loop, each preemption
leaving a dead completion event) therefore stay O(live events) in memory: the
heap never holds more cancelled entries than live ones outside the compaction
call itself, and each compaction's O(heap) cost is amortised over the ≥ heap/2
cancellations that triggered it.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Callable


class Event:
    """One scheduled callback.  ``cancel()`` revokes it in O(1)."""

    __slots__ = ("time", "seq", "fn", "cancelled", "_loop")

    def __init__(self, time: float, seq: int, fn: Callable[[], None], loop: "EventLoop | None" = None):
        self.time = time
        self.seq = seq
        self.fn = fn
        self.cancelled = False
        self._loop = loop

    def cancel(self) -> None:
        if not self.cancelled:
            self.cancelled = True
            if self._loop is not None:
                self._loop._note_cancel()

    def __lt__(self, other: "Event") -> bool:  # heap ordering
        return (self.time, self.seq) < (other.time, other.seq)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = "cancelled" if self.cancelled else "pending"
        return f"Event(t={self.time:.1f}, seq={self.seq}, {state})"


class EventLoop:
    """Monotonic clock + binary-heap run loop.

    The clock unit is *cycles* throughout the serving subsystem (converted to
    seconds only at the metrics layer, via the chip frequency).
    """

    def __init__(self, start: float = 0.0, tracer=None):
        self.now = float(start)
        self._heap: list[Event] = []
        self._seq = itertools.count()
        self._n_cancelled = 0
        self.processed = 0
        # observability seam: a ``repro.obs.Tracer`` bound here timestamps
        # every event it records off THIS clock — the loop is the single
        # source of simulated time, which is what makes traces deterministic
        if tracer is not None and tracer:
            tracer.bind_clock(lambda: self.now)

    def __len__(self) -> int:
        return len(self._heap) - self._n_cancelled

    def call_at(self, time: float, fn: Callable[[], None]) -> Event:
        if time < self.now:
            raise ValueError(f"cannot schedule into the past: {time} < now={self.now}")
        self._maybe_compact()
        ev = Event(float(time), next(self._seq), fn, loop=self)
        heapq.heappush(self._heap, ev)
        return ev

    def _note_cancel(self) -> None:
        """Bookkeeping hook ``Event.cancel`` calls; compacts when dead entries
        outnumber live ones so pure cancellation bursts cannot bloat the heap."""
        self._n_cancelled += 1
        self._maybe_compact()

    def _maybe_compact(self) -> None:
        if self._n_cancelled > 32 and 2 * self._n_cancelled > len(self._heap):
            self._compact()

    def _compact(self) -> None:
        """Drop cancelled entries and re-heapify (amortised by the cancel count)."""
        self._heap = [e for e in self._heap if not e.cancelled]
        heapq.heapify(self._heap)
        self._n_cancelled = 0

    def call_after(self, delay: float, fn: Callable[[], None]) -> Event:
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        return self.call_at(self.now + delay, fn)

    def peek_time(self) -> float | None:
        """Time of the next pending event, or None when drained."""
        while self._heap and self._heap[0].cancelled:
            heapq.heappop(self._heap)
            self._n_cancelled -= 1
        return self._heap[0].time if self._heap else None

    def step(self) -> bool:
        """Dispatch the next pending event; False when the heap is drained."""
        while self._heap:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                self._n_cancelled -= 1
                continue
            assert ev.time >= self.now, "event heap violated monotonic time"
            self.now = ev.time
            self.processed += 1
            ev.fn()
            return True
        return False

    def run(self, until: float | None = None, max_events: int | None = None) -> float:
        """Run to quiescence (or a time/event horizon); returns the final clock.

        ``until`` stops *before* dispatching any event strictly later than the
        horizon (the clock advances to the horizon).  ``max_events`` is a
        safety valve for open-loop sources that never drain.
        """
        dispatched = 0
        while True:
            if max_events is not None and dispatched >= max_events:
                return self.now
            t = self.peek_time()
            if t is None:
                return self.now
            if until is not None and t > until:
                self.now = max(self.now, until)
                return self.now
            self.step()
            dispatched += 1
