"""repro.models"""
