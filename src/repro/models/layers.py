"""Model building blocks (pure JAX, scan/remat-friendly, shard-constraint free —
sharding is annotated at the block level in lm.py so layouts stay in one place).

All compute in bfloat16 with float32 softmax/normalisation statistics.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .scan_util import maybe_scan

BF16 = jnp.bfloat16


# ---------------------------------------------------------------------------
# norms / positional
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm(x, w, b, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


def rope(x, positions, theta: float):
    """x: (..., S, H, D) rotary over last dim; positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (online-softmax, chunked — bounded memory at any sequence length)
# ---------------------------------------------------------------------------


NEG_INF = -1e30

# Beyond-paper perf knob (§Perf hillclimb): statically skip fully-masked
# causal blocks — halves attention FLOPs at long sequence.  Off by default so
# the paper-faithful baseline is measured first.
_BLOCK_SKIP: "contextvars.ContextVar[bool]"
import contextlib as _contextlib
import contextvars as _contextvars

_BLOCK_SKIP = _contextvars.ContextVar("flash_block_skip", default=False)


@_contextlib.contextmanager
def causal_block_skipping():
    tok = _BLOCK_SKIP.set(True)
    try:
        yield
    finally:
        _BLOCK_SKIP.reset(tok)


def flash_attention(q, k, v, *, causal=True, window=0, q_chunk=512, k_chunk=1024,
                    q_offset=0):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D), H = KV·G.

    Online-softmax over KV chunks inside a scan over Q chunks: peak memory is
    O(q_chunk·k_chunk) per head group instead of O(Sq·Sk).
    ``q_offset``: absolute position of q[0] (prefill continuation / decode).
    ``window`` > 0 ⇒ sliding-window attention (|i-j| < window).

    Under `causal_block_skipping()` the q-chunk loop is a static python loop
    and each q chunk only visits KV chunks that can be unmasked (j ≤ i, and
    j ≥ i − ⌈window/ck⌉ for sliding windows).
    """
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    scale = 1.0 / math.sqrt(d)
    nq = -(-sq // q_chunk)
    nk = -(-sk // k_chunk)
    sq_pad = nq * q_chunk
    sk_pad = nk * k_chunk
    qp = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
    qp = qp.reshape(b, nq, q_chunk, kv, g, d)
    kp = kp.reshape(b, nk, k_chunk, kv, d)
    vp = vp.reshape(b, nk, k_chunk, kv, d)

    q_pos_base = jnp.arange(q_chunk) + q_offset
    k_pos_base = jnp.arange(k_chunk)

    def q_step(_, qi):
        qc, iq = qi  # (B, cq, KV, G, D), scalar chunk idx
        qpos = q_pos_base + iq * q_chunk  # (cq,)

        def kv_step(carry, kj):
            m, l, acc = carry
            kc, vc, jk = kj
            kpos = k_pos_base + jk * k_chunk  # (ck,)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qc.astype(BF16), kc.astype(BF16),
                           preferred_element_type=jnp.float32) * scale
            mask = kpos[None, :] <= qpos[:, None] if causal else \
                jnp.ones((q_chunk, k_chunk), bool)
            if window:
                mask = mask & (qpos[:, None] - kpos[None, :] < window)
            mask = mask & (kpos[None, :] < sk)  # padding
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(BF16), vc.astype(BF16),
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kv, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = maybe_scan(
            kv_step, (m0, l0, a0),
            (kp.transpose(1, 0, 2, 3, 4), vp.transpose(1, 0, 2, 3, 4), jnp.arange(nk)),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 3, 1, 2, 4)  # (B, cq, KV, G, D)

    if _BLOCK_SKIP.get() and causal:
        # static python loop over q chunks; each visits only reachable blocks
        kt = kp.transpose(1, 0, 2, 3, 4)
        vt = vp.transpose(1, 0, 2, 3, 4)
        outs = []
        for iq in range(nq):
            hi = min(nk, (iq + 1) * q_chunk // k_chunk + 1)  # j·ck ≤ (iq+1)·cq
            lo = 0
            if window:
                lo = max(0, (iq * q_chunk - window) // k_chunk)
            qc = qp[:, iq]
            # inline online-softmax over the reachable block range
            m_ = jnp.full((b, kv, g, q_chunk), NEG_INF, jnp.float32)
            l_ = jnp.zeros((b, kv, g, q_chunk), jnp.float32)
            acc_ = jnp.zeros((b, kv, g, q_chunk, d), jnp.float32)
            qpos = q_pos_base + iq * q_chunk
            for j in range(lo, hi):
                kc, vc = kt[j], vt[j]
                kpos = k_pos_base + j * k_chunk
                s = jnp.einsum("bqkgd,bckd->bkgqc", qc.astype(BF16), kc.astype(BF16),
                               preferred_element_type=jnp.float32) * scale
                mask = kpos[None, :] <= qpos[:, None]
                if window:
                    mask = mask & (qpos[:, None] - kpos[None, :] < window)
                mask = mask & (kpos[None, :] < sk)
                s = jnp.where(mask[None, None, None], s, NEG_INF)
                m_new = jnp.maximum(m_, s.max(axis=-1))
                pbl = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m_ - m_new)
                l_ = l_ * corr + pbl.sum(axis=-1)
                acc_ = acc_ * corr[..., None] + jnp.einsum(
                    "bkgqc,bckd->bkgqd", pbl.astype(BF16), vc.astype(BF16),
                    preferred_element_type=jnp.float32)
                m_ = m_new
            o = (acc_ / jnp.maximum(l_[..., None], 1e-30)).transpose(0, 3, 1, 2, 4)
            outs.append(o)
        out = jnp.stack(outs, axis=1).reshape(b, nq, q_chunk, h, d) \
            .reshape(b, sq_pad, h, d)
        return out[:, :sq].astype(q.dtype)

    _, outs = maybe_scan(q_step, None,
                         (qp.transpose(1, 0, 2, 3, 4, 5), jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq_pad, h, d)
    return out[:, :sq].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, t, *, window=0):
    """Single-token attention against a (B, Smax, KV, D) cache; t = current len.

    Memory-bound flash-decoding shape: scores (B, KV, G, Smax) in fp32.
    """
    b, _, h, d = q.shape
    _, smax, kv, _ = k_cache.shape
    g = h // kv
    scale = 1.0 / math.sqrt(d)
    qh = q.reshape(b, kv, g, d)
    s = jnp.einsum("bkgd,bskd->bkgs", qh.astype(BF16), k_cache.astype(BF16),
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(smax)
    mask = pos[None, None, None, :] < t
    if window:
        mask = mask & (pos[None, None, None, :] >= t - window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(BF16), v_cache.astype(BF16),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# feed-forward / MoE
# ---------------------------------------------------------------------------


def ffn(x, w1, w2, w3=None, act="swiglu"):
    if act == "swiglu":
        h = jax.nn.silu(x @ w1) * (x @ w3)
    else:
        h = jax.nn.gelu(x @ w1)
    return h @ w2


def moe_ffn(x, router_w, w1, w2, w3, *, top_k: int, capacity_factor: float = 1.25,
            n_shared: int = 0, sw1=None, sw2=None, sw3=None):
    """Capacity-based top-k MoE with token dropping (EP-shardable einsums).

    x: (T, d); router_w: (d, E); w1/w3: (E, d, f); w2: (E, f, d).
    """
    t, d = x.shape
    e = router_w.shape[1]
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    cap = int(capacity_factor * top_k * t / e) + 1
    flat_e = idx.reshape(-1)  # (T·k,)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)
    flat_gate = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_tok[order], flat_gate[order]
    counts = jnp.bincount(se, length=e)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * top_k) - starts[se]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)  # dropped tokens land in a spill row

    buf = jnp.zeros((e, cap + 1, d), x.dtype).at[se, pos_c].set(x[st])
    h = jnp.einsum("ecd,edf->ecf", buf, w1.astype(x.dtype))
    if w3 is not None:
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, w3.astype(x.dtype))
    else:
        h = jax.nn.gelu(h)
    eo = jnp.einsum("ecf,efd->ecd", h, w2.astype(x.dtype))

    contrib = eo[se, pos_c] * (sg * keep)[:, None].astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[st].add(contrib)
    if n_shared:
        out = out + ffn(x, sw1.astype(x.dtype), sw2.astype(x.dtype),
                        sw3.astype(x.dtype), act="swiglu")
    return out, probs


# ---------------------------------------------------------------------------
# Mamba2 / SSD (chunked state-space duality algorithm)
# ---------------------------------------------------------------------------


def ssd_chunked(xh, dt, a_log, b_in, c_in, d_skip, *, chunk: int = 128,
                h0=None):
    """Chunked SSD scan.  xh: (B, S, NH, HD); dt: (B, S, NH);
    b_in/c_in: (B, S, NS); a_log: (NH,); d_skip: (NH,).

    Returns (y: (B, S, NH, HD), h_final: (B, NH, HD, NS)).
    Memory: O(S·NS + (S/chunk)·NH·HD·NS) — never the full outer-product history.
    """
    b, s, nh, hd = xh.shape
    ns = b_in.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
    c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))

    # per-step log-decay: log a_t = −exp(A_log)·dt  (Mamba2 scalar-identity A)
    loga = (-jnp.exp(a_log.astype(jnp.float32))[None, None] * dt)  # (B, S', NH)
    xdt = xh.astype(jnp.float32) * dt[..., None]  # dt-scaled input

    def to_chunks(z):
        return z.reshape((b, nc, chunk) + z.shape[2:]).transpose(1, 0, *range(2, z.ndim + 1))

    xc = to_chunks(xdt)  # (nc, B, c, NH, HD)
    lc = to_chunks(loga)  # (nc, B, c, NH)
    bc = to_chunks(b_in.astype(jnp.float32))  # (nc, B, c, NS)
    cc = to_chunks(c_in.astype(jnp.float32))

    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, ns), jnp.float32)

    def chunk_step(h, inp):
        xcj, lcj, bcj, ccj = inp  # (B,c,NH,HD), (B,c,NH), (B,c,NS), (B,c,NS)
        cum = jnp.cumsum(lcj, axis=1)  # (B, c, NH) inclusive
        total = cum[:, -1]  # (B, NH)
        # intra-chunk (quadratic within chunk):
        # y[i] += Σ_{j≤i} exp(cum_i − cum_j)·(c_i·b_j)·xdt_j
        li = cum[:, :, None, :] - cum[:, None, :, :]  # (B, ci, cj, NH)
        iota = jnp.arange(chunk)
        causal = (iota[:, None] >= iota[None, :])[None, :, :, None]
        w = jnp.where(causal, jnp.exp(li), 0.0)
        sbc = jnp.einsum("bis,bjs->bij", ccj, bcj)  # (B, ci, cj)
        y_intra = jnp.einsum("bijh,bij,bjhd->bihd", w, sbc, xcj)
        # inter-chunk: y[i] += c_i · (exp(cum_i)·h_prev)
        y_inter = jnp.einsum("bis,bih,bhds->bihd", ccj, jnp.exp(cum), h)
        # carried state: h' = exp(total)·h + Σ_j exp(total − cum_j)·b_j ⊗ xdt_j
        decay_j = jnp.exp(total[:, None] - cum)  # (B, c, NH)
        h_add = jnp.einsum("bjh,bjs,bjhd->bhds", decay_j, bcj, xcj)
        h_new = jnp.exp(total)[..., None, None] * h + h_add
        return h_new, (y_intra + y_inter)

    h_final, ys = maybe_scan(chunk_step, h0, (xc, lc, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, nh, hd)
    y = y + xh.astype(jnp.float32) * d_skip[None, None, :, None]
    return y[:, :s].astype(BF16), h_final


def ssd_decode_step(xh, dt, a_log, b_in, c_in, d_skip, h):
    """One-token SSD update.  xh: (B, NH, HD); dt: (B, NH); b/c: (B, NS)."""
    a = jnp.exp(-jnp.exp(a_log.astype(jnp.float32))[None] * dt)  # (B, NH)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    h_new = a[..., None, None] * h + jnp.einsum("bhd,bs->bhds", xdt, b_in.astype(jnp.float32))
    y = jnp.einsum("bhds,bs->bhd", h_new, c_in.astype(jnp.float32))
    y = y + xh.astype(jnp.float32) * d_skip[None, :, None]
    return y.astype(BF16), h_new


def causal_conv1d(x, w, b=None, state=None):
    """Depthwise causal conv, kernel k.  x: (B, S, C); w: (C, k).

    With ``state`` (B, k-1, C) performs streaming (decode) mode on S=1.
    Returns (y, new_state).
    """
    k = w.shape[1]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    windows = jnp.stack([xp[:, i : i + x.shape[1]] for i in range(k)], axis=-1)
    y = jnp.einsum("bsck,ck->bsc", windows, w.astype(x.dtype))
    if b is not None:
        y = y + b
    new_state = xp[:, -(k - 1) :] if k > 1 else state
    return jax.nn.silu(y), new_state
