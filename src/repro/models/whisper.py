"""Whisper-style encoder-decoder backbone (audio frontend is a stub: the
encoder consumes precomputed frame embeddings, as the assigned-architecture
spec requires).

Encoder: bidirectional attention + GELU FFN + layernorm + learned positions.
Decoder: causal self-attention + cross-attention to encoder states.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as sh

from . import layers as L
from .config import ModelConfig
from .lm import BF16, _dense_init, _norm_init, chunked_xent
from .scan_util import maybe_scan

MAX_DEC_POS = 1 << 16


def init_enc_block(cfg: ModelConfig, key):
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    ks = jax.random.split(key, 6)
    return {
        "ln1_w": _norm_init((d,)), "ln1_b": jnp.zeros((d,), jnp.float32),
        "wqkv": _dense_init(ks[0], (d, 3 * cfg.n_heads * hd)),
        "wo": _dense_init(ks[1], (cfg.n_heads * hd, d)),
        "ln2_w": _norm_init((d,)), "ln2_b": jnp.zeros((d,), jnp.float32),
        "w1": _dense_init(ks[2], (d, f)),
        "w2": _dense_init(ks[3], (f, d)),
    }


def init_dec_block(cfg: ModelConfig, key):
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    ks = jax.random.split(key, 8)
    return {
        "ln1_w": _norm_init((d,)), "ln1_b": jnp.zeros((d,), jnp.float32),
        "wqkv": _dense_init(ks[0], (d, 3 * cfg.n_heads * hd)),
        "wo": _dense_init(ks[1], (cfg.n_heads * hd, d)),
        "lnx_w": _norm_init((d,)), "lnx_b": jnp.zeros((d,), jnp.float32),
        "xq": _dense_init(ks[2], (d, cfg.n_heads * hd)),
        "xkv": _dense_init(ks[3], (d, 2 * cfg.n_heads * hd)),
        "xo": _dense_init(ks[4], (cfg.n_heads * hd, d)),
        "ln2_w": _norm_init((d,)), "ln2_b": jnp.zeros((d,), jnp.float32),
        "w1": _dense_init(ks[5], (d, f)),
        "w2": _dense_init(ks[6], (f, d)),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    k = jax.random.split(key, 8)
    enc = jax.vmap(lambda kk: init_enc_block(cfg, kk))(
        jax.random.split(k[0], cfg.enc_layers))
    dec = jax.vmap(lambda kk: init_dec_block(cfg, kk))(
        jax.random.split(k[1], cfg.n_layers))
    d = cfg.d_model
    return {
        "enc_pos": _dense_init(k[2], (cfg.enc_seq, d), scale=0.02),
        "dec_pos": _dense_init(k[3], (MAX_DEC_POS, d), scale=0.02),
        "embed": _dense_init(k[4], (cfg.vocab, d), scale=0.02),
        "enc_blocks": enc,
        "dec_blocks": dec,
        "enc_ln_w": _norm_init((d,)), "enc_ln_b": jnp.zeros((d,), jnp.float32),
        "dec_ln_w": _norm_init((d,)), "dec_ln_b": jnp.zeros((d,), jnp.float32),
        "head": _dense_init(k[5], (d, cfg.vocab)),
    }


def param_specs(cfg: ModelConfig, mesh: Mesh) -> dict:
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    W = lambda shape, tp, fs: P(None, *sh.weight_spec(mesh, shape, tp, fs))
    V = P(None, None)
    enc = {
        "ln1_w": V, "ln1_b": V,
        "wqkv": W((d, 3 * cfg.n_heads * hd), 1, 0),
        "wo": W((cfg.n_heads * hd, d), 0, 1),
        "ln2_w": V, "ln2_b": V,
        "w1": W((d, f), 1, 0), "w2": W((f, d), 0, 1),
    }
    dec = dict(enc)
    dec.update({
        "lnx_w": V, "lnx_b": V,
        "xq": W((d, cfg.n_heads * hd), 1, 0),
        "xkv": W((d, 2 * cfg.n_heads * hd), 1, 0),
        "xo": W((cfg.n_heads * hd, d), 0, 1),
    })
    return {
        "enc_pos": sh.weight_spec(mesh, (cfg.enc_seq, d), None, 0),
        "dec_pos": sh.weight_spec(mesh, (MAX_DEC_POS, d), None, 0),
        "embed": sh.weight_spec(mesh, (cfg.vocab, d), 0, 1),
        "enc_blocks": enc, "dec_blocks": dec,
        "enc_ln_w": P(None), "enc_ln_b": P(None),
        "dec_ln_w": P(None), "dec_ln_b": P(None),
        "head": sh.weight_spec(mesh, (d, cfg.vocab), 1, 0),
    }


def _mha(x, p, cfg, causal, mesh):
    b, s, _ = x.shape
    h = L.layernorm(x, p["ln1_w"].astype(x.dtype), p["ln1_b"].astype(x.dtype))
    qkv = h @ p["wqkv"].astype(x.dtype)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    hd = cfg.hd
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_heads, hd)
    v = v.reshape(b, s, cfg.n_heads, hd)
    out = L.flash_attention(q, k, v, causal=causal)
    return out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


def _ffn(x, p, ln_w, ln_b):
    h = L.layernorm(x, p[ln_w].astype(x.dtype), p[ln_b].astype(x.dtype))
    return jax.nn.gelu(h @ p["w1"].astype(x.dtype)) @ p["w2"].astype(x.dtype)


def encode(cfg: ModelConfig, params, frames, mesh: Mesh | None = None):
    """frames: (B, enc_seq, D) stub frontend embeddings → encoder states."""
    x = frames.astype(BF16) + params["enc_pos"][: frames.shape[1]].astype(BF16)

    def body(h, p):
        h = h + _mha(h, p, cfg, causal=False, mesh=mesh)
        h = h + _ffn(h, p, "ln2_w", "ln2_b")
        if mesh is not None:
            h = sh.constrain(h, mesh, sh.batch_spec(mesh, 3))
        return h, None

    x, _ = maybe_scan(lambda h, p: body(h, p), x, params["enc_blocks"])
    return L.layernorm(x, params["enc_ln_w"].astype(x.dtype),
                       params["enc_ln_b"].astype(x.dtype))


def _cross_attn(x, enc_out, p, cfg):
    b, s, _ = x.shape
    se = enc_out.shape[1]
    h = L.layernorm(x, p["lnx_w"].astype(x.dtype), p["lnx_b"].astype(x.dtype))
    hd = cfg.hd
    q = (h @ p["xq"].astype(x.dtype)).reshape(b, s, cfg.n_heads, hd)
    kv = enc_out @ p["xkv"].astype(x.dtype)
    k, v = jnp.split(kv, 2, axis=-1)
    k = k.reshape(b, se, cfg.n_heads, hd)
    v = v.reshape(b, se, cfg.n_heads, hd)
    out = L.flash_attention(q, k, v, causal=False)
    return out.reshape(b, s, -1) @ p["xo"].astype(x.dtype)


def decoder_hidden(cfg: ModelConfig, params, tokens, enc_out, mesh=None):
    b, s = tokens.shape
    x = params["embed"].astype(BF16)[tokens] + params["dec_pos"][:s].astype(BF16)

    def body(h, p):
        h = h + _mha(h, p, cfg, causal=True, mesh=mesh)
        h = h + _cross_attn(h, enc_out, p, cfg)
        h = h + _ffn(h, p, "ln2_w", "ln2_b")
        if mesh is not None:
            h = sh.constrain(h, mesh, sh.batch_spec(mesh, 3))
        return h, None

    x, _ = maybe_scan(body, x, params["dec_blocks"])
    return L.layernorm(x, params["dec_ln_w"].astype(x.dtype),
                       params["dec_ln_b"].astype(x.dtype))


def train_loss(cfg: ModelConfig, params, frames, tokens, mesh=None):
    """frames: (B, enc_seq, D); tokens: (B, S_dec+1)."""
    enc_out = encode(cfg, params, frames, mesh)
    h = decoder_hidden(cfg, params, tokens[:, :-1], enc_out, mesh)
    fake_cfg_head = {"head": params["head"], "embed": params["embed"]}
    return chunked_xent(cfg, fake_cfg_head, h, tokens[:, 1:], mesh)


# --- serving -----------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    nl, hd = cfg.n_layers, cfg.hd
    return {
        "t": jnp.zeros((), jnp.int32),
        "k": jnp.zeros((nl, batch, max_seq, cfg.n_heads, hd), BF16),
        "v": jnp.zeros((nl, batch, max_seq, cfg.n_heads, hd), BF16),
        # cross-attention K/V precomputed at prefill
        "xk": jnp.zeros((nl, batch, cfg.enc_seq, cfg.n_heads, hd), BF16),
        "xv": jnp.zeros((nl, batch, cfg.enc_seq, cfg.n_heads, hd), BF16),
    }


def cache_specs(cfg: ModelConfig, mesh: Mesh) -> dict:
    dp_t = sh.dp_axes(mesh)
    dp = dp_t or None
    seq_ax = None if "model" in dp_t else "model"
    kv = P(None, dp, seq_ax, None, None)
    return {"t": P(), "k": kv, "v": kv,
            "xk": P(None, dp, None, None, None), "xv": P(None, dp, None, None, None)}


def prefill(cfg: ModelConfig, params, frames, tokens, cache, mesh=None):
    """Encode frames, precompute cross-KV, run decoder prompt; fill caches."""
    enc_out = encode(cfg, params, frames, mesh)
    b, s = tokens.shape
    x = params["embed"].astype(BF16)[tokens] + params["dec_pos"][:s].astype(BF16)
    hd, nh = cfg.hd, cfg.n_heads
    se = enc_out.shape[1]
    smax = cache["k"].shape[2]

    def body(h, p):
        hn = L.layernorm(h, p["ln1_w"].astype(h.dtype), p["ln1_b"].astype(h.dtype))
        qkv = hn @ p["wqkv"].astype(h.dtype)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, s, nh, hd); k = k.reshape(b, s, nh, hd); v = v.reshape(b, s, nh, hd)
        ao = L.flash_attention(q, k, v, causal=True)
        h = h + ao.reshape(b, s, -1) @ p["wo"].astype(h.dtype)
        h = h + _cross_attn(h, enc_out, p, cfg)
        h = h + _ffn(h, p, "ln2_w", "ln2_b")
        kv_x = enc_out @ p["xkv"].astype(h.dtype)
        xk, xv = jnp.split(kv_x, 2, axis=-1)
        pad = smax - s
        return h, (jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(BF16),
                   jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(BF16),
                   xk.reshape(b, se, nh, hd).astype(BF16),
                   xv.reshape(b, se, nh, hd).astype(BF16))

    h, stacked = maybe_scan(body, x, params["dec_blocks"])
    cache = dict(cache)
    cache["k"], cache["v"], cache["xk"], cache["xv"] = stacked
    cache["t"] = jnp.asarray(s, jnp.int32)
    h = L.layernorm(h, params["dec_ln_w"].astype(h.dtype), params["dec_ln_b"].astype(h.dtype))
    logits = (h[:, -1] @ params["head"].astype(BF16)).astype(jnp.float32)
    return logits, cache


def decode_step(cfg: ModelConfig, params, token, cache, mesh=None):
    b = token.shape[0]
    t = cache["t"]
    hd, nh = cfg.hd, cfg.n_heads
    x = params["embed"].astype(BF16)[token[:, None]] + \
        jnp.take(params["dec_pos"], t[None], axis=0).astype(BF16)[None]

    def body(carry, inp):
        (h,) = carry
        p, idx = inp
        hn = L.layernorm(h, p["ln1_w"].astype(h.dtype), p["ln1_b"].astype(h.dtype))
        qkv = hn @ p["wqkv"].astype(h.dtype)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, 1, nh, hd)
        zero = jnp.zeros((), jnp.int32)
        t32 = t.astype(jnp.int32)
        kc = jax.lax.dynamic_update_slice(cache["k"][idx], k.reshape(b, 1, nh, hd).astype(BF16),
                                          (zero, t32, zero, zero))
        vc = jax.lax.dynamic_update_slice(cache["v"][idx], v.reshape(b, 1, nh, hd).astype(BF16),
                                          (zero, t32, zero, zero))
        h = h + L.decode_attention(q, kc, vc, t + 1).reshape(b, 1, -1) @ p["wo"].astype(h.dtype)
        # cross-attention against precomputed encoder KV
        hx = L.layernorm(h, p["lnx_w"].astype(h.dtype), p["lnx_b"].astype(h.dtype))
        qx = (hx @ p["xq"].astype(h.dtype)).reshape(b, 1, nh, hd)
        xo = L.decode_attention(qx, cache["xk"][idx], cache["xv"][idx], cache["xk"].shape[2])
        h = h + xo.reshape(b, 1, -1) @ p["xo"].astype(h.dtype)
        h = h + _ffn(h, p, "ln2_w", "ln2_b")
        return (h,), (kc, vc)

    (h,), (ks, vs) = maybe_scan(body, (x,), (params["dec_blocks"], jnp.arange(cfg.n_layers)))
    cache = dict(cache)
    cache["k"], cache["v"] = ks, vs
    cache["t"] = t + 1
    h = L.layernorm(h, params["dec_ln_w"].astype(h.dtype), params["dec_ln_b"].astype(h.dtype))
    logits = (h[:, 0] @ params["head"].astype(BF16)).astype(jnp.float32)
    return logits, cache
