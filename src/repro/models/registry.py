"""Unified model API over the four implementation families.

ModelApi exposes exactly what the launcher/dry-run needs:
  init_params / param_specs / train_loss / prefill / decode_step /
  init_cache / cache_specs / input_specs(shape_name)
with a kwargs convention: multimodal inputs (patches, frames) ride alongside
tokens and every entry has a ShapeDtypeStruct + PartitionSpec.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as sh

from . import lm, vlm, whisper
from .config import ModelConfig

# The four canonical input shapes (per-arch cells).  LM shapes are
# (seq_len, global_batch); decode shapes lower serve_step with a KV cache.
SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


@dataclasses.dataclass
class ModelApi:
    cfg: ModelConfig
    init_params: Callable
    param_specs: Callable  # (mesh) -> spec pytree
    train_loss: Callable  # (params, mesh=None, **batch) -> scalar
    prefill: Callable  # (params, cache, mesh=None, **batch) -> (logits, cache)
    decode_step: Callable  # (params, token, cache, mesh=None) -> (logits, cache)
    init_cache: Callable  # (batch, max_seq) -> cache pytree
    cache_specs: Callable  # (mesh) -> spec pytree

    def supports_shape(self, shape_name: str) -> tuple[bool, str]:
        info = SHAPES[shape_name]
        if shape_name == "long_500k" and not self.cfg.supports_long_context():
            return False, "O(S²) full attention at S=524288 is not a real configuration"
        return True, ""

    def input_specs(self, shape_name: str, mesh: Mesh) -> dict:
        """{name: (ShapeDtypeStruct, PartitionSpec)} for the lowering entry."""
        info = SHAPES[shape_name]
        cfg = self.cfg
        b, s = info["batch"], info["seq"]
        dp = sh.dp_axes(mesh) or None
        out: dict[str, Any] = {}
        if info["kind"] == "train":
            if cfg.family == "vlm":
                s_txt = s - cfg.n_patches
                out["tokens"] = (jax.ShapeDtypeStruct((b, s_txt + 1), jnp.int32), P(dp))
                out["patches"] = (
                    jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), jnp.bfloat16),
                    P(dp, None, None),
                )
            elif cfg.family == "audio":
                s_dec = s - cfg.enc_seq
                out["frames"] = (
                    jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16),
                    P(dp, None, None),
                )
                out["tokens"] = (jax.ShapeDtypeStruct((b, s_dec + 1), jnp.int32), P(dp))
            else:
                out["tokens"] = (jax.ShapeDtypeStruct((b, s + 1), jnp.int32), P(dp))
        elif info["kind"] == "prefill":
            if cfg.family == "vlm":
                s_txt = s - cfg.n_patches
                out["tokens"] = (jax.ShapeDtypeStruct((b, s_txt), jnp.int32), P(dp))
                out["patches"] = (
                    jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), jnp.bfloat16),
                    P(dp, None, None),
                )
            elif cfg.family == "audio":
                s_dec = s - cfg.enc_seq
                out["frames"] = (
                    jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16),
                    P(dp, None, None),
                )
                out["tokens"] = (jax.ShapeDtypeStruct((b, s_dec), jnp.int32), P(dp))
            else:
                out["tokens"] = (jax.ShapeDtypeStruct((b, s), jnp.int32), P(dp))
        else:  # decode
            out["token"] = (jax.ShapeDtypeStruct((b,), jnp.int32), P(dp))
        return out


def build(cfg: ModelConfig) -> ModelApi:
    if cfg.family == "audio":
        return ModelApi(
            cfg=cfg,
            init_params=lambda key: whisper.init_params(cfg, key),
            param_specs=lambda mesh: whisper.param_specs(cfg, mesh),
            train_loss=lambda params, mesh=None, **kw: whisper.train_loss(
                cfg, params, kw["frames"], kw["tokens"], mesh),
            prefill=lambda params, cache, mesh=None, **kw: whisper.prefill(
                cfg, params, kw["frames"], kw["tokens"], cache, mesh),
            decode_step=lambda params, token, cache, mesh=None: whisper.decode_step(
                cfg, params, token, cache, mesh),
            init_cache=lambda batch, max_seq: whisper.init_cache(cfg, batch, max_seq),
            cache_specs=lambda mesh: whisper.cache_specs(cfg, mesh),
        )
    if cfg.family == "vlm":
        return ModelApi(
            cfg=cfg,
            init_params=lambda key: vlm.init_params(cfg, key),
            param_specs=lambda mesh: vlm.param_specs(cfg, mesh),
            train_loss=lambda params, mesh=None, **kw: vlm.train_loss(
                cfg, params, kw["tokens"], kw["patches"], mesh),
            prefill=lambda params, cache, mesh=None, **kw: vlm.prefill(
                cfg, params, kw["tokens"], kw["patches"], cache, mesh),
            decode_step=lambda params, token, cache, mesh=None: vlm.decode_step(
                cfg, params, token, cache, mesh),
            init_cache=lambda batch, max_seq: vlm.init_cache(cfg, batch, max_seq),
            cache_specs=lambda mesh: vlm.cache_specs(cfg, mesh),
        )
    return ModelApi(
        cfg=cfg,
        init_params=lambda key: lm.init_params(cfg, key),
        param_specs=lambda mesh: lm.param_specs(cfg, mesh),
        train_loss=lambda params, mesh=None, **kw: lm.train_loss(
            cfg, params, kw["tokens"], mesh),
        prefill=lambda params, cache, mesh=None, **kw: lm.prefill(
            cfg, params, kw["tokens"], cache, mesh),
        decode_step=lambda params, token, cache, mesh=None: lm.decode_step(
            cfg, params, token, cache, mesh),
        init_cache=lambda batch, max_seq: lm.init_cache(cfg, batch, max_seq),
        cache_specs=lambda mesh: lm.cache_specs(cfg, mesh),
    )
