"""Decoder-only LM family: dense / MoE / Mamba2-SSD / Hymba-hybrid.

One implementation parameterised by ModelConfig:
  mixer = "attn"  — llama-style GQA transformer (smollm, granite, qwen1.5,
                    phi3-medium, phi-3-vision backbone, + MoE variants)
  mixer = "mamba" — attention-free Mamba2/SSD stack (mamba2-1.3b)
  mixer = "hymba" — parallel attention + SSD heads, outputs fused (hymba-1.5b)

Layers are stacked and scanned (keeps HLO size flat across 30-80 layer
configs); the block body is remat'ed at layer boundaries; losses fold the
LM head into a sequence-chunked cross-entropy so (B, S, vocab) logits are
never materialised.

Sharding: weights via distributed.sharding.weight_spec (TP on feature axes,
FSDP on the other), activations constrained per block.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as sh

from . import layers as L
from .config import ModelConfig
from .scan_util import maybe_scan

BF16 = jnp.bfloat16
CONV_K = 4  # Mamba2 depthwise conv kernel


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _norm_init(shape):
    return jnp.ones(shape, jnp.float32)


def _dense_init(key, shape, scale=None):
    fan_in = shape[0]
    # python float, NOT np.float64 — a strongly-typed numpy scalar would
    # promote the whole weight to f64 when x64 is enabled (the FHE package)
    scale = float(scale if scale is not None else 1.0 / np.sqrt(fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def init_block_params(cfg: ModelConfig, key) -> dict:
    """One layer's parameters (unstacked)."""
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.hd
    ks = jax.random.split(key, 24)
    p: dict[str, Any] = {}
    if cfg.mixer in ("attn", "hymba"):
        n_qkv = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
        p["attn"] = {
            "ln": _norm_init((d,)),
            "wqkv": _dense_init(ks[0], (d, n_qkv)),
            "wo": _dense_init(ks[1], (cfg.n_heads * hd, d)),
        }
        if cfg.qkv_bias:
            p["attn"]["bqkv"] = jnp.zeros((n_qkv,), jnp.float32)
    if cfg.mixer in ("mamba", "hymba"):
        di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
        conv_ch = di + 2 * ns
        p["mamba"] = {
            "ln": _norm_init((d,)),
            "in_proj": _dense_init(ks[2], (d, 2 * di + 2 * ns + nh)),
            "conv_w": _dense_init(ks[3], (conv_ch, CONV_K), scale=0.5),
            "conv_b": jnp.zeros((conv_ch,), jnp.float32),
            "dt_bias": jnp.zeros((nh,), jnp.float32),
            "a_log": jnp.zeros((nh,), jnp.float32),
            "d_skip": jnp.ones((nh,), jnp.float32),
            "out_norm": _norm_init((di,)),
            "out_proj": _dense_init(ks[4], (di, d)),
        }
    if cfg.d_ff == 0:  # pure-Mamba blocks have no MLP
        return p
    p["ffn_ln"] = _norm_init((d,))
    if cfg.is_moe:
        e = cfg.n_experts
        p["moe"] = {
            "router": _dense_init(ks[5], (d, e)),
            "w1": _dense_init(ks[6], (e, d, f)),
            "w2": _dense_init(ks[7], (e, f, d)),
            "w3": _dense_init(ks[8], (e, d, f)),
        }
        if cfg.n_shared_experts:
            fs = f * cfg.n_shared_experts
            p["moe"].update(
                sw1=_dense_init(ks[9], (d, fs)),
                sw2=_dense_init(ks[10], (fs, d)),
                sw3=_dense_init(ks[11], (d, fs)),
            )
    else:
        p["ffn"] = {
            "w1": _dense_init(ks[12], (d, f)),
            "w2": _dense_init(ks[13], (f, d)),
        }
        if cfg.act == "swiglu":
            p["ffn"]["w3"] = _dense_init(ks[14], (d, f))
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    kemb, khead, kblocks = jax.random.split(key, 3)
    blocks = jax.vmap(lambda k: init_block_params(cfg, k))(
        jax.random.split(kblocks, cfg.n_layers)
    )
    params = {
        "embed": _dense_init(kemb, (cfg.vocab, cfg.d_model), scale=0.02),
        "final_ln": _norm_init((cfg.d_model,)),
        "blocks": blocks,
    }
    if not cfg.tie_embeddings:
        params["head"] = _dense_init(khead, (cfg.d_model, cfg.vocab))
    return params


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------


def block_specs(cfg: ModelConfig, mesh: Mesh) -> dict:
    """PartitionSpecs matching init_block_params (stacked: leading layer dim)."""
    W = lambda shape, tp, fsdp: _stacked(sh.weight_spec(mesh, shape, tp, fsdp))
    V = lambda: _stacked(P(None))
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    p: dict[str, Any] = {}
    if cfg.mixer in ("attn", "hymba"):
        n_qkv = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
        p["attn"] = {
            "ln": V(),
            "wqkv": W((d, n_qkv), 1, 0),
            "wo": W((cfg.n_heads * hd, d), 0, 1),
        }
        if cfg.qkv_bias:
            p["attn"]["bqkv"] = _stacked(sh.weight_spec(mesh, (n_qkv,), 0, None))
    if cfg.mixer in ("mamba", "hymba"):
        di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
        p["mamba"] = {
            "ln": V(),
            "in_proj": W((d, 2 * di + 2 * ns + nh), None, 0),
            "conv_w": V(), "conv_b": V(), "dt_bias": V(),
            "a_log": V(), "d_skip": V(),
            "out_norm": V(),
            "out_proj": W((di, d), 0, 1),
        }
    if cfg.d_ff == 0:
        return p
    p["ffn_ln"] = V()
    if cfg.is_moe:
        e = cfg.n_experts
        p["moe"] = {
            "router": W((d, e), None, 0),
            "w1": _stacked(_expert_spec(mesh, (e, d, f))),
            "w2": _stacked(_expert_spec(mesh, (e, f, d))),
            "w3": _stacked(_expert_spec(mesh, (e, d, f))),
        }
        if cfg.n_shared_experts:
            fs = f * cfg.n_shared_experts
            p["moe"].update(
                sw1=W((d, fs), 1, 0), sw2=W((fs, d), 0, 1), sw3=W((d, fs), 1, 0)
            )
    else:
        p["ffn"] = {"w1": W((d, f), 1, 0), "w2": W((f, d), 0, 1)}
        if cfg.act == "swiglu":
            p["ffn"]["w3"] = W((d, f), 1, 0)
    return p


def _stacked(spec: P) -> P:
    return P(None, *spec)


def _expert_spec(mesh: Mesh, shape) -> P:
    """Experts sharded over 'model' (EP), inner dim FSDP over 'data'."""
    parts: list = [None] * len(shape)
    if sh.divisible(shape[0], mesh, "model"):
        parts[0] = "model"
    if sh.divisible(shape[1], mesh, "data"):
        parts[1] = "data"
    return P(*parts)


def param_specs(cfg: ModelConfig, mesh: Mesh) -> dict:
    specs = {
        "embed": sh.weight_spec(mesh, (cfg.vocab, cfg.d_model), 0, 1),
        "final_ln": P(None),
        "blocks": block_specs(cfg, mesh),
    }
    if not cfg.tie_embeddings:
        specs["head"] = sh.weight_spec(mesh, (cfg.d_model, cfg.vocab), 1, 0)
    return specs


# ---------------------------------------------------------------------------
# block forward
# ---------------------------------------------------------------------------


def _split_qkv(cfg: ModelConfig, qkv):
    hd = cfg.hd
    nq = cfg.n_heads * hd
    nkv = cfg.n_kv_heads * hd
    q, k, v = jnp.split(qkv, [nq, nq + nkv], axis=-1)
    b, s = q.shape[:2]
    return (
        q.reshape(b, s, cfg.n_heads, hd),
        k.reshape(b, s, cfg.n_kv_heads, hd),
        v.reshape(b, s, cfg.n_kv_heads, hd),
    )


def attn_forward(cfg: ModelConfig, p, x, positions, *, window: int):
    h = L.rmsnorm(x, p["ln"].astype(x.dtype))
    qkv = h @ p["wqkv"].astype(x.dtype)
    if "bqkv" in p:
        qkv = qkv + p["bqkv"].astype(x.dtype)
    q, k, v = _split_qkv(cfg, qkv)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    out = L.flash_attention(q, k, v, causal=True, window=window)
    b, s = x.shape[:2]
    return out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


def mamba_forward(cfg: ModelConfig, p, x, h0=None, conv0=None):
    """Returns (out, (ssm_state, conv_state))."""
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    h = L.rmsnorm(x, p["ln"].astype(x.dtype))
    zxbcdt = h @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * ns], axis=-1)
    xbc, conv_state = L.causal_conv1d(xbc, p["conv_w"], p["conv_b"], state=conv0)
    xs, b_in, c_in = jnp.split(xbc, [di, di + ns], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    bsz, s = x.shape[:2]
    xh = xs.reshape(bsz, s, nh, cfg.ssm_head_dim)
    y, h_final = L.ssd_chunked(xh, dt, p["a_log"], b_in, c_in, p["d_skip"], h0=h0)
    y = y.reshape(bsz, s, di) * jax.nn.silu(z)
    y = L.rmsnorm(y, p["out_norm"].astype(x.dtype))
    return y @ p["out_proj"].astype(x.dtype), (h_final, conv_state)


def ffn_forward(cfg: ModelConfig, p_block, x):
    if cfg.d_ff == 0:
        return jnp.zeros_like(x)
    h = L.rmsnorm(x, p_block["ffn_ln"].astype(x.dtype))
    if cfg.is_moe:
        b, s, d = h.shape
        m = p_block["moe"]
        flat = h.reshape(b * s, d)
        out, _ = L.moe_ffn(
            flat, m["router"], m["w1"], m["w2"], m["w3"],
            top_k=cfg.top_k, n_shared=cfg.n_shared_experts,
            sw1=m.get("sw1"), sw2=m.get("sw2"), sw3=m.get("sw3"),
        )
        return out.reshape(b, s, d)
    f = p_block["ffn"]
    return L.ffn(h, f["w1"].astype(x.dtype), f["w2"].astype(x.dtype),
                 f["w3"].astype(x.dtype) if "w3" in f else None, act=cfg.act)


def block_forward(cfg: ModelConfig, p_block, x, positions, mesh: Mesh | None):
    """Full-sequence block (train/prefill), no cache."""
    window = cfg.sliding_window
    if cfg.mixer == "attn":
        mix = attn_forward(cfg, p_block["attn"], x, positions, window=window)
    elif cfg.mixer == "mamba":
        mix, _ = mamba_forward(cfg, p_block["mamba"], x)
    else:  # hymba: parallel heads, mean-fused
        a = attn_forward(cfg, p_block["attn"], x, positions, window=window)
        m, _ = mamba_forward(cfg, p_block["mamba"], x)
        mix = 0.5 * (a + m)
    x = x + mix
    x = x + ffn_forward(cfg, p_block, x)
    if mesh is not None:
        x = sh.constrain(x, mesh, sh.batch_spec(mesh, 3))
    return x


# ---------------------------------------------------------------------------
# full model: train / prefill / decode
# ---------------------------------------------------------------------------


def forward_hidden(cfg: ModelConfig, params, x, positions, mesh: Mesh | None,
                   remat: bool = True):
    """Embeddings → scanned blocks → final norm (returns hidden states)."""

    def body(p_block, h):
        return block_forward(cfg, p_block, h, positions, mesh)

    if remat:
        body = jax.checkpoint(body)  # activation checkpointing at block bounds

    def scan_body(h, p_block):
        return body(p_block, h), None

    h, _ = maybe_scan(scan_body, x, params["blocks"])
    return L.rmsnorm(h, params["final_ln"].astype(x.dtype))


def embed(cfg: ModelConfig, params, tokens):
    return params["embed"].astype(BF16)[tokens]


def chunked_xent(cfg: ModelConfig, params, hidden, targets, mesh: Mesh | None,
                 chunk: int = 512):
    """Cross-entropy with the LM head folded into a scan over sequence chunks
    — (B, S, vocab) logits are never materialised at once."""
    head = (params["embed"].T if cfg.tie_embeddings else params["head"]).astype(BF16)
    b, s, d = hidden.shape
    nc = -(-s // chunk)
    pad = nc * chunk - s
    hp = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0))).reshape(b, nc, chunk, d)
    tp = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1).reshape(b, nc, chunk)

    def step(acc, inp):
        hc, tc = inp  # (B, chunk, D), (B, chunk)
        logits = (hc @ head).astype(jnp.float32)  # (B, chunk, V)
        if mesh is not None:
            logits = sh.constrain(logits, mesh, sh.batch_spec(mesh, 3))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(tc, 0)[..., None], axis=-1
        )[..., 0]
        valid = tc >= 0
        nll = jnp.where(valid, lse - gold, 0.0)
        return (acc[0] + nll.sum(), acc[1] + valid.sum(dtype=jnp.int32)), None

    (total, count), _ = maybe_scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (hp.transpose(1, 0, 2, 3), tp.transpose(1, 0, 2)),
    )
    return total / jnp.maximum(count, 1)


def train_loss(cfg: ModelConfig, params, tokens, mesh: Mesh | None = None):
    """tokens: (B, S+1) int32 — next-token xent averaged over positions."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    x = embed(cfg, params, inp)
    if mesh is not None:
        x = sh.constrain(x, mesh, sh.batch_spec(mesh, 3))
    positions = jnp.broadcast_to(jnp.arange(inp.shape[1]), inp.shape)
    h = forward_hidden(cfg, params, x, positions, mesh)
    return chunked_xent(cfg, params, h, tgt, mesh)


# --- serving -----------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """KV / SSM / conv decode state.  KV sharded (batch on data, seq on model)."""
    cache: dict[str, Any] = {"t": jnp.zeros((), jnp.int32)}
    nl = cfg.n_layers
    if cfg.mixer in ("attn", "hymba"):
        s_eff = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
        shape = (nl, batch, s_eff, cfg.n_kv_heads, cfg.hd)
        cache["k"] = jnp.zeros(shape, BF16)
        cache["v"] = jnp.zeros(shape, BF16)
    if cfg.mixer in ("mamba", "hymba"):
        cache["ssm"] = jnp.zeros(
            (nl, batch, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        )
        cache["conv"] = jnp.zeros(
            (nl, batch, CONV_K - 1, cfg.d_inner + 2 * cfg.ssm_state), BF16
        )
    return cache


def cache_specs(cfg: ModelConfig, mesh: Mesh) -> dict:
    specs: dict[str, Any] = {"t": P()}
    dp = sh.dp_axes(mesh)
    seq_ax = None if "model" in dp else "model"  # no reuse under pure-DP policy
    if cfg.mixer in ("attn", "hymba"):
        # batch over data; SEQUENCE over model (flash-decoding / SP layout)
        kv_spec = P(None, dp or None, seq_ax, None, None)
        specs["k"] = kv_spec
        specs["v"] = kv_spec
    if cfg.mixer in ("mamba", "hymba"):
        specs["ssm"] = P(None, sh.dp_axes(mesh) or None, None, None, None)
        specs["conv"] = P(None, sh.dp_axes(mesh) or None, None, None)
    return specs


def decode_step(cfg: ModelConfig, params, token, cache, mesh: Mesh | None = None):
    """token: (B,) int32 → (logits (B, V), new cache).  One autoregressive step."""
    b = token.shape[0]
    t = cache["t"]
    x = embed(cfg, params, token[:, None])  # (B, 1, D)
    positions = jnp.full((b, 1), t, jnp.int32)
    window = cfg.sliding_window

    def body(carry, inp):
        h, = carry
        p_block, idx = inp
        mix_parts = []
        new_kv = new_ssm = new_conv = None
        if cfg.mixer in ("attn", "hymba"):
            pa = p_block["attn"]
            hn = L.rmsnorm(h, pa["ln"].astype(h.dtype))
            qkv = hn @ pa["wqkv"].astype(h.dtype)
            if "bqkv" in pa:
                qkv = qkv + pa["bqkv"].astype(h.dtype)
            q, k, v = _split_qkv(cfg, qkv)
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
            s_eff = cache["k"].shape[2]
            slot = (t % s_eff if window else t).astype(jnp.int32)
            zero = jnp.zeros((), jnp.int32)
            kc = jax.lax.dynamic_update_slice(
                cache["k"][idx], k.astype(BF16), (zero, slot, zero, zero))
            vc = jax.lax.dynamic_update_slice(
                cache["v"][idx], v.astype(BF16), (zero, slot, zero, zero))
            eff_t = jnp.minimum(t + 1, s_eff) if window else t + 1
            ao = L.decode_attention(q, kc, vc, eff_t, window=0)
            mix_parts.append(ao.reshape(b, 1, -1) @ pa["wo"].astype(h.dtype))
            new_kv = (kc, vc)
        if cfg.mixer in ("mamba", "hymba"):
            pm = p_block["mamba"]
            di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
            hn = L.rmsnorm(h, pm["ln"].astype(h.dtype))
            zxbcdt = hn @ pm["in_proj"].astype(h.dtype)
            z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * ns], axis=-1)
            xbc, conv_new = L.causal_conv1d(xbc, pm["conv_w"], pm["conv_b"],
                                            state=cache["conv"][idx])
            xs, b_in, c_in = jnp.split(xbc[:, 0], [di, di + ns], axis=-1)
            dts = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + pm["dt_bias"])
            xh = xs.reshape(b, nh, cfg.ssm_head_dim)
            y, ssm_new = L.ssd_decode_step(xh, dts, pm["a_log"], b_in, c_in,
                                           pm["d_skip"], cache["ssm"][idx])
            y = y.reshape(b, 1, di) * jax.nn.silu(z)
            y = L.rmsnorm(y, pm["out_norm"].astype(h.dtype))
            mix_parts.append(y @ pm["out_proj"].astype(h.dtype))
            new_ssm, new_conv = ssm_new, conv_new
        mix = mix_parts[0] if len(mix_parts) == 1 else 0.5 * (mix_parts[0] + mix_parts[1])
        h = h + mix
        h = h + ffn_forward(cfg, p_block, h)
        outs = (new_kv[0] if new_kv else None, new_kv[1] if new_kv else None,
                new_ssm, new_conv)
        return (h,), outs

    idxs = jnp.arange(cfg.n_layers)
    (h,), stacked = maybe_scan(body, (x,), (params["blocks"], idxs))
    new_cache = dict(cache)
    if cfg.mixer in ("attn", "hymba"):
        new_cache["k"], new_cache["v"] = stacked[0], stacked[1]
    if cfg.mixer in ("mamba", "hymba"):
        new_cache["ssm"], new_cache["conv"] = stacked[2], stacked[3]
    new_cache["t"] = t + 1
    h = L.rmsnorm(h, params["final_ln"].astype(h.dtype))
    head = (params["embed"].T if cfg.tie_embeddings else params["head"]).astype(BF16)
    logits = (h[:, 0] @ head).astype(jnp.float32)
    if mesh is not None:
        logits = sh.constrain(logits, mesh, P(sh.dp_axes(mesh) or None, "model"
                                              if sh.divisible(cfg.vocab, mesh, "model") else None))
    return logits, new_cache


def prefill(cfg: ModelConfig, params, tokens, cache, mesh: Mesh | None = None):
    """Full-sequence prefill filling the KV cache; returns (last_logits, cache).

    Implemented as hidden-state forward + cache write per layer (scan).
    """
    b, s = tokens.shape
    x = embed(cfg, params, tokens)
    if mesh is not None:
        x = sh.constrain(x, mesh, sh.batch_spec(mesh, 3))
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    window = cfg.sliding_window

    def body(h, inp):
        p_block, idx = inp
        mix_parts = []
        kv_out = ssm_out = conv_out = None
        if cfg.mixer in ("attn", "hymba"):
            pa = p_block["attn"]
            hn = L.rmsnorm(h, pa["ln"].astype(h.dtype))
            qkv = hn @ pa["wqkv"].astype(h.dtype)
            if "bqkv" in pa:
                qkv = qkv + pa["bqkv"].astype(h.dtype)
            q, k, v = _split_qkv(cfg, qkv)
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
            ao = L.flash_attention(q, k, v, causal=True, window=window)
            mix_parts.append(ao.reshape(b, s, -1) @ pa["wo"].astype(h.dtype))
            s_eff = cache["k"].shape[2]
            kl, vl = k[:, -s_eff:].astype(BF16), v[:, -s_eff:].astype(BF16)
            if window and s >= s_eff:
                # ring-buffer alignment: token position p lives at slot p % w
                kl = jnp.roll(kl, s % s_eff, axis=1)
                vl = jnp.roll(vl, s % s_eff, axis=1)
            kv_out = (kl, vl)
        if cfg.mixer in ("mamba", "hymba"):
            mo, (ssm_out, conv_out) = mamba_forward(cfg, p_block["mamba"], h)
            mix_parts.append(mo)
        mix = mix_parts[0] if len(mix_parts) == 1 else 0.5 * (mix_parts[0] + mix_parts[1])
        h = h + mix
        h = h + ffn_forward(cfg, p_block, h)
        if mesh is not None:
            h = sh.constrain(h, mesh, sh.batch_spec(mesh, 3))
        return h, (kv_out[0] if kv_out else None, kv_out[1] if kv_out else None,
                   ssm_out, conv_out)

    idxs = jnp.arange(cfg.n_layers)
    h, stacked = maybe_scan(body, x, (params["blocks"], idxs))
    new_cache = dict(cache)
    if cfg.mixer in ("attn", "hymba"):
        s_eff = cache["k"].shape[2]
        pad = s_eff - min(s, s_eff)
        k_st = jnp.pad(stacked[0], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v_st = jnp.pad(stacked[1], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        new_cache["k"], new_cache["v"] = k_st, v_st
    if cfg.mixer in ("mamba", "hymba"):
        new_cache["ssm"], new_cache["conv"] = stacked[2], stacked[3]
    new_cache["t"] = jnp.asarray(s, jnp.int32)
    h = L.rmsnorm(h, params["final_ln"].astype(h.dtype))
    head = (params["embed"].T if cfg.tie_embeddings else params["head"]).astype(BF16)
    logits = (h[:, -1] @ head).astype(jnp.float32)
    return logits, new_cache
