"""Phi-3-vision backbone: phi3-mini decoder LM + stub CLIP patch embeddings.

Per the assigned-architecture rules the modality frontend is a stub —
``input_specs()`` supplies precomputed patch embeddings (B, n_patches, d_model)
which are prepended to the token embeddings.  Loss is masked to text positions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.distributed import sharding as sh

from . import layers as L
from . import lm
from .config import ModelConfig
from .lm import BF16
from .scan_util import maybe_scan


init_params = lm.init_params
param_specs = lm.param_specs
init_cache = lm.init_cache
cache_specs = lm.cache_specs
decode_step = lm.decode_step  # decoding past the image tokens is plain LM


def train_loss(cfg: ModelConfig, params, tokens, patches, mesh: Mesh | None = None):
    """tokens: (B, S_txt+1) int32; patches: (B, n_patches, D) stub embeddings."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    b, s_txt = inp.shape
    tok_emb = lm.embed(cfg, params, inp)
    x = jnp.concatenate([patches.astype(BF16), tok_emb], axis=1)
    if mesh is not None:
        x = sh.constrain(x, mesh, sh.batch_spec(mesh, 3))
    s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = lm.forward_hidden(cfg, params, x, positions, mesh)
    # next-token loss over the text region only
    h_txt = h[:, patches.shape[1]:]
    return lm.chunked_xent(cfg, params, h_txt, tgt, mesh)


def prefill(cfg: ModelConfig, params, tokens, patches, cache, mesh=None):
    """Prefill over (image patches + prompt tokens)."""
    b, s_txt = tokens.shape
    tok_emb = lm.embed(cfg, params, tokens)
    x = jnp.concatenate([patches.astype(BF16), tok_emb], axis=1)
    # reuse the LM prefill by substituting embeddings: build a token path that
    # injects x directly (lm.prefill embeds internally, so we inline its body
    # via the embedding hook below).
    return _prefill_embedded(cfg, params, x, cache, mesh)


def _prefill_embedded(cfg: ModelConfig, params, x, cache, mesh):
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(h, inp):
        p_block, idx = inp
        pa = p_block["attn"]
        hn = L.rmsnorm(h, pa["ln"].astype(h.dtype))
        qkv = hn @ pa["wqkv"].astype(h.dtype)
        q, k, v = lm._split_qkv(cfg, qkv)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        ao = L.flash_attention(q, k, v, causal=True)
        h = h + ao.reshape(b, s, -1) @ pa["wo"].astype(h.dtype)
        h = h + lm.ffn_forward(cfg, p_block, h)
        if mesh is not None:
            h = sh.constrain(h, mesh, sh.batch_spec(mesh, 3))
        smax = cache["k"].shape[2]
        pad = smax - s
        return h, (jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(BF16),
                   jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(BF16))

    h, (ks, vs) = maybe_scan(body, x, (params["blocks"], jnp.arange(cfg.n_layers)))
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = ks, vs
    new_cache["t"] = jnp.asarray(s, jnp.int32)
    h = L.rmsnorm(h, params["final_ln"].astype(h.dtype))
    head = (params["embed"].T if cfg.tie_embeddings else params["head"]).astype(BF16)
    logits = (h[:, -1] @ head).astype(jnp.float32)
    return logits, new_cache
