"""maybe_scan: lax.scan that can be globally unrolled into a python loop.

XLA's cost_analysis counts a while-loop body ONCE regardless of trip count, so
roofline flop/byte numbers from scanned models are undercounted.  The dry-run
therefore lowers small *probe* models under `unrolled()` — every scan becomes
a straight-line program whose costs XLA counts exactly — and reconstructs the
full-size costs from the exact polynomial structure (linear in layer count,
quadratic in sequence for attention).  See launch/dryrun.py.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax

_UNROLL: contextvars.ContextVar[bool] = contextvars.ContextVar("unroll_scans", default=False)


@contextlib.contextmanager
def unrolled():
    tok = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(tok)


def maybe_scan(f, init, xs, length: int | None = None):
    """Drop-in for jax.lax.scan(f, init, xs) honoring the unroll flag."""
    if not _UNROLL.get():
        return jax.lax.scan(f, init, xs, length=length)
    if xs is None:
        n = length
        slices = [None] * n
    else:
        leaves = jax.tree.leaves(xs)
        n = leaves[0].shape[0] if leaves else length
        slices = [jax.tree.map(lambda x: x[i], xs) for i in range(n)]
    carry = init
    ys = []
    for s in slices:
        carry, y = f(carry, s)
        ys.append(y)
    if ys and ys[0] is not None:
        import jax.numpy as jnp

        stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        stacked = None
    return carry, stacked
