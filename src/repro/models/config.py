"""Architecture configuration shared by every model family."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int  # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    # --- attention details ---
    head_dim: int = 0  # 0 ⇒ d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # 0 ⇒ full attention
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    # --- SSM (Mamba2/SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    # --- mixer layout ---
    mixer: str = "attn"  # attn | mamba | hymba (parallel attn+mamba)
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_seq: int = 0  # encoder frame count (stub frontend output length)
    # --- multimodal stub ---
    n_patches: int = 0  # vision stub patch-embedding count
    # --- norm / act ---
    act: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attn_free(self) -> bool:
        return self.mixer == "mamba"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (SSM or sliding-window)."""
        return self.mixer in ("mamba", "hymba") or self.sliding_window > 0

    def has_decoder(self) -> bool:
        return True  # all assigned archs generate tokens (whisper is enc-dec)

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_like = dataclasses.replace(self, n_experts=0, n_shared_experts=0, top_k=0)
        base = dense_like.param_count() - self.n_layers * (
            3 * d * f if self.act == "swiglu" else 2 * d * f)
        per_layer = (self.top_k + self.n_shared_experts) * 3 * d * f + d * self.n_experts
        return base + self.n_layers * per_layer

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.hd
        per_layer = 0
        if self.mixer in ("attn", "hymba"):
            qkv = d * (self.n_heads + 2 * self.n_kv_heads) * hd + d * d  # + out
            per_layer += qkv
        if self.mixer in ("mamba", "hymba"):
            di = self.d_inner
            per_layer += d * (2 * di + 2 * self.ssm_state + self.n_ssm_heads) + di * d
        if self.is_moe:
            per_layer += self.n_experts * 3 * d * f + self.n_shared_experts * 3 * d * f
            per_layer += d * self.n_experts  # router
        else:
            n_mats = 3 if self.act == "swiglu" else 2
            per_layer += n_mats * d * f
        layers = self.n_layers + self.enc_layers
        emb = v * d * (1 if self.tie_embeddings else 2)
        return layers * per_layer + emb
