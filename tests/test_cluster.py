"""Fleet-serving tests (`repro.serve.cluster`): a hypothesis property suite
over random job mixes × chip counts × router policies (work conservation,
exactly-one-chip placement, full completion, fleet-metrics merge identity),
router-policy unit behavior, heterogeneous fleets and cross-chip deep gangs
(lockstep fragments, link-cost monotonicity, gang-vs-single planning), the
warm-set cold-start model, sharded traffic seed-splitting, bursty streams,
and the `core.scheduler` fleet passthrough."""

import dataclasses
import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import serve
from repro.core import hardware as H
from repro.core import jobs as J
from repro.core import scheduler as S
from repro.serve.cluster import ROUTERS, ClusterConfig
from repro.serve.metrics import per_chip_type_utilization
from repro.serve.policy import (
    JobState,
    gang_link_bytes,
    gang_service_cycles,
    working_set_bytes,
)

# cheap presets only (service sims are memoised per (chip, workload, kind))
SHALLOW = ("matmul", "lola_mnist_plain", "dblookup")
DEEP = ("lstm",)


def _random_jobs(seed: int, n: int, deep_frac: float = 0.2) -> list:
    rng = random.Random(seed)
    jobs = []
    for i in range(n):
        pool = DEEP if rng.random() < deep_frac else SHALLOW
        jobs.append(J.make_job(rng.choice(pool), priority=rng.randint(0, 5),
                               arrival_cycle=rng.randint(0, 2_000_000), job_id=i))
    return jobs


# ---------------------------------------------------------------------------
# property suite: cluster invariants over random mixes / chips / routers
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=1, max_value=14),
       n_chips=st.integers(min_value=1, max_value=4),
       router=st.sampled_from(ROUTERS))
def test_cluster_invariants(seed, n, n_chips, router):
    """For ANY routing decision sequence: every submitted job completes, each
    job lands on exactly one chip, per-chip busy cycles equal the service
    demands placed there (work conservation, cold-start inclusive), and the
    fleet metrics are exactly the merge of the per-chip ServeResults."""
    jobs = _random_jobs(seed, n)
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=n_chips,
                                 router=router, seed=seed, validate=True)
    assert len(result.jobs) == n
    assert all(je.state is JobState.DONE for je in result.jobs)

    # exactly-one-chip placement: per-chip job sets partition the stream
    ids_per_chip = [{je.job.job_id for je in r.jobs} for r in result.chip_results]
    flat = [i for s in ids_per_chip for i in s]
    assert len(flat) == len(set(flat)) == n

    # work conservation per chip (segments == service + spill, summed)
    for r in result.chip_results:
        busy = sum(je.busy_cycles for je in r.jobs)
        owed = sum(je.service_cycles + je.spill_restore_cycles for je in r.jobs)
        assert busy == pytest.approx(owed)

    # fleet metrics ≡ merge of the per-chip timelines
    m = serve.summarize(result)
    lats = [je.turnaround for r in result.chip_results for je in r.jobs]
    queues = [je.queueing_delay for r in result.chip_results for je in r.jobs]
    assert m["n_jobs"] == n
    assert m["latency_p50_cycles"] == pytest.approx(float(np.percentile(lats, 50)))
    assert m["latency_p99_cycles"] == pytest.approx(float(np.percentile(lats, 99)))
    assert m["queue_p95_cycles"] == pytest.approx(float(np.percentile(queues, 95)))
    assert m["makespan_mcycles"] == pytest.approx(
        max(r.makespan for r in result.chip_results) / 1e6)
    assert m["queue_max_deep_cycles"] == pytest.approx(
        max((je.queueing_delay for je in result.jobs if je.kind == "deep"), default=0.0))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=1, max_value=10))
def test_cluster_single_chip_equals_engine(seed, n):
    """A 1-chip fleet with cold starts disabled is bit-identical to the plain
    single-engine path — the router adds no timing of its own."""
    jobs = _random_jobs(seed, n)
    fleet = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=1, cold_start=False)
    single = serve.serve(jobs, H.FLASH_FHE)
    assert len(fleet.jobs) == len(single.jobs)
    for a, b in zip(fleet.jobs, single.jobs):
        assert a.job is b.job
        assert a.first_start == b.first_start
        assert a.completion == b.completion
        assert a.lanes == b.lanes


# ---------------------------------------------------------------------------
# router policies
# ---------------------------------------------------------------------------


def test_round_robin_cycles_chips():
    jobs = [J.make_job("matmul", arrival_cycle=0, job_id=i) for i in range(8)]
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=4,
                                 router="round_robin", cold_start=False)
    assert result.placements == {i: i % 4 for i in range(8)}


def test_jsq_routes_around_backlog():
    """A deep job gang-blocks chip 0 for ~3.4 Mcycles; jsq must steer the
    following shallow arrivals to the empty chip."""
    jobs = [J.make_job("lstm", arrival_cycle=0, job_id=0)] + [
        J.make_job("matmul", arrival_cycle=1_000 + i, job_id=1 + i) for i in range(4)]
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2,
                                 router="jsq", cold_start=False)
    assert result.placements[0] == 0
    assert all(result.placements[j] == 1 for j in range(1, 5))


def test_po2_deterministic_and_matches_jsq_at_two_chips():
    """With n=2 the two sampled chips are always {0,1}, so power-of-two picks
    the same chip as jsq; and the router RNG is seed-reproducible."""
    jobs = _random_jobs(seed=31, n=12)
    a = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2, router="po2", seed=5)
    b = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2, router="po2", seed=5)
    assert a.placements == b.placements
    jsq = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2, router="jsq", seed=5)
    assert a.placements == jsq.placements
    for x, y in zip(a.jobs, jsq.jobs):
        assert x.completion == y.completion


def test_affinity_segregates_workloads_and_pays_cold_once():
    """Pairs of (matmul, dblookup) arriving together: after one cold start
    each, affinity keeps each workload on its warm chip (cost = backlog +
    cold penalty), so exactly 2 cold starts total and disjoint workloads."""
    jobs = []
    for k in range(6):
        jobs.append(J.make_job("matmul", arrival_cycle=k * 400_000, job_id=2 * k))
        jobs.append(J.make_job("dblookup", arrival_cycle=k * 400_000, job_id=2 * k + 1))
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2, router="affinity")
    per_chip = [{je.job.workload for je in r.jobs} for r in result.chip_results]
    assert per_chip[0] == {"matmul"} and per_chip[1] == {"dblookup"}
    m = serve.summarize(result)
    assert m["n_cold_starts"] == 2
    # the cold-start charge is the HBM cost of faulting the working set
    first = result.jobs[0]
    expect = 2.0 * working_set_bytes(first.job) / H.FLASH_FHE.hbm_bytes_per_cycle
    assert first.cold_start_cycles == pytest.approx(expect)
    assert first.service_cycles == pytest.approx(first.sim.cycles + expect)
    # warm hits are free
    assert result.jobs[2].cold_start_cycles == 0.0


def test_warm_set_eviction_under_tiny_capacity():
    """A near-zero warm-set capacity makes alternating workloads evict each
    other, so every arrival is a cold start."""
    jobs = [J.make_job(("matmul", "dblookup")[i % 2], arrival_cycle=i * 300_000, job_id=i)
            for i in range(8)]
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=1,
                                 warm_capacity_mb=1e-6)
    m = serve.summarize(result)
    assert m["n_cold_starts"] == 8
    assert all(je.cold_start_cycles > 0 for je in result.jobs)


def test_cluster_config_validation():
    with pytest.raises(ValueError):
        ClusterConfig(n_chips=0)
    with pytest.raises(ValueError):
        ClusterConfig(n_chips=2, router="least-loved")
    # config= passthrough works
    cfg = ClusterConfig(n_chips=2, router="round_robin", cold_start=False)
    jobs = [J.make_job("matmul", job_id=i) for i in range(3)]
    result = serve.serve_cluster(jobs, H.FLASH_FHE, config=cfg)
    assert result.config is cfg and result.n_chips == 2


def test_duplicate_job_ids_rejected():
    jobs = [J.make_job("matmul", job_id=7), J.make_job("dblookup", job_id=7)]
    with pytest.raises(AssertionError, match="duplicate job_id"):
        serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2)


def test_cluster_validate_catches_corrupted_placement():
    jobs = [J.make_job("matmul", arrival_cycle=0, job_id=i) for i in range(4)]
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2)
    result.chip_results[0].jobs[0].chip_index = 99
    with pytest.raises(AssertionError):
        result.validate()


# ---------------------------------------------------------------------------
# heterogeneous fleets + cross-chip deep gangs
# ---------------------------------------------------------------------------

MIXED_FLEET = [H.FLASH_FHE, H.FLASH_FHE, H.CRATERLAKE, H.F1PLUS]


def test_cluster_config_heterogeneous_normalization():
    """Bare ChipConfig entries normalize to (chip, exec_policy) pairs and
    n_chips derives from the fleet length; explicit mismatches are errors."""
    cfg = ClusterConfig(chips=tuple(MIXED_FLEET))
    assert cfg.n_chips == 4
    assert all(isinstance(c, H.ChipConfig) and p is None for c, p in cfg.chips)
    assert [c.name for c, _ in cfg.chip_pairs()] == [c.name for c in MIXED_FLEET]
    # a (chip, policy) pair passes through; None policy falls back to config's
    pol = serve.ExecPolicy(hoisting="always")
    cfg2 = ClusterConfig(chips=((H.FLASH_FHE, pol), H.CRATERLAKE))
    assert cfg2.chips[0][1] is pol and cfg2.chips[1][1] is None
    with pytest.raises(ValueError, match="disagrees"):
        ClusterConfig(n_chips=3, chips=tuple(MIXED_FLEET))
    with pytest.raises(ValueError, match="default chip"):
        ClusterConfig(n_chips=2).chip_pairs()
    with pytest.raises(ValueError):
        ClusterConfig(n_chips=2, gang_max_chips=0)
    with pytest.raises(ValueError):
        ClusterConfig(n_chips=2, link_bytes_per_cycle=0.0)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=1, max_value=12),
       router=st.sampled_from(ROUTERS),
       gang_max=st.integers(min_value=1, max_value=3))
def test_hetero_fleet_invariants(seed, n, router, gang_max):
    """The full invariant suite holds on a mixed fleet with gangs enabled:
    every job completes, non-gang jobs land on exactly one chip, gang
    fragments land on exactly their member set in lockstep, per-chip work
    conservation validates, and the fleet metrics merge cleanly."""
    jobs = _random_jobs(seed, n, deep_frac=0.35)
    result = serve.serve_cluster(jobs, chips=MIXED_FLEET, router=router,
                                 gang_max_chips=gang_max, seed=seed,
                                 validate=True)
    assert len(result.jobs) == n
    assert all(je.state is JobState.DONE for je in result.jobs)
    assert [c.name for c in result.chips] == [c.name for c in MIXED_FLEET]
    for jid, members in result.gangs.items():
        assert len(set(members)) == len(members) >= 2  # never double-book a chip
        frags = [je for r in result.chip_results for je in r.jobs
                 if je.job.job_id == jid]
        assert sorted(je.chip_index for je in frags) == sorted(members)
        comps = [je.completion for je in frags]
        assert max(comps) == pytest.approx(min(comps))  # lockstep finish
    m = serve.summarize(result)
    assert m["n_jobs"] == n
    assert m["n_gang_jobs"] == len(result.gangs)


def test_gang_link_cost_monotone_in_chips():
    """More gang members = more inter-chip traffic (bytes strictly increase
    in M) while the per-chip compute share shrinks — so per-chip service is
    compute/M plus a link term that grows toward 2·syncs·ws."""
    job = J.make_job("lstm")
    single = 3_410_688.0
    bytes_by_m = [gang_link_bytes(job, m) for m in range(1, 6)]
    assert bytes_by_m[0] == 0.0
    assert all(b2 > b1 for b1, b2 in zip(bytes_by_m, bytes_by_m[1:]))
    link_rate = 256.0
    per_chip = {m: gang_service_cycles(single, job, m, link_rate)[0]
                for m in range(1, 6)}
    assert per_chip[1] == single
    for m in range(2, 6):
        compute, link = single / m, gang_link_bytes(job, m) / link_rate
        assert per_chip[m] == pytest.approx(compute + link)
        # total fleet chip-time strictly grows with M: the split is a latency
        # trade, never free capacity
        assert m * per_chip[m] > single


def test_gang_strictly_faster_for_lone_deep_job():
    """On an idle 2×FLASH fleet the planner gangs a lone lstm across both
    chips and finishes strictly earlier than any single chip could; the
    reservation is recorded and both fragments carry the per-chip demand."""
    jobs = [J.make_job("lstm", job_id=0)]
    solo = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2, router="hetero",
                               cold_start=False)
    ganged = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2, router="hetero",
                                 gang_max_chips=2, cold_start=False)
    assert ganged.gangs == {0: (0, 1)}
    assert ganged.jobs[0].gang_size == 2
    assert ganged.jobs[0].completion < solo.jobs[0].completion
    expect_link = gang_link_bytes(jobs[0], 2) / 256.0
    assert ganged.jobs[0].link_cycles == pytest.approx(expect_link)
    assert ganged.jobs[0].completion == pytest.approx(
        solo.jobs[0].completion / 2 + expect_link)
    frags = [je for r in ganged.chip_results for je in r.jobs]
    assert len(frags) == 2
    assert "gang[" in frags[0].lanes


def test_gang_lockstep_preemption_across_chips():
    """A higher-priority shallow arrival on ONE member chip suspends the
    whole gang; both fragments record the preemption and still finish at the
    same instant (spill/restore paid per chip on its ws/M share)."""
    jobs = [J.make_job("lstm", priority=0, arrival_cycle=0, job_id=0),
            J.make_job("matmul", priority=5, arrival_cycle=500_000, job_id=1)]
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2, router="hetero",
                                 gang_max_chips=2, cold_start=False,
                                 validate=True)
    frags = [je for r in result.chip_results for je in r.jobs
             if je.job.job_id == 0]
    assert len(frags) == 2
    assert all(je.n_preemptions == 1 for je in frags)
    assert frags[0].completion == pytest.approx(frags[1].completion)
    half_ws = working_set_bytes(jobs[0]) / 2
    expect_spill = 2.0 * half_ws / H.FLASH_FHE.hbm_bytes_per_cycle
    assert all(je.spill_restore_cycles == pytest.approx(expect_spill)
               for je in frags)


def test_gang_declined_when_members_busy():
    """Two back-to-back deep jobs on a 2×FLASH fleet: the first gangs, the
    second sees the gang's serial backlog on both members and the planner
    keeps it single-chip rather than queue behind the barrier."""
    jobs = [J.make_job("lstm", arrival_cycle=0, job_id=0),
            J.make_job("lstm", arrival_cycle=100_000, job_id=1)]
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=3, router="hetero",
                                 gang_max_chips=2, cold_start=False)
    assert 0 in result.gangs
    assert 1 not in result.gangs  # planner weighed queueing delay and declined
    assert result.placements[1] not in result.gangs[0]


def test_hetero_router_steers_by_chip_strength():
    """On the mixed fleet the hetero router keeps a shallow burst on the
    multi-affiliation FLASH dies and never wastes a deep job on the F1+
    (whose deep service is several× slower)."""
    shallow = [J.make_job("matmul", arrival_cycle=i * 1_000, job_id=i)
               for i in range(12)]
    deep = [J.make_job("lstm", arrival_cycle=0, job_id=100)]
    result = serve.serve_cluster(sorted(shallow + deep,
                                        key=lambda j: j.arrival_cycle),
                                 chips=MIXED_FLEET, router="hetero",
                                 cold_start=False)
    assert result.placements[100] != 3  # F1+ never picked for deep
    on_flash = sum(1 for j in shallow if result.placements[j.job_id] in (0, 1))
    assert on_flash >= 10  # the flood stays on the 8-wide dies


def test_scheduler_chips_and_gang_passthrough():
    jobs = _random_jobs(seed=11, n=8, deep_frac=0.4)
    sched = S.schedule(jobs, chips=MIXED_FLEET, router="hetero",
                       gang_max_chips=2)
    result = serve.serve_cluster(jobs, chips=MIXED_FLEET, router="hetero",
                                 gang_max_chips=2)
    assert len(sched) == len(result.jobs)
    for sj, je in zip(sched, result.jobs):
        assert sj.job is je.job
        assert sj.end_cycle == je.completion
        assert sj.chip_index == je.chip_index


# ---------------------------------------------------------------------------
# fleet metrics
# ---------------------------------------------------------------------------


def test_cluster_metrics_balance_and_tenants():
    cfg = serve.BurstyConfig(
        base=serve.PoissonConfig(rate_per_mcycle=20.0, n_jobs=24,
                                 mix=serve.traffic.SHALLOW_MIX, seed=3),
        n_bursts=2, burst_size=6, burst_mix={"matmul": 1.0})
    result = serve.serve_cluster(serve.bursty_jobs(cfg), H.FLASH_FHE, n_chips=2)
    m = serve.summarize(result)
    assert m["n_chips"] == 2 and m["n_jobs"] == 36
    assert 0.0 <= m["chip_util_min"] <= m["chip_util_mean"] <= m["chip_util_max"] <= 1.0
    assert m["chip_util_imbalance"] == pytest.approx(m["chip_util_max"] - m["chip_util_min"])
    assert 0.0 < m["fairness_jain_chips"] <= 1.0
    assert 0.0 < m["fairness_jain"] <= 1.0  # two tenants (background + bursty)
    assert m["throughput_jobs_per_mcycle"] > 0
    # summarize dispatches on result type: explicit call agrees (NaN-aware:
    # empty percentile samples are NaN and NaN != NaN under plain ==)
    explicit = serve.summarize_cluster(result)
    assert m.keys() == explicit.keys()
    assert all(v == explicit[k] or (np.isnan(v) and np.isnan(explicit[k]))
               for k, v in m.items())


def test_summarize_cluster_idle_chip():
    """A chip that completes zero jobs must not poison the fleet summary:
    its utilization is 0 and every aggregate stays finite."""
    jobs = [J.make_job("matmul", job_id=0)]
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=3, router="jsq",
                                 cold_start=False)
    assert sum(len(r.jobs) for r in result.chip_results) == 1
    m = serve.summarize_cluster(result)
    assert m["n_jobs"] == 1 and m["n_chips"] == 3
    assert m["chip_util_min"] == 0.0
    assert m["chip_util_max"] > 0.0
    # no deep jobs and nothing shed: empty percentile samples are NaN (a 0.0
    # here used to read as a perfect tail and sail through p99 gates)
    assert np.isnan(m["latency_p99_deep_cycles"])
    assert m["n_completed_deep"] == 0.0
    assert np.isnan(m["time_to_shed_p99_cycles"])
    empty_sample_keys = {"latency_p99_deep_cycles", "time_to_shed_p50_cycles",
                         "time_to_shed_p99_cycles", "mttr_mcycles"}
    assert all(np.isfinite(v) for k, v in m.items() if k not in empty_sample_keys)


def test_summarize_cluster_single_chip_fleet():
    """With one chip the cross-chip balance metrics are degenerate by
    definition: Jain fairness 1.0 and zero imbalance."""
    jobs = [J.make_job("matmul", arrival_cycle=i * 50_000, job_id=i)
            for i in range(5)]
    m = serve.summarize_cluster(serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=1))
    assert m["n_chips"] == 1
    assert m["fairness_jain_chips"] == pytest.approx(1.0)
    assert m["chip_util_imbalance"] == 0.0


def test_summarize_cluster_all_cold_start():
    """Every arrival cold (alternating workloads under a near-zero warm cap):
    the cold counters cover the whole stream and the charge shows up in both
    the per-job and fleet-total views."""
    jobs = [J.make_job(("matmul", "dblookup")[i % 2], arrival_cycle=i * 300_000,
                       job_id=i) for i in range(6)]
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2,
                                 warm_capacity_mb=1e-6)
    m = serve.summarize_cluster(result)
    assert m["n_cold_starts"] == 6.0
    assert m["cold_start_mcycles"] == pytest.approx(
        sum(je.cold_start_cycles for je in result.jobs) / 1e6)
    assert m["cold_start_mcycles"] > 0


def test_summarize_cluster_gang_metrics():
    """Gang totals: one ganged lstm across 2 chips reports exactly its link
    bytes once (primary fragment) and link stalls × members in mcycles."""
    jobs = [J.make_job("lstm", job_id=0)]
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2, router="hetero",
                                 gang_max_chips=2, cold_start=False)
    m = serve.summarize_cluster(result)
    assert m["n_gang_jobs"] == 1.0
    assert m["gang_chips_mean"] == 2.0
    assert m["gang_link_bytes"] == pytest.approx(gang_link_bytes(jobs[0], 2))
    assert m["gang_link_mcycles"] == pytest.approx(
        2 * gang_link_bytes(jobs[0], 2) / 256.0 / 1e6)


def test_per_chip_type_utilization_keys_and_range():
    jobs = _random_jobs(seed=13, n=16, deep_frac=0.25)
    result = serve.serve_cluster(jobs, chips=MIXED_FLEET, router="hetero")
    by_type = per_chip_type_utilization(result)
    assert set(by_type) == {c.name for c in MIXED_FLEET}
    assert all(0.0 <= u <= 1.0 for u in by_type.values())
    # the two FLASH dies average into one entry
    assert len(by_type) == 3 < len(result.chips)


# ---------------------------------------------------------------------------
# sharded + bursty traffic (seed splitting)
# ---------------------------------------------------------------------------


def test_sharded_poisson_deterministic_and_partitioned():
    cfg = serve.PoissonConfig(rate_per_mcycle=40.0, n_jobs=64,
                              mix=serve.traffic.SHALLOW_MIX, seed=9)
    a = serve.sharded_poisson_jobs(cfg, 4)
    assert a == serve.sharded_poisson_jobs(cfg, 4)  # reproducible
    assert [len(s) for s in a] == [16, 16, 16, 16]
    ids = sorted(j.job_id for s in a for j in s)
    assert ids == list(range(64))  # contiguous partition of the id space
    # a different shard count is also reproducible (and a different split)
    b = serve.sharded_poisson_jobs(cfg, 3)
    assert b == serve.sharded_poisson_jobs(cfg, 3)
    assert [len(s) for s in b] == [22, 21, 21]
    with pytest.raises(ValueError):
        serve.sharded_poisson_jobs(cfg, 0)


def test_sharded_streams_decorrelated():
    """SeedSequence.spawn gives per-shard RNGs that are uncorrelated — no
    seed-arithmetic collisions between shards or with the parent stream."""
    cfg = serve.PoissonConfig(rate_per_mcycle=40.0, n_jobs=400,
                              mix={"matmul": 1.0}, seed=5)
    s0, s1 = serve.sharded_poisson_jobs(cfg, 2)
    gaps0 = np.diff([j.arrival_cycle for j in s0])
    gaps1 = np.diff([j.arrival_cycle for j in s1])
    n = min(len(gaps0), len(gaps1))
    corr = float(np.corrcoef(gaps0[:n], gaps1[:n])[0, 1])
    assert abs(corr) < 0.15
    assert [j.arrival_cycle for j in s0] != [j.arrival_cycle for j in s1]
    # shard 0 is NOT the parent stream replayed at half rate
    parent = serve.poisson_jobs(dataclasses.replace(
        cfg, rate_per_mcycle=cfg.rate_per_mcycle / 2, n_jobs=200))
    assert [j.arrival_cycle for j in s0] != [j.arrival_cycle for j in parent]


def test_bursty_stream_structure_and_independence():
    cfg = serve.BurstyConfig(
        base=serve.PoissonConfig(rate_per_mcycle=6.0, n_jobs=40, seed=3),
        n_bursts=4, burst_size=8, intra_gap_cycles=1_000.0,
        burst_mix={"matmul": 1.0})
    a = serve.bursty_jobs(cfg)
    assert a == serve.bursty_jobs(cfg)  # deterministic
    assert len(a) == 40 + 4 * 8
    arrivals = [j.arrival_cycle for j in a]
    assert arrivals == sorted(arrivals)
    assert len({j.job_id for j in a}) == len(a)
    burst = [j for j in a if j.tenant_id == 1]
    assert len(burst) == 32 and all(j.workload == "matmul" for j in burst)
    # split RNGs: changing the burst shape never perturbs the background draws
    slim = dataclasses.replace(cfg, burst_size=2, n_bursts=1)
    bg = [(j.workload, j.arrival_cycle) for j in a if j.tenant_id == 0]
    bg_slim = [(j.workload, j.arrival_cycle)
               for j in serve.bursty_jobs(slim) if j.tenant_id == 0]
    assert bg == bg_slim


# ---------------------------------------------------------------------------
# core.scheduler fleet passthrough
# ---------------------------------------------------------------------------


def test_scheduler_wrapper_n_chips_matches_cluster():
    jobs = _random_jobs(seed=7, n=10)
    sched = S.schedule(jobs, H.FLASH_FHE, n_chips=3, router="round_robin")
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=3, router="round_robin")
    assert len(sched) == len(result.jobs)
    for sj, je in zip(sched, result.jobs):
        assert sj.job is je.job
        assert sj.end_cycle == je.completion
        assert sj.chip_index == je.chip_index
    assert {sj.chip_index for sj in sched} <= {0, 1, 2}
