"""Unit + property tests for the two modular-arithmetic backends."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.fhe import modmath as mm


PRIMES = mm.gen_ntt_primes(30, 4, 2 << 16) + mm.gen_ntt_primes(26, 4, 2 << 16)


def test_primes_are_ntt_friendly():
    for q in PRIMES:
        assert mm.is_prime(q)
        assert (q - 1) % (2 << 16) == 0
        assert q < (1 << 31)


def test_root_of_unity_orders():
    q = PRIMES[0]
    for logn in (4, 8, 12):
        order = 2 << logn  # 2N
        w = mm.root_of_unity(order, q)
        assert pow(w, order, q) == 1
        assert pow(w, order // 2, q) == q - 1


@settings(max_examples=200, deadline=None)
@given(
    a=st.integers(0, (1 << 31) - 1),
    b=st.integers(0, (1 << 31) - 1),
    qi=st.integers(0, len(PRIMES) - 1),
)
def test_montmul_matches_u64(a, b, qi):
    q = PRIMES[qi]
    a %= q
    b %= q
    c = mm.MontConstants(q)
    au = jnp.uint32(a)
    bu = jnp.uint32(b)
    qu = jnp.uint32(q)
    qinv = jnp.uint32(c.qinv_neg)
    r2 = jnp.uint32(c.r2)
    got = int(mm.mul_mod_u32(au, bu, qu, qinv, r2))
    assert got == (a * b) % q
    # mont form roundtrip
    am = mm.to_mont_u32(au, qu, qinv, r2)
    assert int(mm.from_mont_u32(am, qu, qinv)) == a
    # montmul with mont-form twiddle equals plain product
    bm = jnp.uint32(c.to_mont_int(b))
    assert int(mm.mont_mul_u32(au, bm, qu, qinv)) == (a * b) % q


@settings(max_examples=100, deadline=None)
@given(
    a=st.integers(0, (1 << 62) - 1),
    b=st.integers(0, (1 << 62) - 1),
)
def test_mulhi32(a, b):
    a &= 0xFFFFFFFF
    b &= 0xFFFFFFFF
    got = int(mm.mulhi32(jnp.uint32(a), jnp.uint32(b)))
    assert got == (a * b) >> 32


def test_vectorised_backends_agree():
    rng = np.random.default_rng(0)
    q = PRIMES[1]
    c = mm.MontConstants(q)
    a = rng.integers(0, q, size=(4, 257), dtype=np.uint32)
    b = rng.integers(0, q, size=(4, 257), dtype=np.uint32)
    qu = jnp.uint32(q)
    got32 = mm.mul_mod_u32(jnp.asarray(a), jnp.asarray(b), qu, jnp.uint32(c.qinv_neg), jnp.uint32(c.r2))
    got64 = mm.mul_mod_u64(a, b, q)
    np.testing.assert_array_equal(np.asarray(got32, np.uint64), np.asarray(got64))
    np.testing.assert_array_equal(
        np.asarray(mm.add_mod_u32(jnp.asarray(a), jnp.asarray(b), qu), np.uint64),
        np.asarray(mm.add_mod_u64(a, b, q)),
    )
    np.testing.assert_array_equal(
        np.asarray(mm.sub_mod_u32(jnp.asarray(a), jnp.asarray(b), qu), np.uint64),
        np.asarray(mm.sub_mod_u64(a, b, q)),
    )


def test_mont_constants_array():
    arrs = mm.mont_constants_array(PRIMES)
    assert arrs["q"].dtype == np.uint32
    for i, q in enumerate(PRIMES):
        c = mm.MontConstants(q)
        assert arrs["qinv_neg"][i] == c.qinv_neg
        assert arrs["r2"][i] == c.r2
        assert (int(arrs["q"][i]) * pow(int(arrs["q"][i]), -1, 1 << 32)) % (1 << 32) == 1


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
