"""Observability suite (`repro.obs`): tracer determinism (same-seed fleet
runs export byte-identical Chrome JSON), zero-overhead disable (no events, no
timeline change), structural validity of every seam's output under chaos
(balanced B/E and async spans through preemption, crashes, gang aborts),
exporter/validator contracts on hand-built traces, metrics-registry
semantics, per-chip shed/fault attribution consistency, and the perf-history
append + trailing-median regression check."""

import json
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro import serve
from repro.core import hardware as H
from repro.core import jobs as J
from repro.core import planner as PL
from repro.core.simulator import lanes_whole_chip, simulate_stream
from repro.fhe import params as P
from repro.fhe.context import ExecPolicy
from repro.obs import (
    MetricsRegistry,
    Tracer,
    append_rows,
    check_regression,
    dumps_chrome_trace,
    load_history,
    parse_row_name,
    to_chrome_trace,
    validate_chrome_trace,
    write_chrome_trace,
)
from repro.serve.faults import FaultPlan

# cheap presets only (service sims are memoised per (chip, workload, kind))
SHALLOW = ("matmul", "lola_mnist_plain", "dblookup")
DEEP = ("lstm",)

RETRY = serve.RetryPolicy(max_attempts=3, backoff_base=1_000.0,
                          backoff_factor=2.0, backoff_cap=64_000.0)


def _random_jobs(seed: int, n: int = 24, deep_frac: float = 0.25) -> list:
    rng = random.Random(seed)
    jobs, t = [], 0
    for i in range(n):
        t += rng.randint(1_000, 40_000)
        pool = DEEP if rng.random() < deep_frac else SHALLOW
        jobs.append(J.make_job(rng.choice(pool), priority=rng.randint(0, 2),
                               arrival_cycle=t, job_id=i, tenant_id=i % 3))
    return jobs


def _faults() -> FaultPlan:
    return (FaultPlan.single_crash(chip=1, at=2.0e5, down=8.0e5)
            .merged(FaultPlan.straggler(chip=0, at=1.0e5, span=6.0e5))
            .merged(FaultPlan.flaky(chip=2, times=(3.0e5,))))


def _fleet(tracer=None, seed: int = 11, n_chips: int = 3):
    return serve.serve_cluster(_random_jobs(seed), H.FLASH_FHE,
                               n_chips=n_chips, router="jsq", seed=3,
                               gang_max_chips=2, faults=_faults(),
                               retry=RETRY, tracer=tracer)


# ---------------------------------------------------------------------------
# tracer core: disabled no-op, track interning, span balance
# ---------------------------------------------------------------------------


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    assert not tr
    tr.name_process(1, "chip")
    tr.complete("seg", 0.0, 5.0)
    tr.begin("down")
    tr.end("down")
    tr.instant("shed")
    tr.counter("backlog", {"total": 1.0})
    tr.job_begin(0, "matmul")
    tr.job_end(0, "matmul", "DONE")
    with tr.span("nested"):
        pass
    tr.dispatch_hook()("NTT")
    assert tr.events == []
    assert tr.process_names == {}
    assert tr.n_dispatches == 0


def test_track_ids_interned_per_registration_order():
    tr = Tracer()
    assert tr.track(1, "chip") == 0
    assert tr.track(1, "affiliation-0") == 1
    assert tr.track(2, "chip") == 0           # tids are per-process
    assert tr.track(1, "chip") == 0           # interned, not re-allocated
    assert tr.thread_names[(1, 1)] == "affiliation-0"


def test_span_closes_on_exception():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("route", pid=0, tid=0):
            raise RuntimeError("boom")
    assert [e["ph"] for e in tr.events] == ["B", "E"]
    assert validate_chrome_trace(to_chrome_trace(tr)) == []


def test_bound_clock_is_default_timestamp_source():
    tr = Tracer()
    t = {"now": 0.0}
    tr.bind_clock(lambda: t["now"])
    tr.instant("a")
    t["now"] = 42.0
    tr.instant("b")
    tr.instant("c", ts=7.0)                   # explicit ts wins — but note it
    assert [e["ts"] for e in tr.events] == [0.0, 42.0, 7.0]


def test_dispatch_hook_uses_dispatch_index_clock():
    tr = Tracer()
    hook = tr.dispatch_hook(pid=5)
    for op in ("NTT", "BCONV", "NTT"):
        hook(op)
    assert tr.n_dispatches == 3
    assert [(e["name"], e["ts"], e["dur"]) for e in tr.events] == [
        ("NTT", 0.0, 1.0), ("BCONV", 1.0, 1.0), ("NTT", 2.0, 1.0)]
    assert validate_chrome_trace(to_chrome_trace(tr)) == []


# ---------------------------------------------------------------------------
# exporter + validator contracts
# ---------------------------------------------------------------------------


def test_export_shape_and_metadata_first():
    tr = Tracer()
    tr.name_process(1, "chip0")
    tid = tr.track(1, "chip")
    tr.complete("seg", 10.0, 20.0, pid=1, tid=tid)
    obj = to_chrome_trace(tr)
    assert obj["metadata"] == {"clock": "sim-cycles"}
    phases = [e["ph"] for e in obj["traceEvents"]]
    assert phases[: phases.index("X")] == ["M"] * phases.index("X")
    names = [e["args"]["name"] for e in obj["traceEvents"]
             if e["ph"] == "M" and e["name"] in ("process_name", "thread_name")]
    assert names == ["chip0", "chip"]
    # canonical dumps round-trips and is stable across identical recordings
    assert json.loads(dumps_chrome_trace(tr)) == json.loads(dumps_chrome_trace(tr))


def test_validator_catches_structural_problems():
    unbalanced = Tracer()
    unbalanced.begin("down", ts=1.0, pid=1)
    assert any("unclosed" in p
               for p in validate_chrome_trace(to_chrome_trace(unbalanced)))

    negative = Tracer()
    negative.complete("seg", 10.0, 5.0, pid=1)          # end < start
    assert any("dur" in p
               for p in validate_chrome_trace(to_chrome_trace(negative)))

    crossed = Tracer()
    crossed.begin("a", ts=0.0, pid=1)
    crossed.events.append({"ph": "E", "name": "b", "ts": 1.0, "pid": 1,
                           "tid": 0})
    assert any("closes" in p
               for p in validate_chrome_trace(to_chrome_trace(crossed)))

    orphan = Tracer()
    orphan.job_end(7, "matmul", "DONE", ts=0.0)          # e before b
    assert any("async" in p
               for p in validate_chrome_trace(to_chrome_trace(orphan)))

    # the exporter's stable ts-sort repairs recording order, so non-monotone
    # timestamps can only reach the validator in an externally-built dict
    def _inst(name, ts, tid):
        return {"ph": "i", "name": name, "ts": ts, "pid": 1, "tid": tid,
                "s": "t", "args": {}}
    skewed = {"traceEvents": [_inst("late", 10.0, 0), _inst("early", 5.0, 0)]}
    assert any("monotone" in p for p in validate_chrome_trace(skewed))
    # separate tracks are independent clocks
    split = {"traceEvents": [_inst("late", 10.0, 0), _inst("early", 5.0, 1)]}
    assert validate_chrome_trace(split) == []


# ---------------------------------------------------------------------------
# seam: kernel dispatch via ExecPolicy.traced
# ---------------------------------------------------------------------------


def test_exec_policy_traced_composes_and_preserves_identity():
    seen = []
    base = ExecPolicy(dispatch_hook=seen.append)
    tr = Tracer()
    traced = base.traced(tr)
    assert traced.policy_key() == base.policy_key()   # hooks excluded from identity
    traced.dispatch_hook("NTT")
    traced.dispatch_hook("BCONV")
    assert seen == ["NTT", "BCONV"]                   # prior hook still fires
    assert [e["name"] for e in tr.events] == ["NTT", "BCONV"]
    # None / disabled tracer: the policy is returned unchanged
    assert base.traced(None) is base
    assert base.traced(Tracer(enabled=False)) is base


# ---------------------------------------------------------------------------
# seam: core simulator
# ---------------------------------------------------------------------------


def test_simulator_tracing_unchanged_cycles_and_valid_trace():
    p = P.workload_params("lola_mnist_plain")
    instrs = PL.workload_stream("lola_mnist_plain", p, mode="hw")
    chip = H.FLASH_FHE
    base = simulate_stream(instrs, chip, lanes_whole_chip(chip))
    tr = Tracer()
    traced = simulate_stream(instrs, chip, lanes_whole_chip(chip), tracer=tr)
    assert traced.cycles == base.cycles               # observation changes nothing
    assert tr.events
    # a second invocation lands on a fresh process, so per-track timestamps
    # stay monotone even though both timelines start at ts 0
    simulate_stream(instrs, chip, lanes_whole_chip(chip), tracer=tr)
    assert len({e["pid"] for e in tr.events}) == 2
    assert validate_chrome_trace(to_chrome_trace(tr)) == []


# ---------------------------------------------------------------------------
# seam: fleet serving — determinism, zero overhead, chaos validity
# ---------------------------------------------------------------------------


def test_fleet_trace_byte_identical_across_same_seed_runs(tmp_path):
    tr1, tr2 = Tracer(), Tracer()
    _fleet(tr1)
    _fleet(tr2)
    blob1, blob2 = dumps_chrome_trace(tr1), dumps_chrome_trace(tr2)
    assert blob1 == blob2
    assert validate_chrome_trace(to_chrome_trace(tr1)) == []
    path = write_chrome_trace(tr1, str(tmp_path / "fleet.json"))
    assert open(path).read() == blob1


def test_disabled_tracer_does_not_change_the_timeline():
    tr = Tracer()
    traced = _fleet(tr)
    bare = _fleet(tracer=None)
    off = _fleet(Tracer(enabled=False))
    for other in (bare, off):
        assert other.makespan == traced.makespan
        assert [(je.job.job_id, je.state, je.completion) for je in other.jobs] \
            == [(je.job.job_id, je.state, je.completion) for je in traced.jobs]
    assert traced.fault_counts == bare.fault_counts


def test_fleet_trace_covers_every_seam():
    tr = Tracer()
    res = _fleet(tr)
    names = {e["name"] for e in tr.events}
    assert {"routed", "down", "backlog_cycles"} <= names
    assert any(e["ph"] == "i" and e["name"] == "retry" for e in tr.events)
    # every job's async span opened and closed exactly once (retries reuse it)
    begins = [e["id"] for e in tr.events if e["ph"] == "b"]
    ends = [e["id"] for e in tr.events if e["ph"] == "e"]
    assert sorted(begins) == sorted(ends) == sorted(range(len(res.jobs)))
    # chips appear as processes 1..n, the router as process 0
    assert set(tr.process_names) == {0, 1, 2, 3}


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_chips=st.integers(min_value=2, max_value=4))
def test_trace_structurally_valid_under_chaos(seed, n_chips):
    """Preemption, crash-requeue, gang abort, retries — whatever the chaos
    config produces, the exported spans balance and timestamps stay monotone."""
    jobs = _random_jobs(seed, 12)
    cfg = serve.FaultConfig(seed=seed, horizon_cycles=4e6, mtbf_cycles=1.2e6,
                            mttr_cycles=2e5, transient_rate=1.0, slow_rate=0.5,
                            slow_span_cycles=3e5, slow_factor=2.0)
    tr = Tracer()
    serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=n_chips, router="jsq",
                        faults=cfg, retry=RETRY, tracer=tr)
    assert validate_chrome_trace(to_chrome_trace(tr)) == []


# ---------------------------------------------------------------------------
# metrics registry + per-chip attribution
# ---------------------------------------------------------------------------


def test_metrics_registry_semantics():
    reg = MetricsRegistry()
    c = reg.counter("serve.shed", labels=("reason", "chip"))
    c.inc(reason="timeout", chip=1)
    c.inc(2, reason="timeout", chip=2)
    c.inc(reason="token_bucket", chip=-1)
    assert c.total() == 4.0
    assert c.group_sum("reason") == {"timeout": 3.0, "token_bucket": 1.0}
    assert c.by_label("chip")["1"] == {("timeout",): 1.0}
    with pytest.raises(ValueError):
        c.inc(reason="timeout")                       # missing label
    with pytest.raises(ValueError):
        c.inc(-1.0, reason="timeout", chip=1)         # counters only go up
    assert reg.counter("serve.shed", labels=("reason", "chip")) is c
    with pytest.raises(ValueError):
        reg.counter("serve.shed", labels=("reason",))  # label-set mismatch

    g = reg.gauge("backlog")
    g.set(5.0)
    g.max(3.0)
    g.max(9.0)
    g.add(1.0)
    assert g.value() == 10.0

    h = reg.histogram("lat", buckets=(10.0, 100.0))
    for v in (5.0, 50.0, 500.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 3
    assert snap["sum"] == 555.0
    assert h.mean == 185.0
    assert reg.snapshot()["histograms"]["lat"]["count"] == 3


def test_cluster_books_live_in_metrics_and_sum_per_chip():
    res = _fleet()
    # derived views agree with each other and with validate()'s invariants
    assert sum(res.shed_reasons.values()) \
        == sum(v for c in res.shed_reasons_by_chip.values() for v in c.values())
    agg = {}
    for counts in res.fault_counts_by_chip.values():
        for k, v in counts.items():
            agg[k] = agg.get(k, 0) + v
    assert agg == res.fault_counts
    assert res.fault_counts_by_chip[1]["crashes"] == 1     # scripted plan
    assert res.fault_counts_by_chip[0]["slow_windows"] == 1
    # the registry snapshot travels on the result
    assert "serve.jobs_completed" in res.metrics["counters"]
    n_done = sum(1 for je in res.jobs if je.completion is not None)
    assert res.metrics["histograms"]["serve.turnaround_cycles"]["count"] == n_done
    res.validate()


def test_door_sheds_attributed_to_no_chip():
    jobs = _random_jobs(5, 20)
    adm = serve.AdmissionConfig(tenant_rate_per_mcycle=0.5, tenant_burst=1.0)
    res = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2, router="jsq",
                              admission=adm)
    assert res.shed_reasons.get("token_bucket", 0) > 0
    assert set(res.shed_reasons_by_chip) == {-1}           # door, not a chip
    res.validate()


# ---------------------------------------------------------------------------
# perf history
# ---------------------------------------------------------------------------


def test_parse_row_name_three_way_split():
    assert parse_row_name("cluster.shallow.jsq.chips4.p99") \
        == ("cluster", "shallow.jsq.chips4", "p99")
    assert parse_row_name("bench.metric") == ("bench", "", "metric")
    assert parse_row_name("metric") == ("metric", "", "metric")


def test_history_append_and_load_roundtrip(tmp_path):
    path = str(tmp_path / "h.json")
    assert load_history(path) == []
    n = append_rows(path, [("b.s.lat", 10.0), ("b.s.note", "text")],
                    commit="abc1234", date="2026-08-09")
    assert n == 1                                          # non-numeric skipped
    rows = load_history(path)
    assert rows == [{"bench": "b", "scenario": "s", "metric": "lat",
                     "value": 10.0, "commit": "abc1234", "date": "2026-08-09"}]
    append_rows(path, [("b.s.lat", 11.0)], commit="def", date="2026-08-10")
    assert [r["value"] for r in load_history(path)] == [10.0, 11.0]


def _rows(metric, values):
    return [{"bench": "b", "scenario": "s", "metric": metric, "value": v}
            for v in values]


def test_check_regression_median_band():
    assert check_regression(_rows("lat", [100, 102, 98, 101])) == []
    problems = check_regression(_rows("lat", [100, 102, 98, 150]))
    assert len(problems) == 1 and "b.s.lat" in problems[0]
    # symmetric: a too-good improvement is also a behaviour change
    assert check_regression(_rows("lat", [100, 102, 98, 50]))
    # single-row groups and wall-clock metrics pass vacuously
    assert check_regression(_rows("lat", [100])) == []
    assert check_regression(_rows("wall_ms", [100, 500])) == []
    assert check_regression(_rows("total_seconds", [100, 500])) == []
    # the window bounds the baseline: old outliers age out of the median
    vals = [1000] + [100] * 8 + [101]
    assert check_regression(_rows("lat", vals), window=8) == []


def test_repo_history_file_is_clean():
    """The committed BENCH_HISTORY.json must parse and pass its own gate."""
    rows = load_history("BENCH_HISTORY.json")
    assert rows, "BENCH_HISTORY.json missing or empty"
    assert check_regression(rows) == []
