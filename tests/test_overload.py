"""Overload-protection tests: the admission property suite (work conservation
with drops excluded, shed jobs never touching placements/warm-sets/backlogs,
token-bucket tenant isolation, cross-run determinism), the engine queue
timeout, event-heap compaction under mass cancellation, the NaN
empty-percentile regression, diurnal traffic determinism, and the
`core.scheduler` admission passthrough."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import serve
from repro.core import hardware as H
from repro.core import jobs as J
from repro.core import scheduler as S
from repro.serve.cluster import ROUTERS
from repro.serve.events import EventLoop
from repro.serve.metrics import _pct
from repro.serve.policy import AdmissionConfig, JobState, ServingEngine, TokenBucket

SHALLOW = ("matmul", "lola_mnist_plain", "dblookup")


def _spaced_jobs(n, gap, workload="matmul", tenant_id=0, start_id=0, start=0):
    return [J.make_job(workload, arrival_cycle=start + i * gap,
                       job_id=start_id + i, tenant_id=tenant_id)
            for i in range(n)]


def _random_jobs(seed, n, deep_frac=0.15):
    import random

    rng = random.Random(seed)
    jobs = []
    for i in range(n):
        w = "lstm" if rng.random() < deep_frac else rng.choice(SHALLOW)
        jobs.append(J.make_job(w, priority=rng.randint(0, 3),
                               arrival_cycle=rng.randint(0, 1_500_000), job_id=i))
    return jobs


# ---------------------------------------------------------------------------
# empty-percentile NaN regression (satellite: _pct must not report p99=0.0)
# ---------------------------------------------------------------------------


def test_pct_empty_sample_is_nan():
    """p99 of an empty sample used to be 0.0 — a 'perfect' tail that sails
    through any p99-must-beat-X gate.  It must be NaN (poisons comparisons)."""
    out = _pct([])
    assert set(out) == {"p50", "p95", "p99"}
    assert all(math.isnan(v) for v in out.values())
    assert all(math.isfinite(v) for v in _pct([1.0, 2.0]).values())


def test_summarize_carries_completion_counts_and_nan_tails():
    """Gates need explicit per-kind completion counts to require non-empty
    samples; a shallow-only stream reports deep p99 as NaN, count 0."""
    res = serve.serve(_spaced_jobs(4, 100_000), H.FLASH_FHE)
    m = serve.summarize(res)
    assert m["n_completed_shallow"] == 4.0 and m["n_completed_deep"] == 0.0
    assert m["n_offered"] == 4.0 and m["n_shed"] == 0.0 and m["drop_rate"] == 0.0
    assert m["goodput_frac"] == 1.0
    assert np.isnan(m["latency_p99_deep_cycles"])
    assert np.isnan(m["time_to_shed_p99_cycles"])  # nothing shed


# ---------------------------------------------------------------------------
# admission property suite (tentpole invariants over random streams)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=5_000),
       n=st.integers(min_value=1, max_value=12),
       n_chips=st.integers(min_value=1, max_value=3),
       router=st.sampled_from(ROUTERS),
       max_wait=st.sampled_from([None, 50_000.0, 500_000.0]),
       rate=st.sampled_from([None, 2.0, 50.0]),
       shed_after=st.sampled_from([None, 150_000.0, 2_000_000.0]))
def test_admission_invariants(seed, n, n_chips, router, max_wait, rate, shed_after):
    """For ANY admission policy over ANY stream/fleet/router: every job ends
    DONE or SHED (drops excluded from conservation), shed jobs never carry
    segments/completions, per-chip busy cycles equal the service demand of
    the DONE jobs placed there, backlog estimators stay non-negative, and the
    whole run is bit-deterministic across repeats."""
    jobs = _random_jobs(seed, n)
    adm = AdmissionConfig(max_wait_cycles=max_wait, tenant_rate_per_mcycle=rate,
                          shed_after_cycles=shed_after)

    def go():
        return serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=n_chips,
                                   router=router, seed=seed, validate=True,
                                   admission=adm)

    result = go()  # validate=True asserts the shed carve-outs + backlog signs
    done = [je for je in result.jobs if je.state is JobState.DONE]
    shed = [je for je in result.jobs if je.state is JobState.SHED]
    assert len(done) + len(shed) == n  # no third terminal state, no losses
    for je in shed:
        assert not je.segments and je.completion is None and je.first_start is None
        assert je.shed_cycle is not None
        assert je.time_to_shed >= 0.0
        if je.chip_index < 0:  # router shed: never placed anywhere
            assert je.job.job_id not in result.placements
    # work conservation with drops excluded: a shed job contributes zero
    # busy cycles even though the router priced (and later un-booked) it
    for r in result.chip_results:
        busy = sum(je.busy_cycles for je in r.jobs)
        owed = sum(je.service_cycles + je.spill_restore_cycles
                   for je in r.jobs if je.state is JobState.DONE)
        assert busy == pytest.approx(owed)
    assert all(v >= 0.0 for v in result.final_backlog)
    assert all(v >= 0.0 for v in result.final_backlog_serial)
    assert result.peak_backlog_cycles >= 0.0
    assert sum(result.shed_reasons.values()) == len(shed)

    repeat = go()  # same seed, same stream -> identical decisions
    assert [je.state for je in repeat.jobs] == [je.state for je in result.jobs]
    assert repeat.placements == result.placements
    assert [je.completion for je in repeat.jobs] == [je.completion for je in result.jobs]
    assert repeat.shed_reasons == result.shed_reasons


def test_reserve_sheds_at_the_door_and_bounds_backlog():
    """max_wait_cycles=0 admits only into idle capacity: every job that would
    queue sheds with reason 'reserve', and the peak backlog never exceeds what
    the admitted jobs themselves put there."""
    jobs = _spaced_jobs(24, 1_000)  # far above one chip's drain rate
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=1,
                                 admission=AdmissionConfig(max_wait_cycles=0.0))
    shed = [je for je in result.jobs if je.state is JobState.SHED]
    assert shed and result.shed_reasons == {"reserve": len(shed)}
    assert all(je.chip_index < 0 and je.time_to_shed == 0.0 for je in shed)
    protected = result.peak_backlog_cycles
    unprotected = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=1).peak_backlog_cycles
    assert protected < unprotected


def test_token_bucket_isolates_abusive_tenant():
    """A flooding tenant drains only its OWN bucket: the victim keeps (almost)
    its solo goodput, while a reserve-only policy punishes both tenants."""
    victim = _spaced_jobs(30, 80_000, tenant_id=0, start_id=0)
    flood = _spaced_jobs(400, 4_000, tenant_id=1, start_id=1_000)
    mixed = sorted(victim + flood, key=lambda j: (j.arrival_cycle, j.job_id))
    bucket = AdmissionConfig(tenant_rate_per_mcycle=15.0, tenant_burst=4.0)

    solo = serve.serve_cluster(victim, H.FLASH_FHE, n_chips=2, admission=bucket)
    solo_goodput = serve.goodput_by_tenant(solo).get(0, 0)
    assert solo_goodput == len(victim)  # victim alone is well under its rate

    flooded = serve.serve_cluster(mixed, H.FLASH_FHE, n_chips=2, admission=bucket)
    goodput = serve.goodput_by_tenant(flooded)
    drops = serve.drop_rate_by_tenant(flooded)
    assert goodput.get(0, 0) >= solo_goodput - 1  # isolation property
    assert drops[0] <= 0.05 < 0.5 <= drops[1]  # the abuser pays, not the victim
    assert flooded.shed_reasons.get("token_bucket", 0) > 0

    # contrast: a tenant-blind utilization reserve sheds whoever arrives when
    # the fleet is congested -- the flood collaterally drops victim jobs
    reserve = serve.serve_cluster(mixed, H.FLASH_FHE, n_chips=2,
                                  admission=AdmissionConfig(max_wait_cycles=50_000.0))
    assert serve.drop_rate_by_tenant(reserve)[0] > drops[0]


def test_engine_queue_timeout_sheds_stuck_jobs():
    """Jobs still QUEUED shed_after cycles past arrival shed exactly at the
    deadline (time_to_shed == shed_after); started jobs are exempt."""
    chip = H.FLASH_FHE
    n_lanes = chip.n_affiliations
    jobs = _spaced_jobs(4 * n_lanes, 0)  # one burst: lanes fill, the rest queue
    shed_after = 10_000.0
    res = serve.serve(jobs, chip, shed_after=shed_after)
    done = [je for je in res.jobs if je.state is JobState.DONE]
    shed = [je for je in res.jobs if je.state is JobState.SHED]
    assert len(done) >= n_lanes  # the first wave dispatched at arrival
    assert shed, "overflow jobs behind a full burst must hit the timeout"
    for je in shed:
        assert je.time_to_shed == pytest.approx(shed_after)
        assert not je.segments and je.completion is None
    m = serve.summarize(res)
    assert m["n_shed"] == len(shed)
    assert m["time_to_shed_p99_cycles"] == pytest.approx(shed_after)


def test_sequential_engine_purges_shed_jobs():
    """The SequentialPolicy FIFO lazily purges SHED entries: a CraterLake-style
    single-job chip under a burst with a short timeout completes some jobs,
    sheds the tail, and still validates its timeline."""
    jobs = _spaced_jobs(8, 0)
    res = serve.serve(jobs, H.CRATERLAKE, shed_after=20_000.0)
    states = {je.state for je in res.jobs}
    assert JobState.DONE in states and JobState.SHED in states
    done = [je for je in res.jobs if je.state is JobState.DONE]
    # the survivors ran back-to-back, never interleaved with shed entries
    assert all(je.completion is not None for je in done)


# ---------------------------------------------------------------------------
# event-heap compaction under mass cancellation (satellite 3)
# ---------------------------------------------------------------------------


class _CheckedLoop(EventLoop):
    """EventLoop that asserts the compaction invariant after every mutation:
    outside the compaction call itself, cancelled entries never outnumber
    live ones (beyond the 32-entry hysteresis floor)."""

    def __init__(self):
        super().__init__()
        self.max_heap = 0
        self.max_live = 0

    def _check(self):
        assert self._n_cancelled <= 32 or 2 * self._n_cancelled <= len(self._heap), (
            f"heap bloat: {self._n_cancelled} cancelled of {len(self._heap)}")
        self.max_heap = max(self.max_heap, len(self._heap))
        self.max_live = max(self.max_live, len(self._heap) - self._n_cancelled)

    def call_at(self, time, fn):
        ev = super().call_at(time, fn)
        self._check()
        return ev

    def _note_cancel(self):
        super()._note_cancel()
        self._check()


def test_heap_compacts_on_pure_cancellation_burst():
    """A mass cancellation with NO follow-up inserts (the admission-shed
    pattern) must compact immediately — O(1) amortised, not O(run length)."""
    loop = _CheckedLoop()
    events = [loop.call_at(1e9 + i, lambda: None) for i in range(5_000)]
    for ev in events[100:]:
        ev.cancel()
    assert len(loop._heap) <= 2 * 100 + 66  # 100 live survivors
    assert len(loop) == 100


def test_heap_bounded_under_mass_shedding():
    """Stress: a 10k-job burst stream on one chip with a tight queue timeout
    sheds >50% of jobs (each shed cancels its queued deadline event); the heap
    must never exceed 2x the live events (+hysteresis) at ANY point."""
    loop = _CheckedLoop()
    eng = ServingEngine(H.FLASH_FHE, loop=loop, shed_after=150_000.0)
    for job in _spaced_jobs(10_000, 2_500):  # ~3x one chip's drain rate
        eng.submit(job)
    res = eng.run()
    shed = sum(1 for je in res.jobs if je.state is JobState.SHED)
    assert shed > 5_000, f"stress stream must shed >50%, shed {shed}"
    assert loop.max_heap <= 2 * loop.max_live + 66, (
        f"heap peaked at {loop.max_heap} with only {loop.max_live} live events")


# ---------------------------------------------------------------------------
# diurnal traffic + capacity estimators
# ---------------------------------------------------------------------------


def test_diurnal_stream_is_deterministic_and_bounded():
    cfg = serve.DiurnalConfig(peak_rate_per_mcycle=10.0, period_mcycles=5.0,
                              n_periods=2.0, trough_frac=0.5, seed=9)
    a, b = serve.diurnal_jobs(cfg), serve.diurnal_jobs(cfg)
    assert [(j.job_id, j.arrival_cycle, j.workload) for j in a] == \
           [(j.job_id, j.arrival_cycle, j.workload) for j in b]
    assert all(0 <= j.arrival_cycle < cfg.horizon_cycles for j in a)
    assert [j.job_id for j in a] == list(range(len(a)))  # contiguous ids
    # the realised count tracks mean_rate x horizon (deterministic seed, so a
    # loose band is safe)
    expect = cfg.mean_rate_per_mcycle * cfg.horizon_cycles / 1e6
    assert 0.5 * expect <= len(a) <= 1.5 * expect


def test_diurnal_rate_curve_shape():
    cfg = serve.DiurnalConfig(peak_rate_per_mcycle=8.0, period_mcycles=10.0,
                              trough_frac=0.25)
    half = cfg.period_mcycles * 1e6 / 2
    assert serve.diurnal_rate(cfg, 0.0) == pytest.approx(2.0)  # trough
    assert serve.diurnal_rate(cfg, half) == pytest.approx(8.0)  # peak
    assert serve.diurnal_rate(cfg, half / 2) == pytest.approx(5.0)  # midpoint
    assert cfg.mean_rate_per_mcycle == pytest.approx(5.0)


def test_diurnal_config_validation():
    with pytest.raises(ValueError):
        serve.DiurnalConfig(peak_rate_per_mcycle=0.0)
    with pytest.raises(ValueError):
        serve.DiurnalConfig(peak_rate_per_mcycle=1.0, period_mcycles=0.0)
    with pytest.raises(ValueError):
        serve.DiurnalConfig(peak_rate_per_mcycle=1.0, trough_frac=1.5)


def test_capacity_estimators_scale_with_fleet():
    mix = {"matmul": 0.7, "lstm": 0.3}
    one = serve.mix_capacity_jobs_per_mcycle(mix, H.FLASH_FHE)
    assert one > 0.0
    fleet = serve.fleet_capacity_jobs_per_mcycle(mix, [H.FLASH_FHE] * 3)
    assert fleet == pytest.approx(3 * one)
    # a pure-shallow mix drains n_affiliations-wide, so capacity is higher
    assert serve.mix_capacity_jobs_per_mcycle({"matmul": 1.0}, H.FLASH_FHE) > one


# ---------------------------------------------------------------------------
# config validation + token bucket unit behaviour
# ---------------------------------------------------------------------------


def test_admission_config_validation():
    with pytest.raises(ValueError):
        AdmissionConfig(max_wait_cycles=-1.0)
    with pytest.raises(ValueError):
        AdmissionConfig(tenant_rate_per_mcycle=0.0)
    with pytest.raises(ValueError):
        AdmissionConfig(tenant_rate_per_mcycle=1.0, tenant_burst=0.5)
    with pytest.raises(ValueError):
        AdmissionConfig(shed_after_cycles=0.0)
    with pytest.raises(ValueError):  # cluster config type-checks the field
        serve.serve_cluster([], H.FLASH_FHE, n_chips=1, admission="reserve")


def test_token_bucket_refill_and_burst_cap():
    b = TokenBucket(rate_per_mcycle=1.0, burst=2.0)
    assert b.try_take(0.0) and b.try_take(0.0)  # starts full at burst
    assert not b.try_take(0.0)  # empty now
    assert not b.try_take(500_000.0)  # +0.5 tokens: still < 1
    assert b.try_take(1_600_000.0)  # refilled past 1
    b2 = TokenBucket(rate_per_mcycle=1.0, burst=2.0)
    b2.try_take(0.0)
    assert b2.try_take(100e6)  # refill caps at burst, not elapsed x rate
    assert b2.try_take(100e6) and not b2.try_take(100e6)


# ---------------------------------------------------------------------------
# scheduler passthrough
# ---------------------------------------------------------------------------


def test_scheduler_drops_shed_jobs_from_schedule():
    jobs = _spaced_jobs(16, 1_000)
    out = S.schedule(jobs, H.FLASH_FHE, n_chips=2,
                     admission=AdmissionConfig(max_wait_cycles=0.0))
    assert 0 < len(out) < len(jobs)  # some admitted, some shed at the door
    assert all(s.sim is not None and s.end_cycle > s.start_cycle >= 0 for s in out)
    # single-chip path threads the queue timeout through serve()
    solo = S.schedule(_spaced_jobs(24, 0), H.FLASH_FHE,
                      admission=AdmissionConfig(shed_after_cycles=10_000.0))
    assert 0 < len(solo) < 24
