"""Per-architecture smoke tests: reduced configs of the same family run one
forward/train step + one prefill/decode step on CPU, asserting shapes + no NaNs.
The full configs are exercised only via the dry-run (ShapeDtypeStruct)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry

ARCHS = configs.ARCH_IDS


def _batch_for(api, kind, b, s):
    cfg = api.cfg
    kr = jax.random.PRNGKey(7)
    if cfg.family == "vlm":
        s_txt = s - cfg.n_patches
        n = s_txt + (1 if kind == "train" else 0)
        return {
            "tokens": jax.random.randint(kr, (b, n), 0, cfg.vocab),
            "patches": jax.random.normal(kr, (b, cfg.n_patches, cfg.d_model), jnp.bfloat16),
        }
    if cfg.family == "audio":
        s_dec = s - cfg.enc_seq
        n = s_dec + (1 if kind == "train" else 0)
        return {
            "frames": jax.random.normal(kr, (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16),
            "tokens": jax.random.randint(kr, (b, n), 0, cfg.vocab),
        }
    n = s + (1 if kind == "train" else 0)
    return {"tokens": jax.random.randint(kr, (b, n), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = configs.get_config(arch, smoke=True)
    api = registry.build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    batch = _batch_for(api, "train", b=2, s=64)
    loss = jax.jit(lambda p, **kw: api.train_loss(p, **kw))(params, **batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    # untrained loss should be near ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab)) < 2.0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = configs.get_config(arch, smoke=True)
    api = registry.build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    b, s = 2, 48
    batch = _batch_for(api, "prefill", b=b, s=s)
    cache = api.init_cache(b, 64)
    logits, cache = jax.jit(lambda p, c, **kw: api.prefill(p, c, **kw))(
        params, cache, **batch)
    assert logits.shape == (b, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits2, cache = jax.jit(api.decode_step)(params, tok, cache)
    assert logits2.shape == (b, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits2)))
    # vlm counts patch positions in t; whisper counts decoder positions only
    expected_t = (s - cfg.enc_seq if cfg.family == "audio" else s) + 1
    assert int(cache["t"]) == expected_t


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-1.3b", "hymba-1.5b"])
def test_prefill_decode_consistency(arch):
    """decode-after-prefill must match an all-at-once prefill (teacher forcing)."""
    cfg = configs.get_config(arch, smoke=True)
    api = registry.build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 17), 0, cfg.vocab)
    # full prefill of 17 tokens
    cache_a = api.init_cache(1, 32)
    logits_full, _ = jax.jit(lambda p, c, **kw: api.prefill(p, c, **kw))(
        params, cache_a, tokens=toks)
    # prefill 16 then decode token 17
    cache_b = api.init_cache(1, 32)
    _, cache_b = jax.jit(lambda p, c, **kw: api.prefill(p, c, **kw))(
        params, cache_b, tokens=toks[:, :16])
    logits_step, _ = jax.jit(api.decode_step)(params, toks[:, 16], cache_b)
    lf, ls = np.asarray(logits_full), np.asarray(logits_step)
    np.testing.assert_allclose(lf, ls, atol=0.55, rtol=0.15)
    # same ranking structure (argmax on near-flat random-init logits is noise)
    assert np.corrcoef(lf.ravel(), ls.ravel())[0, 1] > 0.98


def test_param_counts_match_names():
    """Full configs' parameter counts are in the ballpark their names claim."""
    expect = {
        "hymba-1.5b": (0.9e9, 2.2e9),
        "phi-3-vision-4.2b": (3.3e9, 5.2e9),
        # NOTE: the assigned spec (48L × 64 experts × d_ff 1408) totals ~29B —
        # we implement the assignment verbatim rather than HF's 27-layer card.
        "moonshot-v1-16b-a3b": (12e9, 31e9),
        "deepseek-moe-16b": (12e9, 21e9),
        "mamba2-1.3b": (0.9e9, 1.8e9),
        "smollm-135m": (0.1e9, 0.18e9),
        "granite-20b": (15e9, 26e9),
        "qwen1.5-110b": (85e9, 135e9),
        "phi3-medium-14b": (11e9, 18e9),
        "whisper-medium": (0.25e9, 1.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_long_context_support_flags():
    """long_500k runs only for sub-quadratic mixers (DESIGN.md §4)."""
    runs = {a for a in ARCHS
            if registry.build(configs.get_config(a)).supports_shape("long_500k")[0]}
    assert runs == {"mamba2-1.3b", "hymba-1.5b"}
