"""Validate dry-run artifacts (skipped until the sweep has produced records).

The sweep itself runs via ``python -m repro.launch.dryrun --arch all --shape
all [--multi-pod]`` and writes one JSON per (arch × shape × mesh) cell; these
tests assert the integrity of whatever has been produced so far and, once the
sweep is complete, the full 40-cell contract.
"""

import glob
import json
import os

import pytest

from repro import configs
from repro.models.registry import SHAPES

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def _records():
    recs = {}
    for path in glob.glob(os.path.join(DRYRUN_DIR, "*.json")):
        with open(path) as f:
            recs[os.path.basename(path)] = json.load(f)
    return recs


recs = _records()
pytestmark = pytest.mark.skipif(not recs, reason="no dry-run records yet")


def test_no_failed_cells():
    failed = {k: v.get("error", "")[:100] for k, v in recs.items()
              if v.get("status") == "FAILED"}
    assert not failed, failed


def test_record_integrity():
    for name, r in recs.items():
        assert r.get("status") in ("ok", "skipped"), name
        assert r["arch"] in configs.ARCH_IDS
        assert r["shape"] in SHAPES
        if r["status"] == "ok" and "roofline" in r:
            rl = r["roofline"]
            assert rl["dominant"] in ("compute", "memory", "collective")
            assert rl["compute_s"] >= 0 and rl["memory_s"] >= 0
            assert r["chips"] in (256, 512)


def test_skips_match_design():
    """long_500k skipped exactly for the 8 full-attention archs."""
    skipped = {(r["arch"], r["shape"]) for r in recs.values()
               if r.get("status") == "skipped"}
    for arch, shape in skipped:
        assert shape == "long_500k"
        assert arch not in ("mamba2-1.3b", "hymba-1.5b")


def test_useful_flops_ratio_sane():
    for name, r in recs.items():
        if r.get("status") == "ok" and r.get("useful_flops_ratio"):
            # HLO flops ≥ model flops is expected (attention, remat, waste);
            # a ratio over 1 would mean XLA computed less than the model math
            assert r["useful_flops_ratio"] < 1.5, (name, r["useful_flops_ratio"])


def _baseline(rs):
    return [r for r in rs if r.get("policy", "tp") == "tp" and not r.get("block_skip")]


@pytest.mark.skipif(len(recs) < 40, reason="sweep incomplete")
def test_full_single_pod_table():
    pod1 = _baseline([r for r in recs.values() if r.get("mesh") == "16x16"])
    assert len(pod1) == 40  # 10 archs × 4 shapes (hillclimb variants excluded)
    ok = [r for r in pod1 if r["status"] == "ok"]
    skipped = [r for r in pod1 if r["status"] == "skipped"]
    assert len(skipped) == 8  # long_500k for full-attention archs
    assert len(ok) == 32


@pytest.mark.skipif(
    len([r for r in recs.values() if r.get("mesh") == "pod2x16x16"]) < 40,
    reason="multi-pod sweep incomplete")
def test_full_multi_pod_pass():
    pod2 = _baseline([r for r in recs.values() if r.get("mesh") == "pod2x16x16"])
    assert len(pod2) == 40
    assert sum(1 for r in pod2 if r["status"] == "ok") == 32
    assert all(r["chips"] == 512 for r in pod2 if r["status"] == "ok")
