"""training / data / checkpoint / serving substrate tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.checkpoint import failures, manager
from repro.data import pipeline
from repro.models import registry
from repro.serving.engine import Engine, SamplerConfig
from repro.training import compress, optimizer as opt, train_step as ts


# --- optimizer ---------------------------------------------------------------


def test_adamw_reduces_loss():
    cfg = configs.get_config("smollm-135m", smoke=True)
    api = registry.build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    acfg = opt.AdamWConfig(lr_peak=3e-3, warmup_steps=5, total_steps=40)
    state = opt.init_state(params)
    corpus = pipeline.ByteCorpus(vocab=cfg.vocab)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: api.train_loss(p, tokens=batch))(params)
        params, state, gn = opt.apply_updates(acfg, params, grads, state)
        return params, state, loss

    losses = []
    for i in range(30):
        batch = jnp.asarray(corpus.batch(seed=1, step=i, batch=8, seq=32))
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses[:3] + losses[-3:]


def test_lr_schedule():
    acfg = opt.AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    assert float(opt.lr_at(acfg, 0)) < float(opt.lr_at(acfg, 9))
    assert float(opt.lr_at(acfg, 10)) == pytest.approx(1e-3, rel=0.01)
    assert float(opt.lr_at(acfg, 99)) < 1e-4


def test_grad_accumulation_equivalence():
    """microbatched gradients == full-batch gradients (linearity of mean)."""
    cfg = configs.get_config("smollm-135m", smoke=True)
    api = registry.build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    acfg = opt.AdamWConfig()
    tokens = jnp.asarray(pipeline.synthetic_lm_batch(0, 0, 8, 32, cfg.vocab))
    st1 = ts.build_train_step(api, mesh, acfg, microbatch=0)
    st4 = ts.build_train_step(api, mesh, acfg, microbatch=4)
    state = opt.init_state(params)
    p1, _, m1 = jax.jit(st1)(params, state, {"tokens": tokens})
    p4, _, m4 = jax.jit(st4)(params, state, {"tokens": tokens})
    # losses match; updated weights match to accumulation-order tolerance
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-2
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p4)
    assert max(jax.tree.leaves(d)) < 5e-3


# --- gradient compression ----------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4000), st.floats(0.01, 100.0))
def test_quantize_roundtrip_property(n, scale_mag):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(scale=scale_mag, size=(n,)), jnp.float32)
    q, s = compress.quantize(x)
    back = compress.dequantize(q, s, x.shape, x.dtype)
    err = np.abs(np.asarray(back) - np.asarray(x))
    # per-block bound: half a quantisation step of that block's absmax
    blocks = np.asarray(compress._blocked(x))
    bound = np.repeat(np.abs(blocks).max(1) / 127.0, compress.BLOCK)[: n] * 0.5 + 1e-12
    assert (err <= bound + 1e-7).all()


def test_compressed_psum_multiprocess_math():
    """Shared-scale int8 psum equals the true mean within 1/127 per block."""
    rng = np.random.default_rng(3)
    pods = 4
    gs = [rng.normal(size=(1000,)).astype(np.float32) for _ in range(pods)]
    true_mean = np.mean(gs, axis=0)
    # emulate the protocol without a mesh
    blocks = [np.asarray(compress._blocked(jnp.asarray(g))) for g in gs]
    shared = np.max([np.abs(b).max(1) for b in blocks], axis=0) / 127.0
    qs = [np.asarray(compress.quantize(jnp.asarray(g), jnp.asarray(shared))[0],
                     dtype=np.int32) for g in gs]
    q_sum = np.sum(qs, axis=0, dtype=np.int64)
    approx = np.asarray(compress.dequantize(
        jnp.asarray(q_sum / pods, jnp.float32), jnp.asarray(shared),
        (1000,), jnp.float32))
    assert np.abs(approx - true_mean).max() <= shared.max() * 0.51 + 1e-7
    assert compress.compression_ratio((1000,)) > 3.5


# --- data pipeline -----------------------------------------------------------


def test_pipeline_determinism_and_shards():
    a = pipeline.synthetic_lm_batch(1, 5, 16, 32, 1000, shard=0, n_shards=4)
    b = pipeline.synthetic_lm_batch(1, 5, 16, 32, 1000, shard=0, n_shards=4)
    np.testing.assert_array_equal(a, b)  # recomputable (straggler mitigation)
    full = pipeline.synthetic_lm_batch(1, 5, 16, 32, 1000)
    shards = [pipeline.synthetic_lm_batch(1, 5, 16, 32, 1000, shard=i, n_shards=4)
              for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(shards), full)
    c = pipeline.synthetic_lm_batch(1, 6, 16, 32, 1000)
    assert not np.array_equal(full, c)  # different step ⇒ different data
    assert full.min() >= 0 and full.max() < 1000


# --- checkpointing -----------------------------------------------------------


def test_checkpoint_roundtrip_atomic(tmp_path):
    tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "nested": {"b": np.ones((2,), np.int32)}}
    d = manager.save(str(tmp_path), 7, tree)
    assert os.path.exists(os.path.join(d, "COMMIT"))
    step, got = manager.restore(str(tmp_path))
    assert step == 7
    np.testing.assert_array_equal(got["a"], tree["a"])
    np.testing.assert_array_equal(got["nested"]["b"], tree["nested"]["b"])


def test_checkpoint_retention_and_latest(tmp_path):
    for s in (1, 2, 3, 4, 5):
        manager.save(str(tmp_path), s, {"x": np.array([s])}, keep=3)
    assert manager.latest_step(str(tmp_path)) == 5
    steps = sorted(os.listdir(tmp_path))
    assert len(steps) == 3  # retention


def test_checkpoint_ignores_uncommitted(tmp_path):
    manager.save(str(tmp_path), 1, {"x": np.array([1])})
    # simulate a torn write: step dir without COMMIT
    os.makedirs(tmp_path / "step_00000009")
    assert manager.latest_step(str(tmp_path)) == 1


def test_elastic_restore_resharding(tmp_path):
    """Restore onto a different (1-device) sharding than the writer implied."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
    manager.save(str(tmp_path), 3, tree)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    _, got = manager.restore(str(tmp_path), shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]), tree["w"])
    assert got["w"].sharding == sh["w"]


# --- failure handling --------------------------------------------------------


def test_heartbeat_failure_and_straggler_flow():
    mon = failures.HeartbeatMonitor(4, deadline=10.0, strike_limit=2)
    for h in range(4):
        mon.beat(h, now=0.0, step_time=1.0)
    mon.set_median_step_time(1.0)
    # host 2 straggles twice → quarantine; host 3 goes silent → dead
    for now in (1.0, 2.0):
        for h in (0, 1):
            mon.beat(h, now, step_time=1.0)
        mon.beat(2, now, step_time=5.0)
    rep = mon.check(now=10.5)  # hosts 0-2 beat at t=2 (alive); host 3 silent since 0
    assert rep["dead"] == [3]
    assert rep["quarantine"] == [2]
    plan = failures.plan_restart(mon, latest_ckpt_step=42)
    assert plan.restore_step == 42
    assert 3 not in plan.mesh_hosts
    # shard indices are contiguous over survivors (deterministic pipeline)
    assert sorted(plan.new_shard_of_host.values()) == list(range(3))


# --- serving engine ----------------------------------------------------------


def test_engine_generates():
    cfg = configs.get_config("smollm-135m", smoke=True)
    api = registry.build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    eng = Engine(api, params, batch=2, max_seq=64)
    prompts = np.asarray(pipeline.synthetic_lm_batch(0, 0, 2, 15, cfg.vocab))[:, :16]
    out = eng.generate(prompts, n_tokens=8)
    assert out.shape == (2, 8)
    out2 = eng.generate(prompts, n_tokens=8)
    np.testing.assert_array_equal(out, out2)  # greedy is deterministic
    out3 = eng.generate(prompts, n_tokens=8, sampler=SamplerConfig(temperature=1.0, seed=1))
    assert out3.shape == (2, 8)
