"""NTT correctness: kernel vs ref oracle vs schoolbook, shape/dtype sweeps, properties."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.fhe import modmath as mm
from repro.fhe.ntt import build_plan, galois_eval_perm, galois_coeff_map, fourstep_split
from repro.kernels.ntt import ops as ntt_ops
from repro.kernels.ntt import ref as ntt_ref

MAXN = 1 << 16
PRIMES = tuple(mm.gen_ntt_primes(30, 3, 2 * MAXN) + mm.gen_ntt_primes(26, 3, 2 * MAXN))


def rand_poly(rng, l, n):
    qs = np.array(PRIMES[:l], np.uint32).reshape(l, 1)
    return (rng.integers(0, 1 << 31, size=(l, n)) % qs).astype(np.uint32)


def test_fourstep_split():
    assert fourstep_split(1 << 16) == (256, 256)
    assert fourstep_split(1 << 14) == (128, 128)
    assert fourstep_split(1 << 11) == (16, 128)
    assert fourstep_split(1 << 12) == (32, 128)
    assert fourstep_split(1 << 15) == (128, 256)


@pytest.mark.parametrize("n", [256, 512, 1024])
def test_ref_roundtrip_and_schoolbook(n):
    rng = np.random.default_rng(n)
    plan = build_plan(n, PRIMES[:2])
    x = rand_poly(rng, 2, n)
    fw = ntt_ref.ntt_fwd_ref(jnp.asarray(x), plan)
    back = ntt_ref.ntt_inv_ref(fw, plan)
    np.testing.assert_array_equal(np.asarray(back), x)

    # ring multiplication property against O(N^2) schoolbook (single limb)
    y = rand_poly(rng, 2, n)
    fy = ntt_ref.ntt_fwd_ref(jnp.asarray(y), plan)
    q0 = int(PRIMES[0])
    prod_slots = mm.mul_mod_u64(np.asarray(fw)[0], np.asarray(fy)[0], q0)
    prod = ntt_ref.ntt_inv_ref(
        jnp.asarray(np.asarray(prod_slots, np.uint32)[None, :]), build_plan(n, PRIMES[:1])
    )
    expect = ntt_ref.negacyclic_mul_schoolbook(x[0], y[0], q0)
    np.testing.assert_array_equal(np.asarray(prod)[0].astype(np.uint64), expect)


@pytest.mark.parametrize("n", [1 << 11, 1 << 12, 1 << 13, 1 << 14, 1 << 15, 1 << 16])
def test_kernel_matches_ref_sweep(n):
    """Per-kernel shape sweep: Pallas four-step (interpret) vs uint64 oracle."""
    rng = np.random.default_rng(n)
    nl = 3 if n <= (1 << 13) else 2
    plan = build_plan(n, PRIMES[:nl])
    x = np.stack([rand_poly(rng, nl, n) for _ in range(2)])  # (B=2, L, N)
    xk = jnp.asarray(x)
    fw_k = ntt_ops.ntt_fwd(xk, plan, backend="kernel")
    fw_r = ntt_ops.ntt_fwd(xk, plan, backend="ref")
    np.testing.assert_array_equal(np.asarray(fw_k), np.asarray(fw_r))
    inv_k = ntt_ops.ntt_inv(fw_k, plan, backend="kernel")
    np.testing.assert_array_equal(np.asarray(inv_k), x)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), logn=st.sampled_from([8, 9, 10, 11]))
def test_property_linearity_and_roundtrip(seed, logn):
    """NTT is linear and invertible for random inputs (property-based)."""
    n = 1 << logn
    rng = np.random.default_rng(seed)
    plan = build_plan(n, PRIMES[:2])
    a = rand_poly(rng, 2, n)
    b = rand_poly(rng, 2, n)
    qs = np.array(PRIMES[:2], np.uint64).reshape(2, 1)
    fa = np.asarray(ntt_ref.ntt_fwd_ref(jnp.asarray(a), plan), np.uint64)
    fb = np.asarray(ntt_ref.ntt_fwd_ref(jnp.asarray(b), plan), np.uint64)
    s = ((a.astype(np.uint64) + b) % qs).astype(np.uint32)
    fs = np.asarray(ntt_ref.ntt_fwd_ref(jnp.asarray(s), plan), np.uint64)
    np.testing.assert_array_equal(fs, (fa + fb) % qs)
    back = np.asarray(ntt_ref.ntt_inv_ref(jnp.asarray(fs.astype(np.uint32)), plan))
    np.testing.assert_array_equal(back, s)


@pytest.mark.parametrize("t", [3, 5, 25, -1])
def test_galois_eval_perm_matches_coeff_map(t):
    """Automorphism in eval domain (slot permutation) ≡ coefficient-domain map."""
    n = 512
    tt = t % (2 * n)
    rng = np.random.default_rng(7)
    plan = build_plan(n, PRIMES[:1])
    q = int(PRIMES[0])
    a = rand_poly(rng, 1, n)
    # coefficient domain automorphism
    dst, neg = galois_coeff_map(n, tt)
    sa = np.zeros_like(a)
    vals = np.where(neg == 1, (q - a[0].astype(np.int64)) % q, a[0].astype(np.int64))
    sa[0, dst] = vals.astype(np.uint32)
    f_sa = np.asarray(ntt_ref.ntt_fwd_ref(jnp.asarray(sa), plan))
    # eval domain permutation
    fa = np.asarray(ntt_ref.ntt_fwd_ref(jnp.asarray(a), plan))
    perm = galois_eval_perm(n, tt)
    np.testing.assert_array_equal(f_sa[0], fa[0][perm])


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
