"""Kernel-vs-oracle tests for BConv and fused pointwise modops."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.fhe import modmath as mm
from repro.kernels.bconv import ops as bconv_ops
from repro.kernels.modops import ops as modops


PRIMES = mm.gen_ntt_primes(30, 8, 2 << 16) + mm.gen_ntt_primes(26, 8, 2 << 16)


@pytest.mark.parametrize("k,m,n", [(3, 2, 256), (8, 5, 512), (13, 7, 4096), (60, 8, 4096)])
def test_bconv_kernel_matches_ref(k, m, n):
    rng = np.random.default_rng(k * 1000 + m)
    assert k + m <= len(PRIMES) or k > 8  # reuse primes for big k
    bs = [PRIMES[i % 8] for i in range(k)]
    cs = np.array(PRIMES[8 : 8 + m], np.uint32)
    xhat = np.stack([rng.integers(0, b, size=n, dtype=np.uint32) for b in bs])
    w = np.stack([rng.integers(0, cs, dtype=np.uint32) for _ in range(k)])  # (k, m)
    got_k = bconv_ops.bconv(jnp.asarray(xhat), jnp.asarray(w), cs, backend="kernel")
    got_r = bconv_ops.bconv(jnp.asarray(xhat), jnp.asarray(w), cs, backend="ref")
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(got_r))
    # independent check against slow exact host computation on a few columns
    for col in (0, n // 2, n - 1):
        for j in range(m):
            expect = sum(int(xhat[i, col]) * int(w[i, j]) for i in range(k)) % int(cs[j])
            assert int(got_r[j, col]) == expect


@pytest.mark.parametrize("shape", [(2, 256), (3, 4096), (2, 3, 1024)])
def test_pointwise_ops_kernel_matches_ref(shape):
    rng = np.random.default_rng(42)
    l = shape[-2]
    qs = np.array(PRIMES[:l], np.uint32)
    consts = mm.mont_constants_array(qs.tolist())
    a = (rng.integers(0, 1 << 31, size=shape + (0,)[:0]).astype(np.uint64) % qs.reshape((1,) * (len(shape) - 2) + (l, 1))).astype(np.uint32)
    b = (rng.integers(0, 1 << 31, size=shape).astype(np.uint64) % qs.reshape((1,) * (len(shape) - 2) + (l, 1))).astype(np.uint32)
    a = a.reshape(shape)
    mk = modops.pointwise_mulmod(
        jnp.asarray(a), jnp.asarray(b), qs, consts["qinv_neg"], consts["r2"], backend="kernel"
    )
    mr = modops.pointwise_mulmod(jnp.asarray(a), jnp.asarray(b), qs, backend="ref")
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))
    ak = modops.pointwise_addmod(jnp.asarray(a), jnp.asarray(b), qs, backend="kernel")
    ar = modops.pointwise_addmod(jnp.asarray(a), jnp.asarray(b), qs, backend="ref")
    np.testing.assert_array_equal(np.asarray(ak), np.asarray(ar))
    sk = modops.pointwise_submod(jnp.asarray(a), jnp.asarray(b), qs, backend="kernel")
    sr = modops.pointwise_submod(jnp.asarray(a), jnp.asarray(b), qs, backend="ref")
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_bconv_exact_crt_property(seed):
    """BConv of x in basis B to C equals x + u·B for small u ≥ 0 (CRT property)."""
    rng = np.random.default_rng(seed)
    bs = PRIMES[:3]
    cs = PRIMES[8:10]
    B = int(np.prod([int(b) for b in bs], dtype=object))
    x = int(rng.integers(0, min(B, 1 << 60)))
    bhat_inv = [pow(B // b, -1, b) for b in bs]
    xhat = np.array([[x % b * bhat_inv[i] % b] for i, b in enumerate(bs)], np.uint32)
    w = np.array([[(B // b) % c for c in cs] for b in bs], np.uint32)
    got = np.asarray(bconv_ops.bconv(jnp.asarray(xhat), jnp.asarray(w), np.array(cs, np.uint32), backend="ref"))
    # exact value mod c_j must be (x + u·B) mod c_j for some 0 ≤ u < 3
    ok = False
    for u in range(len(bs)):
        if all(int(got[j, 0]) == (x + u * B) % c for j, c in enumerate(cs)):
            ok = True
            break
    assert ok
