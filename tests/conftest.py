"""Test-environment shims.

Provides a minimal deterministic fallback for ``hypothesis`` when the real
package is not installed (`pip install -e .[dev]` brings the real one).  The
fallback drives each ``@given`` test with seeded pseudo-random examples —
enough to keep the property tests meaningful and the suite collectable on a
bare runtime, while real hypothesis (shrinking, database, edge-case bias) is
used whenever available.  Only the strategy surface this repo uses is
implemented: integers / floats / sampled_from.
"""

from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types

try:
    import hypothesis  # noqa: F401  (real package wins when installed)

    # Fixed CI profile: derandomized example generation so property tests
    # (serving/cluster invariants) can never flake on a lucky-or-unlucky seed.
    hypothesis.settings.register_profile(
        "ci", deadline=None, derandomize=True, max_examples=25)
    if os.environ.get("CI"):
        hypothesis.settings.load_profile("ci")
except ModuleNotFoundError:

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng: random.Random):
            return self._draw(rng)

    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def sampled_from(seq) -> _Strategy:
        items = list(seq)
        return _Strategy(lambda r: r.choice(items))

    def booleans() -> _Strategy:
        return _Strategy(lambda r: bool(r.getrandbits(1)))

    def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10,
              unique: bool = False) -> _Strategy:
        def draw(r: random.Random):
            out: list = []
            for _ in range(200):  # rejection bound for unique draws
                if len(out) >= r.randint(min_size, max_size) and len(out) >= min_size:
                    break
                v = elements.example_from(r)
                if unique and v in out:
                    continue
                out.append(v)
            return out

        return _Strategy(draw)

    _DEFAULT_EXAMPLES = 20

    def given(*arg_strats, **kw_strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples", _DEFAULT_EXAMPLES)
                rng = random.Random(f"{fn.__module__}.{fn.__name__}")
                for _ in range(n):
                    pos = tuple(s.example_from(rng) for s in arg_strats)
                    kws = {k: s.example_from(rng) for k, s in kw_strats.items()}
                    fn(*args, *pos, **kwargs, **kws)

            wrapper._stub_max_examples = _DEFAULT_EXAMPLES
            # expose only fixture params to pytest: strategy-provided args
            # (positional prefix + keyword names) are filled by the wrapper
            params = list(inspect.signature(fn).parameters.values())
            remaining = [
                q for q in params[len(arg_strats):] if q.name not in kw_strats
            ]
            wrapper.__signature__ = inspect.Signature(remaining)
            wrapper.__dict__.pop("__wrapped__", None)
            return wrapper

        return deco

    def settings(*_args, **kw):
        def deco(fn):
            if "max_examples" in kw:
                fn._stub_max_examples = kw["max_examples"]
            return fn

        return deco

    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.integers = integers
    _strategies.floats = floats
    _strategies.sampled_from = sampled_from
    _strategies.booleans = booleans
    _strategies.lists = lists

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _strategies
    _hyp.__stub__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _strategies
