"""Bootstrapping correctness (reduced ring N=2^8; full-size runs via planner).

This is the paper's Packed Bootstrapping workload executed for real: every slot
occupied, ModRaise → CoeffToSlot → EvalMod (Chebyshev sine) → SlotToCoeff, all
rotations/relinearisations through hybrid key-switching.
"""

import numpy as np
import pytest

from repro.fhe import bootstrap as B
from repro.fhe import ops
from repro.fhe import params as P
from repro.fhe import trace
from repro.fhe.context import FheContext


@pytest.fixture(scope="module")
def btctx():
    p = P.make_params(1 << 8, 18, 1, check_security=False)
    bctx = B.build_context(p, seed=0, h=32)
    return p, bctx, FheContext(params=p, keys=bctx.keys)


@pytest.fixture(scope="module")
def boot_result(btctx):
    p, ctx, fc = btctx
    rng = np.random.default_rng(7)
    z = rng.normal(size=p.slots) * 0.4 + 1j * rng.normal(size=p.slots) * 0.4
    ct = fc.encrypt(fc.encode(z))
    att = 1 / 64.0
    ct = ops.level_drop(fc.mul_const(ct, att), 0)
    with trace.capture_trace() as t:
        out = fc.bootstrap(ctx, ct, post_scale=1 / att)
    return p, fc, z, out, list(t)


def test_bootstrap_refreshes_levels(boot_result):
    p, fc, z, out, _ = boot_result
    assert out.level >= 5, f"bootstrap must leave usable depth, got level {out.level}"


def test_bootstrap_value_correct(boot_result):
    p, fc, z, out, _ = boot_result
    got = np.asarray(fc.decrypt_decode(out))
    np.testing.assert_allclose(got, z, atol=5e-2)


def test_post_bootstrap_multiplication(boot_result):
    p, fc, z, out, _ = boot_result
    sq = fc.square(out)
    got = np.asarray(fc.decrypt_decode(sq))
    np.testing.assert_allclose(got, z * z, atol=1e-1)


def test_bootstrap_trace_structure(boot_result):
    _, _, _, _, t = boot_result
    names = [i.op for i in t]
    assert names[0] == "BOOTSTRAP_BEGIN" and names[-1] == "BOOTSTRAP_END"
    assert "MODRAISE" in names
    # the deep-workload signature: many iNTT→BConv→NTT key-switch pipelines
    assert names.count("BCONV") > 50
    assert names.count("AUTO") > 20  # rotation-heavy CtS/StC


def test_eval_mod_precision(btctx):
    """Homomorphic sine matches the numpy Chebyshev evaluation."""
    p, ctx, fc = btctx
    rng = np.random.default_rng(3)
    x = rng.uniform(-0.95, 0.95, size=p.slots)
    xct = fc.encrypt(fc.encode(x))
    basis = fc.chebyshev_basis(xct, ctx.eval_mod_degree)
    out = fc.eval_chebyshev(basis, ctx.sine_coeffs)
    want = np.polynomial.chebyshev.Chebyshev(ctx.sine_coeffs)(x)
    got = np.asarray(fc.decrypt_decode(out)).real
    np.testing.assert_allclose(got, want, atol=1e-3)


def test_force_to_exactness(btctx):
    """force_to's mul-by-one fold is value-preserving across multi-level drops."""
    p, ctx, fc = btctx
    rng = np.random.default_rng(11)
    z = rng.normal(size=p.slots) * 0.3
    ct = fc.encrypt(fc.encode(z))
    dropped = FheContext(params=p).force_to(ct, ct.level - 5, p.scale * 1.01)
    assert dropped.level == ct.level - 5
    assert dropped.scale == p.scale * 1.01
    np.testing.assert_allclose(np.asarray(fc.decrypt_decode(dropped)), z, atol=2e-3)


def test_context_precomputes_galois_union_without_overgeneration(btctx):
    """build_context stores the per-plan rotation union and keygen produced
    exactly one switching key per needed Galois element — no extras."""
    from repro.fhe import keys as K

    p, ctx, _ = btctx
    want = set()
    for plan in (*ctx.cts_plans, *ctx.stc_plans):
        want |= plan.rotations()
    assert tuple(sorted(want)) == tuple(sorted(ctx.galois_rotations))
    elements = K.galois_elements(p, ctx.galois_rotations, conjugate=True)
    assert tuple(sorted(ctx.keys.gks)) == elements
