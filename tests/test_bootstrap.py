"""Bootstrapping correctness (reduced ring N=2^8; full-size runs via planner).

This is the paper's Packed Bootstrapping workload executed for real: every slot
occupied, ModRaise → CoeffToSlot → EvalMod (Chebyshev sine) → SlotToCoeff, all
rotations/relinearisations through hybrid key-switching.
"""

import numpy as np
import pytest

from repro.fhe import bootstrap as B
from repro.fhe import ops
from repro.fhe import params as P
from repro.fhe import trace
from repro.fhe.context import FheContext


@pytest.fixture(scope="module")
def btctx():
    p = P.make_params(1 << 8, 18, 1, check_security=False)
    return p, B.build_context(p, seed=0, h=32)


@pytest.fixture(scope="module")
def boot_result(btctx):
    p, ctx = btctx
    rng = np.random.default_rng(7)
    z = rng.normal(size=p.slots) * 0.4 + 1j * rng.normal(size=p.slots) * 0.4
    ct = ops.encrypt(p, ctx.keys.pk, ops.encode(p, z))
    att = 1 / 64.0
    ct = ops.level_drop(ops.mul_const(p, ct, att), 0)
    fc = FheContext(params=p, keys=ctx.keys)
    with trace.capture_trace() as t:
        out = fc.bootstrap(ctx, ct, post_scale=1 / att)
    return p, ctx, z, out, list(t)


def test_bootstrap_refreshes_levels(boot_result):
    p, ctx, z, out, _ = boot_result
    assert out.level >= 5, f"bootstrap must leave usable depth, got level {out.level}"


def test_bootstrap_value_correct(boot_result):
    p, ctx, z, out, _ = boot_result
    got = ops.decrypt_decode(p, ctx.keys.sk, out)
    np.testing.assert_allclose(got, z, atol=5e-2)


def test_post_bootstrap_multiplication(boot_result):
    p, ctx, z, out, _ = boot_result
    sq = ops.square(p, out, ctx.keys.rlk)
    got = ops.decrypt_decode(p, ctx.keys.sk, sq)
    np.testing.assert_allclose(got, z * z, atol=1e-1)


def test_bootstrap_trace_structure(boot_result):
    _, _, _, _, t = boot_result
    names = [i.op for i in t]
    assert names[0] == "BOOTSTRAP_BEGIN" and names[-1] == "BOOTSTRAP_END"
    assert "MODRAISE" in names
    # the deep-workload signature: many iNTT→BConv→NTT key-switch pipelines
    assert names.count("BCONV") > 50
    assert names.count("AUTO") > 20  # rotation-heavy CtS/StC


def test_eval_mod_precision(btctx):
    """Homomorphic sine matches the numpy Chebyshev evaluation."""
    p, ctx = btctx
    rng = np.random.default_rng(3)
    x = rng.uniform(-0.95, 0.95, size=p.slots)
    xct = ops.encrypt(p, ctx.keys.pk, ops.encode(p, x))
    fc = FheContext(params=p, keys=ctx.keys)
    basis = fc.chebyshev_basis(xct, ctx.eval_mod_degree)
    out = fc.eval_chebyshev(basis, ctx.sine_coeffs)
    want = np.polynomial.chebyshev.Chebyshev(ctx.sine_coeffs)(x)
    got = ops.decrypt_decode(p, ctx.keys.sk, out).real
    np.testing.assert_allclose(got, want, atol=1e-3)


def test_force_to_exactness(btctx):
    """force_to's mul-by-one fold is value-preserving across multi-level drops."""
    p, ctx = btctx
    rng = np.random.default_rng(11)
    z = rng.normal(size=p.slots) * 0.3
    ct = ops.encrypt(p, ctx.keys.pk, ops.encode(p, z))
    dropped = FheContext(params=p).force_to(ct, ct.level - 5, p.scale * 1.01)
    assert dropped.level == ct.level - 5
    assert dropped.scale == p.scale * 1.01
    np.testing.assert_allclose(ops.decrypt_decode(p, ctx.keys.sk, dropped), z, atol=2e-3)


def test_context_precomputes_galois_union_without_overgeneration(btctx):
    """build_context stores the per-plan rotation union and keygen produced
    exactly one switching key per needed Galois element — no extras."""
    from repro.fhe import keys as K

    p, ctx = btctx
    want = set()
    for plan in (*ctx.cts_plans, *ctx.stc_plans):
        want |= plan.rotations()
    assert tuple(sorted(want)) == tuple(sorted(ctx.galois_rotations))
    elements = K.galois_elements(p, ctx.galois_rotations, conjugate=True)
    assert tuple(sorted(ctx.keys.gks)) == elements
