"""Fault-injection chaos suite (`repro.serve.faults`): determinism of seeded
fault plans, the turnaround identity under crash-requeue (queueing + service +
preemption + waste, nothing double-counted), per-attempt work conservation
with waste excluded, no placements on dead chips, gang lockstep-abort and
healthy-sub-fleet re-planning, bounded retries with terminal failure, and
health-aware door shedding when the whole fleet is dark."""

import math
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro import serve
from repro.core import hardware as H
from repro.core import jobs as J
from repro.serve.policy import JobState

# cheap presets only (service sims are memoised per (chip, workload, kind))
SHALLOW = ("matmul", "lola_mnist_plain", "dblookup")
DEEP = ("lstm",)

RETRY = serve.RetryPolicy(max_attempts=3, backoff_base=1_000.0,
                          backoff_factor=2.0, backoff_cap=64_000.0)


def _random_jobs(seed: int, n: int, deep_frac: float = 0.2,
                 span: int = 2_000_000) -> list:
    rng = random.Random(seed)
    jobs = []
    for i in range(n):
        pool = DEEP if rng.random() < deep_frac else SHALLOW
        jobs.append(J.make_job(rng.choice(pool), priority=rng.randint(0, 5),
                               arrival_cycle=rng.randint(0, span), job_id=i))
    return jobs


def _same_summary(a: dict, b: dict) -> bool:
    """Dict equality with NaN == NaN (empty-sample metrics are NaN)."""
    if a.keys() != b.keys():
        return False
    for k in a:
        x, y = a[k], b[k]
        if isinstance(x, float) and isinstance(y, float) \
                and math.isnan(x) and math.isnan(y):
            continue
        if x != y:
            return False
    return True


def _chaos_config(seed: int) -> serve.FaultConfig:
    return serve.FaultConfig(seed=seed, horizon_cycles=4e6,
                             mtbf_cycles=1.2e6, mttr_cycles=2e5,
                             transient_rate=1.0, slow_rate=0.5,
                             slow_span_cycles=3e5, slow_factor=2.0)


# ---------------------------------------------------------------------------
# determinism: a seeded fault run is bit-for-bit reproducible
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_chips=st.integers(min_value=2, max_value=4))
def test_seeded_fault_runs_deterministic(seed, n_chips):
    jobs = _random_jobs(seed, 12)
    cfg = _chaos_config(seed)
    runs = [serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=n_chips,
                                router="jsq", faults=cfg, retry=RETRY)
            for _ in range(2)]
    assert _same_summary(serve.summarize(runs[0]), serve.summarize(runs[1]))
    assert runs[0].placements == runs[1].placements
    assert runs[0].fault_counts == runs[1].fault_counts
    assert runs[0].downtime == runs[1].downtime
    assert [je.state for je in runs[0].jobs] == [je.state for je in runs[1].jobs]
    # the plan itself is deterministic too
    assert cfg.draw(n_chips) == cfg.draw(n_chips)


# ---------------------------------------------------------------------------
# accounting: turnaround identity + per-attempt conservation with waste split out
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_chips=st.integers(min_value=2, max_value=4),
       router=st.sampled_from(("jsq", "round_robin", "po2")))
def test_conservation_and_turnaround_identity(seed, n_chips, router):
    """Every DONE primary record satisfies
    turnaround = queueing_delay + full_service + preempted + wasted_total
    (crash-requeue spill lands in wasted, NEVER double-counted as
    preemption), and every attempt record — failed or done — conserves
    busy + remaining = service + spill + wasted."""
    jobs = _random_jobs(seed, 12)
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=n_chips,
                                 router=router, faults=_chaos_config(seed + 7),
                                 retry=RETRY)
    for je in result.jobs:
        if je.state is not JobState.DONE:
            continue
        parts = (je.queueing_delay + je.full_service_cycles
                 + je.preempted_cycles + je.wasted_total)
        assert je.turnaround == pytest.approx(parts, rel=1e-9, abs=1e-6)
        assert je.preempted_cycles >= -1e-6
        assert je.wasted_total >= 0.0
    for r in result.chip_results:
        for je in r.jobs:
            if je.state in (JobState.DONE, JobState.FAILED,
                            JobState.FAILED_TRANSIENT):
                got = je.busy_cycles + je.remaining
                want = (je.service_cycles + je.spill_restore_cycles
                        + je.wasted_cycles)
                assert got == pytest.approx(want, rel=1e-9, abs=1e-6)


def test_crash_requeue_waste_not_double_counted():
    """Regression (scheduler accounting): a crash mid-service requeues the
    job; the lost run is ``wasted_cycles`` on the dead attempt and carried
    as ``prior_wasted_cycles`` on the retry — the DONE record's
    ``preempted_cycles`` must not re-bill it."""
    job = J.make_job("matmul", arrival_cycle=0.0, job_id=0)
    base = serve.serve_cluster([job], H.FLASH_FHE, n_chips=2, router="jsq")
    svc = base.jobs[0].service_cycles
    crash = serve.FaultPlan.single_crash(chip=base.placements[0],
                                         at=0.5 * svc, down=2.0 * svc)
    result = serve.serve_cluster([job], H.FLASH_FHE, n_chips=2, router="jsq",
                                 faults=crash, retry=RETRY)
    je = result.jobs[0]
    assert je.state is JobState.DONE and je.attempts == 2
    assert result.fault_counts["crashes"] == 1
    assert result.fault_counts["retries"] == 1
    # the first half-run is waste, carried onto the fresh retry record
    assert je.prior_wasted_cycles == pytest.approx(0.5 * svc, rel=1e-6)
    assert je.wasted_cycles == 0.0  # the retry itself ran clean
    parts = (je.queueing_delay + je.full_service_cycles
             + je.preempted_cycles + je.wasted_total)
    assert je.turnaround == pytest.approx(parts, rel=1e-9)
    # preemption covers only the requeue gap (backoff + re-dispatch), not
    # the wasted half-run — double-counting would push it past the identity
    assert 0.0 <= je.preempted_cycles < je.turnaround - je.full_service_cycles


# ---------------------------------------------------------------------------
# health-aware routing: nothing runs on a dead chip
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_chips=st.integers(min_value=2, max_value=4))
def test_no_placement_during_downtime(seed, n_chips):
    jobs = _random_jobs(seed, 12)
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=n_chips,
                                 router="jsq", faults=_chaos_config(seed + 3),
                                 retry=RETRY)
    saw_downtime = False
    for i, r in enumerate(result.chip_results):
        for lo, hi in result.downtime.get(i, ()):
            saw_downtime = True
            # a crash landing on the drain instant closes a zero-width window
            assert lo <= hi
            for je in r.jobs:
                for seg in je.segments:
                    assert seg.end <= lo + 1e-6 or seg.start >= hi - 1e-6, (
                        f"job {je.job.job_id} ran [{seg.start}, {seg.end}) "
                        f"inside chip {i} downtime [{lo}, {hi})")
    # chaos config has crashes armed: at least some runs must see downtime
    # (not asserted per-example — a lucky draw can be crash-free — but the
    # windows that do exist must be well-formed, checked above)
    del saw_downtime


def test_all_dead_fleet_sheds_at_door_and_recovers():
    """With every chip dark, new arrivals shed with reason
    "no_healthy_chip"; after recovery the fleet serves again (cold)."""
    jobs = [J.make_job("matmul", arrival_cycle=t, job_id=i)
            for i, t in enumerate((1_000.0, 50_000.0, 4_000_000.0))]
    plan = serve.FaultPlan(events=tuple(
        ev for c in range(2)
        for ev in serve.FaultPlan.single_crash(chip=c, at=10_000.0,
                                               down=2_000_000.0).events))
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2, router="jsq",
                                 faults=plan, retry=RETRY)
    states = {je.job.job_id: je.state for je in result.jobs}
    assert states[1] is JobState.SHED  # arrived while the fleet was dark
    assert result.shed_reasons.get("no_healthy_chip", 0) >= 1
    assert states[2] is JobState.DONE  # post-recovery arrival served


# ---------------------------------------------------------------------------
# gang failover: lockstep abort + re-plan on the healthy sub-fleet
# ---------------------------------------------------------------------------


def _gang_fleet(**kw):
    return dict(n_chips=4, router="jsq", gang_max_chips=2, **kw)


def test_gang_lockstep_abort_and_failover():
    job = J.make_job("lstm", arrival_cycle=0.0, job_id=0)
    base = serve.serve_cluster([job], H.FLASH_FHE, **_gang_fleet())
    members = base.gangs.get(0, ())
    assert len(members) == 2, "deep job did not gang on the idle fleet"
    mid = 0.5 * base.makespan
    crash = serve.FaultPlan.single_crash(chip=members[0], at=mid,
                                         down=4.0 * base.makespan)
    result = serve.serve_cluster([job], H.FLASH_FHE, **_gang_fleet(),
                                 faults=crash, retry=RETRY)
    je = result.jobs[0]
    assert je.state is JobState.DONE and je.attempts == 2
    # lockstep abort: BOTH fragments froze at the same instant, one per chip
    aborted = [f for r in result.chip_results for f in r.jobs
               if f.state in (JobState.FAILED_TRANSIENT, JobState.FAILED)
               and f.gang_size > 1]
    assert len(aborted) == 2
    assert len({f.failed_cycle for f in aborted}) == 1
    assert sorted(f.chip_index for f in aborted) == sorted(members)
    # the healthy member's aborted progress is waste carried to the retry
    assert je.prior_wasted_cycles > 0.0
    # re-planned entirely off the dead chip
    retry_members = result.gangs.get(0, ())
    assert members[0] not in retry_members
    assert members[0] != result.placements[0]
    result.validate()


# ---------------------------------------------------------------------------
# bounded retries: attempts never exceed the policy, exhaustion is terminal
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       max_attempts=st.integers(min_value=0, max_value=3))
def test_retries_bounded_and_exhaustion_terminal(seed, max_attempts):
    """A permanent two-chip blackout forces every in-flight job through the
    retry ladder: attempts stay ≤ max_attempts + 1 everywhere, exhausted
    jobs end FAILED (counted as lost), and nothing is silently dropped."""
    rp = serve.RetryPolicy(max_attempts=max_attempts, backoff_base=1_000.0)
    jobs = _random_jobs(seed, 8, span=400_000)
    plan = serve.FaultPlan(events=tuple(
        serve.FaultEvent(at=500_000.0, chip=c, kind="crash") for c in range(2)))
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2, router="jsq",
                                 faults=plan, retry=rp)
    by_jid: dict[int, int] = {}
    for r in result.chip_results:
        for je in r.jobs:
            assert 1 <= je.attempts <= max_attempts + 1
            by_jid[je.job.job_id] = max(by_jid.get(je.job.job_id, 0), je.attempts)
    lost = 0
    for je in result.jobs:
        assert je.state in (JobState.DONE, JobState.SHED, JobState.FAILED)
        if je.state is JobState.FAILED:
            lost += 1
            assert je.attempts == by_jid[je.job.job_id]  # the LAST attempt
    assert result.fault_counts.get("jobs_lost", 0) == lost


# ---------------------------------------------------------------------------
# flaky + straggler behavior through the summary surface
# ---------------------------------------------------------------------------


def test_transient_failures_retry_to_done():
    jobs = [J.make_job("matmul", arrival_cycle=0.0, job_id=0)]
    base = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2, router="jsq")
    flaky = serve.FaultPlan.flaky(chip=base.placements[0],
                                  times=[0.5 * base.jobs[0].service_cycles])
    result = serve.serve_cluster(jobs, H.FLASH_FHE, n_chips=2, router="jsq",
                                 faults=flaky, retry=RETRY)
    je = result.jobs[0]
    assert je.state is JobState.DONE and je.attempts == 2
    assert result.fault_counts["transients"] == 1
    m = serve.summarize(result)
    assert m["n_retried_jobs"] == 1 and m["retries_total"] == 1
    assert m["n_failed"] == 0 and m["wasted_mcycles"] > 0.0


def test_straggler_window_slows_service_and_counts_waste():
    job = J.make_job("matmul", arrival_cycle=0.0, job_id=0)
    base = serve.serve_cluster([job], H.FLASH_FHE, n_chips=1, router="round_robin")
    svc = base.jobs[0].service_cycles
    slow = serve.FaultPlan.straggler(chip=0, at=0.0, span=10.0 * svc, factor=3.0)
    result = serve.serve_cluster([job], H.FLASH_FHE, n_chips=1, router="round_robin",
                                 faults=slow, retry=RETRY)
    je = result.jobs[0]
    assert je.state is JobState.DONE
    assert result.makespan > base.makespan  # the window really slowed the run
    assert je.wasted_total == pytest.approx(result.makespan - base.makespan,
                                            rel=1e-6)
    assert result.fault_counts["slow_windows"] == 1
    # availability metrics: slowdowns are not downtime
    m = serve.summarize(result)
    assert m["availability"] == 1.0 and m["downtime_mcycles"] == 0.0
