"""Hoisted rotation key-switching: bit-exactness vs ``ctx.rotate`` across
levels/dnum/rotation sets (hypothesis), dispatch-count amortisation
(β + O(1) vs k·β extended-basis NTTs), planner trace parity for the hoisted
shape, and simulator accounting."""

import collections

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import hardware as H
from repro.core import planner as PL
from repro.core.simulator import lanes_deep, simulate_stream
from repro.fhe import keys as K
from repro.fhe import keyswitch as KS
from repro.fhe import linear, ops
from repro.fhe import params as P
from repro.fhe import trace
from repro.fhe.context import ExecPolicy, FheContext
from repro.kernels import dispatch

ROTS = (1, 2, 3, 5, 7)


@pytest.fixture(scope="module", params=[1, 2, 3], ids=lambda d: f"dnum{d}")
def hset(request):
    p = P.make_params(1 << 9, 5, request.param, check_security=False)
    ks = K.full_keyset(p, seed=0, rotations=ROTS, conjugate=True)
    cr = FheContext(params=p, keys=ks, policy=ExecPolicy(backend="ref"))
    cf = FheContext(params=p, keys=ks, policy=ExecPolicy(backend="fused"))
    rng = np.random.default_rng(7)
    z = rng.normal(size=p.slots) * 0.3
    ct = cr.encrypt(cr.encode(z))
    return p, cr, cf, ct, z


def _sig(instrs, skip=()):
    return collections.Counter((i.op, i.n, i.limbs) for i in instrs if i.op not in skip)


def _ct_equal(a, b) -> bool:
    return bool(jnp.array_equal(a.c0, b.c0)) and bool(jnp.array_equal(a.c1, b.c1))


# ---------------------------------------------------------------------------
# bit-exactness: hoisted == standard, every (level, dnum, rotation set)
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(level=st.integers(min_value=1, max_value=5),
       rs=st.lists(st.sampled_from(ROTS), min_size=1, max_size=4, unique=True))
def test_group_bitexact_vs_rotate(hset, level, rs):
    p, cr, _, ct, _ = hset
    c = ops.level_drop(ct, level)
    group = cr.rotate_hoisted_group(c, tuple(rs))
    for r in rs:
        assert _ct_equal(group[r], cr.rotate(c, r)), (level, r)


def test_group_bitexact_fused_kernels(hset):
    """The batched Pallas path (ModUp + Galois-MAC + batched ModDown kernels)
    against the staged u64 oracle rotations."""
    p, cr, cf, ct, _ = hset
    for level in (p.L, max(1, p.alpha - 1)):
        c = ops.level_drop(ct, level)
        group = cf.rotate_hoisted_group(c, ROTS)
        for r in ROTS:
            assert _ct_equal(group[r], cr.rotate(c, r)), (level, r)


def test_single_hoisted_and_modes(hset):
    p, cr, _, ct, _ = hset
    std = cr.rotate(ct, 3)
    assert _ct_equal(cr.rotate_hoisted(ct, 3), std)
    assert _ct_equal(cr.with_policy(hoisting="always").rotate(ct, 3), std)
    assert _ct_equal(cr.with_policy(hoisting="auto").rotate(ct, 3), std)
    with pytest.raises(ValueError):
        cr.with_policy(hoisting="sometimes")  # modes are validated up front


def test_rotation_values_correct(hset):
    """Hoisted rotations still *rotate*: decode matches np.roll."""
    p, cr, _, ct, z = hset
    group = cr.rotate_hoisted_group(ct, (1, 5))
    for r in (1, 5):
        got = np.asarray(cr.decrypt_decode(group[r]))
        np.testing.assert_allclose(got.real, np.roll(z, -r), atol=2e-2)


def test_hoisted_digits_reused_across_calls(hset):
    """A precomputed ``HoistedDigits`` skips the ModUp entirely: only the
    ModDown's two forward NTTs remain per rotation."""
    p, cr, _, ct, _ = hset
    hd = KS.hoisted_mod_up(ct.c1, p, ct.level, backend="ref")
    with dispatch.count_dispatches() as c:
        out = cr.rotate_hoisted(ct, 2, hoisted=hd)
    assert c.get("ntt", 0) == 2 and c.get("intt", 0) == 2  # ModDown only
    assert _ct_equal(out, cr.rotate(ct, 2))


def test_hoisted_ksk_cached_per_keyset(hset):
    p, cr, _, ct, _ = hset
    ks = cr.keys
    t = pow(5, 3, 2 * p.n)
    a = KS.hoisted_ksk(p, ks, t, p.L)
    assert KS.hoisted_ksk(p, ks, t, p.L) is a
    assert (t, p.L) in ks.hoist_cache


# ---------------------------------------------------------------------------
# dispatch counts: the measurable amortisation (β + O(1) vs k·β)
# ---------------------------------------------------------------------------


def test_group_kernel_dispatches_amortised(hset):
    p, _, cf, ct, _ = hset
    k = len(ROTS)
    with dispatch.count_dispatches() as ch:
        cf.rotate_hoisted_group(ct, ROTS)
    with dispatch.count_dispatches() as cs:
        for r in ROTS:
            cf.rotate(ct, r)
    # hoisted: shared iNTT + ModUp launch + ONE batched Galois-MAC launch +
    # ONE batched ModDown (P-block iNTT + kernel) + k c0-adds
    assert ch["hoistmodup"] == 1 and ch["hoistmac"] == 1
    assert ch["fused_moddown"] == 1 and ch["intt"] == 2
    assert dispatch.total(ch) == 5 + k
    # per-rotation fused path: {iNTT, fused-KS, P-iNTT, ModDown, add} each
    assert cs["fusedks"] == k and cs["fused_moddown"] == k
    assert dispatch.total(cs) == 5 * k
    assert dispatch.total(ch) / dispatch.total(cs) <= 0.6


def test_ref_ntt_launches_beta_plus_k(hset):
    """Staged pipeline: forward-NTT launches collapse from k·(β+2) to β+2k —
    the per-rotation extended-basis NTTs disappear entirely."""
    p, cr, _, ct, _ = hset
    beta, k = p.beta(p.L), len(ROTS)
    with dispatch.count_dispatches() as ch:
        cr.rotate_hoisted_group(ct, ROTS)
    with dispatch.count_dispatches() as cs:
        for r in ROTS:
            cr.rotate(ct, r)
    assert ch["ntt"] == beta + 2 * k  # β ModUp + 2 ModDown per rotation
    assert cs["ntt"] == k * (beta + 2)


def test_ext_basis_ntt_records_beta_vs_k_beta(hset):
    """Trace-level: the group performs exactly β extended-basis forward NTTs
    (one per digit, shared), vs k·β on the per-rotation path."""
    p, cr, _, ct, _ = hset
    beta, k = p.beta(p.L), len(ROTS)
    m = p.L + 1 + p.alpha
    with trace.capture_trace() as th:
        cr.rotate_hoisted_group(ct, ROTS)
    with trace.capture_trace() as ts:
        for r in ROTS:
            cr.rotate(ct, r)
    ext_ntts = lambda t: sum(1 for i in t if i.op == "NTT" and i.limbs == m)
    assert ext_ntts(th) == beta
    assert ext_ntts(ts) == k * beta


# ---------------------------------------------------------------------------
# planner parity: executable traces == analytic hoisted streams
# ---------------------------------------------------------------------------


def test_planner_parity_hoisted_group(hset):
    p, cr, cf, ct, _ = hset
    pp = PL.PlanParams.of(p)
    for level in (p.L, max(1, p.alpha - 1)):
        c = ops.level_drop(ct, level)
        for ctx, fused in ((cr, False), (cf, True)):
            with trace.capture_trace() as t:
                ctx.rotate_hoisted_group(c, ROTS)
            want = PL.hoisted_rotations(pp, level, len(ROTS), fused=fused)
            assert _sig(t) == _sig(want), (level, fused)


def test_planner_parity_standard_rotate_unchanged(hset):
    """The permute-last refactor must not change the standard rotation's
    trace shape — planner ``rotate`` streams still match."""
    p, cr, cf, ct, _ = hset
    pp = PL.PlanParams.of(p)
    for ctx, fused in ((cr, False), (cf, True)):
        with trace.capture_trace() as t:
            ctx.rotate(ct, 5)
        assert _sig(t) == _sig(PL.rotate(pp, p.L, fused=fused)), fused


# ---------------------------------------------------------------------------
# BSGS integration: apply_bsgs hoists its baby group
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bsgs_setup():
    p = P.make_params(1 << 9, 5, 2, check_security=False)
    rng = np.random.default_rng(3)
    mat = (rng.normal(size=(p.slots, p.slots))
           + 1j * rng.normal(size=(p.slots, p.slots))) / p.slots
    plan = linear.plan_matrix(mat)
    ks = K.full_keyset(p, seed=1, rotations=tuple(plan.rotations()))
    base = FheContext(params=p, keys=ks, policy=ExecPolicy(backend="ref"))
    z = rng.normal(size=p.slots) * 0.5
    ct = base.encrypt(base.encode(z))
    return p, ks, plan, mat, ct, z


def test_apply_bsgs_hoisting_bitexact(bsgs_setup):
    p, ks, plan, mat, ct, z = bsgs_setup
    ctx = FheContext(params=p, keys=ks,
                     policy=ExecPolicy(backend="ref", hoisting="always"))
    hoisted = ctx.apply_bsgs(ct, plan)
    staged = ctx.with_policy(hoisting="never").apply_bsgs(ct, plan)
    assert _ct_equal(hoisted, staged)
    got = np.asarray(ctx.decrypt_decode(hoisted))
    np.testing.assert_allclose(got, mat @ z, atol=5e-2)


def test_apply_bsgs_planner_parity_both_modes(bsgs_setup):
    p, ks, plan, _mat, ct, _z = bsgs_setup
    pp = PL.PlanParams.of(p)
    n_diags = len(plan.diags)
    for hoisting, hoist in (("always", True), ("never", False)):
        ctx = FheContext(params=p, keys=ks,
                         policy=ExecPolicy(backend="ref", hoisting=hoisting))
        with trace.capture_trace() as t:
            ctx.apply_bsgs(ct, plan)
        want = PL.bsgs_matvec(pp, ct.level, n_diags, plan.n1, mode="exec",
                              hoist=hoist, fused=False)
        assert _sig(t) == _sig(want), hoisting


def test_bsgs_plan_caches_rotations(bsgs_setup):
    _p, _ks, plan, _mat, _ct, _z = bsgs_setup
    assert plan.rotations() is plan.rotations()
    assert plan.baby_steps() is plan.baby_steps()
    assert set(plan.baby_steps()) == {d % plan.n1 for d in plan.diags} - {0}
    assert set(plan.giant_steps()) == {(d // plan.n1) * plan.n1 for d in plan.diags} - {0}


def test_full_keyset_no_overgeneration():
    """Keygen produces exactly one switching key per needed Galois element:
    r = 0 and slot-congruent rotations must not generate extra keys."""
    p = P.make_params(1 << 9, 5, 2, check_security=False)
    rots = (0, 1, 2, 1 + p.slots, 2 + 2 * p.slots)
    ks = K.full_keyset(p, seed=0, rotations=rots, conjugate=True)
    want = K.galois_elements(p, rots, conjugate=True)
    assert tuple(sorted(ks.gks)) == want
    assert len(ks.gks) == 3  # {σ for r∈{1,2}} + conjugation


# ---------------------------------------------------------------------------
# simulator accounting
# ---------------------------------------------------------------------------


def test_simulator_parity_executable_vs_planner(hset):
    """Simulating a captured hoisted trace equals simulating the planner's
    analytic hoisted stream — cycles, HBM bytes, and per-unit totals."""
    p, _, cf, ct, _ = hset
    pp = PL.PlanParams.of(p)
    with trace.capture_trace() as t:
        cf.rotate_hoisted_group(ct, ROTS)
    chip = H.FLASH_FHE
    got = simulate_stream(list(t), chip, lanes_deep(chip))
    want = simulate_stream(
        PL.hoisted_rotations(pp, p.L, len(ROTS), fused=True), chip, lanes_deep(chip)
    )
    assert got.cycles == pytest.approx(want.cycles)
    assert got.hbm_bytes == pytest.approx(want.hbm_bytes)
    for unit in ("ntt", "bconv", "modmul"):
        assert got.unit_cycles[unit] == pytest.approx(want.unit_cycles[unit])


def test_simulator_rewards_hoisting():
    """hw-mode deep workload streams: hoisting must cut the NTT-unit work and
    the bottleneck cycles on the fused-pipeline chip."""
    job_params = P.workload_params("lstm")
    st_base = PL.workload_stream("lstm", job_params, mode="hw", hoist=False)
    st_hoist = PL.workload_stream("lstm", job_params, mode="hw", hoist=True)
    chip = H.FLASH_FHE
    rb = simulate_stream(st_base, chip, lanes_deep(chip))
    rh = simulate_stream(st_hoist, chip, lanes_deep(chip))
    assert rh.unit_cycles["ntt"] < rb.unit_cycles["ntt"]
    assert rh.unit_cycles["bconv"] < rb.unit_cycles["bconv"]
    assert rh.cycles < rb.cycles


def test_planner_hoisted_stream_counts():
    """Analytic sanity: a hoisted k-rotation group carries β ext-NTT records
    + 2k ModDown NTTs; the per-rotation stream carries k·(β + 2)."""
    pp = PL.PlanParams(n=1 << 16, L=23, alpha=8)
    level, k = 23, 12
    beta = pp.beta(level)
    ext = level + 1 + pp.alpha
    hoisted = PL.hoisted_rotations(pp, level, k)
    per_rot = []
    for _ in range(k):
        per_rot += PL.rotate(pp, level)
    ext_ntts = lambda s: sum(1 for i in s if i.op == "NTT" and i.limbs == ext)
    all_ntts = lambda s: sum(1 for i in s if i.op == "NTT")
    assert ext_ntts(hoisted) == beta
    assert ext_ntts(per_rot) == k * beta
    assert all_ntts(hoisted) == beta + 2 * k
    assert all_ntts(per_rot) == k * (beta + 2)
