"""FheContext / ExecPolicy: the evaluation-context API.

Three contracts pinned here:

  * **shim lifecycle** — every retired free-function tranche
    (linear/polyeval/bootstrap, and now the ``fhe.ops`` kwarg-threading
    entry points) raises ``AttributeError`` with the context migration hint,
    never silently delegating; the context methods carry the full numerics
    contract (cross-backend bit-exactness, hypothesis-driven);
  * **policy identity** — ``ExecPolicy.policy_key()`` distinguishes every
    (scheme, backend, hoisting, numerics) combination, excludes the dispatch
    hook, and is what keys the serving service-time memo (no mode aliasing);
  * **planning** — ``plan_matrix``/``choose_n1`` pick the baby-step count
    from the hoisting-aware cost model (n1 = 16 for the radix-32 CtS stage
    shape the hoisting bench measures, vs the classic √n without hoisting).
"""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import hardware as H
from repro.core import jobs as J
from repro.core import planner as PL
from repro.fhe import keys as K
from repro.fhe import linear, ops, polyeval
from repro.fhe import params as P
from repro.fhe.context import BACKENDS, HOISTING_MODES, NUMERICS_MODES, ExecPolicy, FheContext
from repro.kernels import dispatch
from repro.serve import policy as SP

ROTS = (1, 2, 3, 4, 5)


def _ct_equal(a, b) -> bool:
    return bool(jnp.array_equal(a.c0, b.c0)) and bool(jnp.array_equal(a.c1, b.c1))


@pytest.fixture(scope="module")
def cset():
    p = P.make_params(1 << 9, 5, 2, check_security=False)
    ks = K.full_keyset(p, seed=0, rotations=ROTS, conjugate=True)
    ctx = FheContext(params=p, keys=ks)
    rng = np.random.default_rng(3)
    za = rng.normal(size=p.slots) * 0.3
    zb = rng.normal(size=p.slots) * 0.3
    ct_a = ctx.encrypt(ctx.encode(za))
    ct_b = ctx.encrypt(ctx.encode(zb), seed=23)
    return p, ks, ctx, ct_a, ct_b, za, zb


# ---------------------------------------------------------------------------
# numerics contract: every (backend, hoisting) combination ≡ ref/never, bit-exact
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(backend=st.sampled_from(("ref", "fused")),
       hoisting=st.sampled_from(HOISTING_MODES),
       r=st.sampled_from(ROTS))
def test_ops_backends_bitexact_vs_reference(cset, backend, hoisting, r):
    p, ks, _, ct_a, ct_b, _, _ = cset
    ctx = FheContext(params=p, keys=ks,
                     policy=ExecPolicy(backend=backend, hoisting=hoisting))
    ref = FheContext(params=p, keys=ks,
                     policy=ExecPolicy(backend="ref", hoisting="never"))
    pairs = [
        (ctx.add(ct_a, ct_b), ref.add(ct_a, ct_b)),
        (ctx.sub(ct_a, ct_b), ref.sub(ct_a, ct_b)),
        (ctx.negate(ct_a), ref.negate(ct_a)),
        (ctx.mul(ct_a, ct_b), ref.mul(ct_a, ct_b)),
        (ctx.square(ct_a), ref.square(ct_a)),
        (ctx.rotate(ct_a, r), ref.rotate(ct_a, r)),
        (ctx.conjugate(ct_a), ref.conjugate(ct_a)),
        (ctx.rescale(ct_a), ref.rescale(ct_a)),
        (ctx.add_const(ct_a, 0.25), ref.add_const(ct_a, 0.25)),
        (ctx.mul_const(ct_a, 0.5), ref.mul_const(ct_a, 0.5)),
    ]
    for got, want in pairs:
        assert _ct_equal(got, want)
        assert got.level == want.level and got.scale == want.scale


@settings(max_examples=4, deadline=None)
@given(backend=st.sampled_from(("ref", "fused")),
       hoisting=st.sampled_from(HOISTING_MODES))
def test_encode_encrypt_decrypt_backends_bitexact(cset, backend, hoisting):
    p, ks, _, _, _, za, _ = cset
    ctx = FheContext(params=p, keys=ks,
                     policy=ExecPolicy(backend=backend, hoisting=hoisting))
    ref = FheContext(params=p, keys=ks,
                     policy=ExecPolicy(backend="ref", hoisting="never"))
    pt = ctx.encode(za)
    pt_r = ref.encode(za)
    assert bool(jnp.array_equal(pt.data, pt_r.data))
    ct = ctx.encrypt(pt, seed=5)
    ct_r = ref.encrypt(pt_r, seed=5)
    assert _ct_equal(ct, ct_r)
    got = ctx.decrypt_decode(ct)
    want = ref.decrypt_decode(ct_r)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert np.abs(got - za).max() < 1e-3


@settings(max_examples=6, deadline=None)
@given(backend=st.sampled_from(("ref", "fused")),
       hoisting=st.sampled_from(HOISTING_MODES))
def test_apply_bsgs_modes_bitexact_and_correct(cset, backend, hoisting):
    """The linear-transform shims retired; the context path carries the whole
    contract now: every (backend, hoisting) combination is bit-exact against
    the reference mode and numerically matches the plain matvec."""
    p, ks, _, ct_a, _, za, _ = cset
    rng = np.random.default_rng(11)
    m = np.zeros((p.slots, p.slots))
    for d in range(4):
        m[np.arange(p.slots), (np.arange(p.slots) + d) % p.slots] = rng.normal(size=p.slots) * 0.2
    plan = linear.plan_matrix(m, n1=2, tol=1e-12)
    assert plan.rotations() <= set(ROTS)  # keys for every needed rotation exist
    ctx = FheContext(params=p, keys=ks,
                     policy=ExecPolicy(backend=backend, hoisting=hoisting))
    got = ctx.apply_bsgs(ct_a, plan)
    base = FheContext(params=p, keys=ks,
                      policy=ExecPolicy(backend="ref", hoisting="never"))
    assert _ct_equal(got, base.apply_bsgs(ct_a, plan))
    np.testing.assert_allclose(np.asarray(ctx.decrypt_decode(got)).real,
                               m @ za, atol=5e-3)


def test_real_imag_part_correct(cset):
    p, _, ctx, ct_a, _, za, _ = cset
    np.testing.assert_allclose(np.asarray(ctx.decrypt_decode(ctx.real_part(ct_a))).real,
                               za, atol=1e-3)
    np.testing.assert_allclose(np.asarray(ctx.decrypt_decode(ctx.imag_part(ct_a))).real,
                               np.zeros(p.slots), atol=1e-3)


def test_eval_poly_parity(cset):
    """ctx.eval_poly ≡ explicit basis + ctx.eval_chebyshev, and both match
    the numpy Chebyshev evaluation."""
    p, ks, ctx, ct_a, _, za, _ = cset
    coeffs = np.array([0.1, 0.8, 0.0, -0.2])
    got = ctx.eval_poly(ct_a, coeffs)
    basis = ctx.chebyshev_basis(ct_a, len(coeffs) - 1)
    want = ctx.eval_chebyshev(basis, coeffs)
    assert _ct_equal(got, want)
    assert got.scale == want.scale and got.level == want.level
    np.testing.assert_allclose(np.asarray(ctx.decrypt_decode(got)).real,
                               np.polynomial.chebyshev.Chebyshev(coeffs)(za), atol=1e-3)


def test_force_to_add_any_exactness(cset):
    p, _, ctx, ct_a, ct_b, za, zb = cset
    lo = ctx.mul(ct_a, ct_a)  # one level down, scale back at ≈ 2^30
    forced = ctx.force_to(ct_b, lo.level, lo.scale)
    assert forced.level == lo.level and forced.scale == lo.scale
    np.testing.assert_allclose(np.asarray(ctx.decrypt_decode(forced)).real, zb, atol=1e-3)
    got = ctx.add_any(lo, ct_b)  # aligns the fresh ct down to lo's level
    np.testing.assert_allclose(np.asarray(ctx.decrypt_decode(got)).real,
                               za * za + zb, atol=2e-3)


def test_hoisting_modes_bitexact_through_context(cset):
    """All three hoisting modes agree through the context API (group sharing
    included) — the context must not change the numerics contract."""
    _, _, ctx, ct_a, _, _, _ = cset
    base = {r: ctx.with_policy(hoisting="never").rotate(ct_a, r) for r in ROTS}
    always = ctx.with_policy(hoisting="always")
    group = always.rotate_hoisted_group(ct_a, ROTS)
    for r in ROTS:
        assert _ct_equal(base[r], always.rotate(ct_a, r))
        assert _ct_equal(base[r], group[r])


# ---------------------------------------------------------------------------
# policy identity: policy_key never aliases
# ---------------------------------------------------------------------------


def test_policy_key_distinguishes_every_combination():
    keys = set()
    combos = list(itertools.product(BACKENDS, HOISTING_MODES, NUMERICS_MODES))
    for backend, hoisting, numerics in combos:
        keys.add(ExecPolicy(backend=backend, hoisting=hoisting,
                            numerics=numerics).policy_key())
    assert len(keys) == len(combos), "policy_key aliases distinct policies"


def test_policy_key_excludes_dispatch_hook():
    a = ExecPolicy(backend="ref")
    b = ExecPolicy(backend="ref", dispatch_hook=lambda op: None)
    assert a.policy_key() == b.policy_key()
    assert a == b  # observation must not change equality either


def test_policy_validation():
    with pytest.raises(ValueError):
        ExecPolicy(backend="vectorized")
    with pytest.raises(ValueError):
        ExecPolicy(hoisting="sometimes")
    with pytest.raises(ValueError):
        ExecPolicy(numerics="double_hoist")  # future mode: not implemented yet


def test_service_memo_keys_on_policy():
    """Distinct ExecPolicies must occupy distinct service-time memo entries —
    the serving regression the policy_key contract exists for."""
    job = J.make_job("lola_mnist_plain")
    fused_never = SP.job_service_sim(job, H.FLASH_FHE,
                                     policy=ExecPolicy(backend="fused", hoisting="never"))
    fused_always = SP.job_service_sim(job, H.FLASH_FHE,
                                      policy=ExecPolicy(backend="fused", hoisting="always"))
    staged_never = SP.job_service_sim(job, H.FLASH_FHE,
                                      policy=ExecPolicy(backend="staged", hoisting="never"))
    assert fused_never is not fused_always
    assert fused_never is not staged_never
    # staged pipeline pays working-set round-trips the fused one doesn't
    assert staged_never.cycles > fused_never.cycles
    # legacy bool spelling lands on the same entries (one source of truth)
    assert SP.job_service_sim(job, H.FLASH_FHE, hoist=False) is fused_never
    assert SP.job_service_sim(job, H.FLASH_FHE, hoist=True) is fused_always
    assert SP.exec_policy_from_hoist(True).policy_key() == (
        "ckks", "fused", "always", "standard")


def test_workload_stream_policy_mirrors_legacy_flags():
    """workload_stream(policy=) must reproduce the legacy hoist-bool streams
    (fused pipeline) exactly, and a staged policy must add WS boundaries."""
    p = P.workload_params("lola_mnist_plain")
    for hoist in (False, True):
        legacy = PL.workload_stream("lola_mnist_plain", p, mode="hw", hoist=hoist)
        policy = PL.workload_stream(
            "lola_mnist_plain", p, mode="hw",
            policy=ExecPolicy(backend="fused",
                              hoisting="always" if hoist else "never"))
        assert [(i.op, i.n, i.limbs) for i in legacy] == [
            (i.op, i.n, i.limbs) for i in policy]
    staged = PL.workload_stream("lola_mnist_plain", p, mode="hw",
                                policy=ExecPolicy(backend="staged"))
    fused = PL.workload_stream("lola_mnist_plain", p, mode="hw",
                               policy=ExecPolicy(backend="fused"))
    n_ws = lambda s: sum(1 for i in s if i.op == "STORE_WS")
    assert n_ws(staged) > n_ws(fused)


# ---------------------------------------------------------------------------
# context ergonomics: with_policy, hooks, keys
# ---------------------------------------------------------------------------


def test_with_policy_scoped_override(cset):
    _, ks, ctx, _, _, _, _ = cset
    fast = ctx.with_policy(backend="fused", hoisting="always")
    assert fast.keys is ks and fast.params is ctx.params
    assert fast.policy.backend == "fused" and ctx.policy.backend == "auto"
    replaced = ctx.with_policy(policy=ExecPolicy(backend="ref"))
    assert replaced.policy.backend == "ref"
    with pytest.raises(TypeError):
        ctx.with_policy(policy=ExecPolicy(), backend="ref")


def test_dispatch_hook_observes_kernel_launches(cset):
    _, _, ctx, ct_a, ct_b, _, _ = cset
    seen: list[str] = []
    hooked = ctx.with_policy(backend="ref", dispatch_hook=seen.append)
    hooked.add(ct_a, ct_b)
    assert seen == ["addmod", "addmod"]  # c0 and c1
    # hooks compose with an enclosing counter instead of replacing it
    seen.clear()
    with dispatch.count_dispatches() as counts:
        hooked.mul(ct_a, ct_b)
    assert counts and sum(counts.values()) == len(seen)


def test_keyless_context_rejects_key_ops(cset):
    p, _, _, ct_a, _, _, _ = cset
    bare = FheContext(params=p)
    with pytest.raises(ValueError, match="KeySet"):
        bare.rotate(ct_a, 1)
    with pytest.raises(ValueError, match="KeySet"):
        bare.mul(ct_a, ct_a)
    # key-less ops still work
    assert _ct_equal(bare.add(ct_a, ct_a), bare.add(ct_a, ct_a))


# ---------------------------------------------------------------------------
# hoisting-aware BSGS planning
# ---------------------------------------------------------------------------


def test_choose_n1_shifts_under_hoisting():
    """The radix-32 CtS stage shape (63 diagonals) at the hoisting bench's
    parameters: classic balance point n1 = 8 unhoisted, n1 = 16 hoisted —
    the value the bench used to hand-pick."""
    p = P.make_params(1 << 14, 3, 3, check_security=False)
    assert linear.choose_n1(range(63), p, p.L, hoisted=False) == 8
    assert linear.choose_n1(range(63), p, p.L, hoisted=True) == 16
    # the hoisted optimum never costs more than the unhoisted plan's split
    c_h = linear.bsgs_rotation_cost(range(63), 16, p, p.L, hoisted=True)
    c_u = linear.bsgs_rotation_cost(range(63), 8, p, p.L, hoisted=False)
    assert c_h < c_u


def test_plan_matrix_uses_cost_model_with_params():
    p = P.make_params(1 << 9, 5, 2, check_security=False)
    rng = np.random.default_rng(0)
    m = rng.normal(size=(p.slots, p.slots))
    classic = linear.plan_matrix(m)
    assert classic.n1 == 16  # √256, the historical default — unchanged
    modeled = linear.plan_matrix(m, params=p, hoisting=False)
    assert modeled.n1 == linear.choose_n1(range(p.slots), p, p.L, hoisted=False)
    hoisted = linear.plan_matrix(m, params=p, hoisting=True)
    assert hoisted.n1 >= modeled.n1  # babies get cheaper, never scarcer
    forced = linear.plan_matrix(m, n1=4, params=p, hoisting=True)
    assert forced.n1 == 4  # explicit n1 always wins


def test_context_plan_matrix_follows_policy(cset):
    p, _, ctx, ct_a, _, _, _ = cset
    rng = np.random.default_rng(5)
    m = np.zeros((p.slots, p.slots))
    for d in range(6):
        m[np.arange(p.slots), (np.arange(p.slots) + d) % p.slots] = rng.normal(size=p.slots)
    plan_h = ctx.with_policy(hoisting="always").plan_matrix(m, tol=1e-12)
    plan_n = ctx.with_policy(hoisting="never").plan_matrix(m, tol=1e-12)
    assert plan_h.n1 >= plan_n.n1
    # both plans compute the same transform
    got_h = ctx.with_policy(hoisting="always").apply_bsgs(ct_a, plan_h)
    got_n = ctx.with_policy(hoisting="never").apply_bsgs(ct_a, plan_n)
    dec_h = ctx.decrypt_decode(got_h)
    dec_n = ctx.decrypt_decode(got_n)
    assert np.abs(np.asarray(dec_h) - np.asarray(dec_n)).max() < 1e-3


def test_plan_diags_banded():
    p = P.make_params(1 << 9, 5, 2, check_security=False)
    diags = {d: np.ones(p.slots, np.complex128) for d in range(7)}
    plan = linear.plan_diags(diags, p, hoisting=True)
    assert set(plan.diags) == set(range(7))
    assert plan.n1 == linear.choose_n1(range(7), p, p.L, hoisted=True)


# ---------------------------------------------------------------------------
# deprecation surface
# ---------------------------------------------------------------------------


def test_retired_names_raise_plain_attribute_error():
    """Retirement complete (docs/context_api.md step 5): the transitional
    ``__getattr__`` stub tables are deleted, so every legacy free-function
    name raises a PLAIN AttributeError — no migration-hint string and no
    module ``__getattr__`` left behind in the four op modules."""
    from repro.fhe import bootstrap

    retired = [
        (linear, "apply_bsgs"), (linear, "apply_bsgs_pair"),
        (linear, "real_part"), (linear, "imag_part"),
        (polyeval, "force_to"), (polyeval, "add_any"),
        (polyeval, "eval_chebyshev"),
        (bootstrap, "bootstrap"), (bootstrap, "mod_raise"),
        (bootstrap, "coeff_to_slot"), (bootstrap, "eval_mod"),
        (bootstrap, "slot_to_coeff"),
    ]
    retired += [(ops, name) for name in (
        "encode", "encode_const", "decode", "encrypt", "decrypt",
        "decrypt_decode", "add", "sub", "negate", "add_plain", "add_const",
        "mul_plain", "mul_const", "mul_const_exact", "mul", "square",
        "rescale", "rotate", "rotate_hoisted", "rotate_hoisted_group",
        "conjugate")]
    for mod, _ in retired:
        assert not hasattr(mod, "__getattr__"), f"{mod.__name__} keeps a stub"
    for mod, name in retired:
        with pytest.raises(AttributeError) as exc:
            getattr(mod, name)
        assert "docs/context_api.md" not in str(exc.value)
    with pytest.raises(AttributeError):
        linear.no_such_function  # unknown names still raise plainly
    with pytest.raises(AttributeError):
        ops.no_such_function
    # non-retired ops module members stay importable (level_drop is API)
    assert callable(ops.level_drop)
