"""core/ tests: planner↔execution consistency, scheduler policy, simulator."""

import collections

import numpy as np
import pytest

from repro.core import hardware as H
from repro.core import jobs as J
from repro.core import planner as PL
from repro.core import scheduler as S
from repro.core.cache import MB, LruCache
from repro.core.simulator import lanes_deep, lanes_whole_chip, simulate_stream
from repro.fhe import keys as K
from repro.fhe import params as P
from repro.fhe import trace
from repro.fhe.context import FheContext


def _sig(instrs):
    """Multiset signature of (op, n, limbs) triples (ignoring meta)."""
    return collections.Counter((i.op, i.n, i.limbs) for i in instrs)


@pytest.fixture(scope="module")
def small():
    p = P.make_params(1 << 9, 6, 2, check_security=False)
    ks = K.full_keyset(p, seed=0, rotations=(1, 3), conjugate=True)
    ctx = FheContext(params=p, keys=ks)
    rng = np.random.default_rng(5)
    z = rng.normal(size=p.slots) * 0.4
    a = ctx.encrypt(ctx.encode(z))
    b = ctx.encrypt(ctx.encode(z * 0.5), seed=31)
    return p, ctx, a, b


# ---------------------------------------------------------------------------
# planner validation: analytic streams == captured execution traces
# ---------------------------------------------------------------------------


def test_planner_hmul_matches_execution(small):
    # default CPU execution runs the *staged* key-switch pipeline (explicit
    # working-set boundaries); the fused-pipeline parity lives in test_fusedks
    p, ctx, a, b = small
    with trace.capture_trace() as t:
        ctx.mul(a, b)
    pp = PL.PlanParams.of(p)
    assert _sig(t) == _sig(PL.hmul(pp, a.level, fused=False))


def test_planner_rotate_matches_execution(small):
    p, ctx, a, _ = small
    with trace.capture_trace() as t:
        ctx.rotate(a, 3)
    pp = PL.PlanParams.of(p)
    assert _sig(t) == _sig(PL.rotate(pp, a.level, fused=False))


def test_planner_keyswitch_level_dependence(small):
    """β (digit count) shrinks at lower levels — fewer BCONV/NTT stages."""
    p, _, _, _ = small
    pp = PL.PlanParams.of(p)
    hi = PL.key_switch(pp, p.L)
    lo = PL.key_switch(pp, p.alpha - 1)  # single digit active
    n_bconv_hi = sum(1 for i in hi if i.op == "BCONV")
    n_bconv_lo = sum(1 for i in lo if i.op == "BCONV")
    assert n_bconv_hi == p.num_digits + 2  # β digits + ModDown on (ks0, ks1)
    assert n_bconv_lo == 3  # 1 digit + ModDown on (ks0, ks1)


def test_planner_mul_plain_matches_execution(small):
    p, ctx, a, _ = small
    pt_z = np.ones(p.slots) * 0.5
    with trace.capture_trace() as t:
        ctx.mul_plain(a, ctx.encode(pt_z, level=a.level), rescale_after=True)
    pp = PL.PlanParams.of(p)
    assert _sig(t) == _sig(PL.mul_plain(pp, a.level, rescale_after=True, mode="exec"))


def test_planner_bootstrap_structure():
    """hw-mode bootstrap: factored DFT ⇒ ~100 key-switches, not ~1500."""
    p = P.workload_params("packed_bootstrap")
    pp = PL.PlanParams.of(p)
    hw = PL.bootstrap(pp, degree=63, mode="hw")
    ks_count = sum(1 for i in hw if i.op == "LOAD_KSK")
    assert 50 <= ks_count <= 400
    assert any(i.op == "MODRAISE" for i in hw)


def test_workload_streams_exist():
    for name in PL.available_workloads():
        p = P.workload_params(name)
        st = PL.workload_stream(name, p, mode="hw")
        assert len(st) > 10
        # hw streams carry working-set annotations for every key-switch
        n_ksk = sum(1 for i in st if i.op == "LOAD_KSK")
        n_tws = sum(1 for i in st if i.op == "TOUCH_WS")
        assert n_ksk == n_tws


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------


def test_simulator_paper_deep_claims():
    """Deep workloads: FLASH-FHE ≈ 1.4× CraterLake, ≈ 11× F1+ (geomean)."""
    rs_cl, rs_f1 = [], []
    for w in P.DEEP_WORKLOADS:
        job = J.make_job(w)
        t = {c.name: S.schedule([job], c)[0].sim.time_s
             for c in (H.FLASH_FHE, H.CRATERLAKE, H.F1PLUS)}
        rs_cl.append(t["craterlake"] / t["flash-fhe"])
        rs_f1.append(t["f1plus"] / t["flash-fhe"])
    gm_cl = float(np.exp(np.mean(np.log(rs_cl))))
    gm_f1 = float(np.exp(np.mean(np.log(rs_f1))))
    assert 1.1 <= gm_cl <= 2.0, f"CL geomean {gm_cl} (paper: 1.4)"
    assert 7.0 <= gm_f1 <= 17.0, f"F1+ geomean {gm_f1} (paper: 11.2)"


def test_simulator_multi_job_scaling():
    """8 concurrent shallow jobs: makespan speedup reaches 8× (Fig 12)."""
    jobs = [J.make_job("lola_mnist_plain", job_id=i) for i in range(8)]
    ff = S.schedule(jobs, H.FLASH_FHE)
    cl = S.schedule(jobs, H.CRATERLAKE)
    speedup = S.makespan(cl) / S.makespan(ff)
    assert speedup >= 7.5, f"multi-job speedup {speedup} (paper: up to 8.0)"
    # FLASH-FHE runs them in parallel on distinct affiliations
    assert len({s.lanes for s in ff}) == 8


def test_simulator_unfused_roundtrips_hurt():
    """F1+-style unfused key-switch must be strictly slower on deep work."""
    job = J.make_job("packed_bootstrap")
    st = PL.workload_stream(job.workload, job.params, mode="hw")
    fused = simulate_stream(st, H.CRATERLAKE, lanes_whole_chip(H.CRATERLAKE))
    unfused = simulate_stream(st, H.F1PLUS, lanes_whole_chip(H.F1PLUS))
    assert unfused.cycles > 3 * fused.cycles


def test_cache_sweep_saturates_at_design_point():
    """Fig 8: dnum=1 key-switch performance saturates by ~320 MB."""
    p = P.workload_params("packed_bootstrap")
    pp = PL.PlanParams.of(p)
    stream = PL.add_hw_annotations(PL.key_switch(pp, p.L) * 10, pp)
    times = {}
    for cap in (128, 256, 320, 512):
        r = simulate_stream(stream, H.FLASH_FHE, lanes_deep(H.FLASH_FHE),
                            cache_bytes=cap * MB)
        times[cap] = r.cycles
    assert times[128] > times[256] > times[320]
    assert times[320] == times[512]  # saturated at the paper's design point


def test_lru_cache_model():
    c = LruCache(10 * MB)
    assert c.access("a", 6 * MB) == 6 * MB  # miss
    assert c.access("a", 6 * MB) == 0.0  # hit
    assert c.access("b", 6 * MB) == 6 * MB  # miss, evicts a
    assert c.access("a", 6 * MB) == 6 * MB  # miss again
    assert c.access("huge", 20 * MB) == 20 * MB  # streams, never cached
    assert c.access("huge", 20 * MB) == 20 * MB


# ---------------------------------------------------------------------------
# scheduler policy
# ---------------------------------------------------------------------------


def test_classifier():
    assert J.make_job("lola_mnist_plain").kind == "shallow"
    assert J.make_job("resnet20").kind == "deep"


def test_deep_job_takes_all_affiliations():
    sched = S.schedule([J.make_job("lstm")], H.FLASH_FHE)
    assert "deep(8×boot)" in sched[0].lanes


def test_preemption_avoids_convoy():
    """High-priority shallow job arriving behind a deep job must not wait for
    it (preemptive scheduling, §4.2) — unlike the sequential baseline."""
    deep = J.make_job("resnet20", priority=0, arrival_cycle=0, job_id=0)
    sh = J.make_job("matmul", priority=5, arrival_cycle=1000, job_id=1)
    ff = S.schedule([deep, sh], H.FLASH_FHE)
    cl = S.schedule([deep, sh], H.CRATERLAKE)
    sh_ff = next(s for s in ff if s.job.job_id == 1)
    sh_cl = next(s for s in cl if s.job.job_id == 1)
    deep_ff = next(s for s in ff if s.job.job_id == 0)
    assert sh_ff.turnaround < 0.01 * sh_cl.turnaround  # no convoy effect
    assert deep_ff.preempted_cycles > 0  # deep job paid the spill


def test_priority_respected_in_sequential_baseline():
    j0 = J.make_job("matmul", priority=0, arrival_cycle=0, job_id=0)
    j1 = J.make_job("matmul", priority=9, arrival_cycle=0, job_id=1)
    cl = S.schedule([j0, j1], H.CRATERLAKE)
    first = min(cl, key=lambda s: s.start_cycle)
    assert first.job.job_id == 1


# ---------------------------------------------------------------------------
# area / power (Table 3, Fig 13)
# ---------------------------------------------------------------------------


def test_area_claims():
    assert H.swift_logic_fraction("14nm") < 0.075  # "< 7% extra area"
    assert abs(H.area_total_mm2("14nm") - 519.34) < 1e-6
    assert H.area_total_mm2("14nm") < H.BASELINE_AREAS_MM2["f1plus"]


def test_power_breakdown():
    total = sum(H.POWER_BREAKDOWN_W.values())
    assert abs(total - H.TOTAL_POWER_W) / H.TOTAL_POWER_W < 0.01
    assert H.POWER_BREAKDOWN_W["bootstrappable_clusters"] / H.TOTAL_POWER_W == pytest.approx(0.60, abs=0.02)
    assert H.POWER_BREAKDOWN_W["swift_clusters"] / H.TOTAL_POWER_W == pytest.approx(0.11, abs=0.02)
    assert H.TOTAL_POWER_W < H.BASELINE_POWER_W["craterlake"]
