"""Fused key-switch pipeline: bit-exactness, dispatch counts, trace shape,
and simulator accounting — the kernel-level half of the paper's fused
iNTT→BConv→NTT claim."""

import collections

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hardware as H
from repro.core import planner as PL
from repro.core.simulator import lanes_deep, simulate_stream
from repro.fhe import keys as K
from repro.fhe import keyswitch as KS
from repro.fhe import params as P
from repro.fhe import poly, trace
from repro.kernels import dispatch
from repro.kernels.fusedks import ops as fops

BOUNDARY = ("STORE_WS", "LOAD_WS")


def _sig(instrs, skip=()):
    return collections.Counter((i.op, i.n, i.limbs) for i in instrs if i.op not in skip)


@pytest.fixture(scope="module", params=[1, 2, 3], ids=lambda d: f"dnum{d}")
def ks_setup(request):
    p = P.make_params(1 << 9, 5, request.param, check_security=False)
    sk = K.keygen(p, 0)
    rlk = K.relin_keygen(p, sk)
    return p, rlk


def _rand_eval(p, level, seed=3):
    rng = np.random.default_rng(seed)
    qs = np.array(p.q_primes[: level + 1], np.uint64)
    d = rng.integers(0, 1 << 31, size=(level + 1, p.n)) % qs[:, None]
    return jnp.asarray(d.astype(np.uint32))


# ---------------------------------------------------------------------------
# bit-exactness: fused Pallas pipeline vs staged u64 oracle
# ---------------------------------------------------------------------------


def test_fused_key_switch_bitexact_across_levels(ks_setup):
    p, rlk = ks_setup
    levels = sorted({p.L, min(p.L, p.alpha - 1), min(p.L, p.alpha), 0})
    for level in levels:
        d = _rand_eval(p, level, seed=11 + level)
        f0, f1 = KS.key_switch(d, p, level, rlk, backend="fused")
        r0, r1 = KS.key_switch(d, p, level, rlk, backend="ref")
        np.testing.assert_array_equal(np.asarray(f0), np.asarray(r0))
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(r1))


def test_fused_digit_region_bitexact(ks_setup):
    """The prescale→BConv→NTT→MAC region alone, before ModDown."""
    p, rlk = ks_setup
    level = p.L
    d = _rand_eval(p, level, seed=7)
    d_coeff = poly.to_coeff(d, p, poly.q_idx(p, level), "ref")
    ksk_sel = KS._select_ksk(rlk, p, level, p.beta(level))
    a0, a1 = fops.key_switch_digits(d_coeff, ksk_sel, p, level, backend="kernel")
    b0, b1 = fops.key_switch_digits(d_coeff, ksk_sel, p, level, backend="ref")
    np.testing.assert_array_equal(np.asarray(a0), np.asarray(b0))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(b1))


def test_fused_moddown_bitexact(ks_setup):
    p, rlk = ks_setup
    level = p.L
    rng = np.random.default_rng(5)
    ext = poly.ext_idx(p, level)
    primes = np.array(poly.primes_for(p, ext), np.uint64)
    acc = rng.integers(0, 1 << 31, size=(2, len(ext), p.n)) % primes[None, :, None]
    acc0, acc1 = jnp.asarray(acc[0].astype(np.uint32)), jnp.asarray(acc[1].astype(np.uint32))
    f0, f1 = KS.mod_down_pair(acc0, acc1, p, level, backend="fused")
    r0 = KS.mod_down(acc0, p, level, backend="ref")
    r1 = KS.mod_down(acc1, p, level, backend="ref")
    np.testing.assert_array_equal(np.asarray(f0), np.asarray(r0))
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(r1))


def test_staged_backends_agree(ks_setup):
    """staged (auto stage kernels) == ref (u64 oracle stages)."""
    p, rlk = ks_setup
    d = _rand_eval(p, p.L, seed=13)
    s0, s1 = KS.key_switch(d, p, p.L, rlk, backend="staged")
    r0, r1 = KS.key_switch(d, p, p.L, rlk, backend="ref")
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(r0))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(r1))


# ---------------------------------------------------------------------------
# dispatch counts: the measurable fusion win
# ---------------------------------------------------------------------------


def test_fused_issues_fewer_dispatches(ks_setup):
    p, rlk = ks_setup
    d = _rand_eval(p, p.L, seed=2)
    with dispatch.count_dispatches() as cf:
        KS.key_switch(d, p, p.L, rlk, backend="fused")
    with dispatch.count_dispatches() as cs:
        KS.key_switch(d, p, p.L, rlk, backend="staged")
    beta = p.beta(p.L)
    # fused: shared iNTT + one fused digit launch + batched P-block iNTT +
    # one fused ModDown launch
    assert dispatch.total(cf) == 4
    assert cf["fusedks"] == 1 and cf["fused_moddown"] == 1
    # staged: 7 launches per digit + 2×6 ModDown + shared iNTT
    assert dispatch.total(cs) == 7 * beta + 13
    assert dispatch.total(cf) < dispatch.total(cs)


# ---------------------------------------------------------------------------
# trace shape: boundary instructions & planner parity
# ---------------------------------------------------------------------------


def test_fused_stream_has_no_ws_boundaries(ks_setup):
    p, rlk = ks_setup
    d = _rand_eval(p, p.L, seed=4)
    with trace.capture_trace() as tf:
        KS.key_switch(d, p, p.L, rlk, backend="fused")
    with trace.capture_trace() as ts:
        KS.key_switch(d, p, p.L, rlk, backend="ref")
    n_f = sum(1 for i in tf if i.op in BOUNDARY)
    n_s = sum(1 for i in ts if i.op in BOUNDARY)
    beta = p.beta(p.L)
    assert n_f == 0
    assert n_s == 2 * (4 * beta + 2 * 4)  # 4 boundaries/digit + 4 per ModDown
    assert n_f < n_s
    # identical mathematical work on both streams
    assert _sig(tf) == _sig(ts, skip=BOUNDARY)


def test_planner_parity_both_pipelines(ks_setup):
    p, rlk = ks_setup
    pp = PL.PlanParams.of(p)
    for level in (p.L, p.alpha - 1):
        d = _rand_eval(p, level, seed=6)
        with trace.capture_trace() as tf:
            KS.key_switch(d, p, level, rlk, backend="fused")
        with trace.capture_trace() as ts:
            KS.key_switch(d, p, level, rlk, backend="staged")
        assert _sig(tf) == _sig(PL.key_switch(pp, level, fused=True))
        assert _sig(ts) == _sig(PL.key_switch(pp, level, fused=False))


# ---------------------------------------------------------------------------
# simulator accounting: fused_keyswitch vs the captured streams
# ---------------------------------------------------------------------------


def test_simulator_accounts_fused_stream(ks_setup):
    p, rlk = ks_setup
    d = _rand_eval(p, p.L, seed=8)
    with trace.capture_trace() as tf:
        KS.key_switch(d, p, p.L, rlk, backend="fused")
    with trace.capture_trace() as ts:
        KS.key_switch(d, p, p.L, rlk, backend="staged")
    chip = H.FLASH_FHE
    lanes = lanes_deep(chip)
    rf = simulate_stream(list(tf), chip, lanes)
    rs = simulate_stream(list(ts), chip, lanes)
    # same functional-unit work either way — fusion changes movement, not math
    for unit in ("ntt", "bconv", "modmul"):
        assert rf.unit_cycles[unit] == pytest.approx(rs.unit_cycles[unit])
    # the staged stream pays the boundary round-trips through HBM
    assert rs.hbm_bytes > rf.hbm_bytes
    assert rs.cycles >= rf.cycles
    # boundary traffic == Σ working-set bytes of the explicit records
    extra = sum(
        i.limbs * i.n * chip.word_bytes for i in ts if i.op in BOUNDARY
    )
    assert rs.hbm_bytes - rf.hbm_bytes == pytest.approx(extra)
