"""End-to-end CKKS scheme tests (ref backend, small rings)."""

import numpy as np
import pytest

from repro.fhe import keys as K
from repro.fhe import ops
from repro.fhe import params as P
from repro.fhe import trace


@pytest.fixture(scope="module")
def ctx():
    p = P.make_params(1 << 9, 6, 2, check_security=False)
    ks = K.full_keyset(p, seed=0, rotations=(1, 3, 7), conjugate=True)
    rng = np.random.default_rng(1)
    z = rng.normal(size=p.slots) * 0.5 + 1j * rng.normal(size=p.slots) * 0.5
    w = rng.normal(size=p.slots) * 0.5
    return p, ks, z, w


def test_encode_decode_roundtrip(ctx):
    p, ks, z, _ = ctx
    pt = ops.encode(p, z)
    np.testing.assert_allclose(ops.decode(p, pt), z, atol=1e-4)


def test_encrypt_decrypt(ctx):
    p, ks, z, _ = ctx
    ct = ops.encrypt(p, ks.pk, ops.encode(p, z))
    np.testing.assert_allclose(ops.decrypt_decode(p, ks.sk, ct), z, atol=1e-3)


def test_add_sub(ctx):
    p, ks, z, w = ctx
    a = ops.encrypt(p, ks.pk, ops.encode(p, z))
    b = ops.encrypt(p, ks.pk, ops.encode(p, w), seed=23)
    np.testing.assert_allclose(ops.decrypt_decode(p, ks.sk, ops.add(p, a, b)), z + w, atol=1e-3)
    np.testing.assert_allclose(ops.decrypt_decode(p, ks.sk, ops.sub(p, a, b)), z - w, atol=1e-3)


def test_add_plain_and_const(ctx):
    p, ks, z, w = ctx
    a = ops.encrypt(p, ks.pk, ops.encode(p, z))
    out = ops.add_plain(p, a, ops.encode(p, w, level=a.level, scale=a.scale))
    np.testing.assert_allclose(ops.decrypt_decode(p, ks.sk, out), z + w, atol=1e-3)
    out2 = ops.add_const(p, a, 0.25)
    np.testing.assert_allclose(ops.decrypt_decode(p, ks.sk, out2), z + 0.25, atol=1e-3)


def test_mul_relin_rescale(ctx):
    p, ks, z, w = ctx
    a = ops.encrypt(p, ks.pk, ops.encode(p, z))
    b = ops.encrypt(p, ks.pk, ops.encode(p, w), seed=29)
    m = ops.mul(p, a, b, ks.rlk)
    assert m.level == p.L - 1
    assert abs(np.log2(m.scale) - p.scale_bits) < 1.0  # scale stays stationary
    np.testing.assert_allclose(ops.decrypt_decode(p, ks.sk, m), z * w, atol=2e-3)


def test_mul_plain(ctx):
    p, ks, z, w = ctx
    a = ops.encrypt(p, ks.pk, ops.encode(p, z))
    m = ops.mul_plain(p, a, ops.encode(p, w, level=a.level))
    np.testing.assert_allclose(ops.decrypt_decode(p, ks.sk, m), z * w, atol=2e-3)
    m2 = ops.mul_const(p, a, -1.5)
    np.testing.assert_allclose(ops.decrypt_decode(p, ks.sk, m2), -1.5 * z, atol=2e-3)


@pytest.mark.parametrize("r", [1, 3, 7])
def test_rotate(ctx, r):
    p, ks, z, _ = ctx
    a = ops.encrypt(p, ks.pk, ops.encode(p, z))
    out = ops.rotate(p, a, r, ks)
    np.testing.assert_allclose(ops.decrypt_decode(p, ks.sk, out), np.roll(z, -r), atol=2e-3)


def test_conjugate(ctx):
    p, ks, z, _ = ctx
    a = ops.encrypt(p, ks.pk, ops.encode(p, z))
    out = ops.conjugate(p, a, ks)
    np.testing.assert_allclose(ops.decrypt_decode(p, ks.sk, out), np.conj(z), atol=2e-3)


def test_depth_chain(ctx):
    p, ks, _, w = ctx
    ref = 0.95 * w / np.abs(w).max()  # keep |x| < 1 so x^16 stays bounded
    cur = ops.encrypt(p, ks.pk, ops.encode(p, ref))
    for _ in range(4):
        cur = ops.square(p, cur, ks.rlk)
        ref = ref * ref
    assert cur.level == p.L - 4
    np.testing.assert_allclose(ops.decrypt_decode(p, ks.sk, cur), ref, atol=5e-3)


def test_trace_capture_records_pipeline(ctx):
    p, ks, z, w = ctx
    a = ops.encrypt(p, ks.pk, ops.encode(p, z))
    b = ops.encrypt(p, ks.pk, ops.encode(p, w), seed=5)
    with trace.capture_trace() as t:
        ops.mul(p, a, b, ks.rlk)
    names = [i.op for i in t]
    # key-switching is the iNTT→BConv→NTT pipeline
    assert "INTT" in names and "BCONV" in names and "NTT" in names
    assert names.index("INTT") < names.index("BCONV") < len(names)
    assert any(i.op == "LOAD_KSK" for i in t)


def test_deep_params_digit_structure():
    p = P.workload_params("logreg")
    assert p.num_digits == 2 and p.alpha == 17
    assert p.digit(0) == tuple(range(17)) and p.digit(1) == tuple(range(17, 34))
    assert p.beta(16) == 1 and p.beta(17) == 2  # fewer digits at low level
