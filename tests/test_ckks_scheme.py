"""End-to-end CKKS scheme tests (ref backend, small rings)."""

import numpy as np
import pytest

from repro.fhe import keys as K
from repro.fhe import params as P
from repro.fhe import trace
from repro.fhe.context import FheContext


@pytest.fixture(scope="module")
def ctx():
    p = P.make_params(1 << 9, 6, 2, check_security=False)
    ks = K.full_keyset(p, seed=0, rotations=(1, 3, 7), conjugate=True)
    c = FheContext(params=p, keys=ks)
    rng = np.random.default_rng(1)
    z = rng.normal(size=p.slots) * 0.5 + 1j * rng.normal(size=p.slots) * 0.5
    w = rng.normal(size=p.slots) * 0.5
    return c, z, w


def test_encode_decode_roundtrip(ctx):
    c, z, _ = ctx
    pt = c.encode(z)
    np.testing.assert_allclose(c.decode(pt), z, atol=1e-4)


def test_encrypt_decrypt(ctx):
    c, z, _ = ctx
    ct = c.encrypt(c.encode(z))
    np.testing.assert_allclose(c.decrypt_decode(ct), z, atol=1e-3)


def test_add_sub(ctx):
    c, z, w = ctx
    a = c.encrypt(c.encode(z))
    b = c.encrypt(c.encode(w), seed=23)
    np.testing.assert_allclose(c.decrypt_decode(c.add(a, b)), z + w, atol=1e-3)
    np.testing.assert_allclose(c.decrypt_decode(c.sub(a, b)), z - w, atol=1e-3)


def test_add_plain_and_const(ctx):
    c, z, w = ctx
    a = c.encrypt(c.encode(z))
    out = c.add_plain(a, c.encode(w, level=a.level, scale=a.scale))
    np.testing.assert_allclose(c.decrypt_decode(out), z + w, atol=1e-3)
    out2 = c.add_const(a, 0.25)
    np.testing.assert_allclose(c.decrypt_decode(out2), z + 0.25, atol=1e-3)


def test_mul_relin_rescale(ctx):
    c, z, w = ctx
    p = c.params
    a = c.encrypt(c.encode(z))
    b = c.encrypt(c.encode(w), seed=29)
    m = c.mul(a, b)
    assert m.level == p.L - 1
    assert abs(np.log2(m.scale) - p.scale_bits) < 1.0  # scale stays stationary
    np.testing.assert_allclose(c.decrypt_decode(m), z * w, atol=2e-3)


def test_mul_plain(ctx):
    c, z, w = ctx
    a = c.encrypt(c.encode(z))
    m = c.mul_plain(a, c.encode(w, level=a.level))
    np.testing.assert_allclose(c.decrypt_decode(m), z * w, atol=2e-3)
    m2 = c.mul_const(a, -1.5)
    np.testing.assert_allclose(c.decrypt_decode(m2), -1.5 * z, atol=2e-3)


@pytest.mark.parametrize("r", [1, 3, 7])
def test_rotate(ctx, r):
    c, z, _ = ctx
    a = c.encrypt(c.encode(z))
    out = c.rotate(a, r)
    np.testing.assert_allclose(c.decrypt_decode(out), np.roll(z, -r), atol=2e-3)


def test_conjugate(ctx):
    c, z, _ = ctx
    a = c.encrypt(c.encode(z))
    out = c.conjugate(a)
    np.testing.assert_allclose(c.decrypt_decode(out), np.conj(z), atol=2e-3)


def test_depth_chain(ctx):
    c, _, w = ctx
    p = c.params
    ref = 0.95 * w / np.abs(w).max()  # keep |x| < 1 so x^16 stays bounded
    cur = c.encrypt(c.encode(ref))
    for _ in range(4):
        cur = c.square(cur)
        ref = ref * ref
    assert cur.level == p.L - 4
    np.testing.assert_allclose(c.decrypt_decode(cur), ref, atol=5e-3)


def test_trace_capture_records_pipeline(ctx):
    c, z, w = ctx
    a = c.encrypt(c.encode(z))
    b = c.encrypt(c.encode(w), seed=5)
    with trace.capture_trace() as t:
        c.mul(a, b)
    names = [i.op for i in t]
    # key-switching is the iNTT→BConv→NTT pipeline
    assert "INTT" in names and "BCONV" in names and "NTT" in names
    assert names.index("INTT") < names.index("BCONV") < len(names)
    assert any(i.op == "LOAD_KSK" for i in t)


def test_deep_params_digit_structure():
    p = P.workload_params("logreg")
    assert p.num_digits == 2 and p.alpha == 17
    assert p.digit(0) == tuple(range(17)) and p.digit(1) == tuple(range(17, 34))
    assert p.beta(16) == 1 and p.beta(17) == 2  # fewer digits at low level
