"""Regression: model training must be dtype-stable when repro.fhe (which
enables x64) is imported first — the combined-framework configuration."""

import jax
import jax.numpy as jnp
import numpy as np

import repro.fhe  # noqa: F401  — enables x64, the trigger


def test_params_and_grads_stay_f32_under_x64():
    assert jax.config.read("jax_enable_x64")
    from jax.sharding import Mesh

    from repro import configs
    from repro.data import pipeline
    from repro.models import registry
    from repro.training import optimizer as opt, train_step as ts

    cfg = configs.get_config("smollm-135m", smoke=True)
    api = registry.build(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(params))
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    tokens = jnp.asarray(pipeline.synthetic_lm_batch(0, 0, 8, 32, cfg.vocab))
    step = ts.build_train_step(api, mesh, opt.AdamWConfig(), microbatch=4)
    p, s, m = jax.jit(step)(params, opt.init_state(params), {"tokens": tokens})
    assert m["loss"].dtype == jnp.float32
    assert np.isfinite(float(m["loss"]))
