"""repro.serve tests: event kernel, scheduler invariants (no overlapping
placements, every arrival completes, preemption conserves work), FIFO
baseline ordering, traffic determinism, closed loop, metrics sanity, and the
core.scheduler compatibility wrapper."""

import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import serve
from repro.core import hardware as H
from repro.core import jobs as J
from repro.core import scheduler as S
from repro.core.simulator import SimResult
from repro.serve.events import EventLoop
from repro.serve.policy import JobState

# cheap presets only (service sims are memoised per (chip, workload, kind))
SHALLOW = ("matmul", "lola_mnist_plain", "dblookup")
DEEP = ("lstm",)


def _random_jobs(seed: int, n: int) -> list:
    rng = random.Random(seed)
    jobs = []
    for i in range(n):
        pool = SHALLOW if rng.random() < 0.8 else DEEP
        jobs.append(J.make_job(rng.choice(pool), priority=rng.randint(0, 5),
                               arrival_cycle=rng.randint(0, 2_000_000), job_id=i))
    return jobs


# ---------------------------------------------------------------------------
# event kernel
# ---------------------------------------------------------------------------


def test_event_loop_orders_by_time_then_insertion():
    loop = EventLoop()
    seen = []
    loop.call_at(10.0, lambda: seen.append("b"))
    loop.call_at(5.0, lambda: seen.append("a"))
    loop.call_at(10.0, lambda: seen.append("c"))  # same time: insertion order
    assert loop.run() == 10.0
    assert seen == ["a", "b", "c"]


def test_event_loop_cancel_and_horizon():
    loop = EventLoop()
    seen = []
    ev = loop.call_at(5.0, lambda: seen.append("cancelled"))
    loop.call_at(7.0, lambda: seen.append("kept"))
    loop.call_at(100.0, lambda: seen.append("beyond"))
    ev.cancel()
    assert loop.run(until=50.0) == 50.0
    assert seen == ["kept"]
    assert len(loop) == 1  # the beyond-horizon event is still pending
    loop.run()
    assert seen == ["kept", "beyond"]


def test_event_loop_rejects_past_and_negative():
    loop = EventLoop()
    loop.call_at(5.0, lambda: None)
    loop.run()
    with pytest.raises(ValueError):
        loop.call_at(1.0, lambda: None)
    with pytest.raises(ValueError):
        loop.call_after(-1.0, lambda: None)


def test_event_heap_compacts_cancelled_events():
    """Lazy cancellation must not bloat the heap: compaction now fires on the
    cancellation itself (not just the next insertion), so even a pure
    cancellation burst — admission shedding revoking queued deadlines with no
    follow-up inserts — keeps cancelled entries bounded by max(32, live)."""
    loop = EventLoop()
    evs = [loop.call_at(1_000.0 + i, lambda: None) for i in range(500)]
    for e in evs[:400]:
        e.cancel()
        e.cancel()  # double-cancel must not double-count
        assert loop._n_cancelled <= 32 or 2 * loop._n_cancelled <= len(loop._heap)
    assert len(loop) == 100
    assert len(loop._heap) <= 2 * 100 + 32  # physically bounded, not just logically
    loop.call_at(5_000.0, lambda: None)
    assert len(loop) == 101
    loop.run()
    assert loop.processed == 101


# ---------------------------------------------------------------------------
# scheduler invariants (property tests over random job mixes)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=1, max_value=12))
def test_flash_policy_invariants(seed, n):
    """validate() asserts: every arrival completes, per-affiliation intervals
    never overlap (deep gangs occupy all), run segments sum to service +
    spill/restore (preemption conserves work)."""
    result = serve.serve(_random_jobs(seed, n), H.FLASH_FHE, validate=True)
    assert len(result.jobs) == n
    for je in result.jobs:
        assert je.state is JobState.DONE
        assert je.completion >= je.job.arrival_cycle
        if je.kind == "shallow":
            assert je.n_preemptions == 0  # only deep jobs are ever preempted
            assert je.lanes.startswith("affiliation-")


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=1, max_value=10))
def test_sequential_policy_invariants(seed, n):
    result = serve.serve(_random_jobs(seed, n), H.CRATERLAKE, validate=True)
    # non-preemptive whole-chip baseline: one contiguous segment per job
    for je in result.jobs:
        assert len(je.segments) == 1
        assert je.spill_restore_cycles == 0.0


def test_sequential_fifo_priority_ordering():
    """Baseline dispatch is highest-priority-then-arrival at every decision
    point; with simultaneous arrivals the start order must be the priority
    sort, not the submission order."""
    jobs = [J.make_job("matmul", priority=p, arrival_cycle=0, job_id=i)
            for i, p in enumerate([1, 4, 0, 3, 2])]
    result = serve.serve(jobs, H.CRATERLAKE)
    by_start = sorted(result.jobs, key=lambda je: je.first_start)
    assert [je.job.priority for je in by_start] == [4, 3, 2, 1, 0]
    # work-conserving: no idle gaps between consecutive jobs
    for prev, cur in zip(by_start, by_start[1:]):
        assert cur.first_start == pytest.approx(prev.completion)


# ---------------------------------------------------------------------------
# preemption state machine
# ---------------------------------------------------------------------------


def test_preemption_conserves_work_and_charges_deep():
    deep = J.make_job("lstm", priority=0, arrival_cycle=0, job_id=0)
    sh = J.make_job("matmul", priority=5, arrival_cycle=1000, job_id=1)
    result = serve.serve([deep, sh], H.FLASH_FHE, validate=True)
    d = next(je for je in result.jobs if je.kind == "deep")
    s = next(je for je in result.jobs if je.kind == "shallow")
    assert s.first_start == pytest.approx(1000)  # no convoy effect
    assert d.n_preemptions == 1
    assert d.state is JobState.DONE
    assert d.spill_restore_cycles > 0
    # work conservation: run segments == service + spill/restore, exactly
    assert d.busy_cycles == pytest.approx(d.service_cycles + d.spill_restore_cycles)
    # the deep job lost the suspension gap plus the spill/restore overhead
    assert d.preempted_cycles == pytest.approx(
        s.service_cycles + d.spill_restore_cycles)


def test_equal_priority_shallow_does_not_preempt():
    deep = J.make_job("lstm", priority=3, arrival_cycle=0, job_id=0)
    sh = J.make_job("matmul", priority=3, arrival_cycle=1000, job_id=1)
    result = serve.serve([deep, sh], H.FLASH_FHE)
    d = next(je for je in result.jobs if je.kind == "deep")
    s = next(je for je in result.jobs if je.kind == "shallow")
    assert d.n_preemptions == 0
    assert s.first_start >= d.completion  # shallow waited for the gang


def test_higher_priority_deep_fences_shallow():
    """A waiting deep job with strictly higher priority drains the chip:
    lower-priority shallow arrivals must not jump ahead of it."""
    deep = J.make_job("lstm", priority=9, arrival_cycle=0, job_id=0)
    sh = J.make_job("matmul", priority=0, arrival_cycle=0, job_id=1)
    result = serve.serve([deep, sh], H.FLASH_FHE)
    d = next(je for je in result.jobs if je.kind == "deep")
    s = next(je for je in result.jobs if je.kind == "shallow")
    assert d.first_start == pytest.approx(0.0)
    assert s.first_start >= d.completion


def test_zero_progress_preemption_spills_nothing():
    """Suspending a deep job that has not executed a cycle costs no spill."""
    deep = J.make_job("lstm", priority=0, arrival_cycle=0, job_id=0)
    # arrives one dispatch round later but before the deep job advances
    sh = J.make_job("matmul", priority=5, arrival_cycle=0, job_id=1)
    result = serve.serve([deep, sh], H.FLASH_FHE, validate=True)
    d = next(je for je in result.jobs if je.kind == "deep")
    assert d.spill_restore_cycles == 0.0  # shallow won placement at t=0
    assert d.busy_cycles == pytest.approx(d.service_cycles)


# ---------------------------------------------------------------------------
# traffic generation
# ---------------------------------------------------------------------------


def test_poisson_stream_deterministic():
    cfg = serve.PoissonConfig(rate_per_mcycle=5.0, n_jobs=40, seed=123)
    a, b = serve.poisson_jobs(cfg), serve.poisson_jobs(cfg)
    assert a == b
    c = serve.poisson_jobs(serve.PoissonConfig(rate_per_mcycle=5.0, n_jobs=40, seed=124))
    assert a != c
    assert [j.job_id for j in a] == list(range(40))
    arrivals = [j.arrival_cycle for j in a]
    assert arrivals == sorted(arrivals)


def test_serving_end_to_end_deterministic():
    cfg = serve.PoissonConfig(rate_per_mcycle=8.0, n_jobs=24,
                              mix=serve.traffic.SHALLOW_MIX,
                              priority_mix={0: 0.5, 5: 0.5}, seed=7)
    m1 = serve.summarize(serve.serve(serve.poisson_jobs(cfg), H.FLASH_FHE))
    m2 = serve.summarize(serve.serve(serve.poisson_jobs(cfg), H.FLASH_FHE))
    # NaN-aware equality: empty percentile samples (no deep jobs, no sheds)
    # report NaN, and NaN != NaN under plain ==
    assert m1.keys() == m2.keys()
    assert all(v == m2[k] or (np.isnan(v) and np.isnan(m2[k])) for k, v in m1.items())


def test_trace_jobs_tuples_and_dicts():
    tup = serve.trace_jobs([("matmul", 0), ("lstm", 500, 2)])
    assert tup[0].kind == "shallow" and tup[1].priority == 2
    dic = serve.trace_jobs([{"workload": "matmul", "arrival_cycle": 10,
                             "priority": 1, "job_id": 42, "tenant_id": 3}])
    assert dic[0].job_id == 42 and dic[0].tenant_id == 3


def test_closed_loop_survives_fractional_clock():
    """Regression: a non-integral spill pay (e.g. 1.2 GHz → fractional
    hbm_bytes_per_cycle) makes the clock fractional, and the closed-loop
    source's integer-rounded arrivals can land a fraction of a cycle in the
    past — the engine must clamp instead of raising."""
    import dataclasses

    chip = dataclasses.replace(H.FLASH_FHE, name="flash-1p2ghz", freq_ghz=1.2)
    src = serve.ClosedLoopSource(n_tenants=6, jobs_per_tenant=4,
                                 mix=serve.traffic.MIXED_MIX,
                                 priority_mix={0: 0.5, 5: 0.5},
                                 think_cycles=10_000, seed=4)
    result = serve.serve_source(src, chip, validate=True)
    assert len(result.jobs) == 24
    assert sum(je.n_preemptions for je in result.jobs) >= 1


def test_closed_loop_tenants_complete_all_jobs():
    src = serve.ClosedLoopSource(n_tenants=5, jobs_per_tenant=3,
                                 mix=serve.traffic.SHALLOW_MIX,
                                 think_cycles=10_000, seed=2)
    result = serve.serve_source(src, H.FLASH_FHE, validate=True)
    assert len(result.jobs) == 15
    per_tenant = {}
    for je in result.jobs:
        per_tenant[je.job.tenant_id] = per_tenant.get(je.job.tenant_id, 0) + 1
        assert je.state is JobState.DONE
    assert per_tenant == {t: 3 for t in range(5)}
    # one job in flight per tenant: a tenant's jobs never overlap in time
    for t in range(5):
        mine = sorted((je for je in result.jobs if je.job.tenant_id == t),
                      key=lambda je: je.job.arrival_cycle)
        for prev, cur in zip(mine, mine[1:]):
            assert cur.job.arrival_cycle >= prev.completion


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_metrics_sanity():
    cfg = serve.PoissonConfig(rate_per_mcycle=6.0, n_jobs=32, seed=5,
                              mix=serve.traffic.SHALLOW_MIX)
    m = serve.summarize(serve.serve(serve.poisson_jobs(cfg), H.FLASH_FHE))
    assert m["latency_p50_cycles"] <= m["latency_p95_cycles"] <= m["latency_p99_cycles"]
    assert m["queue_p50_cycles"] <= m["queue_p99_cycles"]
    assert 0.0 < m["util_mean"] <= 1.0 and m["util_max"] <= 1.0
    assert 0.0 < m["fairness_jain"] <= 1.0
    assert m["throughput_jobs_per_mcycle"] > 0
    assert m["n_jobs"] == 32 and m["n_deep"] == 0


def test_utilization_counts_deep_on_all_affiliations():
    result = serve.serve([J.make_job("lstm", job_id=0)], H.FLASH_FHE)
    busy = serve.metrics.per_affiliation_busy(result)
    assert len(busy) == H.FLASH_FHE.n_affiliations
    assert len(set(busy.values())) == 1  # gang occupies every affiliation equally
    m = serve.summarize(result)
    assert m["util_mean"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# starvation coverage (ROADMAP: deep-job aging/fairness)
# ---------------------------------------------------------------------------


def _saturating_shallow_plus_deep():
    """One deep job at t=0 under a same-priority shallow stream that keeps
    most affiliations busy for its whole span (matmul every 25 kcycles vs a
    ~181 kcycle service ⇒ ~7.3 of 8 affiliations occupied in steady state,
    never all free at once)."""
    rows = [("lstm", 0, 0)] + [("matmul", i * 25_000, 0) for i in range(240)]
    return serve.trace_jobs(rows)


def test_deep_starvation_metric_reports():
    """The `queue_max_deep_cycles` starvation counter: without the aging knob
    a saturating same-priority shallow stream keeps the deep job's gang from
    ever finding all affiliations free, so its worst-case queueing dwarfs the
    shallow one (this is the behaviour `aging_quanta` exists to fix)."""
    result = serve.serve(_saturating_shallow_plus_deep(), H.FLASH_FHE)
    d = next(je for je in result.jobs if je.kind == "deep")
    m = serve.summarize(result)
    assert m["queue_max_deep_cycles"] == pytest.approx(d.queueing_delay)
    assert serve.max_queueing_by_kind(result)["deep"] == pytest.approx(d.queueing_delay)
    # the deep job waited for (essentially) the whole shallow stream to drain
    assert m["queue_max_deep_cycles"] > 5_000_000
    assert m["queue_max_deep_cycles"] > 20 * max(m["queue_max_shallow_cycles"], 1.0)


def test_deep_job_not_starved_by_equal_priority_shallow_stream():
    """The aging/utilization-reserve knob: a same-priority deep job launches
    within a bounded number of shallow service quanta instead of waiting out
    the entire stream."""
    result = serve.serve(_saturating_shallow_plus_deep(), H.FLASH_FHE,
                         policy=serve.FlashPolicy(H.FLASH_FHE, aging_quanta=8.0))
    d = next(je for je in result.jobs if je.kind == "deep")
    shallow_service = next(je for je in result.jobs if je.kind == "shallow").service_cycles
    assert d.queueing_delay <= 10 * shallow_service


def test_aging_preserves_timeline_invariants():
    """The fence must not deadlock or double-book: the full validate() suite
    holds with aging active, and every shallow job still completes."""
    result = serve.serve(_saturating_shallow_plus_deep(), H.FLASH_FHE,
                         policy=serve.FlashPolicy(H.FLASH_FHE, aging_quanta=8.0),
                         validate=True)
    assert all(je.state is JobState.DONE for je in result.jobs)


def test_aging_resumes_suspended_deep_under_pressure():
    """A preempted (suspended) deep job under a saturating equal-priority
    shallow stream: the aged fence must drain the chip and resume it — and
    never deadlock (a stuck fence would leave queued jobs uncompleted and
    fail validate())."""
    rows = ([("lstm", 0, 0), ("matmul", 1_000, 5)]
            + [("matmul", 200_000 + i * 25_000, 0) for i in range(240)])
    result = serve.serve(serve.trace_jobs(rows), H.FLASH_FHE,
                         policy=serve.FlashPolicy(H.FLASH_FHE, aging_quanta=8.0),
                         validate=True)
    d = next(je for je in result.jobs if je.kind == "deep")
    assert d.n_preemptions >= 1  # the high-priority shallow job suspended it
    assert d.state is JobState.DONE
    # aged resume: it did not wait for the entire 6.2M-cycle stream to drain
    last_arrival = max(je.job.arrival_cycle for je in result.jobs)
    assert d.completion < last_arrival


def test_aging_respects_strictly_higher_priority_shallow():
    """An aged deep job fences equal/lower priorities only — strictly-higher
    priority shallow traffic still overtakes it."""
    rows = [("lstm", 0, 0)] + [("matmul", i * 25_000, 1) for i in range(240)]
    result = serve.serve(serve.trace_jobs(rows), H.FLASH_FHE,
                         policy=serve.FlashPolicy(H.FLASH_FHE, aging_quanta=8.0))
    d = next(je for je in result.jobs if je.kind == "deep")
    # higher-priority stream: the deep job drains behind the whole stream
    assert d.queueing_delay > 5_000_000


# ---------------------------------------------------------------------------
# deep_coop: swift clusters join deep gangs
# ---------------------------------------------------------------------------


def test_deep_coop_strictly_reduces_deep_p99():
    """FlashPolicy(deep_coop=True) on a deep-only stream: every deep job's
    gang also recruits the swift clusters through the L3 transpose, so the
    deep tail strictly improves vs the paper's boot-only gang."""
    rows = [("lstm", i * 4_000_000, 0) if i % 2 == 0
            else ("logreg", i * 4_000_000, 0) for i in range(6)]
    jobs = serve.trace_jobs(rows)
    base = serve.serve(jobs, H.FLASH_FHE)
    coop = serve.serve(jobs, H.FLASH_FHE,
                       policy=serve.FlashPolicy(H.FLASH_FHE, deep_coop=True))
    mb, mc = serve.summarize(base), serve.summarize(coop)
    assert mc["latency_p99_deep_cycles"] < mb["latency_p99_deep_cycles"]
    # per-job: coop is never slower, and the lane label names the mode
    for b, c in zip(base.jobs, coop.jobs):
        assert c.service_cycles < b.service_cycles
        assert "deep-coop" in c.lanes


def test_deep_coop_leaves_shallow_service_unchanged():
    """The coop flag only re-prices deep gangs — shallow jobs still run on
    their single affiliation with identical service time."""
    jobs = serve.trace_jobs([("matmul", i * 200_000, 0) for i in range(4)])
    base = serve.serve(jobs, H.FLASH_FHE)
    coop = serve.serve(jobs, H.FLASH_FHE,
                       policy=serve.FlashPolicy(H.FLASH_FHE, deep_coop=True))
    for b, c in zip(base.jobs, coop.jobs):
        assert c.service_cycles == b.service_cycles
        assert c.completion == b.completion


# ---------------------------------------------------------------------------
# core.scheduler compatibility wrapper
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=1, max_value=10))
def test_wrapper_differential_vs_engine(seed, n):
    """Differential: the compat wrapper must agree with the engine on seeded
    random job mixes — identical completion cycles and identical ordering —
    so it can't silently drift from `serve.serve`."""
    jobs = _random_jobs(seed, n)
    sched = S.schedule(jobs, H.FLASH_FHE)
    result = serve.serve(jobs, H.FLASH_FHE)
    assert [sj.job.job_id for sj in sched] == [je.job.job_id for je in result.jobs]
    for sj, je in zip(sched, result.jobs):
        assert sj.start_cycle == je.first_start  # exact, not approx
        assert sj.end_cycle == je.completion
        assert sj.preempted_cycles == je.preempted_cycles
    by_end_wrapper = [sj.job.job_id for sj in sorted(sched, key=lambda s: (s.end_cycle, s.job.job_id))]
    by_end_engine = [je.job.job_id for je in sorted(result.jobs, key=lambda j: (j.completion, j.job.job_id))]
    assert by_end_wrapper == by_end_engine


def test_wrapper_matches_engine():
    jobs = _random_jobs(seed=99, n=8)
    sched = S.schedule(jobs, H.FLASH_FHE)
    result = serve.serve(jobs, H.FLASH_FHE)
    assert len(sched) == len(result.jobs)
    for sj, je in zip(sched, result.jobs):
        assert sj.job is je.job
        assert sj.start_cycle == je.first_start
        assert sj.end_cycle == je.completion
        assert sj.lanes == je.lanes
    assert S.makespan(sched) == result.makespan


def test_wrapper_preempted_cycles_reported():
    """Regression for the old `preempted_cycles=preempt_pay` (always 0.0) bug."""
    deep = J.make_job("lstm", priority=0, arrival_cycle=0, job_id=0)
    sh = J.make_job("matmul", priority=5, arrival_cycle=1000, job_id=1)
    sched = S.schedule([deep, sh], H.FLASH_FHE)
    d = next(s for s in sched if s.job.kind == "deep")
    assert d.preempted_cycles > 0
    assert d.end_cycle - d.start_cycle == pytest.approx(
        d.sim.cycles + d.preempted_cycles)


# ---------------------------------------------------------------------------
# SimResult.time_s regression (lazy finalize)
# ---------------------------------------------------------------------------


def test_sim_result_time_s_without_finalize():
    r = SimResult(cycles=3e9, hbm_bytes=0.0, unit_cycles={}, cache_hit_ratio=0.0,
                  instr_count=0)
    assert r.time_s == pytest.approx(3.0)  # defaults to 1 GHz
    assert r.finalize(2.0).time_s == pytest.approx(1.5)
    r2 = SimResult(cycles=3e9, hbm_bytes=0.0, unit_cycles={}, cache_hit_ratio=0.0,
                   instr_count=0, freq_ghz=3.0)
    assert r2.time_s == pytest.approx(1.0)  # lazy, from the stored frequency


# ---------------------------------------------------------------------------
# service-sim memoisation: the kernel/hoisting mode is part of the memo key
# ---------------------------------------------------------------------------


def test_service_memo_keys_on_hoisting_mode():
    """Changing the kernel mode must change the memo entry — a memo keyed only
    on (chip, workload, kind) would silently reuse pre-hoisting cycle counts
    for post-hoisting callers."""
    job = J.make_job("lstm")
    base = serve.job_service_sim(job, H.FLASH_FHE)
    hoisted = serve.job_service_sim(job, H.FLASH_FHE, hoist=True)
    assert hoisted is not base
    # each mode memoises separately and stays stable
    assert serve.job_service_sim(job, H.FLASH_FHE, hoist=True) is hoisted
    assert serve.job_service_sim(job, H.FLASH_FHE) is base
    # hoisting must actually shrink the simulated deep (CtS/StC-heavy) service
    assert hoisted.cycles < base.cycles


def test_engine_threads_hoist_mode_to_service_sim():
    r0 = serve.serve([J.make_job("lstm", job_id=0)], H.FLASH_FHE)
    r1 = serve.serve([J.make_job("lstm", job_id=0)], H.FLASH_FHE, hoist=True)
    assert r1.jobs[0].service_cycles < r0.jobs[0].service_cycles
