"""BGV subsystem: differential correctness against a u64 oracle + identity.

Four contracts pinned here:

  * **oracle parity** — every BGV op (encode/encrypt roundtrip, add/sub/neg,
    mul+relin+mod-switch chains) is bit-exact mod t against a plain-integer
    negacyclic-convolution oracle, across plaintext moduli, levels, and both
    key-switch pipelines (hypothesis-driven);
  * **backend bit-exactness** — the fused Pallas pipeline and the staged
    reference produce identical ciphertext limbs (the t-wrap sandwich runs the
    unmodified ModDown kernels between two pointwise scalings, so this is
    inherited from the CKKS parity rather than re-proven — pinned anyway);
  * **policy identity** — the scheme-tagged ``ExecPolicy.policy_key()`` never
    aliases across (scheme, backend, hoisting, numerics), contexts coerce the
    policy scheme to the params' ground truth, and the serving service-time
    memo keys mixed CKKS/BGV jobs distinctly;
  * **planner parity** — ``core.planner.bgv_hmul``/``bgv_mod_switch`` match
    the captured execution traces instruction-for-instruction, in both
    pipelines, so the serving simulator prices BGV off the real dataflow.
"""

import collections
import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import hardware as H
from repro.core import jobs as J
from repro.core import planner as PL
from repro.fhe import keys as K
from repro.fhe import params as P
from repro.fhe import trace
from repro.fhe.context import (
    BACKENDS,
    HOISTING_MODES,
    NUMERICS_MODES,
    SCHEMES,
    ExecPolicy,
    FheContext,
)
from repro.serve import policy as SP

PIPELINES = ("ref", "fused")  # staged oracle vs fused accelerator pipeline


def oracle_mul(a: np.ndarray, b: np.ndarray, n: int, t: int) -> np.ndarray:
    """Negacyclic convolution mod t — the ring product X^n + 1 induces on
    coefficient-packed messages (the semantics ``bgv._encode`` documents)."""
    conv = np.convolve(a.astype(np.int64), b.astype(np.int64))
    res = np.zeros(n, np.int64)
    res[: min(n, conv.shape[0])] += conv[:n]
    wrap = conv[n:]
    res[: wrap.shape[0]] -= wrap
    return res % t


@pytest.fixture(scope="module", params=(2, 1 << 16), ids=("t=2", "t=2^16"))
def bgv(request):
    t = request.param
    p = P.make_params(1 << 9, 5, 2, check_security=False, plain_modulus=t)
    ks = K.full_keyset(p, seed=0)
    return p, ks, FheContext(params=p, keys=ks), t


def _msgs(rng: np.random.Generator, n: int, t: int, k: int = 2):
    return [rng.integers(0, t, size=n).astype(np.int64) for _ in range(k)]


# ---------------------------------------------------------------------------
# oracle parity: encode/encrypt roundtrip and the additive ops
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_encode_decode_roundtrip(bgv, seed):
    p, _, ctx, t = bgv
    (z,) = _msgs(np.random.default_rng(seed), p.n, t, k=1)
    assert np.array_equal(ctx.decode(ctx.encode(z)), z % t)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), backend=st.sampled_from(PIPELINES))
def test_additive_ops_vs_oracle(bgv, seed, backend):
    p, _, ctx, t = bgv
    ctx = ctx.with_policy(backend=backend)
    rng = np.random.default_rng(seed)
    za, zb = _msgs(rng, p.n, t)
    ct_a = ctx.encrypt(ctx.encode(za), seed=seed)
    ct_b = ctx.encrypt(ctx.encode(zb), seed=seed + 1)
    assert np.array_equal(ctx.decrypt_decode(ct_a), za % t)
    assert np.array_equal(ctx.decrypt_decode(ctx.add(ct_a, ct_b)), (za + zb) % t)
    assert np.array_equal(ctx.decrypt_decode(ctx.sub(ct_a, ct_b)), (za - zb) % t)
    assert np.array_equal(ctx.decrypt_decode(ctx.negate(ct_a)), (-za) % t)


# ---------------------------------------------------------------------------
# oracle parity: multiplication across levels / pipelines / dnum
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), backend=st.sampled_from(PIPELINES),
       level=st.sampled_from((5, 4, 2)))
def test_mul_vs_oracle_across_levels(bgv, seed, backend, level):
    """One mul (relin + mod switch) starting from every tested level."""
    p, _, ctx, t = bgv
    ctx = ctx.with_policy(backend=backend)
    rng = np.random.default_rng(seed)
    za, zb = _msgs(rng, p.n, t)
    ct_a = ctx.encrypt(ctx.encode(za, level=level), seed=seed)
    ct_b = ctx.encrypt(ctx.encode(zb, level=level), seed=seed + 1)
    got = ctx.mul(ct_a, ct_b)
    assert got.level == level - 1  # mod switch dropped exactly one limb
    assert np.array_equal(ctx.decrypt_decode(got), oracle_mul(za, zb, p.n, t))


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), backend=st.sampled_from(PIPELINES))
def test_mul_depth2_and_square_vs_oracle(bgv, seed, backend):
    """(a·b)·c and (a²) — chained products stay exact through the level drops."""
    p, _, ctx, t = bgv
    ctx = ctx.with_policy(backend=backend)
    rng = np.random.default_rng(seed)
    za, zb, zc = _msgs(rng, p.n, t, k=3)
    ct_a = ctx.encrypt(ctx.encode(za), seed=seed)
    ct_b = ctx.encrypt(ctx.encode(zb), seed=seed + 1)
    ct_c = ctx.encrypt(ctx.encode(zc), seed=seed + 2)
    ab = oracle_mul(za, zb, p.n, t)
    got = ctx.mul(ctx.mul(ct_a, ct_b), ct_c)
    assert np.array_equal(ctx.decrypt_decode(got), oracle_mul(ab, zc, p.n, t))
    assert np.array_equal(ctx.decrypt_decode(ctx.square(ct_a)),
                          oracle_mul(za, za, p.n, t))


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), dnum=st.sampled_from((1, 2, 3)))
def test_mul_vs_oracle_across_dnum(seed, dnum):
    """The digit count only reshapes the hybrid key switch — never the result."""
    t = 1 << 8
    p = P.make_params(1 << 9, 5, dnum, check_security=False, plain_modulus=t)
    ctx = FheContext(params=p, keys=K.full_keyset(p, seed=0))
    rng = np.random.default_rng(seed)
    za, zb = _msgs(rng, p.n, t)
    ct_a = ctx.encrypt(ctx.encode(za), seed=seed)
    ct_b = ctx.encrypt(ctx.encode(zb), seed=seed + 1)
    assert np.array_equal(ctx.decrypt_decode(ctx.mul(ct_a, ct_b)),
                          oracle_mul(za, zb, p.n, t))


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mul_backends_bitexact(bgv, seed):
    """Fused and staged pipelines agree on every ciphertext limb, not just the
    decrypted message — the t-wrap sandwich preserves the CKKS parity."""
    p, _, ctx, t = bgv
    rng = np.random.default_rng(seed)
    za, zb = _msgs(rng, p.n, t)
    cts = {}
    for backend in PIPELINES:
        c = ctx.with_policy(backend=backend)
        cts[backend] = c.mul(c.encrypt(c.encode(za), seed=seed),
                             c.encrypt(c.encode(zb), seed=seed + 1))
    ref, fused = cts["ref"], cts["fused"]
    assert bool(jnp.array_equal(ref.c0, fused.c0))
    assert bool(jnp.array_equal(ref.c1, fused.c1))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), backend=st.sampled_from(PIPELINES))
def test_mod_switch_preserves_message(bgv, seed, backend):
    p, _, ctx, t = bgv
    ctx = ctx.with_policy(backend=backend)
    (z,) = _msgs(np.random.default_rng(seed), p.n, t, k=1)
    ct = ctx.encrypt(ctx.encode(z), seed=seed)
    down = ctx.mod_switch(ct)
    assert down.level == ct.level - 1
    assert np.array_equal(ctx.decrypt_decode(down), z % t)


# ---------------------------------------------------------------------------
# policy identity: scheme-tagged keys, context coercion, serving memo
# ---------------------------------------------------------------------------


def test_policy_key_no_aliasing_across_schemes():
    combos = list(itertools.product(SCHEMES, BACKENDS, HOISTING_MODES, NUMERICS_MODES))
    keys = {ExecPolicy(backend=b, hoisting=h, numerics=m, scheme=s).policy_key()
            for s, b, h, m in combos}
    assert len(keys) == len(combos)
    assert all(k[0] in SCHEMES for k in keys)  # the scheme leads the tuple


def test_context_coerces_policy_scheme(bgv):
    p, ks, ctx, _ = bgv
    assert ctx.scheme == "bgv" and ctx.policy_key()[0] == "bgv"
    # a CKKS-tagged policy over BGV params is re-tagged at construction
    mis = FheContext(params=p, keys=ks, policy=ExecPolicy(scheme="ckks"))
    assert mis.scheme == "bgv" and mis.policy_key()[0] == "bgv"
    ckks_p = P.make_params(1 << 9, 5, 2, check_security=False)
    ckks_ctx = FheContext(params=ckks_p, policy=ExecPolicy(scheme="bgv"))
    assert ckks_ctx.scheme == "ckks"


def test_scheme_op_guards(bgv):
    p, _, ctx, t = bgv
    ct = ctx.encrypt(ctx.encode(np.arange(8) % t))
    with pytest.raises(ValueError, match="mod_switch"):
        ctx.rescale(ct)
    ckks_p = P.make_params(1 << 9, 5, 2, check_security=False)
    ckks_ctx = FheContext(params=ckks_p, keys=K.full_keyset(ckks_p, seed=0))
    ckks_ct = ckks_ctx.encrypt(ckks_ctx.encode(np.zeros(ckks_p.slots)))
    with pytest.raises(ValueError, match="BGV op"):
        ckks_ctx.mod_switch(ckks_ct)


def test_preset_scheme_tags_and_job_scheme():
    for name in P.BGV_WORKLOADS:
        assert P.workload_scheme(name) == "bgv"
        assert J.make_job(name).scheme == "bgv"
    assert J.make_job("lola_mnist_plain").scheme == "ckks"


def test_serving_memo_keys_schemes_distinctly():
    """psi and lola_mnist_plain share (N, L, dnum, kind) — only the scheme in
    the policy key separates their cached service times from a common default
    policy, and the BGV job must actually be priced off the BGV expansion."""
    chip = H.FLASH_FHE
    pol = ExecPolicy(backend="fused", hoisting="always")
    r_bgv = SP.job_service_sim(J.make_job("psi"), chip, policy=pol)
    r_ckks = SP.job_service_sim(J.make_job("lola_mnist_plain"), chip, policy=pol)
    schemes = {key[3][0] for key in SP._SERVICE_MEMO
               if key[0] == chip and key[1] in ("psi", "lola_mnist_plain")}
    assert schemes == {"bgv", "ckks"}
    assert r_bgv.cycles != r_ckks.cycles  # distinct expansions, distinct prices


# ---------------------------------------------------------------------------
# planner parity: analytic BGV streams == captured execution traces
# ---------------------------------------------------------------------------


def _sig(instrs):
    """Multiset signature of (op, n, limbs) triples (ignoring meta)."""
    return collections.Counter((i.op, i.n, i.limbs) for i in instrs)


@pytest.mark.parametrize("backend,fused", [("ref", False), ("fused", True)])
def test_planner_bgv_hmul_matches_execution(bgv, backend, fused):
    p, _, ctx, t = bgv
    ctx = ctx.with_policy(backend=backend)
    rng = np.random.default_rng(11)
    za, zb = _msgs(rng, p.n, t)
    ct_a = ctx.encrypt(ctx.encode(za), seed=3)
    ct_b = ctx.encrypt(ctx.encode(zb), seed=4)
    with trace.capture_trace() as tr:
        ctx.mul(ct_a, ct_b)
    pp = PL.PlanParams.of(p)
    assert _sig(tr) == _sig(PL.bgv_hmul(pp, p.L, mod_switch_after=True, fused=fused))


def test_planner_bgv_mod_switch_matches_execution(bgv):
    p, _, ctx, t = bgv
    (z,) = _msgs(np.random.default_rng(7), p.n, t, k=1)
    ct = ctx.encrypt(ctx.encode(z), seed=5)
    with trace.capture_trace() as tr:
        ctx.mod_switch(ct)
    pp = PL.PlanParams.of(p)
    assert _sig(tr) == _sig(PL.bgv_mod_switch(pp, p.L))


def test_bgv_workload_streams_priced():
    """The registered BGV presets expand to non-trivial planner streams."""
    for name in P.BGV_WORKLOADS:
        st_ = PL.workload_stream(name, P.workload_params(name), mode="hw")
        assert len(st_) > 10
        assert any(i.op == "LOAD_KSK" for i in st_)  # relinearisations present
