"""Assemble the §Dry-run / §Roofline sections of EXPERIMENTS.md from the
dry-run artifacts.  Idempotent: replaces everything below the marker line.

    PYTHONPATH=src:. python -m benchmarks.finalize_experiments
"""

from __future__ import annotations


from . import roofline_table as rt

MARKER = "<!-- AUTOGEN:ROOFLINE -->"


def _fmt_pct(x):
    return f"{100*x:.1f}%" if x is not None else "—"


def build_section() -> str:
    recs = rt.load_records()
    base = [r for r in recs if r.get("policy", "tp") == "tp" and not r.get("block_skip")]
    pod1 = [r for r in base if r.get("mesh") == "16x16"]
    pod2 = [r for r in base if r.get("mesh") == "pod2x16x16"]
    opt = [r for r in recs if r not in base]

    lines = [MARKER, "", "### Dry-run status (auto-generated)", ""]
    for name, rs in (("single-pod 16×16", pod1), ("multi-pod 2×16×16", pod2)):
        ok = sum(1 for r in rs if r["status"] == "ok")
        sk = sum(1 for r in rs if r["status"] == "skipped")
        fa = sum(1 for r in rs if r["status"] == "FAILED")
        lines.append(f"- **{name}**: {ok} compiled, {sk} N/A-by-design, {fa} failed "
                     f"({len(rs)}/40 cells recorded)")
    lines += ["", "### §Roofline table — single-pod 16×16 (256 chips), baseline policy", ""]
    lines.append(rt.table_markdown(pod1, mesh="16x16"))

    doms = {}
    fracs = []
    for r in pod1:
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        doms[rl["dominant"]] = doms.get(rl["dominant"], 0) + 1
        tot = rl["compute_s"] + rl["memory_s"] + rl["collective_s"]
        if tot > 0:
            fracs.append((r["arch"], r["shape"], rl["compute_s"] / tot, rl["dominant"]))
    lines += ["", f"Dominant-term histogram: {doms}.", ""]
    if fracs:
        worst = sorted(fracs, key=lambda x: x[2])[:5]
        lines.append("Lowest compute fraction (hillclimb candidates): " +
                     ", ".join(f"{a}×{s} ({c:.0%}, {d})" for a, s, c, d in worst))

    if opt:
        lines += ["", "### §Perf — optimized LM cells (vs baseline above)", "",
                  "| cell | knob | compute s | memory s | collective s | dominant |",
                  "|---|---|---|---|---|---|"]
        for r in opt:
            if r.get("status") != "ok":
                continue
            knob = ("dp-policy" if r.get("policy") == "dp" else "") + \
                   ("+block-skip" if r.get("block_skip") else "")
            rl = r["roofline"]
            lines.append(f"| {r['arch']}×{r['shape']} | {knob} | {rl['compute_s']:.2e} "
                         f"| {rl['memory_s']:.2e} | {rl['collective_s']:.2e} "
                         f"| {rl['dominant']} |")
    return "\n".join(lines) + "\n"


def main():
    with open("EXPERIMENTS.md") as f:
        content = f.read()
    if MARKER in content:
        content = content.split(MARKER)[0]
    with open("EXPERIMENTS.md", "w") as f:
        f.write(content + build_section())
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
