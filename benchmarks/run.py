"""Benchmark harness: one entry per paper table/figure + the roofline table.

Emits ``name,value,derived`` CSV rows (derived=1 marks numbers reconstructed
from the paper's reported ratios rather than simulated from architecture).

  python -m benchmarks.run                 # full paper-figure suite + all benches
  python -m benchmarks.run --smoke         # fast CI pass: fused-KS + hoisting row
                                           #   + fleet scale-out/hetero/gang smoke
  python -m benchmarks.run --out FILE.csv  # also write the rows to FILE.csv
"""

from __future__ import annotations

import argparse
import sys
import time

from . import fusedks_bench


class _Emitter:
    def __init__(self, out_path: str | None):
        self._fh = open(out_path, "w") if out_path else None
        self.rows: list[tuple[str, object]] = []  # every emitted (name, value)

    def __call__(self, name: str, value, derived: int = 0):
        self.rows.append((name, value))
        if isinstance(value, float):
            value = f"{value:.6g}"
        row = f"{name},{value},{derived}"
        print(row)
        if self._fh:
            self._fh.write(row + "\n")

    def close(self):
        if self._fh:
            self._fh.close()


def emit_fusedks(emit, smoke: bool, iters: int) -> None:
    """Fused vs staged key-switch: the dispatch-count/wall-clock comparison."""
    for cfg, row in fusedks_bench.run(smoke=smoke, iters=iters).items():
        for key in (
            "bitexact", "dispatches_fused", "dispatches_staged",
            "dispatch_reduction", "wall_ms_fused", "wall_ms_staged",
        ):
            emit(f"fusedks.{cfg}.{key}", row[key])


def emit_hoisting(emit, smoke: bool, iters: int) -> None:
    """Hoisted vs per-rotation rotations: amortisation rows.

    --smoke runs one SMALL group config only (seconds) — the N=2^14 CtS-stage
    gate configs are owned by the dedicated hoisting-smoke CI job
    (`benchmarks.hoisting_bench --smoke`), which is also the only place the
    gates can actually fail the build; duplicating the heavy run here would
    cost minutes per push for an advisory CSV row."""
    from . import hoisting_bench

    if smoke:
        rows = [hoisting_bench.bench_group(1 << 10, 8, 2, 12, iters=iters)]
    else:
        rows = hoisting_bench.run(smoke=False, iters=iters)
    for r in rows:
        for key in ("bitexact", "ext_ntt_hoisted", "ext_ntt_staged",
                    "dispatch_ratio", "wall_ms_hoisted", "wall_ms_staged",
                    "wall_speedup"):
            emit(f"hoisting.{r['config']}.{key}", r[key])
    if not smoke:
        failures = hoisting_bench.check_gates(rows)
        emit("hoisting.gates_dispatch_and_wallclock", int(not failures))


def emit_serving(emit, smoke: bool) -> None:
    """Multi-tenant serving: SLO metrics per (scenario, chip) + claim check."""
    from . import serving_bench

    rows = serving_bench.run(smoke=smoke)
    for r in rows:
        prefix = f"serving.{r['scenario']}.{r['chip']}"
        for key in ("latency_p50_cycles", "latency_p99_cycles", "queue_p99_cycles",
                    "makespan_mcycles", "throughput_jobs_per_mcycle",
                    "util_mean", "fairness_jain", "n_preemptions"):
            emit(f"{prefix}.{key}", r[key])
    failures = serving_bench.check_paper_claim(rows)
    emit("serving.claim_flash_beats_craterlake", int(not failures))


def emit_multischeme(emit, smoke: bool) -> None:
    """Mixed CKKS+BGV serving: per-(scenario, chip) SLOs + the scheme gates."""
    from . import multischeme_bench

    rows = multischeme_bench.run(smoke=smoke)
    for r in rows:
        prefix = f"multischeme.{r['scenario']}.{r['chip']}"
        for key in ("n_ckks", "n_bgv", "latency_p99_shallow_cycles",
                    "latency_p99_cycles", "makespan_mcycles", "util_mean",
                    "n_preemptions"):
            emit(f"{prefix}.{key}", r[key])
    failures = multischeme_bench.check_paper_claim(rows)
    emit("multischeme.claim_flash_beats_craterlake", int(not failures))


def emit_cluster(emit, smoke: bool) -> None:
    """Fleet scale-out + heterogeneous/gang scenarios: throughput/p99 per
    (scenario, fleet, router, chips, gang) row, plus the four gates."""
    from . import cluster_bench

    rows = cluster_bench.run(smoke=smoke)
    for r in rows:
        prefix = (f"cluster.{r['scenario']}.{r['fleet']}.{r['router']}"
                  f".chips{int(r['n_chips'])}.gang{int(r['gang'])}")
        for key in ("latency_p99_cycles", "latency_p99_deep_cycles",
                    "queue_p99_cycles", "makespan_mcycles",
                    "throughput_jobs_per_mcycle", "chip_util_imbalance",
                    "fairness_jain_chips", "n_cold_starts", "n_gang_jobs"):
            emit(f"{prefix}.{key}", r[key])
    failures = cluster_bench.check_gates(rows)
    emit("cluster.gates_scaleout_hetero_gang", int(not failures))


def emit_overload(emit, smoke: bool) -> None:
    """Overload/admission SLO table: goodput, drop rate, per-kind p99, and
    peak backlog per (chips, load, admission) diurnal run, plus the admission
    gates (flat tail + goodput floor with admission, divergence without)."""
    from . import overload_bench

    rows = overload_bench.run(smoke=smoke)
    for r in rows:
        prefix = (f"overload.{r['scenario']}.chips{int(r['n_chips'])}"
                  f".load{r['load_x']:g}.adm{int(r['admission'])}")
        for key in ("goodput_frac", "drop_rate", "drop_rate_shallow", "drop_rate_deep",
                    "latency_p99_shallow_cycles", "latency_p99_deep_cycles",
                    "peak_backlog_mcycles", "fairness_jain",
                    "time_to_shed_p99_cycles", "n_completed_shallow"):
            emit(f"{prefix}.{key}", r[key])
    failures = overload_bench.check_gates(rows)
    emit("overload.gates_flat_tail_goodput_divergence", int(not failures))


def emit_faults(emit, smoke: bool) -> None:
    """Fault-tolerance table: goodput/loss/retry/availability per scenario
    (fault-free baseline, crash with and without recovery, flaky, straggler),
    plus the recovery gates (goodput floor through the outage, loss
    divergence without recovery, retries recorded)."""
    from . import fault_bench

    rows = fault_bench.run(smoke=smoke)
    for r in rows:
        prefix = f"faults.{r['scenario']}.chips{int(r['n_chips'])}"
        for key in ("goodput_frac", "n_failed", "retries_total",
                    "n_retried_jobs", "wasted_mcycles",
                    "checkpoint_saved_mcycles", "availability",
                    "downtime_mcycles", "latency_p99_shallow_cycles"):
            emit(f"{prefix}.{key}", r[key])
    failures = fault_bench.check_gates(rows)
    emit("faults.gates_goodput_loss_divergence", int(not failures))


def emit_paper_figs(emit) -> None:
    from . import paper_figs, roofline_table

    fig9 = paper_figs.fig9_single_workload()
    emit("fig9.deep_geomean_vs_craterlake", fig9["deep_geomean_vs_craterlake"])
    emit("fig9.deep_geomean_vs_f1plus", fig9["deep_geomean_vs_f1plus"])
    for w, row in fig9["rows"].items():
        emit(f"fig9.{w}.flash_fhe_ms", row["flash_fhe_ms"])
        emit(f"fig9.{w}.craterlake_over_ff", row["craterlake_over_ff"])
        emit(f"fig9.{w}.f1plus_over_ff", row["f1plus_over_ff"])

    fig10 = paper_figs.fig10_7nm()
    emit("fig10.ff_logreg_ms", fig10["ff_logreg_ms"])
    emit("fig10.ff_resnet20_ms", fig10["ff_resnet20_ms"])
    emit("fig10.ark_logreg_ms", fig10["ark_logreg_ms_derived"], 1)
    emit("fig10.perf_per_area_vs_ark_logreg", fig10["perf_per_area_vs_ark_logreg"], 1)

    fig11 = paper_figs.fig11_ntt_hmul()
    emit("fig11.ntt_ops_per_s", fig11["ntt_ops_per_s"])
    emit("fig11.hmul_ops_per_s", fig11["hmul_ops_per_s"])
    emit("fig11.tensorfhe_ntt_ops_per_s", fig11["tensorfhe_ntt_derived"], 1)

    fig12 = paper_figs.fig12_multi_shallow()
    emit("fig12.peak_multi_job_speedup", fig12["peak_speedup"])
    for k, v in fig12["per_job_count"].items():
        emit(f"fig12.jobs{k}.makespan_speedup", v["makespan_speedup"])

    fig8 = paper_figs.fig8_cache_sweep()
    emit("fig8.dnum1_saturates_at_320MB", int(fig8["dnum1_saturates_at_320MB"]))
    for dnum, curve in fig8["curves_ms"].items():
        for cap, ms in curve.items():
            emit(f"fig8.{dnum}.cache{cap}MB_ms", ms)

    t3 = paper_figs.table3_area()
    emit("table3.total_14nm_mm2", t3["total_14nm_mm2"])
    emit("table3.swift_logic_fraction", t3["swift_logic_fraction"])
    emit("table3.claim_under_7pct", int(t3["claim_under_7pct"]))

    fig13 = paper_figs.fig13_power()
    emit("fig13.total_w", fig13["total_w"])
    emit("fig13.vs_craterlake", fig13["vs_craterlake"])

    pre = paper_figs.preemption_study()
    emit("preemption.shallow_turnaround_speedup", pre["shallow_avg_turnaround_speedup"])

    perf = paper_figs.perf_beyond_paper()
    for w, row in perf.items():
        emit(f"perf.{w}.baseline_ms", row["baseline_ms"])
        emit(f"perf.{w}.optimized_ms", row["optimized_ms"])
        emit(f"perf.{w}.speedup", row["speedup"])

    rt = roofline_table.main()
    emit("roofline.cells_ok", rt["summary"]["ok"])
    emit("roofline.cells_skipped", rt["summary"]["skipped"])
    emit("roofline.cells_failed", rt["summary"]["failed"])
    for dom, n in rt["dominant_histogram"].items():
        emit(f"roofline.dominant.{dom}", n)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI pass: fused-vs-staged key-switch (small ring) "
                         "+ a small hoisted-rotation group row (the N=2^14 "
                         "CtS-stage GATES run only in benchmarks.hoisting_bench) "
                         "+ fleet scale-out/hetero/gang smoke (all four cluster "
                         "gates enforced) + mixed CKKS/BGV serving smoke (scheme "
                         "gates enforced) + diurnal overload/admission smoke "
                         "(flat-tail/goodput/divergence gates enforced) + "
                         "fault-tolerance smoke (recovery goodput/loss gates "
                         "enforced)")
    ap.add_argument("--out", default=None, help="also write CSV rows to this file")
    ap.add_argument("--iters", type=int, default=3, help="timing iterations per config")
    ap.add_argument("--history", nargs="?", const="BENCH_HISTORY.json", default=None,
                    metavar="FILE",
                    help="append every emitted row to the perf-history JSON "
                         "(default FILE: BENCH_HISTORY.json); run "
                         "tools/bench_history.py check-regression afterwards "
                         "to compare against the trailing median")
    args = ap.parse_args(argv)

    emit = _Emitter(args.out)
    t0 = time.time()
    try:
        emit_fusedks(emit, smoke=args.smoke, iters=args.iters)
        emit_hoisting(emit, smoke=args.smoke, iters=args.iters)
        emit_cluster(emit, smoke=args.smoke)
        emit_multischeme(emit, smoke=args.smoke)
        emit_overload(emit, smoke=args.smoke)
        emit_faults(emit, smoke=args.smoke)
        if not args.smoke:
            emit_paper_figs(emit)
            emit_serving(emit, smoke=False)
        emit("bench.total_seconds", time.time() - t0)
    finally:
        emit.close()
    if args.history:
        from repro.obs import history
        n = history.append_rows(args.history, emit.rows)
        print(f"# appended {n} rows to {args.history}", file=sys.stderr)


if __name__ == "__main__":
    main(sys.argv[1:])
