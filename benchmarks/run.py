"""Benchmark harness: one entry per paper table/figure + the roofline table.

Prints ``name,value,derived`` CSV rows (derived=1 marks numbers reconstructed
from the paper's reported ratios rather than simulated from architecture).
"""

from __future__ import annotations

import time

from . import paper_figs, roofline_table


def _emit(name: str, value, derived: int = 0):
    if isinstance(value, float):
        value = f"{value:.6g}"
    print(f"{name},{value},{derived}")


def main() -> None:
    t0 = time.time()

    fig9 = paper_figs.fig9_single_workload()
    _emit("fig9.deep_geomean_vs_craterlake", fig9["deep_geomean_vs_craterlake"])
    _emit("fig9.deep_geomean_vs_f1plus", fig9["deep_geomean_vs_f1plus"])
    for w, row in fig9["rows"].items():
        _emit(f"fig9.{w}.flash_fhe_ms", row["flash_fhe_ms"])
        _emit(f"fig9.{w}.craterlake_over_ff", row["craterlake_over_ff"])
        _emit(f"fig9.{w}.f1plus_over_ff", row["f1plus_over_ff"])

    fig10 = paper_figs.fig10_7nm()
    _emit("fig10.ff_logreg_ms", fig10["ff_logreg_ms"])
    _emit("fig10.ff_resnet20_ms", fig10["ff_resnet20_ms"])
    _emit("fig10.ark_logreg_ms", fig10["ark_logreg_ms_derived"], 1)
    _emit("fig10.perf_per_area_vs_ark_logreg", fig10["perf_per_area_vs_ark_logreg"], 1)

    fig11 = paper_figs.fig11_ntt_hmul()
    _emit("fig11.ntt_ops_per_s", fig11["ntt_ops_per_s"])
    _emit("fig11.hmul_ops_per_s", fig11["hmul_ops_per_s"])
    _emit("fig11.tensorfhe_ntt_ops_per_s", fig11["tensorfhe_ntt_derived"], 1)

    fig12 = paper_figs.fig12_multi_shallow()
    _emit("fig12.peak_multi_job_speedup", fig12["peak_speedup"])
    for k, v in fig12["per_job_count"].items():
        _emit(f"fig12.jobs{k}.makespan_speedup", v["makespan_speedup"])

    fig8 = paper_figs.fig8_cache_sweep()
    _emit("fig8.dnum1_saturates_at_320MB", int(fig8["dnum1_saturates_at_320MB"]))
    for dnum, curve in fig8["curves_ms"].items():
        for cap, ms in curve.items():
            _emit(f"fig8.{dnum}.cache{cap}MB_ms", ms)

    t3 = paper_figs.table3_area()
    _emit("table3.total_14nm_mm2", t3["total_14nm_mm2"])
    _emit("table3.swift_logic_fraction", t3["swift_logic_fraction"])
    _emit("table3.claim_under_7pct", int(t3["claim_under_7pct"]))

    fig13 = paper_figs.fig13_power()
    _emit("fig13.total_w", fig13["total_w"])
    _emit("fig13.vs_craterlake", fig13["vs_craterlake"])

    pre = paper_figs.preemption_study()
    _emit("preemption.shallow_turnaround_speedup", pre["shallow_avg_turnaround_speedup"])

    perf = paper_figs.perf_beyond_paper()
    for w, row in perf.items():
        _emit(f"perf.{w}.baseline_ms", row["baseline_ms"])
        _emit(f"perf.{w}.optimized_ms", row["optimized_ms"])
        _emit(f"perf.{w}.speedup", row["speedup"])

    rt = roofline_table.main()
    _emit("roofline.cells_ok", rt["summary"]["ok"])
    _emit("roofline.cells_skipped", rt["summary"]["skipped"])
    _emit("roofline.cells_failed", rt["summary"]["failed"])
    for dom, n in rt["dominant_histogram"].items():
        _emit(f"roofline.dominant.{dom}", n)

    _emit("bench.total_seconds", time.time() - t0)


if __name__ == "__main__":
    main()
