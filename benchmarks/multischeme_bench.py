"""Mixed CKKS+BGV serving benchmark: FLASH-FHE vs CraterLake vs F1+ on
multi-scheme Poisson streams.

The scenario APACHE argues real deployments look like: approximate CKKS
inference traffic (LoLa / matmul / LSTM) interleaved with exact integer BGV
workloads (private set intersection, exact-count aggregation) in ONE arrival
stream.  Both schemes expand over the same RNS/NTT/key-switch substrate, so
one heterogeneous chip serves both — shallow BGV jobs ride the swift clusters
per the paper's affiliation policy, exactly like shallow CKKS, while each
job's service time is priced off its own scheme's planner expansion
(``ExecPolicy.policy_key()`` leads with the scheme, so the memo never aliases
across schemes).

Hard CI gate (``check_paper_claim``): on the mixed-scheme stream FLASH-FHE
must strictly beat the CraterLake baseline on SHALLOW p99 — the multi-job
affiliations absorb the interleaved shallow CKKS+BGV traffic that serialises
behind deep jobs on a whole-chip-per-job design.  A BGV-only stream is also
reported (and must beat CraterLake on makespan) to pin that the scheme axis
alone doesn't break the serving win.

    PYTHONPATH=src python -m benchmarks.multischeme_bench --smoke --out multischeme_smoke.csv
    PYTHONPATH=src python -m benchmarks.multischeme_bench            # full streams
"""

from __future__ import annotations

import argparse
import sys
import time

from repro import serve
from repro.core.hardware import CRATERLAKE, F1PLUS, FLASH_FHE

CHIPS = (FLASH_FHE, CRATERLAKE, F1PLUS)

# Rates sized like serving_bench: the multischeme stream carries ~10% deep
# CKKS background, so 2.0 jobs/Mcycle keeps the deep lane busy while the
# shallow CKKS+BGV slice (~90%) exercises the affiliations; bgv_only is pure
# shallow at a rate one sequential chip cannot absorb.


def scenarios(smoke: bool) -> dict[str, serve.PoissonConfig]:
    scale = 1 if smoke else 4
    return {
        "multischeme": serve.PoissonConfig(
            rate_per_mcycle=2.0, n_jobs=64 * scale, mix=serve.traffic.MULTISCHEME_MIX,
            priority_mix={0: 0.6, 5: 0.4}, seed=23),
        "bgv_only": serve.PoissonConfig(
            rate_per_mcycle=40.0, n_jobs=48 * scale, mix=serve.traffic.BGV_MIX,
            priority_mix={0: 0.7, 5: 0.3}, seed=29),
    }


def _scheme_counts(jobs) -> dict[str, int]:
    out: dict[str, int] = {}
    for j in jobs:
        out[j.scheme] = out.get(j.scheme, 0) + 1
    return out


def run(smoke: bool = True) -> list[dict]:
    rows = []
    for scen, cfg in scenarios(smoke).items():
        jobs = serve.poisson_jobs(cfg)
        counts = _scheme_counts(jobs)
        for chip in CHIPS:
            t0 = time.perf_counter()
            result = serve.serve(jobs, chip, validate=True)
            metrics = serve.summarize(result)
            rows.append({"scenario": scen, "chip": chip.name,
                         "n_ckks": counts.get("ckks", 0), "n_bgv": counts.get("bgv", 0),
                         "sim_wall_s": round(time.perf_counter() - t0, 3), **metrics})
    return rows


def check_paper_claim(rows: list[dict]) -> list[str]:
    """The multi-scheme gates — returns failure messages, [] = pass.

    * ``multischeme``: FLASH-FHE strictly beats CraterLake on shallow p99
      (the headline gate: mixed CKKS+BGV shallow traffic rides the
      affiliations instead of queueing behind the whole chip), and never
      regresses on makespan (the tail deep job can bound both timelines, so
      strictness there would gate on tie-breaking noise).
    * ``bgv_only``: FLASH-FHE strictly beats CraterLake on makespan — the
      scheme axis alone must not cost the multi-job win.
    * every stream actually mixed schemes (guards the mix definitions).
    """
    failures = []
    by = {(r["scenario"], r["chip"]): r for r in rows}
    ff, cl = by[("multischeme", "flash-fhe")], by[("multischeme", "craterlake")]
    if not ff["latency_p99_shallow_cycles"] < cl["latency_p99_shallow_cycles"]:
        failures.append(
            "multischeme: flash-fhe shallow p99="
            f"{ff['latency_p99_shallow_cycles']:.4g} not < craterlake "
            f"{cl['latency_p99_shallow_cycles']:.4g}")
    if ff["makespan_mcycles"] > cl["makespan_mcycles"]:
        failures.append(
            f"multischeme: flash-fhe makespan={ff['makespan_mcycles']:.4g} regressed "
            f"over craterlake {cl['makespan_mcycles']:.4g}")
    if ff["n_ckks"] == 0 or ff["n_bgv"] == 0:
        failures.append(
            f"multischeme stream is not mixed (ckks={ff['n_ckks']}, bgv={ff['n_bgv']})")
    ffb, clb = by[("bgv_only", "flash-fhe")], by[("bgv_only", "craterlake")]
    if not ffb["makespan_mcycles"] < clb["makespan_mcycles"]:
        failures.append(
            f"bgv_only: flash-fhe makespan={ffb['makespan_mcycles']:.4g} not < "
            f"craterlake {clb['makespan_mcycles']:.4g}")
    if ffb["n_bgv"] == 0:
        failures.append("bgv_only stream drew no BGV jobs")
    return failures


def write_csv(rows: list[dict], path: str) -> None:
    cols = list(rows[0].keys())
    with open(path, "w") as fh:
        fh.write(",".join(cols) + "\n")
        for r in rows:
            fh.write(",".join(f"{r[c]:.6g}" if isinstance(r[c], float) else str(r[c])
                              for c in cols) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="small streams (CI)")
    ap.add_argument("--out", default=None, help="write rows to this CSV file")
    args = ap.parse_args(argv)

    rows = run(smoke=args.smoke)
    hdr = f"{'scenario':12s} {'chip':11s} {'ckks':>5s} {'bgv':>4s} {'shallow p99':>12s} " \
          f"{'p99':>10s} {'makespan':>10s} {'util':>6s} {'preempt':>7s}"
    print(hdr)
    for r in rows:
        print(f"{r['scenario']:12s} {r['chip']:11s} {r['n_ckks']:5d} {r['n_bgv']:4d} "
              f"{r['latency_p99_shallow_cycles']/1e6:11.2f}M "
              f"{r['latency_p99_cycles']/1e6:9.2f}M {r['makespan_mcycles']:9.2f}M "
              f"{r['util_mean']:6.2f} {int(r['n_preemptions']):7d}")

    failures = check_paper_claim(rows)
    by = {(r["scenario"], r["chip"]): r for r in rows}
    ff, cl = by[("multischeme", "flash-fhe")], by[("multischeme", "craterlake")]
    print(f"[multischeme] mixed CKKS+BGV: FLASH-FHE vs CraterLake — shallow p99 "
          f"{cl['latency_p99_shallow_cycles']/ff['latency_p99_shallow_cycles']:.2f}×, "
          f"makespan {cl['makespan_mcycles']/ff['makespan_mcycles']:.2f}× better")
    if failures:
        for f in failures:
            print(f"[multischeme] CLAIM VIOLATED — {f}", file=sys.stderr)
    else:
        print("[multischeme] gates passed (FLASH-FHE strictly better on the mixed "
              "CKKS+BGV stream); timelines validated")

    if args.out:
        write_csv(rows, args.out)
        print(f"[multischeme] wrote {len(rows)} rows to {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
