"""Fleet-serving benchmark: homogeneous scale-out sweeps (1→8 chips, all
router policies) plus heterogeneous-fleet and cross-chip-gang scenarios.

Each scenario draws one seeded stream and serves it through
``repro.serve.cluster`` (one shared event loop, per-chip warm-sets with
HBM-priced cold starts).  Every run re-validates the fleet invariants (each
job on exactly one chip — or, for a gang, one fragment per member chip in
lockstep — per-chip timelines overlap-free, work conservation
penalty-inclusive).

The ``skewed`` scenario is the router stress test: a mixed background (15%
deep jobs that gang-block a whole chip for 3–6 Mcycles) plus one bursty
tenant dumping 16-job shallow bursts — blind round-robin keeps feeding
blocked chips while join-shortest-queue routes around them.

``hetero_mixed`` serves one shallow-flood-plus-deep stream on a mixed
2×FLASH-FHE + CraterLake + F1+ fleet and on every 4-chip single-chip-type
fleet.  Per the paper's framing, the comparison that matters is against the
*homogeneous-architecture* accelerators (4×CraterLake, 4×F1+): a FLASH-FHE
die strictly dominates those chips one-on-one (same deep service, 8-wide
shallow), so an all-FLASH fleet is the upper reference, not the baseline —
the mixed fleet shows that heterogeneity-aware dispatch recovers most of
that headroom while only 2 of 4 dies are FLASH.

``deep_gang`` is a lightly loaded mixed fleet receiving a deterministic
batch of priority-1 deep jobs (one every 7 Mcycles — a bootstrapping batch
trace): with ``gang_max_chips=2`` each deep job splits across both FLASH
dies' bootstrappable clusters, paying the inter-chip link (2·syncs·ws·(M-1)/M
bytes at ``link_bytes_per_cycle``) to finish strictly earlier than any
single chip could.

Gates (exit non-zero on violation):
  (a) shallow_only: 4-chip jsq fleet throughput ≥ 3× the single chip;
  (b) skewed: jsq strictly beats round_robin on p99 latency at 4 chips;
  (c) hetero_mixed: the mixed fleet under the hetero router strictly beats
      the best homogeneous-architecture fleet (4×CraterLake, 4×F1+; best
      router each) on BOTH p99 latency and makespan;
  (d) deep_gang: gang_max_chips=2 strictly reduces deep-job p99 vs the same
      fleet/router with gangs disabled.

    PYTHONPATH=src python -m benchmarks.cluster_bench --smoke --out cluster_smoke.csv
    PYTHONPATH=src python -m benchmarks.cluster_bench            # full sweep (1→8 chips)
"""

from __future__ import annotations

import argparse
import sys
import time

from repro import serve
from repro.core.hardware import CRATERLAKE, F1PLUS, FLASH_FHE
from repro.serve.cluster import ROUTERS

THROUGHPUT_GATE_X = 3.0  # 4-chip fleet must deliver ≥ this × single-chip throughput


def scenarios(smoke: bool) -> dict[str, list]:
    """Seeded streams.  Rates are sized against measured FLASH-FHE service
    times (shallow mix ≈ 0.156 Mcycles ⇒ ~51 jobs/Mcycle per chip; deep mix
    ≈ 4.4 Mcycles whole-chip): shallow_only offers ~6× one chip, deep_only
    ~4×, mixed ~3× — so the small fleets run saturated and the sweep shows
    where arrival-bound replaces work-bound."""
    scale = 1 if smoke else 3
    shallow = serve.PoissonConfig(rate_per_mcycle=300.0, n_jobs=320 * scale,
                                  mix=serve.traffic.SHALLOW_MIX,
                                  priority_mix={0: 0.7, 5: 0.3}, seed=11)
    deep = serve.PoissonConfig(rate_per_mcycle=0.9, n_jobs=16 * scale,
                               mix=serve.traffic.DEEP_MIX, seed=13)
    mixed = serve.PoissonConfig(rate_per_mcycle=4.0, n_jobs=96 * scale,
                                mix=serve.traffic.MIXED_MIX,
                                priority_mix={0: 0.6, 5: 0.4}, seed=17)
    skewed = serve.BurstyConfig(
        base=serve.PoissonConfig(rate_per_mcycle=8.0, n_jobs=64 * scale,
                                 mix=serve.traffic.MIXED_MIX,
                                 priority_mix={0: 0.7, 5: 0.3}, seed=17),
        n_bursts=6 * scale, burst_size=16, intra_gap_cycles=2_000.0,
        burst_mix=serve.traffic.SHALLOW_MIX)
    return {
        "shallow_only": serve.poisson_jobs(shallow),
        "deep_only": serve.poisson_jobs(deep),
        "mixed": serve.poisson_jobs(mixed),
        "skewed": serve.bursty_jobs(skewed),
    }


def chip_counts(smoke: bool) -> tuple[int, ...]:
    return (1, 2, 4) if smoke else (1, 2, 4, 8)


def hetero_fleets() -> dict[str, list]:
    """4-chip fleets for the heterogeneity scenarios.  ``mixed`` pairs two
    FLASH-FHE dies (swift-heavy, 8-wide shallow, gang-capable) with one
    CraterLake and one F1+ (single-job homogeneous-architecture chips)."""
    return {
        "mixed": [FLASH_FHE, FLASH_FHE, CRATERLAKE, F1PLUS],
        "flash": [FLASH_FHE] * 4,
        "craterlake": [CRATERLAKE] * 4,
        "f1plus": [F1PLUS] * 4,
    }


def hetero_stream(smoke: bool) -> list:
    """Shallow flood (priority 0, ~60 jobs/Mcycle ≈ 1.2 FLASH dies' worth)
    merged with a sparse priority-1 deep stream.  The flood saturates the
    1-wide chips outright, so fleet p99/makespan hinge on how much of it the
    router keeps on the multi-affiliation dies."""
    scale = 1 if smoke else 2
    shallow = serve.poisson_jobs(serve.PoissonConfig(
        rate_per_mcycle=60.0, n_jobs=600 * scale, mix=serve.traffic.SHALLOW_MIX,
        priority_mix={0: 1.0}, seed=21))
    deep = serve.poisson_jobs(serve.PoissonConfig(
        rate_per_mcycle=0.4, n_jobs=4 * scale, mix=serve.traffic.DEEP_MIX,
        priority_mix={1: 1.0}, seed=22, start_id=100_000))
    return sorted(shallow + deep, key=lambda j: j.arrival_cycle)


def gang_stream() -> list:
    """Light shallow background plus a deterministic bootstrapping batch:
    six priority-1 deep jobs, one every 7 Mcycles (wider than any gang's
    service time, so each job's gang-vs-single choice is isolated)."""
    background = serve.poisson_jobs(serve.PoissonConfig(
        rate_per_mcycle=8.0, n_jobs=120, mix=serve.traffic.SHALLOW_MIX,
        priority_mix={0: 1.0}, seed=31))
    workloads = ("lstm", "logreg")
    batch = serve.trace_jobs([
        {"workload": workloads[k % 2], "arrival_cycle": 2_000_000 + 7_000_000 * k,
         "priority": 1, "job_id": 100_000 + k}
        for k in range(6)])
    return sorted(background + batch, key=lambda j: j.arrival_cycle)


def run(smoke: bool = True) -> list[dict]:
    rows = []
    for scen, jobs in scenarios(smoke).items():
        for router in ROUTERS:
            for n in chip_counts(smoke):
                rows.append(_fleet_row(scen, jobs, "flash", router, 1,
                                       chip=FLASH_FHE, n_chips=n))
    stream = hetero_stream(smoke)
    fleets = hetero_fleets()
    for fleet, chips in fleets.items():
        for router in ("jsq", "hetero"):
            rows.append(_fleet_row("hetero_mixed", stream, fleet, router, 1,
                                   chips=chips))
    gang_jobs = gang_stream()
    for gang in (1, 2):
        rows.append(_fleet_row("deep_gang", gang_jobs, "mixed", "hetero", gang,
                               chips=fleets["mixed"]))
    return rows


def _fleet_row(scen: str, jobs: list, fleet: str, router: str, gang: int,
               chip=None, n_chips: int = 0, chips=None) -> dict:
    t0 = time.perf_counter()
    if chips is not None:
        result = serve.serve_cluster(jobs, chips=chips, router=router,
                                     gang_max_chips=gang, validate=True)
    else:
        result = serve.serve_cluster(jobs, chip, n_chips=n_chips, router=router,
                                     validate=True)
    m = serve.summarize(result)
    return {"scenario": scen, "router": router, "fleet": fleet, "gang": gang,
            "n_chips": n_chips if chips is None else len(chips),
            "sim_wall_s": round(time.perf_counter() - t0, 3), **m}


def _row(rows: list[dict], scen: str, router: str, n: int) -> dict:
    return next(r for r in rows
                if r["scenario"] == scen and r["router"] == router and r["n_chips"] == n)


def _hrow(rows: list[dict], scen: str, fleet: str, router: str, gang: int = 1) -> dict:
    return next(r for r in rows
                if r["scenario"] == scen and r["fleet"] == fleet
                and r["router"] == router and r["gang"] == gang)


def check_gates(rows: list[dict]) -> list[str]:
    """Scale-out acceptance gates — returns failure messages, [] = pass."""
    failures = []
    one = _row(rows, "shallow_only", "jsq", 1)
    four = _row(rows, "shallow_only", "jsq", 4)
    ratio = (four["throughput_jobs_per_mcycle"] / one["throughput_jobs_per_mcycle"]
             if one["throughput_jobs_per_mcycle"] > 0 else 0.0)
    if ratio < THROUGHPUT_GATE_X:
        failures.append(
            f"shallow_only: 4-chip throughput only {ratio:.2f}× single chip "
            f"(gate: ≥ {THROUGHPUT_GATE_X}×)")
    rr = _row(rows, "skewed", "round_robin", 4)
    jsq = _row(rows, "skewed", "jsq", 4)
    if not jsq["latency_p99_cycles"] < rr["latency_p99_cycles"]:
        failures.append(
            f"skewed: jsq p99 {jsq['latency_p99_cycles']:.4g} not < "
            f"round_robin p99 {rr['latency_p99_cycles']:.4g} at 4 chips")
    failures += check_hetero_gates(rows)
    return failures


def check_hetero_gates(rows: list[dict]) -> list[str]:
    """Gates (c) and (d): heterogeneous fleet and cross-chip gang wins.

    Gate (c) compares the mixed fleet against the *homogeneous-architecture*
    fleets (4×CraterLake, 4×F1+), each at its best router — NOT against
    4×FLASH-FHE, which dominates every chip one-on-one and is reported as the
    upper reference instead (see the module docstring)."""
    failures = []
    mixed = _hrow(rows, "hetero_mixed", "mixed", "hetero")
    for fleet in ("craterlake", "f1plus"):
        cand = [r for r in rows
                if r["scenario"] == "hetero_mixed" and r["fleet"] == fleet]
        best_p99 = min(r["latency_p99_cycles"] for r in cand)
        best_mk = min(r["makespan_mcycles"] for r in cand)
        if not mixed["latency_p99_cycles"] < best_p99:
            failures.append(
                f"hetero_mixed: mixed/hetero p99 {mixed['latency_p99_cycles']:.4g} "
                f"not < 4×{fleet} best p99 {best_p99:.4g}")
        if not mixed["makespan_mcycles"] < best_mk:
            failures.append(
                f"hetero_mixed: mixed/hetero makespan {mixed['makespan_mcycles']:.4g}M "
                f"not < 4×{fleet} best makespan {best_mk:.4g}M")
    solo = _hrow(rows, "deep_gang", "mixed", "hetero", gang=1)
    ganged = _hrow(rows, "deep_gang", "mixed", "hetero", gang=2)
    if not ganged["latency_p99_deep_cycles"] < solo["latency_p99_deep_cycles"]:
        failures.append(
            f"deep_gang: gang=2 deep p99 {ganged['latency_p99_deep_cycles']:.4g} "
            f"not < gang=1 deep p99 {solo['latency_p99_deep_cycles']:.4g}")
    if not ganged["n_gang_jobs"] > 0:
        failures.append("deep_gang: gang=2 run committed zero gangs")
    return failures


def write_csv(rows: list[dict], path: str) -> None:
    cols = list(rows[0].keys())
    with open(path, "w") as fh:
        fh.write(",".join(cols) + "\n")
        for r in rows:
            fh.write(",".join(f"{r[c]:.6g}" if isinstance(r[c], float) else str(r[c])
                              for c in cols) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="small streams, chips 1/2/4 (CI)")
    ap.add_argument("--out", default=None, help="write rows to this CSV file")
    args = ap.parse_args(argv)

    rows = run(smoke=args.smoke)
    print(f"{'scenario':13s} {'fleet':11s} {'router':12s} {'chips':>5s} {'gang':>4s} "
          f"{'thr/Mcyc':>9s} {'p99':>10s} {'deep p99':>10s} {'makespan':>10s} "
          f"{'imbal':>6s} {'cold':>5s}")
    for r in rows:
        print(f"{r['scenario']:13s} {r['fleet']:11s} {r['router']:12s} "
              f"{int(r['n_chips']):5d} {int(r['gang']):4d} "
              f"{r['throughput_jobs_per_mcycle']:9.1f} {r['latency_p99_cycles']/1e6:9.2f}M "
              f"{r['latency_p99_deep_cycles']/1e6:9.2f}M {r['makespan_mcycles']:9.2f}M "
              f"{r['chip_util_imbalance']:6.3f} {int(r['n_cold_starts']):5d}")

    one = _row(rows, "shallow_only", "jsq", 1)
    four = _row(rows, "shallow_only", "jsq", 4)
    print(f"[cluster] shallow_only jsq: 4-chip throughput "
          f"{four['throughput_jobs_per_mcycle']/one['throughput_jobs_per_mcycle']:.2f}× "
          f"single chip (gate ≥ {THROUGHPUT_GATE_X}×)")
    rr, jsq = _row(rows, "skewed", "round_robin", 4), _row(rows, "skewed", "jsq", 4)
    print(f"[cluster] skewed @4 chips: p99 jsq {jsq['latency_p99_cycles']/1e6:.2f}M vs "
          f"round_robin {rr['latency_p99_cycles']/1e6:.2f}M "
          f"({rr['latency_p99_cycles']/jsq['latency_p99_cycles']:.2f}× better)")
    mixed = _hrow(rows, "hetero_mixed", "mixed", "hetero")
    cl = _hrow(rows, "hetero_mixed", "craterlake", "jsq")
    flash = _hrow(rows, "hetero_mixed", "flash", "jsq")
    print(f"[cluster] hetero_mixed @4 chips: mixed/hetero p99 "
          f"{mixed['latency_p99_cycles']/1e6:.2f}M mk {mixed['makespan_mcycles']:.2f}M "
          f"vs 4×craterlake/jsq p99 {cl['latency_p99_cycles']/1e6:.2f}M mk "
          f"{cl['makespan_mcycles']:.2f}M (all-FLASH reference: p99 "
          f"{flash['latency_p99_cycles']/1e6:.2f}M mk {flash['makespan_mcycles']:.2f}M)")
    solo = _hrow(rows, "deep_gang", "mixed", "hetero", gang=1)
    ganged = _hrow(rows, "deep_gang", "mixed", "hetero", gang=2)
    print(f"[cluster] deep_gang: gang=2 deep p99 "
          f"{ganged['latency_p99_deep_cycles']/1e6:.2f}M vs gang=1 "
          f"{solo['latency_p99_deep_cycles']/1e6:.2f}M "
          f"({int(ganged['n_gang_jobs'])} gangs, "
          f"{ganged['gang_link_bytes']/1e6:.0f} MB over the inter-chip link)")

    failures = check_gates(rows)
    if failures:
        for f in failures:
            print(f"[cluster] GATE VIOLATED — {f}", file=sys.stderr)
    else:
        print("[cluster] scale-out + hetero + gang gates passed; fleet timelines "
              "validated (unique placement, no overlap, work conservation)")
    if args.out:
        write_csv(rows, args.out)
        print(f"[cluster] wrote {len(rows)} rows to {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
