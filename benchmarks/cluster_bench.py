"""Fleet-serving benchmark: throughput and p99 latency vs chip count (1→8)
for all four router policies over shallow-only / deep-only / mixed / skewed
arrival streams.

Each scenario draws one seeded stream and serves it on FLASH-FHE fleets of
growing size through ``repro.serve.cluster`` (one shared event loop, per-chip
warm-sets with HBM-priced cold starts).  Every run re-validates the fleet
invariants (each job on exactly one chip, per-chip timelines overlap-free,
work conservation penalty-inclusive).

The ``skewed`` scenario is the router stress test: a mixed background (15%
deep jobs that gang-block a whole chip for 3–6 Mcycles) plus one bursty
tenant dumping 16-job shallow bursts — blind round-robin keeps feeding
blocked chips while join-shortest-queue routes around them.

Gates (exit non-zero on violation):
  (a) shallow_only: 4-chip jsq fleet throughput ≥ 3× the single chip;
  (b) skewed: jsq strictly beats round_robin on p99 latency at 4 chips.

    PYTHONPATH=src python -m benchmarks.cluster_bench --smoke --out cluster_smoke.csv
    PYTHONPATH=src python -m benchmarks.cluster_bench            # full sweep (1→8 chips)
"""

from __future__ import annotations

import argparse
import sys
import time

from repro import serve
from repro.core.hardware import FLASH_FHE
from repro.serve.cluster import ROUTERS

THROUGHPUT_GATE_X = 3.0  # 4-chip fleet must deliver ≥ this × single-chip throughput


def scenarios(smoke: bool) -> dict[str, list]:
    """Seeded streams.  Rates are sized against measured FLASH-FHE service
    times (shallow mix ≈ 0.156 Mcycles ⇒ ~51 jobs/Mcycle per chip; deep mix
    ≈ 4.4 Mcycles whole-chip): shallow_only offers ~6× one chip, deep_only
    ~4×, mixed ~3× — so the small fleets run saturated and the sweep shows
    where arrival-bound replaces work-bound."""
    scale = 1 if smoke else 3
    shallow = serve.PoissonConfig(rate_per_mcycle=300.0, n_jobs=320 * scale,
                                  mix=serve.traffic.SHALLOW_MIX,
                                  priority_mix={0: 0.7, 5: 0.3}, seed=11)
    deep = serve.PoissonConfig(rate_per_mcycle=0.9, n_jobs=16 * scale,
                               mix=serve.traffic.DEEP_MIX, seed=13)
    mixed = serve.PoissonConfig(rate_per_mcycle=4.0, n_jobs=96 * scale,
                                mix=serve.traffic.MIXED_MIX,
                                priority_mix={0: 0.6, 5: 0.4}, seed=17)
    skewed = serve.BurstyConfig(
        base=serve.PoissonConfig(rate_per_mcycle=8.0, n_jobs=64 * scale,
                                 mix=serve.traffic.MIXED_MIX,
                                 priority_mix={0: 0.7, 5: 0.3}, seed=17),
        n_bursts=6 * scale, burst_size=16, intra_gap_cycles=2_000.0,
        burst_mix=serve.traffic.SHALLOW_MIX)
    return {
        "shallow_only": serve.poisson_jobs(shallow),
        "deep_only": serve.poisson_jobs(deep),
        "mixed": serve.poisson_jobs(mixed),
        "skewed": serve.bursty_jobs(skewed),
    }


def chip_counts(smoke: bool) -> tuple[int, ...]:
    return (1, 2, 4) if smoke else (1, 2, 4, 8)


def run(smoke: bool = True) -> list[dict]:
    rows = []
    for scen, jobs in scenarios(smoke).items():
        for router in ROUTERS:
            for n in chip_counts(smoke):
                t0 = time.perf_counter()
                result = serve.serve_cluster(jobs, FLASH_FHE, n_chips=n,
                                             router=router, validate=True)
                m = serve.summarize(result)
                rows.append({"scenario": scen, "router": router, "n_chips": n,
                             "sim_wall_s": round(time.perf_counter() - t0, 3), **m})
    return rows


def _row(rows: list[dict], scen: str, router: str, n: int) -> dict:
    return next(r for r in rows
                if r["scenario"] == scen and r["router"] == router and r["n_chips"] == n)


def check_gates(rows: list[dict]) -> list[str]:
    """Scale-out acceptance gates — returns failure messages, [] = pass."""
    failures = []
    one = _row(rows, "shallow_only", "jsq", 1)
    four = _row(rows, "shallow_only", "jsq", 4)
    ratio = (four["throughput_jobs_per_mcycle"] / one["throughput_jobs_per_mcycle"]
             if one["throughput_jobs_per_mcycle"] > 0 else 0.0)
    if ratio < THROUGHPUT_GATE_X:
        failures.append(
            f"shallow_only: 4-chip throughput only {ratio:.2f}× single chip "
            f"(gate: ≥ {THROUGHPUT_GATE_X}×)")
    rr = _row(rows, "skewed", "round_robin", 4)
    jsq = _row(rows, "skewed", "jsq", 4)
    if not jsq["latency_p99_cycles"] < rr["latency_p99_cycles"]:
        failures.append(
            f"skewed: jsq p99 {jsq['latency_p99_cycles']:.4g} not < "
            f"round_robin p99 {rr['latency_p99_cycles']:.4g} at 4 chips")
    return failures


def write_csv(rows: list[dict], path: str) -> None:
    cols = list(rows[0].keys())
    with open(path, "w") as fh:
        fh.write(",".join(cols) + "\n")
        for r in rows:
            fh.write(",".join(f"{r[c]:.6g}" if isinstance(r[c], float) else str(r[c])
                              for c in cols) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="small streams, chips 1/2/4 (CI)")
    ap.add_argument("--out", default=None, help="write rows to this CSV file")
    args = ap.parse_args(argv)

    rows = run(smoke=args.smoke)
    print(f"{'scenario':13s} {'router':12s} {'chips':>5s} {'thr/Mcyc':>9s} {'p99':>10s} "
          f"{'queue p99':>11s} {'makespan':>10s} {'imbal':>6s} {'cold':>5s}")
    for r in rows:
        print(f"{r['scenario']:13s} {r['router']:12s} {int(r['n_chips']):5d} "
              f"{r['throughput_jobs_per_mcycle']:9.1f} {r['latency_p99_cycles']/1e6:9.2f}M "
              f"{r['queue_p99_cycles']/1e6:10.2f}M {r['makespan_mcycles']:9.2f}M "
              f"{r['chip_util_imbalance']:6.3f} {int(r['n_cold_starts']):5d}")

    one = _row(rows, "shallow_only", "jsq", 1)
    four = _row(rows, "shallow_only", "jsq", 4)
    print(f"[cluster] shallow_only jsq: 4-chip throughput "
          f"{four['throughput_jobs_per_mcycle']/one['throughput_jobs_per_mcycle']:.2f}× "
          f"single chip (gate ≥ {THROUGHPUT_GATE_X}×)")
    rr, jsq = _row(rows, "skewed", "round_robin", 4), _row(rows, "skewed", "jsq", 4)
    print(f"[cluster] skewed @4 chips: p99 jsq {jsq['latency_p99_cycles']/1e6:.2f}M vs "
          f"round_robin {rr['latency_p99_cycles']/1e6:.2f}M "
          f"({rr['latency_p99_cycles']/jsq['latency_p99_cycles']:.2f}× better)")

    failures = check_gates(rows)
    if failures:
        for f in failures:
            print(f"[cluster] GATE VIOLATED — {f}", file=sys.stderr)
    else:
        print("[cluster] scale-out gates passed; fleet timelines validated "
              "(unique placement, no overlap, work conservation)")
    if args.out:
        write_csv(rows, args.out)
        print(f"[cluster] wrote {len(rows)} rows to {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
