"""Overload / admission-control benchmark: the SLO table under diurnal load.

Serves ONE seeded diurnal stream (day/night raised-cosine rate curve,
``repro.serve.traffic.DiurnalConfig``) per (fleet size, load multiple) at
0.8× / 1.0× / 1.3× of the fleet's estimated capacity
(``fleet_capacity_jobs_per_mcycle`` over ``OVERLOAD_MIX``), twice each:
admission ON (utilization reserve + engine queue-timeout,
``repro.serve.AdmissionConfig``) and admission OFF (the historical
unbounded-backlog behaviour).  Every run validates the fleet invariants,
including the shed carve-outs (shed jobs on no chip, in no placement, no
segments) and backlog-estimator non-negativity.

The emitted rows are an SLO table per chip count — p99 by kind, drop rate,
goodput, fairness, peak backlog — which turns the bench into a capacity
planner: ``mreq_per_day`` is what the fleet sustains at this SLO, so "how
many chips for X Mreq/day" is a table lookup (printed at the end).

Gates (exit non-zero on violation; measured on the 2-chip fleet):
  (a) admission ON keeps the tail flat across the overload knee: shallow p99
      at 1.3× capacity stays within ``P99_GATE_X`` (2×) of the 0.8× baseline,
      AND goodput at 1.3× is ≥ ``GOODPUT_GATE_FRAC`` (70%) of the offered
      *feasible* load (min(offered, capacity)).  Both loads must actually
      complete shallow jobs (``n_completed_shallow > 0`` — the NaN-percentile
      fix means an empty sample would otherwise poison the ratio silently).
  (b) admission OFF diverges on the SAME stream: at 1.3× the unprotected
      shallow p99 is ≥ ``DIVERGE_GATE_X`` (2×) the admission-ON p99 for
      identical arrivals, AND the unprotected peak backlog at 1.3× is ≥ 2×
      its own 0.8× level (the queue integrates the overload instead of
      plateauing).  NB the OFF runs' *shallow* p99 barely moves with load —
      it is pinned at the deep head-of-line-blocking scale (~one lstm
      whole-chip service) even when feasible — so the load-divergence check
      uses the backlog, and the policy comparison uses the same-stream tail.
  (c) bounded queues: the peak fleet backlog at 1.3× with admission ON is
      ≤ half the admission-OFF peak (the backlog plateaus at the reserve
      instead of integrating the overload).

    PYTHONPATH=src python -m benchmarks.overload_bench --smoke --out overload_smoke.csv
    PYTHONPATH=src python -m benchmarks.overload_bench            # longer days, 8-chip fleet
"""

from __future__ import annotations

import argparse
import math
import sys
import time

from repro import serve
from repro.core.hardware import FLASH_FHE

# shallow-heavy production mix with a thin deep (bootstrapping) minority —
# overload behaviour is dominated by the shallow tail, while the deep jobs
# periodically pin whole chips (the regime admission has to survive)
OVERLOAD_MIX: dict[str, float] = {
    "lola_mnist_plain": 0.30,
    "matmul": 0.28,
    "dblookup": 0.25,
    "lola_cifar_plain": 0.15,
    "lstm": 0.02,
}

LOADS = (0.8, 1.0, 1.3)  # offered mean load as a multiple of fleet capacity
P99_GATE_X = 2.0  # admission ON: shallow p99 @1.3× within this × of @0.8×
GOODPUT_GATE_FRAC = 0.70  # admission ON: goodput ≥ this × offered feasible load
DIVERGE_GATE_X = 2.0  # admission OFF @1.3×: shallow p99 at least this × the ON run's

# the admission policy under test: the reserve bounds estimated wait at one
# megacycle (≈ 6–7 shallow service times), the timeout backstops jobs whose
# queue congested after admission (e.g. behind a deep job)
ADMISSION = serve.AdmissionConfig(max_wait_cycles=1.0e6, shed_after_cycles=2.0e6)


def chip_counts(smoke: bool) -> tuple[int, ...]:
    return (2, 4) if smoke else (2, 4, 8)


def stream_for(n_chips: int, load_x: float, smoke: bool) -> tuple[list, serve.DiurnalConfig]:
    """One diurnal stream whose MEAN rate is ``load_x`` × fleet capacity.

    The raised-cosine curve's mean is peak·(1+trough)/2, so the peak is
    dialed to hit the target mean.  ``trough=0.65`` puts peak/mean at ~1.21×:
    the 0.8× stream grazes capacity at its daytime peak (0.97×) but stays
    feasible — the healthy baseline — while the 1.3× stream is infeasible in
    AGGREGATE (mean > capacity), i.e. sustained overload whose backlog
    integrates across the whole horizon instead of draining at night.  The
    SAME seed per fleet is used for the admission ON and OFF runs, so the
    gates compare policies on identical arrival draws.
    """
    capacity = serve.fleet_capacity_jobs_per_mcycle(OVERLOAD_MIX, [FLASH_FHE] * n_chips)
    trough = 0.65
    cfg = serve.DiurnalConfig(
        peak_rate_per_mcycle=2.0 * load_x * capacity / (1.0 + trough),
        period_mcycles=20.0 if smoke else 60.0,
        n_periods=2.0,
        trough_frac=trough,
        mix=OVERLOAD_MIX,
        seed=43 + n_chips,  # same stream for admission on/off at every load?
    )
    # NB: the seed is shared across loads too — only the rate scale differs,
    # which keeps the load sweep smooth (thinning reuses the draw sequence)
    return serve.diurnal_jobs(cfg), cfg


def _run_row(n_chips: int, load_x: float, admission_on: bool,
             jobs: list, cfg: serve.DiurnalConfig) -> dict:
    t0 = time.perf_counter()
    result = serve.serve_cluster(
        jobs, FLASH_FHE, n_chips=n_chips, router="jsq", validate=True,
        admission=ADMISSION if admission_on else None)
    m = serve.summarize(result)
    capacity = serve.fleet_capacity_jobs_per_mcycle(OVERLOAD_MIX, [FLASH_FHE] * n_chips)
    offered_rate = cfg.mean_rate_per_mcycle
    # what this fleet retires per simulated day at 1 GHz, in Mreq/day —
    # the capacity-planning number ("how many chips for X Mreq/day")
    mreq_per_day = capacity * 86.4 * FLASH_FHE.freq_ghz
    return {
        "scenario": "diurnal", "n_chips": n_chips, "load_x": load_x,
        "admission": int(admission_on),
        "capacity_jobs_per_mcycle": capacity,
        "offered_rate_per_mcycle": offered_rate,
        "feasible_frac": min(1.0, capacity / offered_rate),
        "mreq_per_day": mreq_per_day,
        "sim_wall_s": round(time.perf_counter() - t0, 3),
        **m,
    }


def run(smoke: bool = True) -> list[dict]:
    rows = []
    for n in chip_counts(smoke):
        for load in LOADS:
            jobs, cfg = stream_for(n, load, smoke)
            for admission_on in (True, False):
                rows.append(_run_row(n, load, admission_on, jobs, cfg))
    return rows


def _row(rows: list[dict], n: int, load: float, admission: int) -> dict:
    return next(r for r in rows if r["n_chips"] == n and r["load_x"] == load
                and r["admission"] == admission)


def check_gates(rows: list[dict]) -> list[str]:
    """Overload acceptance gates — returns failure messages, [] = pass."""
    failures = []
    n = min(r["n_chips"] for r in rows)
    on_lo, on_hi = _row(rows, n, 0.8, 1), _row(rows, n, 1.3, 1)
    off_lo, off_hi = _row(rows, n, 0.8, 0), _row(rows, n, 1.3, 0)
    # empty percentile samples are NaN now — require the samples exist before
    # comparing tails (gate (a) precondition)
    for r, tag in ((on_lo, "on@0.8x"), (on_hi, "on@1.3x"),
                   (off_lo, "off@0.8x"), (off_hi, "off@1.3x")):
        if not r["n_completed_shallow"] > 0:
            failures.append(f"{tag}: zero shallow completions — p99 sample empty")
    if failures:
        return failures
    ratio_on = on_hi["latency_p99_shallow_cycles"] / on_lo["latency_p99_shallow_cycles"]
    if not ratio_on <= P99_GATE_X:
        failures.append(
            f"admission on: shallow p99 @1.3x is {ratio_on:.2f}× the 0.8x baseline "
            f"(gate: ≤ {P99_GATE_X}×) — tail not flat across the overload knee")
    goodput_floor = GOODPUT_GATE_FRAC * on_hi["feasible_frac"]
    if not on_hi["goodput_frac"] >= goodput_floor:
        failures.append(
            f"admission on @1.3x: goodput {on_hi['goodput_frac']:.3f} of offered "
            f"< {GOODPUT_GATE_FRAC:.0%} of feasible ({goodput_floor:.3f})")
    ratio_off = off_hi["latency_p99_shallow_cycles"] / on_hi["latency_p99_shallow_cycles"]
    if not ratio_off >= DIVERGE_GATE_X:
        failures.append(
            f"admission off @1.3x: shallow p99 only {ratio_off:.2f}× the admission-on "
            f"run on the same stream (sanity gate: ≥ {DIVERGE_GATE_X}× divergence)")
    backlog_growth = off_hi["peak_backlog_mcycles"] / max(off_lo["peak_backlog_mcycles"], 1e-9)
    if not backlog_growth >= 2.0:
        failures.append(
            f"admission off: peak backlog @1.3x only {backlog_growth:.2f}× the 0.8x "
            f"level — the unprotected queue did not integrate the overload")
    if not off_hi["n_shed"] == 0:
        failures.append("admission off run shed jobs — admission leaked through")
    if not on_hi["peak_backlog_mcycles"] <= 0.5 * off_hi["peak_backlog_mcycles"]:
        failures.append(
            f"admission on @1.3x: peak backlog {on_hi['peak_backlog_mcycles']:.2f}M "
            f"not ≤ half the unprotected peak {off_hi['peak_backlog_mcycles']:.2f}M "
            f"— queues did not plateau")
    return failures


def write_csv(rows: list[dict], path: str) -> None:
    cols = list(rows[0].keys())
    with open(path, "w") as fh:
        fh.write(",".join(cols) + "\n")
        for r in rows:
            fh.write(",".join(f"{r[c]:.6g}" if isinstance(r[c], float) else str(r[c])
                              for c in cols) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="short simulated days, 2/4-chip fleets (CI)")
    ap.add_argument("--out", default=None, help="write rows to this CSV file")
    args = ap.parse_args(argv)

    rows = run(smoke=args.smoke)
    print(f"{'chips':>5s} {'load':>5s} {'adm':>3s} {'offered/Mc':>10s} "
          f"{'goodput':>7s} {'drop':>6s} {'p99 sh':>9s} {'p99 dp':>9s} "
          f"{'peakbk':>8s} {'fair':>5s} {'tts p99':>8s}")
    for r in rows:
        print(f"{int(r['n_chips']):5d} {r['load_x']:5.1f} {int(r['admission']):3d} "
              f"{r['offered_rate_per_mcycle']:10.1f} {r['goodput_frac']:7.3f} "
              f"{r['drop_rate']:6.3f} {r['latency_p99_shallow_cycles']/1e6:8.2f}M "
              f"{r['latency_p99_deep_cycles']/1e6:8.2f}M "
              f"{r['peak_backlog_mcycles']:7.2f}M {r['fairness_jain']:5.3f} "
              f"{r['time_to_shed_p99_cycles']/1e6:7.2f}M")

    # the capacity-planning query: chips for X Mreq/day at this SLO
    per_chip = _row(rows, min(r["n_chips"] for r in rows), 0.8, 1)
    per_chip_mreq = per_chip["mreq_per_day"] / per_chip["n_chips"]
    print(f"[overload] capacity: one FLASH-FHE die ≈ "
          f"{per_chip['capacity_jobs_per_mcycle']/per_chip['n_chips']:.1f} jobs/Mcycle on "
          f"this mix ≈ {per_chip_mreq:.0f} Mreq/day at 1 GHz; e.g. "
          f"{math.ceil(1000.0/per_chip_mreq)} chip(s) for 1000 Mreq/day, "
          f"{math.ceil(10_000.0/per_chip_mreq)} for 10,000 Mreq/day at this SLO")

    n = min(r["n_chips"] for r in rows)
    on_lo, on_hi = _row(rows, n, 0.8, 1), _row(rows, n, 1.3, 1)
    off_lo, off_hi = _row(rows, n, 0.8, 0), _row(rows, n, 1.3, 0)
    print(f"[overload] admission on @{n} chips: shallow p99 "
          f"{on_hi['latency_p99_shallow_cycles']/1e6:.2f}M at 1.3× vs "
          f"{on_lo['latency_p99_shallow_cycles']/1e6:.2f}M at 0.8× "
          f"({on_hi['latency_p99_shallow_cycles']/on_lo['latency_p99_shallow_cycles']:.2f}×, "
          f"gate ≤ {P99_GATE_X}×); goodput {on_hi['goodput_frac']:.3f} "
          f"(floor {GOODPUT_GATE_FRAC * on_hi['feasible_frac']:.3f})")
    print(f"[overload] admission off @1.3×: shallow p99 "
          f"{off_hi['latency_p99_shallow_cycles']/1e6:.2f}M vs "
          f"{on_hi['latency_p99_shallow_cycles']/1e6:.2f}M with admission on the same "
          f"stream ({off_hi['latency_p99_shallow_cycles']/on_hi['latency_p99_shallow_cycles']:.1f}× "
          f"divergence, gate ≥ {DIVERGE_GATE_X}×); unprotected peak backlog grew "
          f"{off_hi['peak_backlog_mcycles']/max(off_lo['peak_backlog_mcycles'], 1e-9):.1f}× "
          f"from 0.8× to 1.3× load ({off_lo['peak_backlog_mcycles']:.1f}M → "
          f"{off_hi['peak_backlog_mcycles']:.1f}M) while admission held it at "
          f"{on_hi['peak_backlog_mcycles']:.1f}M")

    failures = check_gates(rows)
    if failures:
        for f in failures:
            print(f"[overload] GATE VIOLATED — {f}", file=sys.stderr)
    else:
        print("[overload] admission gates passed; shed carve-outs and backlog "
              "invariants validated on every run")
    if args.out:
        write_csv(rows, args.out)
        print(f"[overload] wrote {len(rows)} rows to {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
