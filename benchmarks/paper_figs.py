"""Paper-artifact benchmarks: Fig 8-13 + Table 3.

Every figure is regenerated from the architecture models in repro.core — the
baselines (CraterLake, F1+) are simulator configs, so speedups *emerge* from
architecture (cache volume, fused pipeline, multi-job scheduling) rather than
being transcribed.  ARK/SHARP/GPU/FPGA baselines (closed designs we don't
model) use the paper's reported relative performance, labelled `derived`.
"""

from __future__ import annotations

import numpy as np

from repro.core import hardware as H
from repro.core import jobs as J
from repro.core import planner as PL
from repro.core import scheduler as S
from repro.core.cache import MB
from repro.core.simulator import lanes_deep, lanes_shallow, simulate_stream
from repro.fhe import params as FP


def fig9_single_workload() -> dict:
    """Deep + shallow single-job latency: FLASH-FHE vs CraterLake vs F1+."""
    rows = {}
    deep_cl, deep_f1 = [], []
    for w in FP.WORKLOAD_PRESETS:
        job = J.make_job(w)
        t = {c.name: S.schedule([job], c)[0].sim.time_s
             for c in (H.FLASH_FHE, H.CRATERLAKE, H.F1PLUS)}
        rows[w] = {"kind": job.kind, "flash_fhe_ms": t["flash-fhe"] * 1e3,
                   "craterlake_over_ff": t["craterlake"] / t["flash-fhe"],
                   "f1plus_over_ff": t["f1plus"] / t["flash-fhe"]}
        if job.kind == "deep":
            deep_cl.append(rows[w]["craterlake_over_ff"])
            deep_f1.append(rows[w]["f1plus_over_ff"])
    gm = lambda xs: float(np.exp(np.mean(np.log(xs))))
    return {"rows": rows,
            "deep_geomean_vs_craterlake": gm(deep_cl),  # paper: 1.4×
            "deep_geomean_vs_f1plus": gm(deep_f1),  # paper: 11.2×
            "paper_claims": {"vs_craterlake": 1.4, "vs_f1plus": 11.2}}


def fig10_7nm() -> dict:
    """7nm comparison vs ARK/SHARP (baselines derived from reported ratios)."""
    ff_lr = S.schedule([J.make_job("logreg")], H.FLASH_FHE)[0].sim.time_s
    ff_rn = S.schedule([J.make_job("resnet20")], H.FLASH_FHE)[0].sim.time_s
    # paper §6.3: FF is 42.3% better than ARK on LR, 21.6% worse on ResNet-20
    ark_lr, ark_rn = ff_lr * 1.423, ff_rn / 1.216
    areas = {"flash-fhe": H.area_total_mm2("7nm"), "ark": H.BASELINE_AREAS_MM2["ark"],
             "sharp": H.BASELINE_AREAS_MM2["sharp"]}
    perf_area_lr = (1.0 / ff_lr / areas["flash-fhe"]) / (1.0 / ark_lr / areas["ark"])
    perf_area_rn = (1.0 / ff_rn / areas["flash-fhe"]) / (1.0 / ark_rn / areas["ark"])
    return {"ff_logreg_ms": ff_lr * 1e3, "ff_resnet20_ms": ff_rn * 1e3,
            "ark_logreg_ms_derived": ark_lr * 1e3,
            "ark_resnet20_ms_derived": ark_rn * 1e3,
            "perf_per_area_vs_ark_logreg": perf_area_lr,  # paper: 1.49-1.78×
            "perf_per_area_vs_ark_resnet20": perf_area_rn,
            "areas_mm2": areas}


def fig11_ntt_hmul() -> dict:
    """NTT / HMUL throughput at shallow parameters (N=2^14, logPQ≈438)."""
    chip = H.FLASH_FHE
    n, limbs = 1 << 14, 15  # ≈438/30 limbs
    # one NTT instruction over the full limb set, per affiliation, all 8 in parallel
    stream = [PL.I("NTT", n, limbs)]
    r = simulate_stream(stream, chip, lanes_shallow(chip))
    ntt_per_s = chip.n_affiliations / r.time_s
    hmul_stream = PL.hmul(PL.PlanParams(n=n, L=limbs - 1, alpha=5), limbs - 1)
    rh = simulate_stream(PL.add_hw_annotations(hmul_stream, PL.PlanParams(n, limbs - 1, 5)),
                         chip, lanes_shallow(chip))
    hmul_per_s = chip.n_affiliations / rh.time_s
    # baselines derived from the paper's reported ratios (>30× NTT, 60-100× HMUL)
    return {"ntt_ops_per_s": ntt_per_s, "hmul_ops_per_s": hmul_per_s,
            "tensorfhe_ntt_derived": ntt_per_s / 30.0,
            "fab_hmul_derived": hmul_per_s / 60.0,
            "heax_hmul_derived": hmul_per_s / 100.0}


def fig12_multi_shallow() -> dict:
    """Average/makespan speedup vs CraterLake for 1..10 parallel shallow jobs."""
    out = {}
    for k in range(1, 11):
        jobs = [J.make_job("lola_mnist_plain", job_id=i) for i in range(k)]
        ff = S.schedule(jobs, H.FLASH_FHE)
        cl = S.schedule(jobs, H.CRATERLAKE)
        out[k] = {"avg_speedup": S.avg_completion_cycles(cl) / S.avg_completion_cycles(ff),
                  "makespan_speedup": S.makespan(cl) / S.makespan(ff)}
    peak = max(v["makespan_speedup"] for v in out.values())
    return {"per_job_count": out, "peak_speedup": peak, "paper_claim": 8.0}


def fig8_cache_sweep() -> dict:
    """Key-switch performance vs total cache volume for dnum ∈ {1,2,3}."""
    res = {}
    for dnum in (1, 2, 3):
        p = FP.make_params(1 << 16, 57, dnum, check_security=False)
        pp = PL.PlanParams.of(p)
        stream = PL.add_hw_annotations(PL.key_switch(pp, p.L) * 8, pp)
        curve = {}
        for cap in (64, 128, 192, 256, 320, 384, 512):
            r = simulate_stream(stream, H.FLASH_FHE, lanes_deep(H.FLASH_FHE),
                                cache_bytes=cap * MB)
            curve[cap] = r.time_s * 1e3
        res[f"dnum{dnum}"] = curve
    sat1 = res["dnum1"][320] == res["dnum1"][512]
    return {"curves_ms": res, "dnum1_saturates_at_320MB": sat1}


def table3_area() -> dict:
    swift_frac = H.swift_logic_fraction("14nm")
    return {"total_14nm_mm2": H.area_total_mm2("14nm"),
            "total_7nm_mm2": H.area_total_mm2("7nm"),
            "swift_logic_fraction": swift_frac,
            "claim_under_7pct": swift_frac < 0.075,  # Table-3 arithmetic gives 7.2%; paper rounds to "<7%"
            "scaling_14_to_7": H.area_total_mm2("14nm") / H.area_total_mm2("7nm"),
            "baselines_mm2": H.BASELINE_AREAS_MM2}


def fig13_power() -> dict:
    total = sum(H.POWER_BREAKDOWN_W.values())
    return {"total_w": total,
            "breakdown_fraction": {k: v / total for k, v in H.POWER_BREAKDOWN_W.items()},
            "vs_craterlake": H.BASELINE_POWER_W["craterlake"] / total,
            "vs_ark": H.BASELINE_POWER_W["ark"] / total}


def perf_beyond_paper() -> dict:
    """§Perf FHE hillclimb: fused exit-MACs + (double-)hoisted rotations.

    Paper-faithful baseline vs optimized FLASH-FHE variant, deep workloads.
    """
    from repro.core.planner import workload_stream
    from repro.core.simulator import lanes_deep, simulate_stream
    from repro.fhe.context import ExecPolicy

    out = {}
    base = ExecPolicy(backend="fused", hoisting="never")
    opt = ExecPolicy(backend="fused", hoisting="always")
    for w in FP.DEEP_WORKLOADS:
        job = J.make_job(w)
        st_b = workload_stream(job.workload, job.params, mode="hw", policy=base)
        st_o = workload_stream(job.workload, job.params, mode="hw", policy=opt)
        rb = simulate_stream(st_b, H.FLASH_FHE, lanes_deep(H.FLASH_FHE))
        ro = simulate_stream(st_o, H.FLASH_FHE_FUSED_MAC,
                             lanes_deep(H.FLASH_FHE_FUSED_MAC))
        out[w] = {"baseline_ms": rb.time_s * 1e3, "optimized_ms": ro.time_s * 1e3,
                  "speedup": rb.time_s / ro.time_s,
                  "opt_dominant": max(ro.unit_cycles, key=ro.unit_cycles.get)}
    return out


def preemption_study() -> dict:
    """§4.2 preemptive scheduling: completion time with mixed arrivals."""
    jobs = [J.make_job("resnet20", priority=0, arrival_cycle=0, job_id=0)]
    jobs += [J.make_job("lola_mnist_plain", priority=5,
                        arrival_cycle=1000 + i, job_id=1 + i) for i in range(4)]
    ff = S.schedule(jobs, H.FLASH_FHE)
    cl = S.schedule(jobs, H.CRATERLAKE)
    sh_ff = np.mean([s.turnaround for s in ff if s.job.kind == "shallow"])
    sh_cl = np.mean([s.turnaround for s in cl if s.job.kind == "shallow"])
    return {"shallow_avg_turnaround_speedup": float(sh_cl / sh_ff),
            "deep_penalty_fraction": float(
                next(s for s in ff if s.job.kind == "deep").preempted_cycles /
                next(s for s in ff if s.job.kind == "deep").sim.cycles)}
