"""Fault-tolerance benchmark: identical arrival streams through crash /
straggler / flaky scenarios, with and without recovery.

Serves ONE seeded Poisson stream (≈0.8× fleet capacity over a shallow-heavy
mix with a deep minority) on a 4-chip FLASH-FHE fleet through five scenarios:

  baseline        — fault-free (the goodput yardstick)
  crash_recover   — chip 1 cycles through three crash/recover rounds (~30%
                    total downtime); ``RetryPolicy`` requeues every victim
                    (checkpoint resume for suspended deep jobs, full restart
                    otherwise)
  crash_norecover — the SAME crash with ``RetryPolicy(max_attempts=0)``:
                    every victim is terminally lost (the divergence baseline)
  flaky           — transient single-job failures on chip 0 through the run
  straggler       — chip 0 runs 2.5× slower for ~25% of the horizon

Every run calls ``ClusterResult.validate()`` — the no-lost-job terminal-state
invariant, the no-placement-on-dead-chip downtime check, and the gang
lockstep-abort invariant all gate implicitly.

Gates (exit non-zero on violation):
  (a) availability under recovery: ``crash_recover`` goodput_frac ≥
      ``RECOVER_GOODPUT_X`` (0.7×) the fault-free baseline's — losing 1 of 4
      chips for a quarter of the run must not cost more than ~30% of goodput.
  (b) recovery matters: ``crash_norecover`` loses ≥ ``LOSS_DIVERGE_X`` (2×)
      as many jobs as ``crash_recover`` (and at least one — the crash must
      actually kill something for the comparison to mean anything).
  (c) retries happen and terminate: ``crash_recover`` and ``flaky`` each
      retry ≥ 1 job, and no retried job exceeds the attempt bound (validated
      structurally: FAILED only after max_attempts+1 recorded attempts).

    PYTHONPATH=src python -m benchmarks.fault_bench --smoke --out fault_smoke.csv
    PYTHONPATH=src python -m benchmarks.fault_bench            # longer stream
"""

from __future__ import annotations

import argparse
import sys
import time

from repro import serve
from repro.core.hardware import FLASH_FHE

# shallow-heavy serving mix with a deep (bootstrapping) minority — the deep
# jobs are what exercise gang failover and checkpoint resume
FAULT_MIX: dict[str, float] = {
    "lola_mnist_plain": 0.30,
    "matmul": 0.28,
    "dblookup": 0.25,
    "lola_cifar_plain": 0.12,
    "lstm": 0.05,
}

N_CHIPS = 4
LOAD_X = 0.8  # offered load as a multiple of fleet capacity (feasible)
RECOVER_GOODPUT_X = 0.70  # gate (a): recovered goodput ≥ this × fault-free
LOSS_DIVERGE_X = 2.0  # gate (b): no-recovery loses ≥ this × more jobs
RETRY = serve.RetryPolicy(max_attempts=3, backoff_base=2_000.0,
                          backoff_factor=2.0, backoff_cap=64_000.0)
NO_RETRY = serve.RetryPolicy(max_attempts=0)


def stream(smoke: bool) -> tuple[list, float]:
    """One seeded Poisson stream at LOAD_X × fleet capacity; returns the jobs
    and the horizon estimate (cycles) the fault plans are scaled against."""
    capacity = serve.fleet_capacity_jobs_per_mcycle(
        FAULT_MIX, [FLASH_FHE] * N_CHIPS)
    rate = LOAD_X * capacity
    n_jobs = 400 if smoke else 1600
    cfg = serve.PoissonConfig(rate_per_mcycle=rate, n_jobs=n_jobs,
                              mix=FAULT_MIX, seed=61)
    horizon = n_jobs / rate * 1e6
    return serve.poisson_jobs(cfg), horizon


def scenarios(horizon: float) -> dict[str, tuple]:
    """(FaultPlan | None, RetryPolicy | None) per scenario, all scripted so
    the crash lands mid-stream regardless of the --smoke stream length.

    The crash scenario cycles chip 1 through three crash/recover rounds
    (total downtime ~30% of the horizon): the mix's capacity is dominated by
    whole-chip deep services, so any ONE crash instant catches only the 1–2
    jobs resident on the chip — repeated rounds accumulate enough victims
    that the recovery-vs-loss divergence gate measures something real."""
    crash = serve.FaultPlan(events=tuple(
        ev for at in (0.25, 0.45, 0.65)
        for ev in serve.FaultPlan.single_crash(
            chip=1, at=at * horizon, down=0.10 * horizon).events))
    flaky = serve.FaultPlan.flaky(chip=0, times=[f * horizon for f in
                                                 (0.2, 0.35, 0.5, 0.65, 0.8)])
    slow = serve.FaultPlan.straggler(chip=0, at=0.30 * horizon,
                                     span=0.25 * horizon, factor=2.5)
    return {
        "baseline": (None, None),
        "crash_recover": (crash, RETRY),
        "crash_norecover": (crash, NO_RETRY),
        "flaky": (flaky, RETRY),
        "straggler": (slow, RETRY),
    }


def _run_row(name: str, plan, retry, jobs: list) -> dict:
    t0 = time.perf_counter()
    result = serve.serve_cluster(jobs, FLASH_FHE, n_chips=N_CHIPS,
                                 router="jsq", validate=True,
                                 faults=plan, retry=retry)
    m = serve.summarize(result)
    return {
        "scenario": name, "n_chips": N_CHIPS, "load_x": LOAD_X,
        "recovery": int(retry is not None and retry.max_attempts > 0),
        "sim_wall_s": round(time.perf_counter() - t0, 3),
        **m,
    }


def run(smoke: bool = True) -> list[dict]:
    jobs, horizon = stream(smoke)
    return [_run_row(name, plan, retry, jobs)
            for name, (plan, retry) in scenarios(horizon).items()]


def _row(rows: list[dict], name: str) -> dict:
    return next(r for r in rows if r["scenario"] == name)


def check_gates(rows: list[dict]) -> list[str]:
    """Fault-tolerance acceptance gates — returns failure messages, [] = pass."""
    failures = []
    base = _row(rows, "baseline")
    rec = _row(rows, "crash_recover")
    norec = _row(rows, "crash_norecover")
    flaky = _row(rows, "flaky")
    if not base["n_failed"] == 0 and base["n_crashes"] == 0:
        failures.append("baseline run saw faults — injection leaked through")
    floor = RECOVER_GOODPUT_X * base["goodput_frac"]
    if not rec["goodput_frac"] >= floor:
        failures.append(
            f"crash_recover goodput {rec['goodput_frac']:.3f} < "
            f"{RECOVER_GOODPUT_X}× the fault-free baseline "
            f"({base['goodput_frac']:.3f}) — recovery did not hold availability")
    if not norec["n_failed"] >= 1:
        failures.append(
            "crash_norecover lost zero jobs — the crash scenario is vacuous")
    if not norec["n_failed"] >= LOSS_DIVERGE_X * max(rec["n_failed"], 0.5):
        failures.append(
            f"no-recovery lost {norec['n_failed']:.0f} jobs vs "
            f"{rec['n_failed']:.0f} with recovery — not ≥ {LOSS_DIVERGE_X}× "
            f"divergence; retries are not earning their keep")
    for r, tag in ((rec, "crash_recover"), (flaky, "flaky")):
        if not r["retries_total"] >= 1:
            failures.append(f"{tag}: zero retries recorded — the fault plan "
                            f"never hit running work")
    return failures


def write_csv(rows: list[dict], path: str) -> None:
    cols = list(rows[0].keys())
    with open(path, "w") as fh:
        fh.write(",".join(cols) + "\n")
        for r in rows:
            fh.write(",".join(f"{r[c]:.6g}" if isinstance(r[c], float) else str(r[c])
                              for c in cols) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="short stream (400 jobs) for CI")
    ap.add_argument("--out", default=None, help="write rows to this CSV file")
    args = ap.parse_args(argv)

    rows = run(smoke=args.smoke)
    print(f"{'scenario':>16s} {'rec':>3s} {'goodput':>7s} {'lost':>5s} "
          f"{'retries':>7s} {'wasted':>8s} {'ckpt':>7s} {'avail':>6s} "
          f"{'mttr':>7s} {'p99 sh':>9s}")
    for r in rows:
        print(f"{r['scenario']:>16s} {int(r['recovery']):3d} "
              f"{r['goodput_frac']:7.3f} {int(r['n_failed']):5d} "
              f"{int(r['retries_total']):7d} {r['wasted_mcycles']:7.2f}M "
              f"{r['checkpoint_saved_mcycles']:6.2f}M {r['availability']:6.3f} "
              f"{r['mttr_mcycles']:6.2f}M "
              f"{r['latency_p99_shallow_cycles']/1e6:8.2f}M")

    base, rec, norec = (_row(rows, s) for s in
                        ("baseline", "crash_recover", "crash_norecover"))
    print(f"[faults] crash/recover on {N_CHIPS} chips: goodput "
          f"{rec['goodput_frac']:.3f} vs fault-free {base['goodput_frac']:.3f} "
          f"({rec['goodput_frac']/max(base['goodput_frac'], 1e-9):.2f}×, gate ≥ "
          f"{RECOVER_GOODPUT_X}×); {int(rec['retries_total'])} retries recovered "
          f"{int(rec['n_retried_jobs'])} jobs, {int(rec['n_failed'])} lost")
    print(f"[faults] no-recovery on the same crash: {int(norec['n_failed'])} "
          f"jobs lost vs {int(rec['n_failed'])} with recovery (gate ≥ "
          f"{LOSS_DIVERGE_X}× divergence); availability "
          f"{rec['availability']:.3f}, MTTR {rec['mttr_mcycles']:.2f} Mcycles")

    failures = check_gates(rows)
    if failures:
        for f in failures:
            print(f"[faults] GATE VIOLATED — {f}", file=sys.stderr)
    else:
        print("[faults] fault-tolerance gates passed; no-lost-job, dead-chip "
              "and lockstep-abort invariants validated on every run")
    if args.out:
        write_csv(rows, args.out)
        print(f"[faults] wrote {len(rows)} rows to {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
