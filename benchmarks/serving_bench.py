"""Multi-tenant serving benchmark: FLASH-FHE vs CraterLake vs F1+ under
shallow-only / deep-only / mixed Poisson arrival streams.

Each scenario draws one seeded arrival stream and serves it on every chip
through the discrete-event engine (``repro.serve``), reporting SLO metrics
(p50/p95/p99 latency, queueing delay, makespan, throughput, utilization,
fairness) as CSV rows.  Every run re-validates the engine's timeline
invariants (no overlapping placements per affiliation, work conservation).

The ``mixed`` scenario is the paper's headline multi-tenant case: a
shallow-heavy stream with a deep background and a high-priority shallow slice
that exercises preemption.  The benchmark asserts FLASH-FHE beats CraterLake
on both p99 latency and makespan there — the serving-side counterpart of the
paper's up-to-8× multi-job claim.

    PYTHONPATH=src python -m benchmarks.serving_bench --smoke --out serving_smoke.csv
    PYTHONPATH=src python -m benchmarks.serving_bench            # full streams
"""

from __future__ import annotations

import argparse
import sys
import time

from repro import serve
from repro.core.hardware import CRATERLAKE, F1PLUS, FLASH_FHE

CHIPS = (FLASH_FHE, CRATERLAKE, F1PLUS)

# Arrival rates are sized against the measured service times (shallow ≈
# 0.05–0.28 Mcycles, deep ≈ 3.4–5.8 Mcycles): shallow_only offers ~2× one
# chip's sequential capacity (FLASH absorbs it across 8 affiliations), mixed
# runs the deep lane near saturation, deep_only stays sub-saturated so the
# gang-scheduling order — not raw backlog — sets the latency profile.


def scenarios(smoke: bool) -> dict[str, serve.PoissonConfig]:
    scale = 1 if smoke else 4
    return {
        "shallow_only": serve.PoissonConfig(
            rate_per_mcycle=12.0, n_jobs=48 * scale, mix=serve.traffic.SHALLOW_MIX,
            priority_mix={0: 0.7, 5: 0.3}, seed=11),
        "deep_only": serve.PoissonConfig(
            rate_per_mcycle=0.15, n_jobs=8 * scale, mix=serve.traffic.DEEP_MIX,
            priority_mix={0: 1.0}, seed=13),
        "mixed": serve.PoissonConfig(
            rate_per_mcycle=2.0, n_jobs=64 * scale, mix=serve.traffic.MIXED_MIX,
            priority_mix={0: 0.6, 5: 0.4}, seed=17),
    }


def run(smoke: bool = True) -> list[dict]:
    rows = []
    for scen, cfg in scenarios(smoke).items():
        jobs = serve.poisson_jobs(cfg)
        for chip in CHIPS:
            t0 = time.perf_counter()
            result = serve.serve(jobs, chip, validate=True)
            metrics = serve.summarize(result)
            rows.append({"scenario": scen, "chip": chip.name,
                         "sim_wall_s": round(time.perf_counter() - t0, 3), **metrics})
        # hoisted-rotation kernel mode on FLASH-FHE: deep (CtS/StC-heavy)
        # service times shrink, so the same stream clears faster — the
        # serving-level view of the kernels/hoistrot amortisation.  Selected
        # through an ExecPolicy; its policy_key() keys the service-time memo.
        t0 = time.perf_counter()
        hoisted_policy = serve.ExecPolicy(backend="fused", hoisting="always")
        result = serve.serve(jobs, FLASH_FHE, validate=True, exec_policy=hoisted_policy)
        rows.append({"scenario": f"{scen}_hoisted", "chip": FLASH_FHE.name,
                     "sim_wall_s": round(time.perf_counter() - t0, 3),
                     **serve.summarize(result)})
    return rows


def check_paper_claim(rows: list[dict]) -> list[str]:
    """FLASH-FHE must strictly beat CraterLake on the shallow-heavy mixed
    stream (p99 latency AND makespan) — returns failure messages, [] = pass."""
    failures = []
    for scen in ("mixed", "shallow_only"):
        by_chip = {r["chip"]: r for r in rows if r["scenario"] == scen}
        ff, cl = by_chip["flash-fhe"], by_chip["craterlake"]
        for key in ("latency_p99_cycles", "makespan_mcycles"):
            if not ff[key] < cl[key]:
                failures.append(
                    f"{scen}: flash-fhe {key}={ff[key]:.4g} not < craterlake {cl[key]:.4g}")
    # hoisted rotations must not make any stream worse (the hard dispatch /
    # wall-clock gates live in benchmarks.hoisting_bench; FLASH-FHE is
    # modmul-bound end-to-end, so the serving-level makespan win is small)
    for scen in ("deep_only", "mixed"):
        base = next(r for r in rows
                    if r["scenario"] == scen and r["chip"] == "flash-fhe")
        hoisted = next(r for r in rows if r["scenario"] == f"{scen}_hoisted")
        if hoisted["makespan_mcycles"] > base["makespan_mcycles"]:
            failures.append(
                f"{scen}: hoisted makespan {hoisted['makespan_mcycles']:.4g} regressed "
                f"over baseline {base['makespan_mcycles']:.4g}")
    return failures


def write_csv(rows: list[dict], path: str) -> None:
    cols = list(rows[0].keys())
    with open(path, "w") as fh:
        fh.write(",".join(cols) + "\n")
        for r in rows:
            fh.write(",".join(f"{r[c]:.6g}" if isinstance(r[c], float) else str(r[c])
                              for c in cols) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="small streams (CI)")
    ap.add_argument("--out", default=None, help="write rows to this CSV file")
    args = ap.parse_args(argv)

    rows = run(smoke=args.smoke)
    hdr = f"{'scenario':13s} {'chip':11s} {'jobs':>5s} {'p50':>10s} {'p99':>12s} " \
          f"{'queue p99':>12s} {'makespan':>10s} {'util':>6s} {'fair':>6s} {'preempt':>7s}"
    print(hdr)
    for r in rows:
        print(f"{r['scenario']:13s} {r['chip']:11s} {int(r['n_jobs']):5d} "
              f"{r['latency_p50_cycles']/1e6:9.2f}M {r['latency_p99_cycles']/1e6:11.2f}M "
              f"{r['queue_p99_cycles']/1e6:11.2f}M {r['makespan_mcycles']:9.2f}M "
              f"{r['util_mean']:6.2f} {r['fairness_jain']:6.2f} {int(r['n_preemptions']):7d}")

    failures = check_paper_claim(rows)
    for scen in ("mixed", "shallow_only"):
        by_chip = {r["chip"]: r for r in rows if r["scenario"] == scen}
        ff, cl = by_chip["flash-fhe"], by_chip["craterlake"]
        print(f"[serving] {scen}: FLASH-FHE vs CraterLake — "
              f"p99 {cl['latency_p99_cycles']/ff['latency_p99_cycles']:.2f}×, "
              f"makespan {cl['makespan_mcycles']/ff['makespan_mcycles']:.2f}× better")
    if failures:
        for f in failures:
            print(f"[serving] CLAIM VIOLATED — {f}", file=sys.stderr)
    else:
        print("[serving] paper-claim check passed (FLASH-FHE strictly better on "
              "shallow-heavy streams); timelines validated (no overlapping placements)")

    if args.out:
        write_csv(rows, args.out)
        print(f"[serving] wrote {len(rows)} rows to {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
