"""Hoisted vs per-rotation key-switched rotations: the amortisation, measured.

Two measurement shapes, both executed for real through the kernel pipelines
(Pallas interpret off-TPU — dispatch counts are the architecture-honest
metric there; wall clock still rewards fewer launches):

  * ``group``     — a k-rotation hoisting group (`ctx.rotate_hoisted_group`)
                    vs k standalone `ctx.rotate` calls on the same ciphertext:
                    kernel dispatches, extended-basis forward-NTT trace
                    records (β + O(1) vs k·β), wall clock, bit-exactness.
  * ``cts_stage`` — a radix-32 CoeffToSlot stage shape at N=2^14 (63
                    diagonals; the diagonal *values* are random, the
                    rotation/BSGS structure is the real one) through
                    `ctx.apply_bsgs` under hoisting="always" vs "never".
                    n1 comes from the planner's hoisting-aware cost model
                    (`linear.choose_n1`), which finds n1 = 16 (15 baby + 3
                    giant rotations) over the √63 ≈ 8 classic balance point:
                    hoisting makes baby steps nearly free, shifting the BSGS
                    optimum toward more babies.  The bench asserts the model
                    picks 16 so the planner and the measured win stay coupled.

CI gates (``check_gates``; `python -m benchmarks.hoisting_bench` exits
non-zero on failure):

  1. the hoisted CtS stage at N=2^14 issues ≤ 60% of the staged path's
     key-switch kernel dispatches (intt/fused-KS/ModUp/MAC/ModDown launches —
     the rotation datapath; encode/pointwise launches are identical on both
     sides and reported separately as ``dispatch_ratio_total``),
  2. it beats the staged path on wall clock,
  3. every hoisted result is bit-exact against the per-rotation path.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax.numpy as jnp
import numpy as np

from repro.fhe import ExecPolicy, FheContext
from repro.fhe import keys as K
from repro.fhe import linear
from repro.fhe import params as P
from repro.fhe import trace
from repro.kernels import dispatch

# kernel launches belonging to the rotation/key-switch datapath
KS_KERNELS = ("intt", "fusedks", "hoistmodup", "hoistmac", "fused_moddown")


def _ks_dispatches(counts: dict) -> int:
    return sum(counts.get(k, 0) for k in KS_KERNELS)


def _time_call(fn, iters: int) -> float:
    """Min wall-clock seconds per call (after one warmup/compile call).

    Min, not median: interpret-mode Pallas timings on shared CI runners swing
    >30% run-to-run from load noise, and the minimum is the standard
    noise-robust estimator — the gate compares best-case against best-case."""
    fn()
    times = []
    for _ in range(max(2, iters)):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.min(times))


def _ct_equal(a, b) -> bool:
    return bool(jnp.array_equal(a.c0, b.c0)) and bool(jnp.array_equal(a.c1, b.c1))


def _ext_ntts(instrs, m: int) -> int:
    return sum(1 for i in instrs if i.op == "NTT" and i.limbs == m)


def bench_group(n: int, L: int, dnum: int, k: int, iters: int = 2, seed: int = 0) -> dict:
    """One k-rotation hoisting group vs k standalone rotations (fused path)."""
    p = P.make_params(n, L, dnum, check_security=False)
    rots = tuple(range(1, k + 1))
    ctx = FheContext(params=p, keys=K.full_keyset(p, seed=seed, rotations=rots),
                     policy=ExecPolicy(backend="fused", hoisting="never"))
    rng = np.random.default_rng(seed + 1)
    ct = ctx.encrypt(ctx.encode(rng.normal(size=p.slots) * 0.3))
    level, beta = p.L, p.beta(p.L)
    m = level + 1 + p.alpha

    group = ctx.rotate_hoisted_group(ct, rots)
    singles = {r: ctx.rotate(ct, r) for r in rots}
    bitexact = int(all(_ct_equal(group[r], singles[r]) for r in rots))

    with dispatch.count_dispatches() as ch, trace.capture_trace() as th:
        ctx.rotate_hoisted_group(ct, rots)
    with dispatch.count_dispatches() as cs, trace.capture_trace() as ts:
        for r in rots:
            ctx.rotate(ct, r)

    t_h = _time_call(lambda: ctx.rotate_hoisted_group(ct, rots), iters)
    t_s = _time_call(
        lambda: [ctx.rotate(ct, r) for r in rots], iters
    )
    return {
        "config": f"group_n{n}_L{L}_dnum{dnum}_k{k}",
        "n": n, "L": L, "dnum": dnum, "k": k, "beta": beta,
        "bitexact": bitexact,
        "ext_ntt_hoisted": _ext_ntts(th, m),      # == β
        "ext_ntt_staged": _ext_ntts(ts, m),       # == k·β
        "dispatches_hoisted": dispatch.total(ch),
        "dispatches_staged": dispatch.total(cs),
        "dispatch_ratio": dispatch.total(ch) / dispatch.total(cs),
        "wall_ms_hoisted": t_h * 1e3,
        "wall_ms_staged": t_s * 1e3,
        "wall_speedup": t_s / t_h,
    }


def _cts_stage_plan(p: P.CkksParams, radix: int = 32, seed: int = 0):
    """A radix-``radix`` CoeffToSlot stage *shape*: 2·radix−1 diagonals.

    The true CtS factor matrices at N=2^14 are slots×slots dense (1 GB+) —
    structurally the level-collapsed FFT stage is a banded matrix with
    2·radix−1 populated diagonals, which is what drives the rotation count.
    We build that structure directly with random diagonal values; n1 comes
    from the hoisting-aware cost model (``linear.plan_diags``), which must
    find the n1 = 16 optimum this bench used to hand-pick."""
    rng = np.random.default_rng(seed)
    diags = {
        int(d): (rng.normal(size=p.slots) + 1j * rng.normal(size=p.slots)) / radix
        for d in range(2 * radix - 1)
    }
    plan = linear.plan_diags(diags, p, level=p.L, hoisting=True)
    assert plan.n1 == 16, (
        f"hoisting-aware cost model picked n1={plan.n1}, expected the measured "
        "optimum 16 — model and bench have diverged"
    )
    return plan


def bench_cts_stage(n: int = 1 << 14, L: int = 3, dnum: int = 3,
                    iters: int = 2, seed: int = 0) -> dict:
    """CtS-stage BSGS transform, hoisted vs per-rotation, fused kernels."""
    p = P.make_params(n, L, dnum, check_security=False)
    plan = _cts_stage_plan(p, seed=seed)
    ks = K.full_keyset(p, seed=seed, rotations=tuple(plan.rotations()))
    hctx = FheContext(params=p, keys=ks,
                      policy=ExecPolicy(backend="fused", hoisting="always"))
    sctx = hctx.with_policy(hoisting="never")
    rng = np.random.default_rng(seed + 1)
    ct = hctx.encrypt(hctx.encode(rng.normal(size=p.slots) * 0.3))
    beta = p.beta(p.L)
    m = p.L + 1 + p.alpha
    k = len(plan.baby_steps())

    hoisted = hctx.apply_bsgs(ct, plan)
    staged = sctx.apply_bsgs(ct, plan)
    bitexact = int(_ct_equal(hoisted, staged))

    with dispatch.count_dispatches() as ch, trace.capture_trace() as th:
        hctx.apply_bsgs(ct, plan)
    with dispatch.count_dispatches() as cs, trace.capture_trace() as ts:
        sctx.apply_bsgs(ct, plan)

    t_h = _time_call(lambda: hctx.apply_bsgs(ct, plan), iters)
    t_s = _time_call(lambda: sctx.apply_bsgs(ct, plan), iters)
    return {
        "config": f"cts_stage_n{n}_L{L}_dnum{dnum}",
        "n": n, "L": L, "dnum": dnum, "k": k, "beta": beta, "n1": plan.n1,
        "n_diags": len(plan.diags), "n_giants": len(plan.giant_steps()),
        "bitexact": bitexact,
        "ext_ntt_hoisted": _ext_ntts(th, m),
        "ext_ntt_staged": _ext_ntts(ts, m),
        "ks_dispatches_hoisted": _ks_dispatches(ch),
        "ks_dispatches_staged": _ks_dispatches(cs),
        "dispatch_ratio": _ks_dispatches(ch) / _ks_dispatches(cs),
        "dispatch_ratio_total": dispatch.total(ch) / dispatch.total(cs),
        "wall_ms_hoisted": t_h * 1e3,
        "wall_ms_staged": t_s * 1e3,
        "wall_speedup": t_s / t_h,
    }


SMOKE_GROUPS = [(1 << 14, 3, 3, 15)]
FULL_GROUPS = [(1 << 9, 5, 1, 8), (1 << 9, 5, 2, 8), (1 << 10, 8, 2, 12), (1 << 14, 3, 3, 15)]


def run(smoke: bool = False, iters: int = 2) -> list[dict]:
    rows = []
    for n, L, dnum, k in (SMOKE_GROUPS if smoke else FULL_GROUPS):
        rows.append(bench_group(n, L, dnum, k, iters=iters))
    rows.append(bench_cts_stage(iters=iters))
    return rows


def check_gates(rows: list[dict]) -> list[str]:
    """The hoisting CI gates; returns human-readable failure strings."""
    failures = []
    for r in rows:
        if not r["bitexact"]:
            failures.append(f"{r['config']}: hoisted result NOT bit-exact")
        if r["config"].startswith("cts_stage"):
            if r["dispatch_ratio"] > 0.60:
                failures.append(
                    f"{r['config']}: hoisted issues {r['dispatch_ratio']:.0%} of the "
                    f"staged key-switch dispatches (gate: <= 60%)"
                )
            if r["wall_ms_hoisted"] >= r["wall_ms_staged"]:
                failures.append(
                    f"{r['config']}: hoisted wall clock {r['wall_ms_hoisted']:.1f} ms "
                    f"did not beat staged {r['wall_ms_staged']:.1f} ms"
                )
            if r["ext_ntt_hoisted"] >= r["ext_ntt_staged"]:
                failures.append(f"{r['config']}: ext-NTT records not reduced")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="gate configs only")
    ap.add_argument("--out", default=None, help="write CSV rows to this file")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args(argv)

    rows = run(smoke=args.smoke, iters=args.iters)
    lines = []
    for r in rows:
        for key, val in r.items():
            if key == "config":
                continue
            if isinstance(val, float):
                val = f"{val:.6g}"
            lines.append(f"hoisting.{r['config']}.{key},{val},0")
    print("\n".join(lines))
    if args.out:
        with open(args.out, "w") as fh:
            fh.write("\n".join(lines) + "\n")

    failures = check_gates(rows)
    for f in failures:
        print(f"GATE FAILED: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
