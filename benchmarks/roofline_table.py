"""Aggregate dry-run JSON records into the §Roofline table (markdown + dict)."""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_records(dryrun_dir: str = DRYRUN_DIR) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def summarize(recs: list[dict]) -> dict:
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    failed = [r for r in recs if r.get("status") == "FAILED"]
    return {"ok": len(ok), "skipped": len(skipped), "failed": len(failed),
            "total": len(recs)}


def table_markdown(recs: list[dict], mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful-FLOPs ratio | peak bytes/dev (CPU-backend compile) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | N/A "
                         f"(skipped: {r['reason'][:40]}…) | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | |")
            continue
        rl = r["roofline"]
        mem = r.get("memory", {}).get("bytes_per_device")
        memgb = f"{mem/2**30:.1f} GiB" if mem else "?"
        ratio = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.2e} | "
            f"{rl['memory_s']:.2e} | {rl['collective_s']:.2e} | {rl['dominant']} | "
            f"{ratio:.2f} | {memgb} |")
    return "\n".join(lines)


def main() -> dict:
    recs = load_records()
    s = summarize(recs)
    doms = {}
    for r in recs:
        if r.get("status") == "ok" and r.get("mesh") == "16x16":
            doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    return {"summary": s, "dominant_histogram": doms}
