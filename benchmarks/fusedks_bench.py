"""Fused vs staged key-switch: dispatch counts, wall-clock, bit-exactness.

The fusion claim is measured, not asserted: for each configuration we run the
same `key_switch` through the fused pipeline (one `pallas_call` for the digit
region + one for the ModDown tails) and the staged pipeline (one launch per
stage per digit), and report

  * kernel dispatches per call (the architectural win — intermediates that no
    longer round-trip between launches),
  * median wall-clock per call (meaningful on TPU; on CPU the fused kernel
    runs in Pallas interpret mode, so dispatch counts are the honest metric
    there),
  * bit-exactness of the fused result against the staged u64 oracle.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.fhe import keys as K
from repro.fhe import keyswitch as KS
from repro.fhe import params as P
from repro.kernels import dispatch


def _rand_eval(p, level, seed=3):
    rng = np.random.default_rng(seed)
    qs = np.array(p.q_primes[: level + 1], np.uint64)
    d = rng.integers(0, 1 << 31, size=(level + 1, p.n)) % qs[:, None]
    return jnp.asarray(d.astype(np.uint32))


def _time_call(fn, iters: int) -> float:
    """Median wall-clock seconds per call (after one warmup/compile call)."""
    out = fn()
    for arr in out:
        arr.block_until_ready()
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        out = fn()
        for arr in out:
            arr.block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def bench_key_switch(n: int, L: int, dnum: int, iters: int = 3, seed: int = 0) -> dict:
    """One fused-vs-staged comparison; returns flat CSV-ready metrics."""
    p = P.make_params(n, L, dnum, check_security=False)
    sk = K.keygen(p, seed)
    rlk = K.relin_keygen(p, sk)
    level = p.L
    d = _rand_eval(p, level, seed=seed + 1)

    fused = KS.key_switch(d, p, level, rlk, backend="fused")
    ref = KS.key_switch(d, p, level, rlk, backend="ref")
    bitexact = int(
        bool(jnp.array_equal(fused[0], ref[0])) and bool(jnp.array_equal(fused[1], ref[1]))
    )

    with dispatch.count_dispatches() as cf:
        KS.key_switch(d, p, level, rlk, backend="fused")
    with dispatch.count_dispatches() as cs:
        KS.key_switch(d, p, level, rlk, backend="staged")
    disp_fused, disp_staged = dispatch.total(cf), dispatch.total(cs)

    t_fused = _time_call(lambda: KS.key_switch(d, p, level, rlk, backend="fused"), iters)
    t_staged = _time_call(lambda: KS.key_switch(d, p, level, rlk, backend="staged"), iters)

    return {
        "n": n,
        "L": L,
        "dnum": dnum,
        "beta": p.beta(level),
        "bitexact": bitexact,
        "dispatches_fused": disp_fused,
        "dispatches_staged": disp_staged,
        "dispatch_reduction": disp_staged / disp_fused,
        "wall_ms_fused": t_fused * 1e3,
        "wall_ms_staged": t_staged * 1e3,
    }


SMOKE_CONFIGS = [(1 << 9, 5, 2)]
FULL_CONFIGS = [(1 << 9, 5, 2), (1 << 10, 8, 2), (1 << 10, 8, 3), (1 << 11, 11, 3)]


def run(smoke: bool = False, iters: int = 3) -> dict[str, dict]:
    configs = SMOKE_CONFIGS if smoke else FULL_CONFIGS
    out = {}
    for n, L, dnum in configs:
        out[f"n{n}_L{L}_dnum{dnum}"] = bench_key_switch(n, L, dnum, iters=iters)
    return out
