"""Docs smoke: execute every fenced ``python`` snippet in README.md and
docs/*.md, and check that intra-repo markdown links resolve.

Every snippet runs in a fresh namespace with the repo's ``src/`` on
``sys.path`` and the legacy-shim ``DeprecationWarning``s promoted to errors
(the same ``repro.fhe`` message filter the deprecation-smoke CI job uses), so
documentation can neither rot against the API nor quietly teach the
deprecated surface.  Snippets must therefore be self-contained and fast —
that is a feature: every example a reader copies actually runs.

Link checking covers relative ``[text](path)`` targets: the target (anchor
stripped) must exist on disk.  Targets that escape the repository root (the
README's ``../../actions`` CI-badge idiom resolves only on GitHub) and
absolute URLs are skipped.

    PYTHONPATH=src python tools/docs_smoke.py            # all docs
    PYTHONPATH=src python tools/docs_smoke.py README.md  # one file
"""

from __future__ import annotations

import re
import sys
import time
import warnings
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

FENCE_RE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.MULTILINE | re.DOTALL)
# [text](target) — but not ![image](...) captures too; images are links too,
# and inline code/URLs with parens are rare enough to keep the regex simple
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def doc_files(argv: list[str]) -> list[Path]:
    if argv:
        return [REPO / a for a in argv]
    return [REPO / "README.md"] + sorted((REPO / "docs").glob("*.md"))


def extract_snippets(text: str) -> list[tuple[int, str]]:
    """(starting line number, source) for every ```python fenced block."""
    out = []
    for m in FENCE_RE.finditer(text):
        line = text.count("\n", 0, m.start()) + 2  # code starts after fence
        out.append((line, m.group(1)))
    return out


def run_snippet(path: Path, line: int, src: str) -> str | None:
    """Execute one snippet; returns an error string or None."""
    with warnings.catch_warnings():
        warnings.filterwarnings("error", message=r"repro\.fhe",
                                category=DeprecationWarning)
        try:
            code = compile(src, f"{path.name}:{line}", "exec")
            exec(code, {"__name__": f"docs_smoke_{path.stem}_{line}"})
        except Exception as e:  # noqa: BLE001 — report, don't crash the runner
            return f"{type(e).__name__}: {e}"
    return None


def check_links(path: Path, text: str) -> list[str]:
    errors = []
    for m in LINK_RE.finditer(text):
        target = m.group(1).split("#", 1)[0]
        if not target or target.startswith(("http://", "https://", "mailto:")):
            continue
        resolved = (path.parent / target).resolve()
        if not resolved.is_relative_to(REPO):
            continue  # e.g. the ../../actions CI-badge path, valid on GitHub only
        if not resolved.exists():
            line = text.count("\n", 0, m.start()) + 1
            errors.append(f"{path.name}:{line}: broken link -> {m.group(1)}")
    return errors


def main(argv: list[str]) -> int:
    sys.path.insert(0, str(REPO / "src"))
    failures: list[str] = []
    n_snippets = 0
    for path in doc_files(argv):
        if not path.exists():
            failures.append(f"{path}: file not found")
            continue
        text = path.read_text()
        failures.extend(check_links(path, text))
        for line, src in extract_snippets(text):
            n_snippets += 1
            t0 = time.perf_counter()
            err = run_snippet(path, line, src)
            status = "FAIL" if err else "ok"
            print(f"[docs-smoke] {path.relative_to(REPO)}:{line} "
                  f"{status} ({time.perf_counter() - t0:.1f}s)")
            if err:
                failures.append(f"{path.name}:{line}: {err}")
    for f in failures:
        print(f"[docs-smoke] FAIL — {f}", file=sys.stderr)
    if not failures:
        print(f"[docs-smoke] {n_snippets} snippets executed, all links resolve")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
