"""Observability smoke: trace a seeded faulty fleet run, verify, export.

The scenario exercises every tracer seam at once — a 4-chip FLASH-FHE fleet
with cross-chip deep gangs, a mid-run chip crash + recovery, a straggler
window, transient job failures, retries, and admission — then checks the
four properties CI gates on:

  1. **determinism** — two runs with the same seed export byte-identical
     Chrome trace JSON (the tracer records only sim-clock/index timestamps);
  2. **structural validity** — ``validate_chrome_trace`` finds balanced B/E
     stacks, balanced async spans, monotone per-track timestamps, and only
     known phases;
  3. **zero-overhead disable** — the same run without a tracer produces the
     identical ``ClusterResult`` timeline (makespan and per-job completions);
  4. **consistent books** — per-chip shed/fault attributions sum to the
     fleet-global counters (also asserted inside ``ClusterResult.validate``).

It then writes the trace artifact (open it at https://ui.perfetto.dev),
appends the scenario's headline metrics to the perf history, and runs the
regression check over the file.

    PYTHONPATH=src python tools/obs_smoke.py [--trace-out FILE] [--history FILE]
"""

from __future__ import annotations

import argparse
import random
import sys

from repro import serve
from repro.core import jobs as J
from repro.core.hardware import FLASH_FHE
from repro.obs import (
    Tracer,
    dumps_chrome_trace,
    history,
    to_chrome_trace,
    validate_chrome_trace,
)
from repro.serve.faults import FaultPlan, RetryPolicy

SHALLOW = ("matmul", "lola_mnist_plain", "dblookup")
SEED = 20260809


def make_jobs(seed: int, n: int = 48, deep_frac: float = 0.3) -> list:
    rng = random.Random(seed)
    jobs, t = [], 0
    for i in range(n):
        t += rng.randint(1_000, 30_000)
        wl = "lstm" if rng.random() < deep_frac else rng.choice(SHALLOW)
        jobs.append(J.make_job(wl, priority=rng.randint(0, 2), arrival_cycle=t,
                               job_id=i, tenant_id=i % 3))
    return jobs


def fault_plan() -> FaultPlan:
    return (FaultPlan.single_crash(chip=1, at=2.0e5, down=1.0e6)
            .merged(FaultPlan.straggler(chip=0, at=1.0e5, span=8.0e5, factor=2.0))
            .merged(FaultPlan.flaky(chip=2, times=(3.0e5, 6.0e5))))


def run_fleet(tracer=None):
    return serve.serve_cluster(
        make_jobs(SEED), FLASH_FHE, n_chips=4, router="jsq", seed=3,
        gang_max_chips=2, faults=fault_plan(), retry=RetryPolicy(),
        tracer=tracer, validate=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace-out", default="obs_smoke_trace.json")
    ap.add_argument("--history", default="BENCH_HISTORY.json")
    args = ap.parse_args(argv)
    failures: list[str] = []

    tr1 = Tracer()
    res = run_fleet(tr1)
    tr2 = Tracer()
    run_fleet(tr2)
    blob1, blob2 = dumps_chrome_trace(tr1), dumps_chrome_trace(tr2)
    if blob1 != blob2:
        failures.append("same-seed traces are not byte-identical")
    print(f"trace: {len(tr1.events)} events, {len(blob1)} bytes")

    problems = validate_chrome_trace(to_chrome_trace(tr1))
    if problems:
        failures.append(f"trace fails validation: {problems[:5]}")
    else:
        print("trace validates: balanced spans, monotone timestamps")

    bare = run_fleet(tracer=None)
    if bare.makespan != res.makespan:
        failures.append(
            f"disabled tracer changed the timeline: makespan "
            f"{bare.makespan} != {res.makespan}")
    traced_done = sorted((je.job.job_id, je.completion) for je in res.jobs
                         if je.completion is not None)
    bare_done = sorted((je.job.job_id, je.completion) for je in bare.jobs
                       if je.completion is not None)
    if traced_done != bare_done:
        failures.append("disabled tracer changed per-job completions")
    else:
        print(f"zero-overhead check: {len(bare_done)} completions identical "
              "with tracing off")

    # the fault scenario must actually have exercised the seams it claims to
    fc = res.fault_counts
    for key in ("crashes", "transients", "retries"):
        if fc.get(key, 0) < 1:
            failures.append(f"scenario recorded no {key} — seams untested")
    if not res.gangs:
        failures.append("scenario placed no cross-chip gang")

    with open(args.trace_out, "w") as fh:
        fh.write(blob1)
    print(f"wrote {args.trace_out} — open in https://ui.perfetto.dev")

    n_done = sum(1 for je in res.jobs if je.completion is not None)
    rows = [
        ("obs.traced_fleet.makespan_mcycles", res.makespan / 1e6),
        ("obs.traced_fleet.n_completed", float(n_done)),
        ("obs.traced_fleet.n_trace_events", float(len(tr1.events))),
        ("obs.traced_fleet.retries", float(fc.get("retries", 0))),
        ("obs.traced_fleet.jobs_lost", float(fc.get("jobs_lost", 0))),
    ]
    n = history.append_rows(args.history, rows)
    print(f"appended {n} rows to {args.history}")
    problems = history.check_regression(history.load_history(args.history))
    if problems:
        failures.append(f"perf history regressions: {problems}")
    else:
        print("perf history: newest rows within tolerance of trailing median")

    if failures:
        print("\nOBS SMOKE FAILURES:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nobs smoke: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
